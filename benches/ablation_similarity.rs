//! Experiment A4 — phase-1 graph-construction ablation: epsilon vs t-NN.
//!
//! The paper's phase 1 prices every pair and only then sparsifies by
//! `epsilon`; the knn subsystem builds the graph sparse, pruning candidate
//! pairs before their distance is fully evaluated. This bench runs both
//! modes at several n and reports stored entries (nnz), fully-priced
//! candidate pairs, the pruned-pair ratio and virtual phase-1 time — the
//! phase-1 perf trajectory the ROADMAP was missing.
//!
//! Emits `BENCH_similarity.json`: one point per n with both modes.
//! PASS requires the t-NN path to price strictly fewer candidate pairs
//! than the epsilon path at every n.

mod common;

use std::sync::Arc;

use psch::coordinator::similarity_job::run_similarity_phase;
use psch::coordinator::Services;
use psch::data::gaussian_blobs;
use psch::knn::run_tnn_phase;
use psch::mapreduce::names;
use psch::metrics::table::AsciiTable;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let ns: Vec<usize> = if quick { vec![240, 480] } else { vec![400, 800, 1600] };
    let m = 4;
    let d = 4;
    let sigma = 1.5;
    let epsilon = 1e-8;
    let t = 10;
    let runtime = common::runtime();

    let mut table = AsciiTable::new(&[
        "n", "mode", "virtual", "nnz", "pairs priced", "pruned", "pruned%",
    ]);
    let mut points = Vec::new();
    let mut pass = true;
    let mut last_virtual = 0.0f64;

    for &n in &ns {
        let ps = gaussian_blobs(n, 3, d, 0.4, 8.0, 11);

        // Epsilon mode: all pairs priced, sub-epsilon entries dropped.
        let mut cfg = common::calibrated_config(m);
        cfg.algo.k = 3;
        let svc = Services::from_config(&cfg, runtime.clone());
        let flat32: Vec<f32> = ps.points.iter().flatten().map(|&x| x as f32).collect();
        let eps_out =
            run_similarity_phase(&svc, Arc::new(flat32), n, d, sigma, epsilon, "S")
                .expect("epsilon phase");
        let eps_pairs = eps_out.counters.get(names::SIM_PAIRS_EVALUATED);
        table.row(&[
            n.to_string(),
            "epsilon".into(),
            format!("{:.0}s", eps_out.stats.virtual_s),
            eps_out.nnz.to_string(),
            eps_pairs.to_string(),
            "0".into(),
            "0.0".into(),
        ]);

        // t-NN mode: the kd-tree prunes candidates before pricing them.
        let mut cfg = common::calibrated_config(m);
        cfg.algo.k = 3;
        cfg.set("algo.graph", "tnn").expect("graph key");
        cfg.set("knn.t", &t.to_string()).expect("knn.t key");
        let svc = Services::from_config(&cfg, runtime.clone());
        let flat64: Vec<f64> = ps.points.iter().flatten().copied().collect();
        let tnn_out = run_tnn_phase(&svc, Arc::new(flat64), n, d, sigma, "S")
            .expect("tnn phase");
        let knn = tnn_out.stats.knn_summary();
        last_virtual = tnn_out.stats.virtual_s;
        table.row(&[
            n.to_string(),
            "tnn".into(),
            format!("{:.0}s", tnn_out.stats.virtual_s),
            tnn_out.nnz.to_string(),
            knn.pairs_evaluated.to_string(),
            knn.pruned_pairs.to_string(),
            format!("{:.1}", 100.0 * knn.pruned_ratio()),
        ]);

        if knn.pairs_evaluated >= eps_pairs {
            println!(
                "FAIL: n={n}: tnn priced {} pairs, epsilon {}",
                knn.pairs_evaluated, eps_pairs
            );
            pass = false;
        }
        if tnn_out.nnz == 0 || eps_out.nnz == 0 {
            println!("FAIL: n={n}: empty graph (tnn={}, eps={})", tnn_out.nnz, eps_out.nnz);
            pass = false;
        }
        points.push(format!(
            "{{\"n\":{n},\
             \"epsilon\":{{\"virtual_s\":{:.3},\"nnz\":{},\"pairs_evaluated\":{}}},\
             \"tnn\":{{\"virtual_s\":{:.3},\"nnz\":{},\"pairs_evaluated\":{},\
             \"pruned_pairs\":{},\"pruned_ratio\":{:.4},\"heap_evictions\":{}}}}}",
            eps_out.stats.virtual_s,
            eps_out.nnz,
            eps_pairs,
            tnn_out.stats.virtual_s,
            tnn_out.nnz,
            knn.pairs_evaluated,
            knn.pruned_pairs,
            knn.pruned_ratio(),
            knn.heap_evictions,
        ));
    }

    println!(
        "A4 graph-construction ablation (m={m}, d={d}, t={t}, epsilon={epsilon}):\n{}",
        table.render()
    );
    common::write_bench_json(
        "BENCH_similarity.json",
        &format!(
            "{{\"experiment\":\"similarity_graph_mode\",\"m\":{m},\"d\":{d},\
             \"t\":{t},\"epsilon\":{epsilon},\"curve\":[{}]}}",
            points.join(",")
        ),
    );
    common::log_trajectory("similarity", "BENCH_similarity.json", last_virtual, 11);
    if pass {
        println!(
            "ablation_similarity: PASS — the t-NN path prices strictly fewer \
             candidate pairs than the all-pairs epsilon path"
        );
    } else {
        println!("ablation_similarity: FAIL");
        std::process::exit(1);
    }
}
