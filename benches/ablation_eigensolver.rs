//! Experiment A2 — eigensolver ablation, two parts.
//!
//! Part A (the paper's §4.4 complexity claim): Lanczos (O(k·L_op + k²n)
//! with sparse L_op) vs the dense O(n³) eigensolver the "traditional"
//! algorithm needs. Measures real wall time of both solvers over growing
//! n and locates the crossover.
//!
//! Part B (the job-count claim the ChebDav backend makes): distributed
//! lanczos vs chebdav head-to-head on quick- and paper-shaped calibrated
//! configs. Per solver it reports eigen-phase jobs launched, virtual
//! time, shuffle bytes, mat-vecs batched, oracle max residual and NMI,
//! and emits the lot as `BENCH_eigensolver.json`. PASS requires chebdav
//! to launch strictly fewer eigen-phase jobs at paper scale.

mod common;

use psch::benchutil::time_once;
use psch::config::Config;
use psch::coordinator::eigen::EigenSolverKind;
use psch::coordinator::{Driver, PipelineInput};
use psch::eval::nmi;
use psch::linalg::{
    chebdav_smallest, jacobi_eigen, lanczos_smallest, ChebDavOptions, CsrMatrix,
    LanczosOptions,
};
use psch::metrics::table::AsciiTable;
use psch::spectral::{laplacian_dense, laplacian_sparse, rbf_dense, rbf_sparse};

/// Worst eigenpair residual ‖L·u − θ·u‖ over the k returned pairs
/// (`vecs` is n×k row-major, the layout both solvers return).
fn max_residual(l: &CsrMatrix, vals: &[f64], vecs: &[Vec<f64>]) -> f64 {
    let n = vecs.len();
    let k = vals.len();
    let mut worst = 0.0f64;
    for c in 0..k {
        let u: Vec<f64> = (0..n).map(|i| vecs[i][c]).collect();
        let lu = l.spmv(&u);
        let r2: f64 = (0..n)
            .map(|i| {
                let r = lu[i] - vals[c] * u[i];
                r * r
            })
            .sum();
        worst = worst.max(r2.sqrt());
    }
    worst
}

/// One solver's numbers on one config.
struct SolverRun {
    solver: &'static str,
    eigen_jobs: usize,
    matvecs_batched: u64,
    virtual_s: f64,
    shuffle_bytes: u64,
    max_residual: f64,
    nmi: f64,
}

/// Run the full distributed pipeline with the given backend and measure
/// the eigen phase; the oracle residual is computed on the same graph
/// with the matching single-machine solver.
fn head_to_head(
    cfg: &Config,
    n: usize,
    kind: EigenSolverKind,
    runtime: &std::sync::Arc<psch::runtime::KernelRuntime>,
) -> SolverRun {
    let mut cfg = cfg.clone();
    cfg.eigen.solver = kind;
    let k = cfg.algo.k;
    let ps = psch::data::gaussian_blobs(n, k, 8, 0.4, 8.0, cfg.algo.seed);
    let input = PipelineInput::Points { points: ps.points.clone() };

    // Oracle residual on the identical graph.
    let s = rbf_sparse(&ps.points, cfg.algo.sigma.fixed().unwrap(), cfg.algo.epsilon);
    let l = laplacian_sparse(&s);
    let resid = match kind {
        EigenSolverKind::Lanczos => {
            let r = lanczos_smallest(
                n,
                k,
                &LanczosOptions {
                    max_steps: cfg.algo.lanczos_steps.min(n),
                    seed: cfg.algo.seed,
                    ..Default::default()
                },
                |v| l.spmv(v),
            )
            .unwrap();
            max_residual(&l, &r.eigenvalues, &r.eigenvectors)
        }
        EigenSolverKind::ChebDav => {
            let e = &cfg.eigen;
            let r = chebdav_smallest(
                n,
                k,
                &ChebDavOptions {
                    block_size: e.block_size,
                    filter_degree: e.filter_degree,
                    max_outer: e.max_outer,
                    tol: e.residual_tol,
                    bound_steps: e.bound_steps,
                    seed: cfg.algo.seed,
                },
                |x, m| l.spmv_block_rows(x, m, 0, n),
            )
            .unwrap();
            max_residual(&l, &r.eigenvalues, &r.eigenvectors)
        }
    };

    let driver = Driver::new(cfg, runtime.clone());
    let result = driver.run(&input).unwrap();
    let eig = &result.phases[1];
    let es = eig.eigen_summary();
    SolverRun {
        solver: kind.as_str(),
        eigen_jobs: eig.jobs,
        matvecs_batched: es.matvecs_batched,
        virtual_s: eig.virtual_s,
        shuffle_bytes: eig.shuffle_bytes,
        max_residual: resid,
        nmi: nmi(&ps.labels, &result.labels),
    }
}

fn solver_json(r: &SolverRun) -> String {
    format!(
        "{{\"solver\":\"{}\",\"eigen_jobs\":{},\"matvecs_batched\":{},\
         \"virtual_s\":{:.3},\"shuffle_bytes\":{},\"max_residual\":{:.3e},\
         \"nmi\":{:.4}}}",
        r.solver,
        r.eigen_jobs,
        r.matvecs_batched,
        r.virtual_s,
        r.shuffle_bytes,
        r.max_residual,
        r.nmi,
    )
}

fn main() {
    // ---- Part A: dense Jacobi vs sparse Lanczos crossover. ----
    let k = 4;
    let mut table = AsciiTable::new(&[
        "n",
        "dense Jacobi (s)",
        "sparse Lanczos (s)",
        "speedup",
        "max |eig diff|",
    ]);
    let mut last_speedup = 0.0;
    let mut speedups = Vec::new();
    // n stops at 512: dense Jacobi is already 33 s there and the next
    // doubling costs ~400 s for no additional information (the O(n³)/O(nnz)
    // gap is decisive and still growing).
    for n in [64usize, 128, 256, 512] {
        let ps = psch::data::gaussian_blobs(n, k, 4, 0.4, 8.0, 11);
        // Dense path.
        let (dense_out, dense_t) = time_once(|| {
            let s = rbf_dense(&ps.points, 1.5);
            let l = laplacian_dense(&s);
            jacobi_eigen(&l).unwrap()
        });
        // Sparse Lanczos path.
        let (lanczos_out, lanczos_t) = time_once(|| {
            let s = rbf_sparse(&ps.points, 1.5, 1e-8);
            let l = laplacian_sparse(&s);
            lanczos_smallest(
                n,
                k,
                &LanczosOptions { max_steps: 60.min(n), ..Default::default() },
                |v| l.spmv(v),
            )
            .unwrap()
        });
        // Agreement on the k smallest eigenvalues.
        let max_diff = (0..k)
            .map(|i| (dense_out.0[i] - lanczos_out.eigenvalues[i]).abs())
            .fold(0.0, f64::max);
        last_speedup = dense_t.as_secs_f64() / lanczos_t.as_secs_f64();
        speedups.push((n, last_speedup));
        table.row(&[
            n.to_string(),
            format!("{:.4}", dense_t.as_secs_f64()),
            format!("{:.4}", lanczos_t.as_secs_f64()),
            format!("{last_speedup:.1}x"),
            format!("{max_diff:.2e}"),
        ]);
        assert!(
            max_diff < 1e-6,
            "solvers disagree at n={n}: {max_diff:.2e}"
        );
    }
    println!("A2 eigensolver ablation (k={k}):\n{}", table.render());

    // Shape: lanczos advantage must grow with n and be decisive at n=512.
    assert!(
        speedups.windows(2).filter(|w| w[1].1 > w[0].1).count() >= 2,
        "speedup should grow with n: {speedups:?}"
    );
    assert!(
        last_speedup > 5.0,
        "Lanczos should win clearly at n=512: {last_speedup:.1}x"
    );

    // ---- Part B: distributed lanczos vs chebdav head-to-head. ----
    // quick-shaped: 2 slaves, k=3, 40 lanczos steps vs a 6/6/4 chebdav.
    let mut quick = common::calibrated_config(2);
    quick.algo.k = 3;
    quick.algo.lanczos_steps = 40;
    quick.eigen.block_size = 6;
    quick.eigen.filter_degree = 6;
    quick.eigen.max_outer = 4;
    // paper-shaped: the Table 5-1 calibration at 8 slaves, chebdav defaults.
    let paper = common::calibrated_config(8);

    let mut table = AsciiTable::new(&[
        "config", "solver", "eigen jobs", "matvecs", "virtual", "shuffle", "resid",
        "NMI",
    ]);
    let runtime = common::runtime();
    let mut blocks = Vec::new();
    let mut paper_jobs = (0usize, 0usize); // (lanczos, chebdav)
    let mut last_eigen_virtual = 0.0f64;
    for (name, cfg, n) in [("quick", &quick, 600usize), ("paper", &paper, 2048)] {
        let mut runs = Vec::new();
        for kind in [EigenSolverKind::Lanczos, EigenSolverKind::ChebDav] {
            let r = head_to_head(cfg, n, kind, &runtime);
            last_eigen_virtual = r.virtual_s;
            table.row(&[
                name.to_string(),
                r.solver.to_string(),
                r.eigen_jobs.to_string(),
                r.matvecs_batched.to_string(),
                format!("{:.0}s", r.virtual_s),
                psch::util::fmt::human_bytes(r.shuffle_bytes),
                format!("{:.1e}", r.max_residual),
                format!("{:.3}", r.nmi),
            ]);
            runs.push(r);
        }
        assert!(
            runs[1].eigen_jobs < runs[0].eigen_jobs,
            "{name}: chebdav must launch fewer eigen jobs \
             (chebdav {} vs lanczos {})",
            runs[1].eigen_jobs,
            runs[0].eigen_jobs,
        );
        for r in &runs {
            assert!(r.nmi > 0.9, "{name}/{}: clustering degraded, NMI={}", r.solver, r.nmi);
            assert!(
                r.max_residual < 1e-2,
                "{name}/{}: residual blew up: {}",
                r.solver,
                r.max_residual
            );
        }
        if name == "paper" {
            paper_jobs = (runs[0].eigen_jobs, runs[1].eigen_jobs);
        }
        let solvers: Vec<String> = runs.iter().map(solver_json).collect();
        blocks.push(format!(
            "{{\"name\":\"{name}\",\"n\":{n},\"solvers\":[{}]}}",
            solvers.join(",")
        ));
    }
    println!("A2 distributed head-to-head:\n{}", table.render());

    common::write_bench_json(
        "BENCH_eigensolver.json",
        &format!(
            "{{\"bench\":\"eigensolver\",\"configs\":[{}]}}\n",
            blocks.join(",")
        ),
    );
    common::log_trajectory("eigensolver", "BENCH_eigensolver.json", last_eigen_virtual, 11);

    println!(
        "ablation_eigensolver: PASS — O(n^3) dense loses by {last_speedup:.0}x at n=512; \
         chebdav launches {} eigen jobs vs lanczos {} at paper scale",
        paper_jobs.1, paper_jobs.0
    );
}
