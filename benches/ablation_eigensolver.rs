//! Experiment A2 — ablation of the paper's §4.4 complexity claim: Lanczos
//! (O(k·L_op + k²n) with sparse L_op) vs the dense O(n³) eigensolver the
//! "traditional" algorithm needs. Measures real wall time of both solvers
//! over growing n and locates the crossover.

use psch::benchutil::time_once;
use psch::linalg::{jacobi_eigen, lanczos_smallest, LanczosOptions};
use psch::metrics::table::AsciiTable;
use psch::spectral::{laplacian_dense, laplacian_sparse, rbf_dense, rbf_sparse};

fn main() {
    let k = 4;
    let mut table = AsciiTable::new(&[
        "n",
        "dense Jacobi (s)",
        "sparse Lanczos (s)",
        "speedup",
        "max |eig diff|",
    ]);
    let mut last_speedup = 0.0;
    let mut speedups = Vec::new();
    // n stops at 512: dense Jacobi is already 33 s there and the next
    // doubling costs ~400 s for no additional information (the O(n³)/O(nnz)
    // gap is decisive and still growing).
    for n in [64usize, 128, 256, 512] {
        let ps = psch::data::gaussian_blobs(n, k, 4, 0.4, 8.0, 11);
        // Dense path.
        let (dense_out, dense_t) = time_once(|| {
            let s = rbf_dense(&ps.points, 1.5);
            let l = laplacian_dense(&s);
            jacobi_eigen(&l).unwrap()
        });
        // Sparse Lanczos path.
        let (lanczos_out, lanczos_t) = time_once(|| {
            let s = rbf_sparse(&ps.points, 1.5, 1e-8);
            let l = laplacian_sparse(&s);
            lanczos_smallest(
                n,
                k,
                &LanczosOptions { max_steps: 60.min(n), ..Default::default() },
                |v| l.spmv(v),
            )
            .unwrap()
        });
        // Agreement on the k smallest eigenvalues.
        let max_diff = (0..k)
            .map(|i| (dense_out.0[i] - lanczos_out.eigenvalues[i]).abs())
            .fold(0.0, f64::max);
        last_speedup = dense_t.as_secs_f64() / lanczos_t.as_secs_f64();
        speedups.push((n, last_speedup));
        table.row(&[
            n.to_string(),
            format!("{:.4}", dense_t.as_secs_f64()),
            format!("{:.4}", lanczos_t.as_secs_f64()),
            format!("{last_speedup:.1}x"),
            format!("{max_diff:.2e}"),
        ]);
        assert!(
            max_diff < 1e-6,
            "solvers disagree at n={n}: {max_diff:.2e}"
        );
    }
    println!("A2 eigensolver ablation (k={k}):\n{}", table.render());

    // Shape: lanczos advantage must grow with n and be decisive at n=512.
    assert!(
        speedups.windows(2).filter(|w| w[1].1 > w[0].1).count() >= 2,
        "speedup should grow with n: {speedups:?}"
    );
    assert!(
        last_speedup > 5.0,
        "Lanczos should win clearly at n=512: {last_speedup:.1}x"
    );
    println!(
        "ablation_eigensolver: PASS — O(n^3) dense loses by {last_speedup:.0}x at n=512, gap grows with n"
    );
}
