//! Experiment F5 — regenerate the paper's **Fig. 5** trend chart: total
//! pipeline time (and speedup/efficiency series) vs slave count.
//!
//! Same workload and calibration as benches/table1.rs, finer slave sweep,
//! plotted as ASCII (the paper's chart is a line plot of Table 5-1 totals).

mod common;

use psch::coordinator::PipelineInput;
use psch::data::gaussian_blobs;
use psch::metrics::speedup::SpeedupCurve;
use psch::util::fmt::hms;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let n: usize = if quick { 2_048 } else { 10_029 };
    let runtime = common::runtime();
    println!("fig5: n={n}, backend {:?}", runtime.backend());
    let dataset = gaussian_blobs(n, 4, 8, 0.4, 8.0, 42);
    let input = PipelineInput::Points { points: dataset.points.clone() };

    let sweep = [1usize, 2, 3, 4, 5, 6, 7, 8, 9, 10];
    let mut curve = SpeedupCurve::default();
    let mut runs_json: Vec<String> = Vec::new();
    let mut last_total = 0.0f64;
    for &m in &sweep {
        let driver = common::driver_for(m, &runtime);
        let result = driver.run(&input).expect("pipeline");
        curve.push(m, result.total_virtual_s);
        last_total = result.total_virtual_s;
        println!(
            "m={m:>2}: {}",
            hms(std::time::Duration::from_secs_f64(result.total_virtual_s))
        );
        for p in &result.phases {
            println!("      shuffle[{}]: {}", p.name, p.shuffle_summary().render());
        }
        runs_json.push(common::run_json(m, &result));
    }
    common::write_bench_json(
        "BENCH_fig5.json",
        &format!(
            "{{\"bench\":\"fig5\",\"n\":{n},\"runs\":[{}]}}\n",
            runs_json.join(",")
        ),
    );
    common::log_trajectory("fig5", "BENCH_fig5.json", last_total, 42);

    println!("\ntotal-time trend (Fig. 5):\n{}", curve.ascii_plot(60, 14));
    println!("speedup series:");
    for (m, s) in curve.speedups() {
        let bar = "#".repeat((s * 8.0).round() as usize);
        println!("  m={m:>2} {s:>5.2}x {bar}");
    }
    println!("\nparallel efficiency:");
    for (m, e) in curve.efficiencies() {
        println!("  m={m:>2} {:>5.1}%", e * 100.0);
    }

    // Fig. 5 observations: "From 1 to 2 sets ... reduce the time or so
    // commonly"; "speedup growth began to slow"; flattening at the end.
    let speedups = curve.speedups();
    let s2 = speedups.iter().find(|&&(m, _)| m == 2).unwrap().1;
    assert!(s2 > 1.25, "1->2 slaves should give a substantial cut: {s2:.2}x");
    let eff = curve.efficiencies();
    let e2 = eff.iter().find(|&&(m, _)| m == 2).unwrap().1;
    let e10 = eff.iter().find(|&&(m, _)| m == 10).unwrap().1;
    assert!(
        e10 < e2,
        "efficiency must decay with m: e2={e2:.2}, e10={e10:.2}"
    );
    // The paper's flattening claim is between 8 and 10 slaves.
    let t8 = curve.points().iter().find(|p| p.machines == 8).unwrap().seconds;
    let t10 = curve.points().iter().find(|p| p.machines == 10).unwrap().seconds;
    let gain = (t8 - t10) / t8;
    assert!(gain < 0.10, "8->10 should flatten: {:.1}%", gain * 100.0);
    println!("\nfig5: trend shape checks PASS");
}
