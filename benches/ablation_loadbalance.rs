//! Experiment A1 — ablation of the paper's §4.3.1 load-balancing claim.
//!
//! The similarity phase's work is triangular: row-index block b computes
//! `nb - b` tiles, so naive *contiguous* chunking of indices into one task
//! per slot gives the first task ~2× the mean work and the makespan is
//! bounded by it. The paper pairs index i with n−i+1 so every task carries
//! the same work. We compare three assignments at a fixed task count of one
//! wave per slot (Hadoop's ideal):
//!
//!   - `paired`     — the paper's {b, nb−1−b} pairing,
//!   - `contiguous` — equal-count contiguous index ranges (the strawman the
//!     paper's trick fixes),
//!   - `fine`       — one task per index (imbalanced but over-decomposed;
//!     pull scheduling self-balances at the cost of nb dispatches).
//!
//! Reported: per-task work spread and virtual makespan per slave count.
//!
//! Experiment A2 rides along: the JobTracker locality ablation — the same
//! phase-1 similarity job on a 4-slave / 2-rack cluster under the
//! locality-first policy vs blind FIFO, comparing the data-local map
//! percentage and the virtual input-read time the new counters report.

mod common;

use psch::benchutil::locality_ablation_run;
use psch::cluster::{schedule, NetworkModel, TaskCost};
use psch::metrics::table::AsciiTable;
use psch::scheduler::Policy;

const SECONDS_PER_TILE: f64 = 3.8; // calibrated phase-1 tile cost

/// Work of row-block b in tiles.
fn work(b: usize, nb: usize) -> usize {
    nb - b
}

/// Paper pairing folded into `tasks` equal groups.
fn paired_assignment(nb: usize, tasks: usize) -> Vec<usize> {
    let mut buckets = vec![0usize; tasks];
    let pairs = nb.div_ceil(2);
    for p in 0..pairs {
        let mut w = work(p, nb);
        let mirror = nb - 1 - p;
        if mirror != p {
            w += work(mirror, nb);
        }
        buckets[p % tasks] += w;
    }
    buckets
}

/// Contiguous equal-count chunks.
fn contiguous_assignment(nb: usize, tasks: usize) -> Vec<usize> {
    let per = nb.div_ceil(tasks);
    (0..tasks)
        .map(|t| {
            (t * per..((t + 1) * per).min(nb))
                .map(|b| work(b, nb))
                .sum()
        })
        .collect()
}

/// One task per index.
fn fine_assignment(nb: usize) -> Vec<usize> {
    (0..nb).map(|b| work(b, nb)).collect()
}

fn makespan(tile_counts: &[usize], m: usize, model: &NetworkModel) -> f64 {
    let tasks: Vec<TaskCost> = tile_counts
        .iter()
        .filter(|&&t| t > 0)
        .map(|&t| TaskCost {
            compute_s: t as f64 * SECONDS_PER_TILE / model.compute_scale,
            input_bytes: 0,
            output_bytes: 0,
        })
        .collect();
    model.job_overhead(m) + schedule(&tasks, m * 2, model, None).makespan_s
}

fn spread(tile_counts: &[usize]) -> f64 {
    let max = *tile_counts.iter().max().unwrap() as f64;
    let mean = tile_counts.iter().sum::<usize>() as f64
        / tile_counts.iter().filter(|&&t| t > 0).count() as f64;
    max / mean
}

fn locality_ablation() -> bool {
    let (local, vt_local) = locality_ablation_run(Policy::default());
    let (fifo, vt_fifo) = locality_ablation_run(Policy::Fifo);
    let mut table = AsciiTable::new(&[
        "policy",
        "data-local",
        "rack-local",
        "off-rack",
        "virtual read",
        "phase virtual",
    ]);
    for (name, s, vt) in [("locality", &local, vt_local), ("fifo", &fifo, vt_fifo)] {
        table.row(&[
            name.to_string(),
            format!("{:.1}%", s.data_local_pct()),
            format!("{:.1}%", s.rack_local_pct()),
            format!("{:.1}%", s.off_rack_pct()),
            format!("{:.1}ms", s.virtual_read_s * 1e3),
            format!("{vt:.0}s"),
        ]);
    }
    println!(
        "\nA2 locality ablation (similarity job, 4 slaves / 2 racks):\n{}",
        table.render()
    );
    let pass = local.data_local_pct() > fifo.data_local_pct()
        && local.virtual_read_s < fifo.virtual_read_s;
    println!(
        "locality-first raises data-local % and lowers read time: {}",
        if pass { "PASS" } else { "FAIL" }
    );
    pass
}

fn main() {
    let nb = 79; // paper scale: ceil(10029 / 128)
    let model = common::calibrated_config(1).cluster.network;

    let mut table = AsciiTable::new(&[
        "slaves",
        "paired (paper)",
        "contiguous",
        "fine-grained",
        "paired vs contiguous",
    ]);
    let mut pass = true;
    for m in [1usize, 2, 4, 6, 8, 10] {
        let slots = m * 2;
        let paired = paired_assignment(nb, slots);
        let contiguous = contiguous_assignment(nb, slots);
        let fine = fine_assignment(nb);
        let tp = makespan(&paired, m, &model);
        let tc = makespan(&contiguous, m, &model);
        let tf = makespan(&fine, m, &model);
        let gain = (tc - tp) / tc * 100.0;
        table.row(&[
            m.to_string(),
            format!("{tp:.0}s"),
            format!("{tc:.0}s"),
            format!("{tf:.0}s"),
            format!("{gain:+.1}%"),
        ]);
        if m >= 2 {
            pass &= tp < tc; // pairing must beat the strawman when parallel
        }
        if m >= 4 {
            pass &= gain > 10.0; // ...and decisively at real parallelism
        }
    }
    println!("A1 load-balance ablation (nb={nb} row blocks):\n{}", table.render());
    println!(
        "work spread (max/mean) at 16 slots: paired {:.3}, contiguous {:.3}, fine {:.3}",
        spread(&paired_assignment(nb, 16)),
        spread(&contiguous_assignment(nb, 16)),
        spread(&fine_assignment(nb)),
    );
    println!(
        "dispatch overheads per wave: paired/contiguous = #slots tasks, fine = {nb} tasks"
    );
    pass &= locality_ablation();
    if pass {
        println!(
            "ablation_loadbalance: PASS — the paper's pairing and the \
             locality-aware scheduler are both justified"
        );
    } else {
        println!("ablation_loadbalance: FAIL");
        std::process::exit(1);
    }
}
