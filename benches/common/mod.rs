#![allow(dead_code)]
//! Shared helpers for the bench targets (no criterion offline — see
//! `psch::benchutil`).

use std::sync::Arc;

use psch::config::Config;
use psch::coordinator::{Driver, Services};
use psch::runtime::KernelRuntime;

/// Paper Table 5-1, in seconds: (slaves, similarity, eigen, kmeans, total).
pub const PAPER_TABLE1: [(usize, f64, f64, f64, f64); 6] = [
    (1, 6106.0, 8894.0, 1725.0, 15885.0),
    (2, 3525.0, 6347.0, 1356.0, 11468.0),
    (4, 1856.0, 5110.0, 1089.0, 8895.0),
    (6, 1403.0, 4244.0, 886.0, 6473.0),
    (8, 1275.0, 3619.0, 779.0, 5673.0),
    (10, 1349.0, 3699.0, 705.0, 5753.0),
];

/// The cost-model calibration used for the paper-scale reproduction
/// (EXPERIMENTS.md §T1 explains each constant).
pub fn calibrated_config(m: usize) -> Config {
    let mut cfg = Config::default();
    cfg.cluster.slaves = m;
    cfg.cluster.slots_per_slave = 2; // paper §4.4: two map slots per machine
    cfg.algo.k = 4;
    cfg.algo.sigma = 1.5.into();
    cfg.algo.epsilon = 1e-8;
    cfg.algo.lanczos_steps = 60;
    cfg.algo.kmeans_iters = 20;
    // 2011-era Hadoop constants: multi-second task start, HBase scans far
    // slower than raw disk, per-machine coordination that grows with m.
    // Task COMPUTE is modeled deterministically by the tasks themselves
    // (coordinator::costmodel reference rates), so compute_scale stays 1.
    cfg.cluster.network.job_setup_s = 5.0;
    cfg.cluster.network.task_dispatch_s = 2.0;
    cfg.cluster.network.disk_bw = 5e6; // effective HBase scan rate
    cfg.cluster.network.net_bw = 40e6;
    cfg.cluster.network.coord_per_machine_s = 3.5;
    cfg.cluster.network.shuffle_latency_s = 1.5;
    cfg.cluster.network.compute_scale = 1.0;
    cfg
}

/// Driver with the shared runtime (XLA if artifacts exist).
pub fn driver_for(m: usize, runtime: &Arc<KernelRuntime>) -> Driver {
    Driver::new(calibrated_config(m), runtime.clone())
}

/// Calibrated services for ad-hoc jobs at slave count `m` — the same
/// [`Services::from_config`] constructor the driver uses, so benches never
/// hand-roll cluster/topology/tracker wiring again.
pub fn services_for(m: usize, runtime: &Arc<KernelRuntime>) -> Services {
    Services::from_config(&calibrated_config(m), runtime.clone())
}

/// Load the kernel runtime once per bench process.
pub fn runtime() -> Arc<KernelRuntime> {
    Arc::new(KernelRuntime::auto(&psch::runtime::artifacts_dir()))
}

/// Percent difference helper.
pub fn pct(ours: f64, paper: f64) -> f64 {
    (ours - paper) / paper * 100.0
}

/// One phase's timing + shuffle trajectory as a JSON object (hand-rolled —
/// the offline vendor set has no serde).
pub fn phase_json(p: &psch::coordinator::PhaseStats) -> String {
    let s = p.shuffle_summary();
    format!(
        "{{\"name\":\"{}\",\"virtual_s\":{:.3},\"jobs\":{},\
         \"shuffle_bytes\":{},\"spilled_records\":{},\"merge_passes\":{},\
         \"shuffle_fetch_s\":{:.3},\"fetch_bytes_local\":{},\
         \"fetch_bytes_rack\":{},\"fetch_bytes_remote\":{}}}",
        p.name,
        p.virtual_s,
        p.jobs,
        p.shuffle_bytes,
        s.spilled_records,
        s.merge_passes,
        p.shuffle_fetch_s,
        s.fetch_node_local,
        s.fetch_rack_local,
        s.fetch_off_rack,
    )
}

/// One pipeline run (at slave count `m`) as a JSON object.
pub fn run_json(m: usize, result: &psch::coordinator::PipelineResult) -> String {
    let phases: Vec<String> = result.phases.iter().map(phase_json).collect();
    format!(
        "{{\"m\":{m},\"total_virtual_s\":{:.3},\"phases\":[{}]}}",
        result.total_virtual_s,
        phases.join(",")
    )
}

/// Append this bench's row to the shared `BENCH_trajectory.json` log —
/// call right after [`write_bench_json`] so the log always points at a
/// payload that exists.
pub fn log_trajectory(bench: &str, report: &str, makespan_s: f64, seed: u64) {
    psch::benchutil::append_trajectory(&psch::benchutil::TrajectoryRow {
        bench,
        report,
        makespan_s,
        seed,
    });
}

/// Write a BENCH_*.json payload at the repo root: relative paths are
/// anchored at `CARGO_MANIFEST_DIR`, so every bench's JSON lands beside
/// Cargo.toml no matter what directory invoked it. Failures only warn
/// (benches must keep running on read-only checkouts).
pub fn write_bench_json(path: &str, payload: &str) {
    let p = std::path::Path::new(path);
    let anchored;
    let target = if p.is_absolute() {
        p
    } else {
        anchored = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(p);
        anchored.as_path()
    };
    match std::fs::write(target, payload) {
        Ok(()) => println!("wrote {}", target.display()),
        Err(e) => eprintln!("warning: could not write {}: {e}", target.display()),
    }
}
