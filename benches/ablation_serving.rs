//! Experiment A6 — serving-layer ablation.
//!
//! Trains one paper-calibrated pipeline, captures the model artifact, then
//! assigns a held-out stream through the distributed Nyström path at
//! several batch sizes (plus a mini-batch-refresh run). Per setting it
//! reports batches launched, virtual seconds under the cost model and the
//! assignment throughput in points/s, checks the distributed labels
//! against the single-machine oracle, and emits `BENCH_serving.json`.
//! PASS requires oracle/distributed agreement everywhere and larger
//! batches to amortize job setup into higher points/s.

mod common;

use psch::coordinator::{Driver, PipelineInput};
use psch::data::gaussian_blobs;
use psch::eval::nmi;
use psch::metrics::table::AsciiTable;
use psch::serving::{
    assign_stream_oracle, run_assign, ModelArtifact, RefreshMode, ServingConfig,
};

fn main() {
    let runtime = common::runtime();
    // Train once at the Table 5-1 calibration (4 slaves) with a landmark
    // budget, the realistic serving setting.
    let mut cfg = common::calibrated_config(4);
    cfg.serving.landmarks = 128;
    let n_train = 1024usize;
    let ps = gaussian_blobs(n_train, cfg.algo.k, 8, 0.4, 8.0, cfg.algo.seed);
    let driver = Driver::new(cfg.clone(), runtime.clone());
    let result = driver
        .run(&PipelineInput::Points { points: ps.points.clone() })
        .unwrap();
    let model =
        ModelArtifact::from_run(driver.config(), &ps.points, &result).unwrap();
    println!(
        "trained: n={n_train}, k={}, {} landmarks, sigma={:.3}",
        model.k,
        model.m(),
        model.sigma
    );

    // A held-out stream from a different seed.
    let n_stream = 2048usize;
    let held = gaussian_blobs(n_stream, cfg.algo.k, 8, 0.4, 8.0, cfg.algo.seed + 1);
    let flat: Vec<f64> = held.points.iter().flatten().copied().collect();

    let mut table = AsciiTable::new(&[
        "batch", "refresh", "batches", "virtual", "points/s", "NMI",
    ]);
    let mut blocks = Vec::new();
    let mut rates = Vec::new();
    let mut last_virtual = 0.0f64;
    for (batch, refresh) in [
        (128usize, RefreshMode::Off),
        (256, RefreshMode::Off),
        (512, RefreshMode::Off),
        (256, RefreshMode::Minibatch),
    ] {
        let scfg = ServingConfig {
            landmarks: cfg.serving.landmarks,
            batch_points: batch,
            refresh,
        };
        let services = driver.services();
        let run = run_assign(&services, &model, &flat, &scfg).unwrap();
        let oracle = assign_stream_oracle(&model, &flat, &scfg).unwrap();
        assert_eq!(
            run.labels, oracle.labels,
            "batch={batch}/{}: distributed must match the oracle",
            refresh.as_str()
        );
        let s = run.stats.serving_summary();
        let rate = n_stream as f64 / run.stats.virtual_s;
        last_virtual = run.stats.virtual_s;
        let quality = nmi(&held.labels, &run.labels);
        assert!(
            quality > 0.9,
            "batch={batch}: held-out assignment degraded, NMI={quality:.3}"
        );
        if refresh == RefreshMode::Off {
            rates.push((batch, rate));
        } else {
            assert!(s.refresh_updates > 0, "refresh run must apply updates");
        }
        table.row(&[
            batch.to_string(),
            refresh.as_str().to_string(),
            s.batches.to_string(),
            format!("{:.0}s", run.stats.virtual_s),
            format!("{rate:.2}"),
            format!("{quality:.3}"),
        ]);
        blocks.push(format!(
            "{{\"batch_points\":{batch},\"refresh\":\"{}\",\"batches\":{},\
             \"refresh_updates\":{},\"virtual_s\":{:.3},\
             \"points_per_s\":{:.3},\"nmi\":{:.4}}}",
            refresh.as_str(),
            s.batches,
            s.refresh_updates,
            run.stats.virtual_s,
            rate,
            quality,
        ));
    }
    println!("A6 serving ablation (stream n={n_stream}):\n{}", table.render());

    // Bigger batches amortize per-pipeline job setup: throughput must rise
    // monotonically over the refresh-off sweep.
    for w in rates.windows(2) {
        assert!(
            w[1].1 > w[0].1,
            "points/s should grow with batch size: {rates:?}"
        );
    }

    common::write_bench_json(
        "BENCH_serving.json",
        &format!(
            "{{\"bench\":\"serving\",\"n_train\":{n_train},\
             \"n_stream\":{n_stream},\"landmarks\":{},\"sigma\":{:.6},\
             \"runs\":[{}]}}\n",
            model.m(),
            model.sigma,
            blocks.join(",")
        ),
    );
    common::log_trajectory("serving", "BENCH_serving.json", last_virtual, cfg.algo.seed);

    let (best_batch, best_rate) =
        rates.iter().copied().fold((0usize, 0.0f64), |acc, r| {
            if r.1 > acc.1 {
                r
            } else {
                acc
            }
        });
    println!(
        "ablation_serving: PASS — oracle/distributed agree on all runs; \
         best {best_rate:.1} points/s at batch={best_batch}"
    );
}
