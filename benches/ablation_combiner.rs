//! Experiment A3 — ablation: the k-means map-side combiner (paper §4.3.3
//! emits per-center partial sums from each map task) vs a naive
//! implementation that shuffles one record *per point*. Measures shuffle
//! bytes and virtual job time on the real MR engine.

mod common;

use std::sync::Arc;

use psch::mapreduce::{
    self, FnMapper, FnReducer, JobBuilder, TaskContext, Values,
};
use psch::metrics::table::AsciiTable;
use psch::util::bytes::{decode_f64_vec, decode_u64, encode_f64_vec, encode_u32, encode_u64};
use psch::util::Xoshiro256;

const N: usize = 50_000;
const D: usize = 8;
const K: usize = 8;
const PER_TASK: usize = 2_000;

fn data() -> (Arc<Vec<f64>>, Vec<Vec<f64>>) {
    let mut rng = Xoshiro256::new(3);
    let points: Vec<f64> = (0..N * D).map(|_| rng.next_f64() * 10.0).collect();
    let centers: Vec<Vec<f64>> = (0..K)
        .map(|_| (0..D).map(|_| rng.next_f64() * 10.0).collect())
        .collect();
    (Arc::new(points), centers)
}

fn splits() -> Vec<Vec<(Vec<u8>, Vec<u8>)>> {
    (0..N)
        .step_by(PER_TASK)
        .map(|lo| {
            vec![(
                encode_u64(lo as u64).to_vec(),
                encode_u64(((lo + PER_TASK).min(N)) as u64).to_vec(),
            )]
        })
        .collect()
}

fn nearest(p: &[f64], centers: &[Vec<f64>]) -> usize {
    centers
        .iter()
        .enumerate()
        .map(|(c, ctr)| (c, psch::linalg::vector::sq_dist(p, ctr)))
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap()
        .0
}

/// One k-means iteration; `combine` selects the paper's combiner layout.
fn run_iteration(
    combine: bool,
    runtime: &Arc<psch::runtime::KernelRuntime>,
) -> (f64, u64, Vec<Vec<f64>>) {
    let (points, centers) = data();
    let centers_arc = Arc::new(centers);
    // Shared constructor: same cluster wiring as the driver/benches.
    let cluster = common::services_for(8, runtime).cluster;

    let pts = points.clone();
    let ctrs = centers_arc.clone();
    let mapper = Arc::new(FnMapper(
        move |key: &[u8], value: &[u8], ctx: &mut TaskContext| {
            let lo = decode_u64(key) as usize;
            let hi = decode_u64(value) as usize;
            if combine {
                // Paper layout: per-center partials from the whole split.
                let mut sums = vec![vec![0.0f64; D]; K];
                let mut counts = vec![0.0f64; K];
                for i in lo..hi {
                    let p = &pts[i * D..(i + 1) * D];
                    let c = nearest(p, &ctrs);
                    counts[c] += 1.0;
                    for t in 0..D {
                        sums[c][t] += p[t];
                    }
                }
                for c in 0..K {
                    let mut payload = sums[c].clone();
                    payload.push(counts[c]);
                    ctx.emit(encode_u32(c as u32).to_vec(), encode_f64_vec(&payload));
                }
            } else {
                // Naive layout: one shuffled record per point.
                for i in lo..hi {
                    let p = &pts[i * D..(i + 1) * D];
                    let c = nearest(p, &ctrs);
                    let mut payload = p.to_vec();
                    payload.push(1.0);
                    ctx.emit(encode_u32(c as u32).to_vec(), encode_f64_vec(&payload));
                }
            }
            Ok(())
        },
    ));
    let reducer = Arc::new(FnReducer(
        |key: &[u8], values: &mut dyn Values, ctx: &mut TaskContext| {
            let mut sums = vec![0.0f64; D];
            let mut count = 0.0;
            while let Some(v) = values.next_value() {
                let (payload, _) = decode_f64_vec(v);
                for t in 0..D {
                    sums[t] += payload[t];
                }
                count += payload[D];
            }
            let center: Vec<f64> = sums.iter().map(|s| s / count.max(1.0)).collect();
            ctx.emit(key.to_vec(), encode_f64_vec(&center));
            Ok(())
        },
    ));
    let job = JobBuilder::new("kmeans-iter", splits(), mapper)
        .reducer(reducer, K)
        .build();
    let mut result = mapreduce::run(&cluster, &job).unwrap();
    let mut new_centers = vec![vec![0.0; D]; K];
    for (k, v) in result.sorted_records() {
        new_centers[psch::util::bytes::decode_u32(&k) as usize] = decode_f64_vec(&v).0;
    }
    (result.stats.virtual_time_s, result.stats.shuffle_bytes, new_centers)
}

fn main() {
    println!("A3 combiner ablation: n={N}, d={D}, k={K}, m=8 slaves");
    let runtime = common::runtime(); // load once per bench process
    let (t_comb, b_comb, c_comb) = run_iteration(true, &runtime);
    let (t_naive, b_naive, c_naive) = run_iteration(false, &runtime);

    let mut table =
        AsciiTable::new(&["variant", "shuffle bytes", "virtual time (s)"]);
    table.row(&[
        "with combiner (paper)".into(),
        psch::util::fmt::human_bytes(b_comb),
        format!("{t_comb:.1}"),
    ]);
    table.row(&[
        "naive per-point shuffle".into(),
        psch::util::fmt::human_bytes(b_naive),
        format!("{t_naive:.1}"),
    ]);
    println!("{}", table.render());
    println!(
        "shuffle reduction: {:.0}x; time reduction: {:.2}x",
        b_naive as f64 / b_comb as f64,
        t_naive / t_comb
    );

    // Both layouts must produce identical centers.
    for c in 0..K {
        for t in 0..D {
            assert!(
                (c_comb[c][t] - c_naive[c][t]).abs() < 1e-9,
                "centers diverge at ({c},{t})"
            );
        }
    }
    assert!(b_comb * 100 < b_naive, "combiner should cut shuffle >100x");
    assert!(t_comb < t_naive, "combiner should cut virtual time");
    println!("ablation_combiner: PASS — combiner justified, same result");
}
