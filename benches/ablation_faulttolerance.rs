//! Experiment A3 — the paper-style fault-tolerance curve.
//!
//! The paper's case for Hadoop is that the framework "guarantee[s] the
//! convergence to the optimal solution" on commodity clusters *because* it
//! survives task and node failures. This bench measures what that
//! survival costs: the full three-phase pipeline on a 6-slave cluster
//! with 0, 1, 2 and 3 scheduled node deaths (staggered on the cluster
//! heartbeat clock), reporting virtual job time, the recovery counters
//! (MAP_RERUNS / FETCH_FAILURES / NODE_DEATHS) and the invariant that the
//! clustering itself never changes — only virtual time does.
//!
//! Emits `BENCH_faults.json`: one point per injected-death count.

mod common;

use psch::cluster::NodeDeath;
use psch::coordinator::PipelineInput;
use psch::data::gaussian_blobs;
use psch::mapreduce::names;
use psch::metrics::table::AsciiTable;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let n = if quick { 400 } else { 1200 };
    let m = 6;
    let runtime = common::runtime();

    let mut cfg = common::calibrated_config(m);
    cfg.algo.k = 3;
    cfg.algo.lanczos_steps = if quick { 30 } else { 50 };
    cfg.algo.kmeans_iters = 10;
    cfg.cluster.racks = 2;
    cfg.cluster.replication = 2;

    let ps = gaussian_blobs(n, cfg.algo.k, 8, 0.4, 8.0, cfg.algo.seed);
    let input = PipelineInput::Points { points: ps.points.clone() };

    let mut table = AsciiTable::new(&[
        "deaths",
        "virtual total",
        "slowdown",
        "MAP_RERUNS",
        "FETCH_FAILURES",
        "failed attempts",
    ]);
    let mut points = Vec::new();
    let mut baseline_labels: Option<Vec<usize>> = None;
    let mut baseline_s = 0.0f64;
    let mut last_virtual = 0.0f64;
    let mut pass = true;

    for deaths in 0..=3usize {
        // Stagger the kills so re-replication and re-planning settle
        // between blows (slave 0 stays alive throughout).
        let driver =
            psch::coordinator::Driver::new(cfg_with_deaths(&cfg, deaths), runtime.clone());
        let r = driver.run(&input).expect("pipeline must survive the deaths");

        let counter = |name: &str| -> u64 {
            r.phases.iter().map(|p| p.counters.get(name)).sum()
        };
        if let Some(labels) = &baseline_labels {
            if labels != &r.labels {
                println!("FAIL: {deaths} deaths changed the clustering");
                pass = false;
            }
        } else {
            baseline_labels = Some(r.labels.clone());
            baseline_s = r.total_virtual_s;
        }
        let fired = counter(names::NODE_DEATHS);
        if fired != deaths as u64 {
            println!("FAIL: scheduled {deaths} deaths, observed {fired}");
            pass = false;
        }
        let slowdown = r.total_virtual_s / baseline_s;
        last_virtual = r.total_virtual_s;
        let failed = counter(names::FAILED_MAP_ATTEMPTS)
            + counter(names::FAILED_REDUCE_ATTEMPTS);
        table.row(&[
            deaths.to_string(),
            format!("{:.0}s", r.total_virtual_s),
            format!("{slowdown:.3}x"),
            counter(names::MAP_RERUNS).to_string(),
            counter(names::FETCH_FAILURES).to_string(),
            failed.to_string(),
        ]);
        points.push(format!(
            "{{\"deaths\":{deaths},\"total_virtual_s\":{:.3},\"slowdown\":{slowdown:.4},\
             \"map_reruns\":{},\"fetch_failures\":{},\"node_deaths\":{},\
             \"failed_attempts\":{failed},\"labels_identical\":{}}}",
            r.total_virtual_s,
            counter(names::MAP_RERUNS),
            counter(names::FETCH_FAILURES),
            fired,
            baseline_labels.as_ref() == Some(&r.labels),
        ));
    }

    println!(
        "A3 fault-tolerance curve (n={n}, m={m}, staggered node deaths):\n{}",
        table.render()
    );
    common::write_bench_json(
        "BENCH_faults.json",
        &format!(
            "{{\"experiment\":\"fault_tolerance\",\"n\":{n},\"m\":{m},\
             \"curve\":[{}]}}",
            points.join(",")
        ),
    );
    common::log_trajectory("faults", "BENCH_faults.json", last_virtual, cfg.algo.seed);
    if pass {
        println!(
            "ablation_faulttolerance: PASS — node deaths cost virtual time, \
             never correctness"
        );
    } else {
        println!("ablation_faulttolerance: FAIL");
        std::process::exit(1);
    }
}

/// The base config with `deaths` staggered node kills scheduled.
fn cfg_with_deaths(base: &psch::config::Config, deaths: usize) -> psch::config::Config {
    let mut c = base.clone();
    c.faults.node_deaths = (0..deaths)
        .map(|i| NodeDeath { slave: i + 1, at_heartbeat: 20 + 60 * i as u64 })
        .collect();
    c.validate().expect("bench config");
    c
}
