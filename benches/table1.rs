//! Experiment T1 — regenerate the paper's **Table 5-1**: per-phase time of
//! the parallel pipeline at slave counts {1, 2, 4, 6, 8, 10}.
//!
//! Workload: the paper-scale dataset (n = 10,029 "data points", the size of
//! the paper's topology file) in points mode — Alg. 4.2 computes all
//! (n²+n)/2 similarities exactly as the paper describes. Times are the
//! deterministic virtual clock of the simulated cluster (DESIGN.md §2 —
//! substituted for the authors' physical testbed); wall time of the
//! simulation itself is reported alongside.
//!
//! Pass criteria (DESIGN.md §5): every phase faster at m=8 than m=1 with a
//! speedup within [0.4, 2.5]× of the paper's, similarity the fastest-scaling
//! phase (as in the paper), and the total gain from 8→10 under 10% — the
//! paper's flattening crossover.

mod common;

use psch::coordinator::PipelineInput;
use psch::data::gaussian_blobs;
use psch::metrics::speedup::SpeedupCurve;
use psch::metrics::table::AsciiTable;
use psch::util::fmt::hms;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    // Paper scale by default; --quick for CI-speed runs.
    let n: usize = if quick { 2_048 } else { 10_029 };
    let runtime = common::runtime();
    println!("table1: n={n}, backend {:?}", runtime.backend());
    let dataset = gaussian_blobs(n, 4, 8, 0.4, 8.0, 42);
    let input = PipelineInput::Points { points: dataset.points.clone() };

    let mut table = AsciiTable::new(&[
        "Slave Number",
        "Parallel similarity matrix",
        "Parallel k eigenvectors",
        "Parallel K-means",
        "Total Time",
        "(paper total)",
        "(sim wall s)",
    ]);
    let mut phase_curves = [
        SpeedupCurve::default(),
        SpeedupCurve::default(),
        SpeedupCurve::default(),
    ];
    let mut total_curve = SpeedupCurve::default();

    let mut runs_json: Vec<String> = Vec::new();
    let mut last_total = 0.0f64;
    for &(m, _, _, _, paper_total) in &common::PAPER_TABLE1 {
        let driver = common::driver_for(m, &runtime);
        let (result, wall) =
            psch::benchutil::time_once(|| driver.run(&input).expect("pipeline"));
        let d = |s: f64| hms(std::time::Duration::from_secs_f64(s));
        table.row(&[
            m.to_string(),
            d(result.phases[0].virtual_s),
            d(result.phases[1].virtual_s),
            d(result.phases[2].virtual_s),
            d(result.total_virtual_s),
            d(paper_total),
            format!("{:.1}", wall.as_secs_f64()),
        ]);
        for (i, curve) in phase_curves.iter_mut().enumerate() {
            curve.push(m, result.phases[i].virtual_s);
        }
        total_curve.push(m, result.total_virtual_s);
        println!(
            "m={m:>2}: total {} (paper {}) [simulated in {:.1}s wall]",
            d(result.total_virtual_s),
            d(paper_total),
            wall.as_secs_f64()
        );
        for p in &result.phases {
            println!("      shuffle[{}]: {}", p.name, p.shuffle_summary().render());
        }
        runs_json.push(common::run_json(m, &result));
        last_total = result.total_virtual_s;
    }
    common::write_bench_json(
        "BENCH_table1.json",
        &format!(
            "{{\"bench\":\"table1\",\"n\":{n},\"runs\":[{}]}}\n",
            runs_json.join(",")
        ),
    );
    common::log_trajectory("table1", "BENCH_table1.json", last_total, 42);

    println!("\nTable 5-1 reproduction:\n{}", table.render());

    // ---- shape checks ----
    let phase_names = ["similarity", "eigenvectors", "kmeans"];
    let paper_speedup_at8 = [6106.0 / 1275.0, 8894.0 / 3619.0, 1725.0 / 779.0];
    let mut pass = true;
    let mut speedups_at8 = [0.0f64; 3];
    for (i, curve) in phase_curves.iter().enumerate() {
        let s8 = curve
            .speedups()
            .iter()
            .find(|&&(m, _)| m == 8)
            .map(|&(_, s)| s)
            .unwrap();
        speedups_at8[i] = s8;
        let ratio = s8 / paper_speedup_at8[i];
        let ok = s8 > 1.0 && (0.4..=2.5).contains(&ratio);
        pass &= ok;
        println!(
            "phase {:<13} speedup@8={:.2}x (paper {:.2}x, ratio {:.2}) {}",
            phase_names[i],
            s8,
            paper_speedup_at8[i],
            ratio,
            if ok { "PASS" } else { "FAIL" }
        );
    }
    // The paper's fastest-scaling phase is the similarity matrix (4.79x);
    // ours must preserve that ordering.
    let sim_fastest = speedups_at8[0] >= speedups_at8[1]
        && speedups_at8[0] >= speedups_at8[2];
    pass &= sim_fastest;
    println!(
        "similarity is the fastest-scaling phase: {}",
        if sim_fastest { "PASS (matches paper)" } else { "FAIL" }
    );
    let final_gain = total_curve.final_gain().unwrap();
    let flat = final_gain < 0.10;
    pass &= flat;
    println!(
        "total 8->10 gain: {:.1}% (paper: -1.4%) {}",
        final_gain * 100.0,
        if flat { "PASS (flattens)" } else { "FAIL" }
    );
    println!("\nspeedups (total): {:?}", total_curve.speedups());
    println!("\nFig. 5-style trend:\n{}", total_curve.ascii_plot(48, 12));
    if !pass {
        println!("table1: SHAPE CHECK FAILED");
        std::process::exit(1);
    }
    println!("table1: all shape checks PASS");
}
