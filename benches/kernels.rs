//! Experiment K — hot-path kernel microbenchmarks, two sections:
//!
//! 1. **scalar vs blocked** for the `linalg::kernels` layer at
//!    paper-calibration shapes — the one-query-vs-many-points
//!    squared-distance batch, the row-blocked CSR mat-vec, and the
//!    point×center assignment tile. Each pair runs the public `*_scalar`
//!    reference against the `*_blocked` kernel on identical inputs and the
//!    emitted `BENCH_kernels.json` carries a `speedup` object
//!    (scalar median / blocked median per kernel).
//! 2. **XLA AOT artifacts vs the native Rust fallback**, per runtime
//!    kernel, at the AOT tile geometry — the §Perf evidence that the XLA
//!    path is not a regression over native code (feeding the compute_scale
//!    calibration in EXPERIMENTS.md).
//!
//! Warmup/iteration counts honor `PSCH_BENCH_WARMUP` / `PSCH_BENCH_ITERS`
//! so the CI job can run a reduced schedule.

mod common;

use std::hint::black_box;
use std::path::Path;

use psch::benchutil::{bench, bench_params, stats_json_with_speedups, BenchStats};
use psch::linalg::kernels::{self, ScanSink};
use psch::linalg::CsrMatrix;
use psch::mapreduce::Counters;
use psch::runtime::executor::{KM_K, KM_PTS, MV_BLOCK, PAD_DIM, RBF_TILE};
use psch::runtime::KernelRuntime;
use psch::util::Xoshiro256;

fn randf(rng: &mut Xoshiro256, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect()
}

fn randd(rng: &mut Xoshiro256, n: usize) -> Vec<f64> {
    (0..n).map(|_| rng.next_f64() * 2.0 - 1.0).collect()
}

/// Scan sink that only aggregates — the cheapest possible consumer, so the
/// timings isolate the distance kernel itself.
struct SumSink {
    bound: f64,
    sum: f64,
    kept: u64,
}

impl ScanSink for SumSink {
    fn bound(&self) -> f64 {
        self.bound
    }

    fn emit(&mut self, _id: u32, d2: Option<f64>) {
        if let Some(d2) = d2 {
            self.sum += d2;
            self.kept += 1;
        }
    }
}

fn median_ns(results: &[BenchStats], name: &str) -> f64 {
    results
        .iter()
        .find(|r| r.name == name)
        .unwrap_or_else(|| panic!("missing bench result {name}"))
        .median
        .as_nanos()
        .max(1) as f64
}

fn main() {
    let (warmup, iters) = bench_params(3, 30);
    let mut rng = Xoshiro256::new(7);
    let mut results = Vec::new();

    // ----- section 1: scalar vs blocked linalg kernels ------------------
    // sq_dist batch: one query against 512 points of dimension PAD_DIM —
    // the shape of a kd-tree leaf scan / similarity mapper row.
    const SD_N: usize = 512;
    let sd_points = randd(&mut rng, SD_N * PAD_DIM);
    let sd_q = randd(&mut rng, PAD_DIM);
    let sd_ids: Vec<u32> = (0..SD_N as u32).collect();
    results.push(bench("sq_dist_batch 512x16 [scalar]", warmup, iters, || {
        let mut sink = SumSink { bound: f64::INFINITY, sum: 0.0, kept: 0 };
        kernels::sq_dist_scan_ids_scalar(&sd_q, &sd_points, PAD_DIM, &sd_ids, None, &mut sink);
        black_box((sink.sum, sink.kept));
    }));
    results.push(bench("sq_dist_batch 512x16 [blocked]", warmup, iters, || {
        let mut sink = SumSink { bound: f64::INFINITY, sum: 0.0, kept: 0 };
        kernels::sq_dist_scan_ids_blocked(&sd_q, &sd_points, PAD_DIM, &sd_ids, None, &mut sink);
        black_box((sink.sum, sink.kept));
    }));

    // Row-blocked CSR mat-vec: 4096 rows at ~21 stored entries each — the
    // Laplacian density of a quick-config epsilon graph.
    const SP_N: usize = 4096;
    let mut sp_rows: Vec<Vec<(u32, f64)>> = Vec::with_capacity(SP_N);
    for i in 0..SP_N {
        let mut cols: Vec<u32> = (0..20)
            .map(|_| (rng.next_u64() % SP_N as u64) as u32)
            .collect();
        cols.push(i as u32);
        cols.sort_unstable();
        cols.dedup();
        sp_rows.push(
            cols.into_iter()
                .map(|j| (j, rng.next_f64() * 2.0 - 1.0))
                .collect(),
        );
    }
    let sp_a = CsrMatrix::from_rows(SP_N, sp_rows);
    let sp_x = randd(&mut rng, SP_N);
    let mut sp_y = vec![0.0f64; SP_N];
    results.push(bench("spmv_rows 4096x~21 [scalar]", warmup, iters, || {
        kernels::spmv_rows_scalar(sp_a.view(), &sp_x, 0, SP_N, &mut sp_y);
        black_box(sp_y[0]);
    }));
    results.push(bench("spmv_rows 4096x~21 [blocked]", warmup, iters, || {
        kernels::spmv_rows_blocked(sp_a.view(), &sp_x, 0, SP_N, &mut sp_y);
        black_box(sp_y[0]);
    }));

    // Assignment tile: KM_PTS points against KM_K centers at PAD_DIM — the
    // f64 shape of the k-means oracle's assign step.
    let as_pts = randd(&mut rng, KM_PTS * PAD_DIM);
    let as_ctrs = randd(&mut rng, KM_K * PAD_DIM);
    let as_norms = kernels::center_norms(&as_ctrs, KM_K, PAD_DIM);
    results.push(bench("assign_tile 256x16x16 [scalar]", warmup, iters, || {
        let mut acc = 0u32;
        for i in 0..KM_PTS {
            acc = acc.wrapping_add(kernels::assign_point_scalar(
                &as_pts[i * PAD_DIM..(i + 1) * PAD_DIM],
                &as_ctrs,
                &as_norms,
                KM_K,
                PAD_DIM,
            ));
        }
        black_box(acc);
    }));
    results.push(bench("assign_tile 256x16x16 [blocked]", warmup, iters, || {
        let mut acc = 0u32;
        for i in 0..KM_PTS {
            acc = acc.wrapping_add(kernels::assign_point_blocked(
                &as_pts[i * PAD_DIM..(i + 1) * PAD_DIM],
                &as_ctrs,
                &as_norms,
                KM_K,
                PAD_DIM,
            ));
        }
        black_box(acc);
    }));

    // ----- section 2: XLA artifacts vs the native fallback --------------
    let xla = KernelRuntime::auto(Path::new("artifacts"));
    let native = KernelRuntime::native();
    println!("kernels: xla backend = {:?}\n", xla.backend());

    let x = randf(&mut rng, RBF_TILE * PAD_DIM);
    let y = randf(&mut rng, RBF_TILE * PAD_DIM);
    let a = randf(&mut rng, MV_BLOCK * MV_BLOCK);
    let v = randf(&mut rng, MV_BLOCK);
    let pts = randf(&mut rng, KM_PTS * PAD_DIM);
    let ctrs = randf(&mut rng, KM_K * PAD_DIM);
    let z = randf(&mut rng, 128 * PAD_DIM);

    for (name, rt) in [("xla", &xla), ("native", &native)] {
        results.push(bench(
            &format!("rbf_tile 128x128x16 [{name}]"),
            warmup,
            iters,
            || {
                rt.rbf_tile(&x, &y, RBF_TILE, RBF_TILE, PAD_DIM, 0.5).unwrap();
            },
        ));
        results.push(bench(
            &format!("matvec 256x256 [{name}]"),
            warmup,
            iters,
            || {
                rt.matvec(&a, &v, MV_BLOCK, MV_BLOCK).unwrap();
            },
        ));
        results.push(bench(
            &format!("kmeans_step 256x16x16 [{name}]"),
            warmup,
            iters,
            || {
                rt.kmeans_step(&pts, &ctrs, KM_PTS, KM_K, PAD_DIM).unwrap();
            },
        ));
        results.push(bench(
            &format!("normalize_rows 128x16 [{name}]"),
            warmup,
            iters,
            || {
                rt.normalize_rows(&z, 128, PAD_DIM).unwrap();
            },
        ));
    }
    // Counters::incr hot path (every per-record counter bump in the
    // engine goes through it): the key exists after the first touch, so
    // later increments must take the no-alloc fast path. The micro-assert
    // pins the arithmetic: warmup + iters rounds of 1e6, plus the seed.
    // Round counts are capped so env-reduced schedules stay cheap.
    const INCR_ROUNDS: u64 = 1_000_000;
    let (cw, ci) = (warmup.min(1), iters.min(5));
    let mut counters = Counters::default();
    counters.incr("HOT", 1);
    results.push(bench("counters_incr hot-path x1e6", cw, ci, || {
        for _ in 0..INCR_ROUNDS {
            counters.incr("HOT", 1);
        }
    }));
    assert_eq!(
        counters.get("HOT"),
        (cw + ci) as u64 * INCR_ROUNDS + 1,
        "Counters::incr dropped increments"
    );

    println!();
    for r in &results {
        println!("{}", r.render());
    }

    // Scalar-vs-blocked speedups (median over median).
    let speedups: Vec<(&str, f64)> = [
        ("sq_dist_batch", "sq_dist_batch 512x16"),
        ("spmv_rows", "spmv_rows 4096x~21"),
        ("assign_tile", "assign_tile 256x16x16"),
    ]
    .iter()
    .map(|(key, base)| {
        let s = median_ns(&results, &format!("{base} [scalar]"));
        let b = median_ns(&results, &format!("{base} [blocked]"));
        (*key, s / b)
    })
    .collect();
    println!();
    for (name, ratio) in &speedups {
        println!("speedup {name}: {ratio:.2}x (scalar median / blocked median)");
    }
    let fast = speedups.iter().filter(|(_, r)| *r >= 1.3).count();
    println!("kernels: blocked >= 1.3x scalar on {fast}/{} kernels", speedups.len());

    // Throughput summary for the RBF tile (the phase-1 unit of work).
    let rbf_med_ns = median_ns(&results, "rbf_tile 128x128x16 [xla]");
    let pairs = (RBF_TILE * RBF_TILE) as f64;
    println!(
        "\nrbf tile: {:.1} M similarity-pairs/s (xla median)",
        pairs / (rbf_med_ns / 1e9) / 1e6
    );

    // Parity spot check: identical outputs across backends.
    let sx = xla.rbf_tile(&x, &y, RBF_TILE, RBF_TILE, PAD_DIM, 0.5).unwrap();
    let sn = native
        .rbf_tile(&x, &y, RBF_TILE, RBF_TILE, PAD_DIM, 0.5)
        .unwrap();
    let max_diff = sx
        .iter()
        .zip(&sn)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("rbf parity max |xla - native| = {max_diff:.2e}");
    assert!(max_diff < 1e-5, "backend parity violated");

    common::write_bench_json(
        "BENCH_kernels.json",
        &stats_json_with_speedups("kernels", &results, &speedups),
    );
    // Wall-clock micro-bench: no virtual makespan, fixed data (seed 0).
    common::log_trajectory("kernels", "BENCH_kernels.json", 0.0, 0);
    println!("kernels: OK");
}
