//! Experiment K — hot-path kernel microbenchmarks: XLA AOT artifacts vs the
//! native Rust fallback, per kernel, at the AOT tile geometry.
//!
//! This is the §Perf evidence that the XLA path is not a regression over
//! native code and quantifies per-tile cost (feeding the compute_scale
//! calibration in EXPERIMENTS.md).

mod common;

use std::path::Path;

use psch::benchutil::{bench, stats_json};
use psch::mapreduce::Counters;
use psch::runtime::executor::{KM_K, KM_PTS, MV_BLOCK, PAD_DIM, RBF_TILE};
use psch::runtime::KernelRuntime;
use psch::util::Xoshiro256;

fn randf(rng: &mut Xoshiro256, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect()
}

fn main() {
    let xla = KernelRuntime::auto(Path::new("artifacts"));
    let native = KernelRuntime::native();
    println!("kernels: xla backend = {:?}\n", xla.backend());
    let mut rng = Xoshiro256::new(7);

    let x = randf(&mut rng, RBF_TILE * PAD_DIM);
    let y = randf(&mut rng, RBF_TILE * PAD_DIM);
    let a = randf(&mut rng, MV_BLOCK * MV_BLOCK);
    let v = randf(&mut rng, MV_BLOCK);
    let pts = randf(&mut rng, KM_PTS * PAD_DIM);
    let ctrs = randf(&mut rng, KM_K * PAD_DIM);
    let z = randf(&mut rng, 128 * PAD_DIM);

    let mut results = Vec::new();
    for (name, rt) in [("xla", &xla), ("native", &native)] {
        results.push(bench(
            &format!("rbf_tile 128x128x16 [{name}]"),
            3,
            30,
            || {
                rt.rbf_tile(&x, &y, RBF_TILE, RBF_TILE, PAD_DIM, 0.5).unwrap();
            },
        ));
        results.push(bench(
            &format!("matvec 256x256 [{name}]"),
            3,
            30,
            || {
                rt.matvec(&a, &v, MV_BLOCK, MV_BLOCK).unwrap();
            },
        ));
        results.push(bench(
            &format!("kmeans_step 256x16x16 [{name}]"),
            3,
            30,
            || {
                rt.kmeans_step(&pts, &ctrs, KM_PTS, KM_K, PAD_DIM).unwrap();
            },
        ));
        results.push(bench(
            &format!("normalize_rows 128x16 [{name}]"),
            3,
            30,
            || {
                rt.normalize_rows(&z, 128, PAD_DIM).unwrap();
            },
        ));
    }
    // Counters::incr hot path (every per-record counter bump in the
    // engine goes through it): the key exists after the first touch, so
    // later increments must take the no-alloc fast path. The micro-assert
    // pins the arithmetic: warmup + iters rounds of 1e6, plus the seed.
    const INCR_ROUNDS: u64 = 1_000_000;
    let mut counters = Counters::default();
    counters.incr("HOT", 1);
    results.push(bench("counters_incr hot-path x1e6", 1, 5, || {
        for _ in 0..INCR_ROUNDS {
            counters.incr("HOT", 1);
        }
    }));
    assert_eq!(
        counters.get("HOT"),
        (1 + 5) * INCR_ROUNDS + 1,
        "Counters::incr dropped increments"
    );

    println!();
    for r in &results {
        println!("{}", r.render());
    }

    // Throughput summary for the RBF tile (the phase-1 unit of work).
    let rbf_xla = &results[0];
    let pairs = (RBF_TILE * RBF_TILE) as f64;
    println!(
        "\nrbf tile: {:.1} M similarity-pairs/s (xla median)",
        pairs / rbf_xla.median.as_secs_f64() / 1e6
    );

    // Parity spot check: identical outputs across backends.
    let sx = xla.rbf_tile(&x, &y, RBF_TILE, RBF_TILE, PAD_DIM, 0.5).unwrap();
    let sn = native
        .rbf_tile(&x, &y, RBF_TILE, RBF_TILE, PAD_DIM, 0.5)
        .unwrap();
    let max_diff = sx
        .iter()
        .zip(&sn)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("rbf parity max |xla - native| = {max_diff:.2e}");
    assert!(max_diff < 1e-5, "backend parity violated");

    common::write_bench_json("BENCH_kernels.json", &stats_json("kernels", &results));
    println!("kernels: OK");
}
