//! Property-based integration tests over the coordinator's invariants
//! (routing, batching, state) using the in-tree harness (`psch::testutil`).

use std::sync::Arc;

use psch::cluster::Cluster;
use psch::mapreduce::{
    self, FnMapper, FnReducer, HashPartitioner, JobBuilder, Partitioner,
    RangePartitioner, TaskContext, Values,
};
use psch::testutil::{check, Gen};
use psch::util::bytes::{decode_u64, encode_u64};
use psch::{prop_assert, spectral};

/// Routing: every emitted key lands in exactly one reduce partition, and
/// identical keys always co-locate — for random key sets and partitioners.
#[test]
fn prop_partitioner_routes_each_key_once() {
    check("partitioner-routing", 60, 0xA11, |g: &mut Gen| {
        let n_keys = g.usize_in(1, 200);
        let parts = g.usize_in(1, 16);
        let keys: Vec<Vec<u8>> = (0..n_keys)
            .map(|_| {
                let len = g.usize_in(1, 12);
                g.bytes(len)
            })
            .collect();
        let hash = HashPartitioner;
        for key in &keys {
            let p = hash.partition(key, parts);
            prop_assert!(p < parts, "partition {p} out of range {parts}");
            prop_assert!(
                p == hash.partition(key, parts),
                "partitioner not deterministic"
            );
        }
        // Range partitioner: monotone over u64 keys.
        let rp = RangePartitioner { max_key: 1000 };
        let mut last = 0;
        for k in (0..1000u64).step_by(13) {
            let p = rp.partition(&encode_u64(k), parts);
            prop_assert!(p >= last && p < parts, "range partitioner order");
            last = p;
        }
        Ok(())
    });
}

/// Batching/shuffle: a sum-reduce over random records conserves the total
/// regardless of split sizes, reducer count or combiner use.
#[test]
fn prop_shuffle_conserves_records() {
    check("shuffle-conservation", 25, 0xB22, |g: &mut Gen| {
        let n_records = g.usize_in(1, 400);
        let n_splits = g.usize_in(1, 8);
        let n_reducers = g.usize_in(1, 7);
        let key_space = g.usize_in(1, 30) as u64;
        let use_combiner = g.bool_p(0.5);

        let mut splits: Vec<Vec<(Vec<u8>, Vec<u8>)>> =
            (0..n_splits).map(|_| Vec::new()).collect();
        let mut expected = 0u64;
        for i in 0..n_records {
            let key = g.usize_in(0, key_space as usize - 1) as u64;
            let val = g.usize_in(0, 100) as u64;
            expected += val;
            splits[i % n_splits]
                .push((encode_u64(key).to_vec(), encode_u64(val).to_vec()));
        }
        let mapper = Arc::new(FnMapper(
            |k: &[u8], v: &[u8], ctx: &mut TaskContext| {
                ctx.emit(k.to_vec(), v.to_vec());
                Ok(())
            },
        ));
        let sum = Arc::new(FnReducer(
            |k: &[u8], vs: &mut dyn Values, ctx: &mut TaskContext| {
                let mut total = 0u64;
                while let Some(v) = vs.next_value() {
                    total += decode_u64(v);
                }
                ctx.emit(k.to_vec(), encode_u64(total).to_vec());
                Ok(())
            },
        ));
        let mut builder = JobBuilder::new("sum", splits, mapper)
            .reducer(sum.clone(), n_reducers);
        if use_combiner {
            builder = builder.combiner(sum);
        }
        let mut result =
            mapreduce::run(&Cluster::new(g.usize_in(1, 4)), &builder.build()).unwrap();
        // sorted_records drains the output, so take it once and reuse.
        let records = result.sorted_records();
        let got: u64 = records.iter().map(|(_, v)| decode_u64(v)).sum();
        prop_assert!(
            got == expected,
            "sum conservation: {got} != {expected} (combiner={use_combiner})"
        );
        // Each key appears exactly once in the output.
        for w in records.windows(2) {
            prop_assert!(w[0].0 != w[1].0, "key duplicated across reducers");
        }
        Ok(())
    });
}

/// State: the similarity matrix the phase-1 job builds is symmetric with a
/// unit diagonal, and degrees equal row sums — for random point sets.
#[test]
fn prop_similarity_table_symmetric() {
    check("similarity-symmetry", 8, 0xC33, |g: &mut Gen| {
        let n = g.usize_in(20, 150);
        let d = g.usize_in(1, 6);
        let sigma = g.f64_in(0.5, 2.0);
        let points: Vec<Vec<f64>> =
            (0..n).map(|_| g.vec_f64(d, -3.0, 3.0)).collect();
        let svc = psch::coordinator::Services::new(
            Cluster::new(g.usize_in(1, 4)),
            Arc::new(psch::runtime::KernelRuntime::native()),
        );
        let flat: Vec<f32> = points.iter().flatten().map(|&x| x as f32).collect();
        let out = psch::coordinator::similarity_job::run_similarity_phase(
            &svc,
            Arc::new(flat),
            n,
            d,
            sigma,
            1e-7,
            "S",
        )
        .unwrap();
        let table = svc.tables.open("S").unwrap();
        let nb = n.div_ceil(psch::coordinator::similarity_job::BLOCK);
        let mut rows = Vec::new();
        for i in 0..n {
            rows.push(psch::coordinator::similarity_job::read_similarity_row(
                &table, i as u64, nb,
            ));
        }
        for (i, row) in rows.iter().enumerate() {
            let mut has_diag = false;
            let mut degree = 0.0;
            for &(j, v) in row {
                degree += v;
                if j as usize == i {
                    has_diag = true;
                    prop_assert!((v - 1.0).abs() < 1e-5, "diag {i} = {v}");
                }
                // Symmetric counterpart exists and matches.
                let vt = rows[j as usize]
                    .iter()
                    .find(|&&(jj, _)| jj as usize == i)
                    .map(|&(_, v)| v);
                prop_assert!(vt.is_some(), "missing mirror of ({i},{j})");
                prop_assert!(
                    (vt.unwrap() - v).abs() < 1e-6,
                    "asymmetry at ({i},{j}): {v} vs {:?}",
                    vt
                );
            }
            prop_assert!(has_diag, "row {i} lost its diagonal");
            prop_assert!(
                (degree - out.degrees[i]).abs() < 1e-3,
                "degree {i}: {degree} vs {}",
                out.degrees[i]
            );
        }
        Ok(())
    });
}

/// State: k-means centers remain the mean of their assigned points after
/// every distributed iteration (checked via the single-iteration job).
#[test]
fn prop_kmeans_centers_are_means() {
    check("kmeans-centers", 8, 0xD44, |g: &mut Gen| {
        let n = g.usize_in(30, 200);
        let d = g.usize_in(1, 5);
        let k = g.usize_in(2, 5.min(n));
        let points: Vec<Vec<f64>> =
            (0..n).map(|_| g.vec_f64(d, -5.0, 5.0)).collect();
        let svc = psch::coordinator::Services::new(
            Cluster::new(2),
            Arc::new(psch::runtime::KernelRuntime::native()),
        );
        let flat: Vec<f32> = points.iter().flatten().map(|&x| x as f32).collect();
        let out = psch::coordinator::kmeans_job::run_kmeans_phase(
            &svc,
            Arc::new(flat.clone()),
            n,
            d,
            k,
            10,
            1e-9,
            g.rng().next_u64(),
        )
        .unwrap();
        // Recompute means from the final labels (f32 path, f32 tolerance).
        for c in 0..k {
            let members: Vec<usize> =
                (0..n).filter(|&i| out.labels[i] == c).collect();
            if members.is_empty() {
                continue;
            }
            for t in 0..d {
                let mean: f64 = members
                    .iter()
                    .map(|&i| flat[i * d + t] as f64)
                    .sum::<f64>()
                    / members.len() as f64;
                // Centers were computed from the *previous* assignment; with
                // convergence they match the final means closely.
                if out.converged {
                    prop_assert!(
                        (out.centers[c][t] - mean).abs() < 1e-3,
                        "center ({c},{t}): {} vs mean {mean}",
                        out.centers[c][t]
                    );
                }
            }
        }
        prop_assert!(out.labels.iter().all(|&l| l < k), "label out of range");
        Ok(())
    });
}

/// State: the Laplacian pipeline preserves the spectral invariants on random
/// graphs — lambda_1 = 0 and all eigenvalues within [0, 2].
#[test]
fn prop_laplacian_spectrum_bounds() {
    check("laplacian-spectrum", 10, 0xE55, |g: &mut Gen| {
        let topo = g.graph(3);
        let n = topo.num_vertices();
        let s = spectral::adjacency_similarity(n, &topo.adjacency_triplets());
        let l = spectral::laplacian_sparse(&s);
        let r = psch::linalg::lanczos_smallest(
            n,
            3.min(n),
            &psch::linalg::LanczosOptions {
                max_steps: 40.min(n),
                seed: g.rng().next_u64(),
                ..Default::default()
            },
            |v| l.spmv(v),
        )
        .unwrap();
        prop_assert!(
            r.eigenvalues[0].abs() < 1e-7,
            "lambda_1 = {} != 0",
            r.eigenvalues[0]
        );
        for &v in &r.eigenvalues {
            prop_assert!(
                (-1e-9..=2.0 + 1e-9).contains(&v),
                "eigenvalue {v} outside [0,2]"
            );
        }
        Ok(())
    });
}
