//! Integration: XLA artifacts vs native kernels, element-level parity on
//! random inputs (the Rust-side counterpart of the python kernel-vs-ref
//! tests). Skips when artifacts have not been built.

use psch::runtime::executor::{KM_K, KM_PTS, MV_BLOCK, PAD_DIM, RBF_TILE};
use psch::runtime::{Backend, KernelRuntime};
use psch::util::Xoshiro256;

fn runtimes() -> Option<(KernelRuntime, KernelRuntime)> {
    let xla = KernelRuntime::auto(&psch::runtime::artifacts_dir());
    if xla.backend() != Backend::Xla {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some((xla, KernelRuntime::native()))
}

fn randf(rng: &mut Xoshiro256, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| (rng.next_f32() * 2.0 - 1.0) * scale).collect()
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())),
            "{what}[{i}]: {x} vs {y}"
        );
    }
}

#[test]
fn rbf_parity_sweep() {
    let Some((xla, native)) = runtimes() else { return };
    let mut rng = Xoshiro256::new(1);
    // Odd sizes exercise the padding logic.
    for (p, q, d) in [(1, 1, 1), (7, 13, 3), (128, 128, 16), (200, 150, 9), (300, 64, 16)] {
        let x = randf(&mut rng, p * d, 2.0);
        let y = randf(&mut rng, q * d, 2.0);
        for gamma in [0.1f32, 1.0, 3.0] {
            let a = xla.rbf_tile(&x, &y, p, q, d, gamma).unwrap();
            let b = native.rbf_tile(&x, &y, p, q, d, gamma).unwrap();
            assert_close(&a, &b, 1e-5, "rbf");
        }
    }
}

#[test]
fn matvec_parity_sweep() {
    let Some((xla, native)) = runtimes() else { return };
    let mut rng = Xoshiro256::new(2);
    for (r, c) in [(1, 1), (5, 300), (256, 256), (700, 90), (513, 257)] {
        let a = randf(&mut rng, r * c, 1.0);
        let v = randf(&mut rng, c, 1.0);
        let ya = xla.matvec(&a, &v, r, c).unwrap();
        let yb = native.matvec(&a, &v, r, c).unwrap();
        assert_close(&ya, &yb, 1e-4, "matvec");
    }
}

#[test]
fn kmeans_parity_sweep() {
    let Some((xla, native)) = runtimes() else { return };
    let mut rng = Xoshiro256::new(3);
    for (p, k, d) in [(1, 1, 1), (100, 3, 2), (256, 16, 16), (999, 7, 5)] {
        let pts = randf(&mut rng, p * d, 3.0);
        let ctrs = randf(&mut rng, k * d, 3.0);
        let (a1, s1, c1) = xla.kmeans_step(&pts, &ctrs, p, k, d).unwrap();
        let (a2, s2, c2) = native.kmeans_step(&pts, &ctrs, p, k, d).unwrap();
        assert_eq!(a1, a2, "assignments p={p} k={k} d={d}");
        assert_close(&s1, &s2, 1e-4, "sums");
        assert_close(&c1, &c2, 1e-6, "counts");
    }
}

#[test]
fn normalize_parity_sweep() {
    let Some((xla, native)) = runtimes() else { return };
    let mut rng = Xoshiro256::new(4);
    for (r, d) in [(1, 1), (128, 16), (77, 5), (513, 3)] {
        let mut z = randf(&mut rng, r * d, 1.0);
        // Inject zero rows.
        for i in (0..r).step_by(7) {
            z[i * d..(i + 1) * d].fill(0.0);
        }
        let a = xla.normalize_rows(&z, r, d).unwrap();
        let b = native.normalize_rows(&z, r, d).unwrap();
        assert_close(&a, &b, 1e-5, "normalize");
        assert!(a.iter().all(|v| v.is_finite()), "no NaN from zero rows");
    }
}

#[test]
fn laplacian_parity() {
    let Some((xla, native)) = runtimes() else { return };
    let mut rng = Xoshiro256::new(5);
    for n in [1usize, 64, 200, 256] {
        let s: Vec<f32> = randf(&mut rng, n * n, 1.0).iter().map(|x| x * x).collect();
        let dr: Vec<f32> = randf(&mut rng, n, 1.0).iter().map(|x| x.abs() + 0.1).collect();
        let dc: Vec<f32> = randf(&mut rng, n, 1.0).iter().map(|x| x.abs() + 0.1).collect();
        for diag in [false, true] {
            let a = xla.laplacian_tile(&s, &dr, &dc, n, diag).unwrap();
            let b = native.laplacian_tile(&s, &dr, &dc, n, diag).unwrap();
            assert_close(&a, &b, 1e-5, "laplacian");
        }
    }
}

#[test]
fn xla_rejects_oversized_dims_cleanly() {
    let Some((xla, _)) = runtimes() else { return };
    let x = vec![0.0f32; 10 * (PAD_DIM + 1)];
    assert!(xla.rbf_tile(&x, &x, 10, 10, PAD_DIM + 1, 1.0).is_err());
    let pts = vec![0.0f32; KM_PTS * PAD_DIM];
    let ctrs = vec![0.0f32; (KM_K + 1) * PAD_DIM];
    assert!(xla.kmeans_step(&pts, &ctrs, KM_PTS, KM_K + 1, PAD_DIM).is_err());
    let s = vec![0.0f32; (MV_BLOCK + 1) * (MV_BLOCK + 1)];
    let d = vec![0.0f32; MV_BLOCK + 1];
    assert!(xla.laplacian_tile(&s, &d, &d, MV_BLOCK + 1, true).is_err());
    let _ = RBF_TILE;
}
