//! Integration: the shipped config files parse, validate and drive a run.

use std::sync::Arc;

use psch::config::Config;
use psch::coordinator::eigen::EigenSolverKind;
use psch::coordinator::{Driver, PipelineInput};
use psch::data::gaussian_blobs;
use psch::knn::{GraphMode, IndexKind};
use psch::runtime::KernelRuntime;

#[test]
fn shipped_configs_parse_and_validate() {
    for path in ["configs/paper.toml", "configs/quick.toml", "configs/chaos.toml"] {
        let cfg = Config::load(path).unwrap_or_else(|e| panic!("{path}: {e}"));
        cfg.validate().unwrap();
    }
    let paper = Config::load("configs/paper.toml").unwrap();
    assert_eq!(paper.cluster.slaves, 8);
    assert_eq!(paper.cluster.slots_per_slave, 2);
    assert!((paper.cluster.network.coord_per_machine_s - 3.5).abs() < 1e-12);
    assert_eq!(paper.algo.lanczos_steps, 60);
    // The chaos example actually schedules faults.
    let chaos = Config::load("configs/chaos.toml").unwrap();
    assert!(chaos.faults.is_active());
    assert!(chaos.faults.task_fail_prob > 0.0);
    assert_eq!(chaos.faults.node_deaths.len(), 1);
    assert!(chaos.faults.node_deaths[0].slave < chaos.cluster.slaves);
    // And the fault-free configs stay inert.
    assert!(!Config::load("configs/quick.toml").unwrap().faults.is_active());
    // Every shipped config carries the (inactive) [knn] section with the
    // documented defaults, so --graph tnn works out of the box.
    for path in ["configs/paper.toml", "configs/quick.toml", "configs/chaos.toml"] {
        let cfg = Config::load(path).unwrap();
        assert_eq!(cfg.algo.graph, GraphMode::Epsilon, "{path}");
        assert_eq!(cfg.knn.t, 10, "{path}");
        assert_eq!(cfg.knn.leaf_size, 16, "{path}");
        assert_eq!(cfg.knn.index, IndexKind::KdTree, "{path}");
    }
    // Every shipped config carries an [eigen] section that defaults to
    // lanczos AND whose chebdav worst case undercuts its own lanczos job
    // count, so --eigensolver chebdav is a strict job-count win as shipped.
    for path in ["configs/paper.toml", "configs/quick.toml", "configs/chaos.toml"] {
        let cfg = Config::load(path).unwrap();
        assert_eq!(cfg.eigen.solver, EigenSolverKind::Lanczos, "{path}");
        assert!(
            cfg.eigen.max_operator_jobs() < 1 + cfg.algo.lanczos_steps,
            "{path}: chebdav worst case {} must beat {} lanczos jobs",
            cfg.eigen.max_operator_jobs(),
            1 + cfg.algo.lanczos_steps,
        );
    }
    assert_eq!(paper.eigen.block_size, 8);
    assert_eq!(paper.eigen.filter_degree, 8);
    assert_eq!(paper.eigen.max_outer, 5);
    let quick = Config::load("configs/quick.toml").unwrap();
    assert_eq!(quick.eigen.block_size, 6);
    assert_eq!(quick.eigen.filter_degree, 6);
    assert_eq!(quick.eigen.max_outer, 4);
}

#[test]
fn knn_keys_round_trip_through_parse_and_set() {
    // File syntax (quoted + bare values) and CLI-style --set agree.
    let text = "[algo]\ngraph = \"tnn\"\n\n[knn]\nt = 7\nleaf_size = 8\nindex = \"brute\"\n";
    let parsed = Config::parse(text).unwrap();
    let mut set = Config::default();
    set.set("algo.graph", "tnn").unwrap();
    set.set("knn.t", "7").unwrap();
    set.set("knn.leaf_size", "8").unwrap();
    set.set("knn.index", "brute").unwrap();
    set.validate().unwrap();
    assert_eq!(parsed, set);
    assert_eq!(parsed.algo.graph, GraphMode::Tnn);
    assert_eq!(parsed.knn.t, 7);
    assert_eq!(parsed.knn.leaf_size, 8);
    assert_eq!(parsed.knn.index, IndexKind::Brute);
    // A tnn override on a shipped config keeps the file's other knobs.
    let mut quick = Config::load("configs/quick.toml").unwrap();
    quick.set("algo.graph", "tnn").unwrap();
    quick.set("knn.t", "5").unwrap();
    quick.validate().unwrap();
    assert_eq!(quick.algo.graph, GraphMode::Tnn);
    assert_eq!(quick.knn.t, 5);
    assert_eq!(quick.knn.leaf_size, 16, "file value survives the override");
    assert_eq!(quick.cluster.slaves, 2);
}

#[test]
fn eigen_keys_round_trip_through_parse_and_set() {
    // File syntax (quoted + bare values) and CLI-style --set agree.
    let text = "[eigen]\nsolver = \"chebdav\"\nblock_size = 5\nfilter_degree = 7\n\
                max_outer = 3\nresidual_tol = 1e-5\nbound_steps = 2\n";
    let parsed = Config::parse(text).unwrap();
    let mut set = Config::default();
    set.set("eigen.solver", "chebdav").unwrap();
    set.set("eigen.block_size", "5").unwrap();
    set.set("eigen.filter_degree", "7").unwrap();
    set.set("eigen.max_outer", "3").unwrap();
    set.set("eigen.residual_tol", "1e-5").unwrap();
    set.set("eigen.bound_steps", "2").unwrap();
    set.validate().unwrap();
    assert_eq!(parsed, set);
    assert_eq!(parsed.eigen.solver, EigenSolverKind::ChebDav);
    assert_eq!(parsed.eigen.block_size, 5);
    assert_eq!(parsed.eigen.max_operator_jobs(), 2 + 3 * 8);
    // The paper-facing alias reaches the same field from a [algo] section.
    let aliased = Config::parse("[algo]\neigensolver = \"chebdav\"\n").unwrap();
    assert_eq!(aliased.eigen.solver, EigenSolverKind::ChebDav);
    // A chebdav override on a shipped config keeps the file's other knobs.
    let mut quick = Config::load("configs/quick.toml").unwrap();
    quick.set("eigen.solver", "chebdav").unwrap();
    quick.validate().unwrap();
    assert_eq!(quick.eigen.solver, EigenSolverKind::ChebDav);
    assert_eq!(quick.eigen.filter_degree, 6, "file value survives the override");
    assert_eq!(quick.cluster.slaves, 2);
}

#[test]
fn quick_config_drives_a_pipeline_run() {
    let cfg = Config::load("configs/quick.toml").unwrap();
    let ps = gaussian_blobs(200, cfg.algo.k, 4, 0.3, 10.0, 1);
    let driver = Driver::new(cfg, Arc::new(KernelRuntime::native()));
    let r = driver
        .run(&PipelineInput::Points { points: ps.points.clone() })
        .unwrap();
    assert!(psch::eval::nmi(&ps.labels, &r.labels) > 0.9);
}

#[test]
fn cli_overrides_layer_on_top_of_file() {
    let mut cfg = Config::load("configs/paper.toml").unwrap();
    cfg.set("cluster.slaves", "10").unwrap();
    cfg.set("algo.k", "6").unwrap();
    cfg.validate().unwrap();
    assert_eq!(cfg.cluster.slaves, 10);
    assert_eq!(cfg.algo.k, 6);
    // Untouched file values survive.
    assert!((cfg.algo.sigma.fixed().unwrap() - 1.5).abs() < 1e-12);
}
