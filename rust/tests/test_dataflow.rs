//! Integration: the typed dataflow layer against the hand-wired JobBuilder
//! path — multi-job chaining over configs/quick.toml, byte-identical
//! outputs, and map-fusion provably launching fewer jobs.

use std::sync::Arc;

use psch::config::Config;
use psch::coordinator::{Driver, PipelineInput, Services};
use psch::data::gaussian_blobs;
use psch::dataflow::{Group, Pipeline};
use psch::mapreduce::{self, FnMapper, FnReducer, JobBuilder, TaskContext, Values};
use psch::runtime::KernelRuntime;
use psch::util::bytes::{decode_f64, encode_f64, encode_u64};

fn quick_config() -> Config {
    Config::load(concat!(env!("CARGO_MANIFEST_DIR"), "/configs/quick.toml")).unwrap()
}

fn quick_services() -> Services {
    Services::from_config(&quick_config(), Arc::new(KernelRuntime::native()))
}

fn lines() -> Vec<Vec<(u64, Vec<u8>)>> {
    vec![
        vec![
            (0u64, b"The quick brown fox".to_vec()),
            (1u64, b"the LAZY dog".to_vec()),
        ],
        vec![(2u64, b"The fox JUMPS over the dog".to_vec())],
    ]
}

/// The 3-stage logical chain as a dataflow pipeline:
/// tokenize → normalize → count (fused into job 1), then
/// bucket → bucket-sum (fused into job 2).
fn run_pipeline(svc: &Services) -> (Vec<(Vec<u8>, Vec<u8>)>, psch::dataflow::PlanStats) {
    let p = Pipeline::new("chain3");
    let handle = p
        .from_records(lines())
        .map_kv("tokenize", |_line: u64, text: Vec<u8>, out| {
            for w in std::str::from_utf8(&text).unwrap().split_whitespace() {
                out.emit(w.as_bytes().to_vec(), 1.0f64);
            }
            Ok(())
        })
        .map_kv("normalize", |word: Vec<u8>, c: f64, out| {
            out.emit(word.to_ascii_lowercase(), c);
            Ok(())
        })
        .group_reduce("count")
        .reducers(2)
        .reduce(|word: Vec<u8>, vs: &mut Group<'_, f64>, out| {
            let mut total = 0.0;
            while let Some(v) = vs.next_value() {
                total += v;
            }
            out.emit(word, total);
            Ok(())
        })
        .map_kv("bucket", |word: Vec<u8>, count: f64, out| {
            out.emit(word.len() as u64 % 3, count);
            Ok(())
        })
        .group_reduce("bucket-sum")
        .reducers(2)
        .reduce(|bucket: u64, vs: &mut Group<'_, f64>, out| {
            let mut total = 0.0;
            while let Some(v) = vs.next_value() {
                total += v;
            }
            out.emit(bucket, total);
            Ok(())
        })
        .collect();
    let plan = p.plan().unwrap();
    assert_eq!(
        plan.job_count(),
        2,
        "5 logical ops must plan into exactly 2 jobs"
    );
    let summaries = plan.stage_summaries();
    assert_eq!(summaries[0].fused_maps, 2, "tokenize + normalize fuse");
    assert!(summaries[0].has_reduce);
    assert_eq!(summaries[1].fused_maps, 1);
    assert!(summaries[1].has_reduce);
    let mut run = plan.run(svc).unwrap();
    let records = handle.take_raw(&mut run);
    (records, run.stats)
}

/// The same chain hand-wired on the raw engine: one JobBuilder job per
/// logical operator, outputs threaded by hand (what the coordinator code
/// looked like before the dataflow port).
fn run_hand_wired(svc: &Services) -> (Vec<(Vec<u8>, Vec<u8>)>, usize) {
    let byte_splits: Vec<Vec<(Vec<u8>, Vec<u8>)>> = lines()
        .into_iter()
        .map(|split| {
            split
                .into_iter()
                .map(|(k, v)| (encode_u64(k).to_vec(), v))
                .collect()
        })
        .collect();
    fn identity() -> Arc<dyn psch::mapreduce::Mapper> {
        Arc::new(FnMapper(|k: &[u8], v: &[u8], ctx: &mut TaskContext| {
            ctx.emit(k.to_vec(), v.to_vec());
            Ok(())
        }))
    }
    fn sum() -> Arc<dyn psch::mapreduce::Reducer> {
        Arc::new(FnReducer(
            |k: &[u8], vs: &mut dyn Values, ctx: &mut TaskContext| {
                let mut total = 0.0;
                while let Some(v) = vs.next_value() {
                    total += decode_f64(v);
                }
                ctx.emit(k.to_vec(), encode_f64(total).to_vec());
                Ok(())
            },
        ))
    }
    let mut jobs = 0;
    // Job 1: tokenize (map-only).
    let tokenize = Arc::new(FnMapper(|_k: &[u8], v: &[u8], ctx: &mut TaskContext| {
        for w in std::str::from_utf8(v).unwrap().split_whitespace() {
            ctx.emit(w.as_bytes().to_vec(), encode_f64(1.0).to_vec());
        }
        Ok(())
    }));
    let r1 = mapreduce::run(
        &svc.cluster,
        &JobBuilder::new("tokenize", byte_splits, tokenize).build(),
    )
    .unwrap();
    jobs += 1;
    // Job 2: normalize (map-only).
    let normalize = Arc::new(FnMapper(|k: &[u8], v: &[u8], ctx: &mut TaskContext| {
        ctx.emit(k.to_ascii_lowercase(), v.to_vec());
        Ok(())
    }));
    let r2 = mapreduce::run(
        &svc.cluster,
        &JobBuilder::new("normalize", r1.output, normalize).build(),
    )
    .unwrap();
    jobs += 1;
    // Job 3: count (identity map + sum reduce).
    let r3 = mapreduce::run(
        &svc.cluster,
        &JobBuilder::new("count", r2.output, identity())
            .reducer(sum(), 2)
            .build(),
    )
    .unwrap();
    jobs += 1;
    // Job 4: bucket (map-only).
    let bucket = Arc::new(FnMapper(|k: &[u8], v: &[u8], ctx: &mut TaskContext| {
        ctx.emit(encode_u64(k.len() as u64 % 3).to_vec(), v.to_vec());
        Ok(())
    }));
    let r4 = mapreduce::run(
        &svc.cluster,
        &JobBuilder::new("bucket", r3.output, bucket).build(),
    )
    .unwrap();
    jobs += 1;
    // Job 5: bucket-sum (identity map + sum reduce).
    let mut r5 = mapreduce::run(
        &svc.cluster,
        &JobBuilder::new("bucket-sum", r4.output, identity())
            .reducer(sum(), 2)
            .build(),
    )
    .unwrap();
    jobs += 1;
    (r5.sorted_records(), jobs)
}

#[test]
fn three_stage_chain_matches_hand_wired_jobs_byte_for_byte() {
    let svc = quick_services();
    let (pipeline_records, stats) = run_pipeline(&svc);
    let (hand_records, hand_jobs) = run_hand_wired(&svc);
    assert_eq!(
        pipeline_records, hand_records,
        "pipeline output must be byte-identical to the hand-wired chain"
    );
    assert!(
        stats.jobs() < hand_jobs,
        "fusion must launch fewer jobs: {} vs {}",
        stats.jobs(),
        hand_jobs
    );
    assert_eq!(stats.jobs(), 2);
    assert_eq!(hand_jobs, 5);
    // Sanity on the answer itself: 13 words total across 3 buckets.
    let total: f64 = pipeline_records.iter().map(|(_, v)| decode_f64(v)).sum();
    assert_eq!(total, 13.0);
}

#[test]
fn chained_pipeline_stages_intermediates_in_dfs() {
    let svc = quick_services();
    let (_, stats) = run_pipeline(&svc);
    assert!(stats.staged_bytes > 0, "stage boundary must stage bytes");
    assert!(
        svc.dfs.exists("/dataflow/chain3/stage-0"),
        "staged intermediate must live in the DFS: {:?}",
        svc.dfs.list()
    );
}

#[test]
fn quick_config_driver_explains_plans_without_running() {
    let ps = gaussian_blobs(120, 3, 4, 0.4, 8.0, 3);
    let driver = Driver::new(quick_config(), Arc::new(KernelRuntime::native()));
    let text = driver
        .explain_plan(&PipelineInput::Points { points: ps.points.clone() })
        .unwrap();
    assert!(text.contains("plan similarity: 1 job"), "{text}");
    assert!(text.contains("2 ops fused"), "laplacian fusion: {text}");
    assert!(text.contains("est. shuffle"), "{text}");
}

#[test]
fn lanczos_phase_fuses_maps_and_keeps_job_count() {
    // End-to-end fusion proof on the real phase: the Laplacian build is
    // TWO logical map ops (normalize + table put) but the eigen phase
    // still launches exactly 1 + steps jobs.
    let svc = quick_services();
    let ps = gaussian_blobs(150, 3, 4, 0.4, 8.0, 3);
    let flat: Vec<f32> = ps.points.iter().flatten().map(|&x| x as f32).collect();
    let sim = psch::coordinator::similarity_job::run_similarity_phase(
        &svc,
        Arc::new(flat),
        150,
        4,
        1.0,
        1e-8,
        "S",
    )
    .unwrap();
    let s_table = svc.tables.open("S").unwrap();
    let eig = psch::coordinator::lanczos_job::run_eigen_phase(
        &svc,
        &s_table,
        Arc::new(sim.degrees),
        150,
        3,
        30,
        7,
    )
    .unwrap();
    assert_eq!(
        eig.stats.jobs,
        1 + eig.steps,
        "fused laplacian-build stays one job; one matvec job per step"
    );
}
