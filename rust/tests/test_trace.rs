//! Integration tests for the cluster-wide tracer (DESIGN.md §2.11): span
//! nesting, deterministic Chrome trace-event export, critical-path
//! accounting, and the unified RunReport schema.
//!
//! One traced quick-config pipeline run is shared across tests via a
//! `OnceLock` fixture; determinism is checked by running the identical
//! configuration twice from fresh services and comparing exported bytes.

use std::sync::{Arc, OnceLock};

use psch::config::Config;
use psch::coordinator::{Driver, PipelineInput, PipelineResult};
use psch::data::gaussian_blobs;
use psch::eval::{ari, nmi};
use psch::runtime::KernelRuntime;
use psch::trace::json::Value;
use psch::trace::report::RUN_REPORT_SCHEMA;
use psch::trace::{critical, export, report, SpanKind, TraceData};

struct Fixture {
    cfg: Config,
    result: PipelineResult,
    quality: (f64, f64),
    data: TraceData,
    /// Chrome trace JSON from two independent same-seed runs.
    json_a: String,
    json_b: String,
}

fn traced_run(cfg: &Config) -> (PipelineResult, TraceData) {
    let ps = gaussian_blobs(150, cfg.algo.k, 4, 0.3, 10.0, 42);
    let input = PipelineInput::Points { points: ps.points };
    let driver = Driver::new(cfg.clone(), Arc::new(KernelRuntime::native()));
    let services = driver.services();
    services
        .cluster
        .trace()
        .enable(cfg.cluster.slaves, cfg.cluster.slots_per_slave);
    let result = driver.run_on(&services, &input).expect("pipeline run");
    let data = services.cluster.trace().snapshot().expect("trace enabled");
    (result, data)
}

fn fixture() -> &'static Fixture {
    static FIXTURE: OnceLock<Fixture> = OnceLock::new();
    FIXTURE.get_or_init(|| {
        let cfg = Config::load("configs/quick.toml").expect("quick config");
        let truth = gaussian_blobs(150, cfg.algo.k, 4, 0.3, 10.0, 42).labels;
        let (result, data) = traced_run(&cfg);
        let (result_b, data_b) = traced_run(&cfg);
        assert_eq!(result.labels, result_b.labels, "pipeline must be deterministic");
        let json_a = export::chrome_trace_json(&data);
        let json_b = export::chrome_trace_json(&data_b);
        let quality = (nmi(&truth, &result.labels), ari(&truth, &result.labels));
        Fixture { cfg, result, quality, data, json_a, json_b }
    })
}

#[test]
fn trace_covers_all_three_phases_with_jobs() {
    let fx = fixture();
    let data = &fx.data;
    assert!(data.makespan_s > 0.0);
    assert_eq!(data.slaves, fx.cfg.cluster.slaves);
    assert_eq!(data.slots_per_slave, fx.cfg.cluster.slots_per_slave);
    let names: Vec<&str> = data.phases.iter().map(|p| p.name.as_str()).collect();
    assert_eq!(names, ["similarity", "eigenvectors", "kmeans"]);
    for (i, p) in data.phases.iter().enumerate() {
        assert!(p.end_s >= p.start_s, "phase {} runs backward", p.name);
        if i > 0 {
            assert!(
                (p.start_s - data.phases[i - 1].end_s).abs() < 1e-9,
                "phase windows must abut"
            );
        }
        assert!(
            data.jobs.iter().any(|j| j.phase == p.name),
            "phase {} recorded no jobs",
            p.name
        );
    }
    // Jobs tile the run: consecutive starts advance by virtual_s, and the
    // last job ends at the makespan.
    let mut cursor = 0.0;
    for job in &data.jobs {
        assert!((job.start_s - cursor).abs() < 1e-9, "{}: gap in timeline", job.name);
        cursor += job.virtual_s;
        let sum: f64 = job.segments.iter().map(|s| s.seconds).sum();
        assert!(
            (sum - job.virtual_s).abs() < 1e-6,
            "{}: segments sum {sum} != virtual {}",
            job.name,
            job.virtual_s
        );
    }
    assert!((cursor - data.makespan_s).abs() < 1e-9);
}

#[test]
fn spans_nest_attempts_in_jobs_and_fetches_in_reduce_attempts() {
    let data = &fixture().data;
    let jobs: Vec<_> = data.spans.iter().filter(|s| s.kind == SpanKind::Job).collect();
    assert!(!jobs.is_empty());
    let attempts: Vec<_> =
        data.spans.iter().filter(|s| s.kind == SpanKind::Attempt).collect();
    assert!(!attempts.is_empty());
    for a in &attempts {
        assert!(
            jobs.iter()
                .any(|j| a.start_s >= j.start_s - 1e-9 && a.end_s <= j.end_s + 1e-9),
            "attempt {} [{}, {}] escapes every job span",
            a.name,
            a.start_s,
            a.end_s
        );
        let max_track = data.slaves * data.slots_per_slave;
        assert!(
            a.track >= 1 && a.track <= max_track,
            "attempt {} on bad track {}",
            a.name,
            a.track
        );
    }
    // Every fetch child sits inside a reduce attempt on the same track.
    let fetches: Vec<_> =
        data.spans.iter().filter(|s| s.kind == SpanKind::Fetch).collect();
    assert!(!fetches.is_empty(), "reduce jobs must trace per-reducer fetches");
    for f in &fetches {
        assert!(
            attempts.iter().any(|a| {
                a.name.starts_with("reduce")
                    && a.track == f.track
                    && f.start_s >= a.start_s - 1e-9
                    && f.end_s <= a.end_s + 1e-9
            }),
            "fetch [{}, {}] on track {} has no covering reduce attempt",
            f.start_s,
            f.end_s,
            f.track
        );
    }
    // IO children tile winners: dispatch/read/compute/write stay inside
    // some attempt on their track.
    for c in data.spans.iter().filter(|s| {
        matches!(
            s.kind,
            SpanKind::Dispatch | SpanKind::Read | SpanKind::Compute | SpanKind::Write
        )
    }) {
        assert!(
            attempts.iter().any(|a| {
                a.track == c.track
                    && c.start_s >= a.start_s - 1e-9
                    && c.end_s <= a.end_s + 1e-9
            }),
            "{} child [{}, {}] escapes its attempt",
            c.name,
            c.start_s,
            c.end_s
        );
    }
}

#[test]
fn chrome_trace_export_is_valid_and_byte_identical_across_runs() {
    let fx = fixture();
    assert_eq!(fx.json_a, fx.json_b, "same-seed traces must serialize identically");
    let v = Value::parse(&fx.json_a).expect("valid JSON");
    assert_eq!(v.get("displayTimeUnit").unwrap().as_str(), Some("ms"));
    let events = v.get("traceEvents").unwrap().items().expect("array");
    assert!(events.len() > 10, "only {} events", events.len());
    let mut seen_x = 0u32;
    let mut seen_meta = false;
    for e in events {
        let ph = e.get("ph").unwrap().as_str().unwrap();
        match ph {
            "X" => {
                seen_x += 1;
                assert!(e.get("ts").unwrap().as_u64().is_some());
                assert!(e.get("dur").unwrap().as_u64().is_some());
                assert!(e.get("pid").is_some() && e.get("tid").is_some());
                assert!(e.get("cat").unwrap().as_str().is_some());
            }
            "M" => seen_meta = true,
            "i" => assert!(e.get("s").unwrap().as_str() == Some("g")),
            other => panic!("unexpected event phase {other:?}"),
        }
    }
    assert!(seen_x > 0, "no complete events");
    assert!(seen_meta, "no track-name metadata");
}

#[test]
fn critical_path_total_matches_virtual_makespan() {
    let data = &fixture().data;
    let cp = critical::analyze(data, 5);
    assert!(
        (cp.total_s - data.makespan_s).abs() < 1e-6,
        "critical path {} != makespan {}",
        cp.total_s,
        data.makespan_s
    );
    let by_phase: f64 = cp.by_phase.iter().map(|p| p.seconds).sum();
    assert!((by_phase - cp.total_s).abs() < 1e-6);
    let by_kind: f64 = cp.by_kind.iter().map(|k| k.seconds).sum();
    assert!((by_kind - cp.total_s).abs() < 1e-6);
    assert!(cp.top.len() <= 5 && !cp.top.is_empty());
    let rendered = critical::render_report(data, 5);
    assert!(rendered.starts_with("critical path:"), "{rendered}");
    assert!(rendered.contains("stragglers["));
}

#[test]
fn run_report_validates_against_documented_schema() {
    let fx = fixture();
    let doc = report::run_report_json(&fx.cfg, &fx.result, Some(fx.quality), Some(&fx.data));
    let v = Value::parse(&doc).expect("valid RunReport JSON");
    assert_eq!(v.get("schema").unwrap().as_str(), Some(RUN_REPORT_SCHEMA));

    let cfg = v.get("config").expect("config echo");
    assert_eq!(
        cfg.get("cluster").unwrap().get("slaves").unwrap().as_u64(),
        Some(fx.cfg.cluster.slaves as u64)
    );

    let totals = v.get("totals").expect("totals");
    let virt = totals.get("virtual_s").unwrap().as_f64().unwrap();
    assert!((virt - fx.result.total_virtual_s).abs() < 1e-6);
    assert_eq!(totals.get("nnz").unwrap().as_u64(), Some(fx.result.nnz));

    let phases = v.get("phases").unwrap().items().expect("phase array");
    assert_eq!(phases.len(), 3);
    for (p, stats) in phases.iter().zip(&fx.result.phases) {
        assert_eq!(p.get("name").unwrap().as_str(), Some(stats.name.as_str()));
        assert!(p.get("counters").is_some());
        assert!(p.get("shuffle").is_some());
    }

    let quality = v.get("quality").expect("quality");
    assert!((quality.get("nmi").unwrap().as_f64().unwrap() - fx.quality.0).abs() < 1e-9);

    let trace = v.get("trace").expect("trace section");
    let makespan = trace.get("makespan_s").unwrap().as_f64().unwrap();
    assert!((makespan - fx.data.makespan_s).abs() < 1e-6);
    let cp = trace.get("critical_path").expect("critical_path");
    assert!((cp.get("total_s").unwrap().as_f64().unwrap() - makespan).abs() < 1e-6);
    assert!(trace.get("stragglers").unwrap().items().is_some());

    // Without quality or trace, those sections are null, not absent.
    let bare = report::run_report_json(&fx.cfg, &fx.result, None, None);
    let v = Value::parse(&bare).expect("valid bare RunReport");
    assert!(matches!(v.get("quality"), Some(Value::Null)));
    assert!(matches!(v.get("trace"), Some(Value::Null)));
}
