//! Integration: the unified failure domain across scheduler, engine,
//! shuffle and DFS — the chaos story the paper credits Hadoop for.
//!
//! Determinism is the headline invariant: the failure domain only decides
//! *where and when* work re-executes, never *what* it computes, so a run
//! with seeded faults on must produce byte-identical output to a run with
//! faults off.

use std::sync::Arc;

use psch::cluster::{NodeDeath, TaskCost};
use psch::config::Config;
use psch::coordinator::{Driver, PipelineInput, Services};
use psch::data::gaussian_blobs;
use psch::mapreduce::names;
use psch::runtime::KernelRuntime;
use psch::scheduler::TaskSpec;

fn native() -> Arc<KernelRuntime> {
    Arc::new(KernelRuntime::native())
}

fn phase_counter(r: &psch::coordinator::PipelineResult, name: &str) -> u64 {
    r.phases.iter().map(|p| p.counters.get(name)).sum()
}

/// Every DFS file the two runs share must hold identical bytes.
fn assert_dfs_identical(a: &Services, b: &Services) {
    let paths = a.dfs.list();
    assert_eq!(paths, b.dfs.list(), "runs left different DFS file sets");
    for path in paths {
        assert_eq!(
            a.dfs.read_file(&path).unwrap(),
            b.dfs.read_file(&path).unwrap(),
            "{path} differs between the runs"
        );
    }
}

#[test]
fn seeded_faults_on_vs_off_produce_byte_identical_outputs() {
    // The chaos determinism satellite: all three phases on the quick
    // config, faults off vs seeded attempt failures on.
    let base = Config::load("configs/quick.toml").unwrap();
    let ps = gaussian_blobs(400, base.algo.k, 4, 0.3, 10.0, 3);
    let input = PipelineInput::Points { points: ps.points.clone() };

    let clean_driver = Driver::new(base.clone(), native());
    let clean_svc = clean_driver.services();
    let clean = clean_driver.run_on(&clean_svc, &input).unwrap();

    let mut chaos_cfg = base;
    chaos_cfg.faults.task_fail_prob = 0.04;
    chaos_cfg.faults.seed = 9;
    let chaos_driver = Driver::new(chaos_cfg, native());
    let chaos_svc = chaos_driver.services();
    let chaos = chaos_driver.run_on(&chaos_svc, &input).unwrap();

    // Byte-identical outputs: labels, eigenvalues, every DFS artifact.
    assert_eq!(clean.labels, chaos.labels);
    assert_eq!(clean.eigenvalues, chaos.eigenvalues);
    assert_eq!(clean.nnz, chaos.nnz);
    assert_dfs_identical(&clean_svc, &chaos_svc);

    // ... while the failure domain demonstrably acted.
    let failed = phase_counter(&chaos, names::FAILED_MAP_ATTEMPTS)
        + phase_counter(&chaos, names::FAILED_REDUCE_ATTEMPTS);
    assert!(failed > 0, "4% attempt-failure rate must fail something");
    assert_eq!(phase_counter(&clean, names::MAP_RERUNS), 0);
    assert!(
        chaos.total_virtual_s > clean.total_virtual_s,
        "re-planned attempts must cost virtual time: {} vs {}",
        chaos.total_virtual_s,
        clean.total_virtual_s
    );
}

#[test]
fn node_death_mid_similarity_recovers_lost_maps_and_rereplicates() {
    // The acceptance scenario: quick config, one slave killed
    // mid-similarity-phase. The run must complete with byte-identical
    // output, re-execute the lost map outputs on live nodes (MAP_RERUNS,
    // FETCH_FAILURES) and re-replicate the dead slave's DFS blocks.
    //
    // n = 600 gives the similarity job 3 paired map tasks on the 2-slave
    // quick cluster, so slave 1 always owns at least one map output.
    let base = Config::load("configs/quick.toml").unwrap();
    let n = 600;
    let ps = gaussian_blobs(n, base.algo.k, 4, 0.3, 10.0, 3);
    let input = PipelineInput::Points { points: ps.points.clone() };

    let clean_driver = Driver::new(base.clone(), native());
    let clean = clean_driver.run(&input).unwrap();

    // Locate the similarity phase on the cluster-wide heartbeat clock by
    // dry-running phase 1 alone on identical services: it consumes ticks
    // [1, h]. A death one tick before h lands inside the phase's reduce
    // plan, after every map completed — the exact lost-output window. The
    // dry run's reduce timing is measured (slightly noisy), so probe a
    // small neighbourhood; every probe must keep the output byte-identical
    // and at least one must exercise the recovery path.
    let probe_svc = Driver::new(base.clone(), native()).services();
    let flat: Vec<f32> = ps.points.iter().flatten().map(|&x| x as f32).collect();
    psch::coordinator::similarity_job::run_similarity_phase(
        &probe_svc,
        Arc::new(flat),
        n,
        4,
        base.algo.sigma.fixed().unwrap(),
        base.algo.epsilon,
        "S",
    )
    .unwrap();
    let h = probe_svc.cluster.faults().heartbeats();
    assert!(h > 4, "similarity phase must span several heartbeats: {h}");

    let mut probes: Vec<u64> = vec![
        h.saturating_sub(1).max(1),
        h.saturating_sub(2).max(1),
        h,
        h.saturating_sub(4).max(1),
        h + 2,
    ];
    probes.dedup();
    let mut recovered_at = None;
    for hb in probes {
        let mut cfg = base.clone();
        cfg.faults.node_deaths = vec![NodeDeath { slave: 1, at_heartbeat: hb }];
        let driver = Driver::new(cfg, native());
        let svc = driver.services();
        let r = driver.run_on(&svc, &input).unwrap();
        assert_eq!(r.labels, clean.labels, "death at hb {hb} changed the labels");
        assert_eq!(r.eigenvalues, clean.eigenvalues, "death at hb {hb}");
        assert_eq!(phase_counter(&r, names::NODE_DEATHS), 1, "death must fire");

        // DFS side: the datanode died with its slave; no block location
        // references it and every file still reads.
        assert_eq!(svc.dfs.alive_count(), svc.cluster.num_slaves() - 1);
        for path in svc.dfs.list() {
            for hosts in svc.dfs.block_hosts(&path).unwrap() {
                assert!(
                    !hosts.contains(&1),
                    "{path} still lists the dead datanode: {hosts:?}"
                );
            }
            assert!(svc.dfs.read_file(&path).is_ok(), "{path} unreadable");
        }
        if phase_counter(&r, names::MAP_RERUNS) > 0
            && phase_counter(&r, names::FETCH_FAILURES) > 0
        {
            recovered_at = Some(hb);
            break;
        }
    }
    assert!(
        recovered_at.is_some(),
        "no probed death time exercised lost-map re-execution"
    );
}

#[test]
fn scheduled_death_rereplicates_dfs_blocks_onto_survivors() {
    // 3 datanodes, replication 2: after slave 1 dies, every block must be
    // back at 2 replicas, all on survivors.
    let mut cfg = Config::default();
    cfg.cluster.slaves = 3;
    cfg.cluster.replication = 2;
    cfg.faults.node_deaths = vec![NodeDeath { slave: 1, at_heartbeat: 2 }];
    cfg.validate().unwrap();
    let svc = Services::from_config(&cfg, native());

    let files: Vec<(String, Vec<u8>)> = (0..3u8)
        .map(|i| {
            (
                format!("/chaos/file-{i}"),
                (0..200u8).map(|b| b.wrapping_mul(i + 1)).collect(),
            )
        })
        .collect();
    for (path, data) in &files {
        svc.dfs.write_file(path, data).unwrap();
    }
    // With round-robin placement the dead node holds some replicas.
    let held_before: usize = files
        .iter()
        .flat_map(|(p, _)| svc.dfs.block_hosts(p).unwrap())
        .filter(|hosts| hosts.contains(&1))
        .count();
    assert!(held_before > 0, "test premise: node 1 must hold replicas");

    // Drive the cluster-wide heartbeat clock past the scheduled death.
    let tasks: Vec<TaskSpec> = (0..4)
        .map(|_| TaskSpec {
            cost: TaskCost { compute_s: 1.0, input_bytes: 0, output_bytes: 0 },
            hosts: vec![],
        })
        .collect();
    let plan = svc.cluster.plan_phase(&tasks);
    assert_eq!(plan.deaths, 1, "the scheduled death fires during the plan");

    assert_eq!(svc.dfs.alive_count(), 2);
    for (path, data) in &files {
        for hosts in svc.dfs.block_hosts(path).unwrap() {
            assert_eq!(hosts.len(), 2, "{path}: replication not restored");
            assert!(!hosts.contains(&1), "{path}: dead node still listed");
        }
        assert_eq!(&svc.dfs.read_file(path).unwrap(), data);
    }
}

#[test]
fn chaos_config_drives_a_full_run() {
    // The shipped chaos example completes and reports its faults.
    let cfg = Config::load("configs/chaos.toml").unwrap();
    let ps = gaussian_blobs(300, cfg.algo.k, 4, 0.3, 10.0, 1);
    let clean = {
        let mut quiet = cfg.clone();
        quiet.faults = Default::default();
        Driver::new(quiet, native())
            .run(&PipelineInput::Points { points: ps.points.clone() })
            .unwrap()
    };
    let r = Driver::new(cfg, native())
        .run(&PipelineInput::Points { points: ps.points.clone() })
        .unwrap();
    assert_eq!(clean.labels, r.labels, "chaos must not change the clustering");
    let summaries: Vec<_> = r.phases.iter().map(|p| p.fault_summary()).collect();
    assert!(
        summaries.iter().any(|s| s.any()),
        "chaos.toml schedules faults; some phase must report them"
    );
}
