//! Integration: the eigensolver layer — the chebdav backend against the
//! lanczos backend (embedding parity, strictly fewer jobs), against the
//! single-machine oracle, and against the failure domain (byte-identical
//! output with faults on).

use std::sync::Arc;

use psch::config::Config;
use psch::coordinator::eigen::EigenSolverKind;
use psch::coordinator::{Driver, PipelineInput};
use psch::data::gaussian_blobs;
use psch::eval::nmi;
use psch::linalg::{estimate_spectrum_bounds, jacobi_eigen};
use psch::mapreduce::names;
use psch::runtime::KernelRuntime;
use psch::spectral::{laplacian_dense, laplacian_sparse, rbf_dense, rbf_sparse};

fn native() -> Arc<KernelRuntime> {
    Arc::new(KernelRuntime::native())
}

fn driver(cfg: Config) -> Driver {
    Driver::new(cfg, native())
}

fn phase_counter(r: &psch::coordinator::PipelineResult, name: &str) -> u64 {
    r.phases.iter().map(|p| p.counters.get(name)).sum()
}

/// Quick-shaped config with a selectable backend.
fn cfg_with_solver(solver: EigenSolverKind) -> Config {
    let mut cfg = Config::default();
    cfg.cluster.slaves = 3;
    cfg.algo.k = 4;
    cfg.algo.sigma = 1.5.into();
    cfg.eigen.solver = solver;
    cfg
}

#[test]
fn chebdav_embedding_parity_with_lanczos() {
    // Both backends must cluster the same data equally well and agree on
    // the spectrum: same Laplacian, same k smallest eigenvalues.
    let ps = gaussian_blobs(300, 4, 8, 0.4, 8.0, 42);
    let input = PipelineInput::Points { points: ps.points.clone() };
    let lz = driver(cfg_with_solver(EigenSolverKind::Lanczos)).run(&input).unwrap();
    let cd = driver(cfg_with_solver(EigenSolverKind::ChebDav)).run(&input).unwrap();
    let lz_nmi = nmi(&ps.labels, &lz.labels);
    let cd_nmi = nmi(&ps.labels, &cd.labels);
    assert!(lz_nmi > 0.95, "lanczos quality: {lz_nmi}");
    assert!(cd_nmi > 0.95, "chebdav quality: {cd_nmi}");
    assert!(
        (lz_nmi - cd_nmi).abs() < 0.05,
        "backends must agree within tolerance: lanczos {lz_nmi} vs chebdav {cd_nmi}"
    );
    assert!(cd.eigenvalues[0].abs() < 1e-6, "{:?}", cd.eigenvalues);
    for (a, b) in lz.eigenvalues.iter().zip(&cd.eigenvalues) {
        assert!((a - b).abs() < 1e-5, "spectra differ: {a} vs {b}");
    }
}

#[test]
fn chebdav_launches_strictly_fewer_eigen_jobs_at_paper_config() {
    // The tentpole claim: O(outer iterations) jobs instead of O(steps).
    // Static bound first — the paper config's worst case is already a
    // strict win (1 Laplacian + bound_steps + max_outer·(degree+1) jobs
    // vs 1 + lanczos_steps).
    let paper = Config::load("configs/paper.toml").unwrap();
    assert!(
        1 + paper.eigen.max_operator_jobs() < 1 + paper.algo.lanczos_steps,
        "paper [eigen] knobs must undercut {} lanczos jobs, got worst case {}",
        1 + paper.algo.lanczos_steps,
        1 + paper.eigen.max_operator_jobs(),
    );

    // Then measured: both backends at the paper's algo settings (scaled-
    // down cluster + n to keep the test fast).
    let ps = gaussian_blobs(512, paper.algo.k, 8, 0.4, 8.0, paper.algo.seed);
    let input = PipelineInput::Points { points: ps.points.clone() };
    let mut lz_cfg = paper.clone();
    lz_cfg.cluster.slaves = 4;
    lz_cfg.eigen.solver = EigenSolverKind::Lanczos;
    let mut cd_cfg = lz_cfg.clone();
    cd_cfg.eigen.solver = EigenSolverKind::ChebDav;
    let lz = driver(lz_cfg).run(&input).unwrap();
    let cd = driver(cd_cfg).run(&input).unwrap();
    let (lz_jobs, cd_jobs) = (lz.phases[1].jobs, cd.phases[1].jobs);
    assert!(
        cd_jobs < lz_jobs,
        "chebdav must launch strictly fewer eigen jobs: {cd_jobs} vs {lz_jobs}"
    );
    // The counters tell the same story, and batching is real: more
    // mat-vecs priced per job than jobs launched.
    let cd_eigen = cd.phases[1].eigen_summary();
    assert_eq!(cd_eigen.eigen_jobs, cd_jobs as u64);
    assert!(cd_eigen.matvecs_batched > cd_eigen.eigen_jobs);
    assert_eq!(cd_eigen.filter_degree, 8);
    let lz_eigen = lz.phases[1].eigen_summary();
    assert_eq!(lz_eigen.filter_degree, 0, "lanczos runs unfiltered");
    // And quality does not pay for the job reduction.
    assert!(nmi(&ps.labels, &cd.labels) > 0.95);
}

#[test]
fn explain_plan_prices_chebdav_batching() {
    let mut cfg = cfg_with_solver(EigenSolverKind::ChebDav);
    cfg.algo.lanczos_steps = 60;
    let max_jobs = cfg.eigen.max_operator_jobs();
    assert!(max_jobs < 1 + cfg.algo.lanczos_steps);
    let ps = gaussian_blobs(120, 4, 8, 0.4, 8.0, 42);
    let d = driver(cfg);
    let plan = d
        .explain_plan(&PipelineInput::Points { points: ps.points.clone() })
        .unwrap();
    assert!(plan.contains("solver: chebdav"), "{plan}");
    assert!(plan.contains("columns per job"), "{plan}");
    assert!(
        plan.contains(&format!("= {max_jobs} operator jobs")),
        "plan must price the worst-case job count:\n{plan}"
    );
    // The lanczos plan for the same input advertises the per-step launch.
    let mut lz_cfg = cfg_with_solver(EigenSolverKind::Lanczos);
    lz_cfg.algo.lanczos_steps = 60;
    let lz_plan = driver(lz_cfg)
        .explain_plan(&PipelineInput::Points { points: ps.points })
        .unwrap();
    assert!(lz_plan.contains("solver: lanczos"), "{lz_plan}");
    assert!(!lz_plan.contains("columns per job"), "{lz_plan}");
}

#[test]
fn distributed_chebdav_matches_single_machine_oracle() {
    // The distributed block mat-vec reassembles bitwise to the oracle's
    // spmv_block_rows (unit-tested at the pipeline layer); end to end the
    // runs differ only through the f32 point shipping in phase 1, so the
    // spectra agree to similarity-graph precision and the partitions match.
    let ps = gaussian_blobs(300, 4, 8, 0.4, 8.0, 42);
    let dist = driver(cfg_with_solver(EigenSolverKind::ChebDav))
        .run(&PipelineInput::Points { points: ps.points.clone() })
        .unwrap();
    let params = psch::spectral::SpectralParams {
        k: 4,
        sigma: 1.5,
        ..Default::default()
    };
    let oracle = psch::spectral::spectral_cluster_points(
        &ps.points,
        &params,
        psch::spectral::Eigensolver::ChebDav,
    )
    .unwrap();
    let agreement = nmi(&oracle.labels, &dist.labels);
    assert!(agreement > 0.95, "oracle vs distributed partitions: {agreement}");
    for (a, b) in oracle.eigenvalues.iter().zip(&dist.eigenvalues) {
        assert!((a - b).abs() < 1e-3, "oracle {a} vs distributed {b}");
    }
}

#[test]
fn chebdav_is_byte_deterministic_under_faults() {
    // The chaos satellite: a chebdav run with seeded attempt failures AND
    // a mid-run node death must produce byte-identical output to the
    // fault-free run — reruns of row-independent block mat-vec tasks
    // reassemble to the same bytes.
    let mut base = Config::load("configs/quick.toml").unwrap();
    base.cluster.slaves = 3;
    base.eigen.solver = EigenSolverKind::ChebDav;
    base.validate().unwrap();
    let ps = gaussian_blobs(400, base.algo.k, 4, 0.3, 10.0, 3);
    let input = PipelineInput::Points { points: ps.points.clone() };

    let clean = driver(base.clone()).run(&input).unwrap();

    let mut chaos_cfg = base;
    chaos_cfg.faults.task_fail_prob = 0.04;
    chaos_cfg.faults.seed = 9;
    chaos_cfg.set("faults.fail_node", "1@6").unwrap();
    chaos_cfg.validate().unwrap();
    let chaos = driver(chaos_cfg).run(&input).unwrap();

    assert_eq!(clean.labels, chaos.labels);
    assert_eq!(clean.eigenvalues, chaos.eigenvalues, "bitwise spectrum");
    assert_eq!(clean.nnz, chaos.nnz);
    // The failure domain demonstrably acted on the chaos run.
    assert!(
        phase_counter(&chaos, names::FAILED_MAP_ATTEMPTS)
            + phase_counter(&chaos, names::FAILED_REDUCE_ATTEMPTS)
            > 0,
        "seeded failures must fail something"
    );
    assert!(
        phase_counter(&chaos, names::NODE_DEATHS) >= 1,
        "the scheduled death must fire mid-run"
    );
    // Same backend marker on both runs.
    assert!(phase_counter(&clean, names::CHEB_FILTER_DEGREE) > 0);
    assert_eq!(
        phase_counter(&clean, names::CHEB_FILTER_DEGREE),
        phase_counter(&chaos, names::CHEB_FILTER_DEGREE)
    );
}

#[test]
fn spectrum_bound_estimator_brackets_the_laplacian() {
    // The bounds the Chebyshev filter depends on: lower inside the
    // spectrum, upper at or above the top eigenvalue (the filter damps
    // [a, upper]; an upper below λmax would amplify the top of the
    // spectrum instead).
    let ps = gaussian_blobs(60, 3, 4, 0.4, 8.0, 7);
    let dense_l = laplacian_dense(&rbf_dense(&ps.points, 1.5));
    let (true_vals, _) = jacobi_eigen(&dense_l).unwrap();
    let (lo_true, hi_true) = (true_vals[0], *true_vals.last().unwrap());

    let s = rbf_sparse(&ps.points, 1.5, 1e-8);
    let l = laplacian_sparse(&s);
    let n = 60;
    let mut op = |x: &[f64], m: usize| l.spmv_block_rows(x, m, 0, n);
    let b = estimate_spectrum_bounds(n, 4, 0x5eed, &mut op).unwrap();
    // Slack covers the dense-vs-sparse graph difference (entries below
    // epsilon are dropped on the sparse side).
    assert!(b.lower <= b.upper);
    assert!(
        b.lower >= lo_true - 1e-4,
        "lower bound left the spectrum: {} < {lo_true}",
        b.lower
    );
    assert!(
        b.upper >= hi_true - 1e-4,
        "upper bound must dominate the top eigenvalue: {} < {hi_true}",
        b.upper
    );
    assert!(b.lower <= hi_true, "lower bound above the whole spectrum");
    assert_eq!(b.steps, 4);
}
