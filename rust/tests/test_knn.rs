//! Integration: the t-NN similarity subsystem (rust/src/knn) — index
//! equivalence, distributed-vs-oracle byte identity, symmetrization
//! semantics and the end-to-end tnn graph mode.

use std::sync::Arc;

use psch::cluster::Cluster;
use psch::config::Config;
use psch::coordinator::similarity_job::{read_similarity_row, BLOCK};
use psch::coordinator::{Driver, PipelineInput, Services};
use psch::data::gaussian_blobs;
use psch::knn::{
    run_tnn_phase, tnn_sparse, IndexKind, KnnConfig, KnnIndex, QueryStats,
};
use psch::mapreduce::names;
use psch::runtime::KernelRuntime;

fn flat(points: &[Vec<f64>]) -> Arc<Vec<f64>> {
    Arc::new(points.iter().flatten().copied().collect())
}

fn services_with(m: usize, knn: KnnConfig) -> Services {
    let mut svc = Services::new(Cluster::new(m), Arc::new(KernelRuntime::native()));
    svc.knn = knn;
    svc
}

/// Read every graph row back from the phase-1 table.
fn table_rows(svc: &Services, n: usize) -> Vec<Vec<(u32, f64)>> {
    let table = svc.tables.open("S").unwrap();
    let nb = n.div_ceil(BLOCK);
    (0..n)
        .map(|i| read_similarity_row(&table, i as u64, nb))
        .collect()
}

#[test]
fn kdtree_and_brute_force_oracles_are_bitwise_equal() {
    let (n, d) = (300, 4);
    let ps = gaussian_blobs(n, 3, d, 0.5, 6.0, 9);
    let pts = flat(&ps.points);
    for t in [1usize, 5, 17] {
        for leaf_size in [1usize, 8, 32] {
            let kd_cfg = KnnConfig { t, leaf_size, index: IndexKind::KdTree };
            let bf_cfg = KnnConfig { t, leaf_size, index: IndexKind::Brute };
            let kd = KnnIndex::build(pts.clone(), n, d, &kd_cfg);
            let bf = KnnIndex::build(pts.clone(), n, d, &bf_cfg);
            let mut kd_stats = QueryStats::default();
            let mut bf_stats = QueryStats::default();
            for i in 0..n {
                let a = kd
                    .query(kd.row(i), t, Some(i as u32), &mut kd_stats)
                    .into_sorted();
                let b = bf
                    .query(bf.row(i), t, Some(i as u32), &mut bf_stats)
                    .into_sorted();
                assert_eq!(a.len(), b.len(), "i={i} t={t} leaf={leaf_size}");
                for (x, y) in a.iter().zip(&b) {
                    assert_eq!(x.idx, y.idx, "i={i} t={t} leaf={leaf_size}");
                    assert_eq!(x.d2.to_bits(), y.d2.to_bits(), "i={i} t={t}");
                }
            }
            // Both oracles account for every candidate exactly once.
            let all = (n * (n - 1)) as u64;
            assert_eq!(kd_stats.pairs_evaluated + kd_stats.pruned_pairs, all);
            assert_eq!(bf_stats.pairs_evaluated + bf_stats.pruned_pairs, all);
            // Whole-matrix equality, exact.
            let a = tnn_sparse(&ps.points, 1.2, &kd_cfg);
            let b = tnn_sparse(&ps.points, 1.2, &bf_cfg);
            assert_eq!(a, b, "t={t} leaf={leaf_size}");
        }
    }
}

#[test]
fn oracle_graph_is_symmetric_with_bounded_heaps() {
    let n = 250;
    let ps = gaussian_blobs(n, 3, 4, 0.5, 6.0, 3);
    let cfg = KnnConfig { t: 6, ..Default::default() };
    let s = tnn_sparse(&ps.points, 1.5, &cfg);
    assert!(s.is_symmetric(0.0), "max-symmetrization must be exact");
    for i in 0..n {
        let row: Vec<(u32, f64)> = s.row(i).collect();
        assert!(
            row.iter().any(|&(j, v)| j as usize == i && v == 1.0),
            "row {i} lost its unit diagonal"
        );
        let off_diag = row.len() - 1;
        assert!(off_diag >= 1, "row {i} isolated");
        assert!(
            off_diag >= cfg.t.min(n - 1),
            "row {i}: the union keeps at least the row's own t"
        );
        assert!(off_diag <= n - 1);
    }
    // The bounded object is the pre-symmetrization heap: exactly
    // min(t, n-1) off-diagonal entries per row, self excluded, sorted.
    let index = KnnIndex::build(flat(&ps.points), n, 4, &cfg);
    let mut stats = QueryStats::default();
    for i in (0..n).step_by(11) {
        let nbrs = index
            .query(index.row(i), cfg.t, Some(i as u32), &mut stats)
            .into_sorted();
        assert_eq!(nbrs.len(), cfg.t.min(n - 1), "row {i} heap size");
        assert!(nbrs.iter().all(|nb| nb.idx as usize != i), "self excluded");
        for w in nbrs.windows(2) {
            assert!(
                w[0].d2 < w[1].d2 || (w[0].d2 == w[1].d2 && w[0].idx < w[1].idx),
                "row {i}: heap drains nearest-first"
            );
        }
    }
}

#[test]
fn distributed_graph_byte_identical_to_oracle() {
    let (n, d) = (300, 4);
    let ps = gaussian_blobs(n, 3, d, 0.4, 8.0, 3);
    let cfg = KnnConfig { t: 8, ..Default::default() };
    let svc = services_with(3, cfg);
    let out = run_tnn_phase(&svc, flat(&ps.points), n, d, 1.0, "S").unwrap();
    let oracle = tnn_sparse(&ps.points, 1.0, &cfg);
    let sums = oracle.row_sums();
    let rows = table_rows(&svc, n);
    for (i, row) in rows.iter().enumerate() {
        let want: Vec<(u32, f64)> = oracle.row(i).collect();
        assert_eq!(row.len(), want.len(), "row {i} nnz");
        for ((j1, v1), (j2, v2)) in row.iter().zip(&want) {
            assert_eq!(j1, j2, "row {i}");
            assert_eq!(v1.to_bits(), v2.to_bits(), "row {i} col {j1}");
        }
        assert_eq!(out.degrees[i].to_bits(), sums[i].to_bits(), "degree {i}");
    }
    assert_eq!(out.nnz, oracle.nnz() as u64);
    assert!(out.counters.get(names::KNN_PAIRS_EVALUATED) > 0);
    assert!(out.counters.get(names::KNN_PRUNED_PAIRS) > 0);
}

#[test]
fn distributed_graph_invariant_across_cluster_sizes() {
    let (n, d) = (220, 4);
    let ps = gaussian_blobs(n, 3, d, 0.4, 8.0, 7);
    let cfg = KnnConfig { t: 5, ..Default::default() };
    let run_at = |m: usize| {
        let svc = services_with(m, cfg);
        run_tnn_phase(&svc, flat(&ps.points), n, d, 1.5, "S").unwrap();
        table_rows(&svc, n)
    };
    let two = run_at(2);
    let four = run_at(4);
    for i in 0..n {
        assert_eq!(two[i].len(), four[i].len(), "row {i} nnz");
        for (a, b) in two[i].iter().zip(&four[i]) {
            assert_eq!(a.0, b.0, "row {i}");
            assert_eq!(a.1.to_bits(), b.1.to_bits(), "row {i} col {}", a.0);
        }
    }
}

#[test]
fn tnn_mode_end_to_end_recovers_blobs() {
    let ps = gaussian_blobs(240, 3, 4, 0.3, 10.0, 3);
    let mut cfg = Config::default();
    cfg.cluster.slaves = 3;
    cfg.algo.k = 3;
    cfg.algo.sigma = 1.5.into();
    cfg.set("algo.graph", "tnn").unwrap();
    cfg.set("knn.t", "12").unwrap();
    // Well-separated blobs ⇒ exactly-disconnected t-NN graph (0 eigenvalue
    // of multiplicity k); a full-dimension Krylov space resolves it.
    cfg.set("algo.lanczos_steps", "240").unwrap();
    cfg.validate().unwrap();
    let driver = Driver::new(cfg, Arc::new(KernelRuntime::native()));
    let input = PipelineInput::Points { points: ps.points.clone() };
    let r = driver.run(&input).unwrap();
    let score = psch::eval::nmi(&ps.labels, &r.labels);
    assert!(score > 0.9, "tnn end-to-end nmi={score}");
    assert!(r.nnz > 0);
    let knn = r.phases[0].knn_summary();
    assert!(knn.any(), "knn counters must reach the phase stats");
    assert!(knn.pruned_ratio() > 0.0, "index should prune on blob data");
    // The eigen/kmeans phases never touch the index.
    assert!(!r.phases[1].knn_summary().any());
    assert!(!r.phases[2].knn_summary().any());
}

#[test]
fn tnn_prices_fewer_pairs_than_epsilon_at_equal_n() {
    let (n, d) = (400, 4);
    let ps = gaussian_blobs(n, 3, d, 0.4, 8.0, 11);
    // Epsilon path.
    let svc = services_with(2, KnnConfig::default());
    let flat32: Vec<f32> = ps.points.iter().flatten().map(|&x| x as f32).collect();
    let eps_out = psch::coordinator::similarity_job::run_similarity_phase(
        &svc,
        Arc::new(flat32),
        n,
        d,
        1.5,
        1e-8,
        "S",
    )
    .unwrap();
    let eps_pairs = eps_out.counters.get(names::SIM_PAIRS_EVALUATED);
    // t-NN path.
    let svc = services_with(2, KnnConfig { t: 10, ..Default::default() });
    let tnn_out = run_tnn_phase(&svc, flat(&ps.points), n, d, 1.5, "S").unwrap();
    let tnn_pairs = tnn_out.counters.get(names::KNN_PAIRS_EVALUATED);
    assert!(eps_pairs > 0 && tnn_pairs > 0);
    assert!(
        tnn_pairs < eps_pairs,
        "t-NN must price fewer pairs: {tnn_pairs} vs {eps_pairs}"
    );
    assert!(tnn_out.nnz < eps_out.nnz, "t-NN graph is the sparser one");
}
