//! Integration: the full three-phase pipeline across input modes, slave
//! counts and failure injection — the paper's system exercised end to end.

use std::sync::Arc;

use psch::config::Config;
use psch::coordinator::{Driver, PipelineInput};
use psch::data::{gaussian_blobs, planted_graph};
use psch::eval::nmi;
use psch::runtime::KernelRuntime;

fn driver(m: usize, k: usize) -> Driver {
    let mut cfg = Config::default();
    cfg.cluster.slaves = m;
    cfg.algo.k = k;
    cfg.algo.sigma = 1.5.into();
    Driver::new(cfg, Arc::new(KernelRuntime::native()))
}

#[test]
fn pipeline_deterministic_across_runs() {
    let ps = gaussian_blobs(250, 3, 4, 0.3, 10.0, 3);
    let input = PipelineInput::Points { points: ps.points.clone() };
    let a = driver(2, 3).run(&input).unwrap();
    let b = driver(2, 3).run(&input).unwrap();
    assert_eq!(a.labels, b.labels, "same seed must reproduce labels");
    assert_eq!(a.eigenvalues, b.eigenvalues);
}

#[test]
fn pipeline_labels_invariant_to_slave_count() {
    // The partition must not depend on the cluster size — only times do.
    let ps = gaussian_blobs(250, 3, 4, 0.3, 10.0, 5);
    let input = PipelineInput::Points { points: ps.points.clone() };
    let r1 = driver(1, 3).run(&input).unwrap();
    let r5 = driver(5, 3).run(&input).unwrap();
    let agreement = nmi(&r1.labels, &r5.labels);
    assert!(agreement > 0.999, "m=1 vs m=5 disagree: {agreement}");
}

#[test]
fn pipeline_graph_mode_at_moderate_scale() {
    let topo = planted_graph(1_000, 3_000, 4, 0.03, 17);
    let mut cfg = Config::default();
    cfg.cluster.slaves = 4;
    cfg.algo.k = 4;
    cfg.algo.lanczos_steps = 80;
    let d = Driver::new(cfg, Arc::new(KernelRuntime::native()));
    let r = d.run(&PipelineInput::Graph { topology: topo.clone() }).unwrap();
    let score = nmi(&topo.labels(), &r.labels);
    assert!(score > 0.75, "n=1000 community recovery: {score}");
    // Eigen sanity: lambda_1 = 0, and a spectral gap after k-1 small ones.
    assert!(r.eigenvalues[0].abs() < 1e-8);
}

#[test]
fn pipeline_survives_transient_task_failures() {
    // Real task errors are re-executed by the engine on fresh rounds; the
    // pipeline then runs cleanly on the very same services.
    use std::sync::atomic::{AtomicUsize, Ordering};
    static CALLS: AtomicUsize = AtomicUsize::new(0);
    let ps = gaussian_blobs(200, 3, 4, 0.3, 10.0, 9);
    let d = driver(3, 3);
    let services = d.services();
    let mapper = Arc::new(psch::mapreduce::FnMapper(
        |k: &[u8], _v: &[u8], ctx: &mut psch::mapreduce::TaskContext| {
            if k == [0] && CALLS.fetch_add(1, Ordering::SeqCst) == 0 {
                return Err(psch::error::Error::MapReduce("flaky".into()));
            }
            ctx.emit(vec![1], vec![2]);
            Ok(())
        },
    ));
    let job = psch::mapreduce::JobBuilder::new(
        "flaky",
        vec![vec![(vec![0], vec![])], vec![(vec![1], vec![])]],
        mapper,
    )
    .build();
    let result = psch::mapreduce::run(&services.cluster, &job).unwrap();
    assert_eq!(
        result
            .counters
            .get(psch::mapreduce::names::FAILED_MAP_ATTEMPTS),
        1
    );
    // And the full pipeline still runs on the same services afterwards.
    let r = d
        .run_on(&services, &PipelineInput::Points { points: ps.points.clone() })
        .unwrap();
    assert!(nmi(&ps.labels, &r.labels) > 0.9);
}

#[test]
fn phase_times_structure() {
    let ps = gaussian_blobs(300, 3, 4, 0.3, 10.0, 1);
    let r = driver(4, 3)
        .run(&PipelineInput::Points { points: ps.points.clone() })
        .unwrap();
    assert_eq!(r.phases[0].name, "similarity");
    assert_eq!(r.phases[1].name, "eigenvectors");
    assert_eq!(r.phases[2].name, "kmeans");
    assert!(r.phases.iter().all(|p| p.virtual_s > 0.0));
    assert!(r.phases.iter().all(|p| p.jobs >= 1));
    let sum: f64 = r.phases.iter().map(|p| p.virtual_s).sum();
    assert!((sum - r.total_virtual_s).abs() < 1e-9);
}

#[test]
fn xla_and_native_backends_agree_end_to_end() {
    // Only meaningful when artifacts exist; skip silently otherwise.
    let dir = psch::runtime::artifacts_dir();
    let xla = KernelRuntime::auto(&dir);
    if xla.backend() != psch::runtime::Backend::Xla {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let ps = gaussian_blobs(300, 3, 4, 0.3, 10.0, 21);
    let input = PipelineInput::Points { points: ps.points.clone() };
    let mut cfg = Config::default();
    cfg.cluster.slaves = 2;
    cfg.algo.k = 3;
    cfg.algo.sigma = 1.5.into();
    let r_xla = Driver::new(cfg.clone(), Arc::new(xla)).run(&input).unwrap();
    let r_nat = Driver::new(cfg, Arc::new(KernelRuntime::native()))
        .run(&input)
        .unwrap();
    let agreement = nmi(&r_nat.labels, &r_xla.labels);
    assert!(agreement > 0.999, "backends disagree: {agreement}");
    for (a, b) in r_xla.eigenvalues.iter().zip(&r_nat.eigenvalues) {
        assert!((a - b).abs() < 1e-4, "eigenvalues differ: {a} vs {b}");
    }
}
