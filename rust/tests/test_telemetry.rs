//! Integration tests for the telemetry layer (DESIGN.md §2.15): the
//! byte-identical export guarantee across same-seed runs (Prometheus
//! snapshot, report-v2 timeseries/histograms), including chaos runs with
//! seeded fault injection, plus the `psch report diff` gate semantics and
//! v1-report backward compatibility.
//!
//! One traced quick-config pipeline run (executed twice from fresh
//! services) is shared across tests via a `OnceLock` fixture.

use std::sync::{Arc, OnceLock};

use psch::config::Config;
use psch::coordinator::{Driver, PipelineInput, PipelineResult};
use psch::data::gaussian_blobs;
use psch::runtime::KernelRuntime;
use psch::telemetry::{self, Telemetry};
use psch::trace::json::Value;
use psch::trace::{report, TraceData};

struct Fixture {
    cfg: Config,
    result: PipelineResult,
    data: TraceData,
    /// Telemetry derivations of two independent same-seed runs.
    tel_a: Telemetry,
    tel_b: Telemetry,
    /// Full RunReport documents of both runs.
    report_a: String,
    report_b: String,
}

fn traced_run(cfg: &Config) -> (PipelineResult, TraceData) {
    let ps = gaussian_blobs(150, cfg.algo.k, 4, 0.3, 10.0, 42);
    let input = PipelineInput::Points { points: ps.points };
    let driver = Driver::new(cfg.clone(), Arc::new(KernelRuntime::native()));
    let services = driver.services();
    services.cluster.enable_tracing();
    let result = driver.run_on(&services, &input).expect("pipeline run");
    let data = services.cluster.trace().snapshot().expect("trace enabled");
    (result, data)
}

fn fixture() -> &'static Fixture {
    static FIXTURE: OnceLock<Fixture> = OnceLock::new();
    FIXTURE.get_or_init(|| {
        let cfg = Config::load("configs/quick.toml").expect("quick config");
        let (result, data) = traced_run(&cfg);
        let (result_b, data_b) = traced_run(&cfg);
        assert_eq!(result.labels, result_b.labels, "pipeline must be deterministic");
        let tel_a = telemetry::from_trace(&data, cfg.cluster.racks);
        let tel_b = telemetry::from_trace(&data_b, cfg.cluster.racks);
        let report_a = report::run_report_json(&cfg, &result, None, Some(&data));
        let report_b = report::run_report_json(&cfg, &result_b, None, Some(&data_b));
        Fixture { cfg, result, data, tel_a, tel_b, report_a, report_b }
    })
}

#[test]
fn prometheus_snapshot_is_byte_identical_across_same_seed_runs() {
    let fx = fixture();
    let snap_a = telemetry::prometheus::render(&fx.tel_a, &fx.result.phases);
    let snap_b = telemetry::prometheus::render(&fx.tel_b, &fx.result.phases);
    assert_eq!(snap_a, snap_b, "Prometheus snapshots must match byte for byte");
    // The snapshot carries the headline families and no wall-clock metric.
    assert!(snap_a.contains("psch_makespan_seconds "), "{snap_a}");
    assert!(snap_a.contains("psch_phase_virtual_seconds{phase=\"similarity\"}"));
    assert!(snap_a.contains("psch_gauge_mean{name=\"busy_slots\"}"));
    assert!(snap_a.contains("psch_attempt_duration_seconds_bucket{le=\"+Inf\"}"));
    assert!(!snap_a.contains("wall"), "wall-clock values must never be exported");
}

#[test]
fn report_v2_telemetry_sections_are_byte_identical_across_runs() {
    let fx = fixture();
    assert_eq!(
        telemetry::timeseries_json(&fx.tel_a.timeseries),
        telemetry::timeseries_json(&fx.tel_b.timeseries)
    );
    assert_eq!(
        telemetry::histograms_json(&fx.tel_a.histograms),
        telemetry::histograms_json(&fx.tel_b.histograms)
    );
    // The full reports differ only through wall_s fields; their parsed
    // timeseries sections are equal.
    let va = Value::parse(&fx.report_a).unwrap();
    let vb = Value::parse(&fx.report_b).unwrap();
    assert_eq!(va.get("timeseries"), vb.get("timeseries"));
    assert_eq!(va.get("histograms"), vb.get("histograms"));
}

#[test]
fn gauges_stay_within_capacity_and_histograms_are_populated() {
    let fx = fixture();
    let total = fx.cfg.cluster.slaves * fx.cfg.cluster.slots_per_slave;
    assert_eq!(fx.tel_a.total_slots, total);
    let busy = fx
        .tel_a
        .timeseries
        .gauges
        .iter()
        .find(|g| g.name == "busy_slots")
        .expect("busy_slots gauge");
    assert!(busy.peak() as usize <= total);
    assert!(busy.peak() > 0, "a real run must occupy at least one slot");
    // Attempt durations: every job contributes its winning attempts.
    let attempts = &fx.tel_a.histograms[0];
    assert_eq!(attempts.name, "attempt_duration_seconds");
    assert!(attempts.count() > 0);
    assert!(attempts.percentile(50.0) > 0.0);
    assert!(attempts.percentile(95.0) >= attempts.percentile(50.0));
    // The sparkline renders one line per phase.
    let lines = telemetry::render_phase_utilization(&fx.data, &fx.tel_a);
    for phase in ["similarity", "eigenvectors", "kmeans"] {
        assert!(lines.contains(&format!("util {phase}")), "{lines}");
    }
}

#[test]
fn chaos_runs_export_byte_identical_telemetry_too() {
    let cfg = Config::load("configs/chaos.toml").expect("chaos config");
    let (result_a, data_a) = traced_run(&cfg);
    let (result_b, data_b) = traced_run(&cfg);
    assert_eq!(result_a.labels, result_b.labels);
    let tel_a = telemetry::from_trace(&data_a, cfg.cluster.racks);
    let tel_b = telemetry::from_trace(&data_b, cfg.cluster.racks);
    assert_eq!(
        telemetry::prometheus::render(&tel_a, &result_a.phases),
        telemetry::prometheus::render(&tel_b, &result_b.phases),
        "chaos telemetry must be as deterministic as the fault-free kind"
    );
    assert_eq!(
        telemetry::timeseries_json(&tel_a.timeseries),
        telemetry::timeseries_json(&tel_b.timeseries)
    );
    // Scheduled node deaths that fired show up in the liveness gauges
    // (whether `fail_node = "1@40"` fires depends on run length, so the
    // gauge is checked against the NODE_DEATHS counter, not a constant).
    let deaths_fired: u64 = result_a
        .phases
        .iter()
        .map(|p| p.counters.get(psch::mapreduce::names::NODE_DEATHS))
        .sum();
    let dead = tel_a
        .timeseries
        .gauges
        .iter()
        .find(|g| g.name == "dead_nodes")
        .expect("dead_nodes gauge");
    assert_eq!(dead.values[0], 0);
    assert_eq!(*dead.values.last().unwrap(), deaths_fired);
}

#[test]
fn report_diff_passes_same_seed_runs_and_flags_perturbations() {
    let fx = fixture();
    let a = telemetry::diff::summarize(&Value::parse(&fx.report_a).unwrap()).unwrap();
    let b = telemetry::diff::summarize(&Value::parse(&fx.report_b).unwrap()).unwrap();
    // Same-seed runs pass at ZERO tolerance: wall clock never enters the
    // summary, and everything virtual is byte-identical.
    let (lines, regressed) = telemetry::diff::diff(&a, &b, 0.0);
    let bad: Vec<&str> = lines
        .iter()
        .filter(|l| l.regressed)
        .map(|l| l.metric.as_str())
        .collect();
    assert!(!regressed, "same-seed diff must be clean: {bad:?}");
    assert!(lines.iter().any(|l| l.metric == "total.virtual_s"));
    assert!(lines.iter().any(|l| l.metric.starts_with("counter.")));
    assert!(lines.iter().any(|l| l.metric == "hist.attempt_duration_seconds.p95"));
    // A perturbed makespan regresses at zero tolerance...
    let mut slower = b.clone();
    slower.total_virtual_s *= 1.05;
    let (_, regressed) = telemetry::diff::diff(&a, &slower, 0.0);
    assert!(regressed, "a 5% slower makespan must fail the 0% gate");
    // ...and a loose tolerance forgives it again.
    let (_, regressed) = telemetry::diff::diff(&a, &slower, 10.0);
    assert!(!regressed);
}

#[test]
fn v1_reports_still_parse_through_the_updated_reader() {
    // A pre-telemetry document (no timeseries/histograms keys at all, the
    // exact v1 shape) summarizes cleanly and diffs against a v2 summary.
    let v1 = r#"{"schema":"psch.run_report.v1",
        "config":{"cluster":{"slaves":2}},
        "phases":[{"name":"similarity","virtual_s":10.0,"wall_s":0.5,
                   "counters":{"HEARTBEATS":100}},
                  {"name":"eigenvectors","virtual_s":5.0,"counters":{}},
                  {"name":"kmeans","virtual_s":2.0,"counters":{}}],
        "totals":{"virtual_s":17.0,"wall_s":0.9,"jobs":12,"nnz":100,
                  "sigma_resolved":1.5},
        "quality":{"nmi":0.95,"ari":0.9},
        "trace":null}"#;
    let s = telemetry::diff::summarize(&Value::parse(v1).unwrap()).unwrap();
    assert_eq!(s.schema, "psch.run_report.v1");
    assert_eq!(s.total_virtual_s, 17.0);
    assert_eq!(s.phases.len(), 3);
    assert_eq!(s.counters.get("HEARTBEATS"), Some(&100));
    assert_eq!(s.nmi, Some(0.95));
    assert!(s.percentiles.is_empty());
    // v1-vs-v1 at zero tolerance: identical documents pass.
    let (_, regressed) = telemetry::diff::diff(&s, &s, 0.0);
    assert!(!regressed);
    // And the current writer's v2 output summarizes with the same reader.
    let fx = fixture();
    let v2 = telemetry::diff::summarize(&Value::parse(&fx.report_a).unwrap()).unwrap();
    assert_eq!(v2.schema, "psch.run_report.v2");
    assert_eq!(v2.percentiles.len(), 4);
}
