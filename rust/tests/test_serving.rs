//! Integration: the serving layer end to end — artifact capture from a
//! real trained pipeline, file round-trips, training-set self-assignment,
//! and oracle/distributed byte identity (DESIGN.md §2.13).

use std::sync::Arc;

use psch::config::Config;
use psch::coordinator::{Driver, PipelineInput};
use psch::data::gaussian_blobs;
use psch::runtime::KernelRuntime;
use psch::serving::{
    assign_stream_oracle, run_assign, ModelArtifact, RefreshMode,
};

/// Train on blobs drawn exactly the way the CLI draws them (d = 8,
/// spread 0.4, separation 8.0) and capture the model artifact.
fn train(
    cfg: &Config,
    n: usize,
) -> (ModelArtifact, Vec<usize>, Vec<Vec<f64>>, Driver) {
    let ps = gaussian_blobs(n, cfg.algo.k, 8, 0.4, 8.0, cfg.algo.seed);
    let driver = Driver::new(cfg.clone(), Arc::new(KernelRuntime::native()));
    let result = driver
        .run(&PipelineInput::Points { points: ps.points.clone() })
        .unwrap();
    let model =
        ModelArtifact::from_run(driver.config(), &ps.points, &result).unwrap();
    (model, result.labels, ps.points, driver)
}

#[test]
fn artifact_file_round_trip_is_byte_identical() {
    let cfg = Config::load("configs/quick.toml").unwrap();
    let (model, _, _, _) = train(&cfg, 150);
    let dir = std::env::temp_dir().join("psch_serving_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("model.json");
    let path = path.to_str().unwrap();
    model.save(path).unwrap();
    let loaded = ModelArtifact::load(path).unwrap();
    assert_eq!(loaded, model, "save → load must reproduce the model");
    assert_eq!(
        loaded.to_json(),
        std::fs::read_to_string(path).unwrap(),
        "load → re-export must be byte-identical"
    );
}

#[test]
fn training_set_self_assignment_reproduces_run_labels() {
    // quick.toml pins landmarks = 0 (every training point is an anchor),
    // the exact-extension setting where assigning the training set back
    // through the model reproduces the run's own labels point for point.
    let cfg = Config::load("configs/quick.toml").unwrap();
    assert_eq!(cfg.serving.landmarks, 0, "quick.toml must keep all landmarks");
    let (model, run_labels, points, driver) = train(&cfg, 240);
    let flat: Vec<f64> = points.iter().flatten().copied().collect();
    let oracle = assign_stream_oracle(&model, &flat, &cfg.serving).unwrap();
    assert_eq!(oracle.labels, run_labels, "oracle self-assignment");
    let services = driver.services();
    let dist = run_assign(&services, &model, &flat, &cfg.serving).unwrap();
    assert_eq!(dist.labels, run_labels, "distributed self-assignment");
}

#[test]
fn distributed_assignment_matches_oracle_bitwise_on_a_trained_model() {
    let mut cfg = Config::load("configs/quick.toml").unwrap();
    cfg.serving.batch_points = 64;
    cfg.serving.refresh = RefreshMode::Minibatch;
    let (model, _, _, driver) = train(&cfg, 200);
    // A held-out stream from a different seed: several batches, every one
    // refreshing the centroids before the next.
    let held = gaussian_blobs(180, cfg.algo.k, 8, 0.4, 8.0, cfg.algo.seed + 1);
    let flat: Vec<f64> = held.points.iter().flatten().copied().collect();
    let oracle = assign_stream_oracle(&model, &flat, &cfg.serving).unwrap();
    let services = driver.services();
    let dist = run_assign(&services, &model, &flat, &cfg.serving).unwrap();
    assert_eq!(dist.labels, oracle.labels, "labels must match exactly");
    for (a, b) in dist.model.centroids.iter().zip(&oracle.model.centroids) {
        let ab: Vec<u64> = a.iter().map(|v| v.to_bits()).collect();
        let bb: Vec<u64> = b.iter().map(|v| v.to_bits()).collect();
        assert_eq!(ab, bb, "refreshed centroid bits must match");
    }
    assert_eq!(dist.model.counts, oracle.model.counts);
    assert!(oracle.refresh_updates > 0, "refresh must act across 3 batches");
    let s = dist.stats.serving_summary();
    assert_eq!(s.points, 180);
    assert_eq!(s.batches, 3, "180 points in batches of 64");
    assert_eq!(s.refresh_updates, oracle.refresh_updates);
    // The refreshed model is still a valid, byte-stable artifact.
    dist.model.validate().unwrap();
    let doc = dist.model.to_json();
    assert_eq!(ModelArtifact::from_json(&doc).unwrap().to_json(), doc);
}

#[test]
fn sigma_auto_model_serves_with_a_landmark_budget() {
    let mut cfg = Config::load("configs/quick.toml").unwrap();
    cfg.set("algo.sigma", "auto").unwrap();
    cfg.set("serving.landmarks", "64").unwrap();
    cfg.validate().unwrap();
    let (model, run_labels, points, _) = train(&cfg, 240);
    assert_eq!(model.m(), 64, "landmark budget must stride the training set");
    assert!(
        model.sigma.is_finite() && model.sigma > 0.0,
        "auto sigma must persist resolved: {}",
        model.sigma
    );
    // Nyström with a 64-point anchor subset still reproduces the partition
    // of well-separated blobs.
    let flat: Vec<f64> = points.iter().flatten().copied().collect();
    let out = assign_stream_oracle(&model, &flat, &cfg.serving).unwrap();
    let agreement = psch::eval::nmi(&run_labels, &out.labels);
    assert!(agreement > 0.9, "landmark-subset agreement: {agreement}");
}
