//! Integration: shuffle determinism and accounting invariants.
//!
//! The sort/spill/merge pipeline must be a pure optimization: combiner
//! on/off × spill threshold {tiny, huge} × merge factor {2, 16} all have
//! to produce byte-identical reduce output, and the spill counters must
//! cover every record when the buffer is tiny.

use std::sync::Arc;

use psch::cluster::Cluster;
use psch::mapreduce::{
    self, names, FnMapper, FnReducer, Job, JobBuilder, ShuffleConfig,
    TaskContext, Values, KV,
};
use psch::testutil::{check, Gen};
use psch::util::bytes::{decode_u64, encode_u64};
use psch::{prop_assert, scheduler};

/// A sum job over the given splits (u64 values — exactly associative, so
/// any spill/merge/combine grouping must reproduce identical bytes).
fn sum_job(
    splits: Vec<Vec<KV>>,
    n_reducers: usize,
    with_combiner: bool,
    shuffle: ShuffleConfig,
) -> Job {
    let mapper = Arc::new(FnMapper(
        |k: &[u8], v: &[u8], ctx: &mut TaskContext| {
            ctx.emit(k.to_vec(), v.to_vec());
            Ok(())
        },
    ));
    let sum = Arc::new(FnReducer(
        |k: &[u8], vs: &mut dyn Values, ctx: &mut TaskContext| {
            let mut total = 0u64;
            while let Some(v) = vs.next_value() {
                total += decode_u64(v);
            }
            ctx.emit(k.to_vec(), encode_u64(total).to_vec());
            Ok(())
        },
    ));
    let mut b = JobBuilder::new("shuffle-sum", splits, mapper)
        .reducer(sum.clone(), n_reducers)
        .shuffle_config(shuffle);
    if with_combiner {
        b = b.combiner(sum);
    }
    b.build()
}

fn random_splits(g: &mut Gen) -> Vec<Vec<KV>> {
    let n_records = g.usize_in(1, 300);
    let n_splits = g.usize_in(1, 6);
    let key_space = g.usize_in(1, 40);
    let mut splits: Vec<Vec<KV>> = (0..n_splits).map(|_| Vec::new()).collect();
    for i in 0..n_records {
        let key = g.usize_in(0, key_space - 1) as u64;
        let val = g.usize_in(0, 1000) as u64;
        splits[i % n_splits]
            .push((encode_u64(key).to_vec(), encode_u64(val).to_vec()));
    }
    splits
}

#[test]
fn prop_shuffle_knobs_never_change_reduce_output() {
    check("shuffle-determinism", 12, 0xD44, |g: &mut Gen| {
        let splits = random_splits(g);
        let n_reducers = g.usize_in(1, 5);
        let cluster = Cluster::new(g.usize_in(1, 4));

        // Reference: default shuffle configuration, no combiner.
        let reference = mapreduce::run(
            &cluster,
            &sum_job(splits.clone(), n_reducers, false, ShuffleConfig::default()),
        )
        .unwrap()
        .output;

        for with_combiner in [false, true] {
            for sort_buffer_kb in [1usize, 1 << 14] {
                for merge_factor in [2usize, 16] {
                    let cfg = ShuffleConfig {
                        sort_buffer_kb,
                        merge_factor,
                        fetch_parallelism: 3,
                    };
                    let r = mapreduce::run(
                        &cluster,
                        &sum_job(splits.clone(), n_reducers, with_combiner, cfg),
                    )
                    .unwrap();
                    prop_assert!(
                        r.output == reference,
                        "output diverged: combiner={with_combiner} \
                         buffer={sort_buffer_kb}kb factor={merge_factor}"
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_tiny_threshold_spills_at_least_every_map_output_record() {
    check("shuffle-spill-floor", 12, 0xE55, |g: &mut Gen| {
        let splits = random_splits(g);
        let cluster = Cluster::new(g.usize_in(1, 4));
        let tiny = ShuffleConfig {
            sort_buffer_kb: 1,
            merge_factor: g.usize_in(2, 16),
            fetch_parallelism: 2,
        };
        let r = mapreduce::run(&cluster, &sum_job(splits, 3, false, tiny)).unwrap();
        let map_out = r.counters.get(names::MAP_OUTPUT_RECORDS);
        let spilled = r.counters.get(names::SPILLED_RECORDS);
        prop_assert!(map_out > 0, "workload always emits");
        prop_assert!(
            spilled >= map_out,
            "tiny threshold must spill every record: {spilled} < {map_out}"
        );
        prop_assert!(
            r.counters.get(names::SPILLS) > 0,
            "no spills recorded"
        );
        Ok(())
    });
}

#[test]
fn fetch_tier_bytes_always_sum_to_shuffle_bytes() {
    // On a racked cluster every shuffled byte lands in exactly one of the
    // three fetch tiers, and the totals agree with the engine's stat.
    let mut cluster =
        Cluster::with_model(4, 2, psch::cluster::NetworkModel::default());
    cluster.set_topology(scheduler::RackTopology::uniform(4, 2));
    let splits: Vec<Vec<KV>> = (0..6)
        .map(|s| {
            (0..50)
                .map(|i| {
                    (
                        encode_u64((s * 50 + i) as u64 % 17).to_vec(),
                        encode_u64(i as u64).to_vec(),
                    )
                })
                .collect()
        })
        .collect();
    let r = mapreduce::run(
        &cluster,
        &sum_job(splits, 4, false, ShuffleConfig::default()),
    )
    .unwrap();
    let tiers = r.counters.get(names::SHUFFLE_FETCH_BYTES_LOCAL)
        + r.counters.get(names::SHUFFLE_FETCH_BYTES_RACK)
        + r.counters.get(names::SHUFFLE_FETCH_BYTES_REMOTE);
    assert!(r.stats.shuffle_bytes > 0);
    assert_eq!(tiers, r.stats.shuffle_bytes);
    assert!(r.stats.shuffle_fetch_s > 0.0);
    assert!(r.stats.virtual_time_s > r.stats.shuffle_fetch_s);
}
