//! Integration: the JobTracker scheduler against the live pipeline — the
//! locality ablation the ISSUE acceptance demands (locality-first beats
//! FIFO on a 2-rack cluster), live speculative execution recovering a
//! straggler inside a real MR job, and the invariant that scheduling only
//! moves virtual time, never answers.

use std::sync::Arc;

use psch::benchutil::locality_ablation_run;
use psch::cluster::{Cluster, NetworkModel};
use psch::config::Config;
use psch::coordinator::{Driver, PipelineInput};
use psch::data::gaussian_blobs;
use psch::mapreduce::{self, names, FnMapper, JobBuilder, TaskContext};
use psch::runtime::KernelRuntime;
use psch::scheduler::{Policy, SpeculationConfig, TrackerConfig};

#[test]
fn locality_first_beats_fifo_on_a_two_rack_cluster() {
    // The exact experiment benches/ablation_loadbalance.rs reports (A2):
    // the phase-1 similarity job on 4 slaves / 2 racks.
    let (local, _) = locality_ablation_run(Policy::default());
    let (fifo, _) = locality_ablation_run(Policy::Fifo);
    // Every paired map split declared hosts, so every task is tallied.
    assert_eq!(local.placed(), 7, "{local:?}");
    assert_eq!(fifo.placed(), 7, "{fifo:?}");
    assert!(
        local.data_local_pct() > fifo.data_local_pct(),
        "locality-first must raise the data-local map percentage: \
         {:.1}% vs {:.1}%",
        local.data_local_pct(),
        fifo.data_local_pct()
    );
    assert!(
        local.virtual_read_s < fifo.virtual_read_s,
        "locality-first must lower the virtual read time: {:.6}s vs {:.6}s",
        local.virtual_read_s,
        fifo.virtual_read_s
    );
}

#[test]
fn speculative_execution_recovers_a_straggler_in_a_live_job() {
    // 8 map tasks of 5 modeled seconds; slave 3 runs at 1/10 speed. With
    // speculation the JobTracker duplicates the straggler's tasks onto the
    // fast slaves and the job's virtual time collapses.
    let run = |speculation: bool| {
        let mut cluster = Cluster::with_model(4, 2, NetworkModel::default());
        cluster.set_slave_speed(3, 0.1);
        cluster.set_tracker_config(TrackerConfig {
            speculation: SpeculationConfig {
                enabled: speculation,
                ..Default::default()
            },
            ..Default::default()
        });
        let mapper = Arc::new(FnMapper(
            |_k: &[u8], _v: &[u8], ctx: &mut TaskContext| {
                ctx.incr(names::COMPUTE_US, 5_000_000);
                Ok(())
            },
        ));
        let splits: Vec<Vec<(Vec<u8>, Vec<u8>)>> =
            (0..8).map(|i| vec![(vec![i as u8], vec![])]).collect();
        let job = JobBuilder::new("straggle", splits, mapper).build();
        mapreduce::run(&cluster, &job).unwrap()
    };
    let with = run(true);
    let without = run(false);
    assert!(with.counters.get(names::SPECULATIVE_ATTEMPTS) >= 1, "no duplicates launched");
    assert!(with.counters.get(names::SPECULATIVE_WINS) >= 1, "no duplicate won");
    assert_eq!(without.counters.get(names::SPECULATIVE_ATTEMPTS), 0);
    assert!(
        with.stats.virtual_time_s < without.stats.virtual_time_s * 0.8,
        "speculation should cut the straggled makespan: {:.1}s vs {:.1}s",
        with.stats.virtual_time_s,
        without.stats.virtual_time_s
    );
    assert!(with.counters.get(names::HEARTBEATS) > 0);
}

#[test]
fn scheduling_policy_never_changes_the_answer() {
    // Racks + policy move virtual time and locality counters only; the
    // clustering itself must be bit-identical.
    let ps = gaussian_blobs(250, 3, 4, 0.3, 10.0, 5);
    let input = PipelineInput::Points { points: ps.points.clone() };
    let run = |scheduler: &str| {
        let mut cfg = Config::default();
        cfg.cluster.slaves = 4;
        cfg.cluster.racks = 2;
        cfg.set("cluster.scheduler", scheduler).unwrap();
        cfg.algo.k = 3;
        cfg.algo.sigma = 1.5.into();
        let d = Driver::new(cfg, Arc::new(KernelRuntime::native()));
        d.run(&input).unwrap()
    };
    let locality = run("locality");
    let fifo = run("fifo");
    assert_eq!(locality.labels, fifo.labels);
    assert_eq!(locality.eigenvalues, fifo.eigenvalues);
    assert!(locality.total_virtual_s > 0.0 && fifo.total_virtual_s > 0.0);
}
