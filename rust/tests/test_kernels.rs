//! Property suite for the blocked kernel layer (DESIGN.md §2.14): every
//! blocked kernel bit-identical to its scalar reference across all tail
//! shapes, partition invariance for the row-blocked spmv, tie behavior of
//! the assignment tile, and an end-to-end guard that a quick-config run
//! produces byte-identical labels/artifacts in both kernel modes.

use std::sync::Arc;

use psch::config::Config;
use psch::coordinator::{Driver, PipelineInput};
use psch::data::gaussian_blobs;
use psch::knn::{Neighbor, TopTHeap};
use psch::linalg::kernels::{
    self, set_kernel_mode, KernelMode, ScanSink, DIM_CHUNK, KERNEL_BLOCK, TILE_LANES,
};
use psch::linalg::CsrMatrix;
use psch::runtime::KernelRuntime;
use psch::serving::ModelArtifact;

fn pseudo(seed: u64, len: usize) -> Vec<f64> {
    let mut s = seed;
    (0..len)
        .map(|_| {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (s >> 11) as f64 / (1u64 << 53) as f64 * 2.0 - 1.0
        })
        .collect()
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|f| f.to_bits()).collect()
}

/// Recording sink with a fixed bound: the emitted `(id, Option<bits>)`
/// sequence is the kernel's complete observable behavior.
struct Rec {
    bound: f64,
    out: Vec<(u32, Option<u64>)>,
}

impl ScanSink for Rec {
    fn bound(&self) -> f64 {
        self.bound
    }

    fn emit(&mut self, id: u32, d2: Option<f64>) {
        self.out.push((id, d2.map(f64::to_bits)));
    }
}

#[test]
fn blocked_scan_matches_scalar_for_all_tail_shapes() {
    // Dimensions around every DIM_CHUNK boundary (plus d = 0) and candidate
    // counts covering all partial-tile sizes 0..2·TILE_LANES+1, under fixed
    // bounds from "nothing aborts" to "everything aborts".
    let dims = [0usize, 1, 3, DIM_CHUNK - 1, DIM_CHUNK, DIM_CHUNK + 1, 2 * DIM_CHUNK + 3];
    for &d in &dims {
        for n in 0..=2 * TILE_LANES + 1 {
            let points = pseudo(1000 + (d * 100 + n) as u64, n * d);
            let q = pseudo(7 + d as u64, d);
            let ids: Vec<u32> = (0..n as u32).collect();
            let excludes = [None, Some(0u32), Some(n as u32 / 2)];
            for bound in [f64::INFINITY, 0.0, 0.5, 2.0] {
                for &exclude in &excludes {
                    let mut a = Rec { bound, out: Vec::new() };
                    kernels::sq_dist_scan_ids_scalar(&q, &points, d, &ids, exclude, &mut a);
                    let mut b = Rec { bound, out: Vec::new() };
                    kernels::sq_dist_scan_ids_blocked(&q, &points, d, &ids, exclude, &mut b);
                    assert_eq!(a.out, b.out, "ids d={d} n={n} bound={bound} ex={exclude:?}");
                    let mut c = Rec { bound, out: Vec::new() };
                    kernels::sq_dist_scan_range_blocked(
                        &q, &points, d, 0, n as u32, exclude, &mut c,
                    );
                    assert_eq!(a.out, c.out, "range d={d} n={n} bound={bound} ex={exclude:?}");
                }
            }
        }
    }
}

/// Sink feeding a top-t heap, like the knn query paths: the bound shrinks
/// as survivors arrive, the sampling schedule differs between scalar
/// (per candidate) and blocked (per tile) — the heap contents must not.
struct HSink<'a> {
    heap: &'a mut TopTHeap,
}

impl ScanSink for HSink<'_> {
    fn bound(&self) -> f64 {
        self.heap.bound()
    }

    fn emit(&mut self, id: u32, d2: Option<f64>) {
        if let Some(d2) = d2 {
            self.heap.push(Neighbor { d2, idx: id });
        }
    }
}

#[test]
fn shrinking_bound_scan_leaves_heap_contents_bit_identical() {
    let (n, d) = (200usize, 5usize);
    let points = pseudo(42, n * d);
    let ids: Vec<u32> = (0..n as u32).collect();
    for qi in [0usize, 7, 123] {
        let q = points[qi * d..(qi + 1) * d].to_vec();
        for t in [1usize, 4, 17] {
            let mut hs = TopTHeap::new(t);
            let mut sink = HSink { heap: &mut hs };
            kernels::sq_dist_scan_ids_scalar(&q, &points, d, &ids, Some(qi as u32), &mut sink);
            let mut hb = TopTHeap::new(t);
            let mut sink = HSink { heap: &mut hb };
            kernels::sq_dist_scan_ids_blocked(&q, &points, d, &ids, Some(qi as u32), &mut sink);
            let a: Vec<(u32, u64)> =
                hs.into_sorted().iter().map(|nb| (nb.idx, nb.d2.to_bits())).collect();
            let b: Vec<(u32, u64)> =
                hb.into_sorted().iter().map(|nb| (nb.idx, nb.d2.to_bits())).collect();
            assert_eq!(a, b, "qi={qi} t={t}");
        }
    }
}

/// Ragged CSR fixture: row i holds `(i*7+2) % 12` entries (every nnz count
/// 0..=11, so every lane/tail combination of the row block appears), with
/// distinct columns `(i + 3j) mod n` (n = 37 is prime).
fn ragged_csr(n: usize) -> CsrMatrix {
    let rows: Vec<Vec<(u32, f64)>> = (0..n)
        .map(|i| {
            let nnz = (i * 7 + 2) % 12;
            let vals = pseudo(900 + i as u64, nnz);
            (0..nnz)
                .map(|j| (((i + 3 * j) % n) as u32, vals[j]))
                .collect()
        })
        .collect();
    CsrMatrix::from_rows(n, rows)
}

#[test]
fn blocked_spmv_is_bit_identical_and_partition_invariant() {
    let n = 37usize;
    assert!(n > 2 * KERNEL_BLOCK, "fixture must span several row blocks");
    let a = ragged_csr(n);
    let x = pseudo(5150, n);
    let mut ys = vec![0.0f64; n];
    kernels::spmv_rows_scalar(a.view(), &x, 0, n, &mut ys);
    let mut yb = vec![0.0f64; n];
    kernels::spmv_rows_blocked(a.view(), &x, 0, n, &mut yb);
    assert_eq!(bits(&ys), bits(&yb), "blocked == scalar bitwise");
    assert_eq!(bits(&a.spmv(&x)), bits(&ys), "dispatching spmv agrees");
    assert_eq!(bits(&a.spmv_rows(&x, 0, n)), bits(&ys), "spmv_rows agrees");
    // Partition invariance: any [lo, hi) split reassembles to the full
    // scan, and a partial blocked call equals the full result's slice.
    for &split in &[1usize, 3, KERNEL_BLOCK, KERNEL_BLOCK + 1, 8, 19, n - 1] {
        let mut pieced = a.spmv_rows(&x, 0, split);
        pieced.extend(a.spmv_rows(&x, split, n));
        assert_eq!(bits(&pieced), bits(&ys), "split={split}");
        let mut part = vec![0.0f64; n - split];
        kernels::spmv_rows_blocked(a.view(), &x, split, n, &mut part);
        assert_eq!(bits(&part), bits(&ys[split..]), "offset start split={split}");
    }
}

#[test]
fn blocked_block_spmv_matches_its_scalar_reference() {
    let n = 37usize;
    let m = 3usize;
    let a = ragged_csr(n);
    let x = pseudo(6060, n * m);
    let mut ys = vec![0.0f64; n * m];
    kernels::spmv_block_rows_scalar(a.view(), &x, m, 0, n, &mut ys);
    let mut yb = vec![0.0f64; n * m];
    kernels::spmv_block_rows_blocked(a.view(), &x, m, 0, n, &mut yb);
    assert_eq!(bits(&ys), bits(&yb), "block spmv blocked == scalar bitwise");
    assert_eq!(bits(&a.spmv_block_rows(&x, m, 0, n)), bits(&ys), "method dispatch agrees");
}

#[test]
fn assign_tile_matches_scalar_across_all_center_counts() {
    for k in 1..=2 * TILE_LANES + 2 {
        for &d in &[0usize, 1, 7, 16] {
            let centers = pseudo(30 + (k * 100 + d) as u64, k * d);
            let norms = kernels::center_norms(&centers, k, d);
            for pi in 0..6u64 {
                let p = pseudo(777 ^ (pi * 7919), d);
                let s = kernels::assign_point_scalar(&p, &centers, &norms, k, d);
                let b = kernels::assign_point_blocked(&p, &centers, &norms, k, d);
                assert_eq!(s, b, "k={k} d={d} pi={pi}");
            }
        }
    }
}

#[test]
fn assign_tile_ties_resolve_to_the_lowest_center_index() {
    // Every center identical: every distance ties, so both forms must pick
    // center 0 — the first strict minimum, like the original min_by scan.
    let d = 4usize;
    let one = pseudo(99, d);
    for k in [1usize, 3, TILE_LANES, TILE_LANES + 5] {
        let centers: Vec<f64> = (0..k).flat_map(|_| one.iter().copied()).collect();
        let norms = kernels::center_norms(&centers, k, d);
        let p = pseudo(123, d);
        assert_eq!(kernels::assign_point_scalar(&p, &centers, &norms, k, d), 0);
        assert_eq!(kernels::assign_point_blocked(&p, &centers, &norms, k, d), 0);
    }
}

#[test]
fn f32_assign_tile_matches_scalar() {
    for k in 1..=TILE_LANES + 3 {
        let d = 16usize;
        let centers: Vec<f32> =
            pseudo(400 + k as u64, k * d).iter().map(|&v| v as f32).collect();
        let norms = kernels::center_norms_f32(&centers, k, d);
        for pi in 0..6u64 {
            let p: Vec<f32> = pseudo(500 ^ (pi * 31), d).iter().map(|&v| v as f32).collect();
            assert_eq!(
                kernels::assign_point_scalar_f32(&p, &centers, &norms, k, d),
                kernels::assign_point_blocked_f32(&p, &centers, &norms, k, d),
                "k={k} pi={pi}"
            );
        }
    }
}

#[test]
fn kmeans_assign_routes_through_the_kernel_unchanged() {
    let ps = gaussian_blobs(240, 4, 6, 0.4, 8.0, 3);
    let centers = psch::kmeans::init_centers(&ps.points, 4, psch::kmeans::Init::PlusPlus, 11);
    let got = psch::kmeans::assign(&ps.points, &centers);
    // Inline reference: the pre-kernel min_by scan (first minimum wins).
    let want: Vec<usize> = ps
        .points
        .iter()
        .map(|p| {
            centers
                .iter()
                .enumerate()
                .map(|(c, ctr)| (c, psch::linalg::vector::sq_dist(p, ctr)))
                .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .map(|(c, _)| c)
                .unwrap()
        })
        .collect();
    assert_eq!(got, want);
}

/// Restores the default kernel mode even if the test panics mid-way.
struct RestoreMode;

impl Drop for RestoreMode {
    fn drop(&mut self) {
        set_kernel_mode(KernelMode::Blocked);
    }
}

#[test]
fn quick_run_is_byte_identical_across_kernel_modes() {
    let _guard = RestoreMode;
    let cfg = Config::load("configs/quick.toml").unwrap();
    let ps = gaussian_blobs(150, cfg.algo.k, 8, 0.4, 8.0, cfg.algo.seed);
    let mut outputs: Vec<(Vec<usize>, String)> = Vec::new();
    for mode in [KernelMode::Scalar, KernelMode::Blocked] {
        set_kernel_mode(mode);
        let driver = Driver::new(cfg.clone(), Arc::new(KernelRuntime::native()));
        let result = driver
            .run(&PipelineInput::Points { points: ps.points.clone() })
            .unwrap();
        let model =
            ModelArtifact::from_run(driver.config(), &ps.points, &result).unwrap();
        outputs.push((result.labels, model.to_json()));
    }
    assert_eq!(outputs[0].0, outputs[1].0, "labels must match across modes");
    assert_eq!(outputs[0].1, outputs[1].1, "model artifact must be byte-identical");
}
