//! Simulated Hadoop cluster: slaves, execution slots, cost model.
//!
//! One [`Cluster`] = 1 virtual master + `m` virtual slaves with
//! `slots_per_slave` map/reduce slots each (the paper's setup: "default each
//! machine starts two Map tasks", §4.4). Task closures run on a real thread
//! pool (correctness, concurrency bugs surface for real) while their costs
//! feed the [`vclock`] virtual-time model (speedup numbers, hardware
//! independent).

pub mod faults;
pub mod network;
pub mod vclock;

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::error::{Error, Result};
use crate::mapreduce::ShuffleConfig;
use crate::scheduler::{JobTracker, RackTopology, SchedulePlan, TaskSpec, TrackerConfig};

pub use faults::{FaultConfig, FaultDomain, NodeDeath, NodeState};
pub use network::NetworkModel;
pub use vclock::{job_time, schedule, schedule_speculative, PhaseTime, TaskCost};

/// One simulated slave machine.
#[derive(Debug, Clone)]
pub struct SlaveNode {
    /// Slave id, 0-based.
    pub id: usize,
    /// Relative speed (1.0 = reference machine; <1 = straggler).
    pub speed: f64,
}

/// Outcome of one [`Cluster::execute`] batch: per-task results (order
/// preserved, `None` where the task failed) plus the failures themselves.
#[derive(Debug)]
pub struct BatchOutcome<T> {
    /// `results[i]` is task `i`'s `(output, measured seconds)` — `None`
    /// exactly when `failures` holds an entry for `i`.
    pub results: Vec<Option<(T, f64)>>,
    /// `(task index, error)` of every failed task, ascending by index.
    pub failures: Vec<(usize, Error)>,
}

impl<T> BatchOutcome<T> {
    /// All-or-nothing view: the full result vector, or the first failure.
    /// Callers that can re-plan should consume the fields directly instead.
    pub fn into_result(self) -> Result<Vec<(T, f64)>> {
        if let Some((idx, e)) = self.failures.into_iter().next() {
            return Err(Error::MapReduce(format!("task {idx} failed: {e}")));
        }
        let mut out = Vec::with_capacity(self.results.len());
        for (i, slot) in self.results.into_iter().enumerate() {
            out.push(slot.ok_or_else(|| {
                Error::MapReduce(format!("task {i} produced no result"))
            })?);
        }
        Ok(out)
    }
}

/// The simulated cluster.
#[derive(Clone)]
pub struct Cluster {
    slaves: Vec<SlaveNode>,
    slots_per_slave: usize,
    model: NetworkModel,
    /// Rack topology shared by the JobTracker and (via [`crate::coordinator`])
    /// the DFS replica placement.
    topology: RackTopology,
    /// JobTracker knobs (heartbeat interval, policy, speculation).
    tracker: TrackerConfig,
    /// Cluster-wide shuffle knobs (sort buffer, merge factor, fetch
    /// parallelism); jobs may override per-job.
    shuffle: ShuffleConfig,
    /// The shared failure domain: slave lifecycle, seeded fault injection,
    /// blacklist counts, death listeners. `Arc`, so every clone of the
    /// cluster (driver, planner, benches) observes the same failures.
    faults: Arc<FaultDomain>,
    /// The shared trace sink. Disabled (and free) by default; `Arc`, so
    /// enabling it is visible to every existing clone of the cluster and
    /// jobs record spans no matter which clone ran them.
    trace: Arc<crate::trace::TraceSink>,
    /// Physical worker threads used to execute tasks (bounded by host cores;
    /// virtual time is what scales with `m`, not host parallelism).
    threads: usize,
}

impl Cluster {
    /// A cluster of `m` homogeneous slaves, 2 slots each (paper §4.4).
    pub fn new(m: usize) -> Self {
        Self::with_model(m, 2, NetworkModel::default())
    }

    /// Full control over slot count and cost model.
    pub fn with_model(m: usize, slots_per_slave: usize, model: NetworkModel) -> Self {
        assert!(m > 0, "need at least one slave");
        let threads = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
            .min(m * slots_per_slave)
            .max(1);
        Self {
            slaves: (0..m).map(|id| SlaveNode { id, speed: 1.0 }).collect(),
            slots_per_slave: slots_per_slave.max(1),
            model,
            topology: RackTopology::single(m),
            tracker: TrackerConfig::default(),
            shuffle: ShuffleConfig::default(),
            faults: Arc::new(FaultDomain::new(m, FaultConfig::default())),
            trace: Arc::new(crate::trace::TraceSink::default()),
            threads,
        }
    }

    /// Install the failure-domain configuration, resetting all fault state
    /// (lifecycles, blacklist counts, the heartbeat clock). Death
    /// listeners registered on the previous domain (the DFS re-replication
    /// wiring) carry over. Call before the cluster is cloned/shared —
    /// clones made *earlier* keep observing the old domain.
    pub fn set_fault_config(&mut self, cfg: FaultConfig) {
        let fresh = FaultDomain::new(self.slaves.len(), cfg);
        fresh.adopt_listeners_from(&self.faults);
        self.faults = Arc::new(fresh);
    }

    /// The shared failure domain.
    pub fn faults(&self) -> &Arc<FaultDomain> {
        &self.faults
    }

    /// The shared trace sink (disabled unless [`crate::trace::TraceSink::enable`]
    /// was called; shared across clones like the failure domain).
    pub fn trace(&self) -> &Arc<crate::trace::TraceSink> {
        &self.trace
    }

    /// Turn the shared trace sink on, sized for this cluster — the
    /// one-call form of `trace().enable(slaves, slots_per_slave)` the CLI
    /// uses when any of `--trace-out`/`--report-json`/`--metrics-out`
    /// asks for span data.
    pub fn enable_tracing(&self) {
        self.trace.enable(self.slaves.len(), self.slots_per_slave);
    }

    /// Mark one slave as a straggler with the given relative speed.
    pub fn set_slave_speed(&mut self, slave: usize, speed: f64) {
        assert!(speed > 0.0);
        self.slaves[slave].speed = speed;
    }

    /// Install a rack topology (must cover exactly this cluster's slaves).
    pub fn set_topology(&mut self, topology: RackTopology) {
        assert_eq!(
            topology.num_nodes(),
            self.slaves.len(),
            "topology must cover every slave"
        );
        self.topology = topology;
    }

    /// The rack topology.
    pub fn topology(&self) -> &RackTopology {
        &self.topology
    }

    /// Replace the JobTracker knobs (policy, heartbeat, speculation).
    pub fn set_tracker_config(&mut self, cfg: TrackerConfig) {
        self.tracker = cfg;
    }

    /// The JobTracker knobs.
    pub fn tracker_config(&self) -> &TrackerConfig {
        &self.tracker
    }

    /// Replace the cluster-wide shuffle knobs.
    pub fn set_shuffle_config(&mut self, cfg: ShuffleConfig) {
        self.shuffle = cfg;
    }

    /// The cluster-wide shuffle knobs.
    pub fn shuffle_config(&self) -> &ShuffleConfig {
        &self.shuffle
    }

    /// Number of slaves m.
    pub fn num_slaves(&self) -> usize {
        self.slaves.len()
    }

    /// Execution slots per slave.
    pub fn slots_per_slave(&self) -> usize {
        self.slots_per_slave
    }

    /// Total slots (m × slots_per_slave).
    pub fn total_slots(&self) -> usize {
        self.slaves.len() * self.slots_per_slave
    }

    /// The cost model.
    pub fn model(&self) -> &NetworkModel {
        &self.model
    }

    /// Per-slot speed vector for the virtual scheduler.
    pub fn slot_speeds(&self) -> Vec<f64> {
        let mut v = Vec::with_capacity(self.total_slots());
        for s in &self.slaves {
            for _ in 0..self.slots_per_slave {
                v.push(s.speed);
            }
        }
        v
    }

    /// Execute tasks on the worker pool, preserving order.
    ///
    /// Every task runs to completion even when siblings fail: the outcome
    /// carries each finished task's output and measured CPU seconds
    /// alongside the failures, so the engine can re-plan just the failed
    /// tasks while reusing the completed results (Hadoop never throws away
    /// a finished attempt because another task errored).
    pub fn execute<T, F>(&self, tasks: Vec<F>) -> BatchOutcome<T>
    where
        T: Send,
        F: FnOnce() -> Result<T> + Send,
    {
        let n = tasks.len();
        if n == 0 {
            return BatchOutcome { results: Vec::new(), failures: Vec::new() };
        }
        let queue: Mutex<VecDeque<(usize, F)>> =
            Mutex::new(tasks.into_iter().enumerate().collect());
        let results: Mutex<Vec<Option<(T, f64)>>> =
            Mutex::new((0..n).map(|_| None).collect());
        let failures: Mutex<Vec<(usize, Error)>> = Mutex::new(Vec::new());
        let workers = self.threads.min(n);

        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let item = queue.lock().unwrap().pop_front();
                    let Some((idx, task)) = item else { break };
                    let start = Instant::now();
                    match task() {
                        Ok(out) => {
                            let elapsed = start.elapsed().as_secs_f64();
                            results.lock().unwrap()[idx] = Some((out, elapsed));
                        }
                        Err(e) => failures.lock().unwrap().push((idx, e)),
                    }
                });
            }
        });

        let mut failures = failures.into_inner().unwrap();
        failures.sort_by_key(|(idx, _)| *idx);
        BatchOutcome { results: results.into_inner().unwrap(), failures }
    }

    /// Run one phase's tasks through the JobTracker (heartbeats, locality
    /// tiers, delay scheduling, speculation, the failure domain) and return
    /// the virtual plan.
    pub fn plan_phase(&self, tasks: &[TaskSpec]) -> SchedulePlan {
        let speeds: Vec<f64> = self.slaves.iter().map(|s| s.speed).collect();
        JobTracker::new(
            &self.topology,
            &speeds,
            self.slots_per_slave,
            &self.model,
            &self.tracker,
        )
        .with_faults(&self.faults)
        .plan(tasks)
    }

    /// Virtual wall-clock of a job from its scheduled phase plans: job
    /// overhead + map makespan (+ aggregate-modelled shuffle + reduce
    /// makespan). Reduce jobs whose fetches were planned per segment use
    /// [`Self::planned_job_time_with_fetch`] instead.
    pub fn planned_job_time(
        &self,
        map: &SchedulePlan,
        reduce: Option<&SchedulePlan>,
        shuffle_bytes: u64,
    ) -> f64 {
        let m = self.num_slaves();
        let mut t = self.model.job_overhead(m) + map.makespan_s;
        if let Some(r) = reduce {
            t += self.model.shuffle_time(shuffle_bytes, m) + r.makespan_s;
        }
        t
    }

    /// Virtual wall-clock of a reduce job whose shuffle was charged per
    /// fetched segment at locality tiers: job overhead + map makespan +
    /// the slowest reducer's fetch phase + reduce makespan.
    pub fn planned_job_time_with_fetch(
        &self,
        map: &SchedulePlan,
        reduce: &SchedulePlan,
        fetch_s: f64,
    ) -> f64 {
        self.model.job_overhead(self.num_slaves())
            + map.makespan_s
            + fetch_s
            + reduce.makespan_s
    }

    /// Virtual wall-clock of a job given measured task costs (convenience
    /// wrapper over [`vclock::job_time`] with this cluster's m/slots/model).
    pub fn virtual_job_time(
        &self,
        map_tasks: &[TaskCost],
        reduce_tasks: &[TaskCost],
        shuffle_bytes: u64,
    ) -> f64 {
        vclock::job_time(
            map_tasks,
            reduce_tasks,
            shuffle_bytes,
            self.num_slaves(),
            self.slots_per_slave,
            &self.model,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn execute_preserves_order_and_results() {
        let c = Cluster::new(4);
        let tasks: Vec<_> = (0..32)
            .map(|i| move || -> Result<usize> { Ok(i * i) })
            .collect();
        let results = c.execute(tasks).into_result().unwrap();
        assert_eq!(results.len(), 32);
        for (i, (v, secs)) in results.iter().enumerate() {
            assert_eq!(*v, i * i);
            assert!(*secs >= 0.0);
        }
    }

    #[test]
    fn execute_keeps_completed_results_alongside_the_error() {
        // The re-planning fix: one task failing must not discard its
        // siblings' finished outputs.
        let c = Cluster::new(2);
        let tasks: Vec<Box<dyn FnOnce() -> Result<u32> + Send>> = vec![
            Box::new(|| Ok(1)),
            Box::new(|| Err(Error::MapReduce("boom".into()))),
            Box::new(|| Ok(3)),
        ];
        let outcome = c.execute(tasks);
        assert_eq!(outcome.failures.len(), 1);
        assert_eq!(outcome.failures[0].0, 1);
        assert!(outcome.failures[0].1.to_string().contains("boom"));
        assert_eq!(outcome.results[0].as_ref().map(|(v, _)| *v), Some(1));
        assert!(outcome.results[1].is_none());
        assert_eq!(outcome.results[2].as_ref().map(|(v, _)| *v), Some(3));
        // And the all-or-nothing view still surfaces the error.
        let tasks: Vec<Box<dyn FnOnce() -> Result<u32> + Send>> =
            vec![Box::new(|| Err(Error::MapReduce("boom".into())))];
        assert!(c.execute(tasks).into_result().is_err());
    }

    #[test]
    fn empty_task_list() {
        let c = Cluster::new(1);
        let tasks: Vec<Box<dyn FnOnce() -> Result<()> + Send>> = vec![];
        assert!(c.execute(tasks).into_result().unwrap().is_empty());
    }

    #[test]
    fn set_fault_config_preserves_death_listeners() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let mut c = Cluster::new(2);
        let hits = Arc::new(AtomicUsize::new(0));
        let h = hits.clone();
        c.faults().on_death(move |_| {
            h.fetch_add(1, Ordering::SeqCst);
        });
        // Swapping in a new fault config must keep the wiring alive.
        c.set_fault_config(FaultConfig {
            node_deaths: vec![NodeDeath { slave: 1, at_heartbeat: 1 }],
            ..FaultConfig::default()
        });
        c.faults().tick_heartbeat();
        assert_eq!(hits.load(Ordering::SeqCst), 1, "listener survived the swap");
    }

    #[test]
    fn slot_speeds_reflect_stragglers() {
        let mut c = Cluster::with_model(3, 2, NetworkModel::default());
        c.set_slave_speed(1, 0.5);
        let speeds = c.slot_speeds();
        assert_eq!(speeds, vec![1.0, 1.0, 0.5, 0.5, 1.0, 1.0]);
    }

    #[test]
    fn paper_defaults() {
        let c = Cluster::new(10);
        assert_eq!(c.num_slaves(), 10);
        assert_eq!(c.slots_per_slave(), 2);
        assert_eq!(c.total_slots(), 20);
        assert_eq!(c.topology().num_racks(), 1);
    }

    #[test]
    fn shuffle_config_settable_and_readable() {
        let mut c = Cluster::new(2);
        assert_eq!(*c.shuffle_config(), ShuffleConfig::default());
        let cfg = ShuffleConfig {
            sort_buffer_kb: 64,
            merge_factor: 4,
            fetch_parallelism: 2,
        };
        c.set_shuffle_config(cfg);
        assert_eq!(*c.shuffle_config(), cfg);
    }

    #[test]
    fn fetch_charged_job_time_includes_all_terms() {
        let c = Cluster::new(3);
        let tasks: Vec<crate::scheduler::TaskSpec> = (0..4)
            .map(|_| crate::scheduler::TaskSpec {
                cost: TaskCost { compute_s: 1.0, input_bytes: 0, output_bytes: 0 },
                hosts: vec![],
            })
            .collect();
        let map = c.plan_phase(&tasks);
        let reduce = c.plan_phase(&tasks[..2]);
        let t = c.planned_job_time_with_fetch(&map, &reduce, 7.0);
        let floor = c.model().job_overhead(3) + map.makespan_s + 7.0;
        assert!(t >= floor - 1e-9, "{t} < {floor}");
        assert!(t >= c.planned_job_time(&map, None, 0), "fetch time adds on");
    }

    #[test]
    fn plan_phase_routes_through_the_jobtracker() {
        let mut c = Cluster::with_model(4, 2, NetworkModel::default());
        c.set_topology(crate::scheduler::RackTopology::uniform(4, 2));
        let tasks: Vec<crate::scheduler::TaskSpec> = (0..6)
            .map(|i| crate::scheduler::TaskSpec {
                cost: TaskCost {
                    compute_s: 1.0,
                    input_bytes: 1 << 20,
                    output_bytes: 0,
                },
                hosts: vec![i % 4],
            })
            .collect();
        let plan = c.plan_phase(&tasks);
        assert_eq!(plan.attempts.iter().filter(|a| a.won).count(), 6);
        assert_eq!(plan.placed(), 6);
        assert!(plan.makespan_s > 0.0);
        let t = c.planned_job_time(&plan, None, 0);
        assert!(t >= plan.makespan_s);
    }
}
