//! The cluster-wide failure domain: slave lifecycle, seeded fault
//! injection, blacklisting, and death notification.
//!
//! Hadoop's fault tolerance is a *cluster* property, not a per-job one: the
//! JobTracker observes TaskTracker failures through missed/failed
//! heartbeats, re-plans failed attempts on other nodes, blacklists
//! trackers that keep failing, and the NameNode re-replicates the blocks a
//! dead DataNode held. One [`FaultDomain`] models all of that state,
//! shared (via `Arc`) by every clone of a [`super::Cluster`]:
//!
//! - every slave has a [`NodeState`] lifecycle `Alive → Blacklisted` (too
//!   many failed attempts) or `Alive → Dead` (scheduled node death);
//! - attempt failures are sampled from a **seeded** generator
//!   ([`FaultConfig::task_fail_prob`]), so chaos runs are reproducible
//!   bit-for-bit from the config;
//! - scheduled deaths fire on the cluster-wide heartbeat clock
//!   ([`FaultConfig::node_deaths`], counted cumulatively across every job
//!   the cluster runs), and registered listeners — the DFS wires
//!   `kill_datanode` here — are notified so replicas re-replicate the
//!   moment the scheduler sees the node disappear.
//!
//! The domain only *decides* faults; the [`crate::scheduler::JobTracker`]
//! acts on them (re-planning, blacklist enforcement) and the
//! [`crate::mapreduce::engine`] recovers lost map outputs. Nothing here
//! touches task *results*: real task execution is deterministic, which is
//! exactly why a faulty run must produce byte-identical output to a clean
//! one.

use std::sync::{Arc, Mutex};

use crate::util::rng::SplitMix64;

/// A scheduled node death: `slave` drops dead when the cluster processes
/// its `at_heartbeat`-th heartbeat (cumulative across jobs, 1-based).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NodeDeath {
    /// Slave (and co-located datanode) id to kill.
    pub slave: usize,
    /// Cumulative heartbeat count at which the death fires.
    pub at_heartbeat: u64,
}

impl NodeDeath {
    /// Parse the CLI/config form `<slave>@<heartbeat>`, e.g. `"1@40"`.
    pub fn parse(text: &str) -> Option<Self> {
        let (s, h) = text.trim().split_once('@')?;
        Some(Self {
            slave: s.trim().parse().ok()?,
            at_heartbeat: h.trim().parse().ok()?,
        })
    }
}

/// The `[faults]` config section: every knob of the failure domain.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultConfig {
    /// Seed of the attempt-failure generator (chaos runs are reproducible).
    pub seed: u64,
    /// Probability that any single task attempt fails partway through.
    pub task_fail_prob: f64,
    /// Failed attempts per task before the job fails (Hadoop's
    /// `mapred.map.max.attempts`, default 4).
    pub max_attempts: usize,
    /// Failed attempts on one slave before it is blacklisted (Hadoop's
    /// `mapred.max.tracker.failures` in miniature).
    pub blacklist_after: usize,
    /// Scheduled node deaths on the cumulative heartbeat clock.
    pub node_deaths: Vec<NodeDeath>,
}

impl Default for FaultConfig {
    fn default() -> Self {
        Self {
            seed: 1,
            task_fail_prob: 0.0,
            max_attempts: 4,
            blacklist_after: 3,
            node_deaths: Vec::new(),
        }
    }
}

impl FaultConfig {
    /// Does this config inject any fault at all?
    pub fn is_active(&self) -> bool {
        self.task_fail_prob > 0.0 || !self.node_deaths.is_empty()
    }
}

/// Slave lifecycle state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeState {
    /// Heartbeating and schedulable.
    Alive,
    /// Dead: no heartbeats, no tasks, its map outputs and DFS replicas are
    /// gone.
    Dead,
    /// Still heartbeating, but the JobTracker assigns it no further tasks.
    Blacklisted,
}

/// Mutable failure-domain state (lock-protected inside [`FaultDomain`]).
#[derive(Debug)]
struct FaultState {
    states: Vec<NodeState>,
    /// Failed attempts per slave (feeds blacklisting).
    failures: Vec<usize>,
    /// Cumulative heartbeats processed across every job on this cluster.
    heartbeats: u64,
    /// Attempt-failure samples drawn so far (the RNG stream position).
    samples: u64,
}

/// Death listener: called with the dead slave's id. `Arc`, so listeners
/// can be shared onto a replacement domain without starving the old one.
type DeathListener = Arc<dyn Fn(usize) + Send + Sync>;

/// The shared failure domain of one cluster (see module docs).
pub struct FaultDomain {
    cfg: FaultConfig,
    state: Mutex<FaultState>,
    listeners: Mutex<Vec<DeathListener>>,
}

impl std::fmt::Debug for FaultDomain {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FaultDomain")
            .field("cfg", &self.cfg)
            .field("state", &self.state)
            .finish()
    }
}

impl FaultDomain {
    /// Fresh domain over `num_slaves` alive slaves.
    pub fn new(num_slaves: usize, cfg: FaultConfig) -> Self {
        Self {
            cfg,
            state: Mutex::new(FaultState {
                states: vec![NodeState::Alive; num_slaves],
                failures: vec![0; num_slaves],
                heartbeats: 0,
                samples: 0,
            }),
            listeners: Mutex::new(Vec::new()),
        }
    }

    /// The active fault configuration.
    pub fn config(&self) -> &FaultConfig {
        &self.cfg
    }

    /// Register a death listener (the DFS registers `kill_datanode`).
    pub fn on_death(&self, f: impl Fn(usize) + Send + Sync + 'static) {
        self.listeners.lock().unwrap().push(Arc::new(f));
    }

    /// Copy every listener registered on `other` onto this domain. Used by
    /// [`crate::cluster::Cluster::set_fault_config`] so the DFS death
    /// wiring survives a fault-configuration swap — the old domain keeps
    /// its listeners too, so earlier cluster clones stay fully wired.
    pub fn adopt_listeners_from(&self, other: &FaultDomain) {
        let mut mine = self.listeners.lock().unwrap();
        mine.extend(other.listeners.lock().unwrap().iter().cloned());
    }

    /// Advance the cluster-wide heartbeat clock by one processed heartbeat
    /// and fire any scheduled deaths that are now due. Returns the newly
    /// dead slaves (listeners have already been notified).
    pub fn tick_heartbeat(&self) -> Vec<usize> {
        let newly_dead = {
            let mut st = self.state.lock().unwrap();
            st.heartbeats += 1;
            let hb = st.heartbeats;
            let mut dead = Vec::new();
            for d in &self.cfg.node_deaths {
                if d.at_heartbeat <= hb
                    && d.slave < st.states.len()
                    && st.states[d.slave] != NodeState::Dead
                {
                    st.states[d.slave] = NodeState::Dead;
                    dead.push(d.slave);
                }
            }
            dead
        };
        // Listeners run outside the state lock: they reach into the DFS.
        if !newly_dead.is_empty() {
            let listeners = self.listeners.lock().unwrap();
            for &slave in &newly_dead {
                for l in listeners.iter() {
                    l.as_ref()(slave);
                }
            }
        }
        newly_dead
    }

    /// Kill a slave immediately (tests, ad-hoc chaos), notifying listeners.
    pub fn kill(&self, slave: usize) {
        {
            let mut st = self.state.lock().unwrap();
            if st.states[slave] == NodeState::Dead {
                return;
            }
            st.states[slave] = NodeState::Dead;
        }
        for l in self.listeners.lock().unwrap().iter() {
            l.as_ref()(slave);
        }
    }

    /// Current lifecycle state of a slave.
    pub fn node_state(&self, slave: usize) -> NodeState {
        self.state.lock().unwrap().states[slave]
    }

    /// May the JobTracker assign new attempts to this slave?
    pub fn assignable(&self, slave: usize) -> bool {
        self.node_state(slave) == NodeState::Alive
    }

    /// Is any slave still assignable?
    pub fn any_assignable(&self) -> bool {
        self.state
            .lock()
            .unwrap()
            .states
            .iter()
            .any(|&s| s == NodeState::Alive)
    }

    /// Per-slave "is dead" view (the engine's lost-map-output check).
    pub fn dead(&self) -> Vec<bool> {
        self.state
            .lock()
            .unwrap()
            .states
            .iter()
            .map(|&s| s == NodeState::Dead)
            .collect()
    }

    /// Cumulative heartbeats processed so far.
    pub fn heartbeats(&self) -> u64 {
        self.state.lock().unwrap().heartbeats
    }

    /// Reset the per-slave failure tallies (Hadoop's fault counts are
    /// per-job; ours reset at every phase plan). Dead and blacklisted
    /// lifecycles persist — once a slave is blacklisted, no later phase
    /// assigns it work.
    pub fn begin_phase(&self) {
        let mut st = self.state.lock().unwrap();
        for f in st.failures.iter_mut() {
            *f = 0;
        }
    }

    /// Sample whether the next task attempt fails. `Some(frac)` means the
    /// attempt dies after `frac` of its duration (frac in `[0.05, 0.95]`).
    ///
    /// The stream is a pure function of the seed and the number of samples
    /// drawn so far, and the scheduler draws in a deterministic order — so
    /// the whole chaos schedule replays identically run to run.
    pub fn sample_attempt_failure(&self) -> Option<f64> {
        if self.cfg.task_fail_prob <= 0.0 {
            return None;
        }
        let mut st = self.state.lock().unwrap();
        st.samples += 1;
        let mut rng =
            SplitMix64::new(self.cfg.seed ^ st.samples.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let roll = (rng.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        if roll >= self.cfg.task_fail_prob {
            return None;
        }
        let frac = (rng.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        Some(0.05 + 0.9 * frac)
    }

    /// Record one failed attempt on `slave`; returns `true` when this
    /// failure just tipped the slave into the blacklist
    /// ([`FaultConfig::blacklist_after`] failures within one phase — see
    /// [`Self::begin_phase`]).
    pub fn record_failure(&self, slave: usize) -> bool {
        let mut st = self.state.lock().unwrap();
        st.failures[slave] += 1;
        if st.states[slave] == NodeState::Alive && st.failures[slave] >= self.cfg.blacklist_after
        {
            st.states[slave] = NodeState::Blacklisted;
            return true;
        }
        false
    }

    /// Failed attempts recorded against a slave this phase.
    pub fn failure_count(&self, slave: usize) -> usize {
        self.state.lock().unwrap().failures[slave]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_node_death() {
        assert_eq!(
            NodeDeath::parse("1@40"),
            Some(NodeDeath { slave: 1, at_heartbeat: 40 })
        );
        assert_eq!(
            NodeDeath::parse(" 3 @ 7 "),
            Some(NodeDeath { slave: 3, at_heartbeat: 7 })
        );
        assert!(NodeDeath::parse("3").is_none());
        assert!(NodeDeath::parse("a@b").is_none());
    }

    #[test]
    fn default_config_is_inert() {
        let cfg = FaultConfig::default();
        assert!(!cfg.is_active());
        let d = FaultDomain::new(3, cfg);
        assert!(d.sample_attempt_failure().is_none());
        assert!(d.tick_heartbeat().is_empty());
        assert!((0..3).all(|s| d.assignable(s)));
    }

    #[test]
    fn scheduled_death_fires_once_on_the_cumulative_clock() {
        let cfg = FaultConfig {
            node_deaths: vec![NodeDeath { slave: 1, at_heartbeat: 3 }],
            ..FaultConfig::default()
        };
        let d = FaultDomain::new(2, cfg);
        assert!(d.tick_heartbeat().is_empty());
        assert!(d.tick_heartbeat().is_empty());
        assert_eq!(d.tick_heartbeat(), vec![1]);
        assert_eq!(d.node_state(1), NodeState::Dead);
        assert!(d.tick_heartbeat().is_empty(), "a node dies only once");
        assert_eq!(d.heartbeats(), 4);
        assert_eq!(d.dead(), vec![false, true]);
    }

    #[test]
    fn death_listeners_are_notified() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;
        let cfg = FaultConfig {
            node_deaths: vec![NodeDeath { slave: 0, at_heartbeat: 1 }],
            ..FaultConfig::default()
        };
        let d = FaultDomain::new(2, cfg);
        let hits = Arc::new(AtomicUsize::new(usize::MAX));
        let h = hits.clone();
        d.on_death(move |slave| h.store(slave, Ordering::SeqCst));
        d.tick_heartbeat();
        assert_eq!(hits.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn failure_sampling_is_deterministic_and_roughly_calibrated() {
        let cfg = FaultConfig {
            task_fail_prob: 0.25,
            seed: 7,
            ..FaultConfig::default()
        };
        let a = FaultDomain::new(2, cfg.clone());
        let b = FaultDomain::new(2, cfg);
        let sa: Vec<Option<u64>> = (0..2000)
            .map(|_| a.sample_attempt_failure().map(|f| (f * 1e9) as u64))
            .collect();
        let sb: Vec<Option<u64>> = (0..2000)
            .map(|_| b.sample_attempt_failure().map(|f| (f * 1e9) as u64))
            .collect();
        assert_eq!(sa, sb, "same seed, same chaos schedule");
        let fails = sa.iter().filter(|s| s.is_some()).count();
        assert!((300..700).contains(&fails), "~25% of 2000: {fails}");
        for f in sa.into_iter().flatten() {
            let frac = f as f64 / 1e9;
            assert!((0.05..=0.95).contains(&frac), "{frac}");
        }
    }

    #[test]
    fn blacklist_after_enough_failures() {
        let cfg = FaultConfig { blacklist_after: 2, ..FaultConfig::default() };
        let d = FaultDomain::new(2, cfg);
        assert!(!d.record_failure(0));
        assert!(d.assignable(0));
        assert!(d.record_failure(0), "second failure blacklists");
        assert_eq!(d.node_state(0), NodeState::Blacklisted);
        assert!(!d.assignable(0));
        assert!(!d.record_failure(0), "already blacklisted");
        assert!(d.any_assignable());
        assert_eq!(d.failure_count(0), 3);
    }

    #[test]
    fn phase_boundaries_reset_counts_but_not_the_blacklist() {
        let cfg = FaultConfig { blacklist_after: 2, ..FaultConfig::default() };
        let d = FaultDomain::new(2, cfg);
        assert!(!d.record_failure(1));
        d.begin_phase();
        assert_eq!(d.failure_count(1), 0, "per-phase counts reset");
        assert!(!d.record_failure(1), "one failure this phase: still fine");
        assert!(d.record_failure(1));
        d.begin_phase();
        assert_eq!(
            d.node_state(1),
            NodeState::Blacklisted,
            "lifecycle persists across phases"
        );
    }
}
