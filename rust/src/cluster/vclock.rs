//! Virtual-time job accounting: deterministic makespan of an MR job.
//!
//! Each executed task records its *measured* CPU time plus its input/output
//! byte counts; this module replays those costs through the
//! [`NetworkModel`] on an m-slave cluster using LPT list scheduling (what
//! Hadoop's greedy slot assignment approximates), yielding the virtual
//! wall-clock the paper's Table 5-1 reports — deterministic and independent
//! of how many physical cores this simulator happens to run on.

use super::network::NetworkModel;

/// Cost profile of one executed task.
#[derive(Debug, Clone, Copy, Default)]
pub struct TaskCost {
    /// Measured compute seconds (scaled to the reference machine).
    pub compute_s: f64,
    /// Bytes read by the task.
    pub input_bytes: u64,
    /// Bytes emitted by the task.
    pub output_bytes: u64,
}

/// Summary of one job phase's virtual execution.
#[derive(Debug, Clone, Copy, Default)]
pub struct PhaseTime {
    /// Virtual seconds from first dispatch to last task completion.
    pub makespan_s: f64,
    /// Sum of per-task virtual seconds (the serial cost).
    pub total_work_s: f64,
}

/// LPT (longest processing time first) list scheduling over `slots` slots.
///
/// Per-task virtual time = dispatch + input read + compute. Returns the
/// makespan and total work. `speed` optionally scales each slot (straggler
/// simulation; `None` = homogeneous).
pub fn schedule(
    tasks: &[TaskCost],
    slots: usize,
    model: &NetworkModel,
    speed: Option<&[f64]>,
) -> PhaseTime {
    assert!(slots > 0, "need at least one slot");
    if tasks.is_empty() {
        return PhaseTime::default();
    }
    let mut durations: Vec<f64> = tasks
        .iter()
        .map(|t| {
            model.task_dispatch_s
                + model.read_time(t.input_bytes)
                + model.write_time(t.output_bytes)
                + t.compute_s * model.compute_scale
        })
        .collect();
    let total_work_s: f64 = durations.iter().sum();
    durations.sort_by(|a, b| b.partial_cmp(a).unwrap());

    let mut loads = vec![0.0f64; slots];
    for d in durations {
        // Hadoop's pull model: the next task goes to the slot that frees up
        // first — the scheduler does NOT know task durations or slot speeds
        // in advance, which is exactly why stragglers hurt (and why
        // speculative execution exists).
        let (best, _) = loads
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, &l)| (i, l))
            .unwrap();
        let rate = speed.map(|s| s[best % s.len()]).unwrap_or(1.0);
        loads[best] += d / rate;
    }
    let makespan_s = loads.iter().cloned().fold(0.0, f64::max);
    PhaseTime { makespan_s, total_work_s }
}

/// Virtual time of a complete MR job on `m` slaves with `slots_per_slave`.
///
/// `map_tasks` and `reduce_tasks` carry measured costs; `shuffle_bytes` is
/// the total intermediate data between them.
pub fn job_time(
    map_tasks: &[TaskCost],
    reduce_tasks: &[TaskCost],
    shuffle_bytes: u64,
    m: usize,
    slots_per_slave: usize,
    model: &NetworkModel,
) -> f64 {
    let slots = m.max(1) * slots_per_slave.max(1);
    let map = schedule(map_tasks, slots, model, None);
    let reduce = schedule(reduce_tasks, slots, model, None);
    model.job_overhead(m)
        + map.makespan_s
        + model.shuffle_time(shuffle_bytes, m)
        + reduce.makespan_s
}

/// Makespan with Hadoop-style speculative execution: when a slot is slower
/// than `straggler_factor`× the median, tasks on it are duplicated on the
/// fastest idle slot and the earlier finisher wins.
pub fn schedule_speculative(
    tasks: &[TaskCost],
    slots: usize,
    model: &NetworkModel,
    speed: &[f64],
    straggler_factor: f64,
) -> PhaseTime {
    let base = schedule(tasks, slots, model, Some(speed));
    // A slow slot reruns its share on the fastest slot; effective rate of
    // every task is at least (median speed / straggler_factor).
    let mut speeds: Vec<f64> = (0..slots).map(|i| speed[i % speed.len()]).collect();
    speeds.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = speeds[speeds.len() / 2];
    let floor = median / straggler_factor;
    let clamped: Vec<f64> = (0..slots)
        .map(|i| speed[i % speed.len()].max(floor))
        .collect();
    let spec = schedule(tasks, slots, model, Some(&clamped));
    PhaseTime {
        makespan_s: spec.makespan_s.min(base.makespan_s),
        total_work_s: base.total_work_s,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nm() -> NetworkModel {
        NetworkModel {
            job_setup_s: 0.0,
            task_dispatch_s: 0.0,
            disk_bw: 1e18,
            net_bw: 1e18,
            coord_per_machine_s: 0.0,
            shuffle_latency_s: 0.0,
            compute_scale: 1.0,
            ..NetworkModel::default()
        }
    }

    fn t(compute_s: f64) -> TaskCost {
        TaskCost { compute_s, input_bytes: 0, output_bytes: 0 }
    }

    #[test]
    fn empty_job_zero() {
        let p = schedule(&[], 4, &nm(), None);
        assert_eq!(p.makespan_s, 0.0);
        assert_eq!(p.total_work_s, 0.0);
    }

    #[test]
    fn single_slot_serializes() {
        let tasks = vec![t(1.0), t(2.0), t(3.0)];
        let p = schedule(&tasks, 1, &nm(), None);
        assert!((p.makespan_s - 6.0).abs() < 1e-9);
        assert!((p.total_work_s - 6.0).abs() < 1e-9);
    }

    #[test]
    fn perfect_parallelism_equal_tasks() {
        let tasks = vec![t(2.0); 8];
        let p = schedule(&tasks, 8, &nm(), None);
        assert!((p.makespan_s - 2.0).abs() < 1e-9);
        let p4 = schedule(&tasks, 4, &nm(), None);
        assert!((p4.makespan_s - 4.0).abs() < 1e-9);
    }

    #[test]
    fn lpt_balances_uneven_tasks() {
        // 3, 3, 2, 2, 2 on 2 slots: LPT gives {3,2,2}=7 / {3,2}=5 -> 7
        let tasks = vec![t(3.0), t(3.0), t(2.0), t(2.0), t(2.0)];
        let p = schedule(&tasks, 2, &nm(), None);
        assert!((p.makespan_s - 7.0).abs() < 1e-9);
    }

    #[test]
    fn makespan_bounded_by_longest_task() {
        let tasks = vec![t(10.0), t(0.1), t(0.1)];
        let p = schedule(&tasks, 8, &nm(), None);
        assert!((p.makespan_s - 10.0).abs() < 1e-9);
    }

    #[test]
    fn dispatch_overhead_charged_per_task() {
        let model = NetworkModel { task_dispatch_s: 1.0, ..nm() };
        let p = schedule(&[t(1.0); 4], 1, &model, None);
        assert!((p.makespan_s - 8.0).abs() < 1e-9); // 4 * (1 + 1)
    }

    #[test]
    fn job_time_monotone_then_flattens() {
        // 40 map tasks of 30s each, modest shuffle, heavier per-machine
        // coordination (small-job regime): the paper's trend appears —
        // big win 1->2->4, flat 8->10.
        let model = NetworkModel {
            coord_per_machine_s: 10.0,
            ..NetworkModel::default()
        };
        let maps = vec![TaskCost { compute_s: 30.0, input_bytes: 8 << 20, output_bytes: 1 << 20 }; 40];
        let reduces = vec![TaskCost { compute_s: 5.0, input_bytes: 0, output_bytes: 0 }; 4];
        let times: Vec<f64> = [1usize, 2, 4, 6, 8, 10]
            .iter()
            .map(|&m| job_time(&maps, &reduces, 40 << 20, m, 2, &model))
            .collect();
        // Monotone decreasing through 8 slaves...
        for w in times.windows(2).take(4) {
            assert!(w[1] < w[0], "expected speedup: {times:?}");
        }
        // ...but 8 -> 10 gains little or regresses (within 10%).
        let gain = (times[4] - times[5]) / times[4];
        assert!(gain < 0.10, "8->10 should flatten: {times:?}");
    }

    #[test]
    fn speculative_execution_caps_stragglers() {
        let model = nm();
        let tasks = vec![t(1.0); 8];
        let speed = [1.0, 1.0, 1.0, 0.1]; // one 10x straggler
        let slow = schedule(&tasks, 4, &model, Some(&speed));
        let spec = schedule_speculative(&tasks, 4, &model, &speed, 1.5);
        assert!(spec.makespan_s <= slow.makespan_s);
        // Straggler hurt the plain schedule...
        let fair = schedule(&tasks, 4, &model, None);
        assert!(slow.makespan_s > fair.makespan_s * 1.5);
        // ...speculation recovers most of it.
        assert!(spec.makespan_s < slow.makespan_s * 0.75);
    }
}
