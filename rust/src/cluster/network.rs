//! Network/overhead cost model of the simulated Hadoop cluster.
//!
//! The paper's Table 5-1 shape — near-linear speedup up to ~8 slaves, then a
//! flattening or regression at 10 — is driven by the ratio of per-task
//! compute to fixed scheduling/communication overheads. This model charges:
//!
//! - `task_dispatch_s` per task (JobTracker assignment + JVM start in real
//!   Hadoop — the dominant small-job overhead),
//! - disk reads at `disk_bw` for task input,
//! - shuffle: the fraction `(m-1)/m` of intermediate bytes that cross the
//!   network (with m machines a hash partitioner keeps `1/m` local), over
//!   per-machine bandwidth `net_bw`,
//! - `coord_per_machine_s` per machine per job (heartbeats, barrier,
//!   speculative-exec bookkeeping) — the term that *grows* with m and
//!   eventually eats the speedup,
//! - `job_setup_s` per job (submission, split computation).
//!
//! Defaults are calibrated in benches/table1.rs to reproduce the paper's
//! trend on commodity-2011-hardware-like constants.

/// Cost-model parameters (all times in virtual seconds, rates in bytes/s).
#[derive(Debug, Clone, PartialEq)]
pub struct NetworkModel {
    /// Fixed per-job submission/setup cost.
    pub job_setup_s: f64,
    /// Per-task dispatch overhead (scheduling + task start).
    pub task_dispatch_s: f64,
    /// Sequential disk bandwidth for task input/output.
    pub disk_bw: f64,
    /// Per-machine network bandwidth for shuffle traffic.
    pub net_bw: f64,
    /// Bandwidth of a rack-local (top-of-rack switch) read stream.
    pub rack_bw: f64,
    /// Bandwidth of an off-rack read stream (the oversubscribed core link —
    /// what the scheduler charges a map task whose split lives in another
    /// rack).
    pub cross_rack_bw: f64,
    /// Per-machine, per-job coordination overhead (grows with m).
    pub coord_per_machine_s: f64,
    /// Per-machine all-to-all latency charged once per shuffle barrier.
    pub shuffle_latency_s: f64,
    /// Multiplier mapping *measured* task compute seconds (this host, native
    /// code) to the reference cluster's virtual seconds (the paper's i5-2300
    /// slaves running JVM MapReduce tasks). Calibrated in benches/table1.rs.
    pub compute_scale: f64,
}

impl Default for NetworkModel {
    fn default() -> Self {
        Self {
            // Hadoop-1.x-era constants: multi-second task start, ~100 MB/s
            // disk, ~1 GbE network, noticeable per-node coordination.
            job_setup_s: 8.0,
            task_dispatch_s: 2.0,
            disk_bw: 100e6,
            net_bw: 110e6,
            rack_bw: 110e6,
            cross_rack_bw: 30e6,
            coord_per_machine_s: 4.0,
            shuffle_latency_s: 1.5,
            compute_scale: 1.0,
        }
    }
}

impl NetworkModel {
    /// Time for one task to read `bytes` of input from local disk.
    pub fn read_time(&self, bytes: u64) -> f64 {
        bytes as f64 / self.disk_bw
    }

    /// Time to read `bytes` of task input at a locality tier: node-local
    /// reads stream from local disk; rack-local reads are additionally
    /// bounded by the top-of-rack switch; off-rack reads cross the
    /// oversubscribed core (the remote disk is still in the path).
    pub fn read_time_at(&self, bytes: u64, locality: crate::scheduler::Locality) -> f64 {
        use crate::scheduler::Locality;
        let rate = match locality {
            Locality::NodeLocal => self.disk_bw,
            Locality::RackLocal => self.disk_bw.min(self.rack_bw),
            Locality::OffRack => self.disk_bw.min(self.cross_rack_bw),
        };
        bytes as f64 / rate.max(1.0)
    }

    /// Time for one task to write `bytes` of output (replicated table/DFS
    /// writes go over the network).
    pub fn write_time(&self, bytes: u64) -> f64 {
        bytes as f64 / self.net_bw
    }

    /// Time for the shuffle of `bytes` intermediate data across `m` machines.
    ///
    /// `(m-1)/m` of the bytes cross the network; aggregate bandwidth scales
    /// with m (each machine sends/receives at `net_bw`), but each extra
    /// machine adds `shuffle_latency_s` of all-to-all connection setup.
    pub fn shuffle_time(&self, bytes: u64, m: usize) -> f64 {
        let m = m.max(1) as f64;
        let cross = bytes as f64 * (m - 1.0) / m;
        cross / (self.net_bw * m) + self.shuffle_latency_s * (m - 1.0)
    }

    /// Fixed per-job overhead on an `m`-machine cluster.
    pub fn job_overhead(&self, m: usize) -> f64 {
        self.job_setup_s + self.coord_per_machine_s * m as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_time_linear_in_bytes() {
        let nm = NetworkModel::default();
        assert!((nm.read_time(100_000_000) - 1.0).abs() < 1e-9);
        assert_eq!(nm.read_time(0), 0.0);
    }

    #[test]
    fn read_time_tiers_get_slower_off_rack() {
        use crate::scheduler::Locality;
        let nm = NetworkModel::default();
        let b = 300_000_000u64;
        let local = nm.read_time_at(b, Locality::NodeLocal);
        let rack = nm.read_time_at(b, Locality::RackLocal);
        let remote = nm.read_time_at(b, Locality::OffRack);
        assert!((local - nm.read_time(b)).abs() < 1e-9);
        assert!(rack >= local);
        assert!(remote > rack, "off-rack must pay the core link: {remote} vs {rack}");
    }

    #[test]
    fn shuffle_zero_on_single_machine() {
        let nm = NetworkModel::default();
        assert_eq!(nm.shuffle_time(1 << 30, 1), 0.0);
    }

    #[test]
    fn shuffle_latency_grows_with_m() {
        let nm = NetworkModel::default();
        // For tiny payloads the latency term dominates and grows with m.
        let t2 = nm.shuffle_time(1024, 2);
        let t10 = nm.shuffle_time(1024, 10);
        assert!(t10 > t2);
    }

    #[test]
    fn shuffle_bandwidth_term_shrinks_with_m() {
        let nm = NetworkModel {
            shuffle_latency_s: 0.0,
            ..NetworkModel::default()
        };
        // Pure-bandwidth shuffle: more machines = more aggregate bandwidth;
        // the per-machine transferred share shrinks.
        let big = 100u64 << 30;
        assert!(nm.shuffle_time(big, 10) < nm.shuffle_time(big, 2));
    }

    #[test]
    fn job_overhead_linear_in_m() {
        let nm = NetworkModel::default();
        let d = nm.job_overhead(10) - nm.job_overhead(9);
        assert!((d - nm.coord_per_machine_s).abs() < 1e-9);
    }
}
