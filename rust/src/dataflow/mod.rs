//! Typed dataflow layer: `Pipeline` / `Dataset<K, V>` over the MapReduce
//! engine (FlumeJava / Spark-RDD style).
//!
//! The coordinator phases used to hand-wire every job: build splits, pack
//! `&[u8]` buffers, call `mapreduce::run`, stage intermediates in the DFS
//! by hand. This module replaces that surface with a small composable API:
//!
//! ```ignore
//! let p = Pipeline::new("wordcount");
//! let counts = p
//!     .read_dfs::<u64, Vec<u8>>("/input/lines", splits, ranges) // locality for free
//!     .map_kv("tokenize", |_, line, out| { ...; out.emit(word, 1.0); Ok(()) })
//!     .group_reduce("count")
//!     .reducers(4)
//!     .reduce(|word, values, out| { ...; Ok(()) })
//!     .collect();
//! let mut run = p.run(&services)?;        // plan → fuse → execute
//! let records = counts.take(&mut run);    // typed records back
//! ```
//!
//! `run(&Services)` hands the logical DAG to the [`Planner`], which fuses
//! chains of map-only stages into single jobs, stages intermediates
//! between jobs in the DFS (rack-aware placement ⇒ downstream
//! `split_hosts` for free) and feeds each materialized job through the
//! unchanged [`crate::mapreduce::JobBuilder`] / scheduler / shuffle
//! machinery. Keys and values are typed via [`KvCodec`]; the encodings are
//! bit-identical to the hand-packed buffers the phases used before, so the
//! port is output- and cost-model-neutral.
//!
//! The old `JobBuilder` path remains public — tests and ad-hoc jobs still
//! use it directly (see DESIGN.md §"Dataflow layer" for the migration
//! note).

pub mod codec;
mod graph;
mod planner;

use std::cell::RefCell;
use std::marker::PhantomData;
use std::rc::Rc;
use std::sync::Arc;

use crate::coordinator::Services;
use crate::error::{Error, Result};
use crate::mapreduce::{
    InputSplit, Mapper, Partitioner, Reducer, ShuffleConfig, TaskContext, Values, KV,
};
use crate::table::Table;

use graph::{Graph, LogicalOp, Sink, SinkKind, TablePutMapper};

pub use codec::{read_varint, write_varint, KvCodec, VarU64};
pub use graph::{Locality, NodeId};
pub use planner::{
    decode_staged, Plan, PipelineRun, PlanStats, Planner, StageStats, StageSummary,
    STAGED_RECORDS_PER_SPLIT,
};

/// A dataflow pipeline under construction: a shared logical graph that
/// [`Dataset`] handles append operators to.
pub struct Pipeline {
    graph: Rc<RefCell<Graph>>,
}

impl Pipeline {
    /// New empty pipeline. The name prefixes job names and the DFS staging
    /// directory (`/dataflow/<name>/…`).
    pub fn new(name: &str) -> Self {
        Self { graph: Rc::new(RefCell::new(Graph::new(name))) }
    }

    fn add_source<K: KvCodec, V: KvCodec>(
        &self,
        splits: Vec<Vec<(K, V)>>,
        locality: Locality,
    ) -> Dataset<K, V> {
        let raw: Vec<InputSplit> = splits
            .into_iter()
            .map(|split| {
                split
                    .into_iter()
                    .map(|(k, v)| (k.to_bytes(), v.to_bytes()))
                    .collect()
            })
            .collect();
        let node = self
            .graph
            .borrow_mut()
            .add(None, LogicalOp::Source { splits: raw, locality });
        Dataset { graph: self.graph.clone(), node, _t: PhantomData }
    }

    /// In-memory source with no placement preference.
    pub fn from_records<K: KvCodec, V: KvCodec>(
        &self,
        splits: Vec<Vec<(K, V)>>,
    ) -> Dataset<K, V> {
        self.add_source(splits, Locality::None)
    }

    /// Source whose splits cover byte ranges of a DFS file: each split's
    /// preferred hosts are the replica nodes of its ranges' blocks
    /// (resolved at run time). `ranges[i]` lists the (possibly several)
    /// byte ranges split `i` covers.
    pub fn read_dfs<K: KvCodec, V: KvCodec>(
        &self,
        path: &str,
        splits: Vec<Vec<(K, V)>>,
        ranges: Vec<Vec<(usize, usize)>>,
    ) -> Dataset<K, V> {
        self.add_source(
            splits,
            Locality::DfsRanges { path: path.to_string(), ranges },
        )
    }

    /// Source whose splits are anchored at table row keys: each split's
    /// preferred host is the slave serving the region that owns
    /// `anchor_keys[i]` (HBase-style co-location, resolved at run time).
    pub fn read_table<K: KvCodec, V: KvCodec>(
        &self,
        table: &Arc<Table>,
        splits: Vec<Vec<(K, V)>>,
        anchor_keys: Vec<Vec<u8>>,
    ) -> Dataset<K, V> {
        self.add_source(
            splits,
            Locality::TableKeys { table: table.clone(), keys: anchor_keys },
        )
    }

    /// Override the shuffle knobs for every job this pipeline launches.
    /// (Failure handling needs no per-pipeline hook: the cluster's
    /// `[faults]` domain — [`crate::cluster::FaultConfig`] — governs every
    /// job alike.)
    pub fn shuffle_config(&self, cfg: ShuffleConfig) {
        self.graph.borrow_mut().shuffle = Some(cfg);
    }

    /// Hand the logical DAG to the [`Planner`]: topological order + map
    /// fusion. The plan can be inspected ([`Plan::explain`],
    /// [`Plan::stage_summaries`]) before running.
    pub fn plan(self) -> Result<Plan> {
        let graph = Rc::try_unwrap(self.graph)
            .map_err(|_| {
                Error::MapReduce(
                    "dataflow: pipeline still has live datasets — finish every \
                     chain with a sink before planning"
                        .into(),
                )
            })?
            .into_inner();
        Planner::plan(graph)
    }

    /// Plan and execute on the services.
    pub fn run(self, services: &Services) -> Result<PipelineRun> {
        self.plan()?.run(services)
    }
}

/// Typed emitter handed to map and reduce functions. Wraps the engine's
/// [`TaskContext`]: emitted records are encoded via [`KvCodec`], counters
/// pass straight through (cost-model hooks like `COMPUTE_US` and
/// `EXTRA_INPUT_BYTES` keep working).
pub struct Emit<'a, K: KvCodec, V: KvCodec> {
    ctx: &'a mut TaskContext,
    _t: PhantomData<fn(K, V)>,
}

impl<K: KvCodec, V: KvCodec> Emit<'_, K, V> {
    /// Emit one typed record.
    pub fn emit(&mut self, key: K, value: V) {
        self.ctx.emit(key.to_bytes(), value.to_bytes());
    }

    /// Bump a job counter (user counters and engine cost hooks alike).
    pub fn incr(&mut self, name: &str, delta: u64) {
        self.ctx.incr(name, delta);
    }
}

/// Typed streaming view of one key group's values (wraps the engine's
/// [`Values`] stream — a group is never materialized).
pub struct Group<'a, V: KvCodec> {
    values: &'a mut dyn Values,
    _t: PhantomData<fn() -> V>,
}

impl<V: KvCodec> Group<'_, V> {
    /// The next value of the group, or `None` when the group is done.
    pub fn next_value(&mut self) -> Option<V> {
        self.values.next_value().map(V::decode)
    }
}

/// Adapts a typed map closure to the engine's byte-level [`Mapper`].
struct TypedMapper<K, V, K2, V2, F> {
    f: F,
    _t: PhantomData<fn(K, V) -> (K2, V2)>,
}

impl<K, V, K2, V2, F> Mapper for TypedMapper<K, V, K2, V2, F>
where
    K: KvCodec,
    V: KvCodec,
    K2: KvCodec,
    V2: KvCodec,
    F: Fn(K, V, &mut Emit<'_, K2, V2>) -> Result<()> + Send + Sync,
{
    fn map(&self, key: &[u8], value: &[u8], ctx: &mut TaskContext) -> Result<()> {
        let mut out = Emit { ctx, _t: PhantomData };
        (self.f)(K::decode(key), V::decode(value), &mut out)
    }
}

/// Adapts a typed reduce closure to the engine's byte-level [`Reducer`].
struct TypedReducer<K, V, K2, V2, F> {
    f: F,
    _t: PhantomData<fn(K, V) -> (K2, V2)>,
}

impl<K, V, K2, V2, F> Reducer for TypedReducer<K, V, K2, V2, F>
where
    K: KvCodec,
    V: KvCodec,
    K2: KvCodec,
    V2: KvCodec,
    F: Fn(K, &mut Group<'_, V>, &mut Emit<'_, K2, V2>) -> Result<()> + Send + Sync,
{
    fn reduce(
        &self,
        key: &[u8],
        values: &mut dyn Values,
        ctx: &mut TaskContext,
    ) -> Result<()> {
        let mut group = Group { values, _t: PhantomData };
        let mut out = Emit { ctx, _t: PhantomData };
        (self.f)(K::decode(key), &mut group, &mut out)
    }
}

/// A typed handle to one logical dataset. Handles are consumed by value,
/// so every dataset has exactly one consumer and the logical graph stays a
/// chain forest the planner can fuse aggressively.
pub struct Dataset<K: KvCodec, V: KvCodec> {
    graph: Rc<RefCell<Graph>>,
    node: NodeId,
    _t: PhantomData<fn(K, V)>,
}

impl<K: KvCodec, V: KvCodec> Dataset<K, V> {
    /// Record-at-a-time transform; fusable with adjacent map stages.
    pub fn map_kv<K2, V2, F>(self, name: &str, f: F) -> Dataset<K2, V2>
    where
        K2: KvCodec,
        V2: KvCodec,
        F: Fn(K, V, &mut Emit<'_, K2, V2>) -> Result<()> + Send + Sync + 'static,
    {
        let mapper: Arc<dyn Mapper> =
            Arc::new(TypedMapper::<K, V, K2, V2, F> { f, _t: PhantomData });
        let node = self
            .graph
            .borrow_mut()
            .add(Some(self.node), LogicalOp::Map { name: name.to_string(), mapper });
        Dataset { graph: self.graph, node, _t: PhantomData }
    }

    /// Start a shuffle boundary: group records by key, then reduce each
    /// group. Configure with [`GroupReduceBuilder::reducers`],
    /// [`GroupReduceBuilder::combine`] and
    /// [`GroupReduceBuilder::partitioner`]; finish with
    /// [`GroupReduceBuilder::reduce`].
    pub fn group_reduce(self, name: &str) -> GroupReduceBuilder<K, V> {
        GroupReduceBuilder {
            graph: self.graph,
            input: self.node,
            name: name.to_string(),
            num_reducers: 1,
            combiner: None,
            partitioner: None,
            _t: PhantomData,
        }
    }

    /// Sink: put every record into the table. Runs as a fusable map stage
    /// (like the hand-wired table-writing mappers did), charging
    /// `EXTRA_OUTPUT_BYTES` per put and emitting nothing.
    pub fn write_table(self, table: &Arc<Table>) {
        let mapper: Arc<dyn Mapper> = Arc::new(TablePutMapper { table: table.clone() });
        self.graph.borrow_mut().add(
            Some(self.node),
            LogicalOp::Map { name: format!("table:{}", table.name), mapper },
        );
    }

    /// Sink: write the materialized records to a DFS file (varint framing;
    /// read back with [`decode_staged`]).
    pub fn write_dfs(self, path: &str) {
        self.graph.borrow_mut().sinks.push(Sink {
            node: self.node,
            kind: SinkKind::WriteDfs { path: path.to_string() },
        });
    }

    /// Sink: keep the materialized records; retrieve them typed from the
    /// [`PipelineRun`] after `run`.
    pub fn collect(self) -> Collected<K, V> {
        self.graph
            .borrow_mut()
            .sinks
            .push(Sink { node: self.node, kind: SinkKind::Collect });
        Collected { node: self.node, _t: PhantomData }
    }
}

/// Builder for a `group_reduce` shuffle boundary.
pub struct GroupReduceBuilder<K: KvCodec, V: KvCodec> {
    graph: Rc<RefCell<Graph>>,
    input: NodeId,
    name: String,
    num_reducers: usize,
    combiner: Option<Arc<dyn Reducer>>,
    partitioner: Option<Arc<dyn Partitioner>>,
    _t: PhantomData<fn(K, V)>,
}

impl<K: KvCodec, V: KvCodec> GroupReduceBuilder<K, V> {
    /// Number of reduce partitions (default 1).
    pub fn reducers(mut self, n: usize) -> Self {
        self.num_reducers = n.max(1);
        self
    }

    /// Replace the default hash partitioner.
    pub fn partitioner(mut self, p: Arc<dyn Partitioner>) -> Self {
        self.partitioner = Some(p);
        self
    }

    /// Typed map-side combiner (same key/value types in and out).
    pub fn combine<F>(mut self, f: F) -> Self
    where
        F: Fn(K, &mut Group<'_, V>, &mut Emit<'_, K, V>) -> Result<()>
            + Send
            + Sync
            + 'static,
    {
        self.combiner =
            Some(Arc::new(TypedReducer::<K, V, K, V, F> { f, _t: PhantomData }));
        self
    }

    /// Finish the boundary with the reduce function.
    pub fn reduce<K2, V2, F>(self, f: F) -> Dataset<K2, V2>
    where
        K2: KvCodec,
        V2: KvCodec,
        F: Fn(K, &mut Group<'_, V>, &mut Emit<'_, K2, V2>) -> Result<()>
            + Send
            + Sync
            + 'static,
    {
        let GroupReduceBuilder {
            graph,
            input,
            name,
            num_reducers,
            combiner,
            partitioner,
            _t,
        } = self;
        let reducer: Arc<dyn Reducer> =
            Arc::new(TypedReducer::<K, V, K2, V2, F> { f, _t: PhantomData });
        let node = graph.borrow_mut().add(
            Some(input),
            LogicalOp::GroupReduce { name, reducer, combiner, partitioner, num_reducers },
        );
        Dataset { graph, node, _t: PhantomData }
    }
}

/// Handle to a collected dataset: redeem it against the [`PipelineRun`]
/// returned by `run` to get the typed, globally key-sorted records.
pub struct Collected<K: KvCodec, V: KvCodec> {
    node: NodeId,
    _t: PhantomData<fn() -> (K, V)>,
}

impl<K: KvCodec, V: KvCodec> Collected<K, V> {
    /// Decode and return the collected records, key-sorted.
    pub fn take(&self, run: &mut PipelineRun) -> Vec<(K, V)> {
        run.take_sorted(self.node)
            .into_iter()
            .map(|(k, v)| (K::decode(&k), V::decode(&v)))
            .collect()
    }

    /// The raw byte records, key-sorted (byte-identity tests).
    pub fn take_raw(&self, run: &mut PipelineRun) -> Vec<KV> {
        run.take_sorted(self.node)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::runtime::KernelRuntime;

    fn services(m: usize) -> Services {
        Services::new(Cluster::new(m), Arc::new(KernelRuntime::native()))
    }

    fn word_splits() -> Vec<Vec<(u64, Vec<u8>)>> {
        vec![
            vec![
                (0u64, b"the quick brown fox".to_vec()),
                (1u64, b"the lazy dog".to_vec()),
            ],
            vec![(2u64, b"the fox jumps over the dog".to_vec())],
        ]
    }

    #[test]
    fn typed_wordcount_end_to_end() {
        let svc = services(3);
        let p = Pipeline::new("wordcount");
        let counts = p
            .from_records(word_splits())
            .map_kv("tokenize", |_line: u64, text: Vec<u8>, out| {
                for w in std::str::from_utf8(&text).unwrap().split_whitespace() {
                    out.emit(w.as_bytes().to_vec(), 1.0f64);
                }
                Ok(())
            })
            .group_reduce("count")
            .reducers(3)
            .reduce(|word: Vec<u8>, values: &mut Group<'_, f64>, out| {
                let mut total = 0.0;
                while let Some(v) = values.next_value() {
                    total += v;
                }
                out.emit(word, total);
                Ok(())
            })
            .collect();
        let mut run = p.run(&svc).unwrap();
        assert_eq!(run.stats.jobs(), 1, "map + reduce fuse into one job");
        let result: std::collections::HashMap<String, f64> = counts
            .take(&mut run)
            .into_iter()
            .map(|(k, v)| (String::from_utf8(k).unwrap(), v))
            .collect();
        assert_eq!(result["the"], 4.0);
        assert_eq!(result["fox"], 2.0);
        assert_eq!(result["dog"], 2.0);
    }

    #[test]
    fn combiner_shrinks_shuffle_without_changing_answer() {
        let svc = services(2);
        let run_it = |with_combiner: bool| {
            let p = Pipeline::new("wc");
            let mut g = p
                .from_records(word_splits())
                .map_kv("tokenize", |_k: u64, text: Vec<u8>, out| {
                    for w in std::str::from_utf8(&text).unwrap().split_whitespace() {
                        out.emit(w.as_bytes().to_vec(), 1.0f64);
                    }
                    Ok(())
                })
                .group_reduce("count")
                .reducers(2);
            if with_combiner {
                g = g.combine(|w: Vec<u8>, vs: &mut Group<'_, f64>, out| {
                    let mut t = 0.0;
                    while let Some(v) = vs.next_value() {
                        t += v;
                    }
                    out.emit(w, t);
                    Ok(())
                });
            }
            let counts = g.reduce(|w: Vec<u8>, vs: &mut Group<'_, f64>, out| {
                let mut t = 0.0;
                while let Some(v) = vs.next_value() {
                    t += v;
                }
                out.emit(w, t);
                Ok(())
            });
            let handle = counts.collect();
            let mut run = p.run(&svc).unwrap();
            let shuffle: u64 =
                run.stats.stages.iter().map(|s| s.stats.shuffle_bytes).sum();
            (handle.take_raw(&mut run), shuffle)
        };
        let (plain, plain_shuffle) = run_it(false);
        let (combined, combined_shuffle) = run_it(true);
        assert_eq!(plain, combined, "combiner must not change the answer");
        assert!(
            combined_shuffle < plain_shuffle,
            "combiner should shrink shuffle: {combined_shuffle} vs {plain_shuffle}"
        );
    }

    #[test]
    fn map_only_chain_with_write_dfs_sink() {
        let svc = services(2);
        let p = Pipeline::new("sink");
        p.from_records(vec![vec![(1u64, 10u64), (2u64, 20u64)]])
            .map_kv("double", |k: u64, v: u64, out| {
                out.emit(k, v * 2);
                Ok(())
            })
            .write_dfs("/out/doubled");
        let run = p.run(&svc).unwrap();
        assert_eq!(run.stats.jobs(), 1);
        let raw = svc.dfs.read_file("/out/doubled").unwrap();
        let records = decode_staged(&raw).unwrap();
        assert_eq!(records.len(), 2);
        assert_eq!(u64::decode(&records[0].1), 20);
    }

    #[test]
    fn write_table_fuses_and_lands_rows() {
        let svc = services(2);
        let table = svc.tables.create("T", 2).unwrap();
        let p = Pipeline::new("tput");
        p.from_records(vec![vec![(3u64, ()), (4u64, ())]])
            .map_kv("emit-rows", |k: u64, _: (), out| {
                out.emit(k, vec![k as u8]);
                Ok(())
            })
            .write_table(&table);
        let plan = p.plan().unwrap();
        assert_eq!(plan.job_count(), 1, "map + table-put fuse into one job");
        assert_eq!(plan.stage_summaries()[0].fused_maps, 2);
        let run = plan.run(&svc).unwrap();
        assert_eq!(run.stats.stages[0].fused_maps, 2);
        assert_eq!(
            table.get(&3u64.to_bytes()).unwrap(),
            Some(vec![3u8]),
            "row must land in the table"
        );
        assert!(
            run.stats.counter(crate::mapreduce::names::EXTRA_OUTPUT_BYTES) > 0,
            "table writes must be charged"
        );
    }

    #[test]
    fn unfinished_dataset_fails_plan() {
        let p = Pipeline::new("dangling");
        let ds = p.from_records(vec![vec![(1u64, ())]]);
        let err = p.plan().unwrap_err();
        assert!(err.to_string().contains("live datasets"), "{err}");
        drop(ds);
    }

    #[test]
    fn multi_job_chain_stages_intermediates_in_dfs() {
        let svc = services(2);
        let p = Pipeline::new("chain");
        let sums = p
            .from_records(vec![vec![(1u64, 1.0f64), (2u64, 2.0), (3u64, 3.0)]])
            .group_reduce("first")
            .reducers(2)
            .reduce(|k: u64, vs: &mut Group<'_, f64>, out| {
                let mut t = 0.0;
                while let Some(v) = vs.next_value() {
                    t += v;
                }
                out.emit(k % 2, t);
                Ok(())
            })
            .group_reduce("second")
            .reducers(2)
            .reduce(|k: u64, vs: &mut Group<'_, f64>, out| {
                let mut t = 0.0;
                while let Some(v) = vs.next_value() {
                    t += v;
                }
                out.emit(k, t);
                Ok(())
            })
            .collect();
        let mut run = p.run(&svc).unwrap();
        assert_eq!(run.stats.jobs(), 2);
        assert!(run.stats.staged_bytes > 0, "intermediate must be staged");
        assert!(
            svc.dfs.exists("/dataflow/chain/stage-0"),
            "staged file in DFS: {:?}",
            svc.dfs.list()
        );
        let result = sums.take(&mut run);
        // keys 1,3 -> bucket 1 (sum 4), key 2 -> bucket 0 (sum 2).
        assert_eq!(result, vec![(0, 2.0), (1, 4.0)]);
    }
}
