//! Typed key/value codecs for the dataflow layer.
//!
//! [`KvCodec`] is the contract between the typed [`super::Dataset`] API and
//! the byte-oriented MapReduce engine: every key/value type a pipeline
//! carries knows how to encode itself into the `Vec<u8>` records the
//! shuffle sorts and how to decode itself back. Encodings are chosen to be
//! **bit-identical to the hand-packed buffers the coordinator jobs used
//! before the dataflow port** (big-endian fixed-width numerics from
//! [`crate::util::bytes`], length-prefixed f64 vectors), so porting a job
//! onto the typed API cannot change its outputs, shuffle bytes or spill
//! counters. A LEB128 varint codec is provided for compact record framing
//! (the planner uses it for DFS-staged intermediates).

use crate::util::bytes;

/// A type that can cross the shuffle as a key or value.
///
/// Keys additionally rely on the property that byte-lexicographic order of
/// the encoding equals the natural order of the type (true for the
/// big-endian unsigned codecs here — Hadoop's Writable convention).
pub trait KvCodec: Sized + Send + Sync + 'static {
    /// Append the encoding of `self` to `out`.
    fn encode_into(&self, out: &mut Vec<u8>);

    /// Decode a value from its full encoding.
    fn decode(bytes: &[u8]) -> Self;

    /// Encode into a fresh buffer.
    fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode_into(&mut out);
        out
    }
}

/// Unit: the empty encoding (splits whose records carry no payload).
impl KvCodec for () {
    fn encode_into(&self, _out: &mut Vec<u8>) {}

    fn decode(_bytes: &[u8]) -> Self {}
}

/// Big-endian fixed-width u64 (order-preserving row keys).
impl KvCodec for u64 {
    fn encode_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&bytes::encode_u64(*self));
    }

    fn decode(b: &[u8]) -> Self {
        bytes::decode_u64(b)
    }
}

/// Big-endian fixed-width u32 (center indices, column ids).
impl KvCodec for u32 {
    fn encode_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&bytes::encode_u32(*self));
    }

    fn decode(b: &[u8]) -> Self {
        bytes::decode_u32(b)
    }
}

/// f64 payload (not order-preserving; values only).
impl KvCodec for f64 {
    fn encode_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&bytes::encode_f64(*self));
    }

    fn decode(b: &[u8]) -> Self {
        bytes::decode_f64(b)
    }
}

/// Raw bytes: the escape hatch for pre-encoded payloads (sparse-row chunks,
/// tagged graph records).
impl KvCodec for Vec<u8> {
    fn encode_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(self);
    }

    fn decode(b: &[u8]) -> Self {
        b.to_vec()
    }
}

/// Length-prefixed f64 vector (k-means partial sums).
impl KvCodec for Vec<f64> {
    fn encode_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&bytes::encode_f64_vec(self));
    }

    fn decode(b: &[u8]) -> Self {
        bytes::decode_f64_vec(b).0
    }
}

/// Composite row key `(row, column-block)` — 16 bytes, both halves
/// order-preserving (the table chunk keys of phases 1–2).
impl KvCodec for (u64, u64) {
    fn encode_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&bytes::encode_u64(self.0));
        out.extend_from_slice(&bytes::encode_u64(self.1));
    }

    fn decode(b: &[u8]) -> Self {
        (bytes::decode_u64(&b[..8]), bytes::decode_u64(&b[8..16]))
    }
}

/// `(index, weight)` payload — 16 bytes (graph-mode adjacency records).
impl KvCodec for (u64, f64) {
    fn encode_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&bytes::encode_u64(self.0));
        out.extend_from_slice(&bytes::encode_f64(self.1));
    }

    fn decode(b: &[u8]) -> Self {
        (bytes::decode_u64(&b[..8]), bytes::decode_f64(&b[8..16]))
    }
}

/// LEB128 varint u64: compact framing for staged intermediates.
///
/// NOT order-preserving — use it for values and framing lengths, never for
/// shuffle keys.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VarU64(pub u64);

impl KvCodec for VarU64 {
    fn encode_into(&self, out: &mut Vec<u8>) {
        write_varint(self.0, out);
    }

    fn decode(b: &[u8]) -> Self {
        VarU64(read_varint(b).0)
    }
}

/// Append the LEB128 encoding of `v`.
pub fn write_varint(mut v: u64, out: &mut Vec<u8>) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

/// Read a LEB128 varint; returns `(value, bytes consumed)`.
pub fn read_varint(b: &[u8]) -> (u64, usize) {
    let mut v = 0u64;
    let mut shift = 0u32;
    for (i, &byte) in b.iter().enumerate() {
        v |= ((byte & 0x7f) as u64) << shift;
        if byte & 0x80 == 0 {
            return (v, i + 1);
        }
        shift += 7;
    }
    (v, b.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip<T: KvCodec + PartialEq + std::fmt::Debug>(v: T) {
        let enc = v.to_bytes();
        assert_eq!(T::decode(&enc), v);
    }

    #[test]
    fn fixed_width_roundtrips() {
        roundtrip(0u64);
        roundtrip(u64::MAX);
        roundtrip(42u32);
        roundtrip(-1.5f64);
        roundtrip(());
        roundtrip((7u64, 9u64));
        roundtrip((3u64, 0.25f64));
        roundtrip(vec![1u8, 2, 3]);
        roundtrip(vec![1.0f64, -2.5, 0.0]);
    }

    #[test]
    fn encodings_match_hand_packed_buffers() {
        // The port contract: typed encodings are byte-identical to what the
        // coordinator jobs emitted before the dataflow layer existed.
        assert_eq!(7u64.to_bytes(), bytes::encode_u64(7).to_vec());
        assert_eq!(5u32.to_bytes(), bytes::encode_u32(5).to_vec());
        assert_eq!(1.5f64.to_bytes(), bytes::encode_f64(1.5).to_vec());
        assert_eq!(
            vec![1.0f64, 2.0].to_bytes(),
            bytes::encode_f64_vec(&[1.0, 2.0])
        );
        let mut key = Vec::new();
        key.extend_from_slice(&bytes::encode_u64(3));
        key.extend_from_slice(&bytes::encode_u64(4));
        assert_eq!((3u64, 4u64).to_bytes(), key);
    }

    #[test]
    fn key_order_preserved() {
        assert!(5u64.to_bytes() < 6u64.to_bytes());
        assert!(255u64.to_bytes() < 256u64.to_bytes());
        assert!((1u64, 9u64).to_bytes() < (2u64, 0u64).to_bytes());
    }

    #[test]
    fn varint_roundtrip_and_sizes() {
        for v in [0u64, 1, 127, 128, 300, 1 << 20, u64::MAX] {
            let mut out = Vec::new();
            write_varint(v, &mut out);
            let (back, used) = read_varint(&out);
            assert_eq!(back, v);
            assert_eq!(used, out.len());
        }
        let mut one = Vec::new();
        write_varint(127, &mut one);
        assert_eq!(one.len(), 1);
        let mut two = Vec::new();
        write_varint(128, &mut two);
        assert_eq!(two.len(), 2);
        roundtrip(VarU64(987654321));
    }
}
