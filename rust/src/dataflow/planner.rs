//! The DAG planner: logical graph → fused MapReduce jobs.
//!
//! [`Planner::plan`] walks the logical nodes in topological order (node-id
//! order, by construction) and groups them into **stages**, each of which
//! becomes exactly one job on the existing engine:
//!
//! - a `Source` opens a new stage;
//! - a `Map` whose upstream is the open tail of a stage **fuses** into it
//!   (so `map → map → group` launches one job, not three);
//! - a `GroupReduce` closes the stage it fuses into (the shuffle is a
//!   stage boundary); operators arriving after a closed stage start a new
//!   one, fed by the previous stage's **staged intermediate**.
//!
//! Between jobs, [`Plan::run`] materializes the upstream stage's output
//! into the DFS (varint-framed records) and re-splits it; because DFS
//! block placement is rack-aware, the downstream job's `split_hosts` come
//! for free from [`crate::dfs::Dfs::range_hosts`]. Source locality
//! ([`Locality`]) is resolved the same way at run time, so plans can be
//! built and explained without services.

use std::collections::HashMap;
use std::sync::Arc;

use crate::coordinator::Services;
use crate::error::{Error, Result};
use crate::mapreduce::{
    self, Counters, InputSplit, JobBuilder, JobStats, Mapper, Reducer, ShuffleConfig,
    KV,
};
use crate::util::fmt::human_bytes;

use super::codec::{read_varint, write_varint};
use super::graph::{
    FusedMapper, Graph, IdentityMapper, Locality, LogicalOp, NodeId, Sink, SinkKind,
};

/// Records per split when a staged intermediate is re-split for the next
/// job (the dataflow analogue of an input-format split size).
pub const STAGED_RECORDS_PER_SPLIT: usize = 1024;

/// Where a planned stage reads its input from.
enum StageInput {
    /// The stage's own source splits.
    Source,
    /// The materialized output of an earlier stage (by stage index).
    Staged(usize),
}

/// The reduce side of a stage (when it ends at a shuffle boundary).
struct ReduceSpec {
    name: String,
    reducer: Arc<dyn Reducer>,
    combiner: Option<Arc<dyn Reducer>>,
    partitioner: Option<Arc<dyn mapreduce::Partitioner>>,
    num_reducers: usize,
}

/// One planned stage == one MapReduce job.
struct PlannedStage {
    name: String,
    input: StageInput,
    splits: Vec<InputSplit>,
    locality: Locality,
    maps: Vec<(String, Arc<dyn Mapper>)>,
    reduce: Option<ReduceSpec>,
}

/// Compact public view of one planned stage (tests, tooling).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StageSummary {
    /// Stage (and job) name.
    pub name: String,
    /// Number of logical map operators fused into the stage.
    pub fused_maps: usize,
    /// Whether the stage ends in a shuffle + reduce.
    pub has_reduce: bool,
    /// Source splits (0 when the stage reads a staged intermediate).
    pub source_splits: usize,
}

/// Statistics of one executed stage.
#[derive(Debug, Clone, Default)]
pub struct StageStats {
    /// Stage name.
    pub name: String,
    /// Logical map operators fused into the stage's single job.
    pub fused_maps: usize,
    /// The underlying job's cost/timing profile.
    pub stats: JobStats,
    /// The underlying job's merged counters.
    pub counters: Counters,
}

/// Per-run statistics of a planned pipeline: one entry per launched job
/// plus the bytes staged between jobs. Absorbed into
/// [`crate::coordinator::PhaseStats`] via `absorb_run`.
#[derive(Debug, Clone, Default)]
pub struct PlanStats {
    /// Pipeline name.
    pub pipeline: String,
    /// Per-stage stats, in launch order.
    pub stages: Vec<StageStats>,
    /// Intermediate bytes written to the DFS between jobs.
    pub staged_bytes: u64,
}

impl PlanStats {
    /// Jobs the plan launched.
    pub fn jobs(&self) -> usize {
        self.stages.len()
    }

    /// One counter summed across all stages.
    pub fn counter(&self, name: &str) -> u64 {
        self.stages.iter().map(|s| s.counters.get(name)).sum()
    }

    /// All stage counters merged (the phase-level counter set).
    pub fn merged_counters(&self) -> Counters {
        let mut c = Counters::default();
        for s in &self.stages {
            c.merge(&s.counters);
        }
        c
    }

    /// Sum of per-job virtual times.
    pub fn total_virtual_s(&self) -> f64 {
        self.stages.iter().map(|s| s.stats.virtual_time_s).sum()
    }

    /// Sum of per-job wall times.
    pub fn total_wall_s(&self) -> f64 {
        self.stages.iter().map(|s| s.stats.wall_time_s).sum()
    }

    /// Shuffle lifecycle summary across the whole run (same shape the
    /// phases report).
    pub fn shuffle_summary(&self) -> crate::metrics::ShuffleSummary {
        crate::metrics::ShuffleSummary::from_counters(&self.merged_counters())
    }
}

/// Result of running a plan: stats plus the collected sink outputs.
#[derive(Default)]
pub struct PipelineRun {
    /// Per-stage stats of the run.
    pub stats: PlanStats,
    collected: HashMap<NodeId, Vec<Vec<KV>>>,
}

impl PipelineRun {
    /// Remove and return a collected node's records, flattened across
    /// partitions and globally key-sorted (the dataflow equivalent of
    /// [`crate::mapreduce::JobResult::sorted_records`]).
    pub fn take_sorted(&mut self, node: NodeId) -> Vec<KV> {
        let mut all: Vec<KV> = self
            .collected
            .remove(&node)
            .unwrap_or_default()
            .into_iter()
            .flatten()
            .collect();
        all.sort_unstable_by(|a, b| a.0.cmp(&b.0));
        all
    }
}

/// The planner: turns a logical [`Graph`] into an executable [`Plan`].
pub struct Planner;

impl Planner {
    /// Topologically order the logical nodes and fuse map chains into
    /// stages (see module docs for the rules).
    pub(crate) fn plan(graph: Graph) -> Result<Plan> {
        let node_count = graph.nodes.len();
        let mut stages: Vec<PlannedStage> = Vec::new();
        // Stage producing each node's output, and whether it can still
        // absorb operators (no reduce yet) — `tail[s]` guards against an
        // op attaching to the middle of a fused chain.
        let mut stage_of: Vec<usize> = vec![usize::MAX; node_count];
        let mut open: Vec<bool> = Vec::new();
        let mut tail: Vec<NodeId> = Vec::new();

        for (id, node) in graph.nodes.into_iter().enumerate() {
            match node.op {
                LogicalOp::Source { splits, locality } => {
                    stages.push(PlannedStage {
                        name: String::from("source"),
                        input: StageInput::Source,
                        splits,
                        locality,
                        maps: Vec::new(),
                        reduce: None,
                    });
                    open.push(true);
                    tail.push(id);
                    stage_of[id] = stages.len() - 1;
                }
                LogicalOp::Map { name, mapper } => {
                    let p = node
                        .input
                        .ok_or_else(|| Error::MapReduce("dataflow: map without input".into()))?;
                    let s = stage_of[p];
                    if open[s] && tail[s] == p {
                        stages[s].maps.push((name, mapper));
                        tail[s] = id;
                        stage_of[id] = s;
                    } else {
                        stages.push(PlannedStage {
                            name: String::new(),
                            input: StageInput::Staged(s),
                            splits: Vec::new(),
                            locality: Locality::None,
                            maps: vec![(name, mapper)],
                            reduce: None,
                        });
                        open.push(true);
                        tail.push(id);
                        stage_of[id] = stages.len() - 1;
                    }
                }
                LogicalOp::GroupReduce {
                    name,
                    reducer,
                    combiner,
                    partitioner,
                    num_reducers,
                } => {
                    let p = node.input.ok_or_else(|| {
                        Error::MapReduce("dataflow: group_reduce without input".into())
                    })?;
                    let spec = ReduceSpec { name, reducer, combiner, partitioner, num_reducers };
                    let s = stage_of[p];
                    if open[s] && tail[s] == p {
                        stages[s].reduce = Some(spec);
                        open[s] = false;
                        tail[s] = id;
                        stage_of[id] = s;
                    } else {
                        stages.push(PlannedStage {
                            name: String::new(),
                            input: StageInput::Staged(s),
                            splits: Vec::new(),
                            locality: Locality::None,
                            maps: Vec::new(),
                            reduce: Some(spec),
                        });
                        open.push(false);
                        tail.push(id);
                        stage_of[id] = stages.len() - 1;
                    }
                }
            }
        }

        // Stage/job names: first fused map, else the reducer, else "source".
        for stage in &mut stages {
            stage.name = stage
                .maps
                .first()
                .map(|(n, _)| n.clone())
                .or_else(|| stage.reduce.as_ref().map(|r| r.name.clone()))
                .unwrap_or_else(|| "source".to_string());
        }

        let sinks = graph
            .sinks
            .into_iter()
            .map(|sink| (stage_of[sink.node], sink))
            .collect();
        Ok(Plan {
            name: graph.name,
            stages,
            sinks,
            shuffle: graph.shuffle,
        })
    }
}

/// An executable plan: the fused stages in launch order.
pub struct Plan {
    name: String,
    stages: Vec<PlannedStage>,
    sinks: Vec<(usize, Sink)>,
    shuffle: Option<ShuffleConfig>,
}

impl Plan {
    /// Number of jobs this plan will launch.
    pub fn job_count(&self) -> usize {
        self.stages.len()
    }

    /// Compact per-stage view (fusion decisions, split counts).
    pub fn stage_summaries(&self) -> Vec<StageSummary> {
        self.stages
            .iter()
            .map(|s| StageSummary {
                name: s.name.clone(),
                fused_maps: s.maps.len(),
                has_reduce: s.reduce.is_some(),
                source_splits: s.splits.len(),
            })
            .collect()
    }

    /// Human-readable rendering of the planned DAG: stages, fusion
    /// decisions and estimated shuffle bytes — what `psch run
    /// --explain-plan` prints. Estimates assume map output ≈ map input
    /// (intermediate sizes are unknowable before running).
    pub fn explain(&self) -> String {
        let mut out = format!(
            "plan {}: {} job{}\n",
            self.name,
            self.stages.len(),
            if self.stages.len() == 1 { "" } else { "s" }
        );
        // Estimated input bytes per stage, propagated stage to stage.
        let mut est: Vec<u64> = Vec::new();
        for (i, stage) in self.stages.iter().enumerate() {
            let (input_desc, input_bytes) = match stage.input {
                StageInput::Source => {
                    let bytes: u64 = stage
                        .splits
                        .iter()
                        .flatten()
                        .map(|(k, v)| (k.len() + v.len()) as u64)
                        .sum();
                    let place = match &stage.locality {
                        Locality::None => "memory".to_string(),
                        Locality::DfsRanges { path, .. } => format!("dfs:{path}"),
                        Locality::TableKeys { table, .. } => format!("table:{}", table.name),
                    };
                    (format!("{} splits from {place}", stage.splits.len()), bytes)
                }
                StageInput::Staged(s) => {
                    (format!("staged output of stage {s} (re-split via DFS)"), est[s])
                }
            };
            est.push(input_bytes);
            out.push_str(&format!("  [{i}] {} — {input_desc}\n", stage.name));
            if !stage.maps.is_empty() {
                let chain: Vec<&str> =
                    stage.maps.iter().map(|(n, _)| n.as_str()).collect();
                let fused = if stage.maps.len() > 1 {
                    format!(" ({} ops fused into one job)", stage.maps.len())
                } else {
                    String::new()
                };
                out.push_str(&format!(
                    "      map chain: {}{fused}\n",
                    chain.join(" → ")
                ));
            }
            match &stage.reduce {
                Some(r) => out.push_str(&format!(
                    "      reduce: {} ×{}{}; est. shuffle ≤ {}\n",
                    r.name,
                    r.num_reducers,
                    if r.combiner.is_some() { " (combiner)" } else { "" },
                    human_bytes(input_bytes)
                )),
                None => out.push_str("      map-only (no shuffle)\n"),
            }
            let sink_names: Vec<&str> = self
                .sinks
                .iter()
                .filter(|(s, _)| *s == i)
                .map(|(_, sink)| match &sink.kind {
                    SinkKind::Collect => "collect",
                    SinkKind::WriteDfs { path } => path.as_str(),
                })
                .collect();
            if !sink_names.is_empty() {
                out.push_str(&format!("      sinks: {}\n", sink_names.join(", ")));
            }
        }
        out
    }

    /// Execute the plan on the services: run each stage as one job, stage
    /// intermediates between jobs in the DFS, feed sinks.
    // Index-based loop: the body needs disjoint borrows of `self.stages[i]`
    // (splits are taken out) alongside the graph-level knobs.
    #[allow(clippy::needless_range_loop)]
    pub fn run(mut self, services: &Services) -> Result<PipelineRun> {
        let mut outputs: Vec<Option<Vec<Vec<KV>>>> = Vec::with_capacity(self.stages.len());
        let mut stats = PlanStats {
            pipeline: self.name.clone(),
            ..PlanStats::default()
        };
        let nstages = self.stages.len();
        for i in 0..nstages {
            let (splits, hosts) = match self.stages[i].input {
                StageInput::Source => {
                    let splits = std::mem::take(&mut self.stages[i].splits);
                    let hosts =
                        resolve_hosts(&self.stages[i].locality, services, splits.len())?;
                    (splits, hosts)
                }
                StageInput::Staged(s) => {
                    let parts = outputs[s].as_ref().ok_or_else(|| {
                        Error::MapReduce(format!(
                            "dataflow: stage {i} input (stage {s}) was not materialized"
                        ))
                    })?;
                    let (raw, framed) = encode_staged(parts);
                    let path = format!("/dataflow/{}/stage-{s}", self.name);
                    services.dfs.write_file(&path, &raw)?;
                    stats.staged_bytes += raw.len() as u64;
                    let mut splits = Vec::with_capacity(framed.len());
                    let mut hosts = Vec::with_capacity(framed.len());
                    for (split, (lo, hi)) in framed {
                        hosts.push(services.dfs.range_hosts(&path, lo, hi)?);
                        splits.push(split);
                    }
                    (splits, hosts)
                }
            };

            let stage = &self.stages[i];
            let mapper: Arc<dyn Mapper> = match stage.maps.len() {
                0 => Arc::new(IdentityMapper),
                1 => stage.maps[0].1.clone(),
                _ => Arc::new(FusedMapper {
                    mappers: stage.maps.iter().map(|(_, m)| m.clone()).collect(),
                }),
            };
            let job_name = format!("{}:{}", self.name, stage.name);
            let mut builder =
                JobBuilder::new(&job_name, splits, mapper).split_hosts(hosts);
            if let Some(r) = &stage.reduce {
                builder = builder.reducer(r.reducer.clone(), r.num_reducers);
                if let Some(c) = &r.combiner {
                    builder = builder.combiner(c.clone());
                }
                if let Some(p) = &r.partitioner {
                    builder = builder.partitioner(p.clone());
                }
            }
            if let Some(cfg) = self.shuffle {
                builder = builder.shuffle_config(cfg);
            }

            let result = mapreduce::run(&services.cluster, &builder.build())?;
            stats.stages.push(StageStats {
                name: stage.name.clone(),
                fused_maps: stage.maps.len(),
                stats: result.stats,
                counters: result.counters,
            });
            outputs.push(Some(result.output));
        }

        let mut collected = HashMap::new();
        for (stage_idx, sink) in &self.sinks {
            match &sink.kind {
                SinkKind::Collect => {
                    if let Some(out) = outputs[*stage_idx].take() {
                        collected.insert(sink.node, out);
                    }
                }
                SinkKind::WriteDfs { path } => {
                    if let Some(parts) = outputs[*stage_idx].as_ref() {
                        let raw = encode_staged_raw(parts);
                        services.dfs.write_file(path, &raw)?;
                    }
                }
            }
        }
        Ok(PipelineRun { stats, collected })
    }
}

/// Resolve a source's locality spec into per-split preferred hosts.
fn resolve_hosts(
    locality: &Locality,
    services: &Services,
    nsplits: usize,
) -> Result<Vec<Vec<usize>>> {
    match locality {
        Locality::None => Ok(Vec::new()),
        Locality::DfsRanges { path, ranges } => {
            if ranges.len() != nsplits {
                return Err(Error::MapReduce(format!(
                    "dataflow: {} locality ranges for {nsplits} splits",
                    ranges.len()
                )));
            }
            let mut hosts = Vec::with_capacity(ranges.len());
            for split_ranges in ranges {
                let mut h = Vec::new();
                for &(lo, hi) in split_ranges {
                    h.extend(services.dfs.range_hosts(path, lo, hi)?);
                }
                h.sort_unstable();
                h.dedup();
                hosts.push(h);
            }
            Ok(hosts)
        }
        Locality::TableKeys { table, keys } => {
            if keys.len() != nsplits {
                return Err(Error::MapReduce(format!(
                    "dataflow: {} locality keys for {nsplits} splits",
                    keys.len()
                )));
            }
            Ok(keys
                .iter()
                .map(|k| match table.key_slave(k) {
                    Ok(slave) => vec![slave],
                    Err(_) => Vec::new(),
                })
                .collect())
        }
    }
}

/// Append one varint-framed record.
fn write_frame(raw: &mut Vec<u8>, k: &[u8], v: &[u8]) {
    write_varint(k.len() as u64, raw);
    raw.extend_from_slice(k);
    write_varint(v.len() as u64, raw);
    raw.extend_from_slice(v);
}

/// Serialize records into the staged/`write_dfs` encoding without the
/// split chunking (sinks only need the bytes — no record clones).
pub(crate) fn encode_staged_raw(parts: &[Vec<KV>]) -> Vec<u8> {
    let mut raw = Vec::new();
    for (k, v) in parts.iter().flatten() {
        write_frame(&mut raw, k, v);
    }
    raw
}

/// Frame records into the staged-intermediate encoding (varint-length
/// key/value pairs) and chunk them into splits of
/// [`STAGED_RECORDS_PER_SPLIT`], tracking each split's byte range for
/// locality resolution.
pub(crate) fn encode_staged(
    parts: &[Vec<KV>],
) -> (Vec<u8>, Vec<(InputSplit, (usize, usize))>) {
    let mut raw = Vec::new();
    let mut framed = Vec::new();
    let mut current: InputSplit = Vec::new();
    let mut start = 0usize;
    for (k, v) in parts.iter().flatten() {
        write_frame(&mut raw, k, v);
        current.push((k.clone(), v.clone()));
        if current.len() == STAGED_RECORDS_PER_SPLIT {
            framed.push((std::mem::take(&mut current), (start, raw.len())));
            start = raw.len();
        }
    }
    if !current.is_empty() {
        framed.push((current, (start, raw.len())));
    }
    (raw, framed)
}

/// Read one varint, rejecting a buffer that ends mid-varint.
fn read_varint_checked(b: &[u8]) -> Result<(u64, usize)> {
    let (value, used) = read_varint(b);
    if used == 0 || b[used - 1] & 0x80 != 0 {
        return Err(Error::MapReduce("staged records: truncated varint".into()));
    }
    Ok((value, used))
}

/// Decode a staged-intermediate file (also the `write_dfs` sink format)
/// back into records. Rejects truncated or non-staged input instead of
/// panicking.
pub fn decode_staged(bytes: &[u8]) -> Result<Vec<KV>> {
    let mut b = bytes;
    let mut out = Vec::new();
    while !b.is_empty() {
        let (klen, used) = read_varint_checked(b)?;
        b = &b[used..];
        let klen = klen as usize;
        if klen > b.len() {
            return Err(Error::MapReduce("staged records: truncated key".into()));
        }
        let k = b[..klen].to_vec();
        b = &b[klen..];
        let (vlen, used) = read_varint_checked(b)?;
        b = &b[used..];
        let vlen = vlen as usize;
        if vlen > b.len() {
            return Err(Error::MapReduce("staged records: truncated value".into()));
        }
        let v = b[..vlen].to_vec();
        b = &b[vlen..];
        out.push((k, v));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapreduce::FnMapper;
    use crate::mapreduce::TaskContext;

    fn noop_map() -> Arc<dyn Mapper> {
        Arc::new(FnMapper(|_: &[u8], _: &[u8], _: &mut TaskContext| Ok(())))
    }

    fn noop_reduce() -> Arc<dyn Reducer> {
        Arc::new(crate::mapreduce::FnReducer(
            |_: &[u8], _: &mut dyn mapreduce::Values, _: &mut TaskContext| Ok(()),
        ))
    }

    fn source(g: &mut Graph) -> NodeId {
        g.add(
            None,
            LogicalOp::Source {
                splits: vec![vec![(vec![1], vec![2])]],
                locality: Locality::None,
            },
        )
    }

    fn map(g: &mut Graph, input: NodeId, name: &str) -> NodeId {
        g.add(
            Some(input),
            LogicalOp::Map { name: name.into(), mapper: noop_map() },
        )
    }

    fn group(g: &mut Graph, input: NodeId, name: &str) -> NodeId {
        g.add(
            Some(input),
            LogicalOp::GroupReduce {
                name: name.into(),
                reducer: noop_reduce(),
                combiner: None,
                partitioner: None,
                num_reducers: 2,
            },
        )
    }

    #[test]
    fn map_chains_fuse_into_one_stage() {
        let mut g = Graph::new("t");
        let s = source(&mut g);
        let m1 = map(&mut g, s, "a");
        let m2 = map(&mut g, m1, "b");
        let r = group(&mut g, m2, "c");
        let _ = r;
        let plan = Planner::plan(g).unwrap();
        assert_eq!(plan.job_count(), 1, "map→map→group is one job");
        let summaries = plan.stage_summaries();
        assert_eq!(summaries[0].fused_maps, 2);
        assert!(summaries[0].has_reduce);
        assert_eq!(summaries[0].name, "a");
    }

    #[test]
    fn shuffle_is_a_stage_boundary() {
        let mut g = Graph::new("t");
        let s = source(&mut g);
        let m1 = map(&mut g, s, "a");
        let r1 = group(&mut g, m1, "c1");
        let m2 = map(&mut g, r1, "d");
        let r2 = group(&mut g, m2, "c2");
        let _ = r2;
        let plan = Planner::plan(g).unwrap();
        assert_eq!(plan.job_count(), 2, "two shuffles = two jobs");
        let summaries = plan.stage_summaries();
        assert_eq!(summaries[0].fused_maps, 1);
        assert!(summaries[0].has_reduce);
        assert_eq!(summaries[1].fused_maps, 1);
        assert!(summaries[1].has_reduce);
        assert_eq!(summaries[1].source_splits, 0, "reads staged intermediate");
    }

    #[test]
    fn back_to_back_reduces_get_identity_map_stage() {
        let mut g = Graph::new("t");
        let s = source(&mut g);
        let r1 = group(&mut g, s, "c1");
        let r2 = group(&mut g, r1, "c2");
        let _ = r2;
        let plan = Planner::plan(g).unwrap();
        assert_eq!(plan.job_count(), 2);
        assert_eq!(plan.stage_summaries()[1].fused_maps, 0, "identity map side");
    }

    #[test]
    fn explain_names_stages_and_fusion() {
        let mut g = Graph::new("demo");
        let s = source(&mut g);
        let m1 = map(&mut g, s, "tokenize");
        let m2 = map(&mut g, m1, "normalize");
        let r = group(&mut g, m2, "count");
        g.sinks.push(Sink { node: r, kind: SinkKind::Collect });
        let plan = Planner::plan(g).unwrap();
        let text = plan.explain();
        assert!(text.contains("plan demo: 1 job"), "{text}");
        assert!(text.contains("tokenize → normalize"), "{text}");
        assert!(text.contains("2 ops fused"), "{text}");
        assert!(text.contains("reduce: count ×2"), "{text}");
        assert!(text.contains("collect"), "{text}");
    }

    #[test]
    fn staged_encoding_roundtrips_and_chunks() {
        let records: Vec<KV> = (0..2500u64)
            .map(|i| (i.to_be_bytes().to_vec(), vec![(i % 251) as u8]))
            .collect();
        let parts = vec![records.clone()];
        let (raw, framed) = encode_staged(&parts);
        assert_eq!(decode_staged(&raw).unwrap(), records);
        assert_eq!(encode_staged_raw(&parts), raw, "sink encoding matches");
        assert_eq!(framed.len(), 3, "2500 records at 1024/split");
        let total: usize = framed.iter().map(|(s, _)| s.len()).sum();
        assert_eq!(total, 2500);
        // Byte ranges tile the file exactly.
        let mut cursor = 0usize;
        for (_, (lo, hi)) in &framed {
            assert_eq!(*lo, cursor);
            assert!(hi > lo);
            cursor = *hi;
        }
        assert_eq!(cursor, raw.len());
    }

    #[test]
    fn empty_staged_output_is_empty() {
        let (raw, framed) = encode_staged(&[]);
        assert!(raw.is_empty());
        assert!(framed.is_empty());
        assert!(decode_staged(&raw).unwrap().is_empty());
    }

    #[test]
    fn decode_staged_rejects_malformed_input() {
        // Length prefix pointing past the buffer.
        assert!(decode_staged(&[5, 1, 2]).is_err(), "truncated key");
        // Buffer ending mid-varint (continuation bit set on last byte).
        assert!(decode_staged(&[0x80]).is_err(), "truncated varint");
        // Key fine, value length truncated.
        let mut bad = Vec::new();
        write_varint(1, &mut bad);
        bad.push(7);
        write_varint(9, &mut bad);
        bad.push(1);
        assert!(decode_staged(&bad).is_err(), "truncated value");
    }
}
