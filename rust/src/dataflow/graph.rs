//! The logical dataflow graph the typed API builds and the planner consumes.
//!
//! Nodes are append-only and each node's input has a smaller id than the
//! node itself, so node-id order IS a topological order — the planner
//! walks it directly. Because [`super::Dataset`] handles are consumed by
//! value, every node has at most one downstream consumer and the graph is
//! a forest of chains (multiple independent source→sink chains may coexist
//! in one pipeline).

use std::sync::Arc;

use crate::error::Result;
use crate::mapreduce::names;
use crate::mapreduce::{
    InputSplit, Mapper, Partitioner, Reducer, ShuffleConfig, TaskContext,
};
use crate::table::Table;

/// Logical node id (index into [`Graph::nodes`]).
pub type NodeId = usize;

/// Where a source's map splits physically live — resolved to preferred
/// hosts ([`crate::mapreduce::Job::split_hosts`]) at `run(&Services)` time,
/// so pipelines can be constructed and explained without touching services.
pub enum Locality {
    /// No placement preference.
    None,
    /// Each split covers the given byte ranges of a DFS file; its hosts are
    /// the union of the replica nodes of the overlapping blocks.
    DfsRanges {
        /// DFS path of the staged input file.
        path: String,
        /// Per-split byte ranges (a split may cover several disjoint
        /// ranges, e.g. the paper's paired row blocks).
        ranges: Vec<Vec<(usize, usize)>>,
    },
    /// Each split is anchored at a table row key; its host is the slave
    /// serving the region that owns the key (HBase co-location).
    TableKeys {
        /// The table whose regions provide locality.
        table: Arc<Table>,
        /// One anchor key per split.
        keys: Vec<Vec<u8>>,
    },
}

/// One logical operator.
pub(crate) enum LogicalOp {
    /// Input splits + their locality.
    Source {
        splits: Vec<InputSplit>,
        locality: Locality,
    },
    /// A record-at-a-time transform (fusable).
    Map {
        name: String,
        mapper: Arc<dyn Mapper>,
    },
    /// Shuffle boundary: group by key and reduce each group.
    GroupReduce {
        name: String,
        reducer: Arc<dyn Reducer>,
        combiner: Option<Arc<dyn Reducer>>,
        partitioner: Option<Arc<dyn Partitioner>>,
        num_reducers: usize,
    },
}

/// One logical node: an operator plus its (single) upstream input.
pub(crate) struct LogicalNode {
    pub input: Option<NodeId>,
    pub op: LogicalOp,
}

/// What happens to a materialized node output.
pub(crate) enum SinkKind {
    /// Keep the records for [`super::PipelineRun`] retrieval.
    Collect,
    /// Write the records to a DFS file (varint-framed, see
    /// [`super::planner::encode_staged`]).
    WriteDfs { path: String },
}

/// A sink attached to a node's output.
pub(crate) struct Sink {
    pub node: NodeId,
    pub kind: SinkKind,
}

/// The whole logical pipeline.
pub(crate) struct Graph {
    pub name: String,
    pub nodes: Vec<LogicalNode>,
    pub sinks: Vec<Sink>,
    /// Per-pipeline shuffle override (applies to every planned job).
    /// Failure handling is cluster-wide ([`crate::cluster::faults`]), so
    /// pipelines carry no fault knobs.
    pub shuffle: Option<ShuffleConfig>,
}

impl Graph {
    pub fn new(name: &str) -> Self {
        Self {
            name: name.to_string(),
            nodes: Vec::new(),
            sinks: Vec::new(),
            shuffle: None,
        }
    }

    /// Append a node; returns its id.
    pub fn add(&mut self, input: Option<NodeId>, op: LogicalOp) -> NodeId {
        let id = self.nodes.len();
        self.nodes.push(LogicalNode { input, op });
        id
    }
}

/// Pass-through mapper for stages that begin at a shuffle boundary with no
/// map work of their own (a `group_reduce` directly after another one).
pub(crate) struct IdentityMapper;

impl Mapper for IdentityMapper {
    fn map(&self, key: &[u8], value: &[u8], ctx: &mut TaskContext) -> Result<()> {
        ctx.emit(key.to_vec(), value.to_vec());
        Ok(())
    }
}

/// The `write_table` sink as a fusable map stage: puts every record into
/// the table, charges the write like the hand-wired jobs did
/// (`EXTRA_OUTPUT_BYTES` = payload bytes), and emits nothing — a terminal
/// map-only stage produces an empty job output, exactly like the old
/// table-writing mappers.
pub(crate) struct TablePutMapper {
    pub table: Arc<Table>,
}

impl Mapper for TablePutMapper {
    fn map(&self, key: &[u8], value: &[u8], ctx: &mut TaskContext) -> Result<()> {
        ctx.incr(names::EXTRA_OUTPUT_BYTES, value.len() as u64);
        self.table.put(key.to_vec(), value.to_vec())
    }
}

/// Runs a fused chain of map operators as one engine mapper: records
/// emitted by operator `i` are fed to operator `i + 1`; the final
/// operator's emits (and every operator's counters) land in the real task
/// context. This is what lets a `map → map → group` pipeline run as ONE
/// MapReduce job.
pub(crate) struct FusedMapper {
    pub mappers: Vec<Arc<dyn Mapper>>,
}

impl Mapper for FusedMapper {
    fn map(&self, key: &[u8], value: &[u8], ctx: &mut TaskContext) -> Result<()> {
        // The planner only builds a FusedMapper for chains of >= 2 maps
        // (0 maps → IdentityMapper, 1 → the mapper itself).
        debug_assert!(self.mappers.len() >= 2, "FusedMapper wants a fused chain");
        let n = self.mappers.len();
        let mut current: Vec<(Vec<u8>, Vec<u8>)> = vec![(key.to_vec(), value.to_vec())];
        for (i, m) in self.mappers.iter().enumerate() {
            if i + 1 == n {
                for (k, v) in &current {
                    m.map(k, v, ctx)?;
                }
            } else {
                let mut sub = TaskContext::default();
                for (k, v) in &current {
                    m.map(k, v, &mut sub)?;
                }
                let (emits, counters) = sub.into_parts();
                ctx.merge_counters(&counters);
                current = emits;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapreduce::FnMapper;

    #[test]
    fn graph_ids_are_topological() {
        let mut g = Graph::new("t");
        let s = g.add(
            None,
            LogicalOp::Source { splits: vec![], locality: Locality::None },
        );
        let m = g.add(
            Some(s),
            LogicalOp::Map {
                name: "m".into(),
                mapper: Arc::new(IdentityMapper),
            },
        );
        assert_eq!(s, 0);
        assert_eq!(m, 1);
        assert_eq!(g.nodes[m].input, Some(s));
    }

    #[test]
    fn fused_mapper_cascades_records_and_counters() {
        // map1: word -> (word, 1) per char; map2: uppercase keys.
        let m1 = Arc::new(FnMapper(|_k: &[u8], v: &[u8], ctx: &mut TaskContext| {
            for &b in v {
                ctx.emit(vec![b], vec![1]);
                ctx.incr("M1", 1);
            }
            Ok(())
        }));
        let m2 = Arc::new(FnMapper(|k: &[u8], v: &[u8], ctx: &mut TaskContext| {
            ctx.emit(k.to_ascii_uppercase(), v.to_vec());
            ctx.incr("M2", 1);
            Ok(())
        }));
        let fused = FusedMapper { mappers: vec![m1, m2] };
        let mut ctx = TaskContext::default();
        fused.map(&[], b"ab", &mut ctx).unwrap();
        let (emits, counters) = ctx.into_parts();
        assert_eq!(
            emits,
            vec![(b"A".to_vec(), vec![1]), (b"B".to_vec(), vec![1])]
        );
        assert_eq!(counters.get("M1"), 2);
        assert_eq!(counters.get("M2"), 2);
    }

    #[test]
    fn identity_mapper_passes_through() {
        let mut ctx = TaskContext::default();
        IdentityMapper.map(&[1], &[2], &mut ctx).unwrap();
        assert_eq!(ctx.emitted(), &[(vec![1], vec![2])]);
    }
}
