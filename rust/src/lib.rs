//! # psch — Parallel Spectral Clustering on a Hadoop-like runtime
//!
//! A from-scratch reproduction of *"Parallel Spectral Clustering Algorithm
//! Based on Hadoop"* (Zhao et al., 2015) as a three-layer Rust + JAX/Pallas
//! system:
//!
//! - **Layer 3 (this crate)**: the coordinator — a mini-HDFS ([`dfs`]) with
//!   rack-aware replica placement, a mini-HBase ([`table`]), a MapReduce
//!   engine ([`mapreduce`]), a JobTracker-style locality- and
//!   straggler-aware task scheduler ([`scheduler`]: racks, heartbeats,
//!   delay scheduling, live speculative execution), a simulated cluster
//!   with a network cost model ([`cluster`]), a typed dataflow layer with
//!   a map-fusing DAG planner over the engine ([`dataflow`]:
//!   `Pipeline`/`Dataset<K, V>`), a t-NN sparse-similarity subsystem
//!   ([`knn`]: kd-tree index, bounded neighbor heaps, distributed
//!   max-symmetrization), a virtual-clock tracer with Perfetto export and
//!   critical-path/straggler analysis ([`trace`]), the paper's three
//!   parallel phases ([`coordinator`]) expressed as pipelines, and an
//!   online serving layer ([`serving`]: persisted model artifacts +
//!   Nyström out-of-sample assignment with mini-batch refresh).
//! - **Layer 2**: JAX compute graphs (`python/compile/model.py`), AOT-lowered
//!   to HLO text artifacts loaded by [`runtime`] via XLA PJRT.
//! - **Layer 1**: Pallas kernels (`python/compile/kernels/`) for the per-task
//!   hot spots (RBF similarity tile, mat-vec block, k-means step).
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured record.

pub mod benchutil;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dataflow;
pub mod dfs;
pub mod error;
pub mod eval;
pub mod kmeans;
pub mod knn;
pub mod linalg;
pub mod mapreduce;
pub mod metrics;
pub mod runtime;
pub mod scheduler;
pub mod serving;
pub mod spectral;
pub mod table;
pub mod telemetry;
pub mod testutil;
pub mod trace;
pub mod util;

pub use error::{Error, Result};
