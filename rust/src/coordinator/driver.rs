//! End-to-end pipeline driver: phases 1 → 2 → 3 with per-phase reporting.

use std::sync::Arc;

use crate::config::{Config, SigmaSpec};
use crate::data::Topology;
use crate::error::Result;
use crate::runtime::KernelRuntime;

use super::{
    eigen, kmeans_job, lanczos_job, similarity_job, PhaseStats, Services,
};

/// What the pipeline clusters.
pub enum PipelineInput {
    /// Point-set mode: phase 1 computes RBF similarities (Alg. 4.2).
    Points {
        /// n points, each of dimension d.
        points: Vec<Vec<f64>>,
    },
    /// Graph mode (paper Ch. 5): edge weights ARE the similarities.
    Graph {
        /// The Fig. 4 topology.
        topology: Topology,
    },
}

/// Pipeline result: labels + the paper's per-phase times.
pub struct PipelineResult {
    /// Cluster label per point/vertex.
    pub labels: Vec<usize>,
    /// k smallest Laplacian eigenvalues.
    pub eigenvalues: Vec<f64>,
    /// Phase stats: [similarity, eigenvectors, kmeans] for full pipeline
    /// runs (Table 5-1 columns); a single "serving" entry for assign runs.
    pub phases: Vec<PhaseStats>,
    /// Stored similarity entries.
    pub nnz: u64,
    /// Sum of phase virtual seconds (Table 5-1 "Total Time").
    pub total_virtual_s: f64,
    /// Sum of phase wall seconds.
    pub total_wall_s: f64,
    /// The RBF bandwidth phase 1 actually used (`sigma = "auto"` already
    /// resolved; echoed as `totals.sigma_resolved` in the RunReport).
    pub sigma: f64,
    /// Final k-means centers in embedding space (k × k) — the serving
    /// layer's centroid capture.
    pub centers: Vec<Vec<f64>>,
    /// Row-normalized spectral embedding (n × k row-major) — the serving
    /// layer's landmark-row capture.
    pub embedding: Vec<f32>,
}

impl PipelineResult {
    fn totals(phases: &[PhaseStats]) -> (f64, f64) {
        (
            phases.iter().map(|p| p.virtual_s).sum(),
            phases.iter().map(|p| p.wall_s).sum(),
        )
    }
}

/// Graph-topology input carries its similarities on the edges — there are
/// no point coordinates for a spatial index to prune, so a `tnn` request
/// is a configuration error, not something to silently ignore.
fn reject_tnn_for_graph_input(mode: crate::knn::GraphMode) -> Result<()> {
    if mode == crate::knn::GraphMode::Tnn {
        return Err(crate::error::Error::Config(
            "algo.graph = \"tnn\" needs point input: a graph topology's edge \
             weights ARE the similarities (drop --graph tnn or use --blobs)"
                .into(),
        ));
    }
    Ok(())
}

/// The pipeline driver (the paper's "leader" / job-submitting client).
pub struct Driver {
    config: Config,
    runtime: Arc<KernelRuntime>,
}

/// Resolve `algo.sigma` against the input: a fixed value passes through;
/// `"auto"` measures the mean t-th-neighbor distance over the points (per
/// 1802.04450, via [`crate::knn::auto_sigma`]). A graph topology has no
/// coordinates to measure, so `auto` there is a configuration error —
/// mirroring [`reject_tnn_for_graph_input`].
pub fn resolve_sigma(
    spec: SigmaSpec,
    knn: &crate::knn::KnnConfig,
    input: &PipelineInput,
) -> Result<f64> {
    match (spec, input) {
        (SigmaSpec::Fixed(v), _) => Ok(v),
        (SigmaSpec::Auto, PipelineInput::Points { points }) => {
            if points.is_empty() {
                return Err(crate::error::Error::Cli(
                    "sigma auto: empty point set — nothing to measure".into(),
                ));
            }
            let n = points.len();
            let d = points[0].len();
            let flat: Arc<Vec<f64>> =
                Arc::new(points.iter().flatten().copied().collect());
            crate::knn::auto_sigma(flat, n, d, knn)
        }
        (SigmaSpec::Auto, PipelineInput::Graph { .. }) => {
            Err(crate::error::Error::Config(
                "algo.sigma = \"auto\" needs point input: a graph topology's \
                 edge weights carry no coordinates to measure neighbor \
                 distances on (set a numeric sigma or use --blobs)"
                    .into(),
            ))
        }
    }
}

impl Driver {
    /// Driver with the given config and kernel runtime.
    pub fn new(config: Config, runtime: Arc<KernelRuntime>) -> Self {
        Self { config, runtime }
    }

    /// The active config.
    pub fn config(&self) -> &Config {
        &self.config
    }

    /// Stand up fresh services (cluster, DFS, tables) for one run, wiring
    /// the configured rack topology and JobTracker knobs into the cluster
    /// (delegates to the shared [`Services::from_config`] constructor).
    pub fn services(&self) -> Services {
        Services::from_config(&self.config, self.runtime.clone())
    }

    /// Render the planned dataflow DAG of every phase — stages, fusion
    /// decisions, estimated shuffle bytes — **without running any job**
    /// (the `psch run --explain-plan` output).
    ///
    /// Phase 1's plan is exact for the given input. Phases 2 and 3 depend
    /// on phase 1's output, so their plans are built against surrogate
    /// operands of the right shape (empty S/L tables, unit degrees, zero
    /// embedding): the stage structure, fusion and split counts are what
    /// the real run launches, repeated once per Lanczos step / k-means
    /// iteration.
    pub fn explain_plan(&self, input: &PipelineInput) -> Result<String> {
        let a = &self.config.algo;
        let sigma = resolve_sigma(a.sigma, &self.config.knn, input)?;
        let mut out = String::new();

        // ---- Phase 1: exact plan ----
        out.push_str(&format!(
            "== phase 1: similarity (graph mode: {}) ==\n",
            a.graph.as_str()
        ));
        let svc1 = self.services();
        let n = match input {
            PipelineInput::Points { points } => {
                if points.is_empty() {
                    return Err(crate::error::Error::Cli(
                        "explain-plan: empty point set — nothing to plan".into(),
                    ));
                }
                let n = points.len();
                let d = points[0].len();
                let pipeline = match a.graph {
                    crate::knn::GraphMode::Epsilon => {
                        let flat: Vec<f32> =
                            points.iter().flatten().map(|&x| x as f32).collect();
                        similarity_job::points_pipeline(
                            &svc1,
                            Arc::new(flat),
                            n,
                            d,
                            sigma,
                            a.epsilon,
                            "S",
                        )?
                        .0
                    }
                    crate::knn::GraphMode::Tnn => {
                        let flat: Vec<f64> = points.iter().flatten().copied().collect();
                        crate::knn::job::tnn_pipeline(
                            &svc1,
                            Arc::new(flat),
                            n,
                            d,
                            sigma,
                            "S",
                        )?
                        .0
                    }
                };
                out.push_str(&pipeline.plan()?.explain());
                n
            }
            PipelineInput::Graph { topology } => {
                reject_tnn_for_graph_input(self.config.algo.graph)?;
                let (pipeline, _degrees) =
                    similarity_job::graph_pipeline(&svc1, topology, "S")?;
                out.push_str(&pipeline.plan()?.explain());
                topology.num_vertices()
            }
        };

        // ---- Phase 2: representative plans (selected backend) ----
        let solver = eigen::solver_for(&self.config.eigen, a);
        out.push_str(&format!(
            "== phase 2: eigenvectors (solver: {}) ==\n",
            solver.name()
        ));
        let svc2 = self.services();
        solver.explain(&svc2, n, a.k, &mut out)?;

        // ---- Phase 3: representative plans ----
        out.push_str("== phase 3: kmeans ==\n");
        let svc3 = self.services();
        let emb: Arc<Vec<f32>> = Arc::new(vec![0.0; n * a.k]);
        let ranges = kmeans_job::stage_embedding(&svc3, &emb, n, a.k)?;
        let (pipeline, _centers) = kmeans_job::update_pipeline(
            &svc3,
            &emb,
            n,
            a.k,
            a.k,
            "/kmeans/centers",
            &ranges,
        );
        out.push_str(&pipeline.plan()?.explain());
        out.push_str(&format!(
            "  (update launched once per k-means iteration, ≤{} times)\n",
            a.kmeans_iters
        ));
        let (pipeline, _labels) = kmeans_job::assign_pipeline(
            &svc3,
            &emb,
            n,
            a.k,
            "/kmeans/centers",
            &ranges,
        );
        out.push_str(&pipeline.plan()?.explain());
        Ok(out)
    }

    /// Run the full three-phase pipeline.
    pub fn run(&self, input: &PipelineInput) -> Result<PipelineResult> {
        let services = self.services();
        self.run_on(&services, input)
    }

    /// Run on existing services (tests inject faults through these).
    pub fn run_on(
        &self,
        services: &Services,
        input: &PipelineInput,
    ) -> Result<PipelineResult> {
        let a = &self.config.algo;
        let tracer = services.cluster.trace().clone();

        // Resolve sigma before phase 1 (auto = mean t-th-neighbor distance
        // on the master); the measurement is charged to phase 1 below like
        // other master-side compute.
        let t_sigma = std::time::Instant::now();
        let sigma = resolve_sigma(a.sigma, &self.config.knn, input)?;
        let sigma_wall_s = t_sigma.elapsed().as_secs_f64();

        // ---- Phase 1: similarity matrix + degrees ----
        tracer.begin_phase("similarity");
        let (sim, n) = match input {
            PipelineInput::Points { points } => {
                if points.is_empty() {
                    return Err(crate::error::Error::Cli(
                        "run: empty point set — nothing to cluster".into(),
                    ));
                }
                let n = points.len();
                let d = points[0].len();
                let sim = match self.config.algo.graph {
                    crate::knn::GraphMode::Epsilon => {
                        let flat: Vec<f32> =
                            points.iter().flatten().map(|&x| x as f32).collect();
                        similarity_job::run_similarity_phase(
                            services,
                            Arc::new(flat),
                            n,
                            d,
                            sigma,
                            a.epsilon,
                            "S",
                        )?
                    }
                    // t-NN mode: the graph is born sparse — the spatial
                    // index prunes pairs instead of epsilon post-filtering.
                    crate::knn::GraphMode::Tnn => {
                        let flat: Vec<f64> =
                            points.iter().flatten().copied().collect();
                        crate::knn::run_tnn_phase(
                            services,
                            Arc::new(flat),
                            n,
                            d,
                            sigma,
                            "S",
                        )?
                    }
                };
                (sim, n)
            }
            PipelineInput::Graph { topology } => {
                reject_tnn_for_graph_input(self.config.algo.graph)?;
                (
                    similarity_job::run_similarity_phase_graph(services, topology, "S")?,
                    topology.num_vertices(),
                )
            }
        };

        // ---- Phase 2: k smallest eigenvectors (selected backend) ----
        tracer.begin_phase("eigenvectors");
        let s_table = lanczos_job::open_similarity_table(services, "S")?;
        // The services carry the eigen config so tests that inject services
        // pick the backend per-run (like the knn config).
        let solver = eigen::solver_for(&services.eigen, a);
        let eig = solver.run(services, &s_table, Arc::new(sim.degrees.clone()), n, a.k)?;

        // ---- Phase 3: parallel k-means on the embedding ----
        tracer.begin_phase("kmeans");
        let km = kmeans_job::run_kmeans_phase(
            services,
            Arc::new(eig.embedding.clone()),
            n,
            a.k,
            a.k,
            a.kmeans_iters,
            a.kmeans_tol,
            a.seed,
        )?;

        tracer.end_phase();

        let mut phases = vec![sim.stats, eig.stats, km.stats];
        phases[0]
            .absorb_master(sigma_wall_s, services.cluster.model().compute_scale);
        let (total_virtual_s, total_wall_s) = PipelineResult::totals(&phases);
        Ok(PipelineResult {
            labels: km.labels,
            eigenvalues: eig.eigenvalues,
            phases,
            nnz: sim.nnz,
            total_virtual_s,
            total_wall_s,
            sigma,
            centers: km.centers,
            embedding: eig.embedding,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gaussian_blobs, planted_graph};
    use crate::eval::nmi;

    fn driver(m: usize) -> Driver {
        let mut cfg = Config::default();
        cfg.cluster.slaves = m;
        Driver::new(cfg, Arc::new(KernelRuntime::native()))
    }

    #[test]
    fn end_to_end_points_mode_recovers_blobs() {
        let ps = gaussian_blobs(300, 4, 4, 0.3, 10.0, 3);
        let mut d = driver(3);
        d.config.algo.k = 4;
        d.config.algo.sigma = 1.5.into();
        let r = d
            .run(&PipelineInput::Points { points: ps.points.clone() })
            .unwrap();
        let score = nmi(&ps.labels, &r.labels);
        assert!(score > 0.95, "points-mode nmi={score}");
        assert!(r.eigenvalues[0].abs() < 1e-6);
        assert_eq!(r.phases.len(), 3);
        assert!(r.total_virtual_s > 0.0);
    }

    #[test]
    fn end_to_end_graph_mode_recovers_communities() {
        let topo = planted_graph(240, 720, 4, 0.02, 11);
        let mut d = driver(2);
        d.config.algo.k = 4;
        d.config.algo.lanczos_steps = 80;
        let r = d
            .run(&PipelineInput::Graph { topology: topo.clone() })
            .unwrap();
        let score = nmi(&topo.labels(), &r.labels);
        assert!(score > 0.8, "graph-mode nmi={score}");
    }

    #[test]
    fn matches_single_machine_baseline() {
        let ps = gaussian_blobs(200, 3, 4, 0.3, 10.0, 5);
        let mut d = driver(2);
        d.config.algo.k = 3;
        d.config.algo.sigma = 1.5.into();
        let parallel = d
            .run(&PipelineInput::Points { points: ps.points.clone() })
            .unwrap();
        let baseline = crate::spectral::spectral_cluster_points(
            &ps.points,
            &crate::spectral::SpectralParams {
                k: 3,
                sigma: 1.5,
                ..Default::default()
            },
            crate::spectral::Eigensolver::Lanczos,
        )
        .unwrap();
        // Same partition up to label names.
        let agreement = nmi(&baseline.labels, &parallel.labels);
        assert!(agreement > 0.95, "parallel vs baseline nmi={agreement}");
    }

    #[test]
    fn explain_plan_renders_every_phase_without_running() {
        let ps = gaussian_blobs(200, 3, 4, 0.3, 10.0, 3);
        let mut d = driver(2);
        d.config.algo.k = 3;
        let text = d
            .explain_plan(&PipelineInput::Points { points: ps.points.clone() })
            .unwrap();
        assert!(text.contains("phase 1: similarity"), "{text}");
        assert!(text.contains("plan similarity: 1 job"), "{text}");
        assert!(text.contains("plan laplacian"), "{text}");
        assert!(text.contains("2 ops fused"), "laplacian fusion: {text}");
        assert!(text.contains("lanczos-matvec"), "{text}");
        assert!(text.contains("kmeans-update"), "{text}");
        assert!(text.contains("kmeans-assign"), "{text}");
    }

    #[test]
    fn chebdav_backend_runs_end_to_end_and_plans() {
        let ps = gaussian_blobs(300, 4, 4, 0.3, 10.0, 3);
        let mut d = driver(3);
        d.config.algo.k = 4;
        d.config.algo.sigma = 1.5.into();
        d.config.eigen.solver = crate::coordinator::eigen::EigenSolverKind::ChebDav;
        let input = PipelineInput::Points { points: ps.points.clone() };
        let text = d.explain_plan(&input).unwrap();
        assert!(text.contains("solver: chebdav"), "{text}");
        assert!(text.contains("chebdav-block-matvec"), "{text}");
        assert!(text.contains("columns per job"), "{text}");
        assert!(!text.contains("lanczos-matvec"), "{text}");
        let r = d.run(&input).unwrap();
        let score = nmi(&ps.labels, &r.labels);
        assert!(score > 0.95, "chebdav nmi={score}");
        assert!(r.eigenvalues[0].abs() < 1e-6);
        let es = r.phases[1].eigen_summary();
        assert!(es.any(), "eigen counters must flow");
        assert_eq!(es.filter_degree, d.config.eigen.filter_degree as u64);
        assert!(
            es.matvecs_batched > es.eigen_jobs,
            "batching must price more than one mat-vec per job \
             ({} matvecs over {} jobs)",
            es.matvecs_batched,
            es.eigen_jobs
        );
    }

    #[test]
    fn tnn_graph_mode_runs_end_to_end_and_plans() {
        let ps = gaussian_blobs(240, 3, 4, 0.3, 10.0, 3);
        let mut d = driver(3);
        d.config.algo.k = 3;
        d.config.algo.sigma = 1.5.into();
        d.config.algo.graph = crate::knn::GraphMode::Tnn;
        d.config.knn.t = 12;
        // The t-NN graph of well-separated blobs is exactly disconnected
        // (0 eigenvalue of multiplicity k); a full-dimension Krylov space
        // resolves the multiplicity deterministically.
        d.config.algo.lanczos_steps = 240;
        let input = PipelineInput::Points { points: ps.points.clone() };
        let text = d.explain_plan(&input).unwrap();
        assert!(text.contains("graph mode: tnn"), "{text}");
        assert!(text.contains("plan similarity-tnn"), "{text}");
        let r = d.run(&input).unwrap();
        let score = nmi(&ps.labels, &r.labels);
        assert!(score > 0.9, "tnn-mode nmi={score}");
        assert!(r.nnz > 0);
        assert!(r.phases[0].knn_summary().any(), "knn counters must flow");
        assert_eq!(
            r.phases[0].counters.get(crate::mapreduce::names::SIM_PAIRS_EVALUATED),
            0,
            "tnn mode must not price all pairs"
        );
    }

    #[test]
    fn tnn_mode_rejects_graph_topology_input() {
        let topo = planted_graph(60, 180, 3, 0.02, 5);
        let mut d = driver(2);
        d.config.algo.graph = crate::knn::GraphMode::Tnn;
        let input = PipelineInput::Graph { topology: topo };
        let err = match d.run(&input) {
            Err(e) => e,
            Ok(_) => panic!("tnn + graph input must error"),
        };
        assert!(err.to_string().contains("tnn"), "{err}");
        let err = d.explain_plan(&input).unwrap_err();
        assert!(err.to_string().contains("point input"), "{err}");
    }

    #[test]
    fn sigma_auto_resolves_and_recovers_blobs() {
        let ps = gaussian_blobs(300, 4, 4, 0.3, 10.0, 3);
        let mut d = driver(3);
        d.config.algo.k = 4;
        d.config.algo.sigma = crate::config::SigmaSpec::Auto;
        let r = d
            .run(&PipelineInput::Points { points: ps.points.clone() })
            .unwrap();
        assert!(r.sigma > 0.0 && r.sigma.is_finite(), "resolved {}", r.sigma);
        // The auto estimate equals the knn heuristic computed directly.
        let flat: Arc<Vec<f64>> =
            Arc::new(ps.points.iter().flatten().copied().collect());
        let expect = crate::knn::auto_sigma(flat, 300, 4, &d.config.knn).unwrap();
        assert_eq!(r.sigma.to_bits(), expect.to_bits());
        let score = nmi(&ps.labels, &r.labels);
        assert!(score > 0.95, "sigma-auto nmi={score}");
        // Capture fields for the serving layer ride along.
        assert_eq!(r.centers.len(), 4);
        assert_eq!(r.embedding.len(), 300 * 4);
        // explain-plan resolves too (it needs a concrete bandwidth).
        assert!(d
            .explain_plan(&PipelineInput::Points { points: ps.points.clone() })
            .is_ok());
    }

    #[test]
    fn sigma_auto_rejects_graph_topology_input() {
        let topo = planted_graph(60, 180, 3, 0.02, 5);
        let mut d = driver(2);
        d.config.algo.sigma = crate::config::SigmaSpec::Auto;
        let err = d.run(&PipelineInput::Graph { topology: topo }).unwrap_err();
        assert!(err.to_string().contains("point input"), "{err}");
    }

    #[test]
    fn fixed_sigma_passes_through_unchanged() {
        let ps = gaussian_blobs(200, 3, 4, 0.3, 10.0, 5);
        let mut d = driver(2);
        d.config.algo.k = 3;
        d.config.algo.sigma = 1.5.into();
        let r = d
            .run(&PipelineInput::Points { points: ps.points.clone() })
            .unwrap();
        assert_eq!(r.sigma.to_bits(), 1.5f64.to_bits());
    }

    #[test]
    fn virtual_time_decreases_with_more_slaves() {
        // Needs enough tasks per job for parallelism to matter: n=1200 gives
        // 3 tasks per mat-vec job and 5 paired similarity tasks. Lighter
        // coordination constants put this workload in the regime where the
        // paper's cluster also shows speedup (tiny jobs legitimately do NOT
        // speed up — that is the 8->10 flattening mechanism).
        let ps = gaussian_blobs(1200, 3, 4, 0.3, 10.0, 7);
        let input = PipelineInput::Points { points: ps.points.clone() };
        let run_with = |m: usize| {
            let mut cfg = Config::default();
            cfg.cluster.slaves = m;
            cfg.cluster.network.job_setup_s = 0.5;
            cfg.cluster.network.task_dispatch_s = 1.0;
            cfg.cluster.network.coord_per_machine_s = 0.1;
            cfg.cluster.network.shuffle_latency_s = 0.05;
            cfg.algo.lanczos_steps = 30;
            let d = Driver::new(cfg, Arc::new(KernelRuntime::native()));
            d.run(&input).unwrap().total_virtual_s
        };
        let t1 = run_with(1);
        let t4 = run_with(4);
        assert!(t4 < t1, "4 slaves ({t4:.1}s) should beat 1 ({t1:.1}s)");
    }
}
