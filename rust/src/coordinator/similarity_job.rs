//! Phase 1 (paper Alg. 4.2): parallel similarity matrix construction.
//!
//! Each map task owns a *pair* of row blocks `{b, nb-1-b}` — the paper's
//! load-balancing trick: block b computes `nb - b` tiles of the upper
//! triangle, its mirror computes `b + 1`, so every task computes the same
//! `nb + 1` tiles total. For each owned row block `b`, the task computes the
//! RBF tiles `S[b, cb]` for all `cb >= b` on the XLA kernel, thresholds by
//! `epsilon`, and writes sparse chunks to the table (both `(b, cb)` and the
//! mirrored `(cb, b)` — the paper's "according to the symmetry ... the other
//! half ... are obtained"). Partial row sums ride the shuffle to a reducer
//! that assembles the degree vector (Alg. 4.1 step 2).
//!
//! The phase is expressed as a [`crate::dataflow::Pipeline`]:
//! `read_dfs(points) → map_kv(similarity) → group_reduce(degree-sum) →
//! collect` — split locality (the paired blocks' DFS byte ranges) rides the
//! source and is resolved by the planner at run time.
//!
//! Table layout: key = `row_be || colblock_be` (u64 each), value =
//! `encode_sparse_row` of the (col, value) pairs of that row within the
//! column block — disjoint keys per task, so concurrent puts never conflict.

use std::sync::Arc;

use crate::dataflow::{Collected, Emit, Group, Pipeline};
use crate::error::Result;
use crate::runtime::KernelRuntime;
use crate::table::Table;
use crate::util::bytes::{decode_u64, encode_u64};

use super::{PhaseStats, Services};

/// Row-block edge (also the XLA RBF tile edge).
pub const BLOCK: usize = crate::runtime::executor::RBF_TILE;

/// Output of phase 1.
pub struct SimilarityOutput {
    /// Degree vector d_i = sum_j S_ij.
    pub degrees: Vec<f64>,
    /// Phase timing.
    pub stats: PhaseStats,
    /// Number of stored (non-dropped) similarity entries.
    pub nnz: u64,
    /// Merged job counters (locality/speculation tallies included).
    pub counters: crate::mapreduce::Counters,
}

/// Compose the table key for (row, column block).
pub fn chunk_key(row: u64, colblock: u64) -> Vec<u8> {
    let mut k = Vec::with_capacity(16);
    k.extend_from_slice(&encode_u64(row));
    k.extend_from_slice(&encode_u64(colblock));
    k
}

/// Decompose a chunk key.
pub fn parse_chunk_key(key: &[u8]) -> (u64, u64) {
    (decode_u64(&key[..8]), decode_u64(&key[8..16]))
}

struct SimilarityMapper {
    points: Arc<Vec<f32>>, // n × d row-major
    n: usize,
    d: usize,
    gamma: f32,
    epsilon: f32,
    table: Arc<Table>,
    runtime: Arc<KernelRuntime>,
}

impl SimilarityMapper {
    /// Number of row blocks for n points.
    fn nblocks(n: usize) -> usize {
        n.div_ceil(BLOCK)
    }

    fn block_range(&self, b: usize) -> (usize, usize) {
        let lo = b * BLOCK;
        (lo, (lo + BLOCK).min(self.n))
    }

    /// Map one owned row block: RBF tiles, threshold, table chunks, degree
    /// partials to the shuffle.
    fn map_block(&self, b: u64, out: &mut Emit<'_, u64, f64>) -> Result<()> {
        let b = b as usize;
        let nb = Self::nblocks(self.n);
        let (blo, bhi) = self.block_range(b);
        let rows_b = bhi - blo;
        // The task reads its owned row block from the staged DFS points
        // file; the scheduler charges this at the attempt's locality tier.
        out.incr(
            crate::mapreduce::names::EXTRA_INPUT_BYTES,
            (rows_b * self.d * 4) as u64,
        );
        let mut pairs_evaluated = 0u64;
        // Degree partials for the rows this task touches.
        let mut deg_b = vec![0.0f64; rows_b];
        for cb in b..nb {
            let (clo, chi) = self.block_range(cb);
            let cols = chi - clo;
            let tile = self.runtime.rbf_tile(
                &self.points[blo * self.d..bhi * self.d],
                &self.points[clo * self.d..chi * self.d],
                rows_b,
                cols,
                self.d,
                self.gamma,
            )?;
            // Threshold + emit chunks for rows of block b at column block cb.
            // Buffers are reused across rows and puts are batched per tile
            // (EXPERIMENTS.md §Perf: the threshold/put path dominated wall
            // time before batching).
            let mut kept = 0u64;
            let mut mirror: Vec<Vec<(u32, f64)>> =
                (0..cols).map(|_| Vec::with_capacity(rows_b)).collect();
            let mut chunk: Vec<(u32, f64)> = Vec::with_capacity(cols);
            let mut batch: Vec<(Vec<u8>, Vec<u8>)> = Vec::with_capacity(rows_b);
            let mut out_bytes = 0u64;
            for i in 0..rows_b {
                chunk.clear();
                for j in 0..cols {
                    let v = tile[i * cols + j];
                    let (gi, gj) = (blo + i, clo + j);
                    // Keep the diagonal unconditionally; drop sub-epsilon.
                    if (cb == b && gj == gi) || v >= self.epsilon {
                        chunk.push((gj as u32, v as f64));
                        deg_b[i] += v as f64;
                        if gi != gj {
                            mirror[j].push((gi as u32, v as f64));
                        }
                    }
                }
                if !chunk.is_empty() {
                    kept += chunk.len() as u64;
                    let payload = crate::util::bytes::encode_sparse_row(&chunk);
                    out_bytes += payload.len() as u64;
                    batch.push((chunk_key((blo + i) as u64, cb as u64), payload));
                }
            }
            self.table.put_batch(std::mem::take(&mut batch))?;
            // Mirrored chunks: rows of block cb at column block b.
            if cb != b {
                let mut deg_c = vec![0.0f64; cols];
                let mut batch: Vec<(Vec<u8>, Vec<u8>)> = Vec::with_capacity(cols);
                for (j, entries) in mirror.iter().enumerate() {
                    if entries.is_empty() {
                        continue;
                    }
                    for &(_, v) in entries {
                        deg_c[j] += v;
                    }
                    kept += entries.len() as u64;
                    let payload = crate::util::bytes::encode_sparse_row(entries);
                    out_bytes += payload.len() as u64;
                    batch.push((chunk_key((clo + j) as u64, b as u64), payload));
                }
                self.table.put_batch(batch)?;
                for (j, dval) in deg_c.into_iter().enumerate() {
                    if dval != 0.0 {
                        out.emit((clo + j) as u64, dval);
                    }
                }
            }
            out.incr(crate::mapreduce::names::EXTRA_OUTPUT_BYTES, out_bytes);
            pairs_evaluated += (rows_b * cols) as u64;
            out.incr("SIM_ENTRIES_KEPT", kept);
            out.incr("SIM_TILES", 1);
        }
        // Every tile cell is a fully-priced candidate pair — the all-pairs
        // baseline the t-NN ablation compares against.
        out.incr(crate::mapreduce::names::SIM_PAIRS_EVALUATED, pairs_evaluated);
        // Deterministic virtual compute: Alg. 4.2's pair evaluations at the
        // reference machine's calibrated rate (costmodel.rs).
        out.incr(
            crate::mapreduce::names::COMPUTE_US,
            super::costmodel::units_to_us(
                pairs_evaluated,
                super::costmodel::SIM_PAIRS_PER_S,
            ),
        );
        for (i, dval) in deg_b.into_iter().enumerate() {
            out.emit((blo + i) as u64, dval);
        }
        Ok(())
    }
}

/// Build the points-mode phase-1 pipeline: stage the points in the DFS,
/// pair the row blocks paper-style, and wire `read_dfs → map_kv →
/// group_reduce → collect`. Returns the pipeline and the handle to the
/// collected degree records.
pub(crate) fn points_pipeline(
    services: &Services,
    points: Arc<Vec<f32>>,
    n: usize,
    d: usize,
    sigma: f64,
    epsilon: f64,
    table_name: &str,
) -> Result<(Pipeline, Collected<u64, f64>)> {
    let table = services.tables.create(table_name, services.cluster.num_slaves())?;
    let nb = SimilarityMapper::nblocks(n);
    let gamma = crate::spectral::gamma_of_sigma(sigma) as f32;

    // Stage the input points in the DFS (the paper's samples live on HDFS)
    // so every split can declare the nodes holding its row blocks.
    let input_path = format!("/input/{table_name}.points");
    let mut raw = Vec::with_capacity(points.len() * 4);
    for &x in points.iter() {
        raw.extend_from_slice(&x.to_le_bytes());
    }
    services.dfs.write_file(&input_path, &raw)?;
    let row_bytes = d * 4;
    let byte_range = |b: usize| -> (usize, usize) {
        (b * BLOCK * row_bytes, ((b + 1) * BLOCK).min(n) * row_bytes)
    };

    // Paper pairing: split {b, nb-1-b} — both blocks in one map task; the
    // split's locality is the union of both blocks' byte ranges.
    let mut splits: Vec<Vec<(u64, ())>> = Vec::new();
    let mut ranges: Vec<Vec<(usize, usize)>> = Vec::new();
    for b in 0..nb.div_ceil(2) {
        let mut records = vec![(b as u64, ())];
        let mut r = vec![byte_range(b)];
        let mirror = nb - 1 - b;
        if mirror != b {
            records.push((mirror as u64, ()));
            r.push(byte_range(mirror));
        }
        splits.push(records);
        ranges.push(r);
    }

    let mapper = SimilarityMapper {
        points,
        n,
        d,
        gamma,
        epsilon: epsilon as f32,
        table,
        runtime: services.runtime.clone(),
    };
    let pipeline = Pipeline::new("similarity");
    let degrees = pipeline
        .read_dfs(&input_path, splits, ranges)
        .map_kv("similarity", move |b: u64, _: (), out| mapper.map_block(b, out))
        .group_reduce("degree-sum")
        .reducers(services.cluster.num_slaves())
        .reduce(|key: u64, values: &mut Group<'_, f64>, out| {
            // Degree reducer: sum the partial row sums as they stream off
            // the merge. Modeled compute (one unit per partial) keeps the
            // reduce plan — and the trace built on it — deterministic.
            let mut total = 0.0f64;
            let mut partials = 0u64;
            while let Some(v) = values.next_value() {
                total += v;
                partials += 1;
            }
            out.incr(
                crate::mapreduce::names::COMPUTE_US,
                super::costmodel::units_to_us(
                    partials,
                    super::costmodel::GRAPH_EDGES_PER_S,
                ),
            );
            out.emit(key, total);
            Ok(())
        })
        .collect();
    Ok((pipeline, degrees))
}

/// Run phase 1: build the S table + degree vector for a point set.
///
/// `points` is n×d row-major f32; similarity entries below `epsilon` are
/// dropped (diagonal kept). Returns degrees + phase stats.
pub fn run_similarity_phase(
    services: &Services,
    points: Arc<Vec<f32>>,
    n: usize,
    d: usize,
    sigma: f64,
    epsilon: f64,
    table_name: &str,
) -> Result<SimilarityOutput> {
    let (pipeline, degree_handle) =
        points_pipeline(services, points, n, d, sigma, epsilon, table_name)?;
    let mut run = pipeline.run(services)?;

    // Assemble the degree vector from the collected reducer output.
    let mut degrees = vec![0.0f64; n];
    for (row, degree) in degree_handle.take(&mut run) {
        degrees[row as usize] = degree;
    }
    let mut stats = PhaseStats { name: "similarity".into(), ..Default::default() };
    stats.absorb_run(&run.stats);
    let counters = run.stats.merged_counters();
    Ok(SimilarityOutput {
        degrees,
        stats,
        nnz: counters.get("SIM_ENTRIES_KEPT"),
        counters,
    })
}

/// Build the graph-mode phase-1 pipeline: edge/vertex records staged in
/// the DFS, `read_dfs → map_kv(expand edges) → group_reduce(assemble rows)
/// → collect(degrees)`.
pub(crate) fn graph_pipeline(
    services: &Services,
    topology: &crate::data::Topology,
    table_name: &str,
) -> Result<(Pipeline, Collected<u64, f64>)> {
    let table = services.tables.create(table_name, services.cluster.num_slaves())?;

    // Splits: edges chunked, then vertices chunked (for the diagonal). The
    // records are simultaneously serialized into a staged DFS edge file so
    // each split can declare the nodes holding its byte range.
    const RECORDS_PER_SPLIT: usize = 4096;
    let mut splits: Vec<Vec<(Vec<u8>, Vec<u8>)>> = Vec::new();
    let mut current: Vec<(Vec<u8>, Vec<u8>)> = Vec::new();
    let mut raw: Vec<u8> = Vec::new();
    let mut ranges: Vec<Vec<(usize, usize)>> = Vec::new();
    let mut range_start = 0usize;
    for e in &topology.edges {
        let mut v = Vec::with_capacity(24);
        v.extend_from_slice(&encode_u64(e.src));
        v.extend_from_slice(&encode_u64(e.dst));
        v.extend_from_slice(&crate::util::bytes::encode_f64(e.label.max(1) as f64));
        raw.extend_from_slice(&v);
        current.push((b"e".to_vec(), v));
        if current.len() == RECORDS_PER_SPLIT {
            splits.push(std::mem::take(&mut current));
            ranges.push(vec![(range_start, raw.len())]);
            range_start = raw.len();
        }
    }
    for v in &topology.vertices {
        raw.extend_from_slice(&encode_u64(v.id));
        current.push((b"v".to_vec(), encode_u64(v.id).to_vec()));
        if current.len() == RECORDS_PER_SPLIT {
            splits.push(std::mem::take(&mut current));
            ranges.push(vec![(range_start, raw.len())]);
            range_start = raw.len();
        }
    }
    if !current.is_empty() {
        splits.push(current);
        ranges.push(vec![(range_start, raw.len())]);
    }
    let input_path = format!("/input/{table_name}.edges");
    services.dfs.write_file(&input_path, &raw)?;

    let pipeline = Pipeline::new("similarity-graph");
    let table_c = table.clone();
    let degrees = pipeline
        .read_dfs(&input_path, splits, ranges)
        .map_kv(
            "similarity-graph",
            |tag: Vec<u8>, value: Vec<u8>, out| -> Result<()> {
                // NB: unlike the points/kmeans/lanczos jobs, the real
                // payloads ARE the split records here, so the engine already
                // counts them into the task's input bytes — no
                // EXTRA_INPUT_BYTES on top.
                match tag.as_slice() {
                    b"e" => {
                        let src = decode_u64(&value[..8]);
                        let dst = decode_u64(&value[8..16]);
                        let w = crate::util::bytes::decode_f64(&value[16..24]);
                        out.emit(src, (dst, w));
                        if src != dst {
                            out.emit(dst, (src, w));
                        }
                    }
                    b"v" => {
                        let id = decode_u64(&value);
                        out.emit(id, (id, 1.0));
                    }
                    other => {
                        return Err(crate::error::Error::MapReduce(format!(
                            "graph similarity: unknown record {other:?}"
                        )))
                    }
                }
                out.incr(
                    crate::mapreduce::names::COMPUTE_US,
                    super::costmodel::units_to_us(
                        1,
                        super::costmodel::GRAPH_EDGES_PER_S,
                    ),
                );
                Ok(())
            },
        )
        .group_reduce("graph-row")
        .reducers(services.cluster.num_slaves())
        .reduce(
            move |row: u64, values: &mut Group<'_, (u64, f64)>, out| -> Result<()> {
                // One row's adjacency — bounded by the vertex degree, not
                // the partition (the merge streams the group's values).
                let mut entries: Vec<(u32, f64)> = Vec::new();
                while let Some((j, w)) = values.next_value() {
                    entries.push((j as u32, w));
                }
                entries.sort_unstable_by_key(|&(j, _)| j);
                entries.dedup_by(|a, b| {
                    if a.0 == b.0 {
                        b.1 += a.1; // parallel edges sum
                        true
                    } else {
                        false
                    }
                });
                let degree: f64 = entries.iter().map(|&(_, v)| v).sum();
                out.incr("SIM_ENTRIES_KEPT", entries.len() as u64);
                out.incr(
                    crate::mapreduce::names::COMPUTE_US,
                    super::costmodel::units_to_us(
                        entries.len() as u64,
                        super::costmodel::GRAPH_EDGES_PER_S,
                    ),
                );
                // Write per-column-block chunks.
                let mut i = 0;
                while i < entries.len() {
                    let cb = entries[i].0 as usize / BLOCK;
                    let mut j = i;
                    while j < entries.len() && entries[j].0 as usize / BLOCK == cb {
                        j += 1;
                    }
                    table_c.put(
                        chunk_key(row, cb as u64),
                        crate::util::bytes::encode_sparse_row(&entries[i..j]),
                    )?;
                    i = j;
                }
                out.emit(row, degree);
                Ok(())
            },
        )
        .collect();
    Ok((pipeline, degrees))
}

/// Graph-mode phase 1: build the S table from a topology's edges.
///
/// The edge list is split across map tasks; each map emits both directions
/// of every edge (`sim(i,j) = sim(j,i)`, paper §4.3.1) plus unit diagonals
/// from vertex records. Reducers assemble each row, write its chunks to the
/// table and emit the degree.
pub fn run_similarity_phase_graph(
    services: &Services,
    topology: &crate::data::Topology,
    table_name: &str,
) -> Result<SimilarityOutput> {
    let (pipeline, degree_handle) = graph_pipeline(services, topology, table_name)?;
    let mut run = pipeline.run(services)?;

    let n = topology.num_vertices();
    let mut degrees = vec![0.0f64; n];
    for (row, degree) in degree_handle.take(&mut run) {
        degrees[row as usize] = degree;
    }
    let mut stats = PhaseStats { name: "similarity".into(), ..Default::default() };
    stats.absorb_run(&run.stats);
    let counters = run.stats.merged_counters();
    Ok(SimilarityOutput {
        degrees,
        stats,
        nnz: counters.get("SIM_ENTRIES_KEPT"),
        counters,
    })
}

/// Read one row of S back from the table (tests + phase 2).
pub fn read_similarity_row(table: &Table, row: u64, nblocks: usize) -> Vec<(u32, f64)> {
    let mut out = Vec::new();
    for cb in 0..nblocks as u64 {
        if let Ok(Some(v)) = table.get(&chunk_key(row, cb)) {
            out.extend(crate::util::bytes::decode_sparse_row(&v));
        }
    }
    out.sort_unstable_by_key(|&(j, _)| j);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::data::gaussian_blobs;

    fn services(m: usize) -> Services {
        Services::new(Cluster::new(m), Arc::new(KernelRuntime::native()))
    }

    fn run_phase(n: usize, sigma: f64, eps: f64) -> (Services, SimilarityOutput, usize) {
        let ps = gaussian_blobs(n, 3, 4, 0.4, 8.0, 3);
        let svc = services(3);
        let flat: Vec<f32> = ps.points.iter().flatten().map(|&x| x as f32).collect();
        let out = run_similarity_phase(
            &svc,
            Arc::new(flat),
            n,
            4,
            sigma,
            eps,
            "S",
        )
        .unwrap();
        (svc, out, n)
    }

    #[test]
    fn matches_single_machine_similarity() {
        let n = 300;
        let ps = gaussian_blobs(n, 3, 4, 0.4, 8.0, 3);
        let svc = services(2);
        let flat: Vec<f32> = ps.points.iter().flatten().map(|&x| x as f32).collect();
        let out =
            run_similarity_phase(&svc, Arc::new(flat), n, 4, 1.0, 1e-6, "S").unwrap();
        let oracle = crate::spectral::rbf_sparse(&ps.points, 1.0, 1e-6);
        let table = svc.tables.open("S").unwrap();
        let nb = n.div_ceil(BLOCK);
        for i in (0..n).step_by(37) {
            let row = read_similarity_row(&table, i as u64, nb);
            let oracle_row: Vec<(u32, f64)> = oracle.row(i).collect();
            assert_eq!(row.len(), oracle_row.len(), "row {i} nnz");
            for ((j1, v1), (j2, v2)) in row.iter().zip(&oracle_row) {
                assert_eq!(j1, j2);
                assert!((v1 - v2).abs() < 1e-5, "row {i} col {j1}: {v1} vs {v2}");
            }
        }
        // Degrees match row sums.
        let sums = oracle.row_sums();
        for i in (0..n).step_by(11) {
            assert!(
                (out.degrees[i] - sums[i]).abs() < 1e-3,
                "degree {i}: {} vs {}",
                out.degrees[i],
                sums[i]
            );
        }
    }

    #[test]
    fn diagonal_always_kept() {
        let (svc, _, n) = run_phase(150, 0.2, 0.5); // harsh epsilon
        let table = svc.tables.open("S").unwrap();
        let nb = n.div_ceil(BLOCK);
        for i in (0..n).step_by(29) {
            let row = read_similarity_row(&table, i as u64, nb);
            assert!(
                row.iter().any(|&(j, v)| j as usize == i && (v - 1.0).abs() < 1e-6),
                "row {i} lost its diagonal"
            );
        }
    }

    #[test]
    fn epsilon_controls_sparsity() {
        // Intra-cluster sims sit around exp(-d2/2) ~ 0.5 for these blobs, so
        // a 0.5 threshold cuts into them while 1e-8 keeps them all.
        let (_, loose, _) = run_phase(200, 1.0, 1e-8);
        let (_, tight, _) = run_phase(200, 1.0, 0.5);
        assert!(tight.nnz < loose.nnz, "{} !< {}", tight.nnz, loose.nnz);
    }

    #[test]
    fn stats_populated() {
        let (_, out, _) = run_phase(130, 1.0, 1e-6);
        assert!(out.stats.virtual_s > 0.0);
        assert_eq!(out.stats.jobs, 1, "map + reduce fuse into one job");
        assert!(out.stats.shuffle_bytes > 0, "degrees cross the shuffle");
    }

    #[test]
    fn pipeline_plan_is_one_fused_job() {
        let ps = gaussian_blobs(150, 3, 4, 0.4, 8.0, 3);
        let svc = services(2);
        let flat: Vec<f32> = ps.points.iter().flatten().map(|&x| x as f32).collect();
        let (pipeline, _degrees) =
            points_pipeline(&svc, Arc::new(flat), 150, 4, 1.0, 1e-6, "S").unwrap();
        let plan = pipeline.plan().unwrap();
        assert_eq!(plan.job_count(), 1);
        let summaries = plan.stage_summaries();
        assert_eq!(summaries[0].name, "similarity");
        assert!(summaries[0].has_reduce);
        assert!(summaries[0].source_splits > 0);
    }

    #[test]
    fn pairing_splits_cover_all_blocks() {
        // 5 blocks -> tasks {0,4},{1,3},{2}; 4 -> {0,3},{1,2}.
        for (nb, want) in [(5usize, 3usize), (4, 2), (1, 1)] {
            let n = nb * BLOCK;
            let mut blocks_seen = std::collections::HashSet::new();
            for b in 0..nb.div_ceil(2) {
                blocks_seen.insert(b);
                blocks_seen.insert(nb - 1 - b);
            }
            assert_eq!(blocks_seen.len(), nb, "n={n}");
            assert_eq!(nb.div_ceil(2), want);
        }
    }
}
