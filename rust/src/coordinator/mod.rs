//! The paper's system contribution: the three parallel phases of spectral
//! clustering as [`crate::dataflow`] pipelines over the mini-Hadoop
//! runtime (§4.3) — each phase is a typed `Pipeline` expression whose
//! planned stages run on the MapReduce engine.
//!
//! - [`similarity_job`]: Alg. 4.2 — parallel similarity matrix with the
//!   i/(n−i+1) load-balanced pairing, written to the table store; degrees
//!   aggregated through the shuffle.
//! - [`lanczos_job`]: Alg. 4.3 — Lanczos with the `L·v` hot spot as a
//!   row-partitioned MR job per iteration ("move the vector to the data").
//! - [`eigen`]: the eigensolver layer — the [`eigen::EigensolverJob`] trait
//!   both phase-2 backends (lanczos, chebdav) plug into, and the block
//!   Chebyshev–Davidson job batching m mat-vecs per pipeline run.
//! - [`kmeans_job`]: §4.3.3 — iterated assign/update MR jobs with the DFS
//!   "center file".
//! - [`driver`]: runs the phases end to end and reports per-phase virtual +
//!   wall time (the paper's Table 5-1 rows).

pub mod costmodel;
pub mod driver;
pub mod eigen;
pub mod kmeans_job;
pub mod lanczos_job;
pub mod similarity_job;

use std::sync::Arc;

use crate::cluster::Cluster;
use crate::config::Config;
use crate::dfs::Dfs;
use crate::runtime::KernelRuntime;
use crate::table::TableService;

pub use driver::{Driver, PipelineInput, PipelineResult};

/// Shared service handles every phase needs.
#[derive(Clone)]
pub struct Services {
    /// The simulated cluster (m slaves, slots, cost model).
    pub cluster: Cluster,
    /// Mini-HDFS (input files, the k-means center file).
    pub dfs: Dfs,
    /// Mini-HBase (similarity + Laplacian matrices).
    pub tables: TableService,
    /// XLA PJRT kernel runtime (or native fallback).
    pub runtime: Arc<KernelRuntime>,
    /// t-NN graph construction knobs (`[knn]` config section) — the
    /// similarity phase reads these when `algo.graph = "tnn"`.
    pub knn: crate::knn::KnnConfig,
    /// Eigen-phase knobs (`[eigen]` config section) — the driver reads the
    /// backend selector and ChebDav parameters from here, so tests that
    /// inject services pick the solver per-run.
    pub eigen: eigen::EigenConfig,
}

impl Services {
    /// Stand up services for `m` slaves with the given runtime. The DFS
    /// shares the cluster's rack topology (datanodes are co-located with
    /// slaves), so replica placement and the JobTracker agree on the
    /// network map.
    pub fn new(cluster: Cluster, runtime: Arc<KernelRuntime>) -> Self {
        let m = cluster.num_slaves();
        Self::with_replication(cluster, runtime, 2.min(m))
    }

    /// As [`Self::new`] with an explicit DFS replication factor (clamped
    /// to the slave count).
    ///
    /// The DFS joins the cluster's failure domain here: when a slave dies
    /// (scheduled `[faults]` death observed at a heartbeat), its
    /// co-located datanode is killed and under-replicated blocks are
    /// re-replicated from surviving copies — staged dataflow intermediates
    /// survive, so downstream stages recover without recomputing upstream
    /// phases.
    pub fn with_replication(
        cluster: Cluster,
        runtime: Arc<KernelRuntime>,
        replication: usize,
    ) -> Self {
        let m = cluster.num_slaves();
        let topology = cluster.topology().clone();
        let svc = Self {
            cluster,
            dfs: Dfs::with_topology(
                m,
                replication.clamp(1, m),
                crate::dfs::DEFAULT_BLOCK_SIZE,
                topology,
            ),
            tables: TableService::new(m),
            runtime,
            knn: crate::knn::KnnConfig::default(),
            eigen: eigen::EigenConfig::default(),
        };
        let dfs = svc.dfs.clone();
        svc.cluster.faults().on_death(move |node| {
            // Best-effort: with too few survivors full replication may be
            // unrestorable; surviving replicas still serve reads.
            let _ = dfs.kill_datanode(node);
        });
        svc
    }

    /// Stand up services from a [`Config`]: cluster with the configured
    /// rack topology, JobTracker, shuffle and failure-domain knobs, plus a
    /// DFS with the configured replication. The single constructor the
    /// driver, benches and tests share (it used to be copy-pasted per
    /// caller).
    pub fn from_config(config: &Config, runtime: Arc<KernelRuntime>) -> Self {
        let c = &config.cluster;
        let mut cluster =
            Cluster::with_model(c.slaves, c.slots_per_slave, c.network.clone());
        cluster.set_topology(crate::scheduler::RackTopology::uniform(
            c.slaves, c.racks,
        ));
        cluster.set_tracker_config(crate::scheduler::TrackerConfig {
            heartbeat_s: c.heartbeat_s,
            policy: c.scheduler,
            speculation: c.speculation,
        });
        cluster.set_shuffle_config(config.shuffle);
        cluster.set_fault_config(config.faults.clone());
        let mut svc = Self::with_replication(cluster, runtime, c.replication);
        svc.knn = config.knn;
        svc.eigen = config.eigen;
        svc
    }
}

/// Timing/IO summary of one pipeline phase (one Table 5-1 cell).
#[derive(Debug, Clone, Default)]
pub struct PhaseStats {
    /// Phase name.
    pub name: String,
    /// Virtual seconds on the simulated cluster (Table 5-1's quantity).
    pub virtual_s: f64,
    /// Real wall seconds of the simulation.
    pub wall_s: f64,
    /// MapReduce jobs launched by the phase.
    pub jobs: usize,
    /// Total shuffle bytes across the phase's jobs.
    pub shuffle_bytes: u64,
    /// Virtual seconds of the phase's shuffle-fetch barriers (sum of each
    /// job's slowest-reducer fetch time — NOT the serial per-reducer sum
    /// the `SHUFFLE_FETCH_US` counter tracks).
    pub shuffle_fetch_s: f64,
    /// Counters merged across the phase's jobs — the single source for
    /// spill/merge/fetch-tier tallies (see [`Self::shuffle_summary`]) and
    /// the locality/speculation family.
    pub counters: crate::mapreduce::Counters,
}

impl PhaseStats {
    /// Accumulate one whole job — timing stats AND counters — into the
    /// phase. Prefer this over the split [`Self::absorb`] +
    /// [`Self::absorb_counters`] calls whenever the `JobResult` is at hand.
    pub fn absorb_job(&mut self, result: &crate::mapreduce::JobResult) {
        self.absorb(&result.stats);
        self.absorb_counters(&result.counters);
    }

    /// Accumulate a whole dataflow pipeline run: every planned stage's job
    /// stats and counters land in the phase (per-stage
    /// [`crate::dataflow::PlanStats`] absorbed into the phase totals).
    pub fn absorb_run(&mut self, run: &crate::dataflow::PlanStats) {
        for stage in &run.stages {
            self.absorb(&stage.stats);
            self.absorb_counters(&stage.counters);
        }
    }

    /// Accumulate one job's timing stats into the phase.
    pub fn absorb(&mut self, stats: &crate::mapreduce::JobStats) {
        self.virtual_s += stats.virtual_time_s;
        self.wall_s += stats.wall_time_s;
        self.shuffle_bytes += stats.shuffle_bytes;
        self.shuffle_fetch_s += stats.shuffle_fetch_s;
        self.jobs += 1;
    }

    /// Merge one job's counters into the phase counters.
    pub fn absorb_counters(&mut self, counters: &crate::mapreduce::Counters) {
        self.counters.merge(counters);
    }

    /// Add master-side (non-MR) compute, scaled like task compute.
    pub fn absorb_master(&mut self, wall_s: f64, compute_scale: f64) {
        self.virtual_s += wall_s * compute_scale;
        self.wall_s += wall_s;
    }

    /// Virtual seconds the phase's winning attempts spent queued before
    /// dispatch (`QUEUE_WAIT_US`, converted back to seconds).
    pub fn queue_wait_s(&self) -> f64 {
        self.counters.get(crate::mapreduce::names::QUEUE_WAIT_US) as f64 / 1e6
    }

    /// Slot-seconds the cluster left idle while the phase's plans ran
    /// (`SLOT_IDLE_US`, converted back to seconds).
    pub fn slot_idle_s(&self) -> f64 {
        self.counters.get(crate::mapreduce::names::SLOT_IDLE_US) as f64 / 1e6
    }

    /// Shuffle lifecycle summary of the phase.
    pub fn shuffle_summary(&self) -> crate::metrics::ShuffleSummary {
        crate::metrics::ShuffleSummary::from_counters(&self.counters)
    }

    /// Failure-domain summary of the phase: failed attempts, map reruns,
    /// fetch failures, blacklisted slaves, node deaths (the per-phase
    /// fault report the driver/CLI surface).
    pub fn fault_summary(&self) -> crate::metrics::FaultSummary {
        crate::metrics::FaultSummary::from_counters(&self.counters)
    }

    /// t-NN graph-construction summary of the phase: pairs priced vs
    /// pruned and heap churn (all-zero for epsilon-mode phases).
    pub fn knn_summary(&self) -> crate::metrics::KnnSummary {
        crate::metrics::KnnSummary::from_counters(&self.counters)
    }

    /// Eigensolver summary of the phase: jobs launched, mat-vecs batched
    /// and the Chebyshev filter degree (all-zero for non-eigen phases).
    pub fn eigen_summary(&self) -> crate::metrics::EigenSummary {
        crate::metrics::EigenSummary::from_counters(&self.counters)
    }

    /// Serving summary of the phase: points assigned, assign batches run
    /// and mini-batch refresh updates (all-zero outside `psch assign`).
    pub fn serving_summary(&self) -> crate::metrics::ServingSummary {
        crate::metrics::ServingSummary::from_counters(&self.counters)
    }
}
