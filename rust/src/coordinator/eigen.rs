//! Eigensolver layer: the trait phase 2's backends plug into, plus the
//! distributed block Chebyshev–Davidson job.
//!
//! Both backends share stage 1 (the fused Laplacian-build pipeline in
//! [`super::lanczos_job`]) and differ only in how they apply the operator:
//!
//! - **lanczos** — one `read_table(L) → map_kv(spmv) → collect` job per
//!   Krylov step: O(steps) tiny jobs whose cost is mostly per-job setup.
//! - **chebdav** — the multi-vector extension of the same table-region
//!   layout: each job broadcasts the whole n×m block row-major (records
//!   are `(row, m-values)`), every task runs the blocked spmv over its row
//!   range for all m columns at once, and the master drives the Chebyshev
//!   filter + Rayleigh–Ritz recurrence between jobs. O(outer·(degree+1))
//!   jobs, each pricing m mat-vecs — strictly fewer launches at paper
//!   scale, with the per-job setup amortized m ways.
//!
//! The blocked kernel ([`CsrMatrix::spmv_block_rows`]) is row-independent,
//! so task partitioning — and fault-injected re-execution — reassembles
//! bit-identically to the single-machine oracle.

use std::sync::Arc;

use crate::dataflow::{Collected, Pipeline};
use crate::error::Result;
use crate::linalg::{chebdav_smallest, ChebDavOptions, CsrMatrix};
use crate::mapreduce::names;
use crate::table::Table;

use super::lanczos_job::{self, EigenOutput, ROWS_PER_TASK};
use super::similarity_job::chunk_key;
use super::{PhaseStats, Services};

/// Which phase-2 backend runs (`eigen.solver` / `--eigensolver`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EigenSolverKind {
    /// One mat-vec job per Krylov step (paper Alg. 4.3).
    #[default]
    Lanczos,
    /// Block Chebyshev–Davidson: batched multi-vector mat-vec jobs.
    ChebDav,
}

impl EigenSolverKind {
    /// Parse the config spelling.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "lanczos" => Some(Self::Lanczos),
            "chebdav" => Some(Self::ChebDav),
            _ => None,
        }
    }

    /// The config spelling.
    pub fn as_str(&self) -> &'static str {
        match self {
            Self::Lanczos => "lanczos",
            Self::ChebDav => "chebdav",
        }
    }
}

/// Eigen-phase knobs (`[eigen]` config section).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EigenConfig {
    /// Backend selector.
    pub solver: EigenSolverKind,
    /// ChebDav block width m (clamped to `max(k, block_size).min(n)`).
    pub block_size: usize,
    /// Chebyshev filter degree (operator applications per filter pass).
    pub filter_degree: usize,
    /// Max outer (filter + Rayleigh–Ritz) iterations.
    pub max_outer: usize,
    /// Residual tolerance for ChebDav convergence.
    pub residual_tol: f64,
    /// Lanczos steps spent estimating the filter interval bounds.
    pub bound_steps: usize,
}

impl Default for EigenConfig {
    fn default() -> Self {
        Self {
            solver: EigenSolverKind::Lanczos,
            block_size: 8,
            filter_degree: 8,
            max_outer: 5,
            residual_tol: 1e-6,
            bound_steps: 4,
        }
    }
}

impl EigenConfig {
    /// Worst-case operator jobs a ChebDav eigen phase launches (excluding
    /// the Laplacian build): bound estimation + max_outer filtered rounds
    /// of `degree` filter applications plus one Rayleigh–Ritz projection.
    pub fn max_operator_jobs(&self) -> usize {
        self.bound_steps + self.max_outer * (self.filter_degree + 1)
    }
}

/// One selectable phase-2 backend: turns the S table + degree vector into
/// the row-normalized spectral embedding, launching its own dataflow jobs.
pub trait EigensolverJob {
    /// Config spelling of the backend ("lanczos" | "chebdav").
    fn name(&self) -> &'static str;

    /// Run phase 2 end to end (Laplacian build + eigeniteration +
    /// embedding normalization), reporting through [`PhaseStats`].
    fn run(
        &self,
        services: &Services,
        s_table: &Arc<Table>,
        degrees: Arc<Vec<f64>>,
        n: usize,
        k: usize,
    ) -> Result<EigenOutput>;

    /// Append this backend's planned pipelines (and launch-count bound) to
    /// the `--explain-plan` text without running anything.
    fn explain(&self, services: &Services, n: usize, k: usize, out: &mut String)
        -> Result<()>;
}

/// Pick the backend the config asks for.
pub fn solver_for(
    eigen: &EigenConfig,
    algo: &crate::config::AlgoConfig,
) -> Box<dyn EigensolverJob> {
    match eigen.solver {
        EigenSolverKind::Lanczos => Box::new(LanczosJob {
            steps: algo.lanczos_steps,
            seed: algo.seed,
        }),
        EigenSolverKind::ChebDav => Box::new(ChebDavJob { config: *eigen, seed: algo.seed }),
    }
}

/// Shared `--explain-plan` scaffolding: the surrogate S/L tables, the
/// (exact) Laplacian-build plan, and the surrogate operands the mat-vec
/// plans are built against (identity-structure L: 12 bytes/entry + 16 per
/// row).
fn explain_surrogates(
    services: &Services,
    n: usize,
    out: &mut String,
) -> Result<(Arc<CsrMatrix>, Arc<Table>, Vec<u64>)> {
    let m = services.cluster.num_slaves();
    let s_table = services.tables.create("S", m)?;
    let l_table = services.tables.create("L", m)?;
    let dinv: Arc<Vec<f64>> = Arc::new(vec![1.0; n]);
    let pipeline = lanczos_job::laplacian_pipeline(&s_table, &l_table, &dinv, n);
    out.push_str(&pipeline.plan()?.explain());
    let l = Arc::new(CsrMatrix::from_rows(
        n,
        (0..n).map(|i| vec![(i as u32, 1.0f64)]).collect(),
    ));
    let row_bytes: Vec<u64> = vec![28; n];
    Ok((l, l_table, row_bytes))
}

/// The paper's backend: one mat-vec job per Lanczos step.
pub struct LanczosJob {
    /// Max Krylov steps (`algo.lanczos_steps`).
    pub steps: usize,
    /// Start-vector seed (`algo.seed`).
    pub seed: u64,
}

impl EigensolverJob for LanczosJob {
    fn name(&self) -> &'static str {
        "lanczos"
    }

    fn run(
        &self,
        services: &Services,
        s_table: &Arc<Table>,
        degrees: Arc<Vec<f64>>,
        n: usize,
        k: usize,
    ) -> Result<EigenOutput> {
        lanczos_job::run_eigen_phase(services, s_table, degrees, n, k, self.steps, self.seed)
    }

    fn explain(
        &self,
        services: &Services,
        n: usize,
        _k: usize,
        out: &mut String,
    ) -> Result<()> {
        let (l, l_table, row_bytes) = explain_surrogates(services, n, out)?;
        let v: Arc<Vec<f64>> = Arc::new(vec![0.0; n]);
        let (pipeline, _y) = lanczos_job::matvec_pipeline(&l, &l_table, &v, &row_bytes, n);
        out.push_str(&pipeline.plan()?.explain());
        out.push_str(&format!(
            "  (matvec launched once per Lanczos step, ≤{} times)\n",
            self.steps.min(n)
        ));
        Ok(())
    }
}

/// The block Chebyshev–Davidson backend: batched multi-vector jobs.
pub struct ChebDavJob {
    /// Solver knobs (`[eigen]` config section).
    pub config: EigenConfig,
    /// Start-block seed (`algo.seed`).
    pub seed: u64,
}

impl EigensolverJob for ChebDavJob {
    fn name(&self) -> &'static str {
        "chebdav"
    }

    fn run(
        &self,
        services: &Services,
        s_table: &Arc<Table>,
        degrees: Arc<Vec<f64>>,
        n: usize,
        k: usize,
    ) -> Result<EigenOutput> {
        run_chebdav_phase(services, s_table, degrees, n, k, &self.config, self.seed)
    }

    fn explain(
        &self,
        services: &Services,
        n: usize,
        k: usize,
        out: &mut String,
    ) -> Result<()> {
        let (l, l_table, row_bytes) = explain_surrogates(services, n, out)?;
        let m_cols = self.config.block_size.max(k).min(n.max(1));
        let x: Arc<Vec<f64>> = Arc::new(vec![0.0; n * m_cols]);
        let (pipeline, _y) =
            block_matvec_pipeline(&l, &l_table, &x, m_cols, &row_bytes, n);
        out.push_str(&pipeline.plan()?.explain());
        out.push_str(&format!(
            "  (block matvec prices {m_cols} columns per job; ≤{} bound-estimation \
             + {}×{} filtered launches = {} operator jobs)\n",
            self.config.bound_steps,
            self.config.max_outer,
            self.config.filter_degree + 1,
            self.config.max_operator_jobs(),
        ));
        Ok(())
    }
}

/// Build one block mat-vec pipeline: `read_table(L) → map_kv(block spmv) →
/// collect`. The multi-vector table format: `x` is the whole n×m block
/// row-major, broadcast to every task ("move the *block* to the data");
/// each task emits `(row, m-values)` records for its row range, priced as
/// m mat-vecs over the range's stored entries plus the 8·n·m broadcast
/// bytes.
pub(crate) fn block_matvec_pipeline(
    l: &Arc<CsrMatrix>,
    l_table: &Arc<Table>,
    x: &Arc<Vec<f64>>,
    m_cols: usize,
    row_bytes: &[u64],
    n: usize,
) -> (Pipeline, Collected<u64, Vec<f64>>) {
    let mut splits: Vec<Vec<(u64, u64)>> = Vec::new();
    let mut anchors: Vec<Vec<u8>> = Vec::new();
    for lo in (0..n).step_by(ROWS_PER_TASK) {
        let hi = (lo + ROWS_PER_TASK).min(n);
        let modelled: u64 = row_bytes[lo..hi].iter().sum::<u64>().max(1);
        splits.push(vec![(lo as u64, modelled)]);
        anchors.push(chunk_key(lo as u64, 0));
    }
    let l_cc = l.clone();
    let x_cc = x.clone();
    let pipeline = Pipeline::new("chebdav");
    let y = pipeline
        .read_table(l_table, splits, anchors)
        .map_kv(
            "chebdav-block-matvec",
            move |lo: u64, modelled: u64, out| -> Result<()> {
                let lo = lo as usize;
                let hi = (lo + ROWS_PER_TASK).min(n);
                // Charge the modelled L-row scan plus the broadcast block
                // (all m columns travel with every task).
                out.incr(
                    crate::mapreduce::names::EXTRA_INPUT_BYTES,
                    modelled + 8 * x_cc.len() as u64,
                );
                let nnz: usize = (lo..hi).map(|i| l_cc.row_nnz(i)).sum();
                out.incr(
                    crate::mapreduce::names::COMPUTE_US,
                    super::costmodel::units_to_us(
                        (nnz * m_cols) as u64,
                        super::costmodel::MATVEC_NNZ_PER_S,
                    ),
                );
                let y = l_cc.spmv_block_rows(&x_cc, m_cols, lo, hi);
                for off in 0..(hi - lo) {
                    out.emit(
                        (lo + off) as u64,
                        y[off * m_cols..(off + 1) * m_cols].to_vec(),
                    );
                }
                Ok(())
            },
        )
        .collect();
    (pipeline, y)
}

/// Run phase 2 with the block Chebyshev–Davidson backend: same Laplacian
/// build and embedding normalization as the lanczos path, but the operator
/// closure launches ONE job per application covering all m columns.
#[allow(clippy::too_many_arguments)]
pub fn run_chebdav_phase(
    services: &Services,
    s_table: &Arc<Table>,
    degrees: Arc<Vec<f64>>,
    n: usize,
    k: usize,
    eigen: &EigenConfig,
    seed: u64,
) -> Result<EigenOutput> {
    let mut stats = PhaseStats { name: "eigenvectors".into(), ..Default::default() };
    let (l, l_table) =
        lanczos_job::build_laplacian(services, s_table, &degrees, n, "L", &mut stats)?;
    let row_bytes = lanczos_job::modelled_row_bytes(&l, n);

    let mut block_runs: Vec<crate::dataflow::PlanStats> = Vec::new();
    let mut matvecs_batched = 0u64;
    {
        let services_c = services.clone();
        let l_c = l.clone();
        let l_table_c = l_table.clone();
        let row_bytes_c = row_bytes.clone();
        let mut block_op = |x: &[f64], m_cols: usize| -> Vec<f64> {
            let x_arc: Arc<Vec<f64>> = Arc::new(x.to_vec());
            let (pipeline, y_handle) =
                block_matvec_pipeline(&l_c, &l_table_c, &x_arc, m_cols, &row_bytes_c, n);
            let mut run = pipeline.run(&services_c).expect("block matvec job");
            let mut y = vec![0.0f64; n * m_cols];
            for (row, vals) in y_handle.take(&mut run) {
                let r = row as usize * m_cols;
                y[r..r + m_cols].copy_from_slice(&vals);
            }
            block_runs.push(run.stats);
            matvecs_batched += m_cols as u64;
            y
        };

        let opts = ChebDavOptions {
            block_size: eigen.block_size,
            filter_degree: eigen.filter_degree,
            max_outer: eigen.max_outer,
            tol: eigen.residual_tol,
            bound_steps: eigen.bound_steps,
            seed,
        };
        let master_start = std::time::Instant::now();
        let result = chebdav_smallest(n, k, &opts, &mut block_op)?;
        let master_wall = master_start.elapsed().as_secs_f64();

        // Separate master-side compute from the MR jobs it launched.
        let jobs_wall: f64 = block_runs.iter().map(|r| r.total_wall_s()).sum();
        for run_stats in &block_runs {
            stats.absorb_run(run_stats);
        }
        stats.absorb_master(
            (master_wall - jobs_wall).max(0.0),
            services.cluster.model().compute_scale,
        );

        // Row-normalize Z -> Y on the kernel runtime, like the lanczos path.
        let mut z = vec![0.0f32; n * k];
        for i in 0..n {
            for c in 0..k {
                z[i * k + c] = result.eigenvectors[i][c] as f32;
            }
        }
        let norm_start = std::time::Instant::now();
        let embedding = services.runtime.normalize_rows(&z, n, k)?;
        stats.absorb_master(
            norm_start.elapsed().as_secs_f64(),
            services.cluster.model().compute_scale,
        );

        stats.counters.incr(names::EIGEN_JOBS, stats.jobs as u64);
        stats.counters.incr(names::MATVECS_BATCHED, matvecs_batched);
        stats
            .counters
            .incr(names::CHEB_FILTER_DEGREE, eigen.filter_degree as u64);

        Ok(EigenOutput {
            embedding,
            eigenvalues: result.eigenvalues,
            steps: result.outer_iters,
            stats,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::runtime::KernelRuntime;

    #[test]
    fn kind_parse_round_trips() {
        for kind in [EigenSolverKind::Lanczos, EigenSolverKind::ChebDav] {
            assert_eq!(EigenSolverKind::parse(kind.as_str()), Some(kind));
        }
        assert_eq!(EigenSolverKind::parse("jacobi"), None);
        assert_eq!(EigenSolverKind::default(), EigenSolverKind::Lanczos);
    }

    #[test]
    fn config_defaults_keep_lanczos_behavior() {
        let c = EigenConfig::default();
        assert_eq!(c.solver, EigenSolverKind::Lanczos);
        assert_eq!(c.block_size, 8);
        assert_eq!(c.filter_degree, 8);
        assert_eq!(c.max_outer, 5);
        assert!(c.residual_tol > 0.0);
        // Worst case must undercut the paper config's 60 lanczos steps.
        assert_eq!(c.max_operator_jobs(), 4 + 5 * 9);
        assert!(c.max_operator_jobs() < 60);
    }

    #[test]
    fn block_matvec_pipeline_is_one_job_and_matches_oracle_bitwise() {
        let svc = Services::new(Cluster::new(2), Arc::new(KernelRuntime::native()));
        let n = 20;
        let l_table = svc.tables.create("L", 2).unwrap();
        // Symmetric tridiagonal-ish L surrogate with off-diagonal weights.
        let rows: Vec<Vec<(u32, f64)>> = (0..n)
            .map(|i| {
                let mut r = vec![(i as u32, 2.0 + i as f64 * 0.01)];
                if i > 0 {
                    r.push((i as u32 - 1, -0.7));
                }
                if i + 1 < n {
                    r.push((i as u32 + 1, -0.7));
                }
                r
            })
            .collect();
        let l = Arc::new(CsrMatrix::from_rows(n, rows));
        let row_bytes = lanczos_job::modelled_row_bytes(&l, n);
        let m_cols = 3;
        let x: Arc<Vec<f64>> = Arc::new(
            (0..n * m_cols).map(|i| (i as f64 * 0.37).cos()).collect(),
        );
        let (pipeline, y_handle) =
            block_matvec_pipeline(&l, &l_table, &x, m_cols, &row_bytes, n);
        let plan = pipeline.plan().unwrap();
        assert_eq!(plan.job_count(), 1, "block mat-vec is one map-only job");
        let mut run = plan.run(&svc).unwrap();
        let mut y = vec![0.0f64; n * m_cols];
        for (row, vals) in y_handle.take(&mut run) {
            let r = row as usize * m_cols;
            y[r..r + m_cols].copy_from_slice(&vals);
        }
        let oracle = l.spmv_block_rows(&x, m_cols, 0, n);
        let bits = |v: &[f64]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&y), bits(&oracle), "distributed == oracle bitwise");
    }
}
