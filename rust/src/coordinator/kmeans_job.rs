//! Phase 3 (paper §4.3.3): parallel K-means over the spectral embedding.
//!
//! The paper's loop, verbatim in structure:
//!
//! 1. The driver writes the initial centers to the DFS **center file**.
//! 2. Map: read the center file, assign each point of the split to the
//!    nearest center (the XLA `kmeans_step` kernel does a whole tile at
//!    once) and emit per-center partial sums + counts — the kernel output
//!    IS the combiner result, so the shuffle carries k records per task,
//!    not n.
//! 3. Reduce: sum partials per center, emit the new center.
//! 4. The driver rewrites the center file; stop when centers move less than
//!    `tol` or after `max_iters` (paper step 4).
//!
//! A final map-only job emits the assignment of every point.

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::mapreduce::{self, FnMapper, FnReducer, JobBuilder, TaskContext, Values};
use crate::util::bytes::{
    decode_f64_vec, decode_u64, encode_f64_vec, encode_u32, encode_u64,
};

use super::{PhaseStats, Services};

/// Points per map split.
pub const POINTS_PER_TASK: usize = 256;

/// Output of phase 3.
pub struct KmeansOutput {
    /// Final cluster label per point.
    pub labels: Vec<usize>,
    /// Final centers (k × d).
    pub centers: Vec<Vec<f64>>,
    /// Iterations executed (jobs, excluding the final assignment pass).
    pub iterations: usize,
    /// Whether movement dropped below tol.
    pub converged: bool,
    /// Phase timing.
    pub stats: PhaseStats,
}

/// Serialize centers into the DFS center file (paper's shared file).
fn write_center_file(services: &Services, path: &str, centers: &[Vec<f64>]) -> Result<()> {
    let mut bytes = Vec::new();
    bytes.extend_from_slice(&encode_u32(centers.len() as u32));
    for c in centers {
        bytes.extend_from_slice(&encode_f64_vec(c));
    }
    services.dfs.write_file(path, &bytes)
}

/// Read the center file back.
pub fn read_center_file(services: &Services, path: &str) -> Result<Vec<Vec<f64>>> {
    let bytes = services.dfs.read_file(path)?;
    let k = crate::util::bytes::decode_u32(&bytes) as usize;
    let mut off = 4;
    let mut centers = Vec::with_capacity(k);
    for _ in 0..k {
        let (c, used) = decode_f64_vec(&bytes[off..]);
        centers.push(c);
        off += used;
    }
    Ok(centers)
}

/// Split the n points into contiguous map splits.
fn point_splits(n: usize) -> Vec<Vec<(Vec<u8>, Vec<u8>)>> {
    let mut splits = Vec::new();
    for lo in (0..n).step_by(POINTS_PER_TASK) {
        let hi = (lo + POINTS_PER_TASK).min(n);
        splits.push(vec![(
            encode_u64(lo as u64).to_vec(),
            encode_u64(hi as u64).to_vec(),
        )]);
    }
    splits
}

/// Run phase 3 on the embedding (n × d row-major f32).
#[allow(clippy::too_many_arguments)]
pub fn run_kmeans_phase(
    services: &Services,
    embedding: Arc<Vec<f32>>,
    n: usize,
    d: usize,
    k: usize,
    max_iters: usize,
    tol: f64,
    seed: u64,
) -> Result<KmeansOutput> {
    if n == 0 || k == 0 || k > n {
        return Err(Error::MapReduce(format!("kmeans: bad n={n}, k={k}")));
    }
    let mut stats = PhaseStats { name: "kmeans".into(), ..Default::default() };
    let center_path = "/kmeans/centers";

    // Stage the embedding in the DFS so every point split can declare the
    // nodes holding its rows (paper §4.3.3: the samples live on HDFS).
    let emb_path = "/kmeans/embedding";
    let mut raw = Vec::with_capacity(embedding.len() * 4);
    for &x in embedding.iter() {
        raw.extend_from_slice(&x.to_le_bytes());
    }
    services.dfs.write_file(emb_path, &raw)?;
    let row_bytes = d * 4;
    let mut split_hosts: Vec<Vec<usize>> = Vec::new();
    for lo in (0..n).step_by(POINTS_PER_TASK) {
        let hi = (lo + POINTS_PER_TASK).min(n);
        split_hosts.push(services.dfs.range_hosts(
            emb_path,
            lo * row_bytes,
            hi * row_bytes,
        )?);
    }

    // Init: k-means++ over the embedding rows (driver side).
    let rows: Vec<Vec<f64>> = (0..n)
        .map(|i| (0..d).map(|c| embedding[i * d + c] as f64).collect())
        .collect();
    let t0 = std::time::Instant::now();
    let mut centers =
        crate::kmeans::init_centers(&rows, k, crate::kmeans::Init::PlusPlus, seed);
    stats.absorb_master(
        t0.elapsed().as_secs_f64(),
        services.cluster.model().compute_scale,
    );
    write_center_file(services, center_path, &centers)?;

    let mut iterations = 0;
    let mut converged = false;
    while iterations < max_iters {
        iterations += 1;
        let mut result =
            run_update_job(services, &embedding, n, d, k, center_path, &split_hosts)?;
        stats.absorb_job(&result);

        // New centers from reducer output (key = center index).
        let mut new_centers = centers.clone();
        for (key, value) in result.sorted_records() {
            let c = crate::util::bytes::decode_u32(&key) as usize;
            let (vals, _) = decode_f64_vec(&value);
            new_centers[c] = vals;
        }
        let movement = centers
            .iter()
            .zip(&new_centers)
            .map(|(a, b)| crate::linalg::vector::sq_dist(a, b).sqrt())
            .fold(0.0f64, f64::max);
        centers = new_centers;
        write_center_file(services, center_path, &centers)?;
        if movement < tol {
            converged = true;
            break;
        }
    }

    // Final assignment pass (map-only).
    let labels = run_assign_job(
        services,
        &embedding,
        n,
        d,
        k,
        center_path,
        &split_hosts,
        &mut stats,
    )?;
    Ok(KmeansOutput { labels, centers, iterations, converged, stats })
}

/// One assign+update iteration as an MR job.
#[allow(clippy::too_many_arguments)]
fn run_update_job(
    services: &Services,
    embedding: &Arc<Vec<f32>>,
    n: usize,
    d: usize,
    k: usize,
    center_path: &str,
    split_hosts: &[Vec<usize>],
) -> Result<mapreduce::JobResult> {
    let emb = embedding.clone();
    let dfs = services.dfs.clone();
    let rt = services.runtime.clone();
    let center_path = center_path.to_string();
    let mapper = Arc::new(FnMapper(
        move |key: &[u8], value: &[u8], ctx: &mut TaskContext| -> Result<()> {
            let lo = decode_u64(key) as usize;
            let hi = decode_u64(value) as usize;
            // Paper: "read the center file" at task start.
            let bytes = dfs.read_file(&center_path)?;
            // Embedding rows + center file read from the DFS; the scheduler
            // charges the split read at the attempt's locality tier.
            ctx.incr(
                crate::mapreduce::names::EXTRA_INPUT_BYTES,
                ((hi - lo) * d * 4 + bytes.len()) as u64,
            );
            let kk = crate::util::bytes::decode_u32(&bytes) as usize;
            let mut off = 4;
            let mut centers_flat = Vec::with_capacity(kk * d);
            for _ in 0..kk {
                let (c, used) = decode_f64_vec(&bytes[off..]);
                off += used;
                centers_flat.extend(c.into_iter().map(|x| x as f32));
            }
            let (_assign, sums, counts) = rt.kmeans_step(
                &emb[lo * d..hi * d],
                &centers_flat,
                hi - lo,
                kk,
                d,
            )?;
            ctx.incr(
                crate::mapreduce::names::COMPUTE_US,
                super::costmodel::units_to_us(
                    ((hi - lo) * kk * d) as u64,
                    super::costmodel::KM_POINTDIM_PER_S,
                ),
            );
            // Combiner output: one record per center.
            for c in 0..kk {
                let mut payload: Vec<f64> =
                    (0..d).map(|t| sums[c * d + t] as f64).collect();
                payload.push(counts[c] as f64);
                ctx.emit(encode_u32(c as u32).to_vec(), encode_f64_vec(&payload));
            }
            ctx.incr("KMEANS_POINTS", (hi - lo) as u64);
            Ok(())
        },
    ));
    let reducer = Arc::new(FnReducer(
        move |key: &[u8], values: &mut dyn Values, ctx: &mut TaskContext| -> Result<()> {
            let mut sums = vec![0.0f64; d];
            let mut count = 0.0f64;
            while let Some(v) = values.next_value() {
                let (payload, _) = decode_f64_vec(v);
                for t in 0..d {
                    sums[t] += payload[t];
                }
                count += payload[d];
            }
            if count > 0.0 {
                let center: Vec<f64> = sums.iter().map(|s| s / count).collect();
                ctx.emit(key.to_vec(), encode_f64_vec(&center));
            }
            // Empty cluster: emit nothing; the driver keeps the old center
            // (the paper's implicit behaviour).
            Ok(())
        },
    ));
    let job = JobBuilder::new("kmeans-update", point_splits(n), mapper)
        .split_hosts(split_hosts.to_vec())
        .reducer(reducer, services.cluster.num_slaves().min(k))
        .build();
    mapreduce::run(&services.cluster, &job)
}

/// Final assignment pass.
#[allow(clippy::too_many_arguments)]
fn run_assign_job(
    services: &Services,
    embedding: &Arc<Vec<f32>>,
    n: usize,
    d: usize,
    k: usize,
    center_path: &str,
    split_hosts: &[Vec<usize>],
    stats: &mut PhaseStats,
) -> Result<Vec<usize>> {
    let emb = embedding.clone();
    let dfs = services.dfs.clone();
    let rt = services.runtime.clone();
    let center_path = center_path.to_string();
    let mapper = Arc::new(FnMapper(
        move |key: &[u8], value: &[u8], ctx: &mut TaskContext| -> Result<()> {
            let lo = decode_u64(key) as usize;
            let hi = decode_u64(value) as usize;
            let bytes = dfs.read_file(&center_path)?;
            ctx.incr(
                crate::mapreduce::names::EXTRA_INPUT_BYTES,
                ((hi - lo) * d * 4 + bytes.len()) as u64,
            );
            let kk = crate::util::bytes::decode_u32(&bytes) as usize;
            let mut off = 4;
            let mut centers_flat = Vec::with_capacity(kk * d);
            for _ in 0..kk {
                let (c, used) = decode_f64_vec(&bytes[off..]);
                off += used;
                centers_flat.extend(c.into_iter().map(|x| x as f32));
            }
            ctx.incr(
                crate::mapreduce::names::COMPUTE_US,
                super::costmodel::units_to_us(
                    ((hi - lo) * kk * d) as u64,
                    super::costmodel::KM_POINTDIM_PER_S,
                ),
            );
            let (assign, _, _) =
                rt.kmeans_step(&emb[lo * d..hi * d], &centers_flat, hi - lo, kk, d)?;
            for (off_i, a) in assign.into_iter().enumerate() {
                ctx.emit(
                    encode_u64((lo + off_i) as u64).to_vec(),
                    encode_u32(a as u32).to_vec(),
                );
            }
            Ok(())
        },
    ));
    let _ = k;
    let job = JobBuilder::new("kmeans-assign", point_splits(n), mapper)
        .split_hosts(split_hosts.to_vec())
        .build();
    let result = mapreduce::run(&services.cluster, &job)?;
    stats.absorb_job(&result);
    let mut labels = vec![0usize; n];
    for part in &result.output {
        for (key, value) in part {
            labels[decode_u64(key) as usize] =
                crate::util::bytes::decode_u32(value) as usize;
        }
    }
    Ok(labels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::data::gaussian_blobs;
    use crate::eval::nmi;
    use crate::runtime::KernelRuntime;

    fn services(m: usize) -> Services {
        Services::new(Cluster::new(m), Arc::new(KernelRuntime::native()))
    }

    #[test]
    fn clusters_blobs_like_lloyd() {
        let ps = gaussian_blobs(400, 3, 4, 0.3, 12.0, 5);
        let svc = services(3);
        let flat: Vec<f32> = ps.points.iter().flatten().map(|&x| x as f32).collect();
        let out = run_kmeans_phase(
            &svc,
            Arc::new(flat),
            400,
            4,
            3,
            30,
            1e-6,
            7,
        )
        .unwrap();
        assert!(out.converged, "should converge on separated blobs");
        let score = nmi(&ps.labels, &out.labels);
        assert!(score > 0.98, "nmi={score}");
        // Oracle comparison: Lloyd from the same seed reaches the same NMI.
        let lr = crate::kmeans::lloyd(
            &ps.points, 3, 30, 1e-6, crate::kmeans::Init::PlusPlus, 7,
        );
        let lloyd_score = nmi(&ps.labels, &lr.labels);
        assert!((score - lloyd_score).abs() < 0.02, "{score} vs {lloyd_score}");
    }

    #[test]
    fn center_file_roundtrip() {
        let svc = services(2);
        let centers = vec![vec![1.0, 2.0], vec![-3.0, 0.5]];
        write_center_file(&svc, "/c", &centers).unwrap();
        assert_eq!(read_center_file(&svc, "/c").unwrap(), centers);
    }

    #[test]
    fn labels_in_range_and_every_cluster_used() {
        let ps = gaussian_blobs(300, 4, 4, 0.3, 12.0, 9);
        let svc = services(2);
        let flat: Vec<f32> = ps.points.iter().flatten().map(|&x| x as f32).collect();
        let out =
            run_kmeans_phase(&svc, Arc::new(flat), 300, 4, 4, 30, 1e-6, 3).unwrap();
        assert!(out.labels.iter().all(|&l| l < 4));
        let used: std::collections::HashSet<usize> =
            out.labels.iter().copied().collect();
        assert_eq!(used.len(), 4, "separated blobs should use all clusters");
    }

    #[test]
    fn iteration_cap_respected() {
        let ps = gaussian_blobs(120, 3, 2, 1.5, 2.0, 1); // overlapping blobs
        let svc = services(1);
        let flat: Vec<f32> = ps.points.iter().flatten().map(|&x| x as f32).collect();
        let out = run_kmeans_phase(
            &svc,
            Arc::new(flat),
            120,
            2,
            3,
            2, // cap at 2 iterations
            1e-12,
            1,
        )
        .unwrap();
        assert!(out.iterations <= 2);
        assert_eq!(out.stats.jobs, out.iterations + 1); // + assignment pass
    }

    #[test]
    fn rejects_degenerate_input() {
        let svc = services(1);
        assert!(
            run_kmeans_phase(&svc, Arc::new(vec![]), 0, 2, 2, 5, 1e-6, 1).is_err()
        );
        assert!(run_kmeans_phase(
            &svc,
            Arc::new(vec![0.0; 2]),
            1,
            2,
            5,
            5,
            1e-6,
            1
        )
        .is_err());
    }
}
