//! Phase 3 (paper §4.3.3): parallel K-means over the spectral embedding.
//!
//! The paper's loop, verbatim in structure:
//!
//! 1. The driver writes the initial centers to the DFS **center file**.
//! 2. Map: read the center file, assign each point of the split to the
//!    nearest center (the XLA `kmeans_step` kernel does a whole tile at
//!    once) and emit per-center partial sums + counts — the kernel output
//!    IS the combiner result, so the shuffle carries k records per task,
//!    not n.
//! 3. Reduce: sum partials per center, emit the new center.
//! 4. The driver rewrites the center file; stop when centers move less than
//!    `tol` or after `max_iters` (paper step 4).
//!
//! Each iteration is one `read_dfs(embedding) → map_kv(kmeans-update) →
//! group_reduce(center-avg) → collect` pipeline; the final assignment pass
//! is a map-only `read_dfs → map_kv(kmeans-assign) → collect` pipeline.
//! Split locality (the embedding rows' DFS byte ranges) rides the source.

use std::sync::Arc;

use crate::dataflow::{Collected, Group, Pipeline};
use crate::error::{Error, Result};
use crate::util::bytes::{decode_f64_vec, encode_f64_vec, encode_u32};

use super::{PhaseStats, Services};

/// Points per map split.
pub const POINTS_PER_TASK: usize = 256;

/// DFS path of the staged embedding (paper §4.3.3: samples live on HDFS).
pub(crate) const EMB_PATH: &str = "/kmeans/embedding";

/// Output of phase 3.
pub struct KmeansOutput {
    /// Final cluster label per point.
    pub labels: Vec<usize>,
    /// Final centers (k × d).
    pub centers: Vec<Vec<f64>>,
    /// Iterations executed (jobs, excluding the final assignment pass).
    pub iterations: usize,
    /// Whether movement dropped below tol.
    pub converged: bool,
    /// Phase timing.
    pub stats: PhaseStats,
}

/// Check a center matrix is well-formed — at least one center, uniform
/// nonzero dimension, all coordinates finite — returning `(k, d)`. The one
/// validation gate shared by the center-file codec below and the serving
/// layer's model-artifact loader.
pub fn validate_centers(centers: &[Vec<f64>]) -> Result<(usize, usize)> {
    let bad = |msg: String| Error::Data(format!("centers: {msg}"));
    let k = centers.len();
    if k == 0 {
        return Err(bad("no centers".into()));
    }
    let d = centers[0].len();
    if d == 0 {
        return Err(bad("zero-dimensional centers".into()));
    }
    for (i, c) in centers.iter().enumerate() {
        if c.len() != d {
            return Err(bad(format!(
                "center {i} has dimension {}, expected {d}",
                c.len()
            )));
        }
        if c.iter().any(|x| !x.is_finite()) {
            return Err(bad(format!("center {i} has a non-finite coordinate")));
        }
    }
    Ok((k, d))
}

/// Serialize a center matrix into the center-file wire format: a u32 count
/// followed by one length-prefixed f64 vector per center. The exact-f64
/// codec both phase 3 and the serving layer (`psch assign`) speak.
pub fn encode_centers(centers: &[Vec<f64>]) -> Result<Vec<u8>> {
    validate_centers(centers)?;
    let mut bytes = Vec::new();
    bytes.extend_from_slice(&encode_u32(centers.len() as u32));
    for c in centers {
        bytes.extend_from_slice(&encode_f64_vec(c));
    }
    Ok(bytes)
}

/// Decode a center-file payload back into a center matrix, with bounds and
/// shape validation (truncated payloads are errors, not panics).
pub fn decode_centers(bytes: &[u8]) -> Result<Vec<Vec<f64>>> {
    let bad = |msg: &str| Error::Data(format!("center file: {msg}"));
    if bytes.len() < 4 {
        return Err(bad("truncated count header"));
    }
    let k = crate::util::bytes::decode_u32(bytes) as usize;
    let mut off = 4;
    let mut centers = Vec::with_capacity(k);
    for _ in 0..k {
        if bytes.len() < off + 4 {
            return Err(bad("truncated center length"));
        }
        let len = crate::util::bytes::decode_u32(&bytes[off..]) as usize;
        if bytes.len() < off + 4 + len * 8 {
            return Err(bad("truncated center payload"));
        }
        let (c, used) = decode_f64_vec(&bytes[off..]);
        centers.push(c);
        off += used;
    }
    validate_centers(&centers)?;
    Ok(centers)
}

/// Serialize centers into the DFS center file (paper's shared file).
pub(crate) fn write_center_file(
    services: &Services,
    path: &str,
    centers: &[Vec<f64>],
) -> Result<()> {
    services.dfs.write_file(path, &encode_centers(centers)?)
}

/// Read the center file back.
pub fn read_center_file(services: &Services, path: &str) -> Result<Vec<Vec<f64>>> {
    decode_centers(&services.dfs.read_file(path)?)
}

/// Split the n points into contiguous typed map splits `(lo, hi)`.
fn point_splits(n: usize) -> Vec<Vec<(u64, u64)>> {
    let mut splits = Vec::new();
    for lo in (0..n).step_by(POINTS_PER_TASK) {
        let hi = (lo + POINTS_PER_TASK).min(n);
        splits.push(vec![(lo as u64, hi as u64)]);
    }
    splits
}

/// Stage the embedding in the DFS; returns the per-split byte ranges that
/// give every point split its preferred hosts.
pub(crate) fn stage_embedding(
    services: &Services,
    embedding: &Arc<Vec<f32>>,
    n: usize,
    d: usize,
) -> Result<Vec<Vec<(usize, usize)>>> {
    let mut raw = Vec::with_capacity(embedding.len() * 4);
    for &x in embedding.iter() {
        raw.extend_from_slice(&x.to_le_bytes());
    }
    services.dfs.write_file(EMB_PATH, &raw)?;
    let row_bytes = d * 4;
    Ok((0..n)
        .step_by(POINTS_PER_TASK)
        .map(|lo| {
            let hi = (lo + POINTS_PER_TASK).min(n);
            vec![(lo * row_bytes, hi * row_bytes)]
        })
        .collect())
}

/// Decode the center file payload into a flat f32 center matrix (the
/// kernel-facing view, routed through the shared [`decode_centers`]).
fn centers_from_bytes(bytes: &[u8], d: usize) -> Result<(usize, Vec<f32>)> {
    let centers = decode_centers(bytes)?;
    let kk = centers.len();
    let mut centers_flat = Vec::with_capacity(kk * d);
    for c in centers {
        centers_flat.extend(c.into_iter().map(|x| x as f32));
    }
    Ok((kk, centers_flat))
}

/// Build one assign+update iteration pipeline.
pub(crate) fn update_pipeline(
    services: &Services,
    embedding: &Arc<Vec<f32>>,
    n: usize,
    d: usize,
    k: usize,
    center_path: &str,
    ranges: &[Vec<(usize, usize)>],
) -> (Pipeline, Collected<u32, Vec<f64>>) {
    let emb = embedding.clone();
    let dfs = services.dfs.clone();
    let rt = services.runtime.clone();
    let center_path = center_path.to_string();
    let pipeline = Pipeline::new("kmeans");
    let centers = pipeline
        .read_dfs(EMB_PATH, point_splits(n), ranges.to_vec())
        .map_kv(
            "kmeans-update",
            move |lo: u64, hi: u64, out| -> Result<()> {
                let (lo, hi) = (lo as usize, hi as usize);
                // Paper: "read the center file" at task start.
                let bytes = dfs.read_file(&center_path)?;
                // Embedding rows + center file read from the DFS; the
                // scheduler charges the split read at the attempt's
                // locality tier.
                out.incr(
                    crate::mapreduce::names::EXTRA_INPUT_BYTES,
                    ((hi - lo) * d * 4 + bytes.len()) as u64,
                );
                let (kk, centers_flat) = centers_from_bytes(&bytes, d)?;
                let (_assign, sums, counts) = rt.kmeans_step(
                    &emb[lo * d..hi * d],
                    &centers_flat,
                    hi - lo,
                    kk,
                    d,
                )?;
                out.incr(
                    crate::mapreduce::names::COMPUTE_US,
                    super::costmodel::units_to_us(
                        ((hi - lo) * kk * d) as u64,
                        super::costmodel::KM_POINTDIM_PER_S,
                    ),
                );
                // Combiner output: one record per center.
                for c in 0..kk {
                    let mut payload: Vec<f64> =
                        (0..d).map(|t| sums[c * d + t] as f64).collect();
                    payload.push(counts[c] as f64);
                    out.emit(c as u32, payload);
                }
                out.incr("KMEANS_POINTS", (hi - lo) as u64);
                Ok(())
            },
        )
        .group_reduce("center-avg")
        .reducers(services.cluster.num_slaves().min(k))
        .reduce(
            move |key: u32, values: &mut Group<'_, Vec<f64>>, out| -> Result<()> {
                let mut sums = vec![0.0f64; d];
                let mut count = 0.0f64;
                let mut partials = 0u64;
                while let Some(payload) = values.next_value() {
                    for t in 0..d {
                        sums[t] += payload[t];
                    }
                    count += payload[d];
                    partials += 1;
                }
                // Modeled compute (partials × d point-dims) keeps the
                // reduce plan — and the trace built on it — deterministic.
                out.incr(
                    crate::mapreduce::names::COMPUTE_US,
                    super::costmodel::units_to_us(
                        partials * d as u64,
                        super::costmodel::KM_POINTDIM_PER_S,
                    ),
                );
                if count > 0.0 {
                    let center: Vec<f64> = sums.iter().map(|s| s / count).collect();
                    out.emit(key, center);
                }
                // Empty cluster: emit nothing; the driver keeps the old
                // center (the paper's implicit behaviour).
                Ok(())
            },
        )
        .collect();
    (pipeline, centers)
}

/// Build the final assignment pipeline (map-only).
pub(crate) fn assign_pipeline(
    services: &Services,
    embedding: &Arc<Vec<f32>>,
    n: usize,
    d: usize,
    center_path: &str,
    ranges: &[Vec<(usize, usize)>],
) -> (Pipeline, Collected<u64, u32>) {
    let emb = embedding.clone();
    let dfs = services.dfs.clone();
    let rt = services.runtime.clone();
    let center_path = center_path.to_string();
    let pipeline = Pipeline::new("kmeans-assign");
    let labels = pipeline
        .read_dfs(EMB_PATH, point_splits(n), ranges.to_vec())
        .map_kv(
            "kmeans-assign",
            move |lo: u64, hi: u64, out| -> Result<()> {
                let (lo, hi) = (lo as usize, hi as usize);
                let bytes = dfs.read_file(&center_path)?;
                out.incr(
                    crate::mapreduce::names::EXTRA_INPUT_BYTES,
                    ((hi - lo) * d * 4 + bytes.len()) as u64,
                );
                let (kk, centers_flat) = centers_from_bytes(&bytes, d)?;
                out.incr(
                    crate::mapreduce::names::COMPUTE_US,
                    super::costmodel::units_to_us(
                        ((hi - lo) * kk * d) as u64,
                        super::costmodel::KM_POINTDIM_PER_S,
                    ),
                );
                let (assign, _, _) =
                    rt.kmeans_step(&emb[lo * d..hi * d], &centers_flat, hi - lo, kk, d)?;
                for (off_i, a) in assign.into_iter().enumerate() {
                    out.emit((lo + off_i) as u64, a as u32);
                }
                Ok(())
            },
        )
        .collect();
    (pipeline, labels)
}

/// Run phase 3 on the embedding (n × d row-major f32).
#[allow(clippy::too_many_arguments)]
pub fn run_kmeans_phase(
    services: &Services,
    embedding: Arc<Vec<f32>>,
    n: usize,
    d: usize,
    k: usize,
    max_iters: usize,
    tol: f64,
    seed: u64,
) -> Result<KmeansOutput> {
    if n == 0 || k == 0 || k > n {
        return Err(Error::MapReduce(format!("kmeans: bad n={n}, k={k}")));
    }
    let mut stats = PhaseStats { name: "kmeans".into(), ..Default::default() };
    let center_path = "/kmeans/centers";

    // Stage the embedding so every point split can declare its hosts.
    let ranges = stage_embedding(services, &embedding, n, d)?;

    // Init: k-means++ over the embedding rows (driver side).
    let rows: Vec<Vec<f64>> = (0..n)
        .map(|i| (0..d).map(|c| embedding[i * d + c] as f64).collect())
        .collect();
    let t0 = std::time::Instant::now();
    let mut centers =
        crate::kmeans::init_centers(&rows, k, crate::kmeans::Init::PlusPlus, seed);
    stats.absorb_master(
        t0.elapsed().as_secs_f64(),
        services.cluster.model().compute_scale,
    );
    write_center_file(services, center_path, &centers)?;

    let mut iterations = 0;
    let mut converged = false;
    while iterations < max_iters {
        iterations += 1;
        let (pipeline, centers_handle) =
            update_pipeline(services, &embedding, n, d, k, center_path, &ranges);
        let mut run = pipeline.run(services)?;
        stats.absorb_run(&run.stats);

        // New centers from the collected reducer output (key = center idx).
        let mut new_centers = centers.clone();
        for (c, vals) in centers_handle.take(&mut run) {
            new_centers[c as usize] = vals;
        }
        // Squared movement vs squared threshold: sqrt is monotone, so the
        // convergence decision is unchanged while k sqrts per iteration go.
        let movement_sq = centers
            .iter()
            .zip(&new_centers)
            .map(|(a, b)| crate::linalg::vector::sq_dist(a, b))
            .fold(0.0f64, f64::max);
        centers = new_centers;
        write_center_file(services, center_path, &centers)?;
        if movement_sq < tol * tol {
            converged = true;
            break;
        }
    }

    // Final assignment pass (map-only).
    let (pipeline, labels_handle) =
        assign_pipeline(services, &embedding, n, d, center_path, &ranges);
    let mut run = pipeline.run(services)?;
    stats.absorb_run(&run.stats);
    let mut labels = vec![0usize; n];
    for (point, label) in labels_handle.take(&mut run) {
        labels[point as usize] = label as usize;
    }
    Ok(KmeansOutput { labels, centers, iterations, converged, stats })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::data::gaussian_blobs;
    use crate::eval::nmi;
    use crate::runtime::KernelRuntime;

    fn services(m: usize) -> Services {
        Services::new(Cluster::new(m), Arc::new(KernelRuntime::native()))
    }

    #[test]
    fn clusters_blobs_like_lloyd() {
        let ps = gaussian_blobs(400, 3, 4, 0.3, 12.0, 5);
        let svc = services(3);
        let flat: Vec<f32> = ps.points.iter().flatten().map(|&x| x as f32).collect();
        let out = run_kmeans_phase(
            &svc,
            Arc::new(flat),
            400,
            4,
            3,
            30,
            1e-6,
            7,
        )
        .unwrap();
        assert!(out.converged, "should converge on separated blobs");
        let score = nmi(&ps.labels, &out.labels);
        assert!(score > 0.98, "nmi={score}");
        // Oracle comparison: Lloyd from the same seed reaches the same NMI.
        let lr = crate::kmeans::lloyd(
            &ps.points, 3, 30, 1e-6, crate::kmeans::Init::PlusPlus, 7,
        );
        let lloyd_score = nmi(&ps.labels, &lr.labels);
        assert!((score - lloyd_score).abs() < 0.02, "{score} vs {lloyd_score}");
    }

    #[test]
    fn center_codec_validates_shape_and_truncation() {
        let centers = vec![vec![1.0, 2.0], vec![-3.0, 0.5]];
        let bytes = encode_centers(&centers).unwrap();
        assert_eq!(decode_centers(&bytes).unwrap(), centers);
        assert!(decode_centers(&bytes[..bytes.len() - 1]).is_err(), "truncated");
        assert!(decode_centers(&bytes[..3]).is_err(), "short header");
        assert!(encode_centers(&[]).is_err(), "no centers");
        assert!(encode_centers(&[vec![1.0], vec![1.0, 2.0]]).is_err(), "ragged");
        assert!(encode_centers(&[vec![f64::NAN]]).is_err(), "non-finite");
    }

    #[test]
    fn center_file_roundtrip() {
        let svc = services(2);
        let centers = vec![vec![1.0, 2.0], vec![-3.0, 0.5]];
        write_center_file(&svc, "/c", &centers).unwrap();
        assert_eq!(read_center_file(&svc, "/c").unwrap(), centers);
    }

    #[test]
    fn labels_in_range_and_every_cluster_used() {
        let ps = gaussian_blobs(300, 4, 4, 0.3, 12.0, 9);
        let svc = services(2);
        let flat: Vec<f32> = ps.points.iter().flatten().map(|&x| x as f32).collect();
        let out =
            run_kmeans_phase(&svc, Arc::new(flat), 300, 4, 4, 30, 1e-6, 3).unwrap();
        assert!(out.labels.iter().all(|&l| l < 4));
        let used: std::collections::HashSet<usize> =
            out.labels.iter().copied().collect();
        assert_eq!(used.len(), 4, "separated blobs should use all clusters");
    }

    #[test]
    fn iteration_cap_respected() {
        let ps = gaussian_blobs(120, 3, 2, 1.5, 2.0, 1); // overlapping blobs
        let svc = services(1);
        let flat: Vec<f32> = ps.points.iter().flatten().map(|&x| x as f32).collect();
        let out = run_kmeans_phase(
            &svc,
            Arc::new(flat),
            120,
            2,
            3,
            2, // cap at 2 iterations
            1e-12,
            1,
        )
        .unwrap();
        assert!(out.iterations <= 2);
        assert_eq!(out.stats.jobs, out.iterations + 1); // + assignment pass
    }

    #[test]
    fn update_pipeline_is_one_fused_job() {
        let svc = services(2);
        let emb = Arc::new(vec![0.5f32; 300 * 2]);
        let ranges = stage_embedding(&svc, &emb, 300, 2).unwrap();
        write_center_file(&svc, "/kmeans/centers", &[vec![0.0, 0.0], vec![1.0, 1.0]])
            .unwrap();
        let (pipeline, _centers) =
            update_pipeline(&svc, &emb, 300, 2, 2, "/kmeans/centers", &ranges);
        let plan = pipeline.plan().unwrap();
        assert_eq!(plan.job_count(), 1);
        assert!(plan.stage_summaries()[0].has_reduce);
    }

    #[test]
    fn rejects_degenerate_input() {
        let svc = services(1);
        assert!(
            run_kmeans_phase(&svc, Arc::new(vec![]), 0, 2, 2, 5, 1e-6, 1).is_err()
        );
        assert!(run_kmeans_phase(
            &svc,
            Arc::new(vec![0.0; 2]),
            1,
            2,
            5,
            5,
            1e-6,
            1
        )
        .is_err());
    }
}
