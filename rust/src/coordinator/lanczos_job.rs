//! Phase 2 (paper Alg. 4.3 / §4.3.2): parallel k smallest eigenvectors.
//!
//! Two stages, both expressed as [`crate::dataflow::Pipeline`]s:
//!
//! 1. **Laplacian build** — `read_table(S) → map_kv(normalize) →
//!    write_table(L)`: each task reads its rows of S from the table plus
//!    the broadcast degree vector, forms the L_sym entries
//!    `δ_ij − d_i^{-1/2} S_ij d_j^{-1/2}`, and the fused table-put stage
//!    writes them back to the `L` table (row-partitioned, the paper's
//!    "matrix L cut into lines stored in the HBase"). The two logical map
//!    ops fuse into ONE map-only job — the planner's map fusion at work.
//! 2. **Lanczos iteration** — the master runs the three-term recurrence;
//!    the `L·v` hot spot is one `read_table(L) → map_kv(spmv) → collect`
//!    pipeline per iteration: the vector v is *moved to the data*
//!    (captured by the map closure), each task computes its row range's
//!    partial products, and the master reassembles y. The tridiagonal T is
//!    solved on the master (tql2) and Ritz vectors are recovered against
//!    the stored basis.
//!
//! Like Hadoop's region cache, tasks read L through a shared in-memory CSR
//! snapshot built by stage 1 (the virtual-time model still charges each
//! task its input bytes — the data is *accounted* as read per job).

use std::sync::Arc;

use crate::dataflow::{Collected, Pipeline};
use crate::error::{Error, Result};
use crate::linalg::{lanczos_smallest, CsrMatrix, LanczosOptions};
use crate::table::Table;

use super::similarity_job::{chunk_key, parse_chunk_key};
use super::{PhaseStats, Services};

/// Rows per map task in the mat-vec jobs.
pub const ROWS_PER_TASK: usize = 256;

/// Output of phase 2.
pub struct EigenOutput {
    /// Row-normalized spectral embedding Y, n×k row-major f32.
    pub embedding: Vec<f32>,
    /// The k smallest eigenvalues.
    pub eigenvalues: Vec<f64>,
    /// Lanczos steps executed.
    pub steps: usize,
    /// Phase timing.
    pub stats: PhaseStats,
}

/// Row-range splits `[(lo, hi))` with their table anchor keys — the
/// `read_table` source input shared by both pipelines (anchors resolve to
/// the slave serving the region that owns the range's first row, how
/// Hadoop co-locates maps with HBase regions).
fn row_range_splits(n: usize) -> (Vec<Vec<(u64, u64)>>, Vec<Vec<u8>>) {
    let mut splits = Vec::new();
    let mut anchors = Vec::new();
    for lo in (0..n).step_by(ROWS_PER_TASK) {
        let hi = (lo + ROWS_PER_TASK).min(n);
        splits.push(vec![(lo as u64, hi as u64)]);
        anchors.push(chunk_key(lo as u64, 0));
    }
    (splits, anchors)
}

/// Build the Laplacian pipeline: `read_table(S) → map_kv(laplacian-build)
/// → write_table(L)` — two fusable map ops, one planned job.
pub(crate) fn laplacian_pipeline(
    s_table: &Arc<Table>,
    l_table: &Arc<Table>,
    dinv: &Arc<Vec<f64>>,
    n: usize,
) -> Pipeline {
    let (splits, anchors) = row_range_splits(n);
    let s_table_c = s_table.clone();
    let dinv_c = dinv.clone();
    let pipeline = Pipeline::new("laplacian");
    pipeline
        .read_table(s_table, splits, anchors)
        .map_kv(
            "laplacian-build",
            move |lo: u64, hi: u64, out| -> Result<()> {
                // Scan this row range of S: keys [lo||0, hi||0).
                let scan = s_table_c.scan(&chunk_key(lo, 0), &chunk_key(hi, 0));
                let mut bytes_read = 0u64;
                for (k, v) in scan {
                    let (row, cb) = parse_chunk_key(&k);
                    bytes_read += (k.len() + v.len()) as u64;
                    let entries = crate::util::bytes::decode_sparse_row(&v);
                    let i = row as usize;
                    let l_entries: Vec<(u32, f64)> = entries
                        .iter()
                        .map(|&(j, s)| {
                            let ju = j as usize;
                            let mut val = -dinv_c[i] * s * dinv_c[ju];
                            if ju == i {
                                val += 1.0;
                            }
                            (j, val)
                        })
                        .collect();
                    // The fused write_table stage puts this chunk and
                    // charges the write (EXTRA_OUTPUT_BYTES).
                    out.emit(
                        (row, cb),
                        crate::util::bytes::encode_sparse_row(&l_entries),
                    );
                }
                out.incr(crate::mapreduce::names::EXTRA_INPUT_BYTES, bytes_read);
                // ~12 bytes per stored entry: transform work at the
                // HBase-bound reference rate.
                out.incr(
                    crate::mapreduce::names::COMPUTE_US,
                    super::costmodel::units_to_us(
                        bytes_read / 12,
                        super::costmodel::LBUILD_NNZ_PER_S,
                    ),
                );
                Ok(())
            },
        )
        .write_table(l_table);
    pipeline
}

/// Stage 1: build the L table from the S table + degrees; returns the shared
/// CSR snapshot the mat-vec jobs read through plus the L table handle (its
/// region map seeds the iteration jobs' split locality). Shared with the
/// ChebDav backend in [`super::eigen`] — both solvers build L identically.
pub(crate) fn build_laplacian(
    services: &Services,
    s_table: &Arc<Table>,
    degrees: &Arc<Vec<f64>>,
    n: usize,
    l_table_name: &str,
    stats: &mut PhaseStats,
) -> Result<(Arc<CsrMatrix>, Arc<Table>)> {
    let l_table = services
        .tables
        .create(l_table_name, services.cluster.num_slaves())?;

    // d^{-1/2}, broadcast to every task.
    let dinv: Arc<Vec<f64>> = Arc::new(
        degrees
            .iter()
            .map(|&d| if d > 0.0 { 1.0 / d.sqrt() } else { 0.0 })
            .collect(),
    );

    let run = laplacian_pipeline(s_table, &l_table, &dinv, n)
        .run(services)?;
    stats.absorb_run(&run.stats);

    // Snapshot L into a CSR for the iteration jobs (HBase block cache role).
    let mut rows: Vec<Vec<(u32, f64)>> = vec![Vec::new(); n];
    for (k, v) in l_table.scan_all() {
        let (row, _cb) = parse_chunk_key(&k);
        rows[row as usize].extend(crate::util::bytes::decode_sparse_row(&v));
    }
    Ok((Arc::new(CsrMatrix::from_rows(n, rows)), l_table))
}

/// Build one mat-vec pipeline: `read_table(L) → map_kv(spmv) → collect`.
/// The split value carries the modelled L-row-range bytes the task will
/// "read" (EXTRA_INPUT_BYTES), exactly as the hand-wired job did.
pub(crate) fn matvec_pipeline(
    l: &Arc<CsrMatrix>,
    l_table: &Arc<Table>,
    v: &Arc<Vec<f64>>,
    row_bytes: &[u64],
    n: usize,
) -> (Pipeline, Collected<u64, f64>) {
    let mut splits: Vec<Vec<(u64, u64)>> = Vec::new();
    let mut anchors: Vec<Vec<u8>> = Vec::new();
    for lo in (0..n).step_by(ROWS_PER_TASK) {
        let hi = (lo + ROWS_PER_TASK).min(n);
        // The row-range bytes this task will scan from the L table.
        let modelled: u64 = row_bytes[lo..hi].iter().sum::<u64>().max(1);
        splits.push(vec![(lo as u64, modelled)]);
        anchors.push(chunk_key(lo as u64, 0));
    }
    let l_cc = l.clone();
    let v_cc = v.clone();
    let pipeline = Pipeline::new("lanczos");
    let y = pipeline
        .read_table(l_table, splits, anchors)
        .map_kv(
            "lanczos-matvec",
            move |lo: u64, modelled: u64, out| -> Result<()> {
                let lo = lo as usize;
                let hi = (lo + ROWS_PER_TASK).min(v_cc.len());
                // Charge the modelled L-row scan (HBase read) plus the
                // broadcast vector ("moving the vector to the data").
                out.incr(
                    crate::mapreduce::names::EXTRA_INPUT_BYTES,
                    modelled + 8 * v_cc.len() as u64,
                );
                let nnz: usize = (lo..hi).map(|i| l_cc.row_nnz(i)).sum();
                out.incr(
                    crate::mapreduce::names::COMPUTE_US,
                    super::costmodel::units_to_us(
                        nnz as u64,
                        super::costmodel::MATVEC_NNZ_PER_S,
                    ),
                );
                let y = l_cc.spmv_rows(&v_cc, lo, hi);
                for (off, yi) in y.into_iter().enumerate() {
                    out.emit((lo + off) as u64, yi);
                }
                Ok(())
            },
        )
        .collect();
    (pipeline, y)
}

/// Run phase 2 over the S table built by phase 1.
#[allow(clippy::too_many_arguments)]
pub fn run_eigen_phase(
    services: &Services,
    s_table: &Arc<Table>,
    degrees: Arc<Vec<f64>>,
    n: usize,
    k: usize,
    lanczos_steps: usize,
    seed: u64,
) -> Result<EigenOutput> {
    let mut stats = PhaseStats { name: "eigenvectors".into(), ..Default::default() };
    let (l, l_table) = build_laplacian(services, s_table, &degrees, n, "L", &mut stats)?;

    let row_bytes = modelled_row_bytes(&l, n);

    // Lanczos driver: each matvec is one MR job (one pipeline run).
    let mut matvec_runs: Vec<crate::dataflow::PlanStats> = Vec::new();
    {
        let services_c = services.clone();
        let l_c = l.clone();
        let l_table_c = l_table.clone();
        let row_bytes_c = row_bytes.clone();
        let mut matvec = |v: &[f64]| -> Vec<f64> {
            let v_arc: Arc<Vec<f64>> = Arc::new(v.to_vec());
            let (pipeline, y_handle) =
                matvec_pipeline(&l_c, &l_table_c, &v_arc, &row_bytes_c, n);
            let mut run = pipeline.run(&services_c).expect("matvec job");
            let mut y = vec![0.0f64; n];
            for (row, yi) in y_handle.take(&mut run) {
                y[row as usize] = yi;
            }
            matvec_runs.push(run.stats);
            y
        };

        let opts = LanczosOptions {
            max_steps: lanczos_steps.min(n),
            seed,
            ..Default::default()
        };
        let master_start = std::time::Instant::now();
        let result = lanczos_smallest(n, k, &opts, &mut matvec)?;
        let master_wall = master_start.elapsed().as_secs_f64();

        // Separate master-side compute from the MR jobs it launched.
        let jobs_wall: f64 = matvec_runs.iter().map(|r| r.total_wall_s()).sum();
        for run_stats in &matvec_runs {
            stats.absorb_run(run_stats);
        }
        stats.absorb_master(
            (master_wall - jobs_wall).max(0.0),
            services.cluster.model().compute_scale,
        );

        // Step 5: row-normalize Z -> Y on the XLA kernel.
        let mut z = vec![0.0f32; n * k];
        for i in 0..n {
            for c in 0..k {
                z[i * k + c] = result.eigenvectors[i][c] as f32;
            }
        }
        let norm_start = std::time::Instant::now();
        let embedding = services.runtime.normalize_rows(&z, n, k)?;
        stats.absorb_master(
            norm_start.elapsed().as_secs_f64(),
            services.cluster.model().compute_scale,
        );

        // Eigensolver counter family (see metrics::EigenSummary): every
        // phase job, and one mat-vec priced per matvec job (the ChebDav
        // backend prices m per job — that contrast is the whole point).
        stats
            .counters
            .incr(crate::mapreduce::names::EIGEN_JOBS, stats.jobs as u64);
        stats
            .counters
            .incr(crate::mapreduce::names::MATVECS_BATCHED, result.steps as u64);

        Ok(EigenOutput {
            embedding,
            eigenvalues: result.eigenvalues,
            steps: result.steps,
            stats,
        })
    }
}

/// Bytes each mat-vec task "reads" (its row range of L) for the cost model:
/// ~12 bytes per stored entry + 16 of key overhead per row. Shared by both
/// eigensolver backends.
pub(crate) fn modelled_row_bytes(l: &Arc<CsrMatrix>, n: usize) -> Vec<u64> {
    (0..n).map(|i| 12 * l.row(i).count() as u64 + 16).collect()
}

/// Convenience: dense f32 embedding rows as Vec<Vec<f64>> (tests/eval).
pub fn embedding_rows(embedding: &[f32], n: usize, k: usize) -> Vec<Vec<f64>> {
    (0..n)
        .map(|i| (0..k).map(|c| embedding[i * k + c] as f64).collect())
        .collect()
}

/// Guard: phase 2 needs phase 1's table.
pub fn open_similarity_table(services: &Services, name: &str) -> Result<Arc<Table>> {
    services.tables.open(name).map_err(|_| {
        Error::MapReduce(format!(
            "phase 2 requires the {name} table from phase 1 — run similarity first"
        ))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::data::gaussian_blobs;
    use crate::runtime::KernelRuntime;

    fn setup(n: usize, m: usize) -> (Services, Arc<Table>, Arc<Vec<f64>>, Vec<Vec<f64>>) {
        let ps = gaussian_blobs(n, 3, 4, 0.4, 8.0, 3);
        let svc = Services::new(Cluster::new(m), Arc::new(KernelRuntime::native()));
        let flat: Vec<f32> = ps.points.iter().flatten().map(|&x| x as f32).collect();
        let out = super::super::similarity_job::run_similarity_phase(
            &svc,
            Arc::new(flat),
            n,
            4,
            1.0,
            1e-8,
            "S",
        )
        .unwrap();
        let table = svc.tables.open("S").unwrap();
        (svc, table, Arc::new(out.degrees), ps.points)
    }

    #[test]
    fn eigenvalues_match_single_machine_lanczos() {
        let n = 200;
        let (svc, table, degrees, points) = setup(n, 2);
        let out = run_eigen_phase(&svc, &table, degrees, n, 3, 40, 7).unwrap();
        // Oracle: same algorithm fully in memory (f64 end to end).
        let s = crate::spectral::rbf_sparse(&points, 1.0, 1e-8);
        let l = crate::spectral::laplacian_sparse(&s);
        let opts = LanczosOptions { max_steps: 40, seed: 7, ..Default::default() };
        let oracle = lanczos_smallest(n, 3, &opts, |v| l.spmv(v)).unwrap();
        for i in 0..3 {
            assert!(
                (out.eigenvalues[i] - oracle.eigenvalues[i]).abs() < 1e-4,
                "eig {i}: {} vs {} (f32 table round-trip tolerance)",
                out.eigenvalues[i],
                oracle.eigenvalues[i]
            );
        }
        assert!(out.eigenvalues[0].abs() < 1e-6, "lambda_1(L_sym) = 0");
    }

    #[test]
    fn embedding_rows_unit_or_zero_norm() {
        let n = 150;
        let (svc, table, degrees, _) = setup(n, 3);
        let out = run_eigen_phase(&svc, &table, degrees, n, 3, 40, 7).unwrap();
        for i in 0..n {
            let norm: f32 = (0..3)
                .map(|c| out.embedding[i * 3 + c].powi(2))
                .sum::<f32>()
                .sqrt();
            assert!(
                (norm - 1.0).abs() < 1e-4 || norm == 0.0,
                "row {i} norm {norm}"
            );
        }
    }

    #[test]
    fn stats_cover_lanczos_jobs() {
        let n = 140;
        let (svc, table, degrees, _) = setup(n, 2);
        let out = run_eigen_phase(&svc, &table, degrees, n, 2, 30, 7).unwrap();
        // 1 laplacian-build + one matvec job per Lanczos step.
        assert_eq!(out.stats.jobs, 1 + out.steps);
        assert!(out.stats.virtual_s > 0.0);
    }

    #[test]
    fn laplacian_pipeline_fuses_build_and_table_put_into_one_job() {
        // The fusion proof on the Lanczos phase: two logical map ops
        // (normalize + table put), ONE planned job.
        let n = 140;
        let (svc, s_table, degrees, _) = setup(n, 2);
        let l_table = svc.tables.create("Lfuse", 2).unwrap();
        let dinv: Arc<Vec<f64>> =
            Arc::new(degrees.iter().map(|&d| 1.0 / d.sqrt()).collect());
        let pipeline = laplacian_pipeline(&s_table, &l_table, &dinv, n);
        let plan = pipeline.plan().unwrap();
        assert_eq!(plan.job_count(), 1, "fusion must collapse the map chain");
        let summaries = plan.stage_summaries();
        assert_eq!(summaries[0].fused_maps, 2, "normalize + table-put");
        assert!(!summaries[0].has_reduce, "map-only job");
        let run = plan.run(&svc).unwrap();
        assert_eq!(run.stats.jobs(), 1);
        assert!(
            !l_table.scan_all().is_empty(),
            "fused table-put stage must write L"
        );
    }

    #[test]
    fn missing_table_is_a_clear_error() {
        let svc = Services::new(Cluster::new(1), Arc::new(KernelRuntime::native()));
        let err = match open_similarity_table(&svc, "nope") {
            Err(e) => e,
            Ok(_) => panic!("expected missing-table error"),
        };
        assert!(err.to_string().contains("run similarity first"));
    }
}
