//! Phase 2 (paper Alg. 4.3 / §4.3.2): parallel k smallest eigenvectors.
//!
//! Two stages:
//!
//! 1. **Laplacian build** — a map-only job over row ranges: each task reads
//!    its rows of S from the table plus the broadcast degree vector, forms
//!    the L_sym entries `δ_ij − d_i^{-1/2} S_ij d_j^{-1/2}`, and writes them
//!    back to the `L` table (row-partitioned, the paper's "matrix L cut into
//!    lines stored in the HBase").
//! 2. **Lanczos iteration** — the master runs the three-term recurrence; the
//!    `L·v` hot spot is one MR map-only job per iteration: the vector v is
//!    *moved to the data* (captured by the map closure), each task computes
//!    its row range's partial products, and the master reassembles y. The
//!    tridiagonal T is solved on the master (tql2) and Ritz vectors are
//!    recovered against the stored basis.
//!
//! Like Hadoop's region cache, tasks read L through a shared in-memory CSR
//! snapshot built by stage 1 (the virtual-time model still charges each
//! task its input bytes — the data is *accounted* as read per job).

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::linalg::{lanczos_smallest, CsrMatrix, LanczosOptions};
use crate::mapreduce::{self, FnMapper, JobBuilder, TaskContext};
use crate::table::Table;
use crate::util::bytes::{decode_f64, decode_u64, encode_f64, encode_u64};

use super::similarity_job::{chunk_key, parse_chunk_key};
use super::{PhaseStats, Services};

/// Rows per map task in the mat-vec jobs.
pub const ROWS_PER_TASK: usize = 256;

/// Output of phase 2.
pub struct EigenOutput {
    /// Row-normalized spectral embedding Y, n×k row-major f32.
    pub embedding: Vec<f32>,
    /// The k smallest eigenvalues.
    pub eigenvalues: Vec<f64>,
    /// Lanczos steps executed.
    pub steps: usize,
    /// Phase timing.
    pub stats: PhaseStats,
}

/// Preferred host of a row-range split: the slave serving the table region
/// that owns the range's first row (how Hadoop co-locates maps with HBase
/// regions). Falls back to no preference if the key resolves nowhere.
fn row_range_hosts(table: &Table, lo: usize) -> Vec<usize> {
    match table.key_slave(&chunk_key(lo as u64, 0)) {
        Ok(slave) => vec![slave],
        Err(_) => Vec::new(),
    }
}

/// Stage 1: build the L table from the S table + degrees; returns the shared
/// CSR snapshot the mat-vec jobs read through plus the L table handle (its
/// region map seeds the iteration jobs' split locality).
fn build_laplacian(
    services: &Services,
    s_table: &Arc<Table>,
    degrees: &Arc<Vec<f64>>,
    n: usize,
    l_table_name: &str,
    stats: &mut PhaseStats,
) -> Result<(Arc<CsrMatrix>, Arc<Table>)> {
    let l_table = services
        .tables
        .create(l_table_name, services.cluster.num_slaves())?;
    let _nb = n.div_ceil(super::similarity_job::BLOCK);

    // d^{-1/2}, broadcast to every task.
    let dinv: Arc<Vec<f64>> = Arc::new(
        degrees
            .iter()
            .map(|&d| if d > 0.0 { 1.0 / d.sqrt() } else { 0.0 })
            .collect(),
    );

    // Map-only job: one split per row range, co-located with the S-table
    // region serving the range.
    let mut splits = Vec::new();
    let mut hosts = Vec::new();
    for lo in (0..n).step_by(ROWS_PER_TASK) {
        let hi = (lo + ROWS_PER_TASK).min(n);
        splits.push(vec![(
            encode_u64(lo as u64).to_vec(),
            encode_u64(hi as u64).to_vec(),
        )]);
        hosts.push(row_range_hosts(s_table, lo));
    }
    let s_table_c = s_table.clone();
    let l_table_c = l_table.clone();
    let dinv_c = dinv.clone();
    let mapper = Arc::new(FnMapper(
        move |key: &[u8], value: &[u8], ctx: &mut TaskContext| -> Result<()> {
            let lo = decode_u64(key) as usize;
            let hi = decode_u64(value) as usize;
            // Scan this row range of S: keys [lo||0, hi||0).
            let scan = s_table_c.scan(&chunk_key(lo as u64, 0), &chunk_key(hi as u64, 0));
            let mut bytes_read = 0u64;
            for (k, v) in scan {
                let (row, cb) = parse_chunk_key(&k);
                bytes_read += (k.len() + v.len()) as u64;
                let entries = crate::util::bytes::decode_sparse_row(&v);
                let i = row as usize;
                let l_entries: Vec<(u32, f64)> = entries
                    .iter()
                    .map(|&(j, s)| {
                        let ju = j as usize;
                        let mut val = -dinv_c[i] * s * dinv_c[ju];
                        if ju == i {
                            val += 1.0;
                        }
                        (j, val)
                    })
                    .collect();
                let payload = crate::util::bytes::encode_sparse_row(&l_entries);
                ctx.incr(
                    crate::mapreduce::names::EXTRA_OUTPUT_BYTES,
                    payload.len() as u64,
                );
                l_table_c.put(chunk_key(row, cb), payload)?;
            }
            ctx.incr(crate::mapreduce::names::EXTRA_INPUT_BYTES, bytes_read);
            // ~12 bytes per stored entry: transform work at the HBase-bound
            // reference rate.
            ctx.incr(
                crate::mapreduce::names::COMPUTE_US,
                super::costmodel::units_to_us(
                    bytes_read / 12,
                    super::costmodel::LBUILD_NNZ_PER_S,
                ),
            );
            Ok(())
        },
    ));
    let job = JobBuilder::new("laplacian-build", splits, mapper)
        .split_hosts(hosts)
        .build();
    let result = mapreduce::run(&services.cluster, &job)?;
    stats.absorb_job(&result);

    // Snapshot L into a CSR for the iteration jobs (HBase block cache role).
    let mut rows: Vec<Vec<(u32, f64)>> = vec![Vec::new(); n];
    for (k, v) in l_table.scan_all() {
        let (row, _cb) = parse_chunk_key(&k);
        rows[row as usize].extend(crate::util::bytes::decode_sparse_row(&v));
    }
    Ok((Arc::new(CsrMatrix::from_rows(n, rows)), l_table))
}

/// Run phase 2 over the S table built by phase 1.
#[allow(clippy::too_many_arguments)]
pub fn run_eigen_phase(
    services: &Services,
    s_table: &Arc<Table>,
    degrees: Arc<Vec<f64>>,
    n: usize,
    k: usize,
    lanczos_steps: usize,
    seed: u64,
) -> Result<EigenOutput> {
    let mut stats = PhaseStats { name: "eigenvectors".into(), ..Default::default() };
    let (l, l_table) = build_laplacian(services, s_table, &degrees, n, "L", &mut stats)?;

    // Bytes each mat-vec task "reads" (its row range of L) for the cost model.
    let row_bytes: Vec<u64> = (0..n)
        .map(|i| 12 * l.row(i).count() as u64 + 16)
        .collect();

    // Lanczos driver: each matvec is one MR job.
    let mut matvec_stats: Vec<crate::mapreduce::JobStats> = Vec::new();
    let mut matvec_counters = crate::mapreduce::Counters::default();
    {
        let cluster = services.cluster.clone();
        let l_c = l.clone();
        let l_table_c = l_table.clone();
        let row_bytes_c = row_bytes.clone();
        let mut matvec = |v: &[f64]| -> Vec<f64> {
            let v_arc: Arc<Vec<f64>> = Arc::new(v.to_vec());
            let mut splits = Vec::new();
            let mut hosts = Vec::new();
            for lo in (0..n).step_by(ROWS_PER_TASK) {
                let hi = (lo + ROWS_PER_TASK).min(n);
                // The row-range bytes this task will scan from the L table,
                // charged via EXTRA_INPUT_BYTES in the mapper.
                let modelled: u64 = row_bytes_c[lo..hi].iter().sum::<u64>().max(1);
                splits.push(vec![(
                    encode_u64(lo as u64).to_vec(),
                    encode_u64(modelled).to_vec(),
                )]);
                hosts.push(row_range_hosts(&l_table_c, lo));
            }
            let l_cc = l_c.clone();
            let v_cc = v_arc.clone();
            let mapper = Arc::new(FnMapper(
                move |key: &[u8], value: &[u8], ctx: &mut TaskContext| -> Result<()> {
                    let lo = decode_u64(key) as usize;
                    let hi = (lo + ROWS_PER_TASK).min(v_cc.len());
                    // Charge the modelled L-row scan (HBase read) plus the
                    // broadcast vector ("moving the vector to the data").
                    ctx.incr(
                        crate::mapreduce::names::EXTRA_INPUT_BYTES,
                        decode_u64(value) + 8 * v_cc.len() as u64,
                    );
                    let nnz: usize = (lo..hi).map(|i| l_cc.row_nnz(i)).sum();
                    ctx.incr(
                        crate::mapreduce::names::COMPUTE_US,
                        super::costmodel::units_to_us(
                            nnz as u64,
                            super::costmodel::MATVEC_NNZ_PER_S,
                        ),
                    );
                    let y = l_cc.spmv_rows(&v_cc, lo, hi);
                    for (off, yi) in y.into_iter().enumerate() {
                        ctx.emit(
                            encode_u64((lo + off) as u64).to_vec(),
                            encode_f64(yi).to_vec(),
                        );
                    }
                    Ok(())
                },
            ));
            let job = JobBuilder::new("lanczos-matvec", splits, mapper)
                .split_hosts(hosts)
                .build();
            let result = mapreduce::run(&cluster, &job).expect("matvec job");
            let mut y = vec![0.0f64; n];
            for part in &result.output {
                for (kk, vv) in part {
                    y[decode_u64(kk) as usize] = decode_f64(vv);
                }
            }
            matvec_counters.merge(&result.counters);
            matvec_stats.push(result.stats);
            y
        };

        let opts = LanczosOptions {
            max_steps: lanczos_steps.min(n),
            seed,
            ..Default::default()
        };
        let master_start = std::time::Instant::now();
        let result = lanczos_smallest(n, k, &opts, &mut matvec)?;
        let master_wall = master_start.elapsed().as_secs_f64();

        // Separate master-side compute from the MR jobs it launched.
        let jobs_wall: f64 = matvec_stats.iter().map(|s| s.wall_time_s).sum();
        for js in &matvec_stats {
            stats.absorb(js);
        }
        stats.absorb_counters(&matvec_counters);
        stats.absorb_master(
            (master_wall - jobs_wall).max(0.0),
            services.cluster.model().compute_scale,
        );

        // Step 5: row-normalize Z -> Y on the XLA kernel.
        let mut z = vec![0.0f32; n * k];
        for i in 0..n {
            for c in 0..k {
                z[i * k + c] = result.eigenvectors[i][c] as f32;
            }
        }
        let norm_start = std::time::Instant::now();
        let embedding = services.runtime.normalize_rows(&z, n, k)?;
        stats.absorb_master(
            norm_start.elapsed().as_secs_f64(),
            services.cluster.model().compute_scale,
        );

        Ok(EigenOutput {
            embedding,
            eigenvalues: result.eigenvalues,
            steps: result.steps,
            stats,
        })
    }
}

/// Convenience: dense f32 embedding rows as Vec<Vec<f64>> (tests/eval).
pub fn embedding_rows(embedding: &[f32], n: usize, k: usize) -> Vec<Vec<f64>> {
    (0..n)
        .map(|i| (0..k).map(|c| embedding[i * k + c] as f64).collect())
        .collect()
}

/// Guard: phase 2 needs phase 1's table.
pub fn open_similarity_table(services: &Services, name: &str) -> Result<Arc<Table>> {
    services.tables.open(name).map_err(|_| {
        Error::MapReduce(format!(
            "phase 2 requires the {name} table from phase 1 — run similarity first"
        ))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::data::gaussian_blobs;
    use crate::runtime::KernelRuntime;

    fn setup(n: usize, m: usize) -> (Services, Arc<Table>, Arc<Vec<f64>>, Vec<Vec<f64>>) {
        let ps = gaussian_blobs(n, 3, 4, 0.4, 8.0, 3);
        let svc = Services::new(Cluster::new(m), Arc::new(KernelRuntime::native()));
        let flat: Vec<f32> = ps.points.iter().flatten().map(|&x| x as f32).collect();
        let out = super::super::similarity_job::run_similarity_phase(
            &svc,
            Arc::new(flat),
            n,
            4,
            1.0,
            1e-8,
            "S",
        )
        .unwrap();
        let table = svc.tables.open("S").unwrap();
        (svc, table, Arc::new(out.degrees), ps.points)
    }

    #[test]
    fn eigenvalues_match_single_machine_lanczos() {
        let n = 200;
        let (svc, table, degrees, points) = setup(n, 2);
        let out = run_eigen_phase(&svc, &table, degrees, n, 3, 40, 7).unwrap();
        // Oracle: same algorithm fully in memory (f64 end to end).
        let s = crate::spectral::rbf_sparse(&points, 1.0, 1e-8);
        let l = crate::spectral::laplacian_sparse(&s);
        let opts = LanczosOptions { max_steps: 40, seed: 7, ..Default::default() };
        let oracle = lanczos_smallest(n, 3, &opts, |v| l.spmv(v)).unwrap();
        for i in 0..3 {
            assert!(
                (out.eigenvalues[i] - oracle.eigenvalues[i]).abs() < 1e-4,
                "eig {i}: {} vs {} (f32 table round-trip tolerance)",
                out.eigenvalues[i],
                oracle.eigenvalues[i]
            );
        }
        assert!(out.eigenvalues[0].abs() < 1e-6, "lambda_1(L_sym) = 0");
    }

    #[test]
    fn embedding_rows_unit_or_zero_norm() {
        let n = 150;
        let (svc, table, degrees, _) = setup(n, 3);
        let out = run_eigen_phase(&svc, &table, degrees, n, 3, 40, 7).unwrap();
        for i in 0..n {
            let norm: f32 = (0..3)
                .map(|c| out.embedding[i * 3 + c].powi(2))
                .sum::<f32>()
                .sqrt();
            assert!(
                (norm - 1.0).abs() < 1e-4 || norm == 0.0,
                "row {i} norm {norm}"
            );
        }
    }

    #[test]
    fn stats_cover_lanczos_jobs() {
        let n = 140;
        let (svc, table, degrees, _) = setup(n, 2);
        let out = run_eigen_phase(&svc, &table, degrees, n, 2, 30, 7).unwrap();
        // 1 laplacian-build + one matvec job per Lanczos step.
        assert_eq!(out.stats.jobs, 1 + out.steps);
        assert!(out.stats.virtual_s > 0.0);
    }

    #[test]
    fn missing_table_is_a_clear_error() {
        let svc = Services::new(Cluster::new(1), Arc::new(KernelRuntime::native()));
        let err = match open_similarity_table(&svc, "nope") {
            Err(e) => e,
            Ok(_) => panic!("expected missing-table error"),
        };
        assert!(err.to_string().contains("run similarity first"));
    }
}
