//! Reference-machine compute rates for the deterministic virtual clock.
//!
//! Every MR task in the pipeline self-reports its work in *units* (how many
//! similarity pairs it evaluated, how many sparse entries a mat-vec touched,
//! how many point×dim×center products an assignment computed). These rates
//! convert units to seconds **per execution slot of the paper's reference
//! slave** (Intel i5-2300, 2 map slots, JVM MapReduce over HBase — Ch. 5).
//!
//! Calibration (EXPERIMENTS.md §T1): each rate is fit so the m=1 column of
//! Table 5-1 is reproduced by the makespan model at n = 10,029; the rest of
//! the table — the speedup *shape* — is then a prediction of the model, not
//! a fit. The rates look slow because they absorb everything the paper's
//! stack did per record (JVM, serialization, HBase RPC), which is exactly
//! what "reference machine seconds" means here.

/// RBF similarity evaluations per slot-second (Alg. 4.2 inner loop,
/// fit to the paper's 1:41:46 for (n²+n)/2 ≈ 50.3M pairs on 2 slots).
pub const SIM_PAIRS_PER_S: f64 = 4_100.0;

/// Sparse mat-vec entries per slot-second (Alg. 4.3 `L·v` over HBase rows,
/// fit to the paper's 2:28:14 for ~60 iterations over ~25M stored entries).
pub const MATVEC_NNZ_PER_S: f64 = 188_000.0;

/// Laplacian-build entries per slot-second (same HBase-bound regime).
pub const LBUILD_NNZ_PER_S: f64 = 188_000.0;

/// K-means point×center×dim products per slot-second (paper's 0:28:45 —
/// small embeddings, per-record HBase/center-file overhead dominates).
pub const KM_POINTDIM_PER_S: f64 = 104.0;

/// Graph-mode similarity: edges ingested per slot-second.
pub const GRAPH_EDGES_PER_S: f64 = 20_000.0;

/// t-NN index full distance evaluations per slot-second. Slower than
/// [`SIM_PAIRS_PER_S`]: kd-tree leaf scans are pointer-chasing per-record
/// work in the paper's JVM/HBase regime, without the tiled RBF kernel's
/// locality.
pub const KNN_PAIRS_PER_S: f64 = 2_600.0;

/// Candidate pairs dismissed per slot-second by a bounding-box subtree
/// test or a partial-distance early exit — roughly an order cheaper than
/// pricing the pair in full.
pub const KNN_PRUNED_PAIRS_PER_S: f64 = 26_000.0;

/// Convert work units at a rate into modeled microseconds (>= 1 so the
/// engine can distinguish "modeled" from "not reported", and so per-record
/// charging in graph mode never rounds to zero).
pub fn units_to_us(units: u64, rate_per_s: f64) -> u64 {
    ((units as f64 / rate_per_s) * 1e6).ceil().max(1.0) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn units_to_us_monotone_and_positive() {
        assert_eq!(units_to_us(0, 100.0), 1);
        assert!(units_to_us(1000, 100.0) >= units_to_us(100, 100.0));
        // 4100 pairs at 4100/s = 1s.
        assert_eq!(units_to_us(4_100, SIM_PAIRS_PER_S), 1_000_000);
    }

    #[test]
    fn calibration_magnitudes_match_paper_m1() {
        // Phase 1: 50.3M pairs over 2 slots at SIM rate ~ paper's 6106s.
        let pairs = 10_029u64 * 10_030 / 2;
        let sim_s = pairs as f64 / SIM_PAIRS_PER_S / 2.0;
        assert!((sim_s - 6106.0).abs() / 6106.0 < 0.05, "sim m=1: {sim_s}");
    }
}
