//! XLA PJRT runtime: loads the AOT artifacts produced by
//! `python/compile/aot.py` and exposes typed kernel entry points.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file` → compile →
//! execute), never serialized protos — see DESIGN.md and
//! `/opt/xla-example/README.md` for the version gotcha. Python never runs at
//! request time: once `artifacts/` is built the Rust binary is
//! self-contained, and if artifacts are missing the [`native`] fallback
//! (identical math) keeps the system operational.

pub mod artifact;
pub mod executor;
pub mod native;

#[cfg(feature = "xla")]
pub use artifact::Artifact;
pub use artifact::{parse_manifest, InputSpec, InputValue, ManifestEntry};
pub use executor::{Backend, KernelRuntime};

/// Default artifact directory, overridable via `PSCH_ARTIFACTS`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("PSCH_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}
