//! AOT artifact loading: HLO text → PJRT executable, manifest validation.
//!
//! `artifacts/manifest.txt` (written by `python/compile/aot.py`) pins each
//! artifact's input shapes/dtypes and output arity; we parse it at load time
//! so a tile-geometry mismatch between the Python and Rust sides fails fast
//! with a clear error instead of a shape panic mid-job.

#[cfg(feature = "xla")]
use std::path::Path;
#[cfg(feature = "xla")]
use std::sync::Mutex;

use crate::error::{Error, Result};

/// Parsed input spec: dtype string + dims (empty = scalar).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InputSpec {
    /// Dtype name as emitted by jax (e.g. "float32", "int32").
    pub dtype: String,
    /// Dimensions; empty for scalars.
    pub dims: Vec<usize>,
}

impl InputSpec {
    /// Parse "float32[128x16]" / "float32[scalar]".
    pub fn parse(s: &str) -> Result<Self> {
        let (dtype, rest) = s
            .split_once('[')
            .ok_or_else(|| Error::Runtime(format!("bad input spec: {s:?}")))?;
        let dims_str = rest
            .strip_suffix(']')
            .ok_or_else(|| Error::Runtime(format!("bad input spec: {s:?}")))?;
        let dims = if dims_str == "scalar" {
            vec![]
        } else {
            dims_str
                .split('x')
                .map(|d| {
                    d.parse::<usize>()
                        .map_err(|_| Error::Runtime(format!("bad dim in {s:?}")))
                })
                .collect::<Result<Vec<_>>>()?
        };
        Ok(Self { dtype: dtype.to_string(), dims })
    }

    /// Total element count.
    pub fn elements(&self) -> usize {
        self.dims.iter().product::<usize>().max(1)
    }
}

/// One manifest entry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ManifestEntry {
    /// Artifact name (file stem).
    pub name: String,
    /// Input specs in call order.
    pub inputs: Vec<InputSpec>,
    /// Number of outputs in the result tuple.
    pub out_arity: usize,
}

/// Parse `artifacts/manifest.txt` (`name|spec;spec;...|arity` lines).
pub fn parse_manifest(text: &str) -> Result<Vec<ManifestEntry>> {
    let mut entries = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        let parts: Vec<&str> = line.split('|').collect();
        if parts.len() != 3 {
            return Err(Error::Runtime(format!(
                "manifest line {}: expected name|inputs|arity",
                lineno + 1
            )));
        }
        let inputs = parts[1]
            .split(';')
            .filter(|s| !s.is_empty())
            .map(InputSpec::parse)
            .collect::<Result<Vec<_>>>()?;
        let out_arity = parts[2]
            .parse()
            .map_err(|_| Error::Runtime(format!("manifest line {}: bad arity", lineno + 1)))?;
        entries.push(ManifestEntry { name: parts[0].to_string(), inputs, out_arity });
    }
    Ok(entries)
}

/// A loaded, compiled artifact.
///
/// PJRT executables are thread-safe to execute in the underlying C++ XLA
/// runtime, but the `xla` crate's wrapper holds raw pointers and is not
/// `Send`/`Sync`-marked; we serialize executions behind a mutex (the host
/// here is single-core anyway — virtual time is what models parallelism).
#[cfg(feature = "xla")]
pub struct Artifact {
    /// Manifest entry this artifact was validated against.
    pub meta: ManifestEntry,
    exe: Mutex<xla::PjRtLoadedExecutable>,
}

// SAFETY: PJRT executables/buffers are internally thread-safe in XLA's C++
// runtime; all mutation funnels through the mutex above. The wrapper types
// only lack the auto-traits because they hold raw pointers.
#[cfg(feature = "xla")]
unsafe impl Send for Artifact {}
#[cfg(feature = "xla")]
unsafe impl Sync for Artifact {}

#[cfg(feature = "xla")]
impl Artifact {
    /// Load + compile one HLO text artifact.
    pub fn load(client: &xla::PjRtClient, dir: &Path, meta: ManifestEntry) -> Result<Self> {
        let path = dir.join(format!("{}.hlo.txt", meta.name));
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        Ok(Self { meta, exe: Mutex::new(exe) })
    }

    /// Execute with f32/i32 input buffers; returns the output tuple as raw
    /// literals. Inputs are validated against the manifest spec.
    pub fn execute(&self, inputs: &[InputValue]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.meta.inputs.len() {
            return Err(Error::Runtime(format!(
                "{}: expected {} inputs, got {}",
                self.meta.name,
                self.meta.inputs.len(),
                inputs.len()
            )));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (v, spec) in inputs.iter().zip(&self.meta.inputs) {
            literals.push(v.to_literal(spec, &self.meta.name)?);
        }
        let exe = self.exe.lock().unwrap();
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        drop(exe);
        let outs = result.to_tuple()?;
        if outs.len() != self.meta.out_arity {
            return Err(Error::Runtime(format!(
                "{}: expected {} outputs, got {}",
                self.meta.name,
                self.meta.out_arity,
                outs.len()
            )));
        }
        Ok(outs)
    }
}

/// A typed input value for artifact execution.
#[derive(Debug, Clone)]
pub enum InputValue<'a> {
    /// f32 buffer (scalar when the spec says scalar and len == 1).
    F32(&'a [f32]),
    /// i32 buffer.
    I32(&'a [i32]),
}

#[cfg(feature = "xla")]
impl InputValue<'_> {
    fn to_literal(&self, spec: &InputSpec, name: &str) -> Result<xla::Literal> {
        let mismatch = |got: usize| {
            Error::Runtime(format!(
                "{name}: input len {got} != spec {:?} ({} elems)",
                spec.dims,
                spec.elements()
            ))
        };
        match self {
            InputValue::F32(data) => {
                if spec.dtype != "float32" {
                    return Err(Error::Runtime(format!(
                        "{name}: passing f32 to {} input",
                        spec.dtype
                    )));
                }
                if data.len() != spec.elements() {
                    return Err(mismatch(data.len()));
                }
                if spec.dims.is_empty() {
                    Ok(xla::Literal::from(data[0]))
                } else {
                    let dims: Vec<i64> = spec.dims.iter().map(|&d| d as i64).collect();
                    Ok(xla::Literal::vec1(data).reshape(&dims)?)
                }
            }
            InputValue::I32(data) => {
                if spec.dtype != "int32" {
                    return Err(Error::Runtime(format!(
                        "{name}: passing i32 to {} input",
                        spec.dtype
                    )));
                }
                if data.len() != spec.elements() {
                    return Err(mismatch(data.len()));
                }
                if spec.dims.is_empty() {
                    Ok(xla::Literal::from(data[0]))
                } else {
                    let dims: Vec<i64> = spec.dims.iter().map(|&d| d as i64).collect();
                    Ok(xla::Literal::vec1(data).reshape(&dims)?)
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn input_spec_parses() {
        let s = InputSpec::parse("float32[128x16]").unwrap();
        assert_eq!(s.dtype, "float32");
        assert_eq!(s.dims, vec![128, 16]);
        assert_eq!(s.elements(), 2048);
        let sc = InputSpec::parse("float32[scalar]").unwrap();
        assert!(sc.dims.is_empty());
        assert_eq!(sc.elements(), 1);
        assert!(InputSpec::parse("float32").is_err());
        assert!(InputSpec::parse("float32[axb]").is_err());
    }

    #[test]
    fn manifest_parses() {
        let text = "rbf_block|float32[128x16];float32[128x16];float32[scalar]|1\n\
                    kmeans_step|float32[256x16];float32[16x16];float32[256]|3\n";
        let m = parse_manifest(text).unwrap();
        assert_eq!(m.len(), 2);
        assert_eq!(m[0].name, "rbf_block");
        assert_eq!(m[0].inputs.len(), 3);
        assert_eq!(m[1].out_arity, 3);
        assert!(parse_manifest("bad line\n").is_err());
        assert!(parse_manifest("a|b|c\n").is_err());
    }
}
