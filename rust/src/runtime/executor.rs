//! Typed kernel entry points with tile padding: the bridge between the
//! coordinator's arbitrary problem sizes and the artifacts' fixed AOT tile
//! geometry (PJRT compiles one executable per static shape).
//!
//! Geometry must agree with `python/compile/model.py::ENTRY_POINTS`:
//!
//! | kernel          | tile shape                         |
//! |-----------------|------------------------------------|
//! | rbf_block       | x,y: 128×16, gamma scalar → 128×128 |
//! | matvec_block    | A: 256×256, v: 256 → 256            |
//! | laplacian_block | S: 256×256, dinv: 256, flag → 256×256 |
//! | kmeans_step     | P: 256×16, C: 16×16, mask: 256      |
//! | normalize_rows  | Z: 128×16 → 128×16                  |
//! | degree_rowsum   | S: 128×128 → 128                    |
//!
//! Inputs larger than a tile are decomposed into tiles; smaller ones are
//! zero-padded (sentinel-padded for k-means centers) and outputs sliced back.
//!
//! The XLA/PJRT backend is compiled only with the `xla` cargo feature (the
//! offline image has no `xla` crate); without it [`KernelRuntime::load`]
//! errors and [`KernelRuntime::auto`] falls back to the native kernels.

#[cfg(feature = "xla")]
use std::collections::HashMap;
use std::path::Path;

use crate::error::{Error, Result};

#[cfg(feature = "xla")]
use super::artifact::{parse_manifest, Artifact, InputValue};
use super::native;

/// RBF tile rows/cols.
pub const RBF_TILE: usize = 128;
/// Feature dim every kernel is padded to.
pub const PAD_DIM: usize = 16;
/// Mat-vec / Laplacian block edge.
pub const MV_BLOCK: usize = 256;
/// K-means points-per-tile.
pub const KM_PTS: usize = 256;
/// K-means max (padded) center count.
pub const KM_K: usize = 16;
/// Row-normalization tile rows.
pub const NORM_ROWS: usize = 128;
/// Sentinel coordinate for padding k-means centers: far from all real data
/// but small enough that squared distances stay finite in f32.
pub const CENTER_SENTINEL: f32 = 1e9;

/// Which backend executes the kernels.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// AOT-compiled XLA artifacts via PJRT.
    Xla,
    /// Native Rust fallback (same math, used for parity tests too).
    Native,
}

#[cfg(feature = "xla")]
struct ClientHolder(#[allow(dead_code)] xla::PjRtClient);
// SAFETY: the PJRT CPU client is internally synchronized; the wrapper type
// only lacks auto-traits because it holds raw pointers.
#[cfg(feature = "xla")]
unsafe impl Send for ClientHolder {}
#[cfg(feature = "xla")]
unsafe impl Sync for ClientHolder {}

/// Kernel runtime: owns the PJRT client + compiled artifacts (or nothing,
/// for the native backend). Shared across map tasks via `Arc`.
pub struct KernelRuntime {
    backend: Backend,
    #[cfg(feature = "xla")]
    _client: Option<ClientHolder>,
    #[cfg(feature = "xla")]
    artifacts: HashMap<String, Artifact>,
}

impl KernelRuntime {
    /// Load every artifact listed in `dir/manifest.txt` and compile it on a
    /// fresh PJRT CPU client.
    #[cfg(feature = "xla")]
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest_path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            Error::Runtime(format!("cannot read {}: {e}", manifest_path.display()))
        })?;
        let entries = parse_manifest(&text)?;
        let client = xla::PjRtClient::cpu()?;
        let mut artifacts = HashMap::new();
        for entry in entries {
            let name = entry.name.clone();
            let artifact = Artifact::load(&client, dir, entry)?;
            artifacts.insert(name, artifact);
        }
        Ok(Self {
            backend: Backend::Xla,
            _client: Some(ClientHolder(client)),
            artifacts,
        })
    }

    /// Without the `xla` feature there is nothing to load.
    #[cfg(not(feature = "xla"))]
    pub fn load(dir: &Path) -> Result<Self> {
        Err(Error::Runtime(format!(
            "{}: XLA backend not compiled in (build with --features xla)",
            dir.display()
        )))
    }

    /// Native-only runtime (no artifacts needed).
    pub fn native() -> Self {
        Self {
            backend: Backend::Native,
            #[cfg(feature = "xla")]
            _client: None,
            #[cfg(feature = "xla")]
            artifacts: HashMap::new(),
        }
    }

    /// Try XLA, fall back to native with a log line.
    pub fn auto(dir: &Path) -> Self {
        match Self::load(dir) {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("psch: artifacts unavailable ({e}); using native kernels");
                Self::native()
            }
        }
    }

    /// Active backend.
    pub fn backend(&self) -> Backend {
        self.backend
    }

    #[cfg(feature = "xla")]
    fn artifact(&self, name: &str) -> Result<&Artifact> {
        self.artifacts
            .get(name)
            .ok_or_else(|| Error::Runtime(format!("artifact {name} not loaded")))
    }

    // ------------------------------------------------------------------
    // RBF similarity tile
    // ------------------------------------------------------------------

    /// S[i,j] = exp(-gamma ||x_i - y_j||²) for x (p,d), y (q,d) row-major.
    /// Requires d <= PAD_DIM on the XLA backend.
    pub fn rbf_tile(
        &self,
        x: &[f32],
        y: &[f32],
        p: usize,
        q: usize,
        d: usize,
        gamma: f32,
    ) -> Result<Vec<f32>> {
        if self.backend == Backend::Native {
            return Ok(native::rbf_block(x, y, p, q, d, gamma));
        }
        #[cfg(not(feature = "xla"))]
        unreachable!("Xla backend cannot be constructed without the xla feature");
        #[cfg(feature = "xla")]
        {
            if d > PAD_DIM {
                return Err(Error::Runtime(format!(
                    "rbf_tile: d={d} exceeds padded dim {PAD_DIM}"
                )));
            }
            let artifact = self.artifact("rbf_block")?;
            let mut out = vec![0.0f32; p * q];
            let mut xt = vec![0.0f32; RBF_TILE * PAD_DIM];
            let mut yt = vec![0.0f32; RBF_TILE * PAD_DIM];
            for bi in (0..p).step_by(RBF_TILE) {
                let pi = (p - bi).min(RBF_TILE);
                pad_rows(&mut xt, &x[bi * d..], pi, d, PAD_DIM);
                for bj in (0..q).step_by(RBF_TILE) {
                    let qj = (q - bj).min(RBF_TILE);
                    pad_rows(&mut yt, &y[bj * d..], qj, d, PAD_DIM);
                    let outs = artifact.execute(&[
                        InputValue::F32(&xt),
                        InputValue::F32(&yt),
                        InputValue::F32(&[gamma]),
                    ])?;
                    let tile = outs[0].to_vec::<f32>()?;
                    for i in 0..pi {
                        for j in 0..qj {
                            out[(bi + i) * q + (bj + j)] = tile[i * RBF_TILE + j];
                        }
                    }
                }
            }
            Ok(out)
        }
    }

    // ------------------------------------------------------------------
    // Mat-vec over a dense row block
    // ------------------------------------------------------------------

    /// y = A v for row-major A (r, c).
    pub fn matvec(&self, a: &[f32], v: &[f32], r: usize, c: usize) -> Result<Vec<f32>> {
        if self.backend == Backend::Native {
            return Ok(native::matvec_block(a, v, r, c));
        }
        #[cfg(not(feature = "xla"))]
        unreachable!("Xla backend cannot be constructed without the xla feature");
        #[cfg(feature = "xla")]
        {
            let artifact = self.artifact("matvec_block")?;
            let mut out = vec![0.0f32; r];
            let mut at = vec![0.0f32; MV_BLOCK * MV_BLOCK];
            let mut vt = vec![0.0f32; MV_BLOCK];
            for bi in (0..r).step_by(MV_BLOCK) {
                let ri = (r - bi).min(MV_BLOCK);
                for bj in (0..c).step_by(MV_BLOCK) {
                    let cj = (c - bj).min(MV_BLOCK);
                    // Pack the (ri, cj) sub-block of A.
                    at.fill(0.0);
                    for i in 0..ri {
                        let src = &a[(bi + i) * c + bj..(bi + i) * c + bj + cj];
                        at[i * MV_BLOCK..i * MV_BLOCK + cj].copy_from_slice(src);
                    }
                    vt.fill(0.0);
                    vt[..cj].copy_from_slice(&v[bj..bj + cj]);
                    let outs = artifact
                        .execute(&[InputValue::F32(&at), InputValue::F32(&vt)])?;
                    let block = outs[0].to_vec::<f32>()?;
                    for i in 0..ri {
                        out[bi + i] += block[i];
                    }
                }
            }
            Ok(out)
        }
    }

    // ------------------------------------------------------------------
    // Normalized-Laplacian tile
    // ------------------------------------------------------------------

    /// L tile = is_diag·I − diag(dinv_r)·S·diag(dinv_c), S is (n, n) with
    /// n <= MV_BLOCK (one table block).
    pub fn laplacian_tile(
        &self,
        s: &[f32],
        dinv_r: &[f32],
        dinv_c: &[f32],
        n: usize,
        is_diag: bool,
    ) -> Result<Vec<f32>> {
        let flag = if is_diag { 1.0f32 } else { 0.0 };
        if self.backend == Backend::Native {
            return Ok(native::laplacian_block(s, dinv_r, dinv_c, n, n, flag));
        }
        #[cfg(not(feature = "xla"))]
        unreachable!("Xla backend cannot be constructed without the xla feature");
        #[cfg(feature = "xla")]
        {
            if n > MV_BLOCK {
                return Err(Error::Runtime(format!(
                    "laplacian_tile: n={n} exceeds block {MV_BLOCK}"
                )));
            }
            let artifact = self.artifact("laplacian_block")?;
            let mut st = vec![0.0f32; MV_BLOCK * MV_BLOCK];
            for i in 0..n {
                st[i * MV_BLOCK..i * MV_BLOCK + n]
                    .copy_from_slice(&s[i * n..(i + 1) * n]);
            }
            let mut dr = vec![0.0f32; MV_BLOCK];
            dr[..n].copy_from_slice(dinv_r);
            let mut dc = vec![0.0f32; MV_BLOCK];
            dc[..n].copy_from_slice(dinv_c);
            let outs = artifact.execute(&[
                InputValue::F32(&st),
                InputValue::F32(&dr),
                InputValue::F32(&dc),
                InputValue::F32(&[flag]),
            ])?;
            let full = outs[0].to_vec::<f32>()?;
            let mut out = vec![0.0f32; n * n];
            for i in 0..n {
                out[i * n..(i + 1) * n]
                    .copy_from_slice(&full[i * MV_BLOCK..i * MV_BLOCK + n]);
            }
            Ok(out)
        }
    }

    // ------------------------------------------------------------------
    // K-means assignment + partial sums
    // ------------------------------------------------------------------

    /// One k-means step over `points` (p, d) with `centers` (k, d).
    /// Returns (assign (p,), sums (k, d), counts (k,)).
    pub fn kmeans_step(
        &self,
        points: &[f32],
        centers: &[f32],
        p: usize,
        k: usize,
        d: usize,
    ) -> Result<(Vec<i32>, Vec<f32>, Vec<f32>)> {
        if self.backend == Backend::Native {
            let mask = vec![1.0f32; p];
            return Ok(native::kmeans_step(points, centers, &mask, p, k, d));
        }
        #[cfg(not(feature = "xla"))]
        unreachable!("Xla backend cannot be constructed without the xla feature");
        #[cfg(feature = "xla")]
        {
            if d > PAD_DIM || k > KM_K {
                return Err(Error::Runtime(format!(
                    "kmeans_step: d={d} (max {PAD_DIM}) or k={k} (max {KM_K}) too large"
                )));
            }
            let artifact = self.artifact("kmeans_step")?;
            // Pad centers: real ones zero-extended in dim, fake ones pushed to a
            // far sentinel so no real point ever picks them.
            let mut ct = vec![0.0f32; KM_K * PAD_DIM];
            for ci in 0..KM_K {
                if ci < k {
                    ct[ci * PAD_DIM..ci * PAD_DIM + d]
                        .copy_from_slice(&centers[ci * d..(ci + 1) * d]);
                } else {
                    ct[ci * PAD_DIM..(ci + 1) * PAD_DIM].fill(CENTER_SENTINEL);
                }
            }
            let mut assign = vec![0i32; p];
            let mut sums = vec![0.0f32; k * d];
            let mut counts = vec![0.0f32; k];
            let mut pt = vec![0.0f32; KM_PTS * PAD_DIM];
            let mut mask = vec![0.0f32; KM_PTS];
            for b in (0..p).step_by(KM_PTS) {
                let pb = (p - b).min(KM_PTS);
                pad_rows(&mut pt, &points[b * d..], pb, d, PAD_DIM);
                mask.fill(0.0);
                mask[..pb].fill(1.0);
                let outs = artifact.execute(&[
                    InputValue::F32(&pt),
                    InputValue::F32(&ct),
                    InputValue::F32(&mask),
                ])?;
                let a = outs[0].to_vec::<i32>()?;
                let s = outs[1].to_vec::<f32>()?;
                let c = outs[2].to_vec::<f32>()?;
                assign[b..b + pb].copy_from_slice(&a[..pb]);
                for ci in 0..k {
                    counts[ci] += c[ci];
                    for t in 0..d {
                        sums[ci * d + t] += s[ci * PAD_DIM + t];
                    }
                }
            }
            Ok((assign, sums, counts))
        }
    }

    // ------------------------------------------------------------------
    // Row normalization
    // ------------------------------------------------------------------

    /// Row-wise L2 normalization of Z (r, d); zero rows stay zero.
    pub fn normalize_rows(&self, z: &[f32], r: usize, d: usize) -> Result<Vec<f32>> {
        if self.backend == Backend::Native {
            return Ok(native::normalize_rows(z, r, d));
        }
        #[cfg(not(feature = "xla"))]
        unreachable!("Xla backend cannot be constructed without the xla feature");
        #[cfg(feature = "xla")]
        {
            if d > PAD_DIM {
                return Err(Error::Runtime(format!(
                    "normalize_rows: d={d} exceeds padded dim {PAD_DIM}"
                )));
            }
            let artifact = self.artifact("normalize_rows")?;
            let mut out = vec![0.0f32; r * d];
            let mut zt = vec![0.0f32; NORM_ROWS * PAD_DIM];
            for b in (0..r).step_by(NORM_ROWS) {
                let rb = (r - b).min(NORM_ROWS);
                pad_rows(&mut zt, &z[b * d..], rb, d, PAD_DIM);
                let outs = artifact.execute(&[InputValue::F32(&zt)])?;
                let tile = outs[0].to_vec::<f32>()?;
                for i in 0..rb {
                    out[(b + i) * d..(b + i + 1) * d]
                        .copy_from_slice(&tile[i * PAD_DIM..i * PAD_DIM + d]);
                }
            }
            Ok(out)
        }
    }
}

/// Pack `rows` rows of width `d` from `src` into `dst` (row width `pad_d`),
/// zero-filling the remainder of `dst`.
#[cfg_attr(not(feature = "xla"), allow(dead_code))]
fn pad_rows(dst: &mut [f32], src: &[f32], rows: usize, d: usize, pad_d: usize) {
    dst.fill(0.0);
    for i in 0..rows {
        dst[i * pad_d..i * pad_d + d].copy_from_slice(&src[i * d..(i + 1) * d]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_backend_runs_everything() {
        let rt = KernelRuntime::native();
        assert_eq!(rt.backend(), Backend::Native);
        let x = vec![0.0, 0.0, 1.0, 0.0];
        let s = rt.rbf_tile(&x, &x, 2, 2, 2, 1.0).unwrap();
        assert!((s[0] - 1.0).abs() < 1e-6);
        assert!((s[1] - (-1.0f32).exp()).abs() < 1e-6);

        let a = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(rt.matvec(&a, &[1.0, 1.0], 2, 2).unwrap(), vec![3.0, 7.0]);

        let (assign, sums, counts) = rt
            .kmeans_step(&[0.0, 0.0, 5.0, 5.0], &[0.0, 0.0, 5.0, 5.0], 2, 2, 2)
            .unwrap();
        assert_eq!(assign, vec![0, 1]);
        assert_eq!(counts, vec![1.0, 1.0]);
        assert_eq!(sums, vec![0.0, 0.0, 5.0, 5.0]);

        let y = rt.normalize_rows(&[3.0, 4.0], 1, 2).unwrap();
        assert!((y[0] - 0.6).abs() < 1e-6);
    }

    #[test]
    fn pad_rows_zero_fills() {
        let mut dst = vec![9.0f32; 8];
        pad_rows(&mut dst, &[1.0, 2.0, 3.0, 4.0], 2, 2, 4);
        assert_eq!(dst, vec![1.0, 2.0, 0.0, 0.0, 3.0, 4.0, 0.0, 0.0]);
    }

    #[test]
    fn missing_artifact_dir_falls_back() {
        let rt = KernelRuntime::auto(Path::new("/nonexistent/dir"));
        assert_eq!(rt.backend(), Backend::Native);
    }
}
