//! Native Rust implementations of every AOT kernel (f32, same math as
//! `python/compile/kernels/ref.py`).
//!
//! Two roles: (1) parity oracles — the XLA artifacts are asserted to match
//! these bit-for-tolerance in tests; (2) fallback when `artifacts/` is
//! missing or stale, so the coordinator always runs.

/// S[i,j] = exp(-gamma * ||x_i - y_j||^2). x is (p, d), y is (q, d) row-major.
pub fn rbf_block(x: &[f32], y: &[f32], p: usize, q: usize, d: usize, gamma: f32) -> Vec<f32> {
    assert_eq!(x.len(), p * d);
    assert_eq!(y.len(), q * d);
    let mut out = vec![0.0f32; p * q];
    for i in 0..p {
        let xi = &x[i * d..(i + 1) * d];
        for j in 0..q {
            let yj = &y[j * d..(j + 1) * d];
            let mut d2 = 0.0f32;
            for t in 0..d {
                let diff = xi[t] - yj[t];
                d2 += diff * diff;
            }
            out[i * q + j] = (-gamma * d2).exp();
        }
    }
    out
}

/// y = A v, A row-major (r, c).
pub fn matvec_block(a: &[f32], v: &[f32], r: usize, c: usize) -> Vec<f32> {
    assert_eq!(a.len(), r * c);
    assert_eq!(v.len(), c);
    let mut out = vec![0.0f32; r];
    for i in 0..r {
        let row = &a[i * c..(i + 1) * c];
        let mut acc = 0.0f32;
        for t in 0..c {
            acc += row[t] * v[t];
        }
        out[i] = acc;
    }
    out
}

/// L tile = is_diag * I - diag(dinv_r) * S * diag(dinv_c). All (r, c) row-major.
pub fn laplacian_block(
    s: &[f32],
    dinv_r: &[f32],
    dinv_c: &[f32],
    r: usize,
    c: usize,
    is_diag: f32,
) -> Vec<f32> {
    assert_eq!(s.len(), r * c);
    let mut out = vec![0.0f32; r * c];
    for i in 0..r {
        for j in 0..c {
            let eye = if i == j { is_diag } else { 0.0 };
            out[i * c + j] = eye - dinv_r[i] * s[i * c + j] * dinv_c[j];
        }
    }
    out
}

/// K-means step: returns (assign (p,), sums (k, d), counts (k,)).
///
/// The nearest-center scan runs through the f32 blocked assignment tile
/// ([`crate::linalg::kernels::assign_point_f32`]) with center norms
/// hoisted once per step; selection (including ties to the lowest center
/// index) is bit-identical to the original strict-`<` scan by the
/// kernel-layer contract. Assignment is still computed for padding points
/// (mask 0) — only the sums/counts are mask-gated.
pub fn kmeans_step(
    points: &[f32],
    centers: &[f32],
    mask: &[f32],
    p: usize,
    k: usize,
    d: usize,
) -> (Vec<i32>, Vec<f32>, Vec<f32>) {
    assert_eq!(points.len(), p * d);
    assert_eq!(centers.len(), k * d);
    assert_eq!(mask.len(), p);
    let norms = crate::linalg::kernels::center_norms_f32(centers, k, d);
    let mut assign = vec![0i32; p];
    let mut sums = vec![0.0f32; k * d];
    let mut counts = vec![0.0f32; k];
    for i in 0..p {
        let pi = &points[i * d..(i + 1) * d];
        let best = crate::linalg::kernels::assign_point_f32(pi, centers, &norms, k, d) as usize;
        assign[i] = best as i32;
        if mask[i] != 0.0 {
            counts[best] += mask[i];
            for t in 0..d {
                sums[best * d + t] += mask[i] * pi[t];
            }
        }
    }
    (assign, sums, counts)
}

/// Row-wise L2 normalization; zero rows stay zero. z is (r, d) row-major.
pub fn normalize_rows(z: &[f32], r: usize, d: usize) -> Vec<f32> {
    assert_eq!(z.len(), r * d);
    let mut out = vec![0.0f32; r * d];
    for i in 0..r {
        let row = &z[i * d..(i + 1) * d];
        let norm: f32 = row.iter().map(|x| x * x).sum::<f32>().sqrt();
        let inv = if norm == 0.0 { 0.0 } else { 1.0 / norm };
        for t in 0..d {
            out[i * d + t] = row[t] * inv;
        }
    }
    out
}

/// Row sums of an (r, c) matrix.
pub fn degree_rowsum(s: &[f32], r: usize, c: usize) -> Vec<f32> {
    assert_eq!(s.len(), r * c);
    (0..r)
        .map(|i| s[i * c..(i + 1) * c].iter().sum())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rbf_identity_diag() {
        // Distance 0 -> similarity 1 on the diagonal with x == y.
        let x = vec![1.0, 2.0, 3.0, 4.0]; // 2 points, d=2
        let s = rbf_block(&x, &x, 2, 2, 2, 0.5);
        assert!((s[0] - 1.0).abs() < 1e-7);
        assert!((s[3] - 1.0).abs() < 1e-7);
        // Off-diagonal: d2 = 8, exp(-4).
        assert!((s[1] - (-4.0f32).exp()).abs() < 1e-7);
        assert_eq!(s[1], s[2]);
    }

    #[test]
    fn matvec_small() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let v = vec![5.0, 6.0];
        assert_eq!(matvec_block(&a, &v, 2, 2), vec![17.0, 39.0]);
    }

    #[test]
    fn laplacian_tile_math() {
        let s = vec![1.0, 0.5, 0.5, 1.0];
        let dinv = vec![0.5, 0.5];
        let l = laplacian_block(&s, &dinv, &dinv, 2, 2, 1.0);
        assert!((l[0] - 0.75).abs() < 1e-7); // 1 - .5*1*.5
        assert!((l[1] + 0.125).abs() < 1e-7); // -.5*.5*.5
        let l_off = laplacian_block(&s, &dinv, &dinv, 2, 2, 0.0);
        assert!((l_off[0] + 0.25).abs() < 1e-7); // no identity
    }

    #[test]
    fn kmeans_assigns_nearest_and_masks() {
        let points = vec![0.0, 0.0, 10.0, 10.0, 0.1, 0.1];
        let centers = vec![0.0, 0.0, 10.0, 10.0];
        let mask = vec![1.0, 1.0, 0.0]; // last point is padding
        let (assign, sums, counts) = kmeans_step(&points, &centers, &mask, 3, 2, 2);
        assert_eq!(assign, vec![0, 1, 0]); // assignment computed for padding too
        assert_eq!(counts, vec![1.0, 1.0]); // ...but not counted
        assert_eq!(&sums[..2], &[0.0, 0.0]);
        assert_eq!(&sums[2..], &[10.0, 10.0]);
    }

    #[test]
    fn normalize_rows_unit_norm_and_zero_row() {
        let z = vec![3.0, 4.0, 0.0, 0.0];
        let y = normalize_rows(&z, 2, 2);
        assert!((y[0] - 0.6).abs() < 1e-7);
        assert!((y[1] - 0.8).abs() < 1e-7);
        assert_eq!(&y[2..], &[0.0, 0.0]);
    }

    #[test]
    fn degree_rowsum_small() {
        let s = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(degree_rowsum(&s, 2, 2), vec![3.0, 7.0]);
    }
}
