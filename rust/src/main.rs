//! `psch` binary: leader entrypoint + CLI. See `cli.rs` for subcommands.

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match psch::cli::run(&args) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}
