//! Mini-HBase: a sorted, region-partitioned distributed table (paper §2.3).
//!
//! The paper stores the similarity matrix, the row-partitioned Laplacian and
//! the k-means state in HBase tables keyed by row index. This module provides
//! that: tables are split into key-range **regions** (each pinned to a slave,
//! which is how the MapReduce jobs get locality), writes go through a
//! memstore + sorted-run store per region, and scans merge across them.
//! Regions split automatically when they grow past a threshold.

pub mod memstore;
pub mod region;

use std::collections::HashMap;
use std::sync::{Arc, Mutex, RwLock};

use crate::error::{Error, Result};

pub use memstore::{Key, Value};
pub use region::Region;

/// A handle to the table service. Cheap to clone (shared state).
#[derive(Clone)]
pub struct TableService {
    inner: Arc<TableServiceInner>,
}

struct TableServiceInner {
    tables: RwLock<HashMap<String, Arc<Table>>>,
    /// Number of slaves regions get assigned to (round-robin).
    slaves: usize,
}

impl TableService {
    /// New service over `slaves` region servers.
    pub fn new(slaves: usize) -> Self {
        Self {
            inner: Arc::new(TableServiceInner {
                tables: RwLock::new(HashMap::new()),
                slaves: slaves.max(1),
            }),
        }
    }

    /// Create a table pre-split into `regions` key ranges over u64 row keys.
    pub fn create(&self, name: &str, regions: usize) -> Result<Arc<Table>> {
        let mut tables = self.inner.tables.write().unwrap();
        if tables.contains_key(name) {
            return Err(Error::Table(format!("table exists: {name}")));
        }
        let table = Arc::new(Table::pre_split(name, regions.max(1), self.inner.slaves));
        tables.insert(name.to_string(), table.clone());
        Ok(table)
    }

    /// Open an existing table.
    pub fn open(&self, name: &str) -> Result<Arc<Table>> {
        self.inner
            .tables
            .read()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| Error::Table(format!("no such table: {name}")))
    }

    /// Drop a table.
    pub fn drop_table(&self, name: &str) -> Result<()> {
        self.inner
            .tables
            .write()
            .unwrap()
            .remove(name)
            .map(|_| ())
            .ok_or_else(|| Error::Table(format!("no such table: {name}")))
    }

    /// List table names (sorted).
    pub fn list(&self) -> Vec<String> {
        let mut v: Vec<String> = self.inner.tables.read().unwrap().keys().cloned().collect();
        v.sort();
        v
    }
}

/// One table: ordered regions over the row-key space.
pub struct Table {
    /// Table name.
    pub name: String,
    regions: RwLock<Vec<Arc<Mutex<Region>>>>,
    slaves: usize,
}

impl Table {
    /// Pre-split into `n` regions uniform over the u64 big-endian key space.
    fn pre_split(name: &str, n: usize, slaves: usize) -> Self {
        let mut regions = Vec::with_capacity(n);
        for r in 0..n {
            let start = if r == 0 {
                vec![]
            } else {
                split_point(r as u64, n as u64)
            };
            let end = if r == n - 1 {
                vec![0xffu8; 9] // past any 8-byte key
            } else {
                split_point(r as u64 + 1, n as u64)
            };
            regions.push(Arc::new(Mutex::new(Region::new(start, end, r % slaves))));
        }
        Self { name: name.to_string(), regions: RwLock::new(regions), slaves }
    }

    /// Upsert one cell.
    pub fn put(&self, key: Key, value: Value) -> Result<()> {
        let region = self.region_for(&key)?;
        let needs_split = {
            let mut r = region.lock().unwrap();
            r.put(key, value);
            r.should_split()
        };
        if needs_split {
            self.split_region(&region)?;
        }
        Ok(())
    }

    /// Batched upsert: amortizes the region lookup and lock over runs of
    /// keys that land in the same region (phase-1 writes whole row chunks);
    /// splits are checked once per run instead of per cell.
    pub fn put_batch(&self, cells: Vec<(Key, Value)>) -> Result<()> {
        let mut it = cells.into_iter().peekable();
        while let Some((k, v)) = it.next() {
            let region = self.region_for(&k)?;
            let needs_split = {
                let mut r = region.lock().unwrap();
                r.put(k, v);
                // Drain the run of subsequent keys owned by this region.
                while let Some((nk, _)) = it.peek() {
                    if !r.contains(nk) {
                        break;
                    }
                    let (nk, nv) = it.next().unwrap();
                    r.put(nk, nv);
                }
                r.should_split()
            };
            if needs_split {
                self.split_region(&region)?;
            }
        }
        Ok(())
    }

    /// Point lookup.
    pub fn get(&self, key: &[u8]) -> Result<Option<Value>> {
        let region = self.region_for(key)?;
        let r = region.lock().unwrap();
        Ok(r.get(key))
    }

    /// Sorted scan of [start, end) across regions.
    pub fn scan(&self, start: &[u8], end: &[u8]) -> Vec<(Key, Value)> {
        let regions = self.regions.read().unwrap().clone();
        let mut out = Vec::new();
        for region in regions {
            let r = region.lock().unwrap();
            if r.end_key() <= start || r.start_key() >= end {
                continue;
            }
            out.extend(r.scan(start, end));
        }
        out
    }

    /// Scan an entire table.
    pub fn scan_all(&self) -> Vec<(Key, Value)> {
        self.scan(&[], &[0xffu8; 9])
    }

    /// Region count (grows via splits).
    pub fn region_count(&self) -> usize {
        self.regions.read().unwrap().len()
    }

    /// (start_key, slave) of every region, sorted — the locality map the
    /// MapReduce scheduler uses to co-locate map tasks with their rows.
    pub fn region_assignments(&self) -> Vec<(Key, usize)> {
        self.regions
            .read()
            .unwrap()
            .iter()
            .map(|r| {
                let g = r.lock().unwrap();
                (g.start_key().to_vec(), g.slave())
            })
            .collect()
    }

    /// Slave hosting the region that owns `key` — the locality hint the
    /// MapReduce scheduler uses to co-locate a map task with its rows.
    pub fn key_slave(&self, key: &[u8]) -> Result<usize> {
        let region = self.region_for(key)?;
        let slave = region.lock().unwrap().slave();
        Ok(slave)
    }

    fn region_for(&self, key: &[u8]) -> Result<Arc<Mutex<Region>>> {
        let regions = self.regions.read().unwrap();
        for region in regions.iter() {
            let r = region.lock().unwrap();
            if r.contains(key) {
                return Ok(region.clone());
            }
        }
        Err(Error::Table(format!(
            "table {}: no region for key {key:02x?}",
            self.name
        )))
    }

    /// Split one region at its midpoint key; the new region is assigned to
    /// the next slave round-robin (HBase's balancer in one line).
    fn split_region(&self, region: &Arc<Mutex<Region>>) -> Result<()> {
        let mut regions = self.regions.write().unwrap();
        let idx = regions
            .iter()
            .position(|r| Arc::ptr_eq(r, region))
            .ok_or_else(|| Error::Table("region vanished during split".into()))?;
        let new_region = {
            let mut r = region.lock().unwrap();
            let next_slave = (r.slave() + 1) % self.slaves;
            match r.split(next_slave) {
                Some(nr) => nr,
                None => return Ok(()), // nothing to split
            }
        };
        regions.insert(idx + 1, Arc::new(Mutex::new(new_region)));
        Ok(())
    }
}

/// The i-th of n uniform split points over the 8-byte big-endian key space.
fn split_point(i: u64, n: u64) -> Vec<u8> {
    let point = ((i as u128 * (u64::MAX as u128 + 1)) / n as u128) as u64;
    point.to_be_bytes().to_vec()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::encode_u64;

    #[test]
    fn create_open_drop() {
        let svc = TableService::new(4);
        svc.create("t", 4).unwrap();
        assert!(svc.create("t", 1).is_err());
        assert!(svc.open("t").is_ok());
        assert_eq!(svc.list(), vec!["t".to_string()]);
        svc.drop_table("t").unwrap();
        assert!(svc.open("t").is_err());
        assert!(svc.drop_table("t").is_err());
    }

    #[test]
    fn put_get_across_regions() {
        let svc = TableService::new(3);
        let t = svc.create("m", 4).unwrap();
        for i in 0..1000u64 {
            t.put(encode_u64(i).to_vec(), vec![(i % 256) as u8]).unwrap();
        }
        for i in (0..1000u64).step_by(97) {
            assert_eq!(
                t.get(&encode_u64(i)).unwrap(),
                Some(vec![(i % 256) as u8]),
                "key {i}"
            );
        }
        assert_eq!(t.get(&encode_u64(5000)).unwrap(), None);
    }

    #[test]
    fn scan_is_globally_sorted() {
        let svc = TableService::new(2);
        let t = svc.create("s", 4).unwrap();
        // Insert in reverse order.
        for i in (0..500u64).rev() {
            t.put(encode_u64(i).to_vec(), vec![]).unwrap();
        }
        let all = t.scan_all();
        assert_eq!(all.len(), 500);
        for w in all.windows(2) {
            assert!(w[0].0 < w[1].0, "scan out of order");
        }
        // Bounded scan decodes to the right half-open range.
        let part = t.scan(&encode_u64(100), &encode_u64(200));
        assert_eq!(part.len(), 100);
        assert_eq!(part[0].0, encode_u64(100).to_vec());
    }

    #[test]
    fn regions_split_under_load() {
        let svc = TableService::new(2);
        let t = svc.create("grow", 1).unwrap();
        let before = t.region_count();
        // Write enough bytes to trip the split threshold.
        let big = vec![0u8; 1024];
        for i in 0..(2 * region::SPLIT_THRESHOLD / 1024 + 16) as u64 {
            t.put(encode_u64(i).to_vec(), big.clone()).unwrap();
        }
        assert!(t.region_count() > before, "no split happened");
        // All data still visible post-split.
        let n = 2 * region::SPLIT_THRESHOLD / 1024 + 16;
        assert_eq!(t.scan_all().len(), n);
    }

    #[test]
    fn region_assignments_cover_slaves() {
        let svc = TableService::new(4);
        let t = svc.create("a", 8).unwrap();
        let slaves: std::collections::HashSet<usize> =
            t.region_assignments().iter().map(|&(_, s)| s).collect();
        assert_eq!(slaves.len(), 4, "regions not spread over all slaves");
    }

    #[test]
    fn key_slave_matches_region_assignment() {
        let svc = TableService::new(3);
        let t = svc.create("loc", 6).unwrap();
        let assignments = t.region_assignments();
        for probe in [0u64, 1 << 40, u64::MAX / 2, u64::MAX - 1] {
            let key = probe.to_be_bytes().to_vec();
            let slave = t.key_slave(&key).unwrap();
            // The owning region is the last assignment with start <= key.
            let expect = assignments
                .iter()
                .rev()
                .find(|(start, _)| start.as_slice() <= key.as_slice())
                .map(|&(_, s)| s)
                .unwrap();
            assert_eq!(slave, expect, "probe {probe}");
        }
    }

    #[test]
    fn split_points_monotone() {
        let pts: Vec<Vec<u8>> = (1..8).map(|i| split_point(i, 8)).collect();
        for w in pts.windows(2) {
            assert!(w[0] < w[1]);
        }
    }
}
