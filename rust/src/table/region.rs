//! A region: one contiguous key range of a table, pinned to a slave.

use super::memstore::{Key, Store, Value};

/// Split a region once it holds this many bytes.
pub const SPLIT_THRESHOLD: usize = 32 << 20; // 32 MiB

/// One key-range shard of a table.
#[derive(Debug)]
pub struct Region {
    start: Key,
    end: Key, // exclusive
    store: Store,
    bytes: usize,
    slave: usize,
}

impl Region {
    /// New empty region serving [start, end) on `slave`.
    pub fn new(start: Key, end: Key, slave: usize) -> Self {
        Self { start, end, store: Store::default(), bytes: 0, slave }
    }

    /// Inclusive start key.
    pub fn start_key(&self) -> &[u8] {
        &self.start
    }

    /// Exclusive end key.
    pub fn end_key(&self) -> &[u8] {
        &self.end
    }

    /// Hosting slave id.
    pub fn slave(&self) -> usize {
        self.slave
    }

    /// Does this region own `key`?
    pub fn contains(&self, key: &[u8]) -> bool {
        self.start.as_slice() <= key && key < self.end.as_slice()
    }

    /// Upsert.
    pub fn put(&mut self, key: Key, value: Value) {
        debug_assert!(self.contains(&key));
        self.bytes += key.len() + value.len();
        self.store.put(key, value);
    }

    /// Point lookup.
    pub fn get(&self, key: &[u8]) -> Option<Value> {
        self.store.get(key)
    }

    /// Sorted scan clipped to this region's range.
    pub fn scan(&self, start: &[u8], end: &[u8]) -> Vec<(Key, Value)> {
        let lo = if start < self.start.as_slice() { &self.start } else { start };
        let hi = if end > self.end.as_slice() { &self.end } else { end };
        if lo >= hi {
            return vec![];
        }
        self.store.scan(lo, hi)
    }

    /// Has this region outgrown the split threshold?
    pub fn should_split(&self) -> bool {
        self.bytes >= SPLIT_THRESHOLD
    }

    /// Split at the median visible key; returns the new upper region (on
    /// `new_slave`), or None when there is nothing meaningful to split.
    pub fn split(&mut self, new_slave: usize) -> Option<Region> {
        let all = self.store.scan(&self.start, &self.end);
        if all.len() < 2 {
            return None;
        }
        let mid_key = all[all.len() / 2].0.clone();
        if mid_key == self.start {
            return None;
        }
        let mut upper = Region::new(mid_key.clone(), std::mem::take(&mut self.end), new_slave);
        self.end = mid_key;
        let mut lower_store = Store::default();
        let mut lower_bytes = 0;
        for (k, v) in all {
            let sz = k.len() + v.len();
            if k < self.end {
                lower_bytes += sz;
                lower_store.put(k, v);
            } else {
                upper.bytes += sz;
                upper.store.put(k, v);
            }
        }
        self.store = lower_store;
        self.bytes = lower_bytes;
        Some(upper)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contains_half_open() {
        let r = Region::new(vec![10], vec![20], 0);
        assert!(r.contains(&[10]));
        assert!(r.contains(&[19, 255]));
        assert!(!r.contains(&[20]));
        assert!(!r.contains(&[9]));
    }

    #[test]
    fn split_partitions_data() {
        let mut r = Region::new(vec![], vec![255], 0);
        for i in 0..100u8 {
            r.put(vec![i], vec![i]);
        }
        let upper = r.split(1).unwrap();
        assert_eq!(upper.slave(), 1);
        assert_eq!(r.end_key(), upper.start_key());
        let lower_n = r.scan(&[], &[255]).len();
        let upper_n = upper.scan(&[], &[255]).len();
        assert_eq!(lower_n + upper_n, 100);
        assert!(lower_n > 0 && upper_n > 0);
        // Ownership respected.
        assert!(r.scan(&[], &[255]).iter().all(|(k, _)| r.contains(k)));
        assert!(upper.scan(&[], &[255]).iter().all(|(k, _)| upper.contains(k)));
    }

    #[test]
    fn split_empty_region_is_none() {
        let mut r = Region::new(vec![], vec![255], 0);
        assert!(r.split(1).is_none());
        r.put(vec![1], vec![]);
        assert!(r.split(1).is_none()); // single key: nothing to split
    }

    #[test]
    fn scan_clips_to_region() {
        let mut r = Region::new(vec![50], vec![100], 0);
        for i in 50..100u8 {
            r.put(vec![i], vec![]);
        }
        // Ask for more than the region owns; get only its share.
        assert_eq!(r.scan(&[0], &[200]).len(), 50);
        assert_eq!(r.scan(&[60], &[70]).len(), 10);
        assert_eq!(r.scan(&[150], &[200]).len(), 0);
    }
}
