//! MemStore + immutable sorted runs: HBase's write path in miniature.
//!
//! Writes land in a sorted in-memory map (the memstore); when it exceeds the
//! flush threshold it is frozen into an immutable sorted run (HBase's HFile).
//! Reads consult the memstore first, then runs newest-first. A background
//! "compaction" merges runs when too many accumulate.

use std::collections::BTreeMap;

/// Row key bytes (big-endian for numeric keys keeps scan order numeric).
pub type Key = Vec<u8>;
/// Cell value bytes.
pub type Value = Vec<u8>;

/// Immutable sorted run (flushed memstore).
#[derive(Debug, Clone)]
pub struct SortedRun {
    entries: Vec<(Key, Value)>, // sorted by key, unique keys
}

impl SortedRun {
    /// Freeze a memstore snapshot into a run.
    pub fn from_map(map: BTreeMap<Key, Value>) -> Self {
        Self { entries: map.into_iter().collect() }
    }

    /// Point lookup (binary search).
    pub fn get(&self, key: &[u8]) -> Option<&Value> {
        self.entries
            .binary_search_by(|(k, _)| k.as_slice().cmp(key))
            .ok()
            .map(|i| &self.entries[i].1)
    }

    /// Entries in [start, end).
    pub fn range(&self, start: &[u8], end: &[u8]) -> &[(Key, Value)] {
        let lo = self.entries.partition_point(|(k, _)| k.as_slice() < start);
        let hi = self.entries.partition_point(|(k, _)| k.as_slice() < end);
        &self.entries[lo..hi]
    }

    /// Number of cells.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Is the run empty?
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Merge newest-wins: `self` is newer than `older`.
    pub fn merge_over(self, older: SortedRun) -> SortedRun {
        let mut out = Vec::with_capacity(self.entries.len() + older.entries.len());
        let (mut i, mut j) = (0, 0);
        while i < self.entries.len() && j < older.entries.len() {
            match self.entries[i].0.cmp(&older.entries[j].0) {
                std::cmp::Ordering::Less => {
                    out.push(self.entries[i].clone());
                    i += 1;
                }
                std::cmp::Ordering::Greater => {
                    out.push(older.entries[j].clone());
                    j += 1;
                }
                std::cmp::Ordering::Equal => {
                    out.push(self.entries[i].clone()); // newer wins
                    i += 1;
                    j += 1;
                }
            }
        }
        out.extend_from_slice(&self.entries[i..]);
        out.extend_from_slice(&older.entries[j..]);
        SortedRun { entries: out }
    }
}

/// Write buffer + runs for one store (one region's column data).
#[derive(Debug, Default)]
pub struct Store {
    memstore: BTreeMap<Key, Value>,
    memstore_bytes: usize,
    runs: Vec<SortedRun>, // newest last
}

/// Flush memstore when it exceeds this many bytes.
pub const FLUSH_THRESHOLD: usize = 16 << 20; // 16 MiB
/// Compact when this many runs accumulate.
pub const COMPACT_RUNS: usize = 4;

impl Store {
    /// Upsert a cell.
    pub fn put(&mut self, key: Key, value: Value) {
        self.memstore_bytes += key.len() + value.len();
        self.memstore.insert(key, value);
        if self.memstore_bytes >= FLUSH_THRESHOLD {
            self.flush();
        }
    }

    /// Point lookup: memstore, then runs newest-first.
    pub fn get(&self, key: &[u8]) -> Option<Value> {
        if let Some(v) = self.memstore.get(key) {
            return Some(v.clone());
        }
        for run in self.runs.iter().rev() {
            if let Some(v) = run.get(key) {
                return Some(v.clone());
            }
        }
        None
    }

    /// Freeze the memstore into a run (no-op when empty); maybe compact.
    pub fn flush(&mut self) {
        if self.memstore.is_empty() {
            return;
        }
        let map = std::mem::take(&mut self.memstore);
        self.memstore_bytes = 0;
        self.runs.push(SortedRun::from_map(map));
        if self.runs.len() >= COMPACT_RUNS {
            self.compact();
        }
    }

    /// Merge all runs into one (newest-wins).
    pub fn compact(&mut self) {
        let mut merged: Option<SortedRun> = None;
        // Oldest first; each newer run merges over the accumulated older.
        for run in self.runs.drain(..) {
            merged = Some(match merged {
                None => run,
                Some(older) => run.merge_over(older),
            });
        }
        if let Some(m) = merged {
            self.runs.push(m);
        }
    }

    /// Sorted scan of [start, end): memstore merged over runs, newest-wins.
    pub fn scan(&self, start: &[u8], end: &[u8]) -> Vec<(Key, Value)> {
        let mut out: BTreeMap<Key, Value> = BTreeMap::new();
        for run in &self.runs {
            // Older first; later inserts overwrite.
            for (k, v) in run.range(start, end) {
                out.insert(k.clone(), v.clone());
            }
        }
        for (k, v) in self
            .memstore
            .range::<[u8], _>((
                std::ops::Bound::Included(start),
                std::ops::Bound::Excluded(end),
            ))
        {
            out.insert(k.clone(), v.clone());
        }
        out.into_iter().collect()
    }

    /// Total distinct keys visible (approximate: counts post-merge scan).
    pub fn approx_len(&self) -> usize {
        self.memstore.len() + self.runs.iter().map(|r| r.len()).sum::<usize>()
    }

    /// Bytes buffered in the memstore (flush trigger state).
    pub fn memstore_bytes(&self) -> usize {
        self.memstore_bytes
    }

    /// Number of runs (compaction trigger state).
    pub fn run_count(&self) -> usize {
        self.runs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn k(s: &str) -> Key {
        s.as_bytes().to_vec()
    }

    #[test]
    fn put_get_overwrite() {
        let mut s = Store::default();
        s.put(k("a"), vec![1]);
        s.put(k("a"), vec![2]);
        assert_eq!(s.get(b"a"), Some(vec![2]));
        assert_eq!(s.get(b"b"), None);
    }

    #[test]
    fn flush_preserves_reads() {
        let mut s = Store::default();
        s.put(k("x"), vec![1]);
        s.flush();
        assert_eq!(s.run_count(), 1);
        assert_eq!(s.get(b"x"), Some(vec![1]));
        // Overwrite after flush: memstore wins.
        s.put(k("x"), vec![9]);
        assert_eq!(s.get(b"x"), Some(vec![9]));
        s.flush();
        assert_eq!(s.get(b"x"), Some(vec![9]));
    }

    #[test]
    fn newest_run_wins_after_compaction() {
        let mut s = Store::default();
        for round in 0..COMPACT_RUNS as u8 {
            s.put(k("key"), vec![round]);
            s.flush();
        }
        // COMPACT_RUNS flushes triggered a compaction down to 1 run.
        assert_eq!(s.run_count(), 1);
        assert_eq!(s.get(b"key"), Some(vec![COMPACT_RUNS as u8 - 1]));
    }

    #[test]
    fn scan_merges_and_orders() {
        let mut s = Store::default();
        s.put(k("b"), vec![1]);
        s.put(k("d"), vec![2]);
        s.flush();
        s.put(k("a"), vec![3]);
        s.put(k("c"), vec![4]);
        s.put(k("b"), vec![5]); // overwrite flushed value
        let all = s.scan(b"a", b"z");
        let keys: Vec<&[u8]> = all.iter().map(|(k, _)| k.as_slice()).collect();
        assert_eq!(keys, vec![b"a".as_slice(), b"b", b"c", b"d"]);
        assert_eq!(all[1].1, vec![5]);
        // Bounded scan.
        let mid = s.scan(b"b", b"d");
        assert_eq!(mid.len(), 2);
    }

    #[test]
    fn sorted_run_range_bounds() {
        let mut m = BTreeMap::new();
        for i in 0..10u8 {
            m.insert(vec![i], vec![i]);
        }
        let run = SortedRun::from_map(m);
        assert_eq!(run.range(&[3], &[7]).len(), 4);
        assert_eq!(run.range(&[0], &[0]).len(), 0);
        assert!(run.get(&[5]).is_some());
        assert!(run.get(&[99]).is_none());
    }
}
