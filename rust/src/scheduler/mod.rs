//! JobTracker: the locality- and straggler-aware task scheduler (paper §2.2).
//!
//! Hadoop's scheduling machinery is what the source paper credits for its
//! scaling: the JobTracker holds a rack topology over the slaves, every
//! TaskTracker reports free map/reduce slots via periodic **heartbeats**,
//! pending tasks carry the DFS block locations of their input split, and
//! assignment walks the three locality tiers (node-local → rack-local →
//! off-rack, [`placement`]) — optionally waiting a few heartbeats for local
//! work to appear (delay scheduling, [`policy`]). Slow attempts get
//! duplicated on idle slots and the earlier finisher wins
//! ([`speculative`]).
//!
//! The tracker runs *live* inside [`crate::mapreduce::engine::run`]: each
//! job's measured task costs + split locations are replayed through
//! [`JobTracker::plan`], which simulates the heartbeat protocol in virtual
//! time on the cluster's [`crate::cluster::NetworkModel`] — off-rack reads
//! are charged the oversubscribed core bandwidth, stragglers trigger real
//! duplicate attempts in the plan, and the resulting locality/speculation
//! tallies surface as job counters.

pub mod placement;
pub mod policy;
pub mod rack;
pub mod speculative;

pub use placement::{classify, Locality};
pub use policy::Policy;
pub use rack::RackTopology;
pub use speculative::SpeculationConfig;

use crate::cluster::faults::{FaultDomain, NodeState};
use crate::cluster::{NetworkModel, TaskCost};

/// Comparison slack for virtual-time arithmetic.
const EPS: f64 = 1e-9;

/// One schedulable task: its cost profile plus the nodes holding its input.
#[derive(Debug, Clone, Default)]
pub struct TaskSpec {
    /// Measured/modeled task cost (compute + bytes).
    pub cost: TaskCost,
    /// Nodes holding a replica of the task's input split (empty = no
    /// locality preference, e.g. synthetic splits or shuffle output).
    pub hosts: Vec<usize>,
}

/// JobTracker knobs.
#[derive(Debug, Clone, PartialEq)]
pub struct TrackerConfig {
    /// Virtual seconds between one slave's heartbeats (Hadoop default: 3s).
    pub heartbeat_s: f64,
    /// Slot-filling policy.
    pub policy: Policy,
    /// Speculative-execution knobs.
    pub speculation: SpeculationConfig,
}

impl Default for TrackerConfig {
    fn default() -> Self {
        Self {
            heartbeat_s: 3.0,
            policy: Policy::default(),
            speculation: SpeculationConfig::default(),
        }
    }
}

/// One task attempt in the plan.
#[derive(Debug, Clone, Copy)]
pub struct Attempt {
    /// Task index.
    pub task: usize,
    /// Slave it ran on.
    pub slave: usize,
    /// Global slot index (slave × slots_per_slave + local slot).
    pub slot: usize,
    /// Virtual start time.
    pub start_s: f64,
    /// Virtual end time (a killed loser ends when the winner reports).
    pub end_s: f64,
    /// Locality tier of the attempt.
    pub locality: Locality,
    /// Was this a speculative duplicate?
    pub speculative: bool,
    /// Did this attempt produce the task's result?
    pub won: bool,
}

/// The virtual execution plan of one task phase.
#[derive(Debug, Clone, Default)]
pub struct SchedulePlan {
    /// Virtual seconds from first heartbeat to last task completion.
    pub makespan_s: f64,
    /// Every attempt, in launch order.
    pub attempts: Vec<Attempt>,
    /// Winning attempts that were node-local (tasks with host info only).
    pub node_local: usize,
    /// Winning attempts that were rack-local.
    pub rack_local: usize,
    /// Winning attempts that read across racks.
    pub off_rack: usize,
    /// Speculative duplicates launched.
    pub speculative_attempts: usize,
    /// Duplicates that beat the original attempt.
    pub speculative_wins: usize,
    /// Heartbeats processed while the phase ran.
    pub heartbeats: u64,
    /// Total virtual seconds winning attempts spent reading input.
    pub input_read_s: f64,
    /// Sum of winning-attempt durations (serial work).
    pub total_work_s: f64,
    /// Attempts that failed (fault-injected) and were re-planned.
    pub failed_attempts: u64,
    /// Scheduled node deaths that fired during this phase.
    pub deaths: u64,
    /// `(slave, virtual time)` of each node death that fired — the instant
    /// events the trace renders on the driver track.
    pub death_events: Vec<(usize, f64)>,
    /// Slaves blacklisted during this phase, with the virtual time the
    /// blacklist took effect — no attempt may start on them afterwards.
    pub blacklisted: Vec<(usize, f64)>,
    /// Tasks that exhausted their attempts (or had no live slave left).
    /// Non-empty means the phase — and therefore the job — failed.
    pub failed_tasks: Vec<usize>,
}

impl SchedulePlan {
    /// Winning attempts that had locality information at all.
    pub fn placed(&self) -> usize {
        self.node_local + self.rack_local + self.off_rack
    }

    /// Percentage of placed tasks that ran node-local (0 when no task
    /// carried host info).
    pub fn data_local_pct(&self) -> f64 {
        if self.placed() == 0 {
            0.0
        } else {
            100.0 * self.node_local as f64 / self.placed() as f64
        }
    }

    /// Total virtual seconds winning attempts waited between phase start
    /// (enqueue — every task is ready at t = 0) and dispatch. The
    /// `QUEUE_WAIT_US` counter aggregates this per phase.
    pub fn queue_wait_s(&self) -> f64 {
        self.attempts
            .iter()
            .filter(|a| a.won)
            .map(|a| a.start_s)
            .sum()
    }

    /// Slot-seconds occupied by attempts — winners and killed losers both
    /// hold their slot until they end.
    pub fn busy_slot_s(&self) -> f64 {
        self.attempts.iter().map(|a| a.end_s - a.start_s).sum()
    }

    /// Slot-seconds the cluster left unused during this phase: the
    /// makespan × `total_slots` capacity minus [`busy_slot_s`], clamped at
    /// zero. The `SLOT_IDLE_US` counter aggregates this per phase.
    pub fn slot_idle_s(&self, total_slots: usize) -> f64 {
        (self.makespan_s * total_slots as f64 - self.busy_slot_s()).max(0.0)
    }

    /// The slave each task's winning attempt ran on, indexed by task id —
    /// where a map task's output file lives, and which node a reduce task
    /// fetches from (the shuffle's locality input).
    pub fn winning_slaves(&self, num_tasks: usize) -> Vec<Option<usize>> {
        let mut slaves = vec![None; num_tasks];
        for a in &self.attempts {
            if a.won && a.task < num_tasks {
                slaves[a.task] = Some(a.slave);
            }
        }
        slaves
    }
}

/// Bookkeeping for a task's primary running attempt.
#[derive(Debug, Clone, Copy)]
struct RunningAttempt {
    start: f64,
    end: f64,
    slot: usize,
    attempt_idx: usize,
}

/// The JobTracker: borrows the cluster's topology, per-slave speeds, cost
/// model and knobs, and turns a task list into a [`SchedulePlan`].
pub struct JobTracker<'a> {
    topo: &'a RackTopology,
    /// Relative speed per slave (1.0 = reference machine).
    speeds: &'a [f64],
    slots_per_slave: usize,
    model: &'a NetworkModel,
    cfg: &'a TrackerConfig,
    /// The cluster's failure domain: node lifecycles, seeded attempt
    /// failures, blacklist counts. `None` = nothing ever fails.
    faults: Option<&'a FaultDomain>,
}

impl<'a> JobTracker<'a> {
    /// Tracker over `topo.num_nodes()` slaves with `slots_per_slave` each.
    pub fn new(
        topo: &'a RackTopology,
        speeds: &'a [f64],
        slots_per_slave: usize,
        model: &'a NetworkModel,
        cfg: &'a TrackerConfig,
    ) -> Self {
        Self {
            topo,
            speeds,
            slots_per_slave: slots_per_slave.max(1),
            model,
            cfg,
            faults: None,
        }
    }

    /// Attach the cluster's failure domain: heartbeats drive scheduled
    /// node deaths, attempts may fail and re-plan, failing slaves get
    /// blacklisted. [`crate::cluster::Cluster::plan_phase`] always attaches
    /// it; a tracker without one behaves exactly as before faults existed.
    pub fn with_faults(mut self, faults: &'a FaultDomain) -> Self {
        self.faults = Some(faults);
        self
    }

    /// Virtual duration of one attempt of `spec` on `slave` at `locality`.
    fn duration(&self, spec: &TaskSpec, slave: usize, locality: Locality) -> f64 {
        let speed = self.speeds.get(slave).copied().unwrap_or(1.0).max(1e-9);
        self.model.task_dispatch_s
            + self.model.read_time_at(spec.cost.input_bytes, locality)
            + self.model.write_time(spec.cost.output_bytes)
            + spec.cost.compute_s * self.model.compute_scale / speed
    }

    /// Simulate the heartbeat protocol over `tasks` and return the plan.
    ///
    /// Deterministic: heartbeats are staggered by slave id, ties break on
    /// the lower id, attempt durations are pure functions of the cost
    /// model, and fault injection is a seeded stream — the same inputs
    /// always produce the same plan.
    ///
    /// With a failure domain attached, each processed heartbeat advances
    /// the cluster-wide clock: scheduled deaths fire (running attempts on
    /// the dead slave are *re-planned* on live nodes with fresh locality,
    /// never retried in place), sampled attempt failures are reported at
    /// the virtual time they occur, repeated failures blacklist the slave
    /// (it keeps heartbeating but receives no further attempts), and a
    /// task that exhausts [`crate::cluster::FaultConfig::max_attempts`]
    /// lands in [`SchedulePlan::failed_tasks`].
    pub fn plan(&self, tasks: &[TaskSpec]) -> SchedulePlan {
        let mut plan = SchedulePlan::default();
        if tasks.is_empty() {
            return plan;
        }
        if let Some(f) = self.faults {
            // Hadoop fault counts are per-job: a fresh phase starts clean
            // (dead/blacklisted lifecycles persist regardless).
            f.begin_phase();
        }
        let m = self.topo.num_nodes();
        let hb = self.cfg.heartbeat_s.max(1e-3);
        let max_attempts = self
            .faults
            .map_or(4, |f| f.config().max_attempts)
            .max(1);

        // Slot s*slots_per_slave + j is slot j of slave s.
        let mut busy_until = vec![0.0f64; m * self.slots_per_slave];
        // Pending queue in submission order; re-planned tasks jump the queue.
        let mut pending: Vec<usize> = (0..tasks.len()).collect();
        // Completion time per task (INFINITY until assigned/resolved).
        let mut done_at = vec![f64::INFINITY; tasks.len()];
        // Final (end, duration) of the winning attempt, once known.
        let mut finish: Vec<Option<(f64, f64)>> = vec![None; tasks.len()];
        let mut primary: Vec<Option<RunningAttempt>> = vec![None; tasks.len()];
        // Index into plan.attempts of the task's current winning attempt.
        let mut winner_idx: Vec<Option<usize>> = vec![None; tasks.len()];
        let mut speculated = vec![false; tasks.len()];
        // The losing side of a resolved speculation race, kept as a live
        // backup until the race's win time: (attempt idx, slave, slot,
        // natural end). If the winner's slave dies first, the backup
        // inherits the task instead of a from-scratch re-execution.
        let mut backup: Vec<Option<(usize, usize, usize, f64)>> =
            vec![None; tasks.len()];
        let mut retired = vec![false; tasks.len()];
        // Fault-injected failures per task (max_attempts enforcement).
        let mut failures_of = vec![0usize; tasks.len()];
        let mut remaining = tasks.len();
        // Staggered heartbeat phases so slaves don't report in lockstep.
        // Slaves already dead (an earlier job's death) never heartbeat.
        let mut next_hb: Vec<f64> = (0..m).map(|s| hb * s as f64 / m as f64).collect();
        if let Some(f) = self.faults {
            for (s, t) in next_hb.iter_mut().enumerate() {
                if f.node_state(s) == NodeState::Dead {
                    *t = f64::INFINITY;
                }
            }
        }
        // Delay-scheduling skip count per slave.
        let mut skips = vec![0usize; m];
        // In-flight failure reports: (virtual time, task, slave, was the
        // attempt a speculative duplicate). A failing attempt is only
        // acted on when its failure *reaches* the tracker; a failed
        // duplicate never re-plans its task (the primary is still running).
        let mut failure_reports: Vec<(f64, usize, usize, bool)> = Vec::new();

        while remaining > 0 {
            // Earliest-reporting live slave; lower id wins ties.
            let mut s = usize::MAX;
            for i in 0..m {
                if next_hb[i].is_finite()
                    && (s == usize::MAX || next_hb[i] < next_hb[s] - EPS)
                {
                    s = i;
                }
            }
            if s == usize::MAX {
                // Every slave is dead: whatever has not finished is lost.
                for (t, &r) in retired.iter().enumerate() {
                    if !r {
                        plan.failed_tasks.push(t);
                    }
                }
                break;
            }
            let now = next_hb[s];
            next_hb[s] += hb;
            plan.heartbeats += 1;

            // Scheduled node deaths fire on the cluster-wide heartbeat
            // clock. A running attempt on the dead slave is lost; if its
            // task still has a live speculative duplicate in flight, the
            // duplicate inherits the task (that is what the backup is
            // *for*), otherwise the task goes back to the head of the
            // queue for a fresh placement.
            if let Some(f) = self.faults {
                for d in f.tick_heartbeat() {
                    plan.deaths += 1;
                    plan.death_events.push((d, now));
                    next_hb[d] = f64::INFINITY;
                    for t in 0..tasks.len() {
                        if retired[t] || done_at[t] <= now + EPS {
                            continue;
                        }
                        let Some(w) = winner_idx[t] else { continue };
                        if plan.attempts[w].slave != d {
                            continue;
                        }
                        plan.attempts[w].won = false;
                        plan.attempts[w].end_s = now;
                        if plan.attempts[w].speculative {
                            // The duplicate had pre-claimed the race; the
                            // death undoes its win.
                            plan.speculative_wins =
                                plan.speculative_wins.saturating_sub(1);
                        }
                        if let Some((bi, bslave, bslot, bend)) = backup[t].take() {
                            if f.node_state(bslave) != NodeState::Dead {
                                // Promote the surviving duplicate: it was
                                // never killed (the winner never reported)
                                // and runs to its natural end.
                                plan.attempts[bi].won = true;
                                plan.attempts[bi].end_s = bend;
                                busy_until[bslot] = bend;
                                winner_idx[t] = Some(bi);
                                done_at[t] = bend;
                                finish[t] =
                                    Some((bend, bend - plan.attempts[bi].start_s));
                                if plan.attempts[bi].speculative {
                                    plan.speculative_wins += 1;
                                }
                                continue;
                            }
                        }
                        winner_idx[t] = None;
                        primary[t] = None;
                        finish[t] = None;
                        done_at[t] = f64::INFINITY;
                        speculated[t] = false;
                        pending.insert(0, t);
                    }
                }
            }

            // Failure reports that have reached the tracker by now: count
            // the attempt, maybe blacklist the slave, and re-plan the task
            // unless it just exhausted its attempts.
            if !failure_reports.is_empty() {
                let mut due: Vec<(f64, usize, usize, bool)> = Vec::new();
                failure_reports.retain(|&(t, task, slave, spec)| {
                    if t <= now + EPS {
                        due.push((t, task, slave, spec));
                        false
                    } else {
                        true
                    }
                });
                due.sort_by(|a, b| {
                    a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1))
                });
                for (_, task, slave, was_speculative) in due {
                    plan.failed_attempts += 1;
                    if let Some(f) = self.faults {
                        if f.record_failure(slave) {
                            plan.blacklisted.push((slave, now));
                        }
                    }
                    if was_speculative {
                        // The primary attempt is still running: the failed
                        // duplicate costs a slot and a tracker fault, not
                        // a re-plan, and it never counts against the
                        // task's attempt budget (Hadoop kills duplicates
                        // without charging the task).
                        continue;
                    }
                    failures_of[task] += 1;
                    if failures_of[task] >= max_attempts {
                        if !retired[task] {
                            retired[task] = true;
                            remaining -= 1;
                            plan.failed_tasks.push(task);
                        }
                    } else {
                        pending.insert(0, task);
                    }
                }
            }

            // Retire tasks whose winning attempt has finished by now.
            for task in 0..tasks.len() {
                if !retired[task] && done_at[task] <= now + EPS {
                    retired[task] = true;
                    remaining -= 1;
                }
            }
            if remaining == 0 {
                break;
            }

            // Livelock guard: work is queued but every slave is dead or
            // blacklisted — nothing can ever take it.
            if !pending.is_empty()
                && self.faults.is_some_and(|f| !f.any_assignable())
            {
                for &t in &pending {
                    if !retired[t] {
                        retired[t] = true;
                        remaining -= 1;
                        plan.failed_tasks.push(t);
                    }
                }
                pending.clear();
                if remaining == 0 {
                    break;
                }
                continue;
            }

            // A blacklisted slave still heartbeats (its running attempts
            // finish) but is assigned no further work.
            if self.faults.is_some_and(|f| !f.assignable(s)) {
                continue;
            }

            let mut skipped_for_locality = false;
            let base = s * self.slots_per_slave;
            for slot in base..base + self.slots_per_slave {
                if busy_until[slot] > now + EPS {
                    continue;
                }
                if !pending.is_empty() {
                    // -------- normal assignment --------
                    let choice = match self.cfg.policy {
                        Policy::Fifo => {
                            let loc = classify(s, &tasks[pending[0]].hosts, self.topo);
                            Some((0, loc))
                        }
                        Policy::LocalityAware { locality_delay } => {
                            match placement::pick_best(&pending, tasks, s, self.topo) {
                                Some((pos, Locality::NodeLocal)) => {
                                    skips[s] = 0;
                                    Some((pos, Locality::NodeLocal))
                                }
                                Some((pos, loc)) => {
                                    if skips[s] < locality_delay {
                                        // Delay scheduling: hold the slot
                                        // open, hoping local work frees up.
                                        skipped_for_locality = true;
                                        None
                                    } else {
                                        skips[s] = 0;
                                        Some((pos, loc))
                                    }
                                }
                                None => None,
                            }
                        }
                    };
                    let Some((pos, locality)) = choice else { continue };
                    let task = pending.remove(pos);
                    let dur = self.duration(&tasks[task], s, locality);
                    // Seeded fault injection: a doomed attempt occupies its
                    // slot until it dies partway through, then reports.
                    if let Some(frac) =
                        self.faults.and_then(|f| f.sample_attempt_failure())
                    {
                        let fail_at = now + dur * frac;
                        busy_until[slot] = fail_at;
                        failure_reports.push((fail_at, task, s, false));
                        plan.attempts.push(Attempt {
                            task,
                            slave: s,
                            slot,
                            start_s: now,
                            end_s: fail_at,
                            locality,
                            speculative: false,
                            won: false,
                        });
                        continue;
                    }
                    let end = now + dur;
                    busy_until[slot] = end;
                    done_at[task] = end;
                    finish[task] = Some((end, dur));
                    primary[task] = Some(RunningAttempt {
                        start: now,
                        end,
                        slot,
                        attempt_idx: plan.attempts.len(),
                    });
                    winner_idx[task] = Some(plan.attempts.len());
                    plan.attempts.push(Attempt {
                        task,
                        slave: s,
                        slot,
                        start_s: now,
                        end_s: end,
                        locality,
                        speculative: false,
                        won: true,
                    });
                } else if self.cfg.speculation.enabled {
                    // -------- speculation: duplicate a straggler --------
                    let completed: Vec<f64> = finish
                        .iter()
                        .filter_map(|f| *f)
                        .filter(|&(end, _)| end <= now + EPS)
                        .map(|(_, dur)| dur)
                        .collect();
                    // Hadoop restarts a slow task "on another node": never
                    // duplicate onto the slave already running the attempt.
                    let running: Vec<(usize, f64)> = (0..tasks.len())
                        .filter(|&t| {
                            !speculated[t]
                                && done_at[t] > now + EPS
                                && primary[t].is_some_and(|r| {
                                    r.slot / self.slots_per_slave != s
                                })
                        })
                        .map(|t| (t, primary[t].unwrap().start))
                        .collect();
                    let Some(task) = speculative::pick_straggler(
                        now,
                        &running,
                        &completed,
                        &self.cfg.speculation,
                    ) else {
                        continue;
                    };
                    speculated[task] = true;
                    let orig = primary[task].unwrap();
                    let locality = classify(s, &tasks[task].hosts, self.topo);
                    let dur = self.duration(&tasks[task], s, locality);
                    // Duplicates draw from the same seeded failure stream
                    // as primaries: a doomed duplicate dies partway, the
                    // primary keeps running, and the race never resolves
                    // in the duplicate's favor.
                    if let Some(frac) =
                        self.faults.and_then(|f| f.sample_attempt_failure())
                    {
                        let fail_at = now + dur * frac;
                        busy_until[slot] = fail_at;
                        failure_reports.push((fail_at, task, s, true));
                        plan.speculative_attempts += 1;
                        plan.attempts.push(Attempt {
                            task,
                            slave: s,
                            slot,
                            start_s: now,
                            end_s: fail_at,
                            locality,
                            speculative: true,
                            won: false,
                        });
                        continue;
                    }
                    let spec_end = now + dur;
                    let win_end = orig.end.min(spec_end);
                    // The loser is killed the moment the winner reports;
                    // both slots free then.
                    busy_until[orig.slot] = win_end;
                    busy_until[slot] = win_end;
                    done_at[task] = win_end;
                    plan.speculative_attempts += 1;
                    let spec_wins = spec_end < orig.end;
                    if spec_wins {
                        plan.speculative_wins += 1;
                        plan.attempts[orig.attempt_idx].won = false;
                        plan.attempts[orig.attempt_idx].end_s = win_end;
                        winner_idx[task] = Some(plan.attempts.len());
                        finish[task] = Some((win_end, win_end - now));
                        // The original keeps running until the winner
                        // reports — it survives the winner's node death.
                        backup[task] = Some((
                            orig.attempt_idx,
                            plan.attempts[orig.attempt_idx].slave,
                            orig.slot,
                            orig.end,
                        ));
                    } else {
                        finish[task] = Some((win_end, win_end - orig.start));
                        backup[task] =
                            Some((plan.attempts.len(), s, slot, spec_end));
                    }
                    plan.attempts.push(Attempt {
                        task,
                        slave: s,
                        slot,
                        start_s: now,
                        end_s: win_end,
                        locality,
                        speculative: true,
                        won: spec_wins,
                    });
                }
            }
            if skipped_for_locality {
                skips[s] += 1;
            }
        }

        // Tally the winning attempts.
        for a in &plan.attempts {
            if !a.won {
                continue;
            }
            plan.makespan_s = plan.makespan_s.max(a.end_s);
            plan.total_work_s += a.end_s - a.start_s;
            plan.input_read_s += self
                .model
                .read_time_at(tasks[a.task].cost.input_bytes, a.locality);
            if !tasks[a.task].hosts.is_empty() {
                match a.locality {
                    Locality::NodeLocal => plan.node_local += 1,
                    Locality::RackLocal => plan.rack_local += 1,
                    Locality::OffRack => plan.off_rack += 1,
                }
            }
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quiet_model() -> NetworkModel {
        NetworkModel {
            job_setup_s: 0.0,
            task_dispatch_s: 0.0,
            disk_bw: 1e18,
            net_bw: 1e18,
            rack_bw: 1e18,
            cross_rack_bw: 1e18,
            coord_per_machine_s: 0.0,
            shuffle_latency_s: 0.0,
            compute_scale: 1.0,
        }
    }

    fn compute_task(secs: f64, hosts: Vec<usize>) -> TaskSpec {
        TaskSpec {
            cost: TaskCost { compute_s: secs, input_bytes: 0, output_bytes: 0 },
            hosts,
        }
    }

    fn tracker_cfg(policy: Policy, speculation: bool) -> TrackerConfig {
        TrackerConfig {
            heartbeat_s: 1.0,
            policy,
            speculation: SpeculationConfig {
                enabled: speculation,
                ..Default::default()
            },
        }
    }

    #[test]
    fn empty_phase_is_free() {
        let topo = RackTopology::single(2);
        let model = quiet_model();
        let cfg = TrackerConfig::default();
        let speeds = [1.0, 1.0];
        let jt = JobTracker::new(&topo, &speeds, 2, &model, &cfg);
        let plan = jt.plan(&[]);
        assert_eq!(plan.makespan_s, 0.0);
        assert_eq!(plan.heartbeats, 0);
        assert!(plan.attempts.is_empty());
    }

    #[test]
    fn every_task_runs_exactly_once_without_speculation() {
        let topo = RackTopology::uniform(3, 2);
        let model = quiet_model();
        let cfg = tracker_cfg(Policy::Fifo, false);
        let speeds = [1.0; 3];
        let jt = JobTracker::new(&topo, &speeds, 2, &model, &cfg);
        let tasks: Vec<TaskSpec> =
            (0..10).map(|_| compute_task(2.0, vec![])).collect();
        let plan = jt.plan(&tasks);
        assert_eq!(plan.attempts.len(), 10);
        let mut seen = vec![0usize; 10];
        for a in &plan.attempts {
            assert!(a.won);
            seen[a.task] += 1;
            assert!(a.end_s > a.start_s);
        }
        assert!(seen.iter().all(|&c| c == 1));
        assert!(plan.makespan_s >= 2.0);
        assert!(plan.heartbeats > 0);
        // No host info -> nothing counted in locality tallies.
        assert_eq!(plan.placed(), 0);
    }

    #[test]
    fn locality_aware_places_tasks_on_their_hosts() {
        // Two slaves in two racks; each task's data lives on exactly one.
        let topo = RackTopology::uniform(2, 2);
        let model = quiet_model();
        let cfg = tracker_cfg(Policy::default(), false);
        let speeds = [1.0, 1.0];
        let jt = JobTracker::new(&topo, &speeds, 1, &model, &cfg);
        let tasks = vec![
            compute_task(1.0, vec![1]),
            compute_task(1.0, vec![0]),
            compute_task(1.0, vec![1]),
            compute_task(1.0, vec![0]),
        ];
        let plan = jt.plan(&tasks);
        assert_eq!(plan.node_local, 4, "{plan:?}");
        assert_eq!(plan.off_rack, 0);
        assert!((plan.data_local_pct() - 100.0).abs() < 1e-9);
        for a in &plan.attempts {
            assert!(tasks[a.task].hosts.contains(&a.slave));
        }
    }

    #[test]
    fn fifo_ignores_hosts() {
        // Same setup as above, but FIFO: slave 0 heartbeats first and takes
        // task 0 even though its data lives on slave 1 (off-rack here).
        let topo = RackTopology::uniform(2, 2);
        let model = quiet_model();
        let cfg = tracker_cfg(Policy::Fifo, false);
        let speeds = [1.0, 1.0];
        let jt = JobTracker::new(&topo, &speeds, 1, &model, &cfg);
        let tasks = vec![compute_task(1.0, vec![1]), compute_task(1.0, vec![0])];
        let plan = jt.plan(&tasks);
        assert_eq!(plan.off_rack, 2, "{plan:?}");
        assert_eq!(plan.node_local, 0);
    }

    #[test]
    fn delay_scheduling_gives_up_eventually() {
        // One slave, one rack; the task's host does not exist locally, so
        // after `locality_delay` skipped heartbeats it runs anyway.
        let topo = RackTopology::single(1);
        let model = quiet_model();
        let cfg = TrackerConfig {
            heartbeat_s: 1.0,
            policy: Policy::LocalityAware { locality_delay: 2 },
            speculation: SpeculationConfig { enabled: false, ..Default::default() },
        };
        let speeds = [1.0];
        let jt = JobTracker::new(&topo, &speeds, 1, &model, &cfg);
        let tasks = vec![compute_task(1.0, vec![7])];
        let plan = jt.plan(&tasks);
        assert_eq!(plan.attempts.len(), 1);
        // Skipped the heartbeats at t=0 and t=1, assigned at t=2.
        assert!((plan.attempts[0].start_s - 2.0).abs() < 1e-9, "{plan:?}");
        assert_eq!(plan.off_rack, 1);
    }

    #[test]
    fn speculation_duplicates_the_straggler_and_wins() {
        // Slave 1 is 10x slow; its task gets a duplicate on the fast slave
        // once the pending queue drains, cutting the makespan.
        let topo = RackTopology::single(2);
        let model = quiet_model();
        let speeds = [1.0, 0.1];
        let tasks = vec![
            compute_task(10.0, vec![]),
            compute_task(10.0, vec![]),
            compute_task(10.0, vec![]),
        ];
        let run = |spec: bool| {
            let cfg = tracker_cfg(Policy::Fifo, spec);
            let jt = JobTracker::new(&topo, &speeds, 1, &model, &cfg);
            jt.plan(&tasks)
        };
        let without = run(false);
        let with = run(true);
        assert_eq!(with.speculative_attempts, 1, "{with:?}");
        assert_eq!(with.speculative_wins, 1);
        assert!(
            with.makespan_s < without.makespan_s * 0.6,
            "spec {} vs plain {}",
            with.makespan_s,
            without.makespan_s
        );
        // Exactly one winning attempt per task either way.
        for plan in [&with, &without] {
            let wins = plan.attempts.iter().filter(|a| a.won).count();
            assert_eq!(wins, tasks.len());
        }
    }

    #[test]
    fn winning_slaves_cover_every_task() {
        let topo = RackTopology::uniform(3, 1);
        let model = quiet_model();
        let cfg = tracker_cfg(Policy::Fifo, false);
        let speeds = [1.0; 3];
        let jt = JobTracker::new(&topo, &speeds, 2, &model, &cfg);
        let tasks: Vec<TaskSpec> =
            (0..7).map(|_| compute_task(1.0, vec![])).collect();
        let plan = jt.plan(&tasks);
        let slaves = plan.winning_slaves(7);
        assert!(slaves.iter().all(|s| s.is_some()), "{slaves:?}");
        for a in plan.attempts.iter().filter(|a| a.won) {
            assert_eq!(slaves[a.task], Some(a.slave));
        }
        // Short vectors are tolerated (tasks beyond the bound dropped).
        assert_eq!(plan.winning_slaves(2).len(), 2);
    }

    #[test]
    fn scheduled_death_replans_running_attempts_on_live_nodes() {
        use crate::cluster::{FaultConfig, FaultDomain, NodeDeath};
        let topo = RackTopology::single(2);
        let model = quiet_model();
        let cfg = tracker_cfg(Policy::Fifo, false);
        let speeds = [1.0, 1.0];
        let faults = FaultDomain::new(
            2,
            FaultConfig {
                node_deaths: vec![NodeDeath { slave: 1, at_heartbeat: 4 }],
                ..FaultConfig::default()
            },
        );
        let jt = JobTracker::new(&topo, &speeds, 1, &model, &cfg).with_faults(&faults);
        let tasks = vec![compute_task(10.0, vec![]), compute_task(10.0, vec![])];
        let plan = jt.plan(&tasks);
        assert_eq!(plan.deaths, 1, "{plan:?}");
        assert!(plan.failed_tasks.is_empty(), "both tasks must finish: {plan:?}");
        // Every winning attempt ran on the surviving slave.
        let winners: Vec<&Attempt> = plan.attempts.iter().filter(|a| a.won).collect();
        assert_eq!(winners.len(), 2);
        assert!(winners.iter().all(|a| a.slave == 0), "{plan:?}");
        // The attempt lost to the death was truncated at the death time and
        // no attempt ever starts on the dead slave afterwards.
        let lost: Vec<&Attempt> =
            plan.attempts.iter().filter(|a| a.slave == 1).collect();
        assert_eq!(lost.len(), 1);
        assert!(!lost[0].won);
        assert!((lost[0].end_s - 1.5).abs() < 1e-9, "{plan:?}");
        // Re-execution serializes on the lone survivor: makespan ~ 20s.
        assert!(plan.makespan_s > 19.0, "{plan:?}");
    }

    #[test]
    fn surviving_speculative_duplicate_inherits_task_when_winner_dies() {
        // t1's primary runs on slave 1; a speculative duplicate launches
        // on slave 0 and loses the pre-resolved race. Slave 1 then dies
        // BEFORE the race's win time: the live duplicate must inherit the
        // task (no from-scratch third attempt).
        use crate::cluster::{FaultConfig, FaultDomain, NodeDeath};
        let topo = RackTopology::single(2);
        let model = quiet_model();
        let cfg = tracker_cfg(Policy::Fifo, true); // speculation ON
        let speeds = [1.0, 1.0];
        let faults = FaultDomain::new(
            2,
            FaultConfig {
                // Tick 8 = slave 1's heartbeat at t=3.5, after the
                // duplicate launches at t=3.0 and before the 8.5s win.
                node_deaths: vec![NodeDeath { slave: 1, at_heartbeat: 8 }],
                ..FaultConfig::default()
            },
        );
        let jt = JobTracker::new(&topo, &speeds, 1, &model, &cfg).with_faults(&faults);
        let tasks = vec![compute_task(1.0, vec![]), compute_task(8.0, vec![])];
        let plan = jt.plan(&tasks);
        assert_eq!(plan.deaths, 1, "{plan:?}");
        assert!(plan.failed_tasks.is_empty(), "{plan:?}");
        assert_eq!(
            plan.attempts.len(),
            3,
            "t0 + t1 primary + t1 duplicate — no third t1 attempt: {plan:?}"
        );
        let winner = plan
            .attempts
            .iter()
            .find(|a| a.task == 1 && a.won)
            .expect("t1 must finish");
        assert!(winner.speculative, "the duplicate inherits the task: {plan:?}");
        assert_eq!(winner.slave, 0);
        // The duplicate runs to its natural end: launched at t=3.0 with an
        // 8s task -> finishes at 11.0, which is also the makespan.
        assert!((winner.end_s - 11.0).abs() < 1e-9, "{plan:?}");
        assert!((plan.makespan_s - 11.0).abs() < 1e-9, "{plan:?}");
        assert!(plan.speculative_wins >= 1, "promotion counts as a win");
    }

    #[test]
    fn injected_attempt_failures_replan_and_are_deterministic() {
        use crate::cluster::{FaultConfig, FaultDomain};
        let topo = RackTopology::single(4);
        let model = quiet_model();
        let cfg = tracker_cfg(Policy::Fifo, false);
        let speeds = [1.0; 4];
        let tasks: Vec<TaskSpec> =
            (0..20).map(|_| compute_task(1.0, vec![])).collect();
        let run = || {
            let faults = FaultDomain::new(
                4,
                FaultConfig {
                    task_fail_prob: 0.5,
                    seed: 11,
                    max_attempts: 1000,
                    blacklist_after: 1000,
                    ..FaultConfig::default()
                },
            );
            JobTracker::new(&topo, &speeds, 1, &model, &cfg)
                .with_faults(&faults)
                .plan(&tasks)
        };
        let plan = run();
        assert!(plan.failed_attempts > 0, "p=0.5 must fail attempts: {plan:?}");
        assert!(plan.failed_tasks.is_empty());
        let wins = plan.attempts.iter().filter(|a| a.won).count();
        assert_eq!(wins, 20, "every task still completes exactly once");
        // Failed attempts occupy their slot until they die, then the task
        // re-plans: total attempts = wins + failures.
        assert_eq!(
            plan.attempts.len() as u64,
            20 + plan.failed_attempts,
            "{plan:?}"
        );
        // Seeded chaos is reproducible bit for bit.
        let again = run();
        assert_eq!(again.failed_attempts, plan.failed_attempts);
        assert!((again.makespan_s - plan.makespan_s).abs() < 1e-12);
    }

    #[test]
    fn blacklisted_slave_receives_zero_attempts() {
        use crate::cluster::{FaultConfig, FaultDomain};
        let topo = RackTopology::single(3);
        let model = quiet_model();
        let cfg = tracker_cfg(Policy::Fifo, false);
        let speeds = [1.0; 3];
        let faults = FaultDomain::new(
            3,
            FaultConfig { blacklist_after: 1, ..FaultConfig::default() },
        );
        assert!(faults.record_failure(1), "one failure blacklists at threshold 1");
        let tasks: Vec<TaskSpec> =
            (0..9).map(|_| compute_task(1.0, vec![])).collect();
        let jt = JobTracker::new(&topo, &speeds, 1, &model, &cfg).with_faults(&faults);
        let plan = jt.plan(&tasks);
        assert!(plan.failed_tasks.is_empty());
        assert!(
            plan.attempts.iter().all(|a| a.slave != 1),
            "blacklisted slave must receive zero attempts: {plan:?}"
        );
        assert_eq!(plan.attempts.iter().filter(|a| a.won).count(), 9);
    }

    #[test]
    fn in_plan_blacklisting_stops_further_attempts_immediately() {
        use crate::cluster::{FaultConfig, FaultDomain};
        let topo = RackTopology::single(4);
        let model = quiet_model();
        let cfg = tracker_cfg(Policy::Fifo, false);
        let speeds = [1.0; 4];
        let faults = FaultDomain::new(
            4,
            FaultConfig {
                task_fail_prob: 0.5,
                seed: 3,
                max_attempts: 1000,
                blacklist_after: 2,
                ..FaultConfig::default()
            },
        );
        let tasks: Vec<TaskSpec> =
            (0..40).map(|_| compute_task(1.0, vec![])).collect();
        let jt = JobTracker::new(&topo, &speeds, 1, &model, &cfg).with_faults(&faults);
        let plan = jt.plan(&tasks);
        assert!(
            !plan.blacklisted.is_empty(),
            "p=0.5 with threshold 2 must blacklist someone: {plan:?}"
        );
        for &(slave, when) in &plan.blacklisted {
            assert!(
                plan.attempts
                    .iter()
                    .all(|a| a.slave != slave || a.start_s <= when + EPS),
                "slave {slave} got an attempt after its blacklist at {when}: {plan:?}"
            );
        }
    }

    #[test]
    fn all_slaves_dead_fails_the_remaining_tasks() {
        use crate::cluster::{FaultConfig, FaultDomain, NodeDeath};
        let topo = RackTopology::single(2);
        let model = quiet_model();
        let cfg = tracker_cfg(Policy::Fifo, false);
        let speeds = [1.0, 1.0];
        let faults = FaultDomain::new(
            2,
            FaultConfig {
                node_deaths: vec![
                    NodeDeath { slave: 0, at_heartbeat: 3 },
                    NodeDeath { slave: 1, at_heartbeat: 3 },
                ],
                ..FaultConfig::default()
            },
        );
        let tasks: Vec<TaskSpec> =
            (0..6).map(|_| compute_task(50.0, vec![])).collect();
        let jt = JobTracker::new(&topo, &speeds, 1, &model, &cfg).with_faults(&faults);
        let plan = jt.plan(&tasks);
        assert_eq!(plan.deaths, 2);
        assert!(
            !plan.failed_tasks.is_empty(),
            "with every slave dead the phase must report failure: {plan:?}"
        );
        assert!(plan.attempts.iter().all(|a| !a.won));
    }

    #[test]
    fn off_rack_reads_cost_more() {
        // Same single task, forced node-local vs off-rack by policy: the
        // off-rack read is charged the slower cross-rack bandwidth.
        let topo = RackTopology::uniform(2, 2);
        let model = NetworkModel {
            disk_bw: 100e6,
            cross_rack_bw: 10e6,
            ..quiet_model()
        };
        let speeds = [1.0, 1.0];
        let cfg = tracker_cfg(Policy::Fifo, false);
        let jt = JobTracker::new(&topo, &speeds, 1, &model, &cfg);
        let mk = |hosts: Vec<usize>| TaskSpec {
            cost: TaskCost {
                compute_s: 0.0,
                input_bytes: 100_000_000,
                output_bytes: 0,
            },
            hosts,
        };
        // FIFO sends task 0 to slave 0 (first heartbeat).
        let local = jt.plan(&[mk(vec![0])]);
        let remote = jt.plan(&[mk(vec![1])]);
        assert!(remote.input_read_s > local.input_read_s * 5.0, "{remote:?}");
        assert!(remote.makespan_s > local.makespan_s);
    }

    #[test]
    fn queue_wait_and_slot_idle_accounting() {
        let mk = |start_s: f64, end_s: f64, won: bool| Attempt {
            task: 0,
            slave: 0,
            slot: 0,
            start_s,
            end_s,
            locality: Locality::None,
            speculative: false,
            won,
        };
        let plan = SchedulePlan {
            makespan_s: 10.0,
            attempts: vec![mk(0.0, 4.0, true), mk(2.0, 10.0, true), mk(3.0, 5.0, false)],
            ..Default::default()
        };
        // Winners waited 0 s + 2 s; the killed loser doesn't count.
        assert!((plan.queue_wait_s() - 2.0).abs() < 1e-12);
        // Busy slot-seconds include the loser's occupancy.
        assert!((plan.busy_slot_s() - 14.0).abs() < 1e-12);
        // 2 slots × 10 s capacity − 14 s busy = 6 s idle.
        assert!((plan.slot_idle_s(2) - 6.0).abs() < 1e-12);
        // Idle clamps at zero when attempts oversubscribe the capacity.
        assert_eq!(plan.slot_idle_s(1), 0.0);
        // The empty plan is all zeros.
        let empty = SchedulePlan::default();
        assert_eq!(empty.queue_wait_s(), 0.0);
        assert_eq!(empty.slot_idle_s(4), 0.0);
    }
}
