//! Rack topology over the cluster's nodes.
//!
//! Hadoop's NameNode and JobTracker share one network map: every slave (and
//! its co-located DataNode) lives in a rack, and the scheduler/replica
//! placement reason in the three HDFS distance tiers — same node, same rack,
//! off rack. Node ids here are the shared id space of
//! [`crate::cluster::SlaveNode`], DFS datanodes and table region servers.

/// Immutable node → rack map.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RackTopology {
    rack_of: Vec<usize>,
    racks: usize,
}

impl RackTopology {
    /// All `nodes` in one rack (the pre-scheduler behaviour).
    pub fn single(nodes: usize) -> Self {
        Self::custom(vec![0; nodes.max(1)])
    }

    /// `nodes` spread over `racks` contiguous groups, e.g. 5 nodes on
    /// 2 racks -> racks `[0, 0, 0, 1, 1]`. `racks` is clamped to `1..=nodes`.
    pub fn uniform(nodes: usize, racks: usize) -> Self {
        assert!(nodes > 0, "topology needs at least one node");
        let racks = racks.clamp(1, nodes);
        Self::custom((0..nodes).map(|i| i * racks / nodes).collect())
    }

    /// Explicit node → rack assignment. Rack ids should be dense from 0.
    pub fn custom(rack_of: Vec<usize>) -> Self {
        assert!(!rack_of.is_empty(), "topology needs at least one node");
        let racks = rack_of.iter().copied().max().unwrap_or(0) + 1;
        Self { rack_of, racks }
    }

    /// Number of nodes.
    pub fn num_nodes(&self) -> usize {
        self.rack_of.len()
    }

    /// Number of racks.
    pub fn num_racks(&self) -> usize {
        self.racks
    }

    /// Rack of one node.
    pub fn rack_of(&self, node: usize) -> usize {
        self.rack_of[node]
    }

    /// Do two nodes share a rack?
    pub fn same_rack(&self, a: usize, b: usize) -> bool {
        self.rack_of[a] == self.rack_of[b]
    }

    /// All nodes in one rack, ascending.
    pub fn nodes_in(&self, rack: usize) -> Vec<usize> {
        (0..self.rack_of.len())
            .filter(|&n| self.rack_of[n] == rack)
            .collect()
    }

    /// HDFS-style network distance: 0 same node, 2 same rack, 4 off rack.
    pub fn distance(&self, a: usize, b: usize) -> u32 {
        if a == b {
            0
        } else if self.same_rack(a, b) {
            2
        } else {
            4
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_rack_puts_everyone_together() {
        let t = RackTopology::single(4);
        assert_eq!(t.num_nodes(), 4);
        assert_eq!(t.num_racks(), 1);
        assert!(t.same_rack(0, 3));
        assert_eq!(t.nodes_in(0), vec![0, 1, 2, 3]);
    }

    #[test]
    fn uniform_splits_contiguously() {
        let t = RackTopology::uniform(5, 2);
        assert_eq!(
            (0..5).map(|n| t.rack_of(n)).collect::<Vec<_>>(),
            vec![0, 0, 0, 1, 1]
        );
        assert_eq!(t.num_racks(), 2);
        assert!(t.same_rack(0, 2));
        assert!(!t.same_rack(2, 3));
    }

    #[test]
    fn uniform_clamps_rack_count() {
        assert_eq!(RackTopology::uniform(3, 10).num_racks(), 3);
        assert_eq!(RackTopology::uniform(3, 0).num_racks(), 1);
    }

    #[test]
    fn distance_tiers() {
        let t = RackTopology::uniform(4, 2);
        assert_eq!(t.distance(1, 1), 0);
        assert_eq!(t.distance(0, 1), 2);
        assert_eq!(t.distance(1, 2), 4);
    }

    #[test]
    fn custom_assignment_respected() {
        let t = RackTopology::custom(vec![0, 1, 0, 1]);
        assert_eq!(t.num_racks(), 2);
        assert_eq!(t.nodes_in(1), vec![1, 3]);
    }
}
