//! Placement tiers: classify and pick tasks for a freed slot.
//!
//! Hadoop's JobTracker serves a TaskTracker heartbeat by scanning the
//! pending queue for a split whose DFS replicas sit on that tracker's node
//! (data-local), then its rack (rack-local), then anything (off-rack). This
//! module is that scan, kept pure so both policies and the tests can drive
//! it directly.

use super::rack::RackTopology;
use super::TaskSpec;

/// Locality tier of one task attempt (Hadoop's three levels).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Locality {
    /// A replica of the task's input lives on the executing node.
    NodeLocal,
    /// A replica lives in the executing node's rack.
    RackLocal,
    /// Input must cross the core switch.
    OffRack,
}

impl Locality {
    /// Short human-readable label.
    pub fn label(self) -> &'static str {
        match self {
            Locality::NodeLocal => "node-local",
            Locality::RackLocal => "rack-local",
            Locality::OffRack => "off-rack",
        }
    }
}

/// Classify running a task whose input replicas live on `hosts` on `slave`.
///
/// Tasks with no location info (synthetic splits, shuffle input) count as
/// node-local: there is nothing remote to fetch. Host ids outside the
/// topology are ignored.
pub fn classify(slave: usize, hosts: &[usize], topo: &RackTopology) -> Locality {
    if hosts.is_empty() || hosts.contains(&slave) {
        return Locality::NodeLocal;
    }
    if hosts
        .iter()
        .any(|&h| h < topo.num_nodes() && topo.same_rack(h, slave))
    {
        Locality::RackLocal
    } else {
        Locality::OffRack
    }
}

/// Best pending task for a slot on `slave`: the first node-local candidate,
/// else the first rack-local, else the first pending (FIFO within a tier).
///
/// Returns `(position in pending, locality)`.
pub fn pick_best(
    pending: &[usize],
    specs: &[TaskSpec],
    slave: usize,
    topo: &RackTopology,
) -> Option<(usize, Locality)> {
    let mut rack_local: Option<usize> = None;
    let mut off_rack: Option<usize> = None;
    for (pos, &task) in pending.iter().enumerate() {
        match classify(slave, &specs[task].hosts, topo) {
            Locality::NodeLocal => return Some((pos, Locality::NodeLocal)),
            Locality::RackLocal => {
                if rack_local.is_none() {
                    rack_local = Some(pos);
                }
            }
            Locality::OffRack => {
                if off_rack.is_none() {
                    off_rack = Some(pos);
                }
            }
        }
    }
    if let Some(pos) = rack_local {
        return Some((pos, Locality::RackLocal));
    }
    off_rack.map(|pos| (pos, Locality::OffRack))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::TaskCost;

    fn spec(hosts: Vec<usize>) -> TaskSpec {
        TaskSpec { cost: TaskCost::default(), hosts }
    }

    #[test]
    fn classify_tiers() {
        let topo = RackTopology::uniform(4, 2); // racks [0,0,1,1]
        assert_eq!(classify(1, &[1, 3], &topo), Locality::NodeLocal);
        assert_eq!(classify(0, &[1], &topo), Locality::RackLocal);
        assert_eq!(classify(0, &[2, 3], &topo), Locality::OffRack);
    }

    #[test]
    fn empty_or_bogus_hosts_are_harmless() {
        let topo = RackTopology::uniform(2, 2);
        assert_eq!(classify(0, &[], &topo), Locality::NodeLocal);
        // Host id beyond the topology: ignored, not a panic.
        assert_eq!(classify(0, &[99], &topo), Locality::OffRack);
    }

    #[test]
    fn pick_prefers_node_then_rack_then_any() {
        let topo = RackTopology::uniform(4, 2);
        let specs = vec![
            spec(vec![3]), // off-rack for slave 0
            spec(vec![1]), // rack-local for slave 0
            spec(vec![0]), // node-local for slave 0
        ];
        let pending = vec![0, 1, 2];
        assert_eq!(
            pick_best(&pending, &specs, 0, &topo),
            Some((2, Locality::NodeLocal))
        );
        let pending = vec![0, 1];
        assert_eq!(
            pick_best(&pending, &specs, 0, &topo),
            Some((1, Locality::RackLocal))
        );
        let pending = vec![0];
        assert_eq!(
            pick_best(&pending, &specs, 0, &topo),
            Some((0, Locality::OffRack))
        );
        assert_eq!(pick_best(&[], &specs, 0, &topo), None);
    }

    #[test]
    fn fifo_within_a_tier() {
        let topo = RackTopology::single(2);
        let specs = vec![spec(vec![0]), spec(vec![0])];
        let pending = vec![0, 1];
        // Both node-local on slave 0: the earlier task wins.
        assert_eq!(
            pick_best(&pending, &specs, 0, &topo),
            Some((0, Locality::NodeLocal))
        );
    }
}
