//! Straggler detection for live speculative execution.
//!
//! When a heartbeat offers a free slot and no pending work remains, the
//! JobTracker may launch a *duplicate attempt* of a running task that looks
//! slow (paper §2.2: "when a task fails or goes slowly, the JobTracker
//! restarts it on another node"). The detector here is Hadoop's rule in
//! miniature: an attempt is a straggler once it has been running longer
//! than `slowdown ×` the median duration of already-completed tasks.

/// Speculative-execution knobs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpeculationConfig {
    /// Master switch (`mapred.map.tasks.speculative.execution`).
    pub enabled: bool,
    /// Straggler threshold as a multiple of the median completed duration.
    pub slowdown: f64,
    /// Completed tasks required before duration estimates are trusted.
    pub min_completed: usize,
}

impl Default for SpeculationConfig {
    fn default() -> Self {
        Self { enabled: true, slowdown: 1.5, min_completed: 1 }
    }
}

/// Pick the running task most deserving a duplicate attempt at time `now`.
///
/// `running` holds `(task id, attempt start)` for tasks that are still
/// unfinished and not yet speculated; `completed_durations` the durations
/// of tasks that finished before `now`. Returns the longest-elapsed task
/// over the straggler threshold, if any.
pub fn pick_straggler(
    now: f64,
    running: &[(usize, f64)],
    completed_durations: &[f64],
    cfg: &SpeculationConfig,
) -> Option<usize> {
    if !cfg.enabled || completed_durations.len() < cfg.min_completed.max(1) {
        return None;
    }
    let mut ds = completed_durations.to_vec();
    ds.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = ds[ds.len() / 2];
    let threshold = cfg.slowdown * median;
    running
        .iter()
        .filter(|&&(_, start)| now - start > threshold)
        .max_by(|a, b| {
            // Longest-running first; task id breaks ties deterministically.
            (now - a.1, std::cmp::Reverse(a.0))
                .partial_cmp(&(now - b.1, std::cmp::Reverse(b.0)))
                .unwrap()
        })
        .map(|&(task, _)| task)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_never_speculates() {
        let cfg = SpeculationConfig { enabled: false, ..Default::default() };
        assert_eq!(pick_straggler(100.0, &[(0, 0.0)], &[1.0], &cfg), None);
    }

    #[test]
    fn needs_completed_history() {
        let cfg = SpeculationConfig::default();
        assert_eq!(pick_straggler(100.0, &[(0, 0.0)], &[], &cfg), None);
    }

    #[test]
    fn flags_only_over_threshold() {
        let cfg = SpeculationConfig::default(); // slowdown 1.5
        let completed = [10.0, 10.0, 12.0]; // median 10 -> threshold 15
        // Elapsed 14: under threshold.
        assert_eq!(pick_straggler(20.0, &[(7, 6.0)], &completed, &cfg), None);
        // Elapsed 16: straggler.
        assert_eq!(pick_straggler(20.0, &[(7, 4.0)], &completed, &cfg), Some(7));
    }

    #[test]
    fn picks_longest_running() {
        let cfg = SpeculationConfig::default();
        let completed = [1.0];
        let running = [(3, 10.0), (5, 2.0), (9, 6.0)];
        assert_eq!(pick_straggler(20.0, &running, &completed, &cfg), Some(5));
    }
}
