//! Pluggable task-selection policies for the JobTracker.

/// How the JobTracker fills a freed slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Hadoop's naive slot filling: the next pending task goes to the next
    /// free slot, blind to where its input lives (locality is still
    /// *recorded*, it just never influences the choice).
    Fifo,
    /// Three-tier locality-first with delay scheduling: a slave with no
    /// node-local work may decline up to `locality_delay` of its own
    /// heartbeats, waiting for local work to appear, before settling for
    /// rack-local or off-rack tasks.
    LocalityAware {
        /// Heartbeats a slave may skip before taking non-local work.
        locality_delay: usize,
    },
}

impl Default for Policy {
    fn default() -> Self {
        Policy::LocalityAware { locality_delay: 2 }
    }
}

impl Policy {
    /// Parse a config value (`fifo` / `locality`).
    pub fn parse(s: &str) -> Option<Policy> {
        match s {
            "fifo" => Some(Policy::Fifo),
            "locality" | "locality_first" | "locality-first" => Some(Policy::default()),
            _ => None,
        }
    }

    /// Stable name for reports.
    pub fn name(&self) -> &'static str {
        match self {
            Policy::Fifo => "fifo",
            Policy::LocalityAware { .. } => "locality",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips() {
        assert_eq!(Policy::parse("fifo"), Some(Policy::Fifo));
        assert_eq!(
            Policy::parse("locality"),
            Some(Policy::LocalityAware { locality_delay: 2 })
        );
        assert_eq!(Policy::parse("bogus"), None);
        assert_eq!(Policy::default().name(), "locality");
        assert_eq!(Policy::Fifo.name(), "fifo");
    }
}
