//! Cluster telemetry on the virtual clock (DESIGN.md §2.15).
//!
//! The trace layer (§2.11) keeps every span of a run; this layer turns
//! that one-off timeline into the aggregate signals operators actually
//! watch: time-resolved gauges (slot occupancy per rack, queued vs
//! running attempts, bytes in flight, node liveness) sampled on a fixed
//! grid over the run, and log-bucket [`histogram::Histogram`]s of the
//! latency/size distributions (attempt duration, queue wait, fetch bytes,
//! spill size).
//!
//! Everything here derives from [`TraceData`] — schedule plans, fetch
//! plans and fault instants on the **virtual clock** — so two runs with
//! the same seed produce byte-identical exports: the Prometheus snapshot
//! (`--metrics-out`, [`prometheus`]), the `timeseries`/`histograms`
//! sections of the `psch.run_report.v2` JSON, and the CLI utilization
//! sparklines. Wall-clock times never enter this module.
//!
//! [`diff`] closes the loop: it reads two RunReports back and gates on
//! regressions (`psch report diff`).

pub mod diff;
pub mod histogram;
pub mod prometheus;

use crate::trace::{ArgValue, Span, SpanKind, TraceData};
use histogram::Histogram;

/// Samples in every gauge series: dense enough to show phase structure,
/// small enough to keep reports readable.
pub const SAMPLES: usize = 64;

/// One sampled gauge: a name, an optional label (`rack="2"`), and one
/// value per grid sample.
#[derive(Debug, Clone)]
pub struct GaugeSeries {
    /// Metric name (`busy_slots`, `running_attempts`, ...).
    pub name: &'static str,
    /// Optional label pair rendered as `{key="value"}`.
    pub label: Option<(&'static str, String)>,
    /// One value per entry of [`Timeseries::times_s`].
    pub values: Vec<u64>,
}

impl GaugeSeries {
    /// Mean over the series (0 for the empty series).
    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            0.0
        } else {
            self.values.iter().sum::<u64>() as f64 / self.values.len() as f64
        }
    }

    /// Peak over the series (0 for the empty series).
    pub fn peak(&self) -> u64 {
        self.values.iter().copied().max().unwrap_or(0)
    }
}

/// The sampled gauge block: a shared time grid plus every gauge series.
#[derive(Debug, Clone, Default)]
pub struct Timeseries {
    /// Sample times, seconds since run start (virtual clock).
    pub times_s: Vec<f64>,
    /// Gauge series, in catalog order (racks ascending within a name).
    pub gauges: Vec<GaugeSeries>,
}

/// The full telemetry derivation of one traced run.
#[derive(Debug, Clone)]
pub struct Telemetry {
    /// Virtual makespan the grid spans.
    pub makespan_s: f64,
    /// Slot capacity of the traced cluster (slaves × slots each).
    pub total_slots: usize,
    /// Sampled gauges.
    pub timeseries: Timeseries,
    /// Distribution histograms, finished (sorted) and ready to query.
    pub histograms: Vec<Histogram>,
}

/// One job's window on the run timeline with the spans telemetry needs
/// attributed to it. Jobs are recorded serially (the trace cursor advances
/// per job), so span→job attribution by emission order is exact.
struct JobWindow {
    start_s: f64,
    end_s: f64,
    /// `(start, end)` of every attempt span in the job.
    attempts: Vec<(f64, f64)>,
    /// The shuffle-fetch barrier window, if the job had one.
    barrier: Option<(f64, f64)>,
    /// Total bytes the job's reducers fetch (in flight while the barrier
    /// is open).
    fetch_bytes: u64,
}

impl Telemetry {
    /// Telemetry of a run with no trace (oracle serving paths): empty
    /// grid, empty histograms — still renders/export cleanly.
    pub fn empty() -> Self {
        let data = TraceData {
            slaves: 0,
            slots_per_slave: 1,
            makespan_s: 0.0,
            phases: Vec::new(),
            jobs: Vec::new(),
            spans: Vec::new(),
            instants: Vec::new(),
        };
        from_trace(&data, 1)
    }
}

/// Derive the full telemetry of one traced run. `racks` is the configured
/// rack count; slaves map to racks exactly like
/// `RackTopology::uniform` (`rack = slave × racks / slaves`).
pub fn from_trace(data: &TraceData, racks: usize) -> Telemetry {
    let slaves = data.slaves;
    let slots_per_slave = data.slots_per_slave.max(1);
    let total_slots = slaves * slots_per_slave;
    let racks = racks.clamp(1, slaves.max(1));
    let rack_of = |slave: usize| -> usize {
        if slaves == 0 {
            0
        } else {
            slave * racks / slaves
        }
    };
    // Slot capacity per rack (uniform topology: contiguous slave ranges).
    let mut rack_slots = vec![0u64; racks];
    for s in 0..slaves {
        rack_slots[rack_of(s)] += slots_per_slave as u64;
    }

    let times_s: Vec<f64> = if data.makespan_s <= 0.0 {
        vec![0.0]
    } else {
        (0..SAMPLES)
            .map(|i| data.makespan_s * i as f64 / (SAMPLES - 1) as f64)
            .collect()
    };
    let n = times_s.len();

    // Attribute spans to their job by emission order: each Job span is
    // followed by that job's setup/attempt/barrier/IO spans.
    let mut windows: Vec<JobWindow> = Vec::new();
    let mut reads: Vec<(f64, f64, u64)> = Vec::new();
    let mut writes: Vec<(f64, f64, u64)> = Vec::new();
    let mut fetch_streams: Vec<(f64, f64)> = Vec::new();
    for span in &data.spans {
        match span.kind {
            SpanKind::Job => {
                // Job spans and `data.jobs` records are appended in the
                // same per-job order, so the next window's analysis record
                // sits at the current window count.
                let fetch_bytes = data
                    .jobs
                    .get(windows.len())
                    .map(|j| j.reducer_bytes.iter().sum())
                    .unwrap_or(0);
                windows.push(JobWindow {
                    start_s: span.start_s,
                    end_s: span.end_s,
                    attempts: Vec::new(),
                    barrier: None,
                    fetch_bytes,
                });
            }
            SpanKind::Attempt => {
                if let Some(w) = windows.last_mut() {
                    w.attempts.push((span.start_s, span.end_s));
                }
            }
            SpanKind::FetchBarrier => {
                if let Some(w) = windows.last_mut() {
                    w.barrier = Some((span.start_s, span.end_s));
                }
            }
            SpanKind::Read => reads.push((span.start_s, span.end_s, span_bytes(span))),
            SpanKind::Write => {
                writes.push((span.start_s, span.end_s, span_bytes(span)))
            }
            SpanKind::Fetch => fetch_streams.push((span.start_s, span.end_s)),
            _ => {}
        }
    }

    // Attempt spans tagged with their slave's rack, for per-rack gauges.
    let attempt_racks: Vec<(f64, f64, usize)> = data
        .spans
        .iter()
        .filter(|s| s.kind == SpanKind::Attempt && s.track > 0)
        .map(|s| {
            let slave = (s.track - 1) / slots_per_slave;
            (s.start_s, s.end_s, rack_of(slave.min(slaves.saturating_sub(1))))
        })
        .collect();

    // A span is active at t on the half-open interval [start, end).
    let active = |start: f64, end: f64, t: f64| start <= t && t < end;

    let mut busy_total = vec![0u64; n];
    let mut busy_rack = vec![vec![0u64; n]; racks];
    let mut running = vec![0u64; n];
    let mut queued = vec![0u64; n];
    let mut streams = vec![0u64; n];
    let mut backlog = vec![0u64; n];
    let mut read_fly = vec![0u64; n];
    let mut write_fly = vec![0u64; n];
    let mut dead = vec![0u64; n];
    let mut blacklisted = vec![0u64; n];
    for (i, &t) in times_s.iter().enumerate() {
        for &(s, e, rack) in &attempt_racks {
            if active(s, e, t) {
                busy_total[i] += 1;
                busy_rack[rack][i] += 1;
                running[i] += 1;
            }
        }
        for w in &windows {
            if active(w.start_s, w.end_s, t) {
                queued[i] +=
                    w.attempts.iter().filter(|&&(s, _)| s > t).count() as u64;
                if let Some((bs, be)) = w.barrier {
                    if active(bs, be, t) {
                        backlog[i] += w.fetch_bytes;
                    }
                }
            }
        }
        streams[i] += fetch_streams.iter().filter(|&&(s, e)| active(s, e, t)).count()
            as u64;
        read_fly[i] +=
            reads.iter().filter(|&&(s, e, _)| active(s, e, t)).map(|r| r.2).sum::<u64>();
        write_fly[i] += writes
            .iter()
            .filter(|&&(s, e, _)| active(s, e, t))
            .map(|r| r.2)
            .sum::<u64>();
        dead[i] = data
            .instants
            .iter()
            .filter(|ev| ev.name == "node-death" && ev.time_s <= t)
            .count() as u64;
        blacklisted[i] = data
            .instants
            .iter()
            .filter(|ev| ev.name == "slave-blacklisted" && ev.time_s <= t)
            .count() as u64;
    }

    let mut gauges = Vec::new();
    let total = total_slots as u64;
    gauges.push(GaugeSeries {
        name: "busy_slots",
        label: None,
        values: busy_total.clone(),
    });
    gauges.push(GaugeSeries {
        name: "idle_slots",
        label: None,
        values: busy_total.iter().map(|&b| total.saturating_sub(b)).collect(),
    });
    for (r, series) in busy_rack.iter().enumerate() {
        gauges.push(GaugeSeries {
            name: "busy_slots_rack",
            label: Some(("rack", r.to_string())),
            values: series.clone(),
        });
        gauges.push(GaugeSeries {
            name: "idle_slots_rack",
            label: Some(("rack", r.to_string())),
            values: series.iter().map(|&b| rack_slots[r].saturating_sub(b)).collect(),
        });
    }
    gauges.push(GaugeSeries { name: "running_attempts", label: None, values: running });
    gauges.push(GaugeSeries { name: "queued_attempts", label: None, values: queued });
    gauges.push(GaugeSeries {
        name: "shuffle_fetch_streams",
        label: None,
        values: streams,
    });
    gauges.push(GaugeSeries {
        name: "shuffle_backlog_bytes",
        label: None,
        values: backlog,
    });
    gauges.push(GaugeSeries {
        name: "dfs_read_bytes_in_flight",
        label: None,
        values: read_fly,
    });
    gauges.push(GaugeSeries {
        name: "dfs_write_bytes_in_flight",
        label: None,
        values: write_fly,
    });
    gauges.push(GaugeSeries {
        name: "live_nodes",
        label: None,
        values: dead.iter().map(|&d| (slaves as u64).saturating_sub(d)).collect(),
    });
    gauges.push(GaugeSeries { name: "dead_nodes", label: None, values: dead });
    gauges.push(GaugeSeries {
        name: "blacklisted_nodes",
        label: None,
        values: blacklisted,
    });

    // Distribution histograms from the per-job analysis records.
    let mut attempt_h = Histogram::seconds("attempt_duration_seconds");
    let mut wait_h = Histogram::seconds("queue_wait_seconds");
    let mut fetch_h = Histogram::bytes("fetch_bytes");
    let mut spill_h = Histogram::bytes("spill_bytes");
    for job in &data.jobs {
        attempt_h.record_all(job.map_durations.iter().copied());
        attempt_h.record_all(job.reduce_durations.iter().copied());
        wait_h.record_all(job.queue_waits.iter().copied());
        fetch_h.record_all(job.reducer_bytes.iter().map(|&b| b as f64));
        spill_h.record_all(job.spill_bytes.iter().map(|&b| b as f64));
    }
    let mut histograms = vec![attempt_h, wait_h, fetch_h, spill_h];
    for h in &mut histograms {
        h.finish();
    }

    Telemetry {
        makespan_s: data.makespan_s,
        total_slots,
        timeseries: Timeseries { times_s, gauges },
        histograms,
    }
}

/// The `bytes` argument of a span (0 when absent).
fn span_bytes(span: &Span) -> u64 {
    span.args
        .iter()
        .find_map(|(k, v)| match (k, v) {
            (&"bytes", ArgValue::U64(b)) => Some(*b),
            _ => None,
        })
        .unwrap_or(0)
}

/// The report-v2 `timeseries` JSON object.
pub fn timeseries_json(ts: &Timeseries) -> String {
    let times: Vec<String> =
        ts.times_s.iter().map(|&t| crate::trace::json::num(t)).collect();
    let gauges: Vec<String> = ts
        .gauges
        .iter()
        .map(|g| {
            let labels = match &g.label {
                Some((k, v)) => format!(
                    "{{\"{}\": \"{}\"}}",
                    crate::trace::json::esc(k),
                    crate::trace::json::esc(v)
                ),
                None => "{}".to_string(),
            };
            let values: Vec<String> = g.values.iter().map(u64::to_string).collect();
            format!(
                "{{\"name\": \"{}\", \"labels\": {}, \"values\": [{}]}}",
                crate::trace::json::esc(g.name),
                labels,
                values.join(", ")
            )
        })
        .collect();
    format!(
        "{{\"samples\": {}, \"times_s\": [{}], \"gauges\": [{}]}}",
        ts.times_s.len(),
        times.join(", "),
        gauges.join(", ")
    )
}

/// The report-v2 `histograms` JSON array.
pub fn histograms_json(hists: &[Histogram]) -> String {
    let items: Vec<String> = hists.iter().map(Histogram::to_json).collect();
    format!("[{}]", items.join(", "))
}

/// Per-phase slot-utilization sparklines for the CLI summary: one line
/// per phase window, showing busy/total over the phase's samples.
pub fn render_phase_utilization(data: &TraceData, tel: &Telemetry) -> String {
    let busy = match tel.timeseries.gauges.iter().find(|g| g.name == "busy_slots") {
        Some(g) => &g.values,
        None => return String::new(),
    };
    if tel.total_slots == 0 {
        return String::new();
    }
    let mut out = String::new();
    for phase in &data.phases {
        let utils: Vec<f64> = tel
            .timeseries
            .times_s
            .iter()
            .zip(busy.iter())
            .filter(|(&t, _)| {
                t >= phase.start_s && (t < phase.end_s || phase.end_s <= phase.start_s)
            })
            .map(|(_, &b)| b as f64 / tel.total_slots as f64)
            .collect();
        if utils.is_empty() {
            continue;
        }
        let avg = 100.0 * utils.iter().sum::<f64>() / utils.len() as f64;
        let peak = 100.0 * utils.iter().cloned().fold(0.0, f64::max);
        out.push_str(&format!(
            "  util {:<14} {}  avg {:>3.0}% peak {:>3.0}%\n",
            phase.name,
            crate::metrics::sparkline(&utils, 1.0),
            avg,
            peak
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::{Attempt, Locality, SchedulePlan};
    use crate::trace::{plan_trace, FetchTrace, JobTrace, PlanTrace, TraceSink};

    fn attempt(task: usize, slave: usize, slot: usize, s: f64, e: f64) -> Attempt {
        Attempt {
            task,
            slave,
            slot,
            start_s: s,
            end_s: e,
            locality: Locality::NodeLocal,
            speculative: false,
            won: true,
        }
    }

    fn traced_fixture() -> TraceData {
        let sink = TraceSink::default();
        sink.enable(2, 1);
        sink.begin_phase("similarity");
        let plan = SchedulePlan {
            makespan_s: 8.0,
            attempts: vec![attempt(0, 0, 0, 0.0, 4.0), attempt(1, 1, 1, 2.0, 8.0)],
            ..SchedulePlan::default()
        };
        let specs = Vec::new();
        let model = crate::cluster::NetworkModel::default();
        sink.record_job(JobTrace {
            name: "sim:map".into(),
            overhead_s: 1.0,
            virtual_time_s: 9.0,
            map: plan_trace(&plan, &specs, &model),
            reruns: Vec::new(),
            fetch: None,
            reduce: None,
            spill_bytes: Vec::new(),
        });
        sink.end_phase();
        sink.snapshot().unwrap()
    }

    #[test]
    fn gauges_share_the_grid_and_sum_to_capacity() {
        let tel = from_trace(&traced_fixture(), 2);
        assert_eq!(tel.timeseries.times_s.len(), SAMPLES);
        assert_eq!(tel.total_slots, 2);
        let busy = tel
            .timeseries
            .gauges
            .iter()
            .find(|g| g.name == "busy_slots")
            .unwrap();
        let idle = tel
            .timeseries
            .gauges
            .iter()
            .find(|g| g.name == "idle_slots")
            .unwrap();
        for (b, i) in busy.values.iter().zip(idle.values.iter()) {
            assert_eq!(b + i, 2, "busy + idle == capacity at every sample");
        }
        // Both slots overlap in (3, 4): peak busy is 2.
        assert_eq!(busy.peak(), 2);
        // Per-rack series exist for both racks and sum to the total.
        let r0 = tel
            .timeseries
            .gauges
            .iter()
            .find(|g| {
                g.name == "busy_slots_rack"
                    && g.label == Some(("rack", "0".to_string()))
            })
            .unwrap();
        let r1 = tel
            .timeseries
            .gauges
            .iter()
            .find(|g| {
                g.name == "busy_slots_rack"
                    && g.label == Some(("rack", "1".to_string()))
            })
            .unwrap();
        for ((a, b), t) in r0.values.iter().zip(r1.values.iter()).zip(busy.values.iter())
        {
            assert_eq!(a + b, *t);
        }
    }

    #[test]
    fn queued_attempts_drain_as_the_job_progresses() {
        let tel = from_trace(&traced_fixture(), 1);
        let queued = tel
            .timeseries
            .gauges
            .iter()
            .find(|g| g.name == "queued_attempts")
            .unwrap();
        // Attempt 1 dispatches at job-relative 3.0 (1.0 overhead + 2.0
        // plan wait): early samples see it queued, late samples don't.
        assert!(queued.values[0] >= 1, "{:?}", queued.values);
        assert_eq!(*queued.values.last().unwrap(), 0);
        let running = tel
            .timeseries
            .gauges
            .iter()
            .find(|g| g.name == "running_attempts")
            .unwrap();
        assert!(running.peak() >= 1);
    }

    #[test]
    fn histograms_capture_attempt_durations_and_waits() {
        let tel = from_trace(&traced_fixture(), 1);
        let attempt_h = &tel.histograms[0];
        assert_eq!(attempt_h.name, "attempt_duration_seconds");
        assert_eq!(attempt_h.count(), 2);
        assert_eq!(attempt_h.percentile(50.0), 4.0);
        assert_eq!(attempt_h.max(), 6.0);
        let wait_h = &tel.histograms[1];
        assert_eq!(wait_h.name, "queue_wait_seconds");
        assert_eq!(wait_h.count(), 2);
        assert_eq!(wait_h.percentile(100.0), 2.0);
    }

    #[test]
    fn fetch_backlog_tracks_the_barrier_window() {
        let sink = TraceSink::default();
        sink.enable(1, 2);
        let map = SchedulePlan {
            makespan_s: 2.0,
            attempts: vec![attempt(0, 0, 0, 0.0, 2.0)],
            ..SchedulePlan::default()
        };
        let reduce = SchedulePlan {
            makespan_s: 3.0,
            attempts: vec![attempt(0, 0, 1, 0.0, 3.0)],
            ..SchedulePlan::default()
        };
        let model = crate::cluster::NetworkModel::default();
        sink.record_job(JobTrace {
            name: "r".into(),
            overhead_s: 0.0,
            virtual_time_s: 9.0,
            map: plan_trace(&map, &[], &model),
            reruns: Vec::new(),
            fetch: Some(FetchTrace {
                fetch_s: 4.0,
                reducers: vec![crate::mapreduce::shuffle::fetch::ReducerFetch {
                    fetch_s: 4.0,
                    fetches: 1,
                    bytes: 1000,
                }],
            }),
            reduce: Some(plan_trace(&reduce, &[], &model)),
            spill_bytes: vec![1000],
        });
        let tel = from_trace(&sink.snapshot().unwrap(), 1);
        let backlog = tel
            .timeseries
            .gauges
            .iter()
            .find(|g| g.name == "shuffle_backlog_bytes")
            .unwrap();
        // The barrier spans [2, 6) of a 9 s run: backlog is 1000 inside,
        // 0 outside.
        assert_eq!(backlog.peak(), 1000);
        assert_eq!(backlog.values[0], 0);
        assert_eq!(*backlog.values.last().unwrap(), 0);
        let spill_h = &tel.histograms[3];
        assert_eq!(spill_h.name, "spill_bytes");
        assert_eq!(spill_h.count(), 1);
        assert_eq!(spill_h.max(), 1000.0);
        let fetch_h = &tel.histograms[2];
        assert_eq!(fetch_h.count(), 1);
    }

    #[test]
    fn node_instants_move_the_liveness_gauges() {
        let sink = TraceSink::default();
        sink.enable(3, 1);
        let mut plan = SchedulePlan {
            makespan_s: 4.0,
            attempts: vec![attempt(0, 0, 0, 0.0, 4.0)],
            ..SchedulePlan::default()
        };
        plan.death_events.push((1, 2.0));
        plan.blacklisted.push((2, 3.0));
        let model = crate::cluster::NetworkModel::default();
        sink.record_job(JobTrace {
            name: "j".into(),
            overhead_s: 0.0,
            virtual_time_s: 4.0,
            map: plan_trace(&plan, &[], &model),
            reruns: Vec::new(),
            fetch: None,
            reduce: None,
            spill_bytes: Vec::new(),
        });
        let tel = from_trace(&sink.snapshot().unwrap(), 1);
        let live = tel
            .timeseries
            .gauges
            .iter()
            .find(|g| g.name == "live_nodes")
            .unwrap();
        let dead = tel
            .timeseries
            .gauges
            .iter()
            .find(|g| g.name == "dead_nodes")
            .unwrap();
        let black = tel
            .timeseries
            .gauges
            .iter()
            .find(|g| g.name == "blacklisted_nodes")
            .unwrap();
        assert_eq!(live.values[0], 3);
        assert_eq!(*live.values.last().unwrap(), 2);
        assert_eq!(dead.values[0], 0);
        assert_eq!(*dead.values.last().unwrap(), 1);
        assert_eq!(*black.values.last().unwrap(), 1);
    }

    #[test]
    fn empty_telemetry_renders_without_panicking() {
        let tel = Telemetry::empty();
        assert_eq!(tel.timeseries.times_s, vec![0.0]);
        assert_eq!(tel.histograms.len(), 4);
        let ts = timeseries_json(&tel.timeseries);
        assert!(crate::trace::json::Value::parse(&ts).is_ok());
        let hs = histograms_json(&tel.histograms);
        assert!(crate::trace::json::Value::parse(&hs).is_ok());
    }

    #[test]
    fn timeseries_json_round_trips() {
        let tel = from_trace(&traced_fixture(), 2);
        let v = crate::trace::json::Value::parse(&timeseries_json(&tel.timeseries))
            .unwrap();
        assert_eq!(v.get("samples").unwrap().as_u64(), Some(SAMPLES as u64));
        let gauges = v.get("gauges").unwrap().items().unwrap();
        assert!(!gauges.is_empty());
        let first = &gauges[0];
        assert_eq!(first.get("name").unwrap().as_str(), Some("busy_slots"));
        assert_eq!(
            first.get("values").unwrap().items().unwrap().len(),
            SAMPLES
        );
    }

    #[test]
    fn utilization_sparkline_covers_every_phase() {
        let data = traced_fixture();
        let tel = from_trace(&data, 1);
        let out = render_phase_utilization(&data, &tel);
        assert!(out.contains("util similarity"), "{out}");
        assert!(out.contains("avg"), "{out}");
        assert!(out.contains("peak"), "{out}");
    }

    #[test]
    fn same_trace_derives_identical_telemetry_bytes() {
        let a = from_trace(&traced_fixture(), 2);
        let b = from_trace(&traced_fixture(), 2);
        assert_eq!(timeseries_json(&a.timeseries), timeseries_json(&b.timeseries));
        assert_eq!(histograms_json(&a.histograms), histograms_json(&b.histograms));
    }
}
