//! RunReport comparison: the `psch report show` / `psch report diff`
//! backend, and CI's first perf gate.
//!
//! [`summarize`] reduces a parsed RunReport (v1 or v2 — the telemetry
//! sections are optional) to the deterministic quantities worth gating
//! on: total and per-phase **virtual** seconds, the aggregated counters,
//! quality (NMI) and the histogram p50/p95s. Wall-clock fields are
//! deliberately dropped — they vary run to run on a shared host and would
//! make any zero-tolerance gate flap.
//!
//! [`diff`] compares two summaries under a relative tolerance
//! (`--tolerance-pct`, default 0): times and percentiles regress when B
//! exceeds A, NMI regresses when B falls below A, and counters regress on
//! **any** drift beyond tolerance (same-seed runs are exactly equal, so
//! a drifting counter means behavior changed). The CLI exits non-zero
//! when any line regresses.

use std::collections::BTreeMap;

use crate::error::{Error, Result};
use crate::trace::json::Value;

/// The gate-worthy reduction of one RunReport.
#[derive(Debug, Clone)]
pub struct ReportSummary {
    /// Schema string (`psch.run_report.v1` or `.v2`).
    pub schema: String,
    /// `totals.virtual_s` — the run's virtual makespan.
    pub total_virtual_s: f64,
    /// `(name, virtual_s)` per phase, in report order.
    pub phases: Vec<(String, f64)>,
    /// Counters summed across phases.
    pub counters: BTreeMap<String, u64>,
    /// `quality.nmi` when the run had a planted truth.
    pub nmi: Option<f64>,
    /// `(histogram, p50, p95)` per telemetry histogram (v2 reports only).
    pub percentiles: Vec<(String, f64, f64)>,
}

/// Read and parse a RunReport file.
pub fn load(path: &str) -> Result<Value> {
    let text = std::fs::read_to_string(path)?;
    Value::parse(&text)
        .map_err(|e| Error::Cli(format!("{path}: not a valid RunReport: {e}")))
}

/// Reduce a parsed RunReport to its comparable summary. Accepts every
/// `psch.run_report.v*` version: the v2 telemetry sections contribute
/// percentile lines when present and are skipped when absent.
pub fn summarize(v: &Value) -> Result<ReportSummary> {
    let schema = v
        .get("schema")
        .and_then(Value::as_str)
        .ok_or_else(|| Error::Cli("report has no schema key".into()))?;
    if !schema.starts_with("psch.run_report.v") {
        return Err(Error::Cli(format!("not a RunReport schema: {schema}")));
    }
    let total_virtual_s = v
        .get("totals")
        .and_then(|t| t.get("virtual_s"))
        .and_then(Value::as_f64)
        .ok_or_else(|| Error::Cli("report has no totals.virtual_s".into()))?;
    let mut phases = Vec::new();
    let mut counters: BTreeMap<String, u64> = BTreeMap::new();
    for p in v.get("phases").and_then(Value::items).unwrap_or(&[]) {
        let name = p
            .get("name")
            .and_then(Value::as_str)
            .unwrap_or("?")
            .to_string();
        let virtual_s =
            p.get("virtual_s").and_then(Value::as_f64).unwrap_or(0.0);
        phases.push((name, virtual_s));
        if let Some(Value::Obj(map)) = p.get("counters") {
            for (k, val) in map {
                if let Some(n) = val.as_u64() {
                    *counters.entry(k.clone()).or_insert(0) += n;
                }
            }
        }
    }
    let nmi = v.get("quality").and_then(|q| q.get("nmi")).and_then(Value::as_f64);
    let mut percentiles = Vec::new();
    for h in v.get("histograms").and_then(Value::items).unwrap_or(&[]) {
        let name = h
            .get("name")
            .and_then(Value::as_str)
            .unwrap_or("?")
            .to_string();
        let p50 = h.get("p50").and_then(Value::as_f64).unwrap_or(0.0);
        let p95 = h.get("p95").and_then(Value::as_f64).unwrap_or(0.0);
        percentiles.push((name, p50, p95));
    }
    Ok(ReportSummary {
        schema: schema.to_string(),
        total_virtual_s,
        phases,
        counters,
        nmi,
        percentiles,
    })
}

/// Human-readable rendering of one summary (`psch report show`).
pub fn render_show(s: &ReportSummary) -> String {
    let mut out = format!(
        "schema: {}\ntotal virtual_s: {}\n",
        s.schema,
        crate::trace::json::num(s.total_virtual_s)
    );
    for (name, virtual_s) in &s.phases {
        out.push_str(&format!(
            "phase {:<14} virtual_s {}\n",
            name,
            crate::trace::json::num(*virtual_s)
        ));
    }
    if let Some(nmi) = s.nmi {
        out.push_str(&format!("quality NMI: {nmi:.4}\n"));
    }
    for (name, p50, p95) in &s.percentiles {
        out.push_str(&format!(
            "hist {:<26} p50 {} p95 {}\n",
            name,
            crate::trace::json::num(*p50),
            crate::trace::json::num(*p95)
        ));
    }
    out.push_str(&format!("counters: {}\n", s.counters.len()));
    for (name, value) in &s.counters {
        out.push_str(&format!("  {name} = {value}\n"));
    }
    out
}

/// How a compared metric may regress.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Direction {
    /// Larger in B is worse (times, percentiles).
    HigherWorse,
    /// Smaller in B is worse (quality).
    LowerWorse,
    /// Any drift is worse (counters — deterministic runs match exactly).
    AnyDrift,
}

/// One compared metric.
#[derive(Debug, Clone)]
pub struct DiffLine {
    /// Metric label (`total.virtual_s`, `counter.SHUFFLE_BYTES`, ...).
    pub metric: String,
    /// Value in report A (the baseline).
    pub a: f64,
    /// Value in report B (the candidate).
    pub b: f64,
    /// Relative change in percent, signed (`(b-a)/a`; 100 when a == 0
    /// and b differs).
    pub delta_pct: f64,
    /// Did this metric regress beyond the tolerance?
    pub regressed: bool,
}

/// Compare two report summaries under `tolerance_pct`. Returns every
/// compared line plus the overall regression verdict (true = B regressed).
pub fn diff(
    a: &ReportSummary,
    b: &ReportSummary,
    tolerance_pct: f64,
) -> (Vec<DiffLine>, bool) {
    let mut lines = Vec::new();
    let mut push = |metric: String, a: f64, b: f64, dir: Direction| {
        let delta_pct = if a == 0.0 {
            if b == 0.0 {
                0.0
            } else {
                100.0
            }
        } else {
            (b - a) / a * 100.0
        };
        let bad = match dir {
            Direction::HigherWorse => delta_pct,
            Direction::LowerWorse => -delta_pct,
            Direction::AnyDrift => delta_pct.abs(),
        };
        lines.push(DiffLine {
            metric,
            a,
            b,
            delta_pct,
            regressed: bad > tolerance_pct + 1e-9,
        });
    };

    push(
        "total.virtual_s".into(),
        a.total_virtual_s,
        b.total_virtual_s,
        Direction::HigherWorse,
    );
    // Phases are matched by name; a phase present on one side only is a
    // 0-baseline comparison (flagged unless within tolerance).
    let phase_names: Vec<&String> = a
        .phases
        .iter()
        .map(|(n, _)| n)
        .chain(b.phases.iter().map(|(n, _)| n))
        .collect();
    let mut seen = Vec::new();
    for name in phase_names {
        if seen.contains(&name) {
            continue;
        }
        seen.push(name);
        let av = a
            .phases
            .iter()
            .find(|(n, _)| n == name)
            .map_or(0.0, |(_, v)| *v);
        let bv = b
            .phases
            .iter()
            .find(|(n, _)| n == name)
            .map_or(0.0, |(_, v)| *v);
        push(format!("phase.{name}.virtual_s"), av, bv, Direction::HigherWorse);
    }
    let counter_names: Vec<&String> =
        a.counters.keys().chain(b.counters.keys()).collect();
    let mut seen = Vec::new();
    for name in counter_names {
        if seen.contains(&name) {
            continue;
        }
        seen.push(name);
        push(
            format!("counter.{name}"),
            a.counters.get(name).copied().unwrap_or(0) as f64,
            b.counters.get(name).copied().unwrap_or(0) as f64,
            Direction::AnyDrift,
        );
    }
    // NMI on one side only: nothing comparable, skip rather than flag.
    if let (Some(av), Some(bv)) = (a.nmi, b.nmi) {
        push("quality.nmi".into(), av, bv, Direction::LowerWorse);
    }
    let hist_names: Vec<&String> = a
        .percentiles
        .iter()
        .map(|(n, _, _)| n)
        .chain(b.percentiles.iter().map(|(n, _, _)| n))
        .collect();
    let mut seen = Vec::new();
    for name in hist_names {
        if seen.contains(&name) {
            continue;
        }
        seen.push(name);
        let find = |s: &ReportSummary| {
            s.percentiles
                .iter()
                .find(|(n, _, _)| n == name)
                .map_or((0.0, 0.0), |(_, p50, p95)| (*p50, *p95))
        };
        let (a50, a95) = find(a);
        let (b50, b95) = find(b);
        push(format!("hist.{name}.p50"), a50, b50, Direction::HigherWorse);
        push(format!("hist.{name}.p95"), a95, b95, Direction::HigherWorse);
    }
    let regressed = lines.iter().any(|l| l.regressed);
    (lines, regressed)
}

/// Render a diff result (`psch report diff`): regressed lines always,
/// unchanged lines only with `verbose`.
pub fn render_diff(lines: &[DiffLine], tolerance_pct: f64, verbose: bool) -> String {
    let mut out = String::new();
    let mut shown = 0usize;
    for l in lines {
        if !l.regressed && !verbose && l.delta_pct == 0.0 {
            continue;
        }
        shown += 1;
        out.push_str(&format!(
            "{} {:<38} A={} B={} ({}{:.2}%)\n",
            if l.regressed { "REGRESSED" } else { "ok       " },
            l.metric,
            crate::trace::json::num(l.a),
            crate::trace::json::num(l.b),
            if l.delta_pct >= 0.0 { "+" } else { "" },
            l.delta_pct
        ));
    }
    if shown == 0 {
        out.push_str("identical within tolerance\n");
    }
    let regressed = lines.iter().filter(|l| l.regressed).count();
    out.push_str(&format!(
        "compared {} metrics, {} regressed (tolerance {:.2}%)\n",
        lines.len(),
        regressed,
        tolerance_pct
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn summary(total: f64, nmi: f64) -> ReportSummary {
        let mut counters = BTreeMap::new();
        counters.insert("SHUFFLE_BYTES".to_string(), 1000);
        counters.insert("HEARTBEATS".to_string(), 50);
        ReportSummary {
            schema: "psch.run_report.v2".into(),
            total_virtual_s: total,
            phases: vec![("similarity".into(), total * 0.6), ("kmeans".into(), total * 0.4)],
            counters,
            nmi: Some(nmi),
            percentiles: vec![("attempt_duration_seconds".into(), 0.5, 2.0)],
        }
    }

    #[test]
    fn identical_summaries_pass_at_zero_tolerance() {
        let a = summary(100.0, 0.9);
        let (lines, regressed) = diff(&a, &a.clone(), 0.0);
        assert!(!regressed);
        assert!(lines.iter().all(|l| !l.regressed));
        assert!(lines.iter().any(|l| l.metric == "total.virtual_s"));
        assert!(lines.iter().any(|l| l.metric == "counter.SHUFFLE_BYTES"));
        assert!(lines.iter().any(|l| l.metric == "quality.nmi"));
        assert!(lines
            .iter()
            .any(|l| l.metric == "hist.attempt_duration_seconds.p95"));
        let text = render_diff(&lines, 0.0, false);
        assert!(text.contains("identical within tolerance"), "{text}");
        assert!(text.contains("0 regressed"), "{text}");
    }

    #[test]
    fn slower_makespan_regresses_and_tolerance_forgives_it() {
        let a = summary(100.0, 0.9);
        let b = summary(110.0, 0.9);
        let (lines, regressed) = diff(&a, &b, 0.0);
        assert!(regressed);
        let total = lines.iter().find(|l| l.metric == "total.virtual_s").unwrap();
        assert!(total.regressed);
        assert!((total.delta_pct - 10.0).abs() < 1e-9);
        // A 15% tolerance forgives the 10% slowdown.
        let (_, regressed) = diff(&a, &b, 15.0);
        assert!(!regressed);
        // A FASTER candidate never regresses on time metrics.
        let (_, improved) = diff(&b, &a, 0.0);
        assert!(!improved);
    }

    #[test]
    fn nmi_drop_regresses_but_gain_does_not() {
        let a = summary(100.0, 0.9);
        let worse = summary(100.0, 0.8);
        let (lines, regressed) = diff(&a, &worse, 0.0);
        assert!(regressed);
        assert!(lines.iter().find(|l| l.metric == "quality.nmi").unwrap().regressed);
        let better = summary(100.0, 0.95);
        let (_, regressed) = diff(&a, &better, 0.0);
        assert!(!regressed);
    }

    #[test]
    fn counter_drift_regresses_in_both_directions() {
        let a = summary(100.0, 0.9);
        let mut b = summary(100.0, 0.9);
        *b.counters.get_mut("SHUFFLE_BYTES").unwrap() = 900; // fewer bytes
        let (lines, regressed) = diff(&a, &b, 0.0);
        assert!(regressed, "counter drift must flag even when it shrinks");
        let line =
            lines.iter().find(|l| l.metric == "counter.SHUFFLE_BYTES").unwrap();
        assert!(line.regressed);
        assert!(line.delta_pct < 0.0);
        // A counter present on one side only compares against 0.
        b.counters.insert("NEW_COUNTER".to_string(), 5);
        let (lines, _) = diff(&a, &b, 0.0);
        let new = lines.iter().find(|l| l.metric == "counter.NEW_COUNTER").unwrap();
        assert!(new.regressed);
        assert_eq!(new.a, 0.0);
    }

    #[test]
    fn summarize_accepts_v1_and_v2_documents() {
        let v1 = r#"{"schema":"psch.run_report.v1","phases":[
            {"name":"similarity","virtual_s":10.5,"counters":{"SPILLS":2}}],
            "totals":{"virtual_s":10.5,"wall_s":0.2},
            "quality":{"nmi":0.9,"ari":0.8},"trace":null}"#;
        let s = summarize(&Value::parse(v1).unwrap()).unwrap();
        assert_eq!(s.schema, "psch.run_report.v1");
        assert_eq!(s.phases.len(), 1);
        assert_eq!(s.counters.get("SPILLS"), Some(&2));
        assert_eq!(s.nmi, Some(0.9));
        assert!(s.percentiles.is_empty(), "v1 has no histograms");
        let v2 = r#"{"schema":"psch.run_report.v2","phases":[],
            "totals":{"virtual_s":1.0},"quality":null,"trace":null,
            "timeseries":null,
            "histograms":[{"name":"fetch_bytes","p50":100,"p95":900}]}"#;
        let s2 = summarize(&Value::parse(v2).unwrap()).unwrap();
        assert_eq!(s2.percentiles, vec![("fetch_bytes".to_string(), 100.0, 900.0)]);
        assert_eq!(s2.nmi, None);
        // Cross-version diff works: v1 vs v2 skips the missing sections.
        let (_, regressed) = diff(&s, &s, 0.0);
        assert!(!regressed);
    }

    #[test]
    fn summarize_rejects_non_reports() {
        let bad = Value::parse(r#"{"schema":"psch.model.v1"}"#).unwrap();
        assert!(summarize(&bad).is_err());
        let none = Value::parse(r#"{"foo":1}"#).unwrap();
        assert!(summarize(&none).is_err());
    }

    #[test]
    fn render_show_lists_the_summary() {
        let s = summary(42.0, 0.9);
        let text = render_show(&s);
        assert!(text.contains("schema: psch.run_report.v2"), "{text}");
        assert!(text.contains("total virtual_s: 42"), "{text}");
        assert!(text.contains("phase similarity"), "{text}");
        assert!(text.contains("quality NMI: 0.9000"), "{text}");
        assert!(text.contains("SHUFFLE_BYTES = 1000"), "{text}");
        assert!(text.contains("hist attempt_duration_seconds"), "{text}");
    }
}
