//! Fixed log-bucket histograms with exact percentile extraction.
//!
//! Two bucket families cover every telemetry distribution: powers of two
//! above 1 ms for durations, powers of four above 64 B for byte sizes.
//! The edges are compile-time constants so two runs of the same workload
//! always disagree only in counts, never in shape — a requirement for the
//! byte-identical export guarantee.
//!
//! Percentiles are **exact**, not bucket-interpolated: the histogram keeps
//! its raw samples (telemetry distributions are small — one entry per
//! attempt/reducer/spill) and answers `percentile(q)` by nearest-rank on
//! the sorted samples. Bucket counts exist for the Prometheus exposition,
//! where cumulative `le` buckets are the wire format.

use crate::trace::json;

/// Number of finite bucket edges in each family.
const SECONDS_EDGES: usize = 21;
const BYTES_EDGES: usize = 15;

/// One named histogram: fixed edges, cumulative-friendly counts, raw
/// samples for exact percentiles.
#[derive(Debug, Clone)]
pub struct Histogram {
    /// Metric name (`attempt_duration_seconds`, `fetch_bytes`, ...).
    pub name: &'static str,
    /// Unit tag: `"seconds"` or `"bytes"`.
    pub unit: &'static str,
    /// Finite upper bucket edges, ascending. A sample lands in the first
    /// bucket whose edge is `>=` the sample; larger samples land in the
    /// overflow bucket.
    pub edges: Vec<f64>,
    /// Per-bucket counts, `edges.len() + 1` long (last = overflow).
    pub counts: Vec<u64>,
    /// Raw samples, sorted ascending once recording is done.
    pub samples: Vec<f64>,
}

impl Histogram {
    /// Duration histogram: edges `0.001 × 2^i` seconds, i = 0..21
    /// (1 ms … ~1049 s), overflow beyond.
    pub fn seconds(name: &'static str) -> Self {
        let edges = (0..SECONDS_EDGES).map(|i| 0.001 * f64::powi(2.0, i as i32)).collect();
        Self::with_edges(name, "seconds", edges)
    }

    /// Size histogram: edges `64 × 4^i` bytes, i = 0..15
    /// (64 B … ~17 GB), overflow beyond.
    pub fn bytes(name: &'static str) -> Self {
        let edges = (0..BYTES_EDGES).map(|i| 64.0 * f64::powi(4.0, i as i32)).collect();
        Self::with_edges(name, "bytes", edges)
    }

    fn with_edges(name: &'static str, unit: &'static str, edges: Vec<f64>) -> Self {
        let counts = vec![0; edges.len() + 1];
        Histogram { name, unit, edges, counts, samples: Vec::new() }
    }

    /// Record one sample (negative values clamp to zero — virtual times
    /// are never negative, but clamping keeps the invariants local).
    pub fn record(&mut self, value: f64) {
        let v = value.max(0.0);
        let idx = self
            .edges
            .iter()
            .position(|&e| v <= e)
            .unwrap_or(self.edges.len());
        self.counts[idx] += 1;
        self.samples.push(v);
    }

    /// Record every value in `values`.
    pub fn record_all<I: IntoIterator<Item = f64>>(&mut self, values: I) {
        for v in values {
            self.record(v);
        }
    }

    /// Sort the samples; call once after the last [`record`](Self::record).
    pub fn finish(&mut self) {
        self.samples.sort_by(f64::total_cmp);
    }

    /// Total samples recorded.
    pub fn count(&self) -> u64 {
        self.samples.len() as u64
    }

    /// Sum of all samples.
    pub fn sum(&self) -> f64 {
        self.samples.iter().sum()
    }

    /// Largest sample (0 when empty).
    pub fn max(&self) -> f64 {
        self.samples.last().copied().unwrap_or(0.0)
    }

    /// Exact nearest-rank percentile over the sorted samples: the smallest
    /// sample with at least `q`% of the distribution at or below it.
    /// Returns 0 for the empty histogram.
    pub fn percentile(&self, q: f64) -> f64 {
        let n = self.samples.len();
        if n == 0 {
            return 0.0;
        }
        let rank = (q / 100.0 * n as f64).ceil() as usize;
        self.samples[rank.clamp(1, n) - 1]
    }

    /// Cumulative bucket counts in Prometheus `le` order (the overflow
    /// bucket becomes `le="+Inf"`).
    pub fn cumulative(&self) -> Vec<u64> {
        let mut total = 0;
        self.counts
            .iter()
            .map(|&c| {
                total += c;
                total
            })
            .collect()
    }

    /// The report-v2 JSON object for this histogram.
    pub fn to_json(&self) -> String {
        let edges: Vec<String> = self.edges.iter().map(|&e| json::num(e)).collect();
        let counts: Vec<String> = self.counts.iter().map(u64::to_string).collect();
        format!(
            "{{\"name\": \"{}\", \"unit\": \"{}\", \"edges\": [{}], \
             \"counts\": [{}], \"count\": {}, \"sum\": {}, \"p50\": {}, \
             \"p95\": {}, \"max\": {}}}",
            json::esc(self.name),
            json::esc(self.unit),
            edges.join(", "),
            counts.join(", "),
            self.count(),
            json::num(self.sum()),
            json::num(self.percentile(50.0)),
            json::num(self.percentile(95.0)),
            json::num(self.max()),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram_is_all_zeros() {
        let h = Histogram::seconds("empty");
        assert_eq!(h.count(), 0);
        assert_eq!(h.sum(), 0.0);
        assert_eq!(h.max(), 0.0);
        assert_eq!(h.percentile(50.0), 0.0);
        assert_eq!(h.percentile(95.0), 0.0);
        assert!(h.counts.iter().all(|&c| c == 0));
        assert_eq!(h.cumulative().last(), Some(&0));
    }

    #[test]
    fn one_sample_answers_every_percentile() {
        let mut h = Histogram::seconds("one");
        h.record(0.25);
        h.finish();
        assert_eq!(h.count(), 1);
        for q in [1.0, 50.0, 95.0, 99.9, 100.0] {
            assert_eq!(h.percentile(q), 0.25, "q={q}");
        }
        // 0.25 s lands in the 1ms×2^8 = 0.256 s bucket.
        assert_eq!(h.counts[8], 1);
        assert_eq!(h.counts.iter().sum::<u64>(), 1);
    }

    #[test]
    fn all_samples_in_one_bucket_still_give_exact_percentiles() {
        // Every sample inside (0.128, 0.256]: one bucket, but the exact
        // ranks still separate them — the reason raw samples are kept.
        let mut h = Histogram::seconds("packed");
        h.record_all([0.13, 0.14, 0.15, 0.2, 0.25]);
        h.finish();
        assert_eq!(h.counts[8], 5);
        assert_eq!(h.percentile(50.0), 0.15);
        assert_eq!(h.percentile(95.0), 0.25);
        assert_eq!(h.percentile(20.0), 0.13);
    }

    #[test]
    fn nearest_rank_matches_the_definition() {
        let mut h = Histogram::bytes("b");
        h.record_all([10.0, 20.0, 30.0, 40.0]);
        h.finish();
        // ceil(0.50 × 4) = 2 → second sample.
        assert_eq!(h.percentile(50.0), 20.0);
        // ceil(0.95 × 4) = 4 → the max.
        assert_eq!(h.percentile(95.0), 40.0);
        // q=0 clamps to the first sample.
        assert_eq!(h.percentile(0.0), 10.0);
        assert_eq!(h.max(), 40.0);
        assert!((h.sum() - 100.0).abs() < 1e-12);
    }

    #[test]
    fn bucket_edges_are_the_documented_log_grids() {
        let s = Histogram::seconds("s");
        assert_eq!(s.edges.len(), 21);
        assert!((s.edges[0] - 0.001).abs() < 1e-15);
        assert!((s.edges[1] - 0.002).abs() < 1e-15);
        assert!((s.edges[20] - 0.001 * f64::powi(2.0, 20)).abs() < 1e-9);
        let b = Histogram::bytes("b");
        assert_eq!(b.edges.len(), 15);
        assert_eq!(b.edges[0], 64.0);
        assert_eq!(b.edges[1], 256.0);
        // Overflow: a sample above the top edge lands in the last bucket.
        let mut b = b;
        b.record(1e18);
        assert_eq!(*b.counts.last().unwrap(), 1);
        assert_eq!(b.cumulative().last(), Some(&1));
    }

    #[test]
    fn cumulative_counts_are_monotone_and_end_at_count() {
        let mut h = Histogram::seconds("c");
        h.record_all([0.0005, 0.01, 0.5, 100.0, 1e7]);
        h.finish();
        let cum = h.cumulative();
        assert!(cum.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(*cum.last().unwrap(), h.count());
        assert_eq!(cum.len(), h.edges.len() + 1);
    }

    #[test]
    fn to_json_parses_and_carries_the_exact_percentiles() {
        let mut h = Histogram::bytes("fetch_bytes");
        h.record_all([100.0, 300.0, 900.0]);
        h.finish();
        let v = json::Value::parse(&h.to_json()).unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("fetch_bytes"));
        assert_eq!(v.get("unit").unwrap().as_str(), Some("bytes"));
        assert_eq!(v.get("count").unwrap().as_u64(), Some(3));
        assert_eq!(v.get("p50").unwrap().as_f64(), Some(300.0));
        assert_eq!(v.get("p95").unwrap().as_f64(), Some(900.0));
        assert_eq!(
            v.get("edges").unwrap().items().unwrap().len() + 1,
            v.get("counts").unwrap().items().unwrap().len()
        );
    }
}
