//! Prometheus text-exposition snapshot of one run's telemetry.
//!
//! `psch run --metrics-out FILE` writes this format so the run's signals
//! drop straight into existing scrape-file tooling (node_exporter's
//! textfile collector, CI artifact diffing). Only **virtual-clock**
//! quantities are exported — no wall times, no timestamps — so two
//! same-seed runs produce byte-identical snapshots.
//!
//! Layout, in order: run-level scalars (makespan, per-phase virtual
//! seconds), the per-phase counters, per-gauge mean/peak summaries of the
//! sampled series, and the four histograms with cumulative `le` buckets
//! plus exact p50/p95 gauges.

use crate::coordinator::PhaseStats;
use crate::trace::json::num;

use super::Telemetry;

/// Metric-name prefix for every exported sample.
const PREFIX: &str = "psch";

/// Render the full snapshot.
pub fn render(tel: &Telemetry, phases: &[PhaseStats]) -> String {
    let mut out = String::new();

    header(&mut out, "makespan_seconds", "gauge", "Virtual makespan of the run.");
    out.push_str(&format!("{PREFIX}_makespan_seconds {}\n", num(tel.makespan_s)));
    header(&mut out, "total_slots", "gauge", "Slot capacity of the cluster.");
    out.push_str(&format!("{PREFIX}_total_slots {}\n", tel.total_slots));

    header(
        &mut out,
        "phase_virtual_seconds",
        "gauge",
        "Virtual seconds per pipeline phase.",
    );
    for p in phases {
        out.push_str(&format!(
            "{PREFIX}_phase_virtual_seconds{{phase=\"{}\"}} {}\n",
            p.name,
            num(p.virtual_s)
        ));
    }

    header(
        &mut out,
        "counter_total",
        "counter",
        "Job counters aggregated per phase.",
    );
    for p in phases {
        for (name, value) in p.counters.iter() {
            out.push_str(&format!(
                "{PREFIX}_counter_total{{phase=\"{}\",name=\"{}\"}} {}\n",
                p.name, name, value
            ));
        }
    }

    header(
        &mut out,
        "gauge_mean",
        "gauge",
        "Mean of each sampled gauge series over the run.",
    );
    for g in &tel.timeseries.gauges {
        out.push_str(&format!(
            "{PREFIX}_gauge_mean{{name=\"{}\"{}}} {}\n",
            g.name,
            label_suffix(g),
            num(g.mean())
        ));
    }
    header(
        &mut out,
        "gauge_peak",
        "gauge",
        "Peak of each sampled gauge series over the run.",
    );
    for g in &tel.timeseries.gauges {
        out.push_str(&format!(
            "{PREFIX}_gauge_peak{{name=\"{}\"{}}} {}\n",
            g.name,
            label_suffix(g),
            g.peak()
        ));
    }

    for h in &tel.histograms {
        let base = format!("{PREFIX}_{}", h.name);
        out.push_str(&format!(
            "# HELP {base} Distribution over the run ({}).\n# TYPE {base} histogram\n",
            h.unit
        ));
        let cumulative = h.cumulative();
        for (edge, cum) in h.edges.iter().zip(cumulative.iter()) {
            out.push_str(&format!(
                "{base}_bucket{{le=\"{}\"}} {}\n",
                num(*edge),
                cum
            ));
        }
        out.push_str(&format!(
            "{base}_bucket{{le=\"+Inf\"}} {}\n",
            cumulative.last().copied().unwrap_or(0)
        ));
        out.push_str(&format!("{base}_sum {}\n", num(h.sum())));
        out.push_str(&format!("{base}_count {}\n", h.count()));
        out.push_str(&format!(
            "# TYPE {base}_p50 gauge\n{base}_p50 {}\n",
            num(h.percentile(50.0))
        ));
        out.push_str(&format!(
            "# TYPE {base}_p95 gauge\n{base}_p95 {}\n",
            num(h.percentile(95.0))
        ));
    }
    out
}

fn header(out: &mut String, name: &str, kind: &str, help: &str) {
    out.push_str(&format!(
        "# HELP {PREFIX}_{name} {help}\n# TYPE {PREFIX}_{name} {kind}\n"
    ));
}

fn label_suffix(g: &super::GaugeSeries) -> String {
    match &g.label {
        Some((k, v)) => format!(",{k}=\"{v}\""),
        None => String::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::histogram::Histogram;
    use crate::telemetry::{GaugeSeries, Timeseries};

    fn phase(name: &str, virtual_s: f64) -> PhaseStats {
        let mut p = PhaseStats {
            name: name.to_string(),
            virtual_s,
            ..PhaseStats::default()
        };
        p.counters.incr("SHUFFLE_BYTES", 123);
        p
    }

    fn tel_fixture() -> Telemetry {
        let mut h = Histogram::seconds("attempt_duration_seconds");
        h.record_all([0.5, 1.5]);
        h.finish();
        Telemetry {
            makespan_s: 12.5,
            total_slots: 4,
            timeseries: Timeseries {
                times_s: vec![0.0, 6.25, 12.5],
                gauges: vec![
                    GaugeSeries {
                        name: "busy_slots",
                        label: None,
                        values: vec![1, 4, 0],
                    },
                    GaugeSeries {
                        name: "busy_slots_rack",
                        label: Some(("rack", "1".to_string())),
                        values: vec![0, 2, 0],
                    },
                ],
            },
            histograms: vec![h],
        }
    }

    #[test]
    fn snapshot_has_the_expected_families() {
        let text = render(&tel_fixture(), &[phase("similarity", 8.0)]);
        assert!(text.contains("psch_makespan_seconds 12.5\n"), "{text}");
        assert!(text.contains(
            "psch_phase_virtual_seconds{phase=\"similarity\"} 8\n"
        ));
        assert!(text.contains(
            "psch_counter_total{phase=\"similarity\",name=\"SHUFFLE_BYTES\"} 123\n"
        ));
        assert!(text.contains("psch_gauge_peak{name=\"busy_slots\"} 4\n"));
        assert!(text.contains("psch_gauge_mean{name=\"busy_slots_rack\",rack=\"1\"}"));
        assert!(text.contains("psch_attempt_duration_seconds_bucket{le=\"+Inf\"} 2\n"));
        assert!(text.contains("psch_attempt_duration_seconds_count 2\n"));
        assert!(text.contains("psch_attempt_duration_seconds_p95 1.5\n"));
        // Every non-comment line is `name{labels} value`.
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let mut parts = line.rsplitn(2, ' ');
            let value = parts.next().unwrap();
            assert!(value.parse::<f64>().is_ok(), "unparseable value: {line}");
            assert!(parts.next().unwrap().starts_with("psch_"), "{line}");
        }
    }

    #[test]
    fn rendering_is_deterministic() {
        let a = render(&tel_fixture(), &[phase("p", 1.0)]);
        let b = render(&tel_fixture(), &[phase("p", 1.0)]);
        assert_eq!(a, b);
    }

    #[test]
    fn empty_telemetry_renders_cleanly() {
        let text = render(&Telemetry::empty(), &[]);
        assert!(text.contains("psch_makespan_seconds 0\n"));
        assert!(text.contains("psch_queue_wait_seconds_count 0\n"));
    }
}
