//! Speedup-curve bookkeeping (paper Fig. 5).

/// One (machines, time-seconds) measurement.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScalePoint {
    /// Slave count m.
    pub machines: usize,
    /// Measured (virtual) seconds.
    pub seconds: f64,
}

/// A speedup curve relative to the 1-machine baseline.
#[derive(Debug, Clone, Default)]
pub struct SpeedupCurve {
    points: Vec<ScalePoint>,
}

impl SpeedupCurve {
    /// Add one measurement.
    pub fn push(&mut self, machines: usize, seconds: f64) {
        self.points.push(ScalePoint { machines, seconds });
        self.points.sort_by_key(|p| p.machines);
    }

    /// Raw points sorted by machine count.
    pub fn points(&self) -> &[ScalePoint] {
        &self.points
    }

    /// Speedup of each point vs the smallest-m point.
    pub fn speedups(&self) -> Vec<(usize, f64)> {
        let Some(base) = self.points.first() else { return vec![] };
        self.points
            .iter()
            .map(|p| (p.machines, base.seconds / p.seconds))
            .collect()
    }

    /// Parallel efficiency: speedup / (m / m_base).
    pub fn efficiencies(&self) -> Vec<(usize, f64)> {
        let Some(base) = self.points.first() else { return vec![] };
        self.speedups()
            .into_iter()
            .map(|(m, s)| (m, s / (m as f64 / base.machines as f64)))
            .collect()
    }

    /// Is the curve monotone non-increasing in time up to `up_to` machines?
    pub fn monotone_up_to(&self, up_to: usize) -> bool {
        let pts: Vec<&ScalePoint> =
            self.points.iter().filter(|p| p.machines <= up_to).collect();
        pts.windows(2).all(|w| w[1].seconds <= w[0].seconds)
    }

    /// Relative improvement between the last two points (the paper's 8→10
    /// flattening check): `(t_prev - t_last) / t_prev`.
    pub fn final_gain(&self) -> Option<f64> {
        let n = self.points.len();
        if n < 2 {
            return None;
        }
        let prev = self.points[n - 2].seconds;
        Some((prev - self.points[n - 1].seconds) / prev)
    }

    /// ASCII trend plot (machines on x, time on y), like Fig. 5.
    pub fn ascii_plot(&self, width: usize, height: usize) -> String {
        if self.points.is_empty() {
            return String::new();
        }
        let tmax = self.points.iter().map(|p| p.seconds).fold(0.0, f64::max);
        let mut grid = vec![vec![b' '; width]; height];
        let n = self.points.len();
        for (i, p) in self.points.iter().enumerate() {
            let x = if n == 1 { 0 } else { i * (width - 1) / (n - 1) };
            let y = if tmax == 0.0 {
                height - 1
            } else {
                ((1.0 - p.seconds / tmax) * (height - 1) as f64).round() as usize
            };
            grid[height - 1 - y.min(height - 1)][x] = b'*';
        }
        let mut out = String::new();
        for row in grid {
            out.push_str(std::str::from_utf8(&row).unwrap());
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_total_curve() -> SpeedupCurve {
        // Paper Table 5-1 "Total Time" column, in seconds.
        let mut c = SpeedupCurve::default();
        for (m, t) in [
            (1, 4.0 * 3600.0 + 24.0 * 60.0 + 45.0),
            (2, 3.0 * 3600.0 + 11.0 * 60.0 + 8.0),
            (4, 2.0 * 3600.0 + 28.0 * 60.0 + 15.0),
            (6, 1.0 * 3600.0 + 47.0 * 60.0 + 53.0),
            (8, 1.0 * 3600.0 + 34.0 * 60.0 + 33.0),
            (10, 1.0 * 3600.0 + 35.0 * 60.0 + 53.0),
        ] {
            c.push(m, t);
        }
        c
    }

    #[test]
    fn speedups_relative_to_base() {
        let c = paper_total_curve();
        let s = c.speedups();
        assert_eq!(s[0], (1, 1.0));
        // Paper's total speedup at 8 slaves is ~2.8x.
        assert!((s[4].1 - 2.8).abs() < 0.05, "{:?}", s);
    }

    #[test]
    fn paper_curve_monotone_to_8_but_not_10() {
        let c = paper_total_curve();
        assert!(c.monotone_up_to(8));
        assert!(!c.monotone_up_to(10)); // 10 slaves slower than 8
        assert!(c.final_gain().unwrap() < 0.0); // regression at 10
    }

    #[test]
    fn efficiency_decreasing() {
        let c = paper_total_curve();
        let e = c.efficiencies();
        for w in e.windows(2) {
            assert!(w[1].1 <= w[0].1 + 1e-9, "{e:?}");
        }
    }

    #[test]
    fn unsorted_insert_sorts() {
        let mut c = SpeedupCurve::default();
        c.push(4, 10.0);
        c.push(1, 40.0);
        c.push(2, 20.0);
        let ms: Vec<usize> = c.points().iter().map(|p| p.machines).collect();
        assert_eq!(ms, vec![1, 2, 4]);
    }

    #[test]
    fn ascii_plot_has_marks() {
        let c = paper_total_curve();
        let plot = c.ascii_plot(40, 10);
        assert_eq!(plot.matches('*').count(), 6);
    }
}
