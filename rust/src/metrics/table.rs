//! ASCII table rendering (paper-style result tables).

/// A simple left-aligned ASCII table.
#[derive(Debug, Default)]
pub struct AsciiTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl AsciiTable {
    /// Table with the given column headers.
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match the header arity).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render with column-width alignment and a separator under the header.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for i in 0..ncols {
                line.push_str(&format!(" {:<w$} |", cells[i], w = widths[i]));
            }
            line.push('\n');
            line
        };
        let mut out = fmt_row(&self.header);
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = AsciiTable::new(&["Slave", "Total"]);
        t.row(&["1".to_string(), "4:24:45".to_string()]);
        t.row(&["10".to_string(), "1:35:53".to_string()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        // All lines same width.
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
        assert!(s.contains("4:24:45"));
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = AsciiTable::new(&["a", "b"]);
        t.row(&["only-one".to_string()]);
    }
}
