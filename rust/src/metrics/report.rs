//! Shuffle and fault reporting: turn the engine's spill/merge/fetch and
//! failure-domain counters into compact summaries for the CLI, benches
//! and experiment JSON. [`render_run`] is the one formatter every run
//! summary goes through (`psch run`, scale studies, smoke greps).

use crate::coordinator::PipelineResult;
use crate::mapreduce::{names, Counters};
use crate::metrics::table::AsciiTable;
use crate::util::fmt::{hms, human_bytes};

/// Spill/merge/fetch summary of one job or phase, derived from the
/// counters the shuffle subsystem feeds through the engine.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ShuffleSummary {
    /// Map-side sort-buffer spills.
    pub spills: u64,
    /// Records written in spills + rewritten by merge passes.
    pub spilled_records: u64,
    /// Merge passes, map and reduce side.
    pub merge_passes: u64,
    /// Shuffle bytes fetched from the reducer's own node.
    pub fetch_node_local: u64,
    /// Shuffle bytes fetched within the reducer's rack.
    pub fetch_rack_local: u64,
    /// Shuffle bytes fetched across racks.
    pub fetch_off_rack: u64,
    /// Virtual seconds reducers spent fetching (serial sum).
    pub fetch_s: f64,
}

impl ShuffleSummary {
    /// Extract the summary from merged job counters.
    pub fn from_counters(c: &Counters) -> Self {
        Self {
            spills: c.get(names::SPILLS),
            spilled_records: c.get(names::SPILLED_RECORDS),
            merge_passes: c.get(names::MERGE_PASSES),
            fetch_node_local: c.get(names::SHUFFLE_FETCH_BYTES_LOCAL),
            fetch_rack_local: c.get(names::SHUFFLE_FETCH_BYTES_RACK),
            fetch_off_rack: c.get(names::SHUFFLE_FETCH_BYTES_REMOTE),
            fetch_s: c.get(names::SHUFFLE_FETCH_US) as f64 / 1e6,
        }
    }

    /// All fetched bytes, every tier.
    pub fn total_fetch_bytes(&self) -> u64 {
        self.fetch_node_local + self.fetch_rack_local + self.fetch_off_rack
    }

    /// Percent of fetched bytes that stayed on the reducer's node
    /// (0 when nothing was fetched).
    pub fn node_local_pct(&self) -> f64 {
        let total = self.total_fetch_bytes();
        if total == 0 {
            0.0
        } else {
            100.0 * self.fetch_node_local as f64 / total as f64
        }
    }

    /// One-line human-readable rendering.
    pub fn render(&self) -> String {
        format!(
            "spills={} spilled_records={} merge_passes={} fetched={} \
             (local {}, rack {}, remote {}) fetch={:.2}s",
            self.spills,
            self.spilled_records,
            self.merge_passes,
            human_bytes(self.total_fetch_bytes()),
            human_bytes(self.fetch_node_local),
            human_bytes(self.fetch_rack_local),
            human_bytes(self.fetch_off_rack),
            self.fetch_s,
        )
    }
}

/// Failure-domain summary of one job or phase: what the `[faults]`
/// machinery did while it ran (counter glossary in DESIGN.md §2.9).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultSummary {
    /// Failed map attempts (real task errors + injected virtual failures).
    pub failed_map_attempts: u64,
    /// Failed reduce attempts.
    pub failed_reduce_attempts: u64,
    /// Completed maps re-executed because the slave holding their output
    /// died.
    pub map_reruns: u64,
    /// Reduce-side segment fetches that targeted a dead slave's output.
    pub fetch_failures: u64,
    /// Slaves blacklisted (no further attempts assigned to them).
    pub blacklisted_slaves: u64,
    /// Scheduled node deaths that fired.
    pub node_deaths: u64,
}

impl FaultSummary {
    /// Extract the summary from merged job counters.
    pub fn from_counters(c: &Counters) -> Self {
        Self {
            failed_map_attempts: c.get(names::FAILED_MAP_ATTEMPTS),
            failed_reduce_attempts: c.get(names::FAILED_REDUCE_ATTEMPTS),
            map_reruns: c.get(names::MAP_RERUNS),
            fetch_failures: c.get(names::FETCH_FAILURES),
            blacklisted_slaves: c.get(names::BLACKLISTED_SLAVES),
            node_deaths: c.get(names::NODE_DEATHS),
        }
    }

    /// Did anything fail at all?
    pub fn any(&self) -> bool {
        *self != Self::default()
    }

    /// One-line human-readable rendering (counter names kept verbatim so
    /// chaos runs are grep-able).
    pub fn render(&self) -> String {
        format!(
            "MAP_RERUNS={} FETCH_FAILURES={} FAILED_MAP_ATTEMPTS={} \
             FAILED_REDUCE_ATTEMPTS={} BLACKLISTED_SLAVES={} NODE_DEATHS={}",
            self.map_reruns,
            self.fetch_failures,
            self.failed_map_attempts,
            self.failed_reduce_attempts,
            self.blacklisted_slaves,
            self.node_deaths,
        )
    }
}

/// t-NN graph-construction summary of one job or phase: how much of the
/// candidate-pair space the spatial index dismissed before pricing it
/// (counter glossary in DESIGN.md §2.10). All-zero for epsilon-mode runs.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct KnnSummary {
    /// Candidate pairs priced in full by the index.
    pub pairs_evaluated: u64,
    /// Candidate pairs dismissed by bounding-box or partial-distance tests.
    pub pruned_pairs: u64,
    /// Neighbors displaced from full top-t heaps.
    pub heap_evictions: u64,
}

impl KnnSummary {
    /// Extract the summary from merged job counters.
    pub fn from_counters(c: &Counters) -> Self {
        Self {
            pairs_evaluated: c.get(names::KNN_PAIRS_EVALUATED),
            pruned_pairs: c.get(names::KNN_PRUNED_PAIRS),
            heap_evictions: c.get(names::KNN_HEAP_EVICTIONS),
        }
    }

    /// Did the t-NN path run at all?
    pub fn any(&self) -> bool {
        *self != Self::default()
    }

    /// Fraction of seen candidate pairs that were pruned (0 when none).
    pub fn pruned_ratio(&self) -> f64 {
        let total = self.pairs_evaluated + self.pruned_pairs;
        if total == 0 {
            0.0
        } else {
            self.pruned_pairs as f64 / total as f64
        }
    }

    /// One-line human-readable rendering (counter names kept verbatim so
    /// smoke runs are grep-able).
    pub fn render(&self) -> String {
        format!(
            "KNN_PAIRS_EVALUATED={} KNN_PRUNED_PAIRS={} KNN_HEAP_EVICTIONS={} \
             pruned={:.1}%",
            self.pairs_evaluated,
            self.pruned_pairs,
            self.heap_evictions,
            100.0 * self.pruned_ratio(),
        )
    }
}

/// Eigensolver summary of one job or phase: jobs launched by the eigen
/// phase, mat-vecs priced across its operator jobs, and the Chebyshev
/// filter degree (counter glossary in DESIGN.md §2.12). All-zero for
/// non-eigen phases; `filter_degree` stays 0 under the lanczos backend,
/// so it doubles as the backend marker.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EigenSummary {
    /// Jobs the eigen phase launched (Laplacian build + operator jobs).
    pub eigen_jobs: u64,
    /// Mat-vecs priced across operator jobs (1 per lanczos step job, m
    /// per ChebDav block job).
    pub matvecs_batched: u64,
    /// Chebyshev filter degree the run used (0 under lanczos).
    pub filter_degree: u64,
}

impl EigenSummary {
    /// Extract the summary from merged job counters.
    pub fn from_counters(c: &Counters) -> Self {
        Self {
            eigen_jobs: c.get(names::EIGEN_JOBS),
            matvecs_batched: c.get(names::MATVECS_BATCHED),
            filter_degree: c.get(names::CHEB_FILTER_DEGREE),
        }
    }

    /// Did an eigen phase run at all?
    pub fn any(&self) -> bool {
        *self != Self::default()
    }

    /// Mat-vecs amortized per launched job (0 when no jobs ran) — the
    /// batching win the ChebDav backend exists for.
    pub fn matvecs_per_job(&self) -> f64 {
        if self.eigen_jobs == 0 {
            0.0
        } else {
            self.matvecs_batched as f64 / self.eigen_jobs as f64
        }
    }

    /// One-line human-readable rendering (counter names kept verbatim so
    /// smoke runs are grep-able).
    pub fn render(&self) -> String {
        format!(
            "EIGEN_JOBS={} MATVECS_BATCHED={} CHEB_FILTER_DEGREE={} \
             matvecs/job={:.1}",
            self.eigen_jobs,
            self.matvecs_batched,
            self.filter_degree,
            self.matvecs_per_job(),
        )
    }
}

/// Serving summary of one job or phase: what the online serving layer
/// (`psch assign`) did — points assigned, assign batches launched, and
/// mini-batch refresh updates applied (counter glossary in DESIGN.md
/// §2.13). All-zero for batch pipeline phases.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServingSummary {
    /// Points assigned by the Nyström extension mappers.
    pub points: u64,
    /// Assign pipelines launched (one per point batch).
    pub batches: u64,
    /// Counted centroid updates applied by mini-batch refresh.
    pub refresh_updates: u64,
}

impl ServingSummary {
    /// Extract the summary from merged job counters.
    pub fn from_counters(c: &Counters) -> Self {
        Self {
            points: c.get(names::ASSIGN_POINTS),
            batches: c.get(names::ASSIGN_BATCHES),
            refresh_updates: c.get(names::REFRESH_UPDATES),
        }
    }

    /// Did the serving layer run at all?
    pub fn any(&self) -> bool {
        *self != Self::default()
    }

    /// Points amortized per batch (0 when no batches ran).
    pub fn points_per_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.points as f64 / self.batches as f64
        }
    }

    /// One-line human-readable rendering (counter names kept verbatim so
    /// smoke runs are grep-able).
    pub fn render(&self) -> String {
        format!(
            "ASSIGN_POINTS={} ASSIGN_BATCHES={} REFRESH_UPDATES={} \
             points/batch={:.1}",
            self.points,
            self.batches,
            self.refresh_updates,
            self.points_per_batch(),
        )
    }
}

/// ASCII sparkline of a value series scaled against `peak` (values at or
/// above `peak` render the tallest bar; non-positive `peak` falls back to
/// the series' own maximum). The telemetry layer draws per-phase slot
/// utilization with this.
pub fn sparkline(values: &[f64], peak: f64) -> String {
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let peak = if peak > 0.0 {
        peak
    } else {
        values.iter().cloned().fold(0.0, f64::max)
    };
    values
        .iter()
        .map(|&v| {
            if peak <= 0.0 || v <= 0.0 {
                BARS[0]
            } else {
                let level = (v / peak * 7.0).round().clamp(0.0, 7.0) as usize;
                BARS[level]
            }
        })
        .collect()
}

/// Render the complete human-readable run summary: the per-phase table,
/// one `shuffle[phase]:` line per phase, `knn[phase]:` / `faults[phase]:`
/// lines for phases where those subsystems acted, the quality line (when
/// a planted truth exists) and the nnz line. Every consumer of a run
/// summary (the CLI, smoke greps) goes through this one formatter.
pub fn render_run(result: &PipelineResult, quality: Option<(f64, f64)>) -> String {
    let mut out = String::new();
    let mut table = AsciiTable::new(&[
        "phase", "virtual", "wall_s", "jobs", "shuffle", "spilled", "merges",
        "reruns", "ffail",
    ]);
    for p in &result.phases {
        let shuffle = p.shuffle_summary();
        let faults = p.fault_summary();
        table.row(&[
            p.name.clone(),
            hms(std::time::Duration::from_secs_f64(p.virtual_s)),
            format!("{:.2}", p.wall_s),
            p.jobs.to_string(),
            human_bytes(p.shuffle_bytes),
            shuffle.spilled_records.to_string(),
            shuffle.merge_passes.to_string(),
            faults.map_reruns.to_string(),
            faults.fetch_failures.to_string(),
        ]);
    }
    table.row(&[
        "TOTAL".into(),
        hms(std::time::Duration::from_secs_f64(result.total_virtual_s)),
        format!("{:.2}", result.total_wall_s),
        result.phases.iter().map(|p| p.jobs).sum::<usize>().to_string(),
        String::new(),
        String::new(),
        String::new(),
        String::new(),
        String::new(),
    ]);
    out.push_str(&table.render());
    out.push('\n');
    for p in &result.phases {
        out.push_str(&format!("shuffle[{}]: {}\n", p.name, p.shuffle_summary().render()));
    }
    // t-NN pruning report: only phases that ran the spatial index.
    for p in &result.phases {
        let k = p.knn_summary();
        if k.any() {
            out.push_str(&format!("knn[{}]: {}\n", p.name, k.render()));
        }
    }
    // Eigensolver report: only the phase that ran an eigen backend.
    for p in &result.phases {
        let e = p.eigen_summary();
        if e.any() {
            out.push_str(&format!("eigen[{}]: {}\n", p.name, e.render()));
        }
    }
    // Serving report: only phases that ran the assign path.
    for p in &result.phases {
        let s = p.serving_summary();
        if s.any() {
            out.push_str(&format!("serving[{}]: {}\n", p.name, s.render()));
        }
    }
    // Per-phase fault report: only phases that saw the failure domain act.
    for p in &result.phases {
        let f = p.fault_summary();
        if f.any() {
            out.push_str(&format!("faults[{}]: {}\n", p.name, f.render()));
        }
    }
    // Scheduler occupancy: queue wait and idle slot-seconds per phase.
    for p in &result.phases {
        if p.queue_wait_s() > 0.0 || p.slot_idle_s() > 0.0 {
            out.push_str(&format!(
                "sched[{}]: queue_wait={:.2}s slot_idle={:.2}s\n",
                p.name,
                p.queue_wait_s(),
                p.slot_idle_s()
            ));
        }
    }
    if let Some((nmi, ari)) = quality {
        out.push_str(&format!(
            "quality: NMI={nmi:.4} ARI={ari:.4} (vs planted truth)\n"
        ));
    }
    out.push_str(&format!("similarity nnz: {}\n", result.nnz));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn knn_summary_reads_all_counters() {
        let mut c = Counters::default();
        c.incr(names::KNN_PAIRS_EVALUATED, 30);
        c.incr(names::KNN_PRUNED_PAIRS, 70);
        c.incr(names::KNN_HEAP_EVICTIONS, 5);
        let s = KnnSummary::from_counters(&c);
        assert_eq!(s.pairs_evaluated, 30);
        assert_eq!(s.pruned_pairs, 70);
        assert_eq!(s.heap_evictions, 5);
        assert!(s.any());
        assert!((s.pruned_ratio() - 0.7).abs() < 1e-12);
        let line = s.render();
        assert!(line.contains("KNN_PRUNED_PAIRS=70"), "{line}");
        assert!(line.contains("pruned=70.0%"), "{line}");
        let empty = KnnSummary::from_counters(&Counters::default());
        assert!(!empty.any());
        assert_eq!(empty.pruned_ratio(), 0.0);
    }

    #[test]
    fn eigen_summary_reads_all_counters() {
        let mut c = Counters::default();
        c.incr(names::EIGEN_JOBS, 40);
        c.incr(names::MATVECS_BATCHED, 240);
        c.incr(names::CHEB_FILTER_DEGREE, 8);
        let s = EigenSummary::from_counters(&c);
        assert_eq!(s.eigen_jobs, 40);
        assert_eq!(s.matvecs_batched, 240);
        assert_eq!(s.filter_degree, 8);
        assert!(s.any());
        assert!((s.matvecs_per_job() - 6.0).abs() < 1e-12);
        let line = s.render();
        assert!(line.contains("EIGEN_JOBS=40"), "{line}");
        assert!(line.contains("MATVECS_BATCHED=240"), "{line}");
        assert!(line.contains("CHEB_FILTER_DEGREE=8"), "{line}");
        assert!(line.contains("matvecs/job=6.0"), "{line}");
        let empty = EigenSummary::from_counters(&Counters::default());
        assert!(!empty.any());
        assert_eq!(empty.matvecs_per_job(), 0.0);
    }

    #[test]
    fn fault_summary_reads_all_counters() {
        let mut c = Counters::default();
        c.incr(names::FAILED_MAP_ATTEMPTS, 3);
        c.incr(names::FAILED_REDUCE_ATTEMPTS, 1);
        c.incr(names::MAP_RERUNS, 2);
        c.incr(names::FETCH_FAILURES, 5);
        c.incr(names::BLACKLISTED_SLAVES, 1);
        c.incr(names::NODE_DEATHS, 1);
        let s = FaultSummary::from_counters(&c);
        assert_eq!(s.failed_map_attempts, 3);
        assert_eq!(s.map_reruns, 2);
        assert_eq!(s.fetch_failures, 5);
        assert!(s.any());
        let line = s.render();
        assert!(line.contains("MAP_RERUNS=2"), "{line}");
        assert!(line.contains("NODE_DEATHS=1"), "{line}");
        assert!(!FaultSummary::default().any());
    }

    #[test]
    fn summary_reads_all_counters() {
        let mut c = Counters::default();
        c.incr(names::SPILLS, 3);
        c.incr(names::SPILLED_RECORDS, 120);
        c.incr(names::MERGE_PASSES, 2);
        c.incr(names::SHUFFLE_FETCH_BYTES_LOCAL, 600);
        c.incr(names::SHUFFLE_FETCH_BYTES_RACK, 300);
        c.incr(names::SHUFFLE_FETCH_BYTES_REMOTE, 100);
        c.incr(names::SHUFFLE_FETCH_US, 2_500_000);
        let s = ShuffleSummary::from_counters(&c);
        assert_eq!(s.spills, 3);
        assert_eq!(s.spilled_records, 120);
        assert_eq!(s.merge_passes, 2);
        assert_eq!(s.total_fetch_bytes(), 1000);
        assert!((s.node_local_pct() - 60.0).abs() < 1e-9);
        assert!((s.fetch_s - 2.5).abs() < 1e-9);
        let line = s.render();
        assert!(line.contains("spills=3"), "{line}");
        assert!(line.contains("merge_passes=2"), "{line}");
    }

    #[test]
    fn empty_counters_are_all_zero() {
        let s = ShuffleSummary::from_counters(&Counters::default());
        assert_eq!(s, ShuffleSummary::default());
        assert_eq!(s.node_local_pct(), 0.0);
        // The zero-counter edge case holds for every summary family, and
        // their renders stay well-formed (no NaN%, no div-by-zero).
        let f = FaultSummary::from_counters(&Counters::default());
        assert_eq!(f, FaultSummary::default());
        assert!(f.render().contains("MAP_RERUNS=0"));
        let k = KnnSummary::from_counters(&Counters::default());
        assert_eq!(k, KnnSummary::default());
        assert!(k.render().contains("pruned=0.0%"));
        assert!(s.render().contains("fetch=0.00s"));
    }

    #[test]
    fn from_counters_round_trips_through_incr() {
        // Write every counter a summary reads, read it back, and check
        // nothing is dropped or cross-wired between families.
        let mut c = Counters::default();
        let pairs: &[(&str, u64)] = &[
            (names::SPILLS, 1),
            (names::SPILLED_RECORDS, 2),
            (names::MERGE_PASSES, 3),
            (names::SHUFFLE_FETCH_BYTES_LOCAL, 4),
            (names::SHUFFLE_FETCH_BYTES_RACK, 5),
            (names::SHUFFLE_FETCH_BYTES_REMOTE, 6),
            (names::SHUFFLE_FETCH_US, 7),
            (names::FAILED_MAP_ATTEMPTS, 8),
            (names::FAILED_REDUCE_ATTEMPTS, 9),
            (names::MAP_RERUNS, 10),
            (names::FETCH_FAILURES, 11),
            (names::BLACKLISTED_SLAVES, 12),
            (names::NODE_DEATHS, 13),
            (names::KNN_PAIRS_EVALUATED, 14),
            (names::KNN_PRUNED_PAIRS, 15),
            (names::KNN_HEAP_EVICTIONS, 16),
            (names::EIGEN_JOBS, 17),
            (names::MATVECS_BATCHED, 18),
            (names::CHEB_FILTER_DEGREE, 19),
            (names::ASSIGN_POINTS, 20),
            (names::ASSIGN_BATCHES, 21),
            (names::REFRESH_UPDATES, 22),
        ];
        for &(name, v) in pairs {
            c.incr(name, v);
        }
        let s = ShuffleSummary::from_counters(&c);
        assert_eq!(
            (s.spills, s.spilled_records, s.merge_passes),
            (1, 2, 3)
        );
        assert_eq!(
            (s.fetch_node_local, s.fetch_rack_local, s.fetch_off_rack),
            (4, 5, 6)
        );
        assert!((s.fetch_s - 7e-6).abs() < 1e-12);
        let f = FaultSummary::from_counters(&c);
        assert_eq!(
            (f.failed_map_attempts, f.failed_reduce_attempts, f.map_reruns),
            (8, 9, 10)
        );
        assert_eq!(
            (f.fetch_failures, f.blacklisted_slaves, f.node_deaths),
            (11, 12, 13)
        );
        let k = KnnSummary::from_counters(&c);
        assert_eq!(
            (k.pairs_evaluated, k.pruned_pairs, k.heap_evictions),
            (14, 15, 16)
        );
        let e = EigenSummary::from_counters(&c);
        assert_eq!(
            (e.eigen_jobs, e.matvecs_batched, e.filter_degree),
            (17, 18, 19)
        );
        let sv = ServingSummary::from_counters(&c);
        assert_eq!((sv.points, sv.batches, sv.refresh_updates), (20, 21, 22));
    }

    #[test]
    fn serving_summary_reads_all_counters() {
        let mut c = Counters::default();
        c.incr(names::ASSIGN_POINTS, 600);
        c.incr(names::ASSIGN_BATCHES, 3);
        c.incr(names::REFRESH_UPDATES, 5);
        let s = ServingSummary::from_counters(&c);
        assert_eq!(s.points, 600);
        assert_eq!(s.batches, 3);
        assert_eq!(s.refresh_updates, 5);
        assert!(s.any());
        assert!((s.points_per_batch() - 200.0).abs() < 1e-12);
        let line = s.render();
        assert!(line.contains("ASSIGN_POINTS=600"), "{line}");
        assert!(line.contains("ASSIGN_BATCHES=3"), "{line}");
        assert!(line.contains("REFRESH_UPDATES=5"), "{line}");
        assert!(line.contains("points/batch=200.0"), "{line}");
        let empty = ServingSummary::from_counters(&Counters::default());
        assert!(!empty.any());
        assert_eq!(empty.points_per_batch(), 0.0);
    }

    #[test]
    fn sparkline_scales_against_the_peak() {
        let s = sparkline(&[0.0, 0.5, 1.0], 1.0);
        assert_eq!(s.chars().count(), 3);
        assert_eq!(s.chars().next(), Some('▁'));
        assert_eq!(s.chars().last(), Some('█'));
        // Values above the peak clamp to the tallest bar.
        assert_eq!(sparkline(&[5.0], 1.0), "█");
        // Zero peak falls back to the series' own maximum.
        assert_eq!(sparkline(&[1.0, 2.0], 0.0).chars().last(), Some('█'));
        assert_eq!(sparkline(&[], 1.0), "");
        assert_eq!(sparkline(&[0.0, 0.0], 0.0), "▁▁");
    }

    #[test]
    fn render_run_routes_every_section() {
        use crate::coordinator::PhaseStats;
        let mut phases = vec![
            PhaseStats { name: "similarity".into(), ..Default::default() },
            PhaseStats { name: "eigenvectors".into(), ..Default::default() },
            PhaseStats { name: "kmeans".into(), ..Default::default() },
        ];
        phases[0].jobs = 2;
        phases[0].counters.incr(names::KNN_PRUNED_PAIRS, 9);
        phases[1].counters.incr(names::EIGEN_JOBS, 21);
        phases[1].counters.incr(names::MATVECS_BATCHED, 42);
        phases[2].counters.incr(names::MAP_RERUNS, 1);
        phases[2].counters.incr(names::ASSIGN_POINTS, 99);
        phases[2].counters.incr(names::ASSIGN_BATCHES, 1);
        let result = PipelineResult {
            labels: vec![0],
            eigenvalues: vec![0.0],
            phases,
            nnz: 7,
            total_virtual_s: 1.0,
            total_wall_s: 0.1,
            sigma: 1.0,
            centers: vec![vec![0.0]],
            embedding: vec![0.0],
        };
        let text = render_run(&result, Some((0.5, 0.25)));
        assert!(text.contains("shuffle[similarity]:"), "{text}");
        assert!(text.contains("knn[similarity]:"), "{text}");
        assert!(!text.contains("knn[kmeans]:"), "{text}");
        assert!(text.contains("faults[kmeans]:"), "{text}");
        assert!(!text.contains("faults[similarity]:"), "{text}");
        assert!(text.contains("eigen[eigenvectors]:"), "{text}");
        assert!(text.contains("EIGEN_JOBS=21"), "{text}");
        assert!(!text.contains("eigen[similarity]:"), "{text}");
        assert!(text.contains("serving[kmeans]:"), "{text}");
        assert!(text.contains("ASSIGN_POINTS=99"), "{text}");
        assert!(!text.contains("serving[similarity]:"), "{text}");
        assert!(text.contains("quality: NMI=0.5000 ARI=0.2500"), "{text}");
        assert!(text.contains("similarity nnz: 7"), "{text}");
        assert!(text.contains("TOTAL"), "{text}");
        // Without a planted truth the quality line disappears entirely.
        let no_truth = render_run(&result, None);
        assert!(!no_truth.contains("quality:"), "{no_truth}");
    }
}
