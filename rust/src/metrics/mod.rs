//! Timing, speedup, locality, shuffle and table reporting for
//! experiments/benches.

pub mod report;
pub mod speedup;
pub mod table;

use std::time::{Duration, Instant};

use crate::mapreduce::{names, Counters};

pub use report::{
    render_run, sparkline, EigenSummary, FaultSummary, KnnSummary,
    ServingSummary, ShuffleSummary,
};

/// Data-locality and speculation summary of one job or phase, derived from
/// the counters the JobTracker feeds through the engine.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LocalitySummary {
    /// Map tasks that ran on a node holding their split.
    pub data_local: u64,
    /// Map tasks that ran in their split's rack.
    pub rack_local: u64,
    /// Map tasks that read across racks.
    pub off_rack: u64,
    /// Speculative duplicates launched / won.
    pub speculative_attempts: u64,
    /// Duplicates that beat the original attempt.
    pub speculative_wins: u64,
    /// Virtual seconds map tasks spent reading input.
    pub virtual_read_s: f64,
}

impl LocalitySummary {
    /// Extract the summary from merged job counters.
    pub fn from_counters(c: &Counters) -> Self {
        Self {
            data_local: c.get(names::DATA_LOCAL_MAPS),
            rack_local: c.get(names::RACK_LOCAL_MAPS),
            off_rack: c.get(names::OFF_RACK_MAPS),
            speculative_attempts: c.get(names::SPECULATIVE_ATTEMPTS),
            speculative_wins: c.get(names::SPECULATIVE_WINS),
            virtual_read_s: c.get(names::MAP_READ_US) as f64 / 1e6,
        }
    }

    /// Map tasks that carried locality info at all.
    pub fn placed(&self) -> u64 {
        self.data_local + self.rack_local + self.off_rack
    }

    /// Percent of placed maps that were data-local (0 when none placed).
    pub fn data_local_pct(&self) -> f64 {
        self.pct(self.data_local)
    }

    /// Percent of placed maps that were rack-local.
    pub fn rack_local_pct(&self) -> f64 {
        self.pct(self.rack_local)
    }

    /// Percent of placed maps that read across racks.
    pub fn off_rack_pct(&self) -> f64 {
        self.pct(self.off_rack)
    }

    fn pct(&self, part: u64) -> f64 {
        if self.placed() == 0 {
            0.0
        } else {
            100.0 * part as f64 / self.placed() as f64
        }
    }
}

/// A simple named phase timer.
#[derive(Debug)]
pub struct PhaseTimer {
    phases: Vec<(String, Duration)>,
    current: Option<(String, Instant)>,
}

impl Default for PhaseTimer {
    fn default() -> Self {
        Self::new()
    }
}

impl PhaseTimer {
    /// New empty timer.
    pub fn new() -> Self {
        Self { phases: Vec::new(), current: None }
    }

    /// Start a phase (finishes any running phase first).
    pub fn start(&mut self, name: &str) {
        self.finish();
        self.current = Some((name.to_string(), Instant::now()));
    }

    /// Finish the running phase, if any.
    pub fn finish(&mut self) {
        if let Some((name, t0)) = self.current.take() {
            self.phases.push((name, t0.elapsed()));
        }
    }

    /// Record an externally-measured phase duration.
    pub fn record(&mut self, name: &str, d: Duration) {
        self.phases.push((name.to_string(), d));
    }

    /// All (phase, duration) pairs in order.
    pub fn phases(&self) -> &[(String, Duration)] {
        &self.phases
    }

    /// Total across phases.
    pub fn total(&self) -> Duration {
        self.phases.iter().map(|(_, d)| *d).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn locality_summary_percentages() {
        let mut c = Counters::default();
        c.incr(names::DATA_LOCAL_MAPS, 6);
        c.incr(names::RACK_LOCAL_MAPS, 3);
        c.incr(names::OFF_RACK_MAPS, 1);
        c.incr(names::MAP_READ_US, 2_500_000);
        let s = LocalitySummary::from_counters(&c);
        assert_eq!(s.placed(), 10);
        assert!((s.data_local_pct() - 60.0).abs() < 1e-9);
        assert!((s.rack_local_pct() - 30.0).abs() < 1e-9);
        assert!((s.off_rack_pct() - 10.0).abs() < 1e-9);
        assert!((s.virtual_read_s - 2.5).abs() < 1e-9);
    }

    #[test]
    fn empty_counters_summarize_to_zero() {
        let s = LocalitySummary::from_counters(&Counters::default());
        assert_eq!(s.placed(), 0);
        assert_eq!(s.data_local_pct(), 0.0);
    }

    #[test]
    fn record_and_total() {
        let mut t = PhaseTimer::new();
        t.record("a", Duration::from_secs(2));
        t.record("b", Duration::from_secs(3));
        assert_eq!(t.total(), Duration::from_secs(5));
        assert_eq!(t.phases().len(), 2);
        assert_eq!(t.phases()[0].0, "a");
    }

    #[test]
    fn start_finish_measures_something() {
        let mut t = PhaseTimer::new();
        t.start("work");
        std::thread::sleep(Duration::from_millis(5));
        t.finish();
        assert_eq!(t.phases().len(), 1);
        assert!(t.phases()[0].1 >= Duration::from_millis(4));
    }

    #[test]
    fn start_auto_finishes_previous() {
        let mut t = PhaseTimer::new();
        t.start("a");
        t.start("b");
        t.finish();
        assert_eq!(t.phases().len(), 2);
    }
}
