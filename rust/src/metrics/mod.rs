//! Timing, speedup and table reporting for experiments and benches.

pub mod speedup;
pub mod table;

use std::time::{Duration, Instant};

/// A simple named phase timer.
#[derive(Debug)]
pub struct PhaseTimer {
    phases: Vec<(String, Duration)>,
    current: Option<(String, Instant)>,
}

impl Default for PhaseTimer {
    fn default() -> Self {
        Self::new()
    }
}

impl PhaseTimer {
    /// New empty timer.
    pub fn new() -> Self {
        Self { phases: Vec::new(), current: None }
    }

    /// Start a phase (finishes any running phase first).
    pub fn start(&mut self, name: &str) {
        self.finish();
        self.current = Some((name.to_string(), Instant::now()));
    }

    /// Finish the running phase, if any.
    pub fn finish(&mut self) {
        if let Some((name, t0)) = self.current.take() {
            self.phases.push((name, t0.elapsed()));
        }
    }

    /// Record an externally-measured phase duration.
    pub fn record(&mut self, name: &str, d: Duration) {
        self.phases.push((name.to_string(), d));
    }

    /// All (phase, duration) pairs in order.
    pub fn phases(&self) -> &[(String, Duration)] {
        &self.phases
    }

    /// Total across phases.
    pub fn total(&self) -> Duration {
        self.phases.iter().map(|(_, d)| *d).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_total() {
        let mut t = PhaseTimer::new();
        t.record("a", Duration::from_secs(2));
        t.record("b", Duration::from_secs(3));
        assert_eq!(t.total(), Duration::from_secs(5));
        assert_eq!(t.phases().len(), 2);
        assert_eq!(t.phases()[0].0, "a");
    }

    #[test]
    fn start_finish_measures_something() {
        let mut t = PhaseTimer::new();
        t.start("work");
        std::thread::sleep(Duration::from_millis(5));
        t.finish();
        assert_eq!(t.phases().len(), 1);
        assert!(t.phases()[0].1 >= Duration::from_millis(4));
    }

    #[test]
    fn start_auto_finishes_previous() {
        let mut t = PhaseTimer::new();
        t.start("a");
        t.start("b");
        t.finish();
        assert_eq!(t.phases().len(), 2);
    }
}
