//! Block primitives for the mini-HDFS.

use std::sync::Arc;

/// Globally unique block id.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BlockId(pub u64);

/// Default block size (small: workloads here are MBs, not TBs).
pub const DEFAULT_BLOCK_SIZE: usize = 1 << 20; // 1 MiB

/// Immutable block payload, shared between datanodes (replicas) without copy.
pub type BlockData = Arc<Vec<u8>>;

/// Metadata for one file: ordered blocks plus total length.
#[derive(Debug, Clone)]
pub struct FileMeta {
    /// Blocks in file order.
    pub blocks: Vec<BlockId>,
    /// Exact byte length (last block may be partial).
    pub len: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_id_ordering() {
        assert!(BlockId(1) < BlockId(2));
        let mut v = vec![BlockId(3), BlockId(1), BlockId(2)];
        v.sort();
        assert_eq!(v, vec![BlockId(1), BlockId(2), BlockId(3)]);
    }
}
