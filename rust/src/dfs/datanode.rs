//! DataNode: block storage on one simulated slave machine.

use std::collections::HashMap;

use crate::error::{Error, Result};

use super::block::{BlockData, BlockId};

/// One datanode's block store.
#[derive(Debug)]
pub struct DataNode {
    /// Node id (== slave id in the cluster).
    pub id: usize,
    blocks: HashMap<BlockId, BlockData>,
    alive: bool,
}

impl DataNode {
    /// New empty, alive datanode.
    pub fn new(id: usize) -> Self {
        Self { id, blocks: HashMap::new(), alive: true }
    }

    /// Store a replica.
    pub fn store(&mut self, id: BlockId, data: BlockData) -> Result<()> {
        if !self.alive {
            return Err(Error::Dfs(format!("datanode {} is dead", self.id)));
        }
        self.blocks.insert(id, data);
        Ok(())
    }

    /// Read a replica.
    pub fn read(&self, id: BlockId) -> Result<BlockData> {
        if !self.alive {
            return Err(Error::Dfs(format!("datanode {} is dead", self.id)));
        }
        self.blocks
            .get(&id)
            .cloned()
            .ok_or_else(|| Error::Dfs(format!("datanode {}: no block {id:?}", self.id)))
    }

    /// Drop a replica (GC).
    pub fn delete(&mut self, id: BlockId) {
        self.blocks.remove(&id);
    }

    /// Is this node serving?
    pub fn is_alive(&self) -> bool {
        self.alive
    }

    /// Kill the node (fault injection). Its replicas become unreachable.
    pub fn kill(&mut self) {
        self.alive = false;
        self.blocks.clear();
    }

    /// Restart the node empty.
    pub fn restart(&mut self) {
        self.alive = true;
    }

    /// Number of replicas held.
    pub fn block_count(&self) -> usize {
        self.blocks.len()
    }

    /// Bytes held.
    pub fn bytes(&self) -> usize {
        self.blocks.values().map(|b| b.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn store_read_delete() {
        let mut dn = DataNode::new(0);
        let data = Arc::new(vec![1u8, 2, 3]);
        dn.store(BlockId(1), data.clone()).unwrap();
        assert_eq!(*dn.read(BlockId(1)).unwrap(), vec![1, 2, 3]);
        assert_eq!(dn.block_count(), 1);
        assert_eq!(dn.bytes(), 3);
        dn.delete(BlockId(1));
        assert!(dn.read(BlockId(1)).is_err());
    }

    #[test]
    fn dead_node_rejects_io() {
        let mut dn = DataNode::new(3);
        dn.store(BlockId(1), Arc::new(vec![0u8; 4])).unwrap();
        dn.kill();
        assert!(!dn.is_alive());
        assert!(dn.read(BlockId(1)).is_err());
        assert!(dn.store(BlockId(2), Arc::new(vec![])).is_err());
        dn.restart();
        assert!(dn.is_alive());
        // Replicas were lost on kill.
        assert_eq!(dn.block_count(), 0);
    }
}
