//! Mini-HDFS: a single-master replicated block store (paper §2.1).
//!
//! Write-once/read-many semantics, fixed-size blocks, configurable
//! replication, round-robin block placement, datanode fault injection and
//! re-replication from surviving replicas — the behaviours the paper's
//! pipeline relies on (input file storage, the k-means "center file") plus
//! the reliability mechanism §2.1 highlights.

pub mod block;
pub mod datanode;
pub mod namenode;

use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};

pub use block::{BlockId, FileMeta, DEFAULT_BLOCK_SIZE};
use datanode::DataNode;
use namenode::NameNode;

/// The distributed file system facade. Cheap to clone (shared state).
#[derive(Clone)]
pub struct Dfs {
    inner: Arc<DfsInner>,
}

struct DfsInner {
    namenode: Mutex<NameNode>,
    datanodes: Vec<Mutex<DataNode>>,
    block_size: usize,
    replication: usize,
    next_placement: Mutex<usize>,
}

impl Dfs {
    /// Create a DFS over `nodes` datanodes with the given replication factor
    /// (clamped to the node count) and default block size.
    pub fn new(nodes: usize, replication: usize) -> Self {
        Self::with_block_size(nodes, replication, DEFAULT_BLOCK_SIZE)
    }

    /// Create with an explicit block size (tests use tiny blocks).
    pub fn with_block_size(nodes: usize, replication: usize, block_size: usize) -> Self {
        assert!(nodes > 0, "need at least one datanode");
        assert!(block_size > 0, "block size must be positive");
        Self {
            inner: Arc::new(DfsInner {
                namenode: Mutex::new(NameNode::default()),
                datanodes: (0..nodes).map(|i| Mutex::new(DataNode::new(i))).collect(),
                block_size,
                replication: replication.max(1).min(nodes),
                next_placement: Mutex::new(0),
            }),
        }
    }

    /// Number of datanodes (alive or dead).
    pub fn node_count(&self) -> usize {
        self.inner.datanodes.len()
    }

    /// Configured replication factor.
    pub fn replication(&self) -> usize {
        self.inner.replication
    }

    /// Pick `replication` distinct alive nodes, round-robin from a cursor.
    fn place_replicas(&self) -> Result<Vec<usize>> {
        let n = self.inner.datanodes.len();
        let mut cursor = self.inner.next_placement.lock().unwrap();
        let mut chosen = Vec::with_capacity(self.inner.replication);
        for off in 0..n {
            let cand = (*cursor + off) % n;
            if self.inner.datanodes[cand].lock().unwrap().is_alive() {
                chosen.push(cand);
                if chosen.len() == self.inner.replication {
                    break;
                }
            }
        }
        *cursor = (*cursor + 1) % n;
        if chosen.is_empty() {
            return Err(Error::Dfs("no alive datanodes".into()));
        }
        Ok(chosen)
    }

    /// Write a file (overwrites an existing path, HDFS-style delete+create).
    pub fn write_file(&self, path: &str, data: &[u8]) -> Result<()> {
        if self.exists(path) {
            self.delete(path)?;
        }
        let mut blocks = Vec::new();
        for chunk in data.chunks(self.inner.block_size.max(1)) {
            let payload: block::BlockData = Arc::new(chunk.to_vec());
            let id = self.inner.namenode.lock().unwrap().alloc_block();
            let nodes = self.place_replicas()?;
            for &node in &nodes {
                self.inner.datanodes[node]
                    .lock()
                    .unwrap()
                    .store(id, payload.clone())?;
            }
            self.inner.namenode.lock().unwrap().set_locations(id, nodes);
            blocks.push(id);
        }
        // Empty file still gets metadata.
        self.inner
            .namenode
            .lock()
            .unwrap()
            .create_file(path, FileMeta { blocks, len: data.len() })
    }

    /// Read a whole file, preferring the first alive replica of each block.
    pub fn read_file(&self, path: &str) -> Result<Vec<u8>> {
        let meta = self.inner.namenode.lock().unwrap().get_file(path)?.clone();
        let mut out = Vec::with_capacity(meta.len);
        for block in &meta.blocks {
            out.extend_from_slice(&self.read_block(*block)?);
        }
        Ok(out)
    }

    /// Read one block from any alive replica.
    pub fn read_block(&self, block: BlockId) -> Result<block::BlockData> {
        let locations = self
            .inner
            .namenode
            .lock()
            .unwrap()
            .locations(block)?
            .to_vec();
        for node in locations {
            if let Ok(data) = self.inner.datanodes[node].lock().unwrap().read(block) {
                return Ok(data);
            }
        }
        Err(Error::Dfs(format!("all replicas of {block:?} unreachable")))
    }

    /// File length in bytes.
    pub fn len(&self, path: &str) -> Result<usize> {
        Ok(self.inner.namenode.lock().unwrap().get_file(path)?.len)
    }

    /// Does the path exist?
    pub fn exists(&self, path: &str) -> bool {
        self.inner.namenode.lock().unwrap().exists(path)
    }

    /// Delete a file and GC its replicas.
    pub fn delete(&self, path: &str) -> Result<()> {
        let meta = self.inner.namenode.lock().unwrap().remove_file(path)?;
        for block in meta.blocks {
            if let Ok(nodes) = self
                .inner
                .namenode
                .lock()
                .unwrap()
                .locations(block)
                .map(|s| s.to_vec())
            {
                for node in nodes {
                    self.inner.datanodes[node].lock().unwrap().delete(block);
                }
            }
            self.inner.namenode.lock().unwrap().forget_block(block);
        }
        Ok(())
    }

    /// List all paths.
    pub fn list(&self) -> Vec<String> {
        self.inner.namenode.lock().unwrap().list()
    }

    /// Kill a datanode (fault injection), then re-replicate under-replicated
    /// blocks from surviving replicas onto other alive nodes.
    pub fn kill_datanode(&self, node: usize) -> Result<usize> {
        self.inner.datanodes[node].lock().unwrap().kill();
        let under = self
            .inner
            .namenode
            .lock()
            .unwrap()
            .drop_node(node, self.inner.replication);
        let mut repaired = 0;
        for block in under {
            if self.re_replicate(block).is_ok() {
                repaired += 1;
            }
        }
        Ok(repaired)
    }

    /// Restore a block's replica count from a surviving copy.
    fn re_replicate(&self, block: BlockId) -> Result<()> {
        let data = self.read_block(block)?;
        let current: Vec<usize> = self
            .inner
            .namenode
            .lock()
            .unwrap()
            .locations(block)?
            .to_vec();
        let n = self.inner.datanodes.len();
        let mut new_nodes = current.clone();
        for cand in 0..n {
            if new_nodes.len() >= self.inner.replication {
                break;
            }
            if new_nodes.contains(&cand) {
                continue;
            }
            let mut dn = self.inner.datanodes[cand].lock().unwrap();
            if dn.is_alive() && dn.store(block, data.clone()).is_ok() {
                new_nodes.push(cand);
            }
        }
        if new_nodes.len() < self.inner.replication.min(self.alive_count()) {
            return Err(Error::Dfs(format!("cannot restore replication of {block:?}")));
        }
        self.inner
            .namenode
            .lock()
            .unwrap()
            .set_locations(block, new_nodes);
        Ok(())
    }

    /// Number of alive datanodes.
    pub fn alive_count(&self) -> usize {
        self.inner
            .datanodes
            .iter()
            .filter(|d| d.lock().unwrap().is_alive())
            .count()
    }

    /// Total bytes stored across all replicas (storage amplification view).
    pub fn stored_bytes(&self) -> usize {
        self.inner
            .datanodes
            .iter()
            .map(|d| d.lock().unwrap().bytes())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_read_roundtrip_multi_block() {
        let dfs = Dfs::with_block_size(4, 2, 8);
        let data: Vec<u8> = (0..100u8).collect();
        dfs.write_file("/data", &data).unwrap();
        assert_eq!(dfs.read_file("/data").unwrap(), data);
        assert_eq!(dfs.len("/data").unwrap(), 100);
        // 100 bytes / 8-byte blocks = 13 blocks, x2 replicas.
        assert_eq!(dfs.stored_bytes(), 200);
    }

    #[test]
    fn empty_file() {
        let dfs = Dfs::new(2, 1);
        dfs.write_file("/empty", &[]).unwrap();
        assert_eq!(dfs.read_file("/empty").unwrap(), Vec::<u8>::new());
        assert_eq!(dfs.len("/empty").unwrap(), 0);
    }

    #[test]
    fn overwrite_replaces_content() {
        let dfs = Dfs::with_block_size(3, 2, 4);
        dfs.write_file("/f", b"hello world").unwrap();
        dfs.write_file("/f", b"bye").unwrap();
        assert_eq!(dfs.read_file("/f").unwrap(), b"bye");
    }

    #[test]
    fn delete_gcs_replicas() {
        let dfs = Dfs::with_block_size(3, 3, 4);
        dfs.write_file("/f", b"0123456789").unwrap();
        assert!(dfs.stored_bytes() > 0);
        dfs.delete("/f").unwrap();
        assert_eq!(dfs.stored_bytes(), 0);
        assert!(!dfs.exists("/f"));
        assert!(dfs.read_file("/f").is_err());
    }

    #[test]
    fn survives_datanode_failure_with_replication() {
        let dfs = Dfs::with_block_size(4, 2, 8);
        let data: Vec<u8> = (0..64u8).collect();
        dfs.write_file("/f", &data).unwrap();
        // Kill nodes one at a time; with re-replication the file survives
        // any single failure, and repeated failures too.
        dfs.kill_datanode(0).unwrap();
        assert_eq!(dfs.read_file("/f").unwrap(), data);
        dfs.kill_datanode(1).unwrap();
        assert_eq!(dfs.read_file("/f").unwrap(), data);
        assert_eq!(dfs.alive_count(), 2);
    }

    #[test]
    fn unreplicated_file_lost_on_failure() {
        let dfs = Dfs::with_block_size(2, 1, 1024);
        dfs.write_file("/f", b"data").unwrap();
        // Find which node holds the single replica and kill it.
        let holder = (0..2)
            .find(|&i| {
                dfs.inner.datanodes[i].lock().unwrap().block_count() > 0
            })
            .unwrap();
        dfs.kill_datanode(holder).unwrap();
        assert!(dfs.read_file("/f").is_err());
    }

    #[test]
    fn list_files() {
        let dfs = Dfs::new(1, 1);
        dfs.write_file("/b", b"1").unwrap();
        dfs.write_file("/a", b"2").unwrap();
        assert_eq!(dfs.list(), vec!["/a".to_string(), "/b".to_string()]);
    }

    #[test]
    fn placement_spreads_blocks() {
        let dfs = Dfs::with_block_size(4, 1, 4);
        dfs.write_file("/f", &[0u8; 64]).unwrap(); // 16 blocks
        let counts: Vec<usize> = (0..4)
            .map(|i| dfs.inner.datanodes[i].lock().unwrap().block_count())
            .collect();
        // Round-robin: every node holds exactly 4 of the 16 blocks.
        assert_eq!(counts, vec![4, 4, 4, 4]);
    }
}
