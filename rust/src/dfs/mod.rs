//! Mini-HDFS: a single-master replicated block store (paper §2.1).
//!
//! Write-once/read-many semantics, fixed-size blocks, configurable
//! replication, rack-aware block placement (HDFS's policy: second replica
//! off-rack, third in the remote rack), datanode fault injection and
//! re-replication from surviving replicas onto surviving racks — the
//! behaviours the paper's pipeline relies on (input file storage, the
//! k-means "center file") plus the reliability mechanism §2.1 highlights.
//! Block locations feed the JobTracker's locality-aware map placement via
//! [`Dfs::range_hosts`].

pub mod block;
pub mod datanode;
pub mod namenode;

use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::scheduler::RackTopology;

pub use block::{BlockId, FileMeta, DEFAULT_BLOCK_SIZE};
use datanode::DataNode;
use namenode::NameNode;

/// The distributed file system facade. Cheap to clone (shared state).
#[derive(Clone)]
pub struct Dfs {
    inner: Arc<DfsInner>,
}

struct DfsInner {
    namenode: Mutex<NameNode>,
    datanodes: Vec<Mutex<DataNode>>,
    topology: RackTopology,
    block_size: usize,
    replication: usize,
    next_placement: Mutex<usize>,
}

impl Dfs {
    /// Create a DFS over `nodes` datanodes with the given replication factor
    /// (clamped to the node count) and default block size.
    pub fn new(nodes: usize, replication: usize) -> Self {
        Self::with_block_size(nodes, replication, DEFAULT_BLOCK_SIZE)
    }

    /// Create with an explicit block size (tests use tiny blocks).
    pub fn with_block_size(nodes: usize, replication: usize, block_size: usize) -> Self {
        Self::with_topology(
            nodes,
            replication,
            block_size,
            RackTopology::single(nodes.max(1)),
        )
    }

    /// Create with an explicit rack topology: replica placement becomes
    /// rack-aware, and re-replication prefers restoring rack spread.
    pub fn with_topology(
        nodes: usize,
        replication: usize,
        block_size: usize,
        topology: RackTopology,
    ) -> Self {
        assert!(nodes > 0, "need at least one datanode");
        assert!(block_size > 0, "block size must be positive");
        assert_eq!(
            topology.num_nodes(),
            nodes,
            "topology must cover every datanode"
        );
        Self {
            inner: Arc::new(DfsInner {
                namenode: Mutex::new(NameNode::default()),
                datanodes: (0..nodes).map(|i| Mutex::new(DataNode::new(i))).collect(),
                topology,
                block_size,
                replication: replication.max(1).min(nodes),
                next_placement: Mutex::new(0),
            }),
        }
    }

    /// The rack topology over the datanodes.
    pub fn topology(&self) -> &RackTopology {
        &self.inner.topology
    }

    /// Configured block size in bytes.
    pub fn block_size(&self) -> usize {
        self.inner.block_size
    }

    /// Number of datanodes (alive or dead).
    pub fn node_count(&self) -> usize {
        self.inner.datanodes.len()
    }

    /// Configured replication factor.
    pub fn replication(&self) -> usize {
        self.inner.replication
    }

    /// Pick `replication` distinct alive nodes, rack-aware, round-robin
    /// from a cursor (the placement policy itself lives in
    /// [`namenode::choose_replicas`]).
    fn place_replicas(&self) -> Result<Vec<usize>> {
        let n = self.inner.datanodes.len();
        let alive: Vec<bool> = self
            .inner
            .datanodes
            .iter()
            .map(|d| d.lock().unwrap().is_alive())
            .collect();
        let mut cursor = self.inner.next_placement.lock().unwrap();
        let chosen = namenode::choose_replicas(
            &self.inner.topology,
            &alive,
            self.inner.replication,
            *cursor,
        );
        *cursor = (*cursor + 1) % n;
        if chosen.is_empty() {
            return Err(Error::Dfs("no alive datanodes".into()));
        }
        Ok(chosen)
    }

    /// Write a file (overwrites an existing path, HDFS-style delete+create).
    pub fn write_file(&self, path: &str, data: &[u8]) -> Result<()> {
        if self.exists(path) {
            self.delete(path)?;
        }
        let mut blocks = Vec::new();
        for chunk in data.chunks(self.inner.block_size.max(1)) {
            let payload: block::BlockData = Arc::new(chunk.to_vec());
            let id = self.inner.namenode.lock().unwrap().alloc_block();
            let nodes = self.place_replicas()?;
            for &node in &nodes {
                self.inner.datanodes[node]
                    .lock()
                    .unwrap()
                    .store(id, payload.clone())?;
            }
            self.inner.namenode.lock().unwrap().set_locations(id, nodes);
            blocks.push(id);
        }
        // Empty file still gets metadata.
        self.inner
            .namenode
            .lock()
            .unwrap()
            .create_file(path, FileMeta { blocks, len: data.len() })
    }

    /// Read a whole file, preferring the first alive replica of each block.
    pub fn read_file(&self, path: &str) -> Result<Vec<u8>> {
        let meta = self.inner.namenode.lock().unwrap().get_file(path)?.clone();
        let mut out = Vec::with_capacity(meta.len);
        for block in &meta.blocks {
            out.extend_from_slice(&self.read_block(*block)?);
        }
        Ok(out)
    }

    /// Read one block from any alive replica.
    pub fn read_block(&self, block: BlockId) -> Result<block::BlockData> {
        let locations = self
            .inner
            .namenode
            .lock()
            .unwrap()
            .locations(block)?
            .to_vec();
        for node in locations {
            if let Ok(data) = self.inner.datanodes[node].lock().unwrap().read(block) {
                return Ok(data);
            }
        }
        Err(Error::Dfs(format!("all replicas of {block:?} unreachable")))
    }

    /// File length in bytes.
    pub fn len(&self, path: &str) -> Result<usize> {
        Ok(self.inner.namenode.lock().unwrap().get_file(path)?.len)
    }

    /// Does the path exist?
    pub fn exists(&self, path: &str) -> bool {
        self.inner.namenode.lock().unwrap().exists(path)
    }

    /// Delete a file and GC its replicas.
    pub fn delete(&self, path: &str) -> Result<()> {
        let meta = self.inner.namenode.lock().unwrap().remove_file(path)?;
        for block in meta.blocks {
            if let Ok(nodes) = self
                .inner
                .namenode
                .lock()
                .unwrap()
                .locations(block)
                .map(|s| s.to_vec())
            {
                for node in nodes {
                    self.inner.datanodes[node].lock().unwrap().delete(block);
                }
            }
            self.inner.namenode.lock().unwrap().forget_block(block);
        }
        Ok(())
    }

    /// List all paths.
    pub fn list(&self) -> Vec<String> {
        self.inner.namenode.lock().unwrap().list()
    }

    /// Kill a datanode (fault injection), then re-replicate under-replicated
    /// blocks from surviving replicas onto other alive nodes.
    pub fn kill_datanode(&self, node: usize) -> Result<usize> {
        self.inner.datanodes[node].lock().unwrap().kill();
        let under = self
            .inner
            .namenode
            .lock()
            .unwrap()
            .drop_node(node, self.inner.replication);
        let mut repaired = 0;
        for block in under {
            if self.re_replicate(block).is_ok() {
                repaired += 1;
            }
        }
        Ok(repaired)
    }

    /// Restore a block's replica count from a surviving copy, preferring
    /// candidate nodes whose rack is not yet represented (so a block that
    /// spanned two racks keeps spanning two after a failure).
    fn re_replicate(&self, block: BlockId) -> Result<()> {
        let data = self.read_block(block)?;
        let current: Vec<usize> = self
            .inner
            .namenode
            .lock()
            .unwrap()
            .locations(block)?
            .to_vec();
        let n = self.inner.datanodes.len();
        let topo = &self.inner.topology;
        let covered: std::collections::HashSet<usize> =
            current.iter().map(|&c| topo.rack_of(c)).collect();
        let mut candidates: Vec<usize> =
            (0..n).filter(|c| !current.contains(c)).collect();
        // New racks first (false < true), node id breaks ties.
        candidates.sort_by_key(|&c| (covered.contains(&topo.rack_of(c)), c));
        let mut new_nodes = current.clone();
        for cand in candidates {
            if new_nodes.len() >= self.inner.replication {
                break;
            }
            let mut dn = self.inner.datanodes[cand].lock().unwrap();
            if dn.is_alive() && dn.store(block, data.clone()).is_ok() {
                new_nodes.push(cand);
            }
        }
        if new_nodes.len() < self.inner.replication.min(self.alive_count()) {
            return Err(Error::Dfs(format!("cannot restore replication of {block:?}")));
        }
        self.inner
            .namenode
            .lock()
            .unwrap()
            .set_locations(block, new_nodes);
        Ok(())
    }

    /// Replica locations of every block of a file, in file order.
    pub fn block_hosts(&self, path: &str) -> Result<Vec<Vec<usize>>> {
        let nn = self.inner.namenode.lock().unwrap();
        let blocks = nn.get_file(path)?.blocks.clone();
        blocks
            .iter()
            .map(|&b| nn.locations(b).map(|s| s.to_vec()))
            .collect()
    }

    /// Union of replica nodes of the blocks overlapping byte range
    /// `[lo, hi)` of a file — the preferred hosts of a map split covering
    /// that range (sorted, deduplicated).
    pub fn range_hosts(&self, path: &str, lo: usize, hi: usize) -> Result<Vec<usize>> {
        let hosts = self.block_hosts(path)?;
        let bs = self.inner.block_size;
        if lo >= hi || hosts.is_empty() {
            return Ok(Vec::new());
        }
        let first = lo / bs;
        let last = hi.div_ceil(bs).min(hosts.len());
        let mut out: Vec<usize> = hosts
            .iter()
            .take(last)
            .skip(first)
            .flatten()
            .copied()
            .collect();
        out.sort_unstable();
        out.dedup();
        Ok(out)
    }

    /// Number of alive datanodes.
    pub fn alive_count(&self) -> usize {
        self.inner
            .datanodes
            .iter()
            .filter(|d| d.lock().unwrap().is_alive())
            .count()
    }

    /// Total bytes stored across all replicas (storage amplification view).
    pub fn stored_bytes(&self) -> usize {
        self.inner
            .datanodes
            .iter()
            .map(|d| d.lock().unwrap().bytes())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_read_roundtrip_multi_block() {
        let dfs = Dfs::with_block_size(4, 2, 8);
        let data: Vec<u8> = (0..100u8).collect();
        dfs.write_file("/data", &data).unwrap();
        assert_eq!(dfs.read_file("/data").unwrap(), data);
        assert_eq!(dfs.len("/data").unwrap(), 100);
        // 100 bytes / 8-byte blocks = 13 blocks, x2 replicas.
        assert_eq!(dfs.stored_bytes(), 200);
    }

    #[test]
    fn empty_file() {
        let dfs = Dfs::new(2, 1);
        dfs.write_file("/empty", &[]).unwrap();
        assert_eq!(dfs.read_file("/empty").unwrap(), Vec::<u8>::new());
        assert_eq!(dfs.len("/empty").unwrap(), 0);
    }

    #[test]
    fn overwrite_replaces_content() {
        let dfs = Dfs::with_block_size(3, 2, 4);
        dfs.write_file("/f", b"hello world").unwrap();
        dfs.write_file("/f", b"bye").unwrap();
        assert_eq!(dfs.read_file("/f").unwrap(), b"bye");
    }

    #[test]
    fn delete_gcs_replicas() {
        let dfs = Dfs::with_block_size(3, 3, 4);
        dfs.write_file("/f", b"0123456789").unwrap();
        assert!(dfs.stored_bytes() > 0);
        dfs.delete("/f").unwrap();
        assert_eq!(dfs.stored_bytes(), 0);
        assert!(!dfs.exists("/f"));
        assert!(dfs.read_file("/f").is_err());
    }

    #[test]
    fn survives_datanode_failure_with_replication() {
        let dfs = Dfs::with_block_size(4, 2, 8);
        let data: Vec<u8> = (0..64u8).collect();
        dfs.write_file("/f", &data).unwrap();
        // Kill nodes one at a time; with re-replication the file survives
        // any single failure, and repeated failures too.
        dfs.kill_datanode(0).unwrap();
        assert_eq!(dfs.read_file("/f").unwrap(), data);
        dfs.kill_datanode(1).unwrap();
        assert_eq!(dfs.read_file("/f").unwrap(), data);
        assert_eq!(dfs.alive_count(), 2);
    }

    #[test]
    fn unreplicated_file_lost_on_failure() {
        let dfs = Dfs::with_block_size(2, 1, 1024);
        dfs.write_file("/f", b"data").unwrap();
        // Find which node holds the single replica and kill it.
        let holder = (0..2)
            .find(|&i| {
                dfs.inner.datanodes[i].lock().unwrap().block_count() > 0
            })
            .unwrap();
        dfs.kill_datanode(holder).unwrap();
        assert!(dfs.read_file("/f").is_err());
    }

    #[test]
    fn list_files() {
        let dfs = Dfs::new(1, 1);
        dfs.write_file("/b", b"1").unwrap();
        dfs.write_file("/a", b"2").unwrap();
        assert_eq!(dfs.list(), vec!["/a".to_string(), "/b".to_string()]);
    }

    #[test]
    fn rack_aware_placement_spans_two_racks() {
        let topo = RackTopology::uniform(4, 2);
        let dfs = Dfs::with_topology(4, 2, 8, topo);
        dfs.write_file("/f", &[7u8; 64]).unwrap(); // 8 blocks x 2 replicas
        for (i, hosts) in dfs.block_hosts("/f").unwrap().iter().enumerate() {
            assert_eq!(hosts.len(), 2, "block {i}");
            let racks: std::collections::HashSet<usize> =
                hosts.iter().map(|&h| dfs.topology().rack_of(h)).collect();
            assert_eq!(racks.len(), 2, "block {i} replicas share a rack: {hosts:?}");
        }
    }

    #[test]
    fn rereplication_recovers_onto_surviving_racks() {
        // 6 nodes over 2 racks; every block starts with one replica per
        // rack. Killing nodes must re-replicate (drop_node reports the
        // under-replicated blocks) AND keep each block on two racks while
        // both racks have alive nodes.
        let topo = RackTopology::uniform(6, 2);
        let dfs = Dfs::with_topology(6, 2, 8, topo);
        let data: Vec<u8> = (0..96u8).collect();
        dfs.write_file("/f", &data).unwrap();
        for killed in [0usize, 3] {
            let repaired = dfs.kill_datanode(killed).unwrap();
            assert!(repaired > 0, "killing {killed} must trigger re-replication");
            assert_eq!(dfs.read_file("/f").unwrap(), data);
            for (i, hosts) in dfs.block_hosts("/f").unwrap().iter().enumerate() {
                assert_eq!(hosts.len(), 2, "block {i} under-replicated");
                assert!(!hosts.contains(&killed), "block {i} still on dead node");
                let racks: std::collections::HashSet<usize> =
                    hosts.iter().map(|&h| dfs.topology().rack_of(h)).collect();
                assert_eq!(
                    racks.len(),
                    2,
                    "block {i} lost rack spread after killing {killed}: {hosts:?}"
                );
            }
        }
    }

    #[test]
    fn range_hosts_cover_the_split_blocks() {
        let dfs = Dfs::with_block_size(4, 1, 8);
        dfs.write_file("/f", &[0u8; 32]).unwrap(); // 4 single-replica blocks
        let per_block = dfs.block_hosts("/f").unwrap();
        // Range spanning blocks 1 and 2 unions exactly their holders.
        let mut expect: Vec<usize> =
            per_block[1].iter().chain(&per_block[2]).copied().collect();
        expect.sort_unstable();
        expect.dedup();
        assert_eq!(dfs.range_hosts("/f", 8, 24).unwrap(), expect);
        // Empty and out-of-file ranges are harmless.
        assert!(dfs.range_hosts("/f", 5, 5).unwrap().is_empty());
        assert!(!dfs.range_hosts("/f", 24, 1000).unwrap().is_empty());
    }

    #[test]
    fn placement_spreads_blocks() {
        let dfs = Dfs::with_block_size(4, 1, 4);
        dfs.write_file("/f", &[0u8; 64]).unwrap(); // 16 blocks
        let counts: Vec<usize> = (0..4)
            .map(|i| dfs.inner.datanodes[i].lock().unwrap().block_count())
            .collect();
        // Round-robin: every node holds exactly 4 of the 16 blocks.
        assert_eq!(counts, vec![4, 4, 4, 4]);
    }
}
