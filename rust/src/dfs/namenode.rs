//! NameNode: file → blocks and block → replica-location metadata.
//!
//! Mirrors HDFS's single-master design (paper §2.1: the Master "only
//! store[s] metadata file blocks and … control[s] the distribution").

use std::collections::HashMap;

use crate::error::{Error, Result};
use crate::scheduler::RackTopology;

use super::block::{BlockId, FileMeta};

/// HDFS-style rack-aware replica chooser (the NameNode's placement policy):
///
/// 1. the first replica goes to the first alive node scanning round-robin
///    from `cursor` (the "writer" node),
/// 2. the second to the first alive node in a *different* rack,
/// 3. the third to another node in the *second* replica's rack (HDFS keeps
///    two replicas in one remote rack to cap cross-rack write traffic),
/// 4. any further replicas fill round-robin over the remaining alive nodes.
///
/// With a single rack this degrades to plain round-robin — the placement
/// the DFS used before racks existed. Returns fewer than `replication`
/// nodes when not enough are alive, and an empty vec when none are.
pub fn choose_replicas(
    topology: &RackTopology,
    alive: &[bool],
    replication: usize,
    cursor: usize,
) -> Vec<usize> {
    let n = alive.len();
    if n == 0 || replication == 0 {
        return Vec::new();
    }
    let scan: Vec<usize> = (0..n)
        .map(|off| (cursor + off) % n)
        .filter(|&c| alive[c])
        .collect();
    let Some(&first) = scan.first() else {
        return Vec::new();
    };
    let mut chosen = vec![first];
    if chosen.len() < replication {
        // Rotate the pick WITHIN the remote racks by cursor, not just the
        // scan start: always taking the first remote-rack node in scan
        // order would funnel every second replica onto one node per rack.
        let remote: Vec<usize> = scan
            .iter()
            .copied()
            .filter(|&c| !chosen.contains(&c) && !topology.same_rack(c, first))
            .collect();
        if !remote.is_empty() {
            chosen.push(remote[cursor % remote.len()]);
        }
    }
    if chosen.len() >= 2 && chosen.len() < replication {
        let second = chosen[1];
        if let Some(&c) = scan
            .iter()
            .find(|&&c| !chosen.contains(&c) && topology.same_rack(c, second))
        {
            chosen.push(c);
        }
    }
    for &c in &scan {
        if chosen.len() >= replication {
            break;
        }
        if !chosen.contains(&c) {
            chosen.push(c);
        }
    }
    chosen
}

/// NameNode state (wrapped in a lock by [`super::Dfs`]).
#[derive(Debug, Default)]
pub struct NameNode {
    files: HashMap<String, FileMeta>,
    locations: HashMap<BlockId, Vec<usize>>, // block -> datanode ids
    next_block: u64,
}

impl NameNode {
    /// Allocate a fresh block id.
    pub fn alloc_block(&mut self) -> BlockId {
        let id = BlockId(self.next_block);
        self.next_block += 1;
        id
    }

    /// Record a new file (fails if it already exists).
    pub fn create_file(&mut self, path: &str, meta: FileMeta) -> Result<()> {
        if self.files.contains_key(path) {
            return Err(Error::Dfs(format!("file exists: {path}")));
        }
        self.files.insert(path.to_string(), meta);
        Ok(())
    }

    /// Replace a file's metadata (for overwrite semantics).
    pub fn put_file(&mut self, path: &str, meta: FileMeta) {
        self.files.insert(path.to_string(), meta);
    }

    /// Look up a file.
    pub fn get_file(&self, path: &str) -> Result<&FileMeta> {
        self.files
            .get(path)
            .ok_or_else(|| Error::Dfs(format!("no such file: {path}")))
    }

    /// Remove a file, returning its blocks for garbage collection.
    pub fn remove_file(&mut self, path: &str) -> Result<FileMeta> {
        self.files
            .remove(path)
            .ok_or_else(|| Error::Dfs(format!("no such file: {path}")))
    }

    /// Does the file exist?
    pub fn exists(&self, path: &str) -> bool {
        self.files.contains_key(path)
    }

    /// All file paths (sorted).
    pub fn list(&self) -> Vec<String> {
        let mut v: Vec<String> = self.files.keys().cloned().collect();
        v.sort();
        v
    }

    /// Record replica locations for a block.
    pub fn set_locations(&mut self, block: BlockId, nodes: Vec<usize>) {
        self.locations.insert(block, nodes);
    }

    /// Replica locations for a block.
    pub fn locations(&self, block: BlockId) -> Result<&[usize]> {
        self.locations
            .get(&block)
            .map(|v| v.as_slice())
            .ok_or_else(|| Error::Dfs(format!("no locations for {block:?}")))
    }

    /// Drop a datanode from every block's location list; returns the blocks
    /// whose replica count fell below `replication` (need re-replication).
    pub fn drop_node(&mut self, node: usize, replication: usize) -> Vec<BlockId> {
        let mut under = Vec::new();
        for (block, nodes) in self.locations.iter_mut() {
            nodes.retain(|&n| n != node);
            if nodes.len() < replication {
                under.push(*block);
            }
        }
        under
    }

    /// Forget a block entirely.
    pub fn forget_block(&mut self, block: BlockId) {
        self.locations.remove(&block);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn create_get_remove() {
        let mut nn = NameNode::default();
        let b = nn.alloc_block();
        nn.create_file("/a", FileMeta { blocks: vec![b], len: 10 }).unwrap();
        assert!(nn.exists("/a"));
        assert!(nn.create_file("/a", FileMeta { blocks: vec![], len: 0 }).is_err());
        assert_eq!(nn.get_file("/a").unwrap().len, 10);
        nn.remove_file("/a").unwrap();
        assert!(!nn.exists("/a"));
        assert!(nn.get_file("/a").is_err());
    }

    #[test]
    fn block_ids_unique() {
        let mut nn = NameNode::default();
        let a = nn.alloc_block();
        let b = nn.alloc_block();
        assert_ne!(a, b);
    }

    #[test]
    fn drop_node_reports_under_replicated() {
        let mut nn = NameNode::default();
        let b1 = nn.alloc_block();
        let b2 = nn.alloc_block();
        nn.set_locations(b1, vec![0, 1]);
        nn.set_locations(b2, vec![1, 2]);
        let under = nn.drop_node(0, 2);
        assert_eq!(under, vec![b1]);
        assert_eq!(nn.locations(b1).unwrap(), &[1]);
        assert_eq!(nn.locations(b2).unwrap(), &[1, 2]);
    }

    #[test]
    fn rack_aware_chooser_spans_two_racks() {
        let topo = RackTopology::uniform(4, 2); // racks [0,0,1,1]
        let alive = [true; 4];
        for cursor in 0..4 {
            let chosen = choose_replicas(&topo, &alive, 2, cursor);
            assert_eq!(chosen.len(), 2, "cursor {cursor}");
            assert!(
                !topo.same_rack(chosen[0], chosen[1]),
                "cursor {cursor}: {chosen:?} share a rack"
            );
        }
    }

    #[test]
    fn second_replicas_spread_over_the_remote_rack() {
        // Without cursor rotation inside the remote rack, every rack-0
        // writer would pin its second replica on one node (a hotspot).
        let topo = RackTopology::uniform(4, 2);
        let alive = [true; 4];
        let seconds: std::collections::HashSet<usize> =
            (0..8).map(|cursor| choose_replicas(&topo, &alive, 2, cursor)[1]).collect();
        assert_eq!(
            seconds.len(),
            4,
            "every node should receive second replicas: {seconds:?}"
        );
    }

    #[test]
    fn third_replica_joins_the_remote_rack() {
        let topo = RackTopology::uniform(6, 2); // racks [0,0,0,1,1,1]
        let alive = [true; 6];
        let chosen = choose_replicas(&topo, &alive, 3, 0);
        assert_eq!(chosen.len(), 3);
        assert!(!topo.same_rack(chosen[0], chosen[1]));
        assert!(
            topo.same_rack(chosen[1], chosen[2]),
            "HDFS keeps two replicas in the remote rack: {chosen:?}"
        );
    }

    #[test]
    fn single_rack_degrades_to_round_robin() {
        let topo = RackTopology::single(4);
        let alive = [true; 4];
        assert_eq!(choose_replicas(&topo, &alive, 2, 1), vec![1, 2]);
        assert_eq!(choose_replicas(&topo, &alive, 1, 3), vec![3]);
    }

    #[test]
    fn chooser_skips_dead_nodes() {
        let topo = RackTopology::uniform(4, 2);
        let alive = [false, true, true, true];
        let chosen = choose_replicas(&topo, &alive, 2, 0);
        assert_eq!(chosen.len(), 2);
        assert!(!chosen.contains(&0));
        assert!(!topo.same_rack(chosen[0], chosen[1]));
        assert!(choose_replicas(&topo, &[false; 4], 2, 0).is_empty());
    }

    #[test]
    fn list_sorted() {
        let mut nn = NameNode::default();
        nn.create_file("/b", FileMeta { blocks: vec![], len: 0 }).unwrap();
        nn.create_file("/a", FileMeta { blocks: vec![], len: 0 }).unwrap();
        assert_eq!(nn.list(), vec!["/a".to_string(), "/b".to_string()]);
    }
}
