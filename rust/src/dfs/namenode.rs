//! NameNode: file → blocks and block → replica-location metadata.
//!
//! Mirrors HDFS's single-master design (paper §2.1: the Master "only
//! store[s] metadata file blocks and … control[s] the distribution").

use std::collections::HashMap;

use crate::error::{Error, Result};

use super::block::{BlockId, FileMeta};

/// NameNode state (wrapped in a lock by [`super::Dfs`]).
#[derive(Debug, Default)]
pub struct NameNode {
    files: HashMap<String, FileMeta>,
    locations: HashMap<BlockId, Vec<usize>>, // block -> datanode ids
    next_block: u64,
}

impl NameNode {
    /// Allocate a fresh block id.
    pub fn alloc_block(&mut self) -> BlockId {
        let id = BlockId(self.next_block);
        self.next_block += 1;
        id
    }

    /// Record a new file (fails if it already exists).
    pub fn create_file(&mut self, path: &str, meta: FileMeta) -> Result<()> {
        if self.files.contains_key(path) {
            return Err(Error::Dfs(format!("file exists: {path}")));
        }
        self.files.insert(path.to_string(), meta);
        Ok(())
    }

    /// Replace a file's metadata (for overwrite semantics).
    pub fn put_file(&mut self, path: &str, meta: FileMeta) {
        self.files.insert(path.to_string(), meta);
    }

    /// Look up a file.
    pub fn get_file(&self, path: &str) -> Result<&FileMeta> {
        self.files
            .get(path)
            .ok_or_else(|| Error::Dfs(format!("no such file: {path}")))
    }

    /// Remove a file, returning its blocks for garbage collection.
    pub fn remove_file(&mut self, path: &str) -> Result<FileMeta> {
        self.files
            .remove(path)
            .ok_or_else(|| Error::Dfs(format!("no such file: {path}")))
    }

    /// Does the file exist?
    pub fn exists(&self, path: &str) -> bool {
        self.files.contains_key(path)
    }

    /// All file paths (sorted).
    pub fn list(&self) -> Vec<String> {
        let mut v: Vec<String> = self.files.keys().cloned().collect();
        v.sort();
        v
    }

    /// Record replica locations for a block.
    pub fn set_locations(&mut self, block: BlockId, nodes: Vec<usize>) {
        self.locations.insert(block, nodes);
    }

    /// Replica locations for a block.
    pub fn locations(&self, block: BlockId) -> Result<&[usize]> {
        self.locations
            .get(&block)
            .map(|v| v.as_slice())
            .ok_or_else(|| Error::Dfs(format!("no locations for {block:?}")))
    }

    /// Drop a datanode from every block's location list; returns the blocks
    /// whose replica count fell below `replication` (need re-replication).
    pub fn drop_node(&mut self, node: usize, replication: usize) -> Vec<BlockId> {
        let mut under = Vec::new();
        for (block, nodes) in self.locations.iter_mut() {
            nodes.retain(|&n| n != node);
            if nodes.len() < replication {
                under.push(*block);
            }
        }
        under
    }

    /// Forget a block entirely.
    pub fn forget_block(&mut self, block: BlockId) {
        self.locations.remove(&block);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn create_get_remove() {
        let mut nn = NameNode::default();
        let b = nn.alloc_block();
        nn.create_file("/a", FileMeta { blocks: vec![b], len: 10 }).unwrap();
        assert!(nn.exists("/a"));
        assert!(nn.create_file("/a", FileMeta { blocks: vec![], len: 0 }).is_err());
        assert_eq!(nn.get_file("/a").unwrap().len, 10);
        nn.remove_file("/a").unwrap();
        assert!(!nn.exists("/a"));
        assert!(nn.get_file("/a").is_err());
    }

    #[test]
    fn block_ids_unique() {
        let mut nn = NameNode::default();
        let a = nn.alloc_block();
        let b = nn.alloc_block();
        assert_ne!(a, b);
    }

    #[test]
    fn drop_node_reports_under_replicated() {
        let mut nn = NameNode::default();
        let b1 = nn.alloc_block();
        let b2 = nn.alloc_block();
        nn.set_locations(b1, vec![0, 1]);
        nn.set_locations(b2, vec![1, 2]);
        let under = nn.drop_node(0, 2);
        assert_eq!(under, vec![b1]);
        assert_eq!(nn.locations(b1).unwrap(), &[1]);
        assert_eq!(nn.locations(b2).unwrap(), &[1, 2]);
    }

    #[test]
    fn list_sorted() {
        let mut nn = NameNode::default();
        nn.create_file("/b", FileMeta { blocks: vec![], len: 0 }).unwrap();
        nn.create_file("/a", FileMeta { blocks: vec![], len: 0 }).unwrap();
        assert_eq!(nn.list(), vec!["/a".to_string(), "/b".to_string()]);
    }
}
