//! The paper's Fig. 4 topology text format.
//!
//! A graph file is lines of whitespace-separated tokens:
//!
//! ```text
//! t # 0          <- graph header (id after '#')
//! v 0 1          <- vertex: id, label
//! v 1 1
//! e 0 1 2        <- edge: src, dst, label/weight
//! ```
//!
//! The paper's dataset: "a total of 10029 points and 21054 side" in this
//! format. We parse and write it exactly, treating the edge label as an
//! integer weight.

use crate::error::{Error, Result};

/// One vertex: id and integer label.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Vertex {
    /// Vertex id (dense, 0-based in well-formed files).
    pub id: u64,
    /// Label (cluster id for planted data, arbitrary otherwise).
    pub label: i64,
}

/// One undirected edge: endpoints and integer label (used as weight).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Edge {
    /// Source vertex id.
    pub src: u64,
    /// Destination vertex id.
    pub dst: u64,
    /// Edge label / weight.
    pub label: i64,
}

/// A parsed topology file.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Topology {
    /// Graph id (the paper's `t # 0` header).
    pub graph_id: u64,
    /// Vertices in file order.
    pub vertices: Vec<Vertex>,
    /// Edges in file order.
    pub edges: Vec<Edge>,
}

impl Topology {
    /// Parse the Fig. 4 text format.
    pub fn parse(text: &str) -> Result<Self> {
        let mut topo = Topology::default();
        let mut seen_header = false;
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('%') {
                continue;
            }
            let toks: Vec<&str> = line.split_whitespace().collect();
            let ctx = |msg: &str| {
                Error::Data(format!("topology line {}: {msg}: {line:?}", lineno + 1))
            };
            match toks[0] {
                "t" => {
                    // "t # <id>" (gSpan style) or "t <id>".
                    let id_tok = if toks.len() >= 3 && toks[1] == "#" {
                        toks[2]
                    } else if toks.len() >= 2 {
                        toks[1]
                    } else {
                        return Err(ctx("malformed graph header"));
                    };
                    topo.graph_id = id_tok
                        .parse()
                        .map_err(|_| ctx("bad graph id"))?;
                    seen_header = true;
                }
                "v" => {
                    if toks.len() < 3 {
                        return Err(ctx("vertex needs id and label"));
                    }
                    topo.vertices.push(Vertex {
                        id: toks[1].parse().map_err(|_| ctx("bad vertex id"))?,
                        label: toks[2].parse().map_err(|_| ctx("bad vertex label"))?,
                    });
                }
                "e" => {
                    if toks.len() < 4 {
                        return Err(ctx("edge needs src, dst and label"));
                    }
                    topo.edges.push(Edge {
                        src: toks[1].parse().map_err(|_| ctx("bad edge src"))?,
                        dst: toks[2].parse().map_err(|_| ctx("bad edge dst"))?,
                        label: toks[3].parse().map_err(|_| ctx("bad edge label"))?,
                    });
                }
                other => {
                    return Err(ctx(&format!("unknown record type {other:?}")));
                }
            }
        }
        if !seen_header && (!topo.vertices.is_empty() || !topo.edges.is_empty()) {
            return Err(Error::Data("topology: missing 't' header".into()));
        }
        topo.validate()?;
        Ok(topo)
    }

    /// Serialize back to the Fig. 4 text format.
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("t # {}\n", self.graph_id));
        for v in &self.vertices {
            out.push_str(&format!("v {} {}\n", v.id, v.label));
        }
        for e in &self.edges {
            out.push_str(&format!("e {} {} {}\n", e.src, e.dst, e.label));
        }
        out
    }

    /// Check edges reference declared vertices.
    pub fn validate(&self) -> Result<()> {
        let ids: std::collections::HashSet<u64> =
            self.vertices.iter().map(|v| v.id).collect();
        if ids.len() != self.vertices.len() {
            return Err(Error::Data("topology: duplicate vertex id".into()));
        }
        for e in &self.edges {
            if !ids.contains(&e.src) || !ids.contains(&e.dst) {
                return Err(Error::Data(format!(
                    "topology: edge ({}, {}) references undeclared vertex",
                    e.src, e.dst
                )));
            }
        }
        Ok(())
    }

    /// Number of vertices.
    pub fn num_vertices(&self) -> usize {
        self.vertices.len()
    }

    /// Number of edges.
    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// Ground-truth labels by dense vertex id (for planted graphs).
    pub fn labels(&self) -> Vec<usize> {
        let mut sorted = self.vertices.clone();
        sorted.sort_by_key(|v| v.id);
        sorted.iter().map(|v| v.label.max(0) as usize).collect()
    }

    /// Symmetric adjacency triplets (both directions per undirected edge).
    pub fn adjacency_triplets(&self) -> Vec<(usize, usize, f64)> {
        let mut t = Vec::with_capacity(self.edges.len() * 2);
        for e in &self.edges {
            let w = e.label.max(1) as f64;
            t.push((e.src as usize, e.dst as usize, w));
            if e.src != e.dst {
                t.push((e.dst as usize, e.src as usize, w));
            }
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "t # 0\nv 0 1\nv 1 1\nv 2 0\ne 0 1 2\ne 1 2 1\n";

    #[test]
    fn parse_fig4_sample() {
        let t = Topology::parse(SAMPLE).unwrap();
        assert_eq!(t.graph_id, 0);
        assert_eq!(t.num_vertices(), 3);
        assert_eq!(t.num_edges(), 2);
        assert_eq!(t.vertices[0], Vertex { id: 0, label: 1 });
        assert_eq!(t.edges[1], Edge { src: 1, dst: 2, label: 1 });
    }

    #[test]
    fn roundtrip() {
        let t = Topology::parse(SAMPLE).unwrap();
        let t2 = Topology::parse(&t.to_text()).unwrap();
        assert_eq!(t, t2);
    }

    #[test]
    fn tolerates_blank_lines_comments_and_extra_spaces() {
        let text = "t # 7\n\n% comment\nv  0   1\nv 1 2\ne 0  1  3\n";
        let t = Topology::parse(text).unwrap();
        assert_eq!(t.graph_id, 7);
        assert_eq!(t.num_edges(), 1);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Topology::parse("v 0 1\n").is_err(), "missing header");
        assert!(Topology::parse("t # 0\nv 0\n").is_err(), "vertex arity");
        assert!(Topology::parse("t # 0\ne 0 1\n").is_err(), "edge arity");
        assert!(Topology::parse("t # 0\nx 1 2 3\n").is_err(), "unknown type");
        assert!(Topology::parse("t # 0\nv 0 1\nv 0 2\n").is_err(), "dup vertex");
        assert!(
            Topology::parse("t # 0\nv 0 1\ne 0 9 1\n").is_err(),
            "dangling edge"
        );
    }

    #[test]
    fn adjacency_is_symmetric() {
        let t = Topology::parse(SAMPLE).unwrap();
        let trips = t.adjacency_triplets();
        assert_eq!(trips.len(), 4);
        assert!(trips.contains(&(0, 1, 2.0)));
        assert!(trips.contains(&(1, 0, 2.0)));
    }

    #[test]
    fn self_loop_emitted_once() {
        let t = Topology::parse("t # 0\nv 0 1\ne 0 0 5\n").unwrap();
        assert_eq!(t.adjacency_triplets(), vec![(0, 0, 5.0)]);
    }
}
