//! Datasets: the paper's Fig. 4 topology format and synthetic generators.

pub mod generators;
pub mod topology;

pub use generators::{
    gaussian_blobs, pad_points_f32, paper_scale_graph, planted_graph, two_moons,
    two_rings, PointSet,
};
pub use topology::{Edge, Topology, Vertex};
