//! Synthetic data generators: planted-partition graphs (the paper-scale
//! dataset substitute), Gaussian blobs, concentric rings and two moons.
//!
//! The paper's 10,029-vertex / 21,054-edge dataset is unnamed and not
//! public; [`planted_graph`] generates a graph with the same vertex/edge
//! counts and a planted k-way community structure, so clustering quality is
//! measurable against ground truth (DESIGN.md §2 substitution table).

use crate::util::Xoshiro256;

use super::topology::{Edge, Topology, Vertex};

/// A labelled point dataset.
#[derive(Debug, Clone)]
pub struct PointSet {
    /// Row-major points, `n × dim`.
    pub points: Vec<Vec<f64>>,
    /// Ground-truth cluster per point.
    pub labels: Vec<usize>,
    /// Feature dimension.
    pub dim: usize,
}

impl PointSet {
    /// Number of points.
    pub fn len(&self) -> usize {
        self.points.len()
    }

    /// Is the set empty?
    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }
}

/// Isotropic Gaussian blobs: `k` clusters of ~n/k points in `dim` dims.
///
/// Centers sit on a scaled simplex (distance `separation` apart), points are
/// N(center, sigma^2 I).
pub fn gaussian_blobs(
    n: usize,
    k: usize,
    dim: usize,
    sigma: f64,
    separation: f64,
    seed: u64,
) -> PointSet {
    assert!(k >= 1 && dim >= 1 && n >= k);
    let mut rng = Xoshiro256::new(seed);
    // Random well-separated centers.
    let mut centers = Vec::with_capacity(k);
    for c in 0..k {
        let mut center = vec![0.0; dim];
        // Deterministic placement: axis c (mod dim) offset + jitter.
        center[c % dim] = separation * (1.0 + (c / dim) as f64);
        for x in center.iter_mut() {
            *x += rng.next_gaussian() * 0.05 * separation;
        }
        centers.push(center);
    }
    let mut points = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let c = i % k;
        let mut p = centers[c].clone();
        for x in p.iter_mut() {
            *x += rng.next_gaussian() * sigma;
        }
        points.push(p);
        labels.push(c);
    }
    PointSet { points, labels, dim }
}

/// Two concentric rings in 2-D — the "arbitrary shape" case where k-means
/// fails and spectral clustering shines (paper §3.1).
pub fn two_rings(n: usize, r_inner: f64, r_outer: f64, noise: f64, seed: u64) -> PointSet {
    let mut rng = Xoshiro256::new(seed);
    let mut points = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let ring = i % 2;
        let r = if ring == 0 { r_inner } else { r_outer };
        let theta = rng.next_f64() * std::f64::consts::TAU;
        points.push(vec![
            r * theta.cos() + rng.next_gaussian() * noise,
            r * theta.sin() + rng.next_gaussian() * noise,
        ]);
        labels.push(ring);
    }
    PointSet { points, labels, dim: 2 }
}

/// Two interleaved half-moons in 2-D.
pub fn two_moons(n: usize, noise: f64, seed: u64) -> PointSet {
    let mut rng = Xoshiro256::new(seed);
    let mut points = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let moon = i % 2;
        let t = rng.next_f64() * std::f64::consts::PI;
        let (x, y) = if moon == 0 {
            (t.cos(), t.sin())
        } else {
            (1.0 - t.cos(), 0.5 - t.sin())
        };
        points.push(vec![
            x + rng.next_gaussian() * noise,
            y + rng.next_gaussian() * noise,
        ]);
        labels.push(moon);
    }
    PointSet { points, labels, dim: 2 }
}

/// Planted-partition graph with exactly `n` vertices and (approximately,
/// then trimmed/padded to exactly) `edges` edges over `k` communities.
///
/// Intra-community edges are sampled with probability proportional to
/// `p_in`, inter-community with `p_out` (p_in >> p_out). Vertex labels carry
/// the planted community; edge labels are 1 (the paper's Fig. 4 uses small
/// integer labels).
pub fn planted_graph(n: usize, edges: usize, k: usize, p_out_frac: f64, seed: u64) -> Topology {
    assert!(k >= 1 && n >= k);
    let mut rng = Xoshiro256::new(seed);
    let mut topo = Topology {
        graph_id: 0,
        vertices: (0..n as u64)
            .map(|id| Vertex { id, label: (id as usize % k) as i64 })
            .collect(),
        edges: Vec::with_capacity(edges),
    };
    let mut seen = std::collections::HashSet::with_capacity(edges * 2);
    let n_inter = (edges as f64 * p_out_frac).round() as usize;
    let n_intra = edges - n_inter;

    // Intra-community edges.
    let mut tries = 0;
    while topo.edges.len() < n_intra && tries < edges * 50 {
        tries += 1;
        let c = rng.next_index(k);
        // Two distinct members of community c (ids ≡ c mod k).
        let size = (n - c + k - 1) / k;
        if size < 2 {
            continue;
        }
        let a = (rng.next_index(size) * k + c) as u64;
        let b = (rng.next_index(size) * k + c) as u64;
        if a == b {
            continue;
        }
        let key = (a.min(b), a.max(b));
        if seen.insert(key) {
            topo.edges.push(Edge { src: key.0, dst: key.1, label: 1 });
        }
    }
    // Inter-community edges.
    tries = 0;
    while topo.edges.len() < edges && tries < edges * 50 {
        tries += 1;
        let a = rng.next_index(n) as u64;
        let b = rng.next_index(n) as u64;
        if a == b || (a as usize % k) == (b as usize % k) {
            continue;
        }
        let key = (a.min(b), a.max(b));
        if seen.insert(key) {
            topo.edges.push(Edge { src: key.0, dst: key.1, label: 1 });
        }
    }
    topo
}

/// The paper-scale dataset: 10,029 vertices, 21,054 edges (Ch. 5.1).
pub fn paper_scale_graph(k: usize, seed: u64) -> Topology {
    planted_graph(10_029, 21_054, k, 0.05, seed)
}

/// Pad a point set's coordinates into fixed-width f32 rows (for the XLA
/// kernels' fixed tile geometry). Returns (row-major data, padded dim).
pub fn pad_points_f32(points: &[Vec<f64>], pad_dim: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(points.len() * pad_dim);
    for p in points {
        for j in 0..pad_dim {
            out.push(p.get(j).copied().unwrap_or(0.0) as f32);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blobs_shape_and_determinism() {
        let a = gaussian_blobs(100, 4, 3, 0.1, 10.0, 7);
        assert_eq!(a.len(), 100);
        assert_eq!(a.dim, 3);
        assert_eq!(a.labels.iter().filter(|&&l| l == 0).count(), 25);
        let b = gaussian_blobs(100, 4, 3, 0.1, 10.0, 7);
        assert_eq!(a.points, b.points);
        let c = gaussian_blobs(100, 4, 3, 0.1, 10.0, 8);
        assert_ne!(a.points, c.points);
    }

    #[test]
    fn blobs_are_separated() {
        let ps = gaussian_blobs(200, 2, 2, 0.5, 20.0, 1);
        // Mean intra-cluster distance << inter-cluster distance.
        let c0: Vec<&Vec<f64>> = ps
            .points
            .iter()
            .zip(&ps.labels)
            .filter(|(_, &l)| l == 0)
            .map(|(p, _)| p)
            .collect();
        let c1: Vec<&Vec<f64>> = ps
            .points
            .iter()
            .zip(&ps.labels)
            .filter(|(_, &l)| l == 1)
            .map(|(p, _)| p)
            .collect();
        let centroid = |pts: &[&Vec<f64>]| -> Vec<f64> {
            let mut c = vec![0.0; 2];
            for p in pts {
                c[0] += p[0];
                c[1] += p[1];
            }
            c.iter().map(|x| x / pts.len() as f64).collect()
        };
        let d = crate::linalg::vector::sq_dist(&centroid(&c0), &centroid(&c1)).sqrt();
        assert!(d > 10.0, "centroids too close: {d}");
    }

    #[test]
    fn rings_radii() {
        let ps = two_rings(400, 1.0, 5.0, 0.0, 3);
        for (p, &l) in ps.points.iter().zip(&ps.labels) {
            let r = (p[0] * p[0] + p[1] * p[1]).sqrt();
            let expect = if l == 0 { 1.0 } else { 5.0 };
            assert!((r - expect).abs() < 1e-9);
        }
    }

    #[test]
    fn moons_two_classes() {
        let ps = two_moons(100, 0.05, 9);
        assert_eq!(ps.len(), 100);
        assert_eq!(ps.labels.iter().filter(|&&l| l == 1).count(), 50);
    }

    #[test]
    fn planted_graph_exact_counts() {
        let t = planted_graph(500, 1000, 4, 0.05, 11);
        assert_eq!(t.num_vertices(), 500);
        assert_eq!(t.num_edges(), 1000);
        t.validate().unwrap();
        // No duplicate undirected edges.
        let set: std::collections::HashSet<(u64, u64)> = t
            .edges
            .iter()
            .map(|e| (e.src.min(e.dst), e.src.max(e.dst)))
            .collect();
        assert_eq!(set.len(), 1000);
    }

    #[test]
    fn planted_graph_mostly_intra() {
        let t = planted_graph(500, 1000, 4, 0.05, 13);
        let intra = t
            .edges
            .iter()
            .filter(|e| e.src % 4 == e.dst % 4)
            .count();
        assert!(intra as f64 > 0.9 * 1000.0, "intra edges: {intra}");
    }

    #[test]
    fn paper_scale_counts() {
        let t = paper_scale_graph(4, 1);
        assert_eq!(t.num_vertices(), 10_029);
        assert_eq!(t.num_edges(), 21_054);
    }

    #[test]
    fn pad_points_zero_fills() {
        let pts = vec![vec![1.0, 2.0], vec![3.0, 4.0]];
        let padded = pad_points_f32(&pts, 4);
        assert_eq!(padded, vec![1.0, 2.0, 0.0, 0.0, 3.0, 4.0, 0.0, 0.0]);
    }
}
