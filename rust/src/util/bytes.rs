//! Typed key/value byte codecs for the MapReduce engine and table store.
//!
//! Hadoop's Writables equivalent: fixed-width big-endian encodings so that
//! byte-lexicographic order equals numeric order for unsigned keys — the
//! property the shuffle sort and the HBase-style row-key scans rely on.

/// Encode a u64 big-endian (order-preserving for row keys).
pub fn encode_u64(v: u64) -> [u8; 8] {
    v.to_be_bytes()
}

/// Decode a big-endian u64.
pub fn decode_u64(b: &[u8]) -> u64 {
    let mut a = [0u8; 8];
    a.copy_from_slice(&b[..8]);
    u64::from_be_bytes(a)
}

/// Encode a u32 big-endian.
pub fn encode_u32(v: u32) -> [u8; 4] {
    v.to_be_bytes()
}

/// Decode a big-endian u32.
pub fn decode_u32(b: &[u8]) -> u32 {
    let mut a = [0u8; 4];
    a.copy_from_slice(&b[..4]);
    u32::from_be_bytes(a)
}

/// Encode an f64 (not order-preserving; payload only).
pub fn encode_f64(v: f64) -> [u8; 8] {
    v.to_be_bytes()
}

/// Decode an f64.
pub fn decode_f64(b: &[u8]) -> f64 {
    let mut a = [0u8; 8];
    a.copy_from_slice(&b[..8]);
    f64::from_be_bytes(a)
}

/// Encode a slice of f64 values (length-prefixed).
pub fn encode_f64_vec(v: &[f64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + v.len() * 8);
    out.extend_from_slice(&encode_u32(v.len() as u32));
    for &x in v {
        out.extend_from_slice(&encode_f64(x));
    }
    out
}

/// Decode a length-prefixed f64 vector; returns (values, bytes consumed).
pub fn decode_f64_vec(b: &[u8]) -> (Vec<f64>, usize) {
    let n = decode_u32(b) as usize;
    let mut out = Vec::with_capacity(n);
    let mut off = 4;
    for _ in 0..n {
        out.push(decode_f64(&b[off..]));
        off += 8;
    }
    (out, off)
}

/// Encode sparse (index, value) pairs — one table row of the matrix L.
pub fn encode_sparse_row(entries: &[(u32, f64)]) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + entries.len() * 12);
    out.extend_from_slice(&encode_u32(entries.len() as u32));
    for &(j, v) in entries {
        out.extend_from_slice(&encode_u32(j));
        out.extend_from_slice(&encode_f64(v));
    }
    out
}

/// Decode sparse (index, value) pairs.
pub fn decode_sparse_row(b: &[u8]) -> Vec<(u32, f64)> {
    let n = decode_u32(b) as usize;
    let mut out = Vec::with_capacity(n);
    let mut off = 4;
    for _ in 0..n {
        let j = decode_u32(&b[off..]);
        let v = decode_f64(&b[off + 4..]);
        out.push((j, v));
        off += 12;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u64_roundtrip_and_order() {
        for v in [0u64, 1, 255, 256, u32::MAX as u64, u64::MAX] {
            assert_eq!(decode_u64(&encode_u64(v)), v);
        }
        // Byte-lexicographic order == numeric order.
        assert!(encode_u64(5).as_slice() < encode_u64(6).as_slice());
        assert!(encode_u64(255).as_slice() < encode_u64(256).as_slice());
        assert!(encode_u64(u32::MAX as u64).as_slice() < encode_u64(u64::MAX).as_slice());
    }

    #[test]
    fn f64_roundtrip() {
        for v in [0.0, -1.5, std::f64::consts::PI, f64::MIN_POSITIVE, 1e300] {
            assert_eq!(decode_f64(&encode_f64(v)), v);
        }
    }

    #[test]
    fn f64_vec_roundtrip() {
        let v = vec![1.0, -2.5, 0.0, 1e-10];
        let enc = encode_f64_vec(&v);
        let (dec, used) = decode_f64_vec(&enc);
        assert_eq!(dec, v);
        assert_eq!(used, enc.len());
    }

    #[test]
    fn sparse_row_roundtrip() {
        let row = vec![(0u32, 0.5), (17, -3.25), (9999, 1e-8)];
        assert_eq!(decode_sparse_row(&encode_sparse_row(&row)), row);
        assert_eq!(decode_sparse_row(&encode_sparse_row(&[])), vec![]);
    }
}
