//! Deterministic PRNG substrate (no `rand` crate in the offline vendor set).
//!
//! [`SplitMix64`] seeds [`Xoshiro256`] (xoshiro256**), the same construction
//! the reference implementations by Blackman & Vigna recommend. All data
//! generation, k-means initialization and property-test case generation in
//! the crate flow through this module, so every run is reproducible from a
//! single `u64` seed.

/// SplitMix64: tiny, full-period 2^64 generator used to expand seeds.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256**: fast, high-quality general-purpose PRNG.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 (avoids the all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Next 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        // 53 top bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire's method).
    pub fn next_bounded(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "bound must be positive");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [0, bound).
    pub fn next_index(&mut self, bound: usize) -> usize {
        self.next_bounded(bound as u64) as usize
    }

    /// Standard normal via Box–Muller (cached second value dropped: simple).
    pub fn next_gaussian(&mut self) -> f64 {
        // Rejection-free polar-less Box-Muller; u1 in (0,1].
        let u1 = 1.0 - self.next_f64();
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, data: &mut [T]) {
        for i in (1..data.len()).rev() {
            let j = self.next_index(i + 1);
            data.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (Floyd's algorithm).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.next_index(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference sequence for seed 1234567 (from the public-domain C code).
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism.
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(a, sm2.next_u64());
        assert_eq!(b, sm2.next_u64());
    }

    #[test]
    fn xoshiro_deterministic_and_distinct_by_seed() {
        let mut r1 = Xoshiro256::new(42);
        let mut r2 = Xoshiro256::new(42);
        let mut r3 = Xoshiro256::new(43);
        let v1: Vec<u64> = (0..8).map(|_| r1.next_u64()).collect();
        let v2: Vec<u64> = (0..8).map(|_| r2.next_u64()).collect();
        let v3: Vec<u64> = (0..8).map(|_| r3.next_u64()).collect();
        assert_eq!(v1, v2);
        assert_ne!(v1, v3);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x), "{x} out of [0,1)");
        }
    }

    #[test]
    fn bounded_is_unbiased_enough_and_in_range() {
        let mut r = Xoshiro256::new(99);
        let bound = 10u64;
        let mut counts = [0usize; 10];
        let n = 100_000;
        for _ in 0..n {
            let x = r.next_bounded(bound);
            assert!(x < bound);
            counts[x as usize] += 1;
        }
        // Each bucket should be within 10% of n/10.
        for (i, &c) in counts.iter().enumerate() {
            let expect = n / 10;
            assert!(
                (c as i64 - expect as i64).unsigned_abs() < (expect / 10) as u64,
                "bucket {i}: {c} vs {expect}"
            );
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Xoshiro256::new(5);
        let n = 200_000;
        let samples: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::new(3);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct_in_range() {
        let mut r = Xoshiro256::new(11);
        let ks = r.sample_indices(50, 10);
        assert_eq!(ks.len(), 10);
        let set: std::collections::HashSet<_> = ks.iter().collect();
        assert_eq!(set.len(), 10);
        assert!(ks.iter().all(|&i| i < 50));
    }

    #[test]
    #[should_panic]
    fn sample_more_than_population_panics() {
        let mut r = Xoshiro256::new(1);
        let _ = r.sample_indices(3, 4);
    }
}
