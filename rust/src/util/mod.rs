//! Small shared substrates: PRNG, byte codecs, formatting.
//!
//! These exist because the offline vendor set has no `rand`, `serde` or
//! similar crates — see DESIGN.md §4 inventory items 13–16.

pub mod bytes;
pub mod fmt;
pub mod rng;

pub use rng::{SplitMix64, Xoshiro256};
