//! Formatting helpers: the paper's `h:mm:ss` time format and byte counts.

use std::time::Duration;

/// Format a duration like the paper's Table 5-1 (`1:41:46`).
pub fn hms(d: Duration) -> String {
    let total = d.as_secs();
    format!("{}:{:02}:{:02}", total / 3600, (total % 3600) / 60, total % 60)
}

/// Format a duration with sub-second precision for bench output.
pub fn human_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else if s < 120.0 {
        format!("{:.2}s", s)
    } else {
        hms(d)
    }
}

/// Format a byte count (1024-based).
pub fn human_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b}B")
    } else {
        format!("{v:.1}{}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hms_matches_paper_style() {
        assert_eq!(hms(Duration::from_secs(1 * 3600 + 41 * 60 + 46)), "1:41:46");
        assert_eq!(hms(Duration::from_secs(0)), "0:00:00");
        assert_eq!(hms(Duration::from_secs(59)), "0:00:59");
        assert_eq!(hms(Duration::from_secs(3600)), "1:00:00");
    }

    #[test]
    fn human_duration_scales() {
        assert!(human_duration(Duration::from_nanos(50)).ends_with("ns"));
        assert!(human_duration(Duration::from_micros(50)).ends_with("us"));
        assert!(human_duration(Duration::from_millis(50)).ends_with("ms"));
        assert!(human_duration(Duration::from_secs(5)).ends_with('s'));
        assert_eq!(human_duration(Duration::from_secs(7200)), "2:00:00");
    }

    #[test]
    fn human_bytes_scales() {
        assert_eq!(human_bytes(512), "512B");
        assert_eq!(human_bytes(2048), "2.0KiB");
        assert_eq!(human_bytes(1024 * 1024 * 3 / 2), "1.5MiB");
    }
}
