//! Single-machine serving oracle: the reference implementation of Nyström
//! assignment the distributed pipeline ([`super::job`]) must match **byte
//! for byte**. Both paths call the same [`extend_point`] /
//! [`nearest_centroid`] / [`fold_labeled`] functions and fold points in
//! ascending index order, so labels and refreshed-centroid bits agree
//! exactly.

use crate::error::{Error, Result};
use crate::linalg::vector::sq_dist;
use crate::spectral::gamma_of_sigma;

use super::artifact::ModelArtifact;
use super::refresh::{minibatch_update, RefreshMode};
use super::ServingConfig;

/// Nyström extension of one input point: RBF weights against the landmark
/// set, weighted mean of the landmark embedding rows, then row-normalized
/// like the training embedding. A point far from every landmark (all
/// weights underflow to 0) maps to the zero vector — still deterministic.
pub fn extend_point(model: &ModelArtifact, x: &[f64]) -> Vec<f64> {
    debug_assert_eq!(x.len(), model.d);
    let gamma = gamma_of_sigma(model.sigma);
    let ed = model.embed_dim;
    let mut y = vec![0.0f64; ed];
    let mut wsum = 0.0f64;
    for (l, row) in model.landmark_points.iter().zip(&model.landmark_rows) {
        let w = (-gamma * sq_dist(l, x)).exp();
        wsum += w;
        for t in 0..ed {
            y[t] += w * row[t];
        }
    }
    if wsum > 0.0 {
        for v in y.iter_mut() {
            *v /= wsum;
        }
    }
    let norm = y.iter().map(|v| v * v).sum::<f64>().sqrt();
    if norm > 0.0 {
        for v in y.iter_mut() {
            *v /= norm;
        }
    }
    y
}

/// Nearest centroid in embedding space: strict `<`, so ties go to the
/// lowest index — the same rule on both serving paths.
pub fn nearest_centroid(centroids: &[Vec<f64>], y: &[f64]) -> usize {
    let mut best = 0usize;
    let mut best_d = f64::INFINITY;
    for (c, center) in centroids.iter().enumerate() {
        let d2 = sq_dist(center, y);
        if d2 < best_d {
            best_d = d2;
            best = c;
        }
    }
    best
}

/// One assigned batch: labels in point order plus the per-cluster
/// embedding sums/masses mini-batch refresh consumes.
pub struct BatchAssign {
    /// Cluster label per batch point.
    pub labels: Vec<usize>,
    /// k × embed_dim per-cluster sums of projected embeddings.
    pub sums: Vec<Vec<f64>>,
    /// Per-cluster batch masses.
    pub counts: Vec<u64>,
}

/// Fold `(label, ŷ)` pairs — which MUST arrive in ascending point order —
/// into a [`BatchAssign`]. This one loop fixes the f64 summation order for
/// both serving paths; reordering it would break oracle/distributed byte
/// identity.
pub(crate) fn fold_labeled(
    k: usize,
    embed_dim: usize,
    pairs: impl Iterator<Item = (usize, Vec<f64>)>,
) -> BatchAssign {
    let mut labels = Vec::new();
    let mut sums = vec![vec![0.0f64; embed_dim]; k];
    let mut counts = vec![0u64; k];
    for (label, y) in pairs {
        for t in 0..embed_dim {
            sums[label][t] += y[t];
        }
        counts[label] += 1;
        labels.push(label);
    }
    BatchAssign { labels, sums, counts }
}

/// Assign one batch of flat row-major points (n × model.d) against the
/// model's current centroids.
pub fn assign_batch_oracle(
    model: &ModelArtifact,
    points: &[f64],
) -> Result<BatchAssign> {
    if points.is_empty() || points.len() % model.d != 0 {
        return Err(Error::Data(format!(
            "assign: {} coordinates is not a whole number of {}-d points",
            points.len(),
            model.d
        )));
    }
    let n = points.len() / model.d;
    Ok(fold_labeled(
        model.k,
        model.embed_dim,
        (0..n).map(|i| {
            let y = extend_point(model, &points[i * model.d..(i + 1) * model.d]);
            (nearest_centroid(&model.centroids, &y), y)
        }),
    ))
}

/// A fully assigned point stream.
pub struct AssignOutput {
    /// Cluster label per stream point.
    pub labels: Vec<usize>,
    /// Batches processed.
    pub batches: u64,
    /// Counted refresh updates applied (0 with `refresh = off`).
    pub refresh_updates: u64,
    /// The model after the stream — refreshed centroids/counts when
    /// `refresh = minibatch`, untouched otherwise.
    pub model: ModelArtifact,
}

/// Assign a whole point stream batch-by-batch (`cfg.batch_points` per
/// batch), applying mini-batch refresh between batches when enabled. The
/// single-machine mirror of [`super::job::run_assign`]'s batching loop.
pub fn assign_stream_oracle(
    model: &ModelArtifact,
    points: &[f64],
    cfg: &ServingConfig,
) -> Result<AssignOutput> {
    let mut model = model.clone();
    let mut labels = Vec::new();
    let mut batches = 0u64;
    let mut refresh_updates = 0u64;
    let step = cfg.batch_points.max(1) * model.d;
    let mut at = 0usize;
    while at < points.len() {
        let hi = (at + step).min(points.len());
        let batch = assign_batch_oracle(&model, &points[at..hi])?;
        labels.extend_from_slice(&batch.labels);
        batches += 1;
        if cfg.refresh == RefreshMode::Minibatch {
            refresh_updates += minibatch_update(
                &mut model.centroids,
                &mut model.counts,
                &batch.sums,
                &batch.counts,
            );
        }
        at = hi;
    }
    Ok(AssignOutput { labels, batches, refresh_updates, model })
}

#[cfg(test)]
mod tests {
    use super::super::artifact::tests::fixture;
    use super::*;

    #[test]
    fn landmark_points_extend_near_their_own_rows() {
        let m = fixture();
        // The fixture's landmarks are far apart relative to sigma, so each
        // landmark's extension is dominated by its own embedding row.
        for (p, row) in m.landmark_points.iter().zip(&m.landmark_rows) {
            let y = extend_point(&m, p);
            let d = sq_dist(&y, row).sqrt();
            assert!(d < 0.2, "landmark {p:?}: ŷ {y:?} vs row {row:?}");
        }
    }

    #[test]
    fn nearest_centroid_breaks_ties_low() {
        let cents = vec![vec![1.0, 0.0], vec![-1.0, 0.0]];
        assert_eq!(nearest_centroid(&cents, &[0.0, 5.0]), 0, "equidistant → 0");
        assert_eq!(nearest_centroid(&cents, &[-0.9, 0.0]), 1);
    }

    #[test]
    fn batch_oracle_labels_sums_and_counts_agree() {
        let m = fixture();
        let pts = vec![-1.0, 0.25, 4.0, -0.8];
        let b = assign_batch_oracle(&m, &pts).unwrap();
        assert_eq!(b.labels.len(), 4);
        assert_eq!(b.counts.iter().sum::<u64>(), 4);
        for (c, &cnt) in b.counts.iter().enumerate() {
            let from_labels = b.labels.iter().filter(|&&l| l == c).count() as u64;
            assert_eq!(cnt, from_labels, "cluster {c}");
            if cnt == 0 {
                assert!(b.sums[c].iter().all(|&s| s == 0.0));
            }
        }
        assert!(assign_batch_oracle(&m, &[]).is_err(), "empty batch");
    }

    #[test]
    fn stream_oracle_refresh_is_deterministic_and_counts_batches() {
        let m = fixture();
        let pts: Vec<f64> = (0..10).map(|i| i as f64 * 0.5 - 2.0).collect();
        let cfg = ServingConfig {
            batch_points: 4,
            refresh: RefreshMode::Minibatch,
            ..Default::default()
        };
        let a = assign_stream_oracle(&m, &pts, &cfg).unwrap();
        let b = assign_stream_oracle(&m, &pts, &cfg).unwrap();
        assert_eq!(a.batches, 3, "10 points in batches of 4");
        assert_eq!(a.labels, b.labels);
        assert!(a.refresh_updates > 0);
        assert_eq!(a.refresh_updates, b.refresh_updates);
        for (x, y) in a.model.centroids.iter().zip(&b.model.centroids) {
            let xb: Vec<u64> = x.iter().map(|v| v.to_bits()).collect();
            let yb: Vec<u64> = y.iter().map(|v| v.to_bits()).collect();
            assert_eq!(xb, yb, "refreshed centroids must replay bit-exactly");
        }
        // Off leaves the model untouched.
        let off = assign_stream_oracle(&m, &pts, &ServingConfig::default()).unwrap();
        assert_eq!(off.refresh_updates, 0);
        assert_eq!(off.model, m);
    }
}
