//! Online serving layer: persisted model artifacts and Nyström
//! out-of-sample assignment (`psch assign`).
//!
//! The batch pipeline ends at a clustering result; this module turns that
//! result into a servable model. A run with `--model-out` captures a
//! [`ModelArtifact`] — centroids, a landmark subset of the training points
//! with their embedding rows, and the kernel/graph/eigen parameters — as
//! versioned zero-dependency JSON (schema [`MODEL_SCHEMA`]). `psch assign`
//! then maps *new* point batches to clusters without re-running the
//! pipeline, via Nyström-style extension (after Jin & JaJa, arXiv
//! 1802.04450):
//!
//! 1. RBF weights against the stored landmarks:
//!    `w_j = exp(-‖x − l_j‖² / 2σ²)`;
//! 2. projected embedding `ŷ = Σ_j w_j · U_j / Σ_j w_j` (row-normalized
//!    like the training embedding);
//! 3. nearest centroid in embedding space (strict `<`, ties to the lowest
//!    index).
//!
//! Two implementations share those exact functions: a single-machine
//! oracle ([`oracle`]) and a distributed dataflow pipeline ([`job`]) that
//! stages batches in the DFS and fans the extension out over map tasks.
//! The distributed path is **byte-identical** to the oracle — same labels,
//! same refreshed-centroid bits — which is what makes it testable at all.
//! Between batches, [`refresh`] optionally applies counted mini-batch
//! centroid updates (`serving.refresh = minibatch`) so the model tracks
//! drift between full re-clusterings.

pub mod artifact;
pub mod job;
pub mod oracle;
pub mod refresh;

pub use artifact::{ModelArtifact, MODEL_SCHEMA};
pub use job::{run_assign, ServingRun};
pub use oracle::{assign_batch_oracle, assign_stream_oracle, AssignOutput};
pub use refresh::{minibatch_update, RefreshMode};

use crate::error::{Error, Result};

/// `[serving]` config section.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServingConfig {
    /// Landmark points sampled into the artifact (deterministic stride over
    /// the training set). `0` keeps **all** training points as landmarks —
    /// the exact-extension setting where training-set self-assignment
    /// reproduces the run's own labels.
    pub landmarks: usize,
    /// Points per assign batch: each batch is one dataflow pipeline (and
    /// one refresh step when enabled).
    pub batch_points: usize,
    /// Centroid refresh policy applied after each assigned batch.
    pub refresh: RefreshMode,
}

impl Default for ServingConfig {
    fn default() -> Self {
        Self { landmarks: 0, batch_points: 256, refresh: RefreshMode::Off }
    }
}

/// Parse a text file of points — one point per line, coordinates separated
/// by whitespace or commas; blank lines and `#` comments skipped. Every
/// point must have dimension `d` (the model's input dimension).
pub fn parse_points(text: &str, d: usize) -> Result<Vec<f64>> {
    let mut points = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let start = points.len();
        for tok in line.split(|c: char| c.is_whitespace() || c == ',') {
            if tok.is_empty() {
                continue;
            }
            let v: f64 = tok.parse().map_err(|_| {
                Error::Data(format!(
                    "points line {}: bad coordinate {:?}",
                    lineno + 1,
                    tok
                ))
            })?;
            points.push(v);
        }
        let got = points.len() - start;
        if got != d {
            return Err(Error::Data(format!(
                "points line {}: {} coordinates, model expects {}",
                lineno + 1,
                got,
                d
            )));
        }
    }
    if points.is_empty() {
        return Err(Error::Data("points file has no points".into()));
    }
    Ok(points)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_points_accepts_whitespace_commas_and_comments() {
        let text = "# header\n1.0 2.0\n3.0,4.0\n\n  5e-1\t-6.25  \n";
        let pts = parse_points(text, 2).unwrap();
        assert_eq!(pts, vec![1.0, 2.0, 3.0, 4.0, 0.5, -6.25]);
    }

    #[test]
    fn parse_points_rejects_bad_input() {
        assert!(parse_points("1.0 oops", 2).is_err(), "bad coordinate");
        assert!(parse_points("1.0 2.0 3.0", 2).is_err(), "wrong dimension");
        assert!(parse_points("# only comments\n", 2).is_err(), "empty");
    }
}
