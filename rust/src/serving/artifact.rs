//! The persisted model artifact (`psch run --model-out`): everything
//! `psch assign` needs to map new points to clusters without re-running
//! the pipeline, as versioned zero-dependency JSON built on
//! [`crate::trace::json`].
//!
//! Schema `psch.model.v1` glossary:
//!
//! | field              | meaning                                          |
//! |--------------------|--------------------------------------------------|
//! | `schema`           | version tag (this file: `psch.model.v1`)         |
//! | `k`                | cluster count                                    |
//! | `d`                | input point dimension                            |
//! | `embed_dim`        | spectral embedding dimension (= k today)         |
//! | `sigma`            | resolved RBF bandwidth (auto already folded in)  |
//! | `graph`/`solver`   | training graph mode and eigensolver (echo)       |
//! | `seed`/`epsilon`/`knn_t` | training config echo                       |
//! | `counts`           | lifetime per-cluster masses (refresh state)      |
//! | `centroids`        | k × embed_dim k-means centers                    |
//! | `landmarks.m`      | landmark count                                   |
//! | `landmarks.points` | m × d landmark input points                      |
//! | `landmarks.rows`   | m × embed_dim landmark embedding rows            |
//!
//! Numbers are written with Rust's shortest-roundtrip `Display` (see
//! [`num`]), which re-parses bit-exactly — so save → load → re-export is
//! **byte-identical**, the property the round-trip test pins.

use crate::config::Config;
use crate::coordinator::driver::PipelineResult;
use crate::coordinator::eigen::EigenSolverKind;
use crate::coordinator::kmeans_job::validate_centers;
use crate::error::{Error, Result};
use crate::knn::GraphMode;
use crate::trace::json::{num, Value};

/// Artifact schema tag.
pub const MODEL_SCHEMA: &str = "psch.model.v1";

/// A servable spectral-clustering model.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelArtifact {
    /// Cluster count.
    pub k: usize,
    /// Input point dimension.
    pub d: usize,
    /// Embedding dimension (centroids and landmark rows live here).
    pub embed_dim: usize,
    /// Resolved RBF bandwidth (a `sigma = "auto"` run stores its mean
    /// t-th-neighbor estimate, so serving never re-derives it).
    pub sigma: f64,
    /// Training graph mode (config echo).
    pub graph: GraphMode,
    /// Training eigensolver (config echo).
    pub solver: EigenSolverKind,
    /// Training seed (config echo; fixes refresh determinism provenance).
    pub seed: u64,
    /// Training epsilon threshold (config echo).
    pub epsilon: f64,
    /// Training t-NN neighbor count (config echo).
    pub knn_t: usize,
    /// Lifetime per-cluster masses — initialized to the training cluster
    /// sizes, grown by mini-batch refresh (the counted-update state).
    pub counts: Vec<u64>,
    /// k × embed_dim cluster centers in embedding space.
    pub centroids: Vec<Vec<f64>>,
    /// m × d landmark input points (the Nyström anchor set).
    pub landmark_points: Vec<Vec<f64>>,
    /// m × embed_dim embedding rows of the landmarks.
    pub landmark_rows: Vec<Vec<f64>>,
}

impl ModelArtifact {
    /// Landmark count.
    pub fn m(&self) -> usize {
        self.landmark_points.len()
    }

    /// Capture the artifact from a finished run. `serving.landmarks`
    /// selects an evenly-strided landmark subset (index `i·n/m`); `0`
    /// keeps every training point.
    pub fn from_run(
        cfg: &Config,
        points: &[Vec<f64>],
        result: &PipelineResult,
    ) -> Result<Self> {
        let bad = |msg: String| Error::Data(format!("model capture: {msg}"));
        let n = points.len();
        if n == 0 {
            return Err(bad("no training points".into()));
        }
        let d = points[0].len();
        let (k, embed_dim) = validate_centers(&result.centers)?;
        if result.labels.len() != n {
            return Err(bad(format!("{} labels for {n} points", result.labels.len())));
        }
        if result.embedding.len() != n * embed_dim {
            return Err(bad(format!(
                "embedding has {} values, expected {n}×{embed_dim}",
                result.embedding.len()
            )));
        }
        let mut counts = vec![0u64; k];
        for &l in &result.labels {
            if l >= k {
                return Err(bad(format!("label {l} out of range (k={k})")));
            }
            counts[l] += 1;
        }
        let m = match cfg.serving.landmarks {
            0 => n,
            m => m.min(n),
        };
        let idx = |i: usize| i * n / m;
        let landmark_points: Vec<Vec<f64>> =
            (0..m).map(|i| points[idx(i)].clone()).collect();
        let landmark_rows: Vec<Vec<f64>> = (0..m)
            .map(|i| {
                let row = idx(i);
                (0..embed_dim)
                    .map(|c| result.embedding[row * embed_dim + c] as f64)
                    .collect()
            })
            .collect();
        let artifact = Self {
            k,
            d,
            embed_dim,
            sigma: result.sigma,
            graph: cfg.algo.graph,
            solver: cfg.eigen.solver,
            seed: cfg.algo.seed,
            epsilon: cfg.algo.epsilon,
            knn_t: cfg.knn.t,
            counts,
            centroids: result.centers.clone(),
            landmark_points,
            landmark_rows,
        };
        artifact.validate()?;
        Ok(artifact)
    }

    /// Structural validation (one gate for capture and load).
    pub fn validate(&self) -> Result<()> {
        let bad = |msg: String| Error::Data(format!("model artifact: {msg}"));
        if !(self.sigma.is_finite() && self.sigma > 0.0) {
            return Err(bad(format!("sigma must be finite and > 0, got {}", self.sigma)));
        }
        if !(self.epsilon.is_finite() && self.epsilon >= 0.0) {
            return Err(bad(format!("bad epsilon {}", self.epsilon)));
        }
        let (k, dim) = validate_centers(&self.centroids)?;
        if k != self.k || dim != self.embed_dim {
            return Err(bad(format!(
                "centroids are {k}×{dim}, header says {}×{}",
                self.k, self.embed_dim
            )));
        }
        if self.counts.len() != self.k {
            return Err(bad(format!("{} counts for k={}", self.counts.len(), self.k)));
        }
        let m = self.landmark_points.len();
        if m == 0 {
            return Err(bad("no landmarks".into()));
        }
        if self.landmark_rows.len() != m {
            return Err(bad(format!(
                "{} landmark rows for {m} landmark points",
                self.landmark_rows.len()
            )));
        }
        for (name, rows, width) in [
            ("landmark point", &self.landmark_points, self.d),
            ("landmark row", &self.landmark_rows, self.embed_dim),
        ] {
            for (i, r) in rows.iter().enumerate() {
                if r.len() != width {
                    return Err(bad(format!(
                        "{name} {i} has dimension {}, expected {width}",
                        r.len()
                    )));
                }
                if r.iter().any(|x| !x.is_finite()) {
                    return Err(bad(format!("{name} {i} has a non-finite value")));
                }
            }
        }
        Ok(())
    }

    /// Render the canonical JSON document (fixed key and row order — the
    /// byte-identity contract).
    pub fn to_json(&self) -> String {
        let row =
            |v: &[f64]| -> String {
                let cells: Vec<String> = v.iter().map(|&x| num(x)).collect();
                format!("[{}]", cells.join(","))
            };
        let matrix = |m: &[Vec<f64>]| -> String {
            let rows: Vec<String> = m.iter().map(|r| row(r)).collect();
            format!("[\n  {}\n ]", rows.join(",\n  "))
        };
        let counts: Vec<String> = self.counts.iter().map(|c| c.to_string()).collect();
        format!(
            "{{\n \"schema\": \"{schema}\",\n \"k\": {k},\n \"d\": {d},\n \
             \"embed_dim\": {ed},\n \"sigma\": {sigma},\n \"graph\": \"{graph}\",\n \
             \"solver\": \"{solver}\",\n \"seed\": {seed},\n \"epsilon\": {eps},\n \
             \"knn_t\": {t},\n \"counts\": [{counts}],\n \"centroids\": {cent},\n \
             \"landmarks\": {{\n \"m\": {m},\n \"points\": {pts},\n \"rows\": {rows}\n }}\n}}\n",
            schema = MODEL_SCHEMA,
            k = self.k,
            d = self.d,
            ed = self.embed_dim,
            sigma = num(self.sigma),
            graph = self.graph.as_str(),
            solver = self.solver.as_str(),
            seed = self.seed,
            eps = num(self.epsilon),
            t = self.knn_t,
            counts = counts.join(","),
            cent = matrix(&self.centroids),
            m = self.m(),
            pts = matrix(&self.landmark_points),
            rows = matrix(&self.landmark_rows),
        )
    }

    /// Parse and validate a JSON document produced by [`Self::to_json`].
    pub fn from_json(text: &str) -> Result<Self> {
        let bad = |msg: String| Error::Data(format!("model artifact: {msg}"));
        let v = Value::parse(text).map_err(|e| bad(format!("bad JSON: {e}")))?;
        let field = |key: &str| -> Result<&Value> {
            v.get(key).ok_or_else(|| bad(format!("missing field {key:?}")))
        };
        let schema = field("schema")?
            .as_str()
            .ok_or_else(|| bad("schema must be a string".into()))?;
        if schema != MODEL_SCHEMA {
            return Err(bad(format!(
                "schema {schema:?}, this build reads {MODEL_SCHEMA:?}"
            )));
        }
        let uint = |key: &str| -> Result<u64> {
            field(key)?
                .as_u64()
                .ok_or_else(|| bad(format!("{key} must be a number")))
        };
        let float = |key: &str| -> Result<f64> {
            field(key)?
                .as_f64()
                .ok_or_else(|| bad(format!("{key} must be a number")))
        };
        let matrix = |val: &Value, key: &str| -> Result<Vec<Vec<f64>>> {
            let rows = val
                .items()
                .ok_or_else(|| bad(format!("{key} must be an array")))?;
            rows.iter()
                .map(|r| {
                    r.items()
                        .ok_or_else(|| bad(format!("{key} rows must be arrays")))?
                        .iter()
                        .map(|x| {
                            x.as_f64().ok_or_else(|| {
                                bad(format!("{key} values must be numbers"))
                            })
                        })
                        .collect()
                })
                .collect()
        };
        let graph_str = field("graph")?
            .as_str()
            .ok_or_else(|| bad("graph must be a string".into()))?;
        let graph = GraphMode::parse(graph_str)
            .ok_or_else(|| bad(format!("unknown graph mode {graph_str:?}")))?;
        let solver_str = field("solver")?
            .as_str()
            .ok_or_else(|| bad("solver must be a string".into()))?;
        let solver = EigenSolverKind::parse(solver_str)
            .ok_or_else(|| bad(format!("unknown solver {solver_str:?}")))?;
        let counts: Vec<u64> = field("counts")?
            .items()
            .ok_or_else(|| bad("counts must be an array".into()))?
            .iter()
            .map(|x| x.as_u64().ok_or_else(|| bad("counts must be numbers".into())))
            .collect::<Result<_>>()?;
        let landmarks = field("landmarks")?;
        let lm_field = |key: &str| -> Result<&Value> {
            landmarks
                .get(key)
                .ok_or_else(|| bad(format!("missing field landmarks.{key}")))
        };
        let artifact = Self {
            k: uint("k")? as usize,
            d: uint("d")? as usize,
            embed_dim: uint("embed_dim")? as usize,
            sigma: float("sigma")?,
            graph,
            solver,
            seed: uint("seed")?,
            epsilon: float("epsilon")?,
            knn_t: uint("knn_t")? as usize,
            counts,
            centroids: matrix(field("centroids")?, "centroids")?,
            landmark_points: matrix(lm_field("points")?, "landmarks.points")?,
            landmark_rows: matrix(lm_field("rows")?, "landmarks.rows")?,
        };
        let m = lm_field("m")?
            .as_u64()
            .ok_or_else(|| bad("landmarks.m must be a number".into()))?
            as usize;
        if m != artifact.m() {
            return Err(bad(format!(
                "landmarks.m = {m} but {} points are present",
                artifact.m()
            )));
        }
        artifact.validate()?;
        Ok(artifact)
    }

    /// Write the artifact to a filesystem path.
    pub fn save(&self, path: &str) -> Result<()> {
        self.validate()?;
        std::fs::write(path, self.to_json())?;
        Ok(())
    }

    /// Load an artifact from a filesystem path.
    pub fn load(path: &str) -> Result<Self> {
        Self::from_json(&std::fs::read_to_string(path)?)
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    /// A tiny well-formed artifact: 2 clusters in a 2-d embedding over 1-d
    /// points, 3 landmarks.
    pub(crate) fn fixture() -> ModelArtifact {
        ModelArtifact {
            k: 2,
            d: 1,
            embed_dim: 2,
            sigma: 0.75,
            graph: GraphMode::Epsilon,
            solver: EigenSolverKind::Lanczos,
            seed: 42,
            epsilon: 0.001,
            knn_t: 10,
            counts: vec![2, 1],
            centroids: vec![vec![1.0, 0.0], vec![0.0, 1.0]],
            landmark_points: vec![vec![-1.0], vec![0.25], vec![4.0]],
            landmark_rows: vec![
                vec![1.0, 0.0],
                vec![0.8, 0.6],
                vec![0.0, 1.0],
            ],
        }
    }

    #[test]
    fn json_round_trip_is_byte_identical() {
        let a = fixture();
        let doc = a.to_json();
        let b = ModelArtifact::from_json(&doc).unwrap();
        assert_eq!(a, b);
        assert_eq!(b.to_json(), doc, "re-export must be byte-identical");
    }

    #[test]
    fn from_json_rejects_corruption() {
        let a = fixture();
        let doc = a.to_json();
        assert!(ModelArtifact::from_json(&doc.replace(
            MODEL_SCHEMA,
            "psch.model.v999"
        ))
        .is_err());
        assert!(ModelArtifact::from_json(&doc.replace("\"k\": 2", "\"k\": 3"))
            .is_err());
        assert!(
            ModelArtifact::from_json(&doc.replace("\"m\": 3", "\"m\": 4")).is_err()
        );
        assert!(ModelArtifact::from_json("{\"schema\": 1}").is_err());
        assert!(ModelArtifact::from_json("not json").is_err());
    }

    #[test]
    fn validate_rejects_shape_drift() {
        let mut a = fixture();
        a.landmark_rows.pop();
        assert!(a.validate().is_err(), "row/point count mismatch");
        let mut b = fixture();
        b.sigma = -1.0;
        assert!(b.validate().is_err(), "bad sigma");
        let mut c = fixture();
        c.counts = vec![1];
        assert!(c.validate().is_err(), "counts/k mismatch");
        let mut e = fixture();
        e.landmark_points[0][0] = f64::NAN;
        assert!(e.validate().is_err(), "non-finite landmark");
    }
}
