//! Distributed assignment: the `psch assign` dataflow path.
//!
//! Per batch, one pipeline: `read_dfs(staged batch points)` →
//! `map_kv(nystrom-extend)` (each task extends its split of points against
//! the broadcast landmark set) → `group_reduce(assign-collect)` (each
//! point's projected embedding meets the centroids read from the DFS
//! center file and picks its cluster). The driver folds the collected
//! `(index, label, ŷ)` records in ascending point order through the same
//! [`super::oracle::fold_labeled`] the oracle uses, then (optionally)
//! applies the same mini-batch refresh — which is why the distributed path
//! is byte-identical to [`super::oracle::assign_stream_oracle`].
//!
//! Centroids travel between batches the way phase 3 ships them: through
//! the DFS center file, encoded/decoded by the shared
//! [`crate::coordinator::kmeans_job`] centroid codec (exact f64), so a
//! refresh on batch b is visible to batch b+1's reduce tasks.

use std::sync::Arc;

use crate::coordinator::{costmodel, kmeans_job, PhaseStats, Services};
use crate::dataflow::{Group, Pipeline};
use crate::error::{Error, Result};
use crate::mapreduce::names;

use super::artifact::ModelArtifact;
use super::oracle::{extend_point, fold_labeled, nearest_centroid};
use super::refresh::{minibatch_update, RefreshMode};
use super::ServingConfig;

/// DFS path of the staged batch points.
const BATCH_PATH: &str = "/serving/batch";
/// DFS path of the serving center file (rewritten per batch under refresh).
const CENTER_PATH: &str = "/serving/centers";

/// Points per extension map split (same granularity as phase 3).
const POINTS_PER_TASK: usize = kmeans_job::POINTS_PER_TASK;

/// Output of a distributed assign stream.
pub struct ServingRun {
    /// Cluster label per stream point.
    pub labels: Vec<usize>,
    /// The model after the stream (refreshed when enabled).
    pub model: ModelArtifact,
    /// Phase stats across all batch pipelines (one "serving" phase).
    pub stats: PhaseStats,
}

/// Stage one batch's points in the DFS as row-major f64 LE; returns the
/// per-split byte ranges that give every split its preferred hosts.
fn stage_batch(
    services: &Services,
    points: &[f64],
    n: usize,
    d: usize,
) -> Result<Vec<Vec<(usize, usize)>>> {
    let mut raw = Vec::with_capacity(points.len() * 8);
    for &x in points {
        raw.extend_from_slice(&x.to_le_bytes());
    }
    services.dfs.write_file(BATCH_PATH, &raw)?;
    let row_bytes = d * 8;
    Ok((0..n)
        .step_by(POINTS_PER_TASK)
        .map(|lo| {
            let hi = (lo + POINTS_PER_TASK).min(n);
            vec![(lo * row_bytes, hi * row_bytes)]
        })
        .collect())
}

/// Contiguous typed map splits over the batch's points.
fn batch_splits(n: usize) -> Vec<Vec<(u64, u64)>> {
    (0..n)
        .step_by(POINTS_PER_TASK)
        .map(|lo| vec![(lo as u64, ((lo + POINTS_PER_TASK).min(n)) as u64)])
        .collect()
}

/// Run one batch's extend→assign pipeline; returns `(index, payload)`
/// records where `payload[0]` is the label and the rest is ŷ.
fn run_batch_pipeline(
    services: &Services,
    model: &Arc<ModelArtifact>,
    batch: Arc<Vec<f64>>,
    stats: &mut PhaseStats,
) -> Result<Vec<(u64, Vec<f64>)>> {
    let n = batch.len() / model.d;
    let d = model.d;
    let ranges = stage_batch(services, &batch, n, d)?;
    // Centroids ride the DFS center file like phase 3's iterations — the
    // exact f64 codec keeps the reduce-side copy bit-identical to the
    // oracle's in-memory centroids.
    kmeans_job::write_center_file(services, CENTER_PATH, &model.centroids)?;
    let centers = Arc::new(kmeans_job::read_center_file(services, CENTER_PATH)?);
    // Broadcast cost of the landmark set every map task starts from.
    let model_bytes = (model.m() * (model.d + model.embed_dim) * 8) as u64;

    let pipeline = Pipeline::new("serving-assign");
    let map_model = model.clone();
    let map_batch = batch.clone();
    let reduce_centers = centers.clone();
    let embed_dim = model.embed_dim;
    let k = model.k;
    let collected = pipeline
        .read_dfs(BATCH_PATH, batch_splits(n), ranges)
        .map_kv(
            "nystrom-extend",
            move |lo: u64, hi: u64, out| -> Result<()> {
                let (lo, hi) = (lo as usize, hi as usize);
                // Split bytes + the broadcast landmark set.
                out.incr(
                    names::EXTRA_INPUT_BYTES,
                    ((hi - lo) * d * 8) as u64 + model_bytes,
                );
                // One RBF kernel evaluation per (point, landmark) pair.
                out.incr(
                    names::COMPUTE_US,
                    costmodel::units_to_us(
                        ((hi - lo) * map_model.m()) as u64,
                        costmodel::SIM_PAIRS_PER_S,
                    ),
                );
                for i in lo..hi {
                    let y =
                        extend_point(&map_model, &map_batch[i * d..(i + 1) * d]);
                    out.emit(i as u64, y);
                }
                out.incr(names::ASSIGN_POINTS, (hi - lo) as u64);
                Ok(())
            },
        )
        .group_reduce("assign-collect")
        .reducers(services.cluster.num_slaves())
        .reduce(
            move |idx: u64, values: &mut Group<'_, Vec<f64>>, out| -> Result<()> {
                let y = values
                    .next_value()
                    .ok_or_else(|| Error::MapReduce("assign: empty group".into()))?;
                out.incr(
                    names::COMPUTE_US,
                    costmodel::units_to_us(
                        (k * embed_dim) as u64,
                        costmodel::KM_POINTDIM_PER_S,
                    ),
                );
                let label = nearest_centroid(&reduce_centers, &y);
                let mut payload = Vec::with_capacity(1 + y.len());
                payload.push(label as f64);
                payload.extend_from_slice(&y);
                out.emit(idx, payload);
                Ok(())
            },
        )
        .collect();

    let mut run = pipeline.run(services)?;
    stats.absorb_run(&run.stats);
    let mut records = collected.take(&mut run);
    records.sort_by_key(|&(idx, _)| idx);
    if records.len() != n {
        return Err(Error::MapReduce(format!(
            "assign: {} records collected for {n} points",
            records.len()
        )));
    }
    Ok(records)
}

/// Assign a whole point stream on the cluster, batch-by-batch, mirroring
/// [`super::oracle::assign_stream_oracle`]'s exact batching and refresh
/// semantics.
pub fn run_assign(
    services: &Services,
    model: &ModelArtifact,
    points: &[f64],
    cfg: &ServingConfig,
) -> Result<ServingRun> {
    if points.is_empty() || points.len() % model.d != 0 {
        return Err(Error::Data(format!(
            "assign: {} coordinates is not a whole number of {}-d points",
            points.len(),
            model.d
        )));
    }
    let tracer = services.cluster.trace().clone();
    tracer.begin_phase("serving");
    let mut model = model.clone();
    let mut stats = PhaseStats { name: "serving".into(), ..Default::default() };
    let mut labels = Vec::with_capacity(points.len() / model.d);
    let step = cfg.batch_points.max(1) * model.d;
    let mut at = 0usize;
    while at < points.len() {
        let hi = (at + step).min(points.len());
        let shared = Arc::new(model.clone());
        let batch = Arc::new(points[at..hi].to_vec());
        let records = run_batch_pipeline(services, &shared, batch, &mut stats)?;
        // Ascending point order through the SAME fold as the oracle: the
        // per-cluster f64 sums come out bit-identical.
        let folded = fold_labeled(
            model.k,
            model.embed_dim,
            records.into_iter().map(|(_, mut payload)| {
                let y = payload.split_off(1);
                (payload[0] as usize, y)
            }),
        );
        labels.extend_from_slice(&folded.labels);
        stats.counters.incr(names::ASSIGN_BATCHES, 1);
        if cfg.refresh == RefreshMode::Minibatch {
            let updates = minibatch_update(
                &mut model.centroids,
                &mut model.counts,
                &folded.sums,
                &folded.counts,
            );
            stats.counters.incr(names::REFRESH_UPDATES, updates);
        }
        at = hi;
    }
    tracer.end_phase();
    Ok(ServingRun { labels, model, stats })
}

#[cfg(test)]
mod tests {
    use super::super::artifact::tests::fixture;
    use super::super::oracle::assign_stream_oracle;
    use super::*;
    use crate::cluster::Cluster;
    use crate::runtime::KernelRuntime;

    fn services(m: usize) -> Services {
        Services::new(Cluster::new(m), Arc::new(KernelRuntime::native()))
    }

    #[test]
    fn distributed_matches_oracle_bitwise_with_refresh() {
        let model = fixture();
        let pts: Vec<f64> = (0..600).map(|i| (i % 11) as f64 * 0.6 - 3.0).collect();
        let cfg = ServingConfig {
            batch_points: 200,
            refresh: RefreshMode::Minibatch,
            ..Default::default()
        };
        let svc = services(3);
        let dist = run_assign(&svc, &model, &pts, &cfg).unwrap();
        let oracle = assign_stream_oracle(&model, &pts, &cfg).unwrap();
        assert_eq!(dist.labels, oracle.labels, "labels must match exactly");
        for (a, b) in dist.model.centroids.iter().zip(&oracle.model.centroids) {
            let ab: Vec<u64> = a.iter().map(|v| v.to_bits()).collect();
            let bb: Vec<u64> = b.iter().map(|v| v.to_bits()).collect();
            assert_eq!(ab, bb, "refreshed centroid bits must match");
        }
        assert_eq!(dist.model.counts, oracle.model.counts);
        let s = dist.stats.serving_summary();
        assert_eq!(s.points, 600);
        assert_eq!(s.batches, 3);
        assert_eq!(s.refresh_updates, oracle.refresh_updates);
        assert!(dist.stats.virtual_s > 0.0, "cost model must charge time");
    }

    #[test]
    fn rejects_ragged_input() {
        let model = fixture();
        let svc = services(1);
        assert!(run_assign(&svc, &model, &[], &ServingConfig::default()).is_err());
    }
}
