//! Mini-batch centroid refresh (`serving.refresh`): counted updates in the
//! Sculley web-scale k-means style, so a served model tracks drift between
//! full re-clusterings without re-running the pipeline.
//!
//! Per assigned batch and per cluster `c` with batch mass `m_c` and batch
//! embedding mean `μ_c`, the artifact's lifetime count absorbs the mass and
//! the centroid moves with the per-center learning rate `η = m_c / n_c`:
//!
//! ```text
//! n_c ← n_c + m_c;   η = m_c / n_c;   centroid_c ← centroid_c + η (μ_c − centroid_c)
//! ```
//!
//! The update is pure f64 arithmetic in a fixed order (clusters ascending,
//! coordinates ascending), so the distributed assign path and the
//! single-machine oracle — which both call this one function with identical
//! inputs — stay byte-identical, and replaying the same batch stream from
//! the same artifact reproduces the same centroids bit for bit.

/// `serving.refresh` mode: leave the centroids frozen, or apply counted
/// mini-batch updates after every assigned batch.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum RefreshMode {
    /// Centroids stay exactly as trained.
    #[default]
    Off,
    /// Counted mini-batch updates after each assigned batch.
    Minibatch,
}

impl RefreshMode {
    /// Parse a config/CLI value.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "off" => Some(Self::Off),
            "minibatch" => Some(Self::Minibatch),
            _ => None,
        }
    }

    /// The config spelling.
    pub fn as_str(&self) -> &'static str {
        match self {
            Self::Off => "off",
            Self::Minibatch => "minibatch",
        }
    }
}

/// Apply one batch of counted updates: `batch_sums[c]` / `batch_counts[c]`
/// are the per-cluster sums and masses of the batch's projected embeddings.
/// Returns the number of centroids moved (the `REFRESH_UPDATES` feed);
/// clusters the batch never touched are left untouched.
pub fn minibatch_update(
    centroids: &mut [Vec<f64>],
    counts: &mut [u64],
    batch_sums: &[Vec<f64>],
    batch_counts: &[u64],
) -> u64 {
    debug_assert_eq!(centroids.len(), counts.len());
    debug_assert_eq!(centroids.len(), batch_sums.len());
    debug_assert_eq!(centroids.len(), batch_counts.len());
    let mut updates = 0u64;
    for c in 0..centroids.len() {
        let m = batch_counts[c];
        if m == 0 {
            continue;
        }
        counts[c] += m;
        let eta = m as f64 / counts[c] as f64;
        let inv_m = 1.0 / m as f64;
        for t in 0..centroids[c].len() {
            let mu = batch_sums[c][t] * inv_m;
            centroids[c][t] += eta * (mu - centroids[c][t]);
        }
        updates += 1;
    }
    updates
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parse_roundtrip() {
        for m in [RefreshMode::Off, RefreshMode::Minibatch] {
            assert_eq!(RefreshMode::parse(m.as_str()), Some(m));
        }
        assert_eq!(RefreshMode::parse("banana"), None);
    }

    #[test]
    fn counted_update_moves_toward_the_batch_mean() {
        let mut centroids = vec![vec![0.0, 0.0]];
        let mut counts = vec![3u64];
        // Batch of one point at (4, 8): eta = 1/4, centroid moves a quarter.
        let updates =
            minibatch_update(&mut centroids, &mut counts, &[vec![4.0, 8.0]], &[1]);
        assert_eq!(updates, 1);
        assert_eq!(counts, vec![4]);
        assert_eq!(centroids[0], vec![1.0, 2.0]);
    }

    #[test]
    fn empty_clusters_are_untouched_and_replay_is_deterministic() {
        let run = || {
            let mut centroids = vec![vec![1.0], vec![-1.0]];
            let mut counts = vec![10u64, 10];
            let mut total = 0;
            for _ in 0..3 {
                total += minibatch_update(
                    &mut centroids,
                    &mut counts,
                    &[vec![5.0], vec![0.0]],
                    &[5, 0],
                );
            }
            (centroids, counts, total)
        };
        let (c1, n1, u1) = run();
        let (c2, n2, u2) = run();
        assert_eq!(c1[0][0].to_bits(), c2[0][0].to_bits(), "bitwise replay");
        assert_eq!(n1, n2);
        assert_eq!(u1, 3, "one touched cluster per batch");
        assert_eq!(u1, u2);
        assert_eq!(c1[1], vec![-1.0], "empty cluster frozen");
        assert_eq!(n1[1], 10);
    }
}
