//! Dense symmetric eigensolver (cyclic Jacobi rotations).
//!
//! The O(n^3) oracle: used by the single-machine baseline (the comparator the
//! paper speeds up) and as the ground truth the Lanczos implementation is
//! validated against in tests. Classic cyclic-by-row Jacobi with the
//! Rutishauser threshold strategy.

use crate::error::{Error, Result};

use super::dense::DenseMatrix;

/// Full eigen decomposition of a symmetric matrix.
///
/// Returns `(eigenvalues, eigenvectors)` sorted ascending; eigenvector `k` is
/// column `k` of the returned matrix.
pub fn jacobi_eigen(a: &DenseMatrix) -> Result<(Vec<f64>, DenseMatrix)> {
    let n = a.rows();
    if a.cols() != n {
        return Err(Error::Linalg("jacobi: matrix not square".into()));
    }
    if !a.is_symmetric(1e-9) {
        return Err(Error::Linalg("jacobi: matrix not symmetric".into()));
    }
    let mut m = a.clone();
    let mut v = DenseMatrix::eye(n);

    let max_sweeps = 100;
    for sweep in 0..max_sweeps {
        // Off-diagonal Frobenius norm.
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if off.sqrt() < 1e-13 * (1.0 + frobenius(&m)) {
            break;
        }
        if sweep == max_sweeps - 1 {
            return Err(Error::Linalg("jacobi: no convergence in 100 sweeps".into()));
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let theta = (aqq - app) / (2.0 * apq);
                let t = {
                    let s = if theta >= 0.0 { 1.0 } else { -1.0 };
                    s / (theta.abs() + (theta * theta + 1.0).sqrt())
                };
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;

                // Apply rotation G(p,q,theta): M <- G^T M G, V <- V G.
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }

    let mut vals: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&x, &y| vals[x].partial_cmp(&vals[y]).unwrap());
    vals.sort_by(|x, y| x.partial_cmp(y).unwrap());
    let mut sorted_v = DenseMatrix::zeros(n, n);
    for (new_c, &old_c) in order.iter().enumerate() {
        for i in 0..n {
            sorted_v[(i, new_c)] = v[(i, old_c)];
        }
    }
    Ok((vals, sorted_v))
}

fn frobenius(m: &DenseMatrix) -> f64 {
    m.data().iter().map(|x| x * x).sum::<f64>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Xoshiro256;

    fn random_symmetric(n: usize, seed: u64) -> DenseMatrix {
        let mut rng = Xoshiro256::new(seed);
        let mut m = DenseMatrix::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let v = rng.next_f64() * 2.0 - 1.0;
                m[(i, j)] = v;
                m[(j, i)] = v;
            }
        }
        m
    }

    #[test]
    fn two_by_two_analytic() {
        let a = DenseMatrix::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]).unwrap();
        let (vals, vecs) = jacobi_eigen(&a).unwrap();
        assert!((vals[0] - 1.0).abs() < 1e-10);
        assert!((vals[1] - 3.0).abs() < 1e-10);
        // Eigenvector for lambda=1 is (1,-1)/sqrt(2) up to sign.
        let r = (vecs[(0, 0)] / vecs[(1, 0)] + 1.0).abs();
        assert!(r < 1e-8, "vec ratio {r}");
    }

    #[test]
    fn reconstruction_residual() {
        for n in [3usize, 8, 20] {
            let a = random_symmetric(n, 42 + n as u64);
            let (vals, v) = jacobi_eigen(&a).unwrap();
            // || A v_k - lambda_k v_k || small for all k.
            for k in 0..n {
                let vk: Vec<f64> = (0..n).map(|i| v[(i, k)]).collect();
                let av = a.matvec(&vk);
                for i in 0..n {
                    assert!(
                        (av[i] - vals[k] * vk[i]).abs() < 1e-8,
                        "n={n} k={k} i={i}"
                    );
                }
            }
        }
    }

    #[test]
    fn eigenvectors_orthonormal() {
        let a = random_symmetric(12, 7);
        let (_, v) = jacobi_eigen(&a).unwrap();
        let vt_v = v.transpose().matmul(&v).unwrap();
        assert!(vt_v.max_abs_diff(&DenseMatrix::eye(12)) < 1e-9);
    }

    #[test]
    fn trace_preserved() {
        let a = random_symmetric(10, 99);
        let trace: f64 = (0..10).map(|i| a[(i, i)]).sum();
        let (vals, _) = jacobi_eigen(&a).unwrap();
        assert!((vals.iter().sum::<f64>() - trace).abs() < 1e-9);
    }

    #[test]
    fn rejects_non_symmetric_and_non_square() {
        let mut a = DenseMatrix::eye(3);
        a[(0, 1)] = 1.0;
        assert!(jacobi_eigen(&a).is_err());
        assert!(jacobi_eigen(&DenseMatrix::zeros(2, 3)).is_err());
    }
}
