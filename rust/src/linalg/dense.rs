//! Row-major dense matrix, the workhorse of the single-machine baseline.

use crate::error::{Error, Result};

/// Row-major dense matrix of f64.
#[derive(Debug, Clone, PartialEq)]
pub struct DenseMatrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl DenseMatrix {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// From row-major data.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::Linalg(format!(
                "from_vec: {rows}x{cols} needs {} values, got {}",
                rows * cols,
                data.len()
            )));
        }
        Ok(Self { rows, cols, data })
    }

    /// Row count.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Column count.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Borrow one row.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Borrow one row mutably.
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Raw row-major data.
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// y = A x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols, "matvec dimension mismatch");
        (0..self.rows)
            .map(|i| super::vector::dot(self.row(i), x))
            .collect()
    }

    /// C = A * B.
    pub fn matmul(&self, b: &DenseMatrix) -> Result<DenseMatrix> {
        if self.cols != b.rows {
            return Err(Error::Linalg(format!(
                "matmul: {}x{} * {}x{}",
                self.rows, self.cols, b.rows, b.cols
            )));
        }
        let mut c = DenseMatrix::zeros(self.rows, b.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let aik = self[(i, k)];
                if aik == 0.0 {
                    continue;
                }
                let brow = b.row(k);
                let crow = c.row_mut(i);
                for (cj, bj) in crow.iter_mut().zip(brow) {
                    *cj += aik * bj;
                }
            }
        }
        Ok(c)
    }

    /// Transpose.
    pub fn transpose(&self) -> DenseMatrix {
        let mut t = DenseMatrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Max |A_ij - B_ij|.
    pub fn max_abs_diff(&self, other: &DenseMatrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Is the matrix symmetric to within `tol`?
    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.rows != self.cols {
            return false;
        }
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                if (self[(i, j)] - self[(j, i)]).abs() > tol {
                    return false;
                }
            }
        }
        true
    }
}

impl std::ops::Index<(usize, usize)> for DenseMatrix {
    type Output = f64;
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for DenseMatrix {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_indexing() {
        let m = DenseMatrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(m[(0, 0)], 1.0);
        assert_eq!(m[(1, 2)], 6.0);
        assert_eq!(m.row(1), &[4., 5., 6.]);
        assert!(DenseMatrix::from_vec(2, 2, vec![1.0]).is_err());
    }

    #[test]
    fn eye_matvec() {
        let i3 = DenseMatrix::eye(3);
        assert_eq!(i3.matvec(&[1., 2., 3.]), vec![1., 2., 3.]);
    }

    #[test]
    fn matmul_against_hand_result() {
        let a = DenseMatrix::from_vec(2, 2, vec![1., 2., 3., 4.]).unwrap();
        let b = DenseMatrix::from_vec(2, 2, vec![5., 6., 7., 8.]).unwrap();
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.data(), &[19., 22., 43., 50.]);
        let bad = DenseMatrix::zeros(3, 3);
        assert!(a.matmul(&bad).is_err());
    }

    #[test]
    fn transpose_involution() {
        let a = DenseMatrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(a.transpose().transpose(), a);
        assert_eq!(a.transpose()[(2, 1)], 6.0);
    }

    #[test]
    fn symmetry_check() {
        let mut a = DenseMatrix::eye(3);
        assert!(a.is_symmetric(0.0));
        a[(0, 1)] = 0.5;
        assert!(!a.is_symmetric(1e-12));
        a[(1, 0)] = 0.5;
        assert!(a.is_symmetric(1e-12));
        assert!(!DenseMatrix::zeros(2, 3).is_symmetric(1.0));
    }
}
