//! Symmetric tridiagonal eigensolver (implicit-shift QL, the classic `tql2`).
//!
//! This is the cheap master-side step of the paper's phase 2: after the
//! distributed Lanczos iteration produces the tridiagonal `T_mm` (paper Alg.
//! 4.3 / the matrix display after it), "it is easy to get its eigenvalues and
//! eigenvectors by some methods (such as QR)". We port the EISPACK `tql2`
//! routine (via the Numerical Recipes formulation), which returns ALL
//! eigenvalues and eigenvectors of T in O(m^2)–O(m^3) for the m×m T — m is
//! tiny (tens), so this never matters for scale.

use crate::error::{Error, Result};

/// Eigen decomposition of a symmetric tridiagonal matrix.
///
/// `diag` (length m) holds the diagonal, `off` (length m, `off[0]` unused by
/// convention — `off[i]` couples rows i-1 and i) the sub/super diagonal.
/// Returns `(eigenvalues, eigenvectors)` sorted ascending; eigenvector `k` is
/// column `k` of the returned row-major m×m matrix (i.e. `vecs[i][k]`).
pub fn tridiag_eigen(diag: &[f64], off: &[f64]) -> Result<(Vec<f64>, Vec<Vec<f64>>)> {
    let n = diag.len();
    if off.len() != n {
        return Err(Error::Linalg(format!(
            "tridiag_eigen: diag len {n}, off len {} (want equal)",
            off.len()
        )));
    }
    if n == 0 {
        return Ok((vec![], vec![]));
    }
    let mut d = diag.to_vec();
    let mut e = off.to_vec();
    // Shift e down: e[i] couples i and i+1 internally; e[n-1] = 0.
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;
    // z starts as identity; accumulates rotations -> eigenvectors.
    let mut z = vec![vec![0.0; n]; n];
    for (i, zi) in z.iter_mut().enumerate() {
        zi[i] = 1.0;
    }

    for l in 0..n {
        let mut iter = 0;
        loop {
            // Find a small off-diagonal element to split the problem.
            let mut m = l;
            while m < n - 1 {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            if iter > 50 {
                return Err(Error::Linalg(
                    "tql2: too many iterations (50)".to_string(),
                ));
            }
            // Form the implicit shift.
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = g.hypot(1.0);
            g = d[m] - d[l] + e[l] / (g + if g >= 0.0 { r.abs() } else { -r.abs() });
            let (mut s, mut c) = (1.0, 1.0);
            let mut p = 0.0;
            for i in (l..m).rev() {
                let mut f = s * e[i];
                let b = c * e[i];
                r = f.hypot(g);
                e[i + 1] = r;
                if r == 0.0 {
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                // Accumulate the rotation into the eigenvector matrix.
                for zk in z.iter_mut() {
                    f = zk[i + 1];
                    zk[i + 1] = s * zk[i] + c * f;
                    zk[i] = c * zk[i] - s * f;
                }
            }
            if r == 0.0 && m > l + 1 {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }

    // Sort ascending, permuting eigenvector columns along.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| d[a].partial_cmp(&d[b]).unwrap());
    let vals: Vec<f64> = order.iter().map(|&i| d[i]).collect();
    let mut vecs = vec![vec![0.0; n]; n];
    for (new_c, &old_c) in order.iter().enumerate() {
        for i in 0..n {
            vecs[i][new_c] = z[i][old_c];
        }
    }
    Ok((vals, vecs))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_decomposition(diag: &[f64], off: &[f64], tol: f64) {
        let n = diag.len();
        let (vals, vecs) = tridiag_eigen(diag, off).unwrap();
        // T v_k = lambda_k v_k for every k.
        for k in 0..n {
            for i in 0..n {
                let mut tv = diag[i] * vecs[i][k];
                if i > 0 {
                    tv += off[i] * vecs[i - 1][k];
                }
                if i + 1 < n {
                    tv += off[i + 1] * vecs[i + 1][k];
                }
                assert!(
                    (tv - vals[k] * vecs[i][k]).abs() < tol,
                    "residual at ({i},{k}): {tv} vs {}",
                    vals[k] * vecs[i][k]
                );
            }
        }
        // Eigenvectors orthonormal.
        for a in 0..n {
            for b in 0..n {
                let d: f64 = (0..n).map(|i| vecs[i][a] * vecs[i][b]).sum();
                let expect = if a == b { 1.0 } else { 0.0 };
                assert!((d - expect).abs() < tol, "ortho ({a},{b}): {d}");
            }
        }
        // Sorted ascending.
        for k in 1..n {
            assert!(vals[k] >= vals[k - 1]);
        }
    }

    #[test]
    fn two_by_two_analytic() {
        // [[2, 1], [1, 2]] -> eigenvalues 1 and 3.
        let (vals, _) = tridiag_eigen(&[2.0, 2.0], &[0.0, 1.0]).unwrap();
        assert!((vals[0] - 1.0).abs() < 1e-12);
        assert!((vals[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn diagonal_matrix_trivial() {
        let (vals, _) = tridiag_eigen(&[3.0, 1.0, 2.0], &[0.0, 0.0, 0.0]).unwrap();
        assert_eq!(vals, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn laplacian_path_graph() {
        // Path-graph Laplacian (tridiagonal): eigenvalues 2 - 2 cos(k pi / n).
        let n = 8;
        let diag: Vec<f64> = (0..n)
            .map(|i| if i == 0 || i == n - 1 { 1.0 } else { 2.0 })
            .collect();
        let mut off = vec![-1.0; n];
        off[0] = 0.0;
        let (vals, _) = tridiag_eigen(&diag, &off).unwrap();
        for (k, &v) in vals.iter().enumerate() {
            let expect = 2.0 - 2.0 * (std::f64::consts::PI * k as f64 / n as f64).cos();
            assert!((v - expect).abs() < 1e-10, "k={k}: {v} vs {expect}");
        }
    }

    #[test]
    fn random_tridiagonals_full_checks() {
        use crate::util::Xoshiro256;
        let mut rng = Xoshiro256::new(123);
        for n in [1usize, 2, 3, 5, 16, 33] {
            let diag: Vec<f64> = (0..n).map(|_| rng.next_f64() * 4.0 - 2.0).collect();
            let mut off: Vec<f64> =
                (0..n).map(|_| rng.next_f64() * 2.0 - 1.0).collect();
            off[0] = 0.0;
            check_decomposition(&diag, &off, 1e-8);
        }
    }

    #[test]
    fn empty_and_mismatched() {
        assert!(tridiag_eigen(&[], &[]).unwrap().0.is_empty());
        assert!(tridiag_eigen(&[1.0], &[0.0, 0.0]).is_err());
    }
}
