//! Dense vector primitives used by the Lanczos iteration and k-means.

/// Dot product.
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Euclidean norm.
pub fn norm(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// y += alpha * x.
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// x *= alpha.
pub fn scale(alpha: f64, x: &mut [f64]) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

/// Normalize in place; returns the original norm (0 leaves x untouched).
pub fn normalize(x: &mut [f64]) -> f64 {
    let n = norm(x);
    if n > 0.0 {
        scale(1.0 / n, x);
    }
    n
}

/// Squared Euclidean distance.
pub fn sq_dist(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// Squared Euclidean distance with an abort bound: `None` as soon as the
/// running sum **strictly** exceeds `bound` (the pair cannot matter),
/// `Some(d2)` otherwise. The accumulation order matches [`sq_dist`], so a
/// completed result is bit-identical to the unbounded kernel — the
/// property the t-NN index-equivalence tests rely on. Equality with the
/// bound never aborts, because a tie may still be admitted downstream.
pub fn sq_dist_bounded(a: &[f64], b: &[f64], bound: f64) -> Option<f64> {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        let d = x - y;
        acc += d * d;
        if acc > bound {
            return None;
        }
    }
    Some(acc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_and_norm() {
        assert_eq!(dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
        assert!((norm(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn axpy_scale() {
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[3.0, -1.0], &mut y);
        assert_eq!(y, vec![7.0, -1.0]);
        scale(0.5, &mut y);
        assert_eq!(y, vec![3.5, -0.5]);
    }

    #[test]
    fn normalize_unit_and_zero() {
        let mut x = vec![3.0, 4.0];
        let n = normalize(&mut x);
        assert!((n - 5.0).abs() < 1e-12);
        assert!((norm(&x) - 1.0).abs() < 1e-12);
        let mut z = vec![0.0, 0.0];
        assert_eq!(normalize(&mut z), 0.0);
        assert_eq!(z, vec![0.0, 0.0]);
    }

    #[test]
    fn sq_dist_symmetric() {
        let a = [1.0, 2.0];
        let b = [4.0, 6.0];
        assert_eq!(sq_dist(&a, &b), 25.0);
        assert_eq!(sq_dist(&a, &b), sq_dist(&b, &a));
        assert_eq!(sq_dist(&a, &a), 0.0);
    }

    #[test]
    fn sq_dist_bounded_aborts_late_and_matches_bitwise() {
        let a = [1.0, 2.0, 3.5];
        let b = [4.0, 6.0, -0.25];
        // Generous bound: completed result is bit-identical to sq_dist.
        let full = sq_dist(&a, &b);
        assert_eq!(sq_dist_bounded(&a, &b, f64::INFINITY), Some(full));
        assert_eq!(
            sq_dist_bounded(&a, &b, full).map(f64::to_bits),
            Some(full.to_bits()),
            "equality with the bound must not abort"
        );
        // Tight bound: aborts (first dim already contributes 9).
        assert_eq!(sq_dist_bounded(&a, &b, 5.0), None);
        // Boundary: the running sum equals the bound mid-way — no abort.
        assert_eq!(sq_dist_bounded(&a, &b, 25.0), None, "third dim exceeds");
        assert_eq!(sq_dist_bounded(&a[..2], &b[..2], 25.0), Some(25.0));
    }
}
