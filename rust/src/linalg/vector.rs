//! Dense vector primitives used by the eigensolvers and k-means.
//!
//! [`dot`], [`axpy`] and the block mat-vec inner loop
//! ([`super::sparse::CsrMatrix::spmv_block_rows`]) are 4-way unrolled with
//! independent accumulator lanes: the unrolling breaks the sequential
//! floating-point dependency chain, and the lanes fold through a **fixed
//! reduction tree** `((acc0+acc1)+(acc2+acc3)) + tail`, so the result is a
//! pure function of the input lengths and values — the same everywhere the
//! kernel runs. That determinism is what lets the distributed eigen phase
//! and its single-machine oracle compare byte-for-byte.
//!
//! [`sq_dist`]/[`sq_dist_bounded`] deliberately stay sequential: their
//! documented contract is that a completed bounded scan is bit-identical to
//! the unbounded one, which requires identical (left-to-right) accumulation
//! order in both.

/// Accumulator lanes in the unrolled kernels. The unrolled bodies and the
/// final reduction trees hardcode 4 where they mean `NUM_ACC`; the constant
/// documents intent and sizes the scratch in the block mat-vec.
pub const NUM_ACC: usize = 4;

/// Dot product. 4-way unrolled multi-accumulator with an explicit tail:
/// lanes are summed through a fixed tree, the 0..3 leftover elements
/// accumulate separately and fold in last, so the reduction order depends
/// only on `a.len()` — deterministic across every call site.
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let len = a.len();
    let (mut acc0, mut acc1, mut acc2, mut acc3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    let mut i = 0;
    while i + NUM_ACC <= len {
        acc0 += a[i] * b[i];
        acc1 += a[i + 1] * b[i + 1];
        acc2 += a[i + 2] * b[i + 2];
        acc3 += a[i + 3] * b[i + 3];
        i += NUM_ACC;
    }
    let mut tail = 0.0f64;
    while i < len {
        tail += a[i] * b[i];
        i += 1;
    }
    ((acc0 + acc1) + (acc2 + acc3)) + tail
}

/// Euclidean norm.
pub fn norm(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// y += alpha * x. 4-way unrolled with an explicit tail; each element is
/// updated independently, so the result is bit-identical to the scalar
/// loop by construction.
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    let len = x.len();
    let mut i = 0;
    while i + NUM_ACC <= len {
        y[i] += alpha * x[i];
        y[i + 1] += alpha * x[i + 1];
        y[i + 2] += alpha * x[i + 2];
        y[i + 3] += alpha * x[i + 3];
        i += NUM_ACC;
    }
    while i < len {
        y[i] += alpha * x[i];
        i += 1;
    }
}

/// x *= alpha. 4-way unrolled with an explicit tail, like [`axpy`]: the
/// update is element-wise, so the unrolled form is bit-identical to the
/// scalar loop by construction.
pub fn scale(alpha: f64, x: &mut [f64]) {
    let len = x.len();
    let mut i = 0;
    while i + NUM_ACC <= len {
        x[i] *= alpha;
        x[i + 1] *= alpha;
        x[i + 2] *= alpha;
        x[i + 3] *= alpha;
        i += NUM_ACC;
    }
    while i < len {
        x[i] *= alpha;
        i += 1;
    }
}

/// Normalize in place; returns the original norm (0 leaves x untouched).
/// Routed through the unrolled [`scale`], so every hot vector primitive
/// shares the same blocked shape.
pub fn normalize(x: &mut [f64]) -> f64 {
    let n = norm(x);
    if n > 0.0 {
        scale(1.0 / n, x);
    }
    n
}

/// Squared Euclidean distance.
pub fn sq_dist(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// Squared Euclidean distance with an abort bound: `None` as soon as the
/// running sum **strictly** exceeds `bound` (the pair cannot matter),
/// `Some(d2)` otherwise. The accumulation order matches [`sq_dist`], so a
/// completed result is bit-identical to the unbounded kernel — the
/// property the t-NN index-equivalence tests rely on. Equality with the
/// bound never aborts, because a tie may still be admitted downstream.
pub fn sq_dist_bounded(a: &[f64], b: &[f64], bound: f64) -> Option<f64> {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        let d = x - y;
        acc += d * d;
        if acc > bound {
            return None;
        }
    }
    Some(acc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_and_norm() {
        assert_eq!(dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
        assert!((norm(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn axpy_scale() {
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[3.0, -1.0], &mut y);
        assert_eq!(y, vec![7.0, -1.0]);
        scale(0.5, &mut y);
        assert_eq!(y, vec![3.5, -0.5]);
    }

    #[test]
    fn normalize_unit_and_zero() {
        let mut x = vec![3.0, 4.0];
        let n = normalize(&mut x);
        assert!((n - 5.0).abs() < 1e-12);
        assert!((norm(&x) - 1.0).abs() < 1e-12);
        let mut z = vec![0.0, 0.0];
        assert_eq!(normalize(&mut z), 0.0);
        assert_eq!(z, vec![0.0, 0.0]);
    }

    /// Scalar reference implementations: what `dot`/`axpy` looked like
    /// before unrolling. The unrolled `axpy` must match bitwise for any
    /// length (element-wise update, order unchanged); the unrolled `dot`
    /// must be deterministic and exact on integer-valued inputs.
    fn dot_scalar(a: &[f64], b: &[f64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| x * y).sum()
    }

    fn axpy_scalar(alpha: f64, x: &[f64], y: &mut [f64]) {
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi += alpha * xi;
        }
    }

    fn pseudo(seed: u64, len: usize) -> Vec<f64> {
        let mut s = seed;
        (0..len)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                (s >> 11) as f64 / (1u64 << 53) as f64 * 2.0 - 1.0
            })
            .collect()
    }

    #[test]
    fn unrolled_dot_handles_every_tail_length() {
        // Integer-valued inputs are exact in f64, so every summation order
        // gives the same answer — the unrolled kernel must hit it for all
        // tail lengths 0..NUM_ACC around several multiples of the stride.
        for len in 0..=13 {
            let a: Vec<f64> = (0..len).map(|i| (i + 1) as f64).collect();
            let b: Vec<f64> = (0..len).map(|i| (2 * i) as f64 - 3.0).collect();
            assert_eq!(dot(&a, &b), dot_scalar(&a, &b), "len={len}");
        }
    }

    #[test]
    fn unrolled_dot_is_deterministic_and_close_to_scalar() {
        for len in [1usize, 3, 4, 5, 8, 17, 256, 1001] {
            let a = pseudo(0x5eed ^ len as u64, len);
            let b = pseudo(0xbeef ^ len as u64, len);
            let d1 = dot(&a, &b);
            let d2 = dot(&a, &b);
            assert_eq!(d1.to_bits(), d2.to_bits(), "determinism len={len}");
            let reference = dot_scalar(&a, &b);
            let scale = 1.0 + reference.abs();
            assert!(
                (d1 - reference).abs() <= 1e-12 * scale,
                "len={len}: {d1} vs {reference}"
            );
        }
    }

    #[test]
    fn unrolled_axpy_is_bit_identical_to_scalar() {
        for len in [0usize, 1, 3, 4, 5, 8, 17, 256, 1001] {
            let x = pseudo(0xabc ^ len as u64, len);
            let mut y1 = pseudo(0xdef ^ len as u64, len);
            let mut y2 = y1.clone();
            axpy(-0.3721, &x, &mut y1);
            axpy_scalar(-0.3721, &x, &mut y2);
            let bits = |v: &[f64]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&y1), bits(&y2), "len={len}");
        }
    }

    #[test]
    fn unrolled_scale_is_bit_identical_to_scalar() {
        for len in [0usize, 1, 3, 4, 5, 8, 17, 256, 1001] {
            let mut y1 = pseudo(0x5ca1e ^ len as u64, len);
            let mut y2 = y1.clone();
            scale(-0.3721, &mut y1);
            for yi in y2.iter_mut() {
                *yi *= -0.3721;
            }
            let bits = |v: &[f64]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&y1), bits(&y2), "len={len}");
        }
    }

    #[test]
    fn sq_dist_symmetric() {
        let a = [1.0, 2.0];
        let b = [4.0, 6.0];
        assert_eq!(sq_dist(&a, &b), 25.0);
        assert_eq!(sq_dist(&a, &b), sq_dist(&b, &a));
        assert_eq!(sq_dist(&a, &a), 0.0);
    }

    #[test]
    fn sq_dist_bounded_aborts_late_and_matches_bitwise() {
        let a = [1.0, 2.0, 3.5];
        let b = [4.0, 6.0, -0.25];
        // Generous bound: completed result is bit-identical to sq_dist.
        let full = sq_dist(&a, &b);
        assert_eq!(sq_dist_bounded(&a, &b, f64::INFINITY), Some(full));
        assert_eq!(
            sq_dist_bounded(&a, &b, full).map(f64::to_bits),
            Some(full.to_bits()),
            "equality with the bound must not abort"
        );
        // Tight bound: aborts (first dim already contributes 9).
        assert_eq!(sq_dist_bounded(&a, &b, 5.0), None);
        // Boundary: the running sum equals the bound mid-way — no abort.
        assert_eq!(sq_dist_bounded(&a, &b, 25.0), None, "third dim exceeds");
        assert_eq!(sq_dist_bounded(&a[..2], &b[..2], 25.0), Some(25.0));
    }
}
