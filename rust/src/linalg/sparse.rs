//! CSR sparse matrix: the row-partitioned storage format of the Laplacian.
//!
//! Rows of this structure are what phase 1 writes into the mini-HBase table
//! and what phase 2's distributed mat-vec map tasks consume (paper §4.3.2:
//! "the matrix L on the HBase stored … when the line to the segmentation
//! store" — i.e. row-wise partitioning).

use crate::error::{Error, Result};

use super::dense::DenseMatrix;
use super::kernels::{self, CsrView};

/// Compressed-sparse-row matrix of f64.
#[derive(Debug, Clone, PartialEq)]
pub struct CsrMatrix {
    rows: usize,
    cols: usize,
    indptr: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<f64>,
}

impl CsrMatrix {
    /// Build from (row, col, value) triplets; duplicate entries are summed.
    pub fn from_triplets(
        rows: usize,
        cols: usize,
        triplets: &[(usize, usize, f64)],
    ) -> Result<Self> {
        for &(i, j, _) in triplets {
            if i >= rows || j >= cols {
                return Err(Error::Linalg(format!(
                    "triplet ({i},{j}) out of {rows}x{cols}"
                )));
            }
        }
        let mut sorted: Vec<_> = triplets.to_vec();
        sorted.sort_unstable_by_key(|&(i, j, _)| (i, j));
        let mut indptr = vec![0usize; rows + 1];
        let mut indices: Vec<u32> = Vec::with_capacity(sorted.len());
        let mut values: Vec<f64> = Vec::with_capacity(sorted.len());
        let mut prev: Option<(usize, usize)> = None;
        for &(i, j, v) in &sorted {
            if prev == Some((i, j)) {
                *values.last_mut().unwrap() += v; // duplicate: sum
                continue;
            }
            prev = Some((i, j));
            indices.push(j as u32);
            values.push(v);
            indptr[i + 1] += 1;
        }
        for i in 0..rows {
            indptr[i + 1] += indptr[i];
        }
        Ok(Self { rows, cols, indptr, indices, values })
    }

    /// Build from per-row (col, value) lists (already deduplicated/sorted).
    pub fn from_rows(cols: usize, rows: Vec<Vec<(u32, f64)>>) -> Self {
        let nrows = rows.len();
        let nnz: usize = rows.iter().map(|r| r.len()).sum();
        let mut indptr = Vec::with_capacity(nrows + 1);
        let mut indices = Vec::with_capacity(nnz);
        let mut values = Vec::with_capacity(nnz);
        indptr.push(0);
        for mut r in rows {
            r.sort_unstable_by_key(|&(j, _)| j);
            for (j, v) in r {
                debug_assert!((j as usize) < cols);
                indices.push(j);
                values.push(v);
            }
            indptr.push(indices.len());
        }
        Self { rows: nrows, cols, indptr, indices, values }
    }

    /// Row count.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Column count.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Number of stored entries in row `i` (O(1)).
    pub fn row_nnz(&self, i: usize) -> usize {
        self.indptr[i + 1] - self.indptr[i]
    }

    /// Sparse entries of row `i` as (col, value) pairs.
    pub fn row(&self, i: usize) -> impl Iterator<Item = (u32, f64)> + '_ {
        let range = self.indptr[i]..self.indptr[i + 1];
        self.indices[range.clone()]
            .iter()
            .copied()
            .zip(self.values[range].iter().copied())
    }

    /// Borrowed view of the storage arrays — the form the
    /// [`kernels`](super::kernels) mat-vec routines consume.
    pub fn view(&self) -> CsrView<'_> {
        CsrView {
            indptr: &self.indptr,
            indices: &self.indices,
            values: &self.values,
        }
    }

    /// y = A x. Routed through the row-blocked kernel
    /// ([`kernels::spmv_rows_into`]); bit-identical to the per-row scalar
    /// scan by the kernel-layer contract (DESIGN.md §2.14).
    pub fn spmv(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols, "spmv dimension mismatch");
        let mut y = vec![0.0; self.rows];
        kernels::spmv_rows_into(self.view(), x, 0, self.rows, &mut y);
        y
    }

    /// spmv restricted to a row range [lo, hi) — one MR map task's work.
    /// Same kernel as [`Self::spmv`]; rows never share accumulators, so
    /// any task partition reassembles bit-identically to the full scan.
    pub fn spmv_rows(&self, x: &[f64], lo: usize, hi: usize) -> Vec<f64> {
        assert!(lo <= hi && hi <= self.rows);
        let mut y = vec![0.0; hi - lo];
        kernels::spmv_rows_into(self.view(), x, lo, hi, &mut y);
        y
    }

    /// Block spmv restricted to a row range: `Y[lo..hi) = A[lo..hi) · X`
    /// for an n×m column block. `x` is row-major (`x[j*m + c]` is row `j`,
    /// column `c` — the layout of the coordinator's multi-vector table
    /// records); the result is row-major `(hi-lo)×m`.
    ///
    /// The body lives in [`kernels::spmv_block_rows_into`]: `NUM_ACC`
    /// lanes of m-wide scratch accumulate the row's stored entries, an
    /// explicit tail lane takes the 0..3 leftovers, and each output folds
    /// through the fixed tree `((l0+l1)+(l2+l3)) + tail`. Every output row
    /// depends only on that row's entries and `x` — never on `[lo, hi)` —
    /// so any task partitioning of the row space reassembles
    /// bit-identically to the single-machine call over `[0, n)`. The
    /// distributed ChebDav job and its oracle rely on exactly this.
    pub fn spmv_block_rows(&self, x: &[f64], m: usize, lo: usize, hi: usize) -> Vec<f64> {
        assert!(lo <= hi && hi <= self.rows);
        assert!(m > 0, "spmv_block_rows needs at least one column");
        assert_eq!(x.len(), self.cols * m, "spmv_block dimension mismatch");
        let mut y = vec![0.0f64; (hi - lo) * m];
        kernels::spmv_block_rows_into(self.view(), x, m, lo, hi, &mut y);
        y
    }

    /// Row sums (degrees when self is a similarity/adjacency matrix).
    pub fn row_sums(&self) -> Vec<f64> {
        (0..self.rows)
            .map(|i| self.row(i).map(|(_, v)| v).sum())
            .collect()
    }

    /// Densify (small matrices / tests only).
    pub fn to_dense(&self) -> DenseMatrix {
        let mut d = DenseMatrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            for (j, v) in self.row(i) {
                d[(i, j as usize)] = v;
            }
        }
        d
    }

    /// Is the matrix symmetric to within `tol`? (O(nnz log nnz) via lookup.)
    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.rows != self.cols {
            return false;
        }
        for i in 0..self.rows {
            for (j, v) in self.row(i) {
                let vt = self.get(j as usize, i);
                if (v - vt).abs() > tol {
                    return false;
                }
            }
        }
        true
    }

    /// Entry lookup (binary search within the row).
    pub fn get(&self, i: usize, j: usize) -> f64 {
        let range = self.indptr[i]..self.indptr[i + 1];
        match self.indices[range.clone()].binary_search(&(j as u32)) {
            Ok(pos) => self.values[range.start + pos],
            Err(_) => 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CsrMatrix {
        // [[1, 0, 2],
        //  [0, 3, 0],
        //  [4, 0, 5]]
        CsrMatrix::from_triplets(
            3,
            3,
            &[(0, 0, 1.0), (0, 2, 2.0), (1, 1, 3.0), (2, 0, 4.0), (2, 2, 5.0)],
        )
        .unwrap()
    }

    #[test]
    fn triplets_roundtrip() {
        let m = sample();
        assert_eq!(m.nnz(), 5);
        assert_eq!(m.get(0, 2), 2.0);
        assert_eq!(m.get(0, 1), 0.0);
        assert_eq!(m.get(2, 2), 5.0);
    }

    #[test]
    fn duplicate_triplets_sum() {
        let m = CsrMatrix::from_triplets(2, 2, &[(0, 0, 1.0), (0, 0, 2.5)]).unwrap();
        assert_eq!(m.get(0, 0), 3.5);
        assert_eq!(m.nnz(), 1);
    }

    #[test]
    fn out_of_bounds_triplet_rejected() {
        assert!(CsrMatrix::from_triplets(2, 2, &[(2, 0, 1.0)]).is_err());
        assert!(CsrMatrix::from_triplets(2, 2, &[(0, 5, 1.0)]).is_err());
    }

    #[test]
    fn spmv_matches_dense() {
        let m = sample();
        let x = vec![1.0, 2.0, 3.0];
        assert_eq!(m.spmv(&x), m.to_dense().matvec(&x));
        assert_eq!(m.spmv(&x), vec![7.0, 6.0, 19.0]);
    }

    #[test]
    fn spmv_rows_partitions_agree() {
        let m = sample();
        let x = vec![0.5, -1.0, 2.0];
        let full = m.spmv(&x);
        let mut pieced = m.spmv_rows(&x, 0, 1);
        pieced.extend(m.spmv_rows(&x, 1, 3));
        assert_eq!(pieced, full);
    }

    #[test]
    fn spmv_block_rows_matches_per_column_spmv() {
        let m = sample();
        // 2-column block, row-major: column 0 = [1,2,3], column 1 = [0.5,-1,2].
        let x = vec![1.0, 0.5, 2.0, -1.0, 3.0, 2.0];
        let y = m.spmv_block_rows(&x, 2, 0, 3);
        let c0 = m.spmv(&[1.0, 2.0, 3.0]);
        let c1 = m.spmv(&[0.5, -1.0, 2.0]);
        for r in 0..3 {
            assert_eq!(y[2 * r], c0[r], "col 0 row {r}");
            assert_eq!(y[2 * r + 1], c1[r], "col 1 row {r}");
        }
    }

    #[test]
    fn spmv_block_rows_partitions_reassemble_bitwise() {
        // Long rows (nnz > NUM_ACC) on a wider matrix so both the unrolled
        // body and the tail lane are exercised; any row partitioning must
        // reassemble bit-identically to the full call.
        let n = 23;
        let rows: Vec<Vec<(u32, f64)>> = (0..n)
            .map(|i| {
                (0..n)
                    .filter(|j| (i + j) % 3 != 1)
                    .map(|j| (j as u32, ((i * 31 + j * 17) % 13) as f64 * 0.37 - 1.1))
                    .collect()
            })
            .collect();
        let a = CsrMatrix::from_rows(n, rows);
        let m = 3;
        let x: Vec<f64> = (0..n * m).map(|i| (i as f64 * 0.61).sin()).collect();
        let full = a.spmv_block_rows(&x, m, 0, n);
        let mut pieced = a.spmv_block_rows(&x, m, 0, 7);
        pieced.extend(a.spmv_block_rows(&x, m, 7, 8));
        pieced.extend(a.spmv_block_rows(&x, m, 8, n));
        let bits = |v: &[f64]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&pieced), bits(&full));
    }

    #[test]
    fn spmv_block_rows_single_column_close_to_spmv() {
        // m=1 agrees with the scalar spmv up to reduction-order rounding.
        let m = sample();
        let x = vec![0.5, -1.0, 2.0];
        let y = m.spmv_block_rows(&x, 1, 0, 3);
        let reference = m.spmv(&x);
        for r in 0..3 {
            assert!((y[r] - reference[r]).abs() < 1e-12, "row {r}");
        }
    }

    #[test]
    fn row_sums_and_symmetry() {
        let m = sample();
        assert_eq!(m.row_sums(), vec![3.0, 3.0, 9.0]);
        assert!(!m.is_symmetric(1e-12)); // 2 vs 4 at (0,2)/(2,0)
        let s = CsrMatrix::from_triplets(
            2,
            2,
            &[(0, 1, 7.0), (1, 0, 7.0), (0, 0, 1.0)],
        )
        .unwrap();
        assert!(s.is_symmetric(1e-12));
    }

    #[test]
    fn from_rows_matches_triplets() {
        let by_rows = CsrMatrix::from_rows(
            3,
            vec![
                vec![(2, 2.0), (0, 1.0)], // unsorted on purpose
                vec![(1, 3.0)],
                vec![(0, 4.0), (2, 5.0)],
            ],
        );
        assert_eq!(by_rows, sample());
    }
}
