//! Block Chebyshev–Davidson eigensolver for the k smallest eigenpairs.
//!
//! The alternative phase-2 backend (after "A Distributed Block
//! Chebyshev–Davidson Algorithm for Parallel Spectral Clustering",
//! arXiv:2212.04443): instead of one mat-vec per Krylov step, the solver
//! iterates an m-column block through a degree-d Chebyshev polynomial
//! filter that damps the unwanted upper spectrum `[a, b]` and amplifies the
//! wanted lower end, then extracts Ritz pairs by Rayleigh–Ritz projection.
//! Each filter application is ONE operator application on all m columns at
//! once — in the distributed pipeline, one dataflow job pricing m mat-vecs
//! — so the eigen phase drops from O(steps) jobs to
//! O(outer · (degree + 1)) jobs with far better per-job efficiency.
//!
//! The operator is only touched through a caller-supplied block closure
//! `op(x, m) -> A·X` over row-major n×m blocks, mirroring the mat-vec
//! closure of [`super::lanczos::lanczos_smallest`]. Everything else
//! (orthonormalization, projection, small dense solves, the three-term
//! filter recurrence) is master-side and uses the deterministic unrolled
//! kernels in [`super::vector`], so same-seed runs are byte-identical
//! regardless of how the operator partitions its rows.

use crate::error::{Error, Result};
use crate::util::Xoshiro256;

use super::dense::DenseMatrix;
use super::jacobi::jacobi_eigen;
use super::tridiag::tridiag_eigen;
use super::vector::{axpy, dot, norm, normalize, scale};

/// Options for [`chebdav_smallest`].
#[derive(Debug, Clone)]
pub struct ChebDavOptions {
    /// Block width m (clamped to `max(k, block_size).min(n)` internally).
    pub block_size: usize,
    /// Chebyshev filter degree d: operator applications per filter pass.
    pub filter_degree: usize,
    /// Maximum outer (filter + Rayleigh–Ritz) iterations.
    pub max_outer: usize,
    /// Convergence tolerance on the max residual ‖A·u − θ·u‖ of the first
    /// k Ritz pairs, relative to the spectrum scale (1 + |upper bound|).
    pub tol: f64,
    /// Plain Lanczos steps used to estimate the spectrum bounds [λmin, λmax]
    /// before filtering starts (single-column operator applications).
    pub bound_steps: usize,
    /// Seed for the random start block (and bound-estimation start vector).
    pub seed: u64,
}

impl Default for ChebDavOptions {
    fn default() -> Self {
        Self {
            block_size: 8,
            filter_degree: 8,
            max_outer: 5,
            tol: 1e-6,
            bound_steps: 4,
            seed: 0x5eed,
        }
    }
}

/// Result of a Chebyshev–Davidson run.
#[derive(Debug, Clone)]
pub struct ChebDavResult {
    /// Ritz values (approximate eigenvalues), ascending, `k` of them.
    pub eigenvalues: Vec<f64>,
    /// Ritz vectors, row-major n×k: `eigenvectors[i][j]` = component i of
    /// approximate eigenvector j (same layout as `LanczosResult`).
    pub eigenvectors: Vec<Vec<f64>>,
    /// Outer iterations actually performed.
    pub outer_iters: usize,
    /// Operator applications (each prices one dataflow job distributed).
    pub block_applies: usize,
    /// Total mat-vecs across all applications (Σ block widths).
    pub matvecs: usize,
    /// Estimated spectrum bounds (lower estimate, safe upper bound).
    pub bounds: (f64, f64),
    /// Max residual ‖A·u − θ·u‖ over the first k Ritz pairs at exit.
    pub max_residual: f64,
}

/// Spectrum bounds estimated by a few plain Lanczos steps.
#[derive(Debug, Clone, Copy)]
pub struct SpectrumBounds {
    /// Ritz estimate of λmin (an upper bound on the true λmin).
    pub lower: f64,
    /// Safe upper bound on λmax: θmax + ‖residual‖ of the last step.
    pub upper: f64,
    /// Operator applications spent (= Lanczos steps actually run).
    pub steps: usize,
}

/// Estimate the spectrum bounds of a symmetric n×n operator with `steps`
/// plain Lanczos steps (no reorthogonalization — a handful of steps give a
/// coarse λmin estimate and, via θmax + ‖f‖, a safe λmax upper bound; the
/// margin makes the Chebyshev filter interval contain the whole unwanted
/// spectrum, which is what filter stability needs).
pub fn estimate_spectrum_bounds<F>(
    n: usize,
    steps: usize,
    seed: u64,
    op: &mut F,
) -> Result<SpectrumBounds>
where
    F: FnMut(&[f64], usize) -> Vec<f64>,
{
    if n == 0 {
        return Err(Error::Linalg("spectrum bounds: empty operator".into()));
    }
    let steps = steps.clamp(2, n.max(2)).min(n);
    let mut rng = Xoshiro256::new(seed ^ 0x9e37_79b9_7f4a_7c15);
    let mut v = vec![0.0; n];
    for vi in v.iter_mut() {
        *vi = rng.next_gaussian();
    }
    normalize(&mut v);

    let mut v_prev = vec![0.0; n];
    let mut beta_prev = 0.0f64;
    let mut alphas: Vec<f64> = Vec::with_capacity(steps);
    let mut betas: Vec<f64> = Vec::with_capacity(steps);
    let mut final_beta = 0.0f64;
    for j in 0..steps {
        let mut w = op(&v, 1);
        if j > 0 {
            axpy(-beta_prev, &v_prev, &mut w);
        }
        let alpha = dot(&w, &v);
        axpy(-alpha, &v, &mut w);
        alphas.push(alpha);
        let beta = norm(&w);
        final_beta = beta;
        if j + 1 == steps || beta < 1e-12 {
            // Exhausted Krylov space: the tridiagonal eigenvalues are exact
            // for the invariant subspace found so far.
            break;
        }
        betas.push(beta);
        v_prev = v;
        v = w;
        scale(1.0 / beta, &mut v);
    }

    let m = alphas.len();
    let mut off = vec![0.0; m];
    for j in 1..m {
        off[j] = betas[j - 1];
    }
    let (tvals, _) = tridiag_eigen(&alphas[..m], &off)?;
    Ok(SpectrumBounds {
        lower: tvals[0],
        upper: tvals[m - 1] + final_beta,
        steps: m,
    })
}

/// Modified Gram–Schmidt over the block's columns, done twice ("twice is
/// enough"). A column whose norm collapses below 1e-10 is replaced by a
/// fresh random direction from `rng` (re-orthogonalized against the earlier
/// columns), keeping the basis full-rank; the rng draw order is fixed, so
/// the replacement — like everything else here — is deterministic.
fn orthonormalize_block(cols: &mut [Vec<f64>], rng: &mut Xoshiro256) {
    let m = cols.len();
    for j in 0..m {
        let mut attempts = 0;
        loop {
            for _pass in 0..2 {
                for i in 0..j {
                    let (head, tail) = cols.split_at_mut(j);
                    let c = dot(&tail[0], &head[i]);
                    axpy(-c, &head[i], &mut tail[0]);
                }
            }
            if normalize(&mut cols[j]) > 1e-10 {
                break;
            }
            attempts += 1;
            if attempts > 4 {
                // n columns always fit in R^n; only pathological fp noise
                // gets here — give up with whatever direction we have.
                normalize(&mut cols[j]);
                break;
            }
            for x in cols[j].iter_mut() {
                *x = rng.next_gaussian();
            }
        }
    }
}

/// Flatten m columns of length n into the row-major n×m layout the block
/// operator (and the multi-vector table format) uses.
fn cols_to_flat(cols: &[Vec<f64>], n: usize) -> Vec<f64> {
    let m = cols.len();
    let mut flat = vec![0.0f64; n * m];
    for (c, col) in cols.iter().enumerate() {
        for i in 0..n {
            flat[i * m + c] = col[i];
        }
    }
    flat
}

/// Inverse of [`cols_to_flat`].
fn flat_to_cols(flat: &[f64], n: usize, m: usize) -> Vec<Vec<f64>> {
    let mut cols = vec![vec![0.0f64; n]; m];
    for i in 0..n {
        for (c, col) in cols.iter_mut().enumerate() {
            col[i] = flat[i * m + c];
        }
    }
    cols
}

/// Apply the block operator to m columns: flatten, one `op` call, unflatten.
fn apply_block<F>(op: &mut F, cols: &[Vec<f64>], n: usize) -> Vec<Vec<f64>>
where
    F: FnMut(&[f64], usize) -> Vec<f64>,
{
    let m = cols.len();
    let flat = cols_to_flat(cols, n);
    let out = op(&flat, m);
    debug_assert_eq!(out.len(), n * m, "block operator shape mismatch");
    flat_to_cols(&out, n, m)
}

/// Degree-d Chebyshev filter on the block (Zhou–Saad scaled three-term
/// recurrence). Damps `[a, b]` and amplifies below `a`; `a0 < a` is the
/// current λmin estimate setting the scaling reference. Costs exactly
/// `degree` operator applications.
fn cheb_filter<F>(
    op: &mut F,
    x: &[Vec<f64>],
    n: usize,
    degree: usize,
    a: f64,
    b: f64,
    a0: f64,
) -> Vec<Vec<f64>>
where
    F: FnMut(&[f64], usize) -> Vec<f64>,
{
    let e = (b - a) / 2.0;
    let c = (b + a) / 2.0;
    let sigma1 = e / (a0 - c);
    let mut sigma = sigma1;

    // Y = (A·X − c·X) · (σ1 / e)
    let ax = apply_block(op, x, n);
    let mut y: Vec<Vec<f64>> = Vec::with_capacity(x.len());
    for (col_ax, col_x) in ax.iter().zip(x) {
        let mut yc = col_ax.clone();
        axpy(-c, col_x, &mut yc);
        scale(sigma1 / e, &mut yc);
        y.push(yc);
    }

    let mut x_prev: Vec<Vec<f64>> = x.to_vec();
    for _deg in 2..=degree {
        let sigma_new = 1.0 / (2.0 / sigma1 - sigma);
        let ay = apply_block(op, &y, n);
        let mut y_new: Vec<Vec<f64>> = Vec::with_capacity(y.len());
        for ((col_ay, col_y), col_xp) in ay.iter().zip(&y).zip(&x_prev) {
            // Ynew = (A·Y − c·Y) · (2σnew/e) − (σ·σnew)·Xprev
            let mut yc = col_ay.clone();
            axpy(-c, col_y, &mut yc);
            scale(2.0 * sigma_new / e, &mut yc);
            axpy(-(sigma * sigma_new), col_xp, &mut yc);
            y_new.push(yc);
        }
        x_prev = y;
        y = y_new;
        sigma = sigma_new;
    }
    y
}

/// Compute the `k` smallest eigenpairs of a symmetric n×n operator with the
/// block Chebyshev–Davidson iteration.
///
/// `op(x, m) -> A·X` over row-major n×m blocks is the only access to the
/// matrix. Each outer iteration costs `filter_degree + 1` operator
/// applications (filter passes + the Rayleigh–Ritz projection); the bound
/// estimation up front costs `bound_steps` single-column applications.
/// Like `lanczos_smallest`, the best available Ritz pairs are returned even
/// if the residual tolerance was not reached within `max_outer` iterations
/// (`max_residual` reports how far convergence got).
pub fn chebdav_smallest<F>(
    n: usize,
    k: usize,
    opts: &ChebDavOptions,
    mut op: F,
) -> Result<ChebDavResult>
where
    F: FnMut(&[f64], usize) -> Vec<f64>,
{
    if k == 0 || n == 0 {
        return Err(Error::Linalg(format!("chebdav: degenerate k={k}, n={n}")));
    }
    if k > n {
        return Err(Error::Linalg(format!("chebdav: k={k} > n={n}")));
    }
    if opts.filter_degree == 0 {
        return Err(Error::Linalg("chebdav: filter_degree must be >= 1".into()));
    }
    if opts.max_outer == 0 {
        return Err(Error::Linalg("chebdav: max_outer must be >= 1".into()));
    }
    let m = opts.block_size.max(k).min(n);

    let mut block_applies = 0usize;
    let mut matvecs = 0usize;

    // Bounds first: the filter interval must cover the unwanted spectrum.
    let bounds = {
        let mut counted = |x: &[f64], w: usize| {
            block_applies += 1;
            matvecs += w;
            op(x, w)
        };
        estimate_spectrum_bounds(n, opts.bound_steps, opts.seed, &mut counted)?
    };
    let lo_est = bounds.lower;
    let mut upper = bounds.upper;
    let mut span = upper - lo_est;
    if span < 1e-12 {
        // Degenerate spectrum (A ≈ λI): widen artificially so the filter
        // recurrence stays well-defined; RR converges in one pass anyway.
        upper = lo_est + 1.0;
        span = 1.0;
    }

    // Random start block, orthonormalized.
    let mut rng = Xoshiro256::new(opts.seed);
    let mut v: Vec<Vec<f64>> = (0..m)
        .map(|_| (0..n).map(|_| rng.next_gaussian()).collect())
        .collect();
    orthonormalize_block(&mut v, &mut rng);

    // Filter lower edge: start a little above the λmin estimate; updated
    // each outer iteration from the Ritz values (the first unwanted one).
    let mut a_filter = lo_est + 0.1 * span;

    let mut outer_iters = 0usize;
    let mut max_residual = f64::INFINITY;
    let mut eigenvalues: Vec<f64> = Vec::new();
    let mut ritz: Vec<Vec<f64>> = Vec::new();

    for _outer in 0..opts.max_outer {
        // Filter the block (degree operator applications)...
        let mut filtered = {
            let mut counted = |x: &[f64], w: usize| {
                block_applies += 1;
                matvecs += w;
                op(x, w)
            };
            cheb_filter(
                &mut counted,
                &v,
                n,
                opts.filter_degree,
                a_filter,
                upper,
                lo_est,
            )
        };
        // ...orthonormalize, and project (one more application).
        orthonormalize_block(&mut filtered, &mut rng);
        let w = {
            let mut counted = |x: &[f64], wd: usize| {
                block_applies += 1;
                matvecs += wd;
                op(x, wd)
            };
            apply_block(&mut counted, &filtered, n)
        };

        // H = Xᵀ A X, symmetrized explicitly (jacobi_eigen requires it).
        let mut h = DenseMatrix::zeros(m, m);
        for i in 0..m {
            for j in i..m {
                let hij = 0.5 * (dot(&filtered[i], &w[j]) + dot(&filtered[j], &w[i]));
                h[(i, j)] = hij;
                h[(j, i)] = hij;
            }
        }
        let (theta, q) = jacobi_eigen(&h)?;

        // Ritz vectors u_c = Σ_j q[j][c] x_j (all m become the next block);
        // residuals on the first k, using A·u_c = Σ_j q[j][c] w_j.
        let mut u: Vec<Vec<f64>> = vec![vec![0.0; n]; m];
        for (c, uc) in u.iter_mut().enumerate() {
            for (j, xj) in filtered.iter().enumerate() {
                axpy(q[(j, c)], xj, uc);
            }
        }
        let mut worst = 0.0f64;
        for c in 0..k {
            let mut r = vec![0.0; n];
            for (j, wj) in w.iter().enumerate() {
                axpy(q[(j, c)], wj, &mut r);
            }
            axpy(-theta[c], &u[c], &mut r);
            worst = worst.max(norm(&r));
        }

        outer_iters += 1;
        max_residual = worst;
        eigenvalues = theta[..k].to_vec();
        ritz = u.iter().take(k).cloned().collect();

        if worst <= opts.tol * (1.0 + upper.abs()) {
            break;
        }

        // Next round: iterate the Ritz block, filter everything above the
        // first unwanted Ritz value (clamped inside the estimated spectrum
        // so the interval never collapses or escapes).
        let proposed = if m > k { theta[k] } else { theta[m - 1] + 1e-3 * span };
        a_filter = proposed.clamp(lo_est + 0.01 * span, upper - 0.1 * span);
        v = u;
    }

    // Row-major n×k, the LanczosResult layout.
    let mut eigenvectors = vec![vec![0.0; k]; n];
    for (c, rc) in ritz.iter().enumerate() {
        for i in 0..n {
            eigenvectors[i][c] = rc[i];
        }
    }
    Ok(ChebDavResult {
        eigenvalues,
        eigenvectors,
        outer_iters,
        block_applies,
        matvecs,
        bounds: (lo_est, upper),
        max_residual,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::jacobi::jacobi_eigen;
    use crate::linalg::sparse::CsrMatrix;

    fn random_symmetric(n: usize, seed: u64) -> DenseMatrix {
        let mut rng = Xoshiro256::new(seed);
        let mut m = DenseMatrix::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let v = rng.next_f64() * 2.0 - 1.0;
                m[(i, j)] = v;
                m[(j, i)] = v;
            }
        }
        m
    }

    /// Dense block operator: column-by-column matvec, row-major in/out.
    fn dense_block_op(a: &DenseMatrix) -> impl FnMut(&[f64], usize) -> Vec<f64> + '_ {
        move |x: &[f64], m: usize| {
            let n = a.rows();
            let mut y = vec![0.0f64; n * m];
            for c in 0..m {
                let col: Vec<f64> = (0..n).map(|r| x[r * m + c]).collect();
                let ac = a.matvec(&col);
                for r in 0..n {
                    y[r * m + c] = ac[r];
                }
            }
            y
        }
    }

    #[test]
    fn bounds_bracket_the_spectrum() {
        let n = 30;
        let a = random_symmetric(n, 404);
        let (jvals, _) = jacobi_eigen(&a).unwrap();
        let b = estimate_spectrum_bounds(n, 6, 1, &mut dense_block_op(&a)).unwrap();
        // The Ritz λmin estimate approaches from above; the upper bound
        // carries a ‖f‖ safety margin and must clear the true λmax.
        assert!(b.lower >= jvals[0] - 1e-9, "{} < {}", b.lower, jvals[0]);
        assert!(b.lower <= jvals[n - 1] + 1e-9);
        assert!(b.upper >= jvals[n - 1] - 1e-9, "{} < {}", b.upper, jvals[n - 1]);
        assert!(b.steps >= 2 && b.steps <= 6);
    }

    #[test]
    fn matches_jacobi_on_dense_random() {
        let n = 30;
        let a = random_symmetric(n, 2024);
        let (jvals, _) = jacobi_eigen(&a).unwrap();
        let opts = ChebDavOptions {
            block_size: 8,
            filter_degree: 10,
            max_outer: 60,
            tol: 1e-9,
            ..Default::default()
        };
        let r = chebdav_smallest(n, 3, &opts, dense_block_op(&a)).unwrap();
        for i in 0..3 {
            assert!(
                (r.eigenvalues[i] - jvals[i]).abs() < 1e-6,
                "eig {i}: {} vs {} (residual {})",
                r.eigenvalues[i],
                jvals[i],
                r.max_residual
            );
        }
        assert!(r.max_residual < 1e-6 * (1.0 + r.bounds.1.abs()) * 10.0);
        // Cost accounting: bounds + outer·(degree+1) operator applications.
        assert_eq!(
            r.block_applies,
            r.outer_iters * (opts.filter_degree + 1) + estimate_applies(n, &opts)
        );
        assert!(r.matvecs >= r.block_applies);
    }

    fn estimate_applies(n: usize, opts: &ChebDavOptions) -> usize {
        // The bound estimator runs at most bound_steps (≥2) Lanczos steps.
        opts.bound_steps.clamp(2, n)
    }

    #[test]
    fn ritz_pairs_satisfy_residual_bound() {
        let n = 25;
        let a = random_symmetric(n, 77);
        let k = 4;
        let opts = ChebDavOptions {
            block_size: 8,
            filter_degree: 10,
            max_outer: 60,
            tol: 1e-8,
            ..Default::default()
        };
        let r = chebdav_smallest(n, k, &opts, dense_block_op(&a)).unwrap();
        for c in 0..k {
            let vc: Vec<f64> = (0..n).map(|i| r.eigenvectors[i][c]).collect();
            let av = a.matvec(&vc);
            for i in 0..n {
                assert!(
                    (av[i] - r.eigenvalues[c] * vc[i]).abs() < 1e-5,
                    "residual c={c} i={i}"
                );
            }
        }
    }

    #[test]
    fn laplacian_zero_eigenvalues_found() {
        // Two disjoint triangles: eigenvalue 0 with multiplicity 2, then a
        // gap — the shape the spectral embedding depends on.
        let mut trips = vec![];
        for base in [0usize, 3] {
            for a in 0..3usize {
                for b in 0..3usize {
                    if a != b {
                        trips.push((base + a, base + b, -1.0));
                    }
                }
                trips.push((base + a, base + a, 2.0));
            }
        }
        let l = CsrMatrix::from_triplets(6, 6, &trips).unwrap();
        let opts = ChebDavOptions {
            block_size: 4,
            filter_degree: 6,
            max_outer: 40,
            tol: 1e-9,
            ..Default::default()
        };
        let r =
            chebdav_smallest(6, 3, &opts, |x, m| l.spmv_block_rows(x, m, 0, 6)).unwrap();
        assert!(r.eigenvalues[0].abs() < 1e-7, "{:?}", r.eigenvalues);
        assert!(r.eigenvalues[1].abs() < 1e-7, "{:?}", r.eigenvalues);
        assert!(r.eigenvalues[2] > 1.0, "{:?}", r.eigenvalues);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = random_symmetric(20, 5);
        let opts = ChebDavOptions {
            block_size: 6,
            filter_degree: 8,
            max_outer: 10,
            seed: 9,
            ..Default::default()
        };
        let r1 = chebdav_smallest(20, 3, &opts, dense_block_op(&a)).unwrap();
        let r2 = chebdav_smallest(20, 3, &opts, dense_block_op(&a)).unwrap();
        assert_eq!(r1.eigenvalues, r2.eigenvalues);
        assert_eq!(r1.eigenvectors, r2.eigenvectors);
        assert_eq!(r1.block_applies, r2.block_applies);
    }

    #[test]
    fn rejects_bad_arguments() {
        let noop = |x: &[f64], _m: usize| x.to_vec();
        assert!(chebdav_smallest(5, 0, &Default::default(), noop).is_err());
        assert!(chebdav_smallest(5, 6, &Default::default(), noop).is_err());
        let opts = ChebDavOptions { filter_degree: 0, ..Default::default() };
        assert!(chebdav_smallest(5, 2, &opts, noop).is_err());
        let opts = ChebDavOptions { max_outer: 0, ..Default::default() };
        assert!(chebdav_smallest(5, 2, &opts, noop).is_err());
    }

    #[test]
    fn degenerate_spectrum_converges_immediately() {
        // A = 3·I: every direction is an eigenvector; the artificial span
        // widening must keep the filter finite and RR exact.
        let n = 12;
        let r = chebdav_smallest(
            n,
            2,
            &ChebDavOptions::default(),
            |x: &[f64], _m: usize| x.iter().map(|v| 3.0 * v).collect(),
        )
        .unwrap();
        assert!((r.eigenvalues[0] - 3.0).abs() < 1e-9);
        assert!((r.eigenvalues[1] - 3.0).abs() < 1e-9);
        assert_eq!(r.outer_iters, 1);
    }
}
