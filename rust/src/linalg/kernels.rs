//! Compile-time-blocked, multi-accumulator kernels for the three hot math
//! paths (DESIGN.md §2.14): one-query-vs-many-points squared-distance
//! scans, row-blocked CSR mat-vec, and the point×center k-means assignment
//! tile. Every phase of the paper's pipeline bottoms out here — RBF
//! similarity and t-NN queries (phase 1), Laplacian mat-vecs (phase 2),
//! nearest-center scans (phase 3).
//!
//! # Shape
//!
//! The kernels follow the form proven in [`super::vector::dot`] /
//! [`super::vector::axpy`] and the ChebDav block mat-vec: a fixed lane
//! count known at compile time, independent accumulators that break the
//! sequential floating-point dependency chain, and explicit tail handling
//! for the leftovers. The crucial difference from a classic SIMD rewrite
//! is **which axis is blocked**: the distance and assignment tiles block
//! across the *candidate* axis and the CSR kernel across the *row* axis,
//! so each candidate/row keeps its own left-to-right accumulation order.
//! That is what makes the blocked results bit-identical to the scalar
//! references instead of merely close.
//!
//! # Determinism contract
//!
//! Every dispatching kernel here keeps a public `*_scalar` reference, and
//! the blocked form is **bit-identical** to it:
//!
//! - completed squared distances are accumulated dimension-sequentially
//!   per lane — the same adds in the same order as
//!   [`super::vector::sq_dist`];
//! - abort classification is unchanged: squared-distance increments are
//!   non-negative, and IEEE round-to-nearest addition of a non-negative
//!   term is monotone non-decreasing, so "some prefix exceeds the bound"
//!   is *equivalent* to "the final sum exceeds the bound". The blocked
//!   kernels may therefore check the bound at tile granularity (or only at
//!   the end) and still classify exactly like the per-dimension check in
//!   [`super::vector::sq_dist_bounded`];
//! - argmin tie behavior is unchanged: strict `<` on bit-identical values
//!   keeps the lowest center index, everywhere;
//! - CSR rows never borrow accumulator lanes across a row boundary, so any
//!   `[lo, hi)` task partition of the row space reassembles bit-identically
//!   to the full scan.
//!
//! The distributed-vs-oracle byte-identity tests (knn, eigensolver,
//! faults, serving) all sit on top of these loops; `tests/test_kernels.rs`
//! pins the blocked≡scalar property directly across all tail shapes.
//!
//! # Dispatch
//!
//! A process-wide [`KernelMode`] selects blocked (default) or scalar.
//! Because the two modes agree bitwise, flipping the mode mid-run is
//! observable only in timings and in pruning *counters* (a tile samples
//! its abort bound once, so a shrinking bound classifies a few more
//! candidates as "evaluated") — never in results. `PSCH_KERNELS=scalar`
//! forces the references, which is how the before/after bench and the
//! end-to-end mode-invariance test drive both paths.

use std::sync::atomic::{AtomicU8, Ordering};

use super::vector::NUM_ACC;

/// Rows processed per iteration by the row-blocked CSR mat-vec.
pub const KERNEL_BLOCK: usize = 4;

/// Candidate lanes per distance/assignment tile.
pub const TILE_LANES: usize = 8;

/// Dimensions accumulated between whole-tile abort checks.
pub const DIM_CHUNK: usize = 8;

/// Which implementation the dispatching kernels run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelMode {
    /// Compile-time-blocked multi-accumulator kernels (the default).
    Blocked,
    /// The scalar reference implementations.
    Scalar,
}

/// 0 = unresolved, 1 = blocked, 2 = scalar.
static KERNEL_MODE: AtomicU8 = AtomicU8::new(0);

/// The active [`KernelMode`]. Resolved once from `PSCH_KERNELS`
/// (`scalar` | `blocked`, default blocked) on first use.
pub fn kernel_mode() -> KernelMode {
    match KERNEL_MODE.load(Ordering::Relaxed) {
        1 => KernelMode::Blocked,
        2 => KernelMode::Scalar,
        _ => {
            let mode = match std::env::var("PSCH_KERNELS").as_deref() {
                Ok("scalar") => KernelMode::Scalar,
                _ => KernelMode::Blocked,
            };
            set_kernel_mode(mode);
            mode
        }
    }
}

/// Override the process-wide [`KernelMode`] (tests/benches). Safe at any
/// point: both modes produce bit-identical results by contract.
pub fn set_kernel_mode(mode: KernelMode) {
    let v = match mode {
        KernelMode::Blocked => 1,
        KernelMode::Scalar => 2,
    };
    KERNEL_MODE.store(v, Ordering::Relaxed);
}

/// Consumer of a one-query-vs-many-points squared-distance scan.
pub trait ScanSink {
    /// Current abort bound: a candidate whose running squared distance
    /// strictly exceeds it cannot matter downstream (equality never
    /// aborts — a tie may still be admitted). The scalar reference samples
    /// it per candidate, the blocked kernel once per tile; under a fixed
    /// bound both classify identically, and a shrinking bound only
    /// *completes more* candidates, whose push is then rejected by the
    /// consumer's own total order.
    fn bound(&self) -> f64;

    /// One candidate's outcome, in scan order: `Some(d2)` with the full
    /// squared distance (bit-identical to [`super::vector::sq_dist`]) or
    /// `None` when the running sum passed `bound`.
    fn emit(&mut self, id: u32, d2: Option<f64>);
}

// ---------------------------------------------------------------------------
// (a) one-query-vs-many-points squared-distance scans
// ---------------------------------------------------------------------------

/// Scan the candidates `ids` (skipping `exclude`) against query `q` over
/// the flat row-major point set, dispatching on [`kernel_mode`].
pub fn sq_dist_scan_ids<S: ScanSink>(
    q: &[f64],
    points: &[f64],
    d: usize,
    ids: &[u32],
    exclude: Option<u32>,
    sink: &mut S,
) {
    match kernel_mode() {
        KernelMode::Scalar => sq_dist_scan_ids_scalar(q, points, d, ids, exclude, sink),
        KernelMode::Blocked => sq_dist_scan_ids_blocked(q, points, d, ids, exclude, sink),
    }
}

/// Scan the contiguous candidate range `[lo, hi)` against query `q`,
/// dispatching on [`kernel_mode`].
pub fn sq_dist_scan_range<S: ScanSink>(
    q: &[f64],
    points: &[f64],
    d: usize,
    lo: u32,
    hi: u32,
    exclude: Option<u32>,
    sink: &mut S,
) {
    match kernel_mode() {
        KernelMode::Scalar => sq_dist_scan_range_scalar(q, points, d, lo, hi, exclude, sink),
        KernelMode::Blocked => sq_dist_scan_range_blocked(q, points, d, lo, hi, exclude, sink),
    }
}

/// Scalar reference: one [`super::vector::sq_dist_bounded`] per candidate,
/// bound sampled per candidate.
pub fn sq_dist_scan_ids_scalar<S: ScanSink>(
    q: &[f64],
    points: &[f64],
    d: usize,
    ids: &[u32],
    exclude: Option<u32>,
    sink: &mut S,
) {
    for &id in ids {
        if exclude == Some(id) {
            continue;
        }
        let i = id as usize;
        let p = &points[i * d..i * d + d];
        let res = super::vector::sq_dist_bounded(q, p, sink.bound());
        sink.emit(id, res);
    }
}

/// Scalar reference over a contiguous id range.
pub fn sq_dist_scan_range_scalar<S: ScanSink>(
    q: &[f64],
    points: &[f64],
    d: usize,
    lo: u32,
    hi: u32,
    exclude: Option<u32>,
    sink: &mut S,
) {
    for id in lo..hi {
        if exclude == Some(id) {
            continue;
        }
        let i = id as usize;
        let p = &points[i * d..i * d + d];
        let res = super::vector::sq_dist_bounded(q, p, sink.bound());
        sink.emit(id, res);
    }
}

/// Blocked scan over an explicit id list.
pub fn sq_dist_scan_ids_blocked<S: ScanSink>(
    q: &[f64],
    points: &[f64],
    d: usize,
    ids: &[u32],
    exclude: Option<u32>,
    sink: &mut S,
) {
    let mut it = ids.iter().copied();
    sq_dist_scan_blocked(q, points, d, || it.next(), exclude, sink);
}

/// Blocked scan over a contiguous id range.
pub fn sq_dist_scan_range_blocked<S: ScanSink>(
    q: &[f64],
    points: &[f64],
    d: usize,
    lo: u32,
    hi: u32,
    exclude: Option<u32>,
    sink: &mut S,
) {
    let mut next = lo;
    sq_dist_scan_blocked(
        q,
        points,
        d,
        || {
            if next < hi {
                let id = next;
                next += 1;
                Some(id)
            } else {
                None
            }
        },
        exclude,
        sink,
    );
}

/// Tile loop shared by both blocked scans: fill up to [`TILE_LANES`]
/// candidate ids from the source, price them together, emit in order.
fn sq_dist_scan_blocked<S: ScanSink>(
    q: &[f64],
    points: &[f64],
    d: usize,
    mut next_id: impl FnMut() -> Option<u32>,
    exclude: Option<u32>,
    sink: &mut S,
) {
    let mut ids = [0u32; TILE_LANES];
    loop {
        let mut lanes = 0usize;
        while lanes < TILE_LANES {
            match next_id() {
                Some(id) => {
                    if exclude == Some(id) {
                        continue;
                    }
                    ids[lanes] = id;
                    lanes += 1;
                }
                None => break,
            }
        }
        if lanes == 0 {
            return;
        }
        dist_tile_emit(q, points, d, &ids, lanes, sink);
        if lanes < TILE_LANES {
            return;
        }
    }
}

/// Price one tile of `lanes` candidates and emit each outcome.
///
/// Each lane accumulates its own distance dimension-sequentially (the
/// exact add sequence of the scalar kernel); idle lanes in a final partial
/// tile duplicate lane 0's row and are never emitted. The bound is sampled
/// once at tile entry; after every [`DIM_CHUNK`] dimensions the tile
/// aborts early iff *every* lane's running sum already exceeds it — lanes
/// cut short that way are classified `None`, which is exactly what their
/// completed sum would have yielded (monotone non-negative accumulation).
fn dist_tile_emit<S: ScanSink>(
    q: &[f64],
    points: &[f64],
    d: usize,
    ids: &[u32; TILE_LANES],
    lanes: usize,
    sink: &mut S,
) {
    let bound = sink.bound();
    let mut acc = [0.0f64; TILE_LANES];
    let mut rows: [&[f64]; TILE_LANES] = [&[]; TILE_LANES];
    for (l, row) in rows.iter_mut().enumerate() {
        let i = ids[if l < lanes { l } else { 0 }] as usize;
        *row = &points[i * d..i * d + d];
    }
    let mut t = 0usize;
    while t < d {
        let stop = (t + DIM_CHUNK).min(d);
        for c in t..stop {
            let qc = q[c];
            for l in 0..TILE_LANES {
                let diff = qc - rows[l][c];
                acc[l] += diff * diff;
            }
        }
        t = stop;
        let mut lowest = acc[0];
        for &a in &acc[1..] {
            if a < lowest {
                lowest = a;
            }
        }
        if lowest > bound {
            break;
        }
    }
    for l in 0..lanes {
        // d == 0 completes with 0.0 unconditionally, like the scalar
        // reference whose per-dimension abort check never runs.
        let res = if d > 0 && acc[l] > bound {
            None
        } else {
            Some(acc[l])
        };
        sink.emit(ids[l], res);
    }
}

// ---------------------------------------------------------------------------
// (b) row-blocked CSR mat-vec
// ---------------------------------------------------------------------------

/// Borrowed view of a CSR matrix's storage arrays — what the mat-vec
/// kernels consume ([`super::sparse::CsrMatrix`] hands it out via `view`).
#[derive(Debug, Clone, Copy)]
pub struct CsrView<'a> {
    /// Row pointer array (`rows + 1` entries).
    pub indptr: &'a [usize],
    /// Column index per stored entry.
    pub indices: &'a [u32],
    /// Value per stored entry.
    pub values: &'a [f64],
}

/// `y[i - lo] = A[i] · x` for rows `[lo, hi)`, dispatching on
/// [`kernel_mode`].
pub fn spmv_rows_into(a: CsrView<'_>, x: &[f64], lo: usize, hi: usize, y: &mut [f64]) {
    match kernel_mode() {
        KernelMode::Scalar => spmv_rows_scalar(a, x, lo, hi, y),
        KernelMode::Blocked => spmv_rows_blocked(a, x, lo, hi, y),
    }
}

/// Scalar reference: one sequential accumulator per row.
pub fn spmv_rows_scalar(a: CsrView<'_>, x: &[f64], lo: usize, hi: usize, y: &mut [f64]) {
    debug_assert!(lo <= hi && hi + 1 <= a.indptr.len());
    debug_assert_eq!(y.len(), hi - lo);
    for i in lo..hi {
        let mut acc = 0.0f64;
        for k in a.indptr[i]..a.indptr[i + 1] {
            acc += a.values[k] * x[a.indices[k] as usize];
        }
        y[i - lo] = acc;
    }
}

/// Row-blocked mat-vec: [`KERNEL_BLOCK`] consecutive rows advance in lock
/// step over their common entry-count prefix with independent
/// accumulators, then finish their leftovers row by row. Each row's own
/// add order is unchanged, so the result is bit-identical to the scalar
/// reference and independent of the `[lo, hi)` task partition.
pub fn spmv_rows_blocked(a: CsrView<'_>, x: &[f64], lo: usize, hi: usize, y: &mut [f64]) {
    debug_assert!(lo <= hi && hi + 1 <= a.indptr.len());
    debug_assert_eq!(y.len(), hi - lo);
    let CsrView { indptr, indices, values } = a;
    let mut i = lo;
    while i + KERNEL_BLOCK <= hi {
        let s = [indptr[i], indptr[i + 1], indptr[i + 2], indptr[i + 3]];
        let e = [indptr[i + 1], indptr[i + 2], indptr[i + 3], indptr[i + 4]];
        let mut common = e[0] - s[0];
        for l in 1..KERNEL_BLOCK {
            common = common.min(e[l] - s[l]);
        }
        let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
        for t in 0..common {
            a0 += values[s[0] + t] * x[indices[s[0] + t] as usize];
            a1 += values[s[1] + t] * x[indices[s[1] + t] as usize];
            a2 += values[s[2] + t] * x[indices[s[2] + t] as usize];
            a3 += values[s[3] + t] * x[indices[s[3] + t] as usize];
        }
        for t in s[0] + common..e[0] {
            a0 += values[t] * x[indices[t] as usize];
        }
        for t in s[1] + common..e[1] {
            a1 += values[t] * x[indices[t] as usize];
        }
        for t in s[2] + common..e[2] {
            a2 += values[t] * x[indices[t] as usize];
        }
        for t in s[3] + common..e[3] {
            a3 += values[t] * x[indices[t] as usize];
        }
        let o = i - lo;
        y[o] = a0;
        y[o + 1] = a1;
        y[o + 2] = a2;
        y[o + 3] = a3;
        i += KERNEL_BLOCK;
    }
    while i < hi {
        let mut acc = 0.0f64;
        for k in indptr[i]..indptr[i + 1] {
            acc += values[k] * x[indices[k] as usize];
        }
        y[i - lo] = acc;
        i += 1;
    }
}

/// Multi-column block mat-vec `Y[lo..hi) = A[lo..hi) · X` for an n×m
/// row-major column block, dispatching on [`kernel_mode`]. `y` must hold
/// `(hi - lo) * m` values.
pub fn spmv_block_rows_into(
    a: CsrView<'_>,
    x: &[f64],
    m: usize,
    lo: usize,
    hi: usize,
    y: &mut [f64],
) {
    match kernel_mode() {
        KernelMode::Scalar => spmv_block_rows_scalar(a, x, m, lo, hi, y),
        KernelMode::Blocked => spmv_block_rows_blocked(a, x, m, lo, hi, y),
    }
}

/// Scalar reference for the multi-column block mat-vec, with the **same
/// reduction contract** as the blocked form: per (row, column), entries
/// decompose into [`NUM_ACC`] strided lane sums plus a tail lane, folded
/// through the fixed tree `((l0+l1)+(l2+l3)) + tail`. The adds per lane
/// happen in the same order as the blocked kernel's scratch rows, so the
/// two are bit-identical.
pub fn spmv_block_rows_scalar(
    a: CsrView<'_>,
    x: &[f64],
    m: usize,
    lo: usize,
    hi: usize,
    y: &mut [f64],
) {
    debug_assert!(lo <= hi && hi + 1 <= a.indptr.len());
    debug_assert_eq!(y.len(), (hi - lo) * m);
    for i in lo..hi {
        let start = a.indptr[i];
        let end = a.indptr[i + 1];
        let yo = (i - lo) * m;
        for c in 0..m {
            let mut lanes = [0.0f64; NUM_ACC];
            let mut tail = 0.0f64;
            let mut k = start;
            while k + NUM_ACC <= end {
                for (l, acc) in lanes.iter_mut().enumerate() {
                    *acc += a.values[k + l] * x[a.indices[k + l] as usize * m + c];
                }
                k += NUM_ACC;
            }
            while k < end {
                tail += a.values[k] * x[a.indices[k] as usize * m + c];
                k += 1;
            }
            y[yo + c] = ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3])) + tail;
        }
    }
}

/// Blocked multi-column mat-vec: [`NUM_ACC`] unroll lanes + 1 tail lane,
/// each `m` wide, walking a whole row's entries once for all columns (the
/// ChebDav operator application). Moved verbatim from
/// `CsrMatrix::spmv_block_rows`, which now delegates here.
pub fn spmv_block_rows_blocked(
    a: CsrView<'_>,
    x: &[f64],
    m: usize,
    lo: usize,
    hi: usize,
    y: &mut [f64],
) {
    debug_assert!(lo <= hi && hi + 1 <= a.indptr.len());
    debug_assert_eq!(y.len(), (hi - lo) * m);
    let mut acc = vec![0.0f64; (NUM_ACC + 1) * m];
    for i in lo..hi {
        for v in acc.iter_mut() {
            *v = 0.0;
        }
        let end = a.indptr[i + 1];
        let mut k = a.indptr[i];
        while k + NUM_ACC <= end {
            for lane in 0..NUM_ACC {
                let v = a.values[k + lane];
                let xo = a.indices[k + lane] as usize * m;
                let ao = lane * m;
                for c in 0..m {
                    acc[ao + c] += v * x[xo + c];
                }
            }
            k += NUM_ACC;
        }
        while k < end {
            let v = a.values[k];
            let xo = a.indices[k] as usize * m;
            let ao = NUM_ACC * m;
            for c in 0..m {
                acc[ao + c] += v * x[xo + c];
            }
            k += 1;
        }
        let yo = (i - lo) * m;
        for c in 0..m {
            y[yo + c] =
                ((acc[c] + acc[m + c]) + (acc[2 * m + c] + acc[3 * m + c])) + acc[NUM_ACC * m + c];
        }
    }
}

// ---------------------------------------------------------------------------
// (c) point×center assignment tile (f64 + f32)
// ---------------------------------------------------------------------------

macro_rules! assign_kernels {
    ($ty:ty, $dispatch:ident, $scalar:ident, $blocked:ident, $norms_fn:ident,
     $margin:expr, $slack:expr) => {
        /// Hoisted per-center Euclidean norms over a flat k×d center block
        /// — the screen input of the blocked assignment tile.
        pub fn $norms_fn(centers: &[$ty], k: usize, d: usize) -> Vec<$ty> {
            debug_assert_eq!(centers.len(), k * d);
            (0..k)
                .map(|c| {
                    centers[c * d..(c + 1) * d]
                        .iter()
                        .map(|v| v * v)
                        .sum::<$ty>()
                        .sqrt()
                })
                .collect()
        }

        /// Nearest center of `p` (ties to the lowest index), dispatching
        /// on [`kernel_mode`].
        pub fn $dispatch(p: &[$ty], centers: &[$ty], norms: &[$ty], k: usize, d: usize) -> u32 {
            match kernel_mode() {
                KernelMode::Scalar => $scalar(p, centers, norms, k, d),
                KernelMode::Blocked => $blocked(p, centers, norms, k, d),
            }
        }

        /// Scalar reference: full sequential distance per center, strict
        /// `<` keeps the lowest index on ties.
        pub fn $scalar(p: &[$ty], centers: &[$ty], _norms: &[$ty], k: usize, d: usize) -> u32 {
            assert!(k >= 1, "assign needs at least one center");
            debug_assert_eq!(p.len(), d);
            debug_assert_eq!(centers.len(), k * d);
            let mut best = <$ty>::INFINITY;
            let mut best_idx = 0u32;
            for c in 0..k {
                let ctr = &centers[c * d..(c + 1) * d];
                let mut acc: $ty = 0.0;
                for t in 0..d {
                    let diff = p[t] - ctr[t];
                    acc += diff * diff;
                }
                if acc < best {
                    best = acc;
                    best_idx = c as u32;
                }
            }
            best_idx
        }

        /// Blocked assignment: [`TILE_LANES`] center lanes per tile, a
        /// hoisted-norm screen that skips tiles proven hopeless, and a
        /// whole-tile running-partial abort against the entry best.
        ///
        /// Soundness of the screen (why it can never flip the argmin):
        /// `‖p − c‖ ≥ |‖p‖ − ‖c‖|` exactly. The *computed* norms carry a
        /// relative error ≲ (d/2+2)·ε, which the subtracted margin
        /// `(‖p‖+‖c‖)·margin` dominates for any realistic d; the computed
        /// squared distance undershoots the real one by at most a
        /// ≈ 2(d+2)·ε factor, which the `slack` multiplier dominates. So
        /// `gap²·slack > best` ⟹ the lane's computed d2 strictly exceeds
        /// `best`, and strict `<` would have rejected it anyway. Lanes cut
        /// short by the tile abort hold a partial sum already above the
        /// tile-entry best — the same argument applies. Completed lanes
        /// are bit-identical to the scalar scan, and the fold visits them
        /// in center order, so selection and ties match exactly.
        pub fn $blocked(p: &[$ty], centers: &[$ty], norms: &[$ty], k: usize, d: usize) -> u32 {
            assert!(k >= 1, "assign needs at least one center");
            debug_assert_eq!(p.len(), d);
            debug_assert_eq!(centers.len(), k * d);
            debug_assert_eq!(norms.len(), k);
            // Center 0 priced in full: the scalar scan's first iteration.
            let mut best: $ty = 0.0;
            for t in 0..d {
                let diff = p[t] - centers[t];
                best += diff * diff;
            }
            let mut best_idx = 0u32;
            let pn: $ty = p.iter().map(|v| v * v).sum::<$ty>().sqrt();
            let mut c0 = 1usize;
            while c0 < k {
                let lanes = (k - c0).min(TILE_LANES);
                let mut screened = true;
                for &nc in &norms[c0..c0 + lanes] {
                    let gap = (pn - nc).abs() - (pn + nc) * $margin;
                    if !(gap > 0.0 && gap * gap * $slack > best) {
                        screened = false;
                        break;
                    }
                }
                if screened {
                    c0 += lanes;
                    continue;
                }
                let mut acc: [$ty; TILE_LANES] = [0.0; TILE_LANES];
                let mut rows: [&[$ty]; TILE_LANES] = [&[]; TILE_LANES];
                for (l, row) in rows.iter_mut().enumerate() {
                    let c = c0 + if l < lanes { l } else { 0 };
                    *row = &centers[c * d..(c + 1) * d];
                }
                let mut t = 0usize;
                while t < d {
                    let stop = (t + DIM_CHUNK).min(d);
                    for c in t..stop {
                        let pc = p[c];
                        for l in 0..TILE_LANES {
                            let diff = pc - rows[l][c];
                            acc[l] += diff * diff;
                        }
                    }
                    t = stop;
                    let mut lowest = acc[0];
                    for &a in &acc[1..] {
                        if a < lowest {
                            lowest = a;
                        }
                    }
                    if lowest > best {
                        break;
                    }
                }
                for l in 0..lanes {
                    if acc[l] < best {
                        best = acc[l];
                        best_idx = (c0 + l) as u32;
                    }
                }
                c0 += lanes;
            }
            best_idx
        }
    };
}

assign_kernels!(
    f64,
    assign_point,
    assign_point_scalar,
    assign_point_blocked,
    center_norms,
    1e-12,
    1.0 - 1e-9
);
assign_kernels!(
    f32,
    assign_point_f32,
    assign_point_scalar_f32,
    assign_point_blocked_f32,
    center_norms_f32,
    1e-4,
    1.0 - 1e-4
);

#[cfg(test)]
mod tests {
    use super::*;

    fn pseudo(seed: u64, len: usize) -> Vec<f64> {
        let mut s = seed;
        (0..len)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                (s >> 11) as f64 / (1u64 << 53) as f64 * 2.0 - 1.0
            })
            .collect()
    }

    struct Rec {
        bound: f64,
        out: Vec<(u32, Option<u64>)>,
    }

    impl ScanSink for Rec {
        fn bound(&self) -> f64 {
            self.bound
        }
        fn emit(&mut self, id: u32, d2: Option<f64>) {
            self.out.push((id, d2.map(f64::to_bits)));
        }
    }

    #[test]
    fn mode_flag_round_trips() {
        let before = kernel_mode();
        set_kernel_mode(KernelMode::Scalar);
        assert_eq!(kernel_mode(), KernelMode::Scalar);
        set_kernel_mode(KernelMode::Blocked);
        assert_eq!(kernel_mode(), KernelMode::Blocked);
        set_kernel_mode(before);
    }

    #[test]
    fn blocked_scan_completed_values_match_sq_dist_bitwise() {
        let d = 9;
        let n = TILE_LANES + 3;
        let points = pseudo(11, n * d);
        let q = pseudo(13, d);
        let ids: Vec<u32> = (0..n as u32).collect();
        let mut sink = Rec { bound: f64::INFINITY, out: Vec::new() };
        sq_dist_scan_ids_blocked(&q, &points, d, &ids, None, &mut sink);
        assert_eq!(sink.out.len(), n);
        for (id, bits) in sink.out {
            let i = id as usize;
            let want = super::super::vector::sq_dist(&q, &points[i * d..(i + 1) * d]);
            assert_eq!(bits, Some(want.to_bits()), "id={id}");
        }
    }

    #[test]
    fn blocked_scan_classifies_like_the_scalar_reference() {
        let d = 2 * DIM_CHUNK + 1;
        let n = 3 * TILE_LANES;
        let points = pseudo(17, n * d);
        let q = pseudo(19, d);
        let ids: Vec<u32> = (0..n as u32).collect();
        for bound in [0.0, 2.0, 8.0, f64::INFINITY] {
            let mut a = Rec { bound, out: Vec::new() };
            sq_dist_scan_ids_scalar(&q, &points, d, &ids, Some(4), &mut a);
            let mut b = Rec { bound, out: Vec::new() };
            sq_dist_scan_ids_blocked(&q, &points, d, &ids, Some(4), &mut b);
            assert_eq!(a.out, b.out, "bound={bound}");
        }
    }

    #[test]
    fn assign_blocked_matches_scalar_on_random_centers() {
        for k in 1..=2 * TILE_LANES + 1 {
            let d = 6;
            let centers = pseudo(23 + k as u64, k * d);
            let norms = center_norms(&centers, k, d);
            for pi in 0..8u64 {
                let p = pseudo(29 ^ (pi * 7919), d);
                assert_eq!(
                    assign_point_scalar(&p, &centers, &norms, k, d),
                    assign_point_blocked(&p, &centers, &norms, k, d),
                    "k={k} pi={pi}"
                );
            }
        }
    }

    #[test]
    fn spmv_blocked_matches_scalar_bitwise() {
        let n = 2 * KERNEL_BLOCK + 3;
        let indptr: Vec<usize> = (0..=n).map(|i| i * (i + 1) / 2).collect();
        let nnz = indptr[n];
        let indices: Vec<u32> = (0..nnz).map(|k| (k % n) as u32).collect();
        let values = pseudo(31, nnz);
        let x = pseudo(37, n);
        let a = CsrView { indptr: &indptr, indices: &indices, values: &values };
        let mut ys = vec![0.0; n];
        spmv_rows_scalar(a, &x, 0, n, &mut ys);
        let mut yb = vec![0.0; n];
        spmv_rows_blocked(a, &x, 0, n, &mut yb);
        let bits = |v: &[f64]| v.iter().map(|f| f.to_bits()).collect::<Vec<u64>>();
        assert_eq!(bits(&ys), bits(&yb));
    }
}
