//! Linear-algebra substrate: dense/sparse matrices and eigensolvers.
//!
//! - [`dense::DenseMatrix`] — row-major dense matrix (baseline + tests).
//! - [`sparse::CsrMatrix`] — the row-partitioned Laplacian storage format.
//! - [`tridiag::tridiag_eigen`] — master-side QL solve of the Lanczos T.
//! - [`jacobi::jacobi_eigen`] — O(n^3) dense oracle (the paper's comparator).
//! - [`lanczos::lanczos_smallest`] — paper Alg. 4.3 with reorthogonalization,
//!   matrix accessed only through a mat-vec closure so the distributed
//!   pipeline can plug in a MapReduce job.
//! - [`chebdav::chebdav_smallest`] — block Chebyshev–Davidson (filtered
//!   subspace iteration + Rayleigh–Ritz), matrix accessed through a block
//!   mat-vec closure so one distributed job prices all m columns at once.
//! - [`kernels`] — compile-time-blocked multi-accumulator kernels for the
//!   hot paths (distance scans, row-blocked CSR mat-vec, the k-means
//!   assignment tile), each with a bit-identical scalar reference.

pub mod chebdav;
pub mod dense;
pub mod jacobi;
pub mod kernels;
pub mod lanczos;
pub mod sparse;
pub mod tridiag;
pub mod vector;

pub use chebdav::{
    chebdav_smallest, estimate_spectrum_bounds, ChebDavOptions, ChebDavResult, SpectrumBounds,
};
pub use dense::DenseMatrix;
pub use jacobi::jacobi_eigen;
pub use lanczos::{lanczos_smallest, LanczosOptions, LanczosResult};
pub use sparse::CsrMatrix;
pub use tridiag::tridiag_eigen;
