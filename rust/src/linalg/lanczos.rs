//! Lanczos iteration for the k smallest eigenpairs (paper Alg. 4.3).
//!
//! The matrix is only touched through a caller-supplied `matvec` closure —
//! exactly the abstraction the paper's phase 2 needs: in the distributed
//! pipeline the closure launches a MapReduce job over the row-partitioned L
//! in the table store ("move the vector to the data"), while tests plug in a
//! local [`CsrMatrix::spmv`].
//!
//! We add full reorthogonalization on top of the paper's bare three-term
//! recurrence: in floating point the bare recurrence loses orthogonality
//! after a few tens of iterations and produces ghost eigenvalues; full
//! reorthogonalization (modified Gram–Schmidt against all previous basis
//! vectors, done twice) keeps the basis orthonormal to machine precision.
//! DESIGN.md §7 records this deviation.

use crate::error::{Error, Result};
use crate::util::Xoshiro256;

use super::tridiag::tridiag_eigen;
use super::vector::{axpy, dot, normalize};

/// Result of a Lanczos run.
#[derive(Debug, Clone)]
pub struct LanczosResult {
    /// Ritz values (approximate eigenvalues), ascending, `k` of them.
    pub eigenvalues: Vec<f64>,
    /// Ritz vectors, row-major n×k: `eigenvectors[i][j]` = component i of
    /// approximate eigenvector j.
    pub eigenvectors: Vec<Vec<f64>>,
    /// Lanczos steps actually performed.
    pub steps: usize,
}

/// Options for [`lanczos_smallest`].
#[derive(Debug, Clone)]
pub struct LanczosOptions {
    /// Maximum Krylov subspace dimension m (paper's iteration count).
    pub max_steps: usize,
    /// Convergence tolerance on the residual estimate |beta_m * u_mk|.
    pub tol: f64,
    /// Seed for the random start vector v1 (paper step 1).
    pub seed: u64,
}

impl Default for LanczosOptions {
    fn default() -> Self {
        Self { max_steps: 80, tol: 1e-10, seed: 0x5eed }
    }
}

/// Compute the `k` smallest eigenpairs of a symmetric n×n operator.
///
/// `matvec(v) -> L v` is the only access to the matrix. Returns an error if
/// `k` exceeds what the Krylov space can resolve (k > max_steps or k > n).
pub fn lanczos_smallest<F>(
    n: usize,
    k: usize,
    opts: &LanczosOptions,
    mut matvec: F,
) -> Result<LanczosResult>
where
    F: FnMut(&[f64]) -> Vec<f64>,
{
    if k == 0 || n == 0 {
        return Err(Error::Linalg(format!("lanczos: degenerate k={k}, n={n}")));
    }
    if k > n {
        return Err(Error::Linalg(format!("lanczos: k={k} > n={n}")));
    }
    let m_max = opts.max_steps.min(n);
    if k > m_max {
        return Err(Error::Linalg(format!(
            "lanczos: k={k} > max_steps={} (capped at n={n})",
            opts.max_steps
        )));
    }

    // Paper step 1: v1 <- random vector of norm 1.
    let mut rng = Xoshiro256::new(opts.seed);
    let mut v = vec![0.0; n];
    for vi in v.iter_mut() {
        *vi = rng.next_gaussian();
    }
    normalize(&mut v);

    let mut basis: Vec<Vec<f64>> = Vec::with_capacity(m_max); // v_1 .. v_m
    let mut alphas: Vec<f64> = Vec::with_capacity(m_max);
    let mut betas: Vec<f64> = Vec::with_capacity(m_max); // beta_{j+1}

    let mut steps = 0;
    for j in 0..m_max {
        basis.push(v.clone());
        // Paper step 2: w_j <- L v_j  (the distributed hot spot).
        let mut w = matvec(&v);
        if j > 0 {
            let beta_j = betas[j - 1];
            axpy(-beta_j, &basis[j - 1], &mut w); // w -= beta_j v_{j-1}
        }
        let alpha = dot(&w, &basis[j]);
        axpy(-alpha, &basis[j], &mut w); // w -= alpha_j v_j
        alphas.push(alpha);

        // Full reorthogonalization, twice ("twice is enough" — Parlett).
        for _pass in 0..2 {
            for vb in &basis {
                let c = dot(&w, vb);
                axpy(-c, vb, &mut w);
            }
        }

        let mut beta = super::vector::norm(&w);
        steps = j + 1;
        if j + 1 == m_max {
            betas.push(beta);
            break;
        }
        if beta < opts.tol * (1.0 + alpha.abs()) {
            // Krylov space exhausted (an invariant subspace was found — e.g.
            // the operator has fewer distinct eigenvalues than max_steps).
            // Deflate: restart with a fresh random direction orthogonal to
            // the basis so further eigenpairs can be resolved. beta = 0
            // makes T block-diagonal, which tql2 handles exactly.
            if steps >= n {
                betas.push(beta);
                break;
            }
            let mut fresh = vec![0.0; n];
            for x in fresh.iter_mut() {
                *x = rng.next_gaussian();
            }
            for _pass in 0..2 {
                for vb in &basis {
                    let c = dot(&fresh, vb);
                    axpy(-c, vb, &mut fresh);
                }
            }
            if normalize(&mut fresh) < 1e-12 {
                // Basis already spans the whole space numerically.
                betas.push(0.0);
                break;
            }
            w = fresh;
            beta = 0.0;
        }
        betas.push(beta);
        v = w;
        if beta != 0.0 {
            normalize(&mut v);
        }
    }

    // Master-side: eigen decomposition of the m×m tridiagonal T.
    let m = steps;
    let mut off = vec![0.0; m];
    for j in 1..m {
        off[j] = betas[j - 1];
    }
    let (tvals, tvecs) = tridiag_eigen(&alphas[..m], &off)?;

    if k > m {
        return Err(Error::Linalg(format!(
            "lanczos: Krylov space dim {m} cannot resolve k={k} pairs"
        )));
    }

    // Ritz vectors: y_c = sum_j u[j][c] * v_j.
    let mut eigenvectors = vec![vec![0.0; k]; n];
    for c in 0..k {
        for (j, vb) in basis.iter().take(m).enumerate() {
            let coeff = tvecs[j][c];
            for i in 0..n {
                eigenvectors[i][c] += coeff * vb[i];
            }
        }
    }
    Ok(LanczosResult {
        eigenvalues: tvals[..k].to_vec(),
        eigenvectors,
        steps,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dense::DenseMatrix;
    use crate::linalg::jacobi::jacobi_eigen;
    use crate::linalg::sparse::CsrMatrix;

    fn random_symmetric(n: usize, seed: u64) -> DenseMatrix {
        let mut rng = Xoshiro256::new(seed);
        let mut m = DenseMatrix::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let v = rng.next_f64() * 2.0 - 1.0;
                m[(i, j)] = v;
                m[(j, i)] = v;
            }
        }
        m
    }

    #[test]
    fn matches_jacobi_on_dense_random() {
        let n = 40;
        let a = random_symmetric(n, 2024);
        let (jvals, _) = jacobi_eigen(&a).unwrap();
        let r = lanczos_smallest(
            n,
            5,
            &LanczosOptions { max_steps: n, ..Default::default() },
            |v| a.matvec(v),
        )
        .unwrap();
        for i in 0..5 {
            assert!(
                (r.eigenvalues[i] - jvals[i]).abs() < 1e-7,
                "eig {i}: {} vs {}",
                r.eigenvalues[i],
                jvals[i]
            );
        }
    }

    #[test]
    fn ritz_vectors_are_eigenvectors() {
        let n = 30;
        let a = random_symmetric(n, 77);
        let k = 4;
        let r = lanczos_smallest(
            n,
            k,
            &LanczosOptions { max_steps: n, ..Default::default() },
            |v| a.matvec(v),
        )
        .unwrap();
        for c in 0..k {
            let vc: Vec<f64> = (0..n).map(|i| r.eigenvectors[i][c]).collect();
            let av = a.matvec(&vc);
            for i in 0..n {
                assert!(
                    (av[i] - r.eigenvalues[c] * vc[i]).abs() < 1e-6,
                    "residual c={c} i={i}"
                );
            }
        }
    }

    #[test]
    fn graph_laplacian_zero_eigenvalue_per_component() {
        // Two disjoint triangles: Laplacian has eigenvalue 0 with multiplicity 2.
        let mut trips = vec![];
        for base in [0usize, 3] {
            for a in 0..3usize {
                for b in 0..3usize {
                    if a != b {
                        trips.push((base + a, base + b, -1.0));
                    }
                }
                trips.push((base + a, base + a, 2.0));
            }
        }
        let l = CsrMatrix::from_triplets(6, 6, &trips).unwrap();
        let r = lanczos_smallest(
            6,
            3,
            &LanczosOptions { max_steps: 6, ..Default::default() },
            |v| l.spmv(v),
        )
        .unwrap();
        assert!(r.eigenvalues[0].abs() < 1e-9, "{:?}", r.eigenvalues);
        assert!(r.eigenvalues[1].abs() < 1e-9, "{:?}", r.eigenvalues);
        assert!(r.eigenvalues[2] > 1.0, "{:?}", r.eigenvalues); // spectral gap
    }

    #[test]
    fn early_termination_on_low_rank() {
        // Rank-1 matrix: Krylov space exhausts after ~1 step from any start.
        let n = 10;
        let mut a = DenseMatrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                a[(i, j)] = 1.0; // ones matrix: eigenvalues {0 (x9), n}
            }
        }
        let r = lanczos_smallest(
            n,
            2,
            &LanczosOptions { max_steps: n, ..Default::default() },
            |v| a.matvec(v),
        )
        .unwrap();
        assert!(r.eigenvalues[0].abs() < 1e-8, "{:?}", r.eigenvalues);
    }

    #[test]
    fn rejects_bad_k() {
        assert!(lanczos_smallest(5, 0, &Default::default(), |v| v.to_vec()).is_err());
        assert!(lanczos_smallest(5, 6, &Default::default(), |v| v.to_vec()).is_err());
        let opts = LanczosOptions { max_steps: 3, ..Default::default() };
        assert!(lanczos_smallest(10, 4, &opts, |v| v.to_vec()).is_err());
    }

    #[test]
    fn deterministic_given_seed() {
        let a = random_symmetric(20, 5);
        let opts = LanczosOptions { max_steps: 20, seed: 9, ..Default::default() };
        let r1 = lanczos_smallest(20, 3, &opts, |v| a.matvec(v)).unwrap();
        let r2 = lanczos_smallest(20, 3, &opts, |v| a.matvec(v)).unwrap();
        assert_eq!(r1.eigenvalues, r2.eigenvalues);
        assert_eq!(r1.eigenvectors, r2.eigenvectors);
    }
}
