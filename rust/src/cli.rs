//! Command-line interface (in-tree parser — no clap in the offline set).
//!
//! ```text
//! psch gen-data   --out FILE [--n N --edges E --k K --seed S]
//! psch run        [--input FILE | --blobs N] [--config FILE] [--set k=v ...]
//!                 [--explain-plan]   print the planned dataflow DAGs and exit
//!                 [--graph epsilon|tnn]  similarity-graph construction mode
//!                 [--knn-t T]        neighbors per row in tnn mode
//!                 [--eigensolver lanczos|chebdav]  phase-2 backend
//!                                    (alias for --set eigen.solver=...)
//!                 [--fail-node S@H]  kill slave S at cumulative heartbeat H
//!                 [--task-fail-prob P]  seeded per-attempt failure probability
//!                 [--trace-out FILE] write a Chrome trace-event JSON
//!                                    (Perfetto-loadable, virtual clock)
//!                 [--report-json FILE]  write the unified RunReport JSON
//!                 [--metrics-out FILE]  write a Prometheus text-format
//!                                    snapshot of the run's telemetry
//!                 [--model-out FILE] persist the trained model artifact
//!                                    (psch.model.v1 JSON) for `psch assign`
//!                 [--quiet]          suppress the per-phase summary lines
//! psch assign     --model FILE       assign new points with a saved model
//!                 [--points FILE | --blobs N [--batch-seed S]]
//!                 [--batch B]        points per serving batch
//!                 [--refresh off|minibatch]  mini-batch centroid refresh
//!                 [--oracle]         single-machine path (byte-identical)
//!                 [--report-json FILE] [--metrics-out FILE]  as in `run`
//!                 [--labels-out FILE] [--model-out FILE] [--quiet]
//! psch report show FILE              summarize a RunReport JSON
//! psch report diff A B [--tolerance-pct N] [--verbose]
//!                                    compare two RunReports; exit 1 when
//!                                    B regresses beyond the tolerance
//! psch baseline   [--blobs N] [--config FILE]   single-machine comparator
//! psch scale-study [--n N] [--slaves 1,2,4,6,8,10] [--config FILE]
//! psch inspect-artifacts [--dir DIR]
//! ```

use std::collections::HashMap;
use std::sync::Arc;

use crate::config::Config;
use crate::coordinator::{Driver, PipelineInput};
use crate::data::{gaussian_blobs, planted_graph, Topology};
use crate::error::{Error, Result};
use crate::eval::{ari, nmi};
use crate::metrics::speedup::SpeedupCurve;
use crate::metrics::table::AsciiTable;
use crate::runtime::KernelRuntime;
use crate::util::fmt::hms;

/// Parsed flags: `--key value` pairs plus repeated `--set k=v`.
#[derive(Debug, Default)]
pub struct Flags {
    values: HashMap<String, String>,
    sets: Vec<(String, String)>,
}

impl Flags {
    /// Flags that are boolean switches: bare `--flag` parses as `"true"`.
    /// Every other flag still requires a value (a forgotten value stays a
    /// hard error instead of silently becoming the string `"true"`).
    const BOOL_FLAGS: &'static [&'static str] =
        &["explain-plan", "quiet", "oracle", "verbose"];

    /// Parse `--key value` / `--set k=v` arguments; switches listed in
    /// [`Self::BOOL_FLAGS`] may appear bare (e.g. `--explain-plan`).
    pub fn parse(args: &[String]) -> Result<Self> {
        let mut flags = Flags::default();
        let mut i = 0;
        while i < args.len() {
            let arg = &args[i];
            let Some(key) = arg.strip_prefix("--") else {
                return Err(Error::Cli(format!("unexpected argument: {arg}")));
            };
            let is_bool = Self::BOOL_FLAGS.contains(&key);
            let value = match args.get(i + 1) {
                Some(v) if !(is_bool && v.starts_with("--")) => {
                    i += 2;
                    v.clone()
                }
                _ if is_bool => {
                    i += 1;
                    "true".to_string()
                }
                _ => return Err(Error::Cli(format!("--{key} needs a value"))),
            };
            if key == "set" {
                let (k, v) = value
                    .split_once('=')
                    .ok_or_else(|| Error::Cli(format!("--set wants k=v, got {value}")))?;
                flags.sets.push((k.to_string(), v.to_string()));
            } else {
                flags.values.insert(key.to_string(), value);
            }
        }
        Ok(flags)
    }

    /// String flag.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    /// Boolean switch: present with no value (or `true`/`1`/`yes`).
    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Parsed flag with default.
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.values.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Cli(format!("bad value for --{key}: {v}"))),
        }
    }

    /// Build the config: file, then --set overrides.
    pub fn config(&self) -> Result<Config> {
        let mut cfg = match self.get("config") {
            Some(path) => Config::load(path)?,
            None => Config::default(),
        };
        for (k, v) in &self.sets {
            cfg.set(k, v)?;
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Entry point; returns the process exit code.
pub fn run(args: &[String]) -> Result<i32> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(2);
    };
    // `report` takes positional file arguments the flag parser rejects, so
    // it dispatches before Flags::parse.
    if cmd == "report" {
        return cmd_report(&args[1..]);
    }
    let flags = Flags::parse(&args[1..])?;
    match cmd.as_str() {
        "gen-data" => cmd_gen_data(&flags),
        "run" => cmd_run(&flags),
        "assign" => cmd_assign(&flags),
        "baseline" => cmd_baseline(&flags),
        "scale-study" => cmd_scale_study(&flags),
        "inspect-artifacts" => cmd_inspect_artifacts(&flags),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(0)
        }
        other => Err(Error::Cli(format!("unknown command: {other}"))),
    }
}

fn print_usage() {
    eprintln!(
        "psch — parallel spectral clustering on a Hadoop-like runtime\n\n\
         commands:\n\
         \x20 gen-data          generate a planted topology file (Fig. 4 format)\n\
         \x20 run               run the 3-phase parallel pipeline\n\
         \x20 assign            assign new points with a saved model (Nystrom)\n\
         \x20 report            show or diff RunReport JSON files\n\
         \x20 baseline          single-machine spectral clustering (O(n^3) path)\n\
         \x20 scale-study       Table 5-1: per-phase time vs slave count\n\
         \x20 inspect-artifacts list AOT artifacts + backend status\n"
    );
}

fn cmd_gen_data(flags: &Flags) -> Result<i32> {
    let out = flags
        .get("out")
        .ok_or_else(|| Error::Cli("--out FILE required".into()))?;
    let n = flags.get_parse("n", 10_029usize)?;
    let edges = flags.get_parse("edges", 21_054usize)?;
    let k = flags.get_parse("k", 4usize)?;
    let seed = flags.get_parse("seed", 1u64)?;
    let topo = planted_graph(n, edges, k, 0.05, seed);
    std::fs::write(out, topo.to_text())?;
    println!(
        "wrote {} ({} vertices, {} edges, k={k})",
        out,
        topo.num_vertices(),
        topo.num_edges()
    );
    Ok(0)
}

fn load_input(flags: &Flags, cfg: &Config) -> Result<(PipelineInput, Option<Vec<usize>>)> {
    if let Some(path) = flags.get("input") {
        let text = std::fs::read_to_string(path)?;
        let topo = Topology::parse(&text)?;
        let truth = topo.labels();
        Ok((PipelineInput::Graph { topology: topo }, Some(truth)))
    } else {
        let n = flags.get_parse("blobs", 1024usize)?;
        let ps = gaussian_blobs(n, cfg.algo.k, 8, 0.4, 8.0, cfg.algo.seed);
        Ok((
            PipelineInput::Points { points: ps.points },
            Some(ps.labels),
        ))
    }
}

/// Apply the chaos switches (`--task-fail-prob P`, `--fail-node S@H`) —
/// sugar over the `[faults]` config section — and re-validate.
fn apply_chaos_flags(flags: &Flags, cfg: &mut Config) -> Result<()> {
    if let Some(p) = flags.get("task-fail-prob") {
        cfg.set("faults.task_fail_prob", p)?;
    }
    if let Some(deaths) = flags.get("fail-node") {
        cfg.set("faults.fail_node", deaths)?;
    }
    cfg.validate()
}

/// Apply the graph-mode switches (`--graph epsilon|tnn`, `--knn-t T`) —
/// sugar over `algo.graph` / the `[knn]` section — and re-validate.
fn apply_graph_flags(flags: &Flags, cfg: &mut Config) -> Result<()> {
    if let Some(mode) = flags.get("graph") {
        cfg.set("algo.graph", mode)?;
    }
    if let Some(t) = flags.get("knn-t") {
        cfg.set("knn.t", t)?;
    }
    cfg.validate()
}

/// Apply the eigensolver switch (`--eigensolver lanczos|chebdav`) — sugar
/// over `eigen.solver` — and re-validate.
fn apply_eigen_flags(flags: &Flags, cfg: &mut Config) -> Result<()> {
    if let Some(solver) = flags.get("eigensolver") {
        cfg.set("eigen.solver", solver)?;
    }
    cfg.validate()
}

fn cmd_run(flags: &Flags) -> Result<i32> {
    let mut cfg = flags.config()?;
    apply_chaos_flags(flags, &mut cfg)?;
    apply_graph_flags(flags, &mut cfg)?;
    apply_eigen_flags(flags, &mut cfg)?;
    let quiet = flags.get_bool("quiet");
    let trace_out = flags.get("trace-out");
    let report_out = flags.get("report-json");
    let metrics_out = flags.get("metrics-out");
    let (input, truth) = load_input(flags, &cfg)?;
    let runtime = Arc::new(KernelRuntime::auto(&crate::runtime::artifacts_dir()));
    if !quiet {
        println!("backend: {:?}; slaves: {}", runtime.backend(), cfg.cluster.slaves);
    }
    let driver = Driver::new(cfg, runtime);
    if flags.get_bool("explain-plan") {
        // Print the planned DAGs (stages, fusion, estimated shuffle) and
        // exit without launching a single job.
        print!("{}", driver.explain_plan(&input)?);
        return Ok(0);
    }
    // Tracing is off (and free) unless an output asked for it; the sink is
    // shared through the cluster, so enabling it here is seen by every job.
    let services = driver.services();
    if trace_out.is_some() || report_out.is_some() || metrics_out.is_some() {
        services.cluster.enable_tracing();
    }
    let result = driver.run_on(&services, &input)?;

    let quality =
        truth.map(|t| (nmi(&t, &result.labels), ari(&t, &result.labels)));
    if !quiet {
        // One formatter renders every summary line (table, shuffle/knn/
        // faults, quality, nnz) — see `metrics::report::render_run`.
        print!("{}", crate::metrics::report::render_run(&result, quality));
    }
    let data = services.cluster.trace().snapshot();
    // One telemetry derivation feeds the sparkline and the Prometheus
    // snapshot; the RunReport re-derives internally from the same spans.
    let tel = data
        .as_ref()
        .map(|d| crate::telemetry::from_trace(d, driver.config().cluster.racks));
    if let Some(data) = &data {
        if !quiet {
            print!("{}", crate::trace::critical::render_report(data, 5));
            if let Some(tel) = &tel {
                print!("{}", crate::telemetry::render_phase_utilization(data, tel));
            }
        }
        if let Some(path) = trace_out {
            std::fs::write(path, crate::trace::export::chrome_trace_json(data))?;
            println!("trace written: {path}");
        }
    }
    if let Some(path) = metrics_out {
        let owned;
        let tel = match &tel {
            Some(t) => t,
            None => {
                owned = crate::telemetry::Telemetry::empty();
                &owned
            }
        };
        std::fs::write(
            path,
            crate::telemetry::prometheus::render(tel, &result.phases),
        )?;
        println!("metrics written: {path}");
    }
    if let Some(path) = report_out {
        std::fs::write(
            path,
            crate::trace::report::run_report_json(
                driver.config(),
                &result,
                quality,
                data.as_ref(),
            ),
        )?;
        println!("report written: {path}");
    }
    if let Some(path) = flags.get("model-out") {
        let PipelineInput::Points { points } = &input else {
            return Err(Error::Cli(
                "--model-out needs point input: a graph topology carries no \
                 coordinates for Nystrom extension (use --blobs or a point \
                 file)"
                    .into(),
            ));
        };
        let artifact = crate::serving::ModelArtifact::from_run(
            driver.config(),
            points,
            &result,
        )?;
        artifact.save(path)?;
        println!(
            "model written: {path} ({} landmarks, k={}, d={})",
            artifact.m(),
            artifact.k,
            artifact.d
        );
    }
    Ok(0)
}

fn cmd_assign(flags: &Flags) -> Result<i32> {
    let mut cfg = flags.config()?;
    // `--batch B` / `--refresh MODE` are sugar over the `[serving]` config
    // section, mirroring the chaos/graph flag helpers.
    if let Some(b) = flags.get("batch") {
        cfg.set("serving.batch_points", b)?;
    }
    if let Some(r) = flags.get("refresh") {
        cfg.set("serving.refresh", r)?;
    }
    cfg.validate()?;
    let quiet = flags.get_bool("quiet");
    let model_path = flags
        .get("model")
        .ok_or_else(|| Error::Cli("--model FILE required".into()))?;
    let model = crate::serving::ModelArtifact::load(model_path)?;
    let scfg = cfg.serving;
    // The batch: a whitespace/comma point file, or fresh blobs drawn in the
    // model's own space (dimension `d`, `k` clusters) from a held-out seed.
    let points: Vec<f64> = if let Some(path) = flags.get("points") {
        crate::serving::parse_points(&std::fs::read_to_string(path)?, model.d)?
    } else {
        let n = flags.get_parse("blobs", 256usize)?;
        let seed = flags.get_parse("batch-seed", model.seed.wrapping_add(1))?;
        gaussian_blobs(n, model.k, model.d, 0.4, 8.0, seed)
            .points
            .into_iter()
            .flatten()
            .collect()
    };
    let report_out = flags.get("report-json");
    let metrics_out = flags.get("metrics-out");
    let n_points = points.len() / model.d.max(1);
    let t0 = std::time::Instant::now();
    let (labels, refreshed, summary, seconds, phases, data) = if flags
        .get_bool("oracle")
    {
        let out = crate::serving::assign_stream_oracle(&model, &points, &scfg)?;
        let summary = crate::metrics::ServingSummary {
            points: n_points as u64,
            batches: out.batches,
            refresh_updates: out.refresh_updates,
        };
        let wall = t0.elapsed().as_secs_f64();
        // The oracle path runs no cluster: its report carries a bare
        // "serving" phase (wall time only) and null telemetry sections.
        let stats = crate::coordinator::PhaseStats {
            name: "serving".into(),
            wall_s: wall,
            ..Default::default()
        };
        (out.labels, out.model, summary, wall, vec![stats], None)
    } else {
        let runtime =
            Arc::new(KernelRuntime::auto(&crate::runtime::artifacts_dir()));
        let driver = Driver::new(cfg.clone(), runtime);
        let services = driver.services();
        if report_out.is_some() || metrics_out.is_some() {
            services.cluster.enable_tracing();
        }
        let run = crate::serving::run_assign(&services, &model, &points, &scfg)?;
        let summary = run.stats.serving_summary();
        let data = services.cluster.trace().snapshot();
        (
            run.labels,
            run.model,
            summary,
            run.stats.virtual_s,
            vec![run.stats],
            data,
        )
    };
    if !quiet {
        let rate = if seconds > 0.0 { n_points as f64 / seconds } else { 0.0 };
        println!("serving[assign]: {}", summary.render());
        println!(
            "assigned {n_points} points in {seconds:.3}s ({rate:.0} points/s, \
             refresh={})",
            scfg.refresh.as_str()
        );
    }
    if let Some(path) = flags.get("labels-out") {
        let mut text = String::with_capacity(labels.len() * 2);
        for l in &labels {
            text.push_str(&l.to_string());
            text.push('\n');
        }
        std::fs::write(path, text)?;
        println!("labels written: {path}");
    }
    if let Some(path) = flags.get("model-out") {
        refreshed.save(path)?;
        println!("model written: {path}");
    }
    if report_out.is_some() || metrics_out.is_some() {
        // Serving runs report through the same RunReport/Prometheus pipe as
        // `psch run`, carrying a single "serving" phase.
        let result = crate::coordinator::PipelineResult {
            labels: labels.clone(),
            eigenvalues: Vec::new(),
            nnz: 0,
            total_virtual_s: phases.iter().map(|p| p.virtual_s).sum(),
            total_wall_s: phases.iter().map(|p| p.wall_s).sum(),
            sigma: model.sigma,
            centers: Vec::new(),
            embedding: Vec::new(),
            phases,
        };
        if let Some(path) = report_out {
            std::fs::write(
                path,
                crate::trace::report::run_report_json(
                    &cfg,
                    &result,
                    None,
                    data.as_ref(),
                ),
            )?;
            println!("report written: {path}");
        }
        if let Some(path) = metrics_out {
            let tel = match &data {
                Some(d) => crate::telemetry::from_trace(d, cfg.cluster.racks),
                None => crate::telemetry::Telemetry::empty(),
            };
            std::fs::write(
                path,
                crate::telemetry::prometheus::render(&tel, &result.phases),
            )?;
            println!("metrics written: {path}");
        }
    }
    Ok(0)
}

/// `psch report show FILE` / `psch report diff A B [--tolerance-pct N]` —
/// positional arguments, parsed here rather than by [`Flags`].
fn cmd_report(args: &[String]) -> Result<i32> {
    const USAGE: &str =
        "usage: psch report show FILE | psch report diff A B \
         [--tolerance-pct N] [--verbose]";
    let Some(sub) = args.first() else {
        return Err(Error::Cli(USAGE.into()));
    };
    let positional: Vec<&String> =
        args[1..].iter().take_while(|a| !a.starts_with("--")).collect();
    let flags = Flags::parse(&args[1 + positional.len()..])?;
    match sub.as_str() {
        "show" => {
            let [path] = positional[..] else {
                return Err(Error::Cli(USAGE.into()));
            };
            let doc = crate::telemetry::diff::load(path)?;
            let summary = crate::telemetry::diff::summarize(&doc)?;
            print!("{}", crate::telemetry::diff::render_show(&summary));
            Ok(0)
        }
        "diff" => {
            let [a_path, b_path] = positional[..] else {
                return Err(Error::Cli(USAGE.into()));
            };
            let tolerance = flags.get_parse("tolerance-pct", 0.0f64)?;
            if !tolerance.is_finite() || tolerance < 0.0 {
                return Err(Error::Cli(format!(
                    "--tolerance-pct must be >= 0, got {tolerance}"
                )));
            }
            let a = crate::telemetry::diff::summarize(
                &crate::telemetry::diff::load(a_path)?,
            )?;
            let b = crate::telemetry::diff::summarize(
                &crate::telemetry::diff::load(b_path)?,
            )?;
            let (lines, regressed) =
                crate::telemetry::diff::diff(&a, &b, tolerance);
            print!(
                "{}",
                crate::telemetry::diff::render_diff(
                    &lines,
                    tolerance,
                    flags.get_bool("verbose"),
                )
            );
            Ok(if regressed { 1 } else { 0 })
        }
        other => Err(Error::Cli(format!(
            "unknown report subcommand: {other}\n{USAGE}"
        ))),
    }
}

fn cmd_baseline(flags: &Flags) -> Result<i32> {
    let mut cfg = flags.config()?;
    apply_graph_flags(flags, &mut cfg)?;
    apply_eigen_flags(flags, &mut cfg)?;
    let n = flags.get_parse("blobs", 512usize)?;
    let ps = gaussian_blobs(n, cfg.algo.k, 8, 0.4, 8.0, cfg.algo.seed);
    // The baseline shares the driver's sigma resolution so `auto` means the
    // same bandwidth on both paths.
    let sigma_input = PipelineInput::Points { points: ps.points.clone() };
    let sigma = crate::coordinator::driver::resolve_sigma(
        cfg.algo.sigma,
        &cfg.knn,
        &sigma_input,
    )?;
    let params = crate::spectral::SpectralParams {
        k: cfg.algo.k,
        sigma,
        epsilon: cfg.algo.epsilon,
        graph: cfg.algo.graph,
        knn: cfg.knn,
        lanczos_steps: cfg.algo.lanczos_steps,
        kmeans_iters: cfg.algo.kmeans_iters,
        kmeans_tol: cfg.algo.kmeans_tol,
        seed: cfg.algo.seed,
        eigen: cfg.eigen,
    };
    let solver = match cfg.eigen.solver {
        crate::coordinator::eigen::EigenSolverKind::Lanczos => {
            crate::spectral::Eigensolver::Lanczos
        }
        crate::coordinator::eigen::EigenSolverKind::ChebDav => {
            crate::spectral::Eigensolver::ChebDav
        }
    };
    let t0 = std::time::Instant::now();
    let r = crate::spectral::spectral_cluster_points(&ps.points, &params, solver)?;
    println!(
        "single-machine: n={n} wall={:.2}s NMI={:.4}",
        t0.elapsed().as_secs_f64(),
        nmi(&ps.labels, &r.labels)
    );
    Ok(0)
}

fn cmd_scale_study(flags: &Flags) -> Result<i32> {
    let base_cfg = flags.config()?;
    let n = flags.get_parse("n", 2048usize)?;
    let slaves: Vec<usize> = flags
        .get("slaves")
        .unwrap_or("1,2,4,6,8,10")
        .split(',')
        .map(|s| s.trim().parse().map_err(|_| Error::Cli(format!("bad slave count {s}"))))
        .collect::<Result<Vec<_>>>()?;
    let runtime = Arc::new(KernelRuntime::auto(&crate::runtime::artifacts_dir()));
    let ps = gaussian_blobs(n, base_cfg.algo.k, 8, 0.4, 8.0, base_cfg.algo.seed);
    let input = PipelineInput::Points { points: ps.points.clone() };

    let mut table = AsciiTable::new(&[
        "Slave Number",
        "Parallel similarity matrix",
        "Parallel k eigenvectors",
        "Parallel K-means",
        "Total Time",
    ]);
    let mut curve = SpeedupCurve::default();
    for &m in &slaves {
        let mut cfg = base_cfg.clone();
        cfg.cluster.slaves = m;
        let driver = Driver::new(cfg, runtime.clone());
        let r = driver.run(&input)?;
        let d = |s: f64| hms(std::time::Duration::from_secs_f64(s));
        table.row(&[
            m.to_string(),
            d(r.phases[0].virtual_s),
            d(r.phases[1].virtual_s),
            d(r.phases[2].virtual_s),
            d(r.total_virtual_s),
        ]);
        curve.push(m, r.total_virtual_s);
        println!("m={m}: total {} (wall {:.1}s)", d(r.total_virtual_s), r.total_wall_s);
    }
    println!("\nTable 5-1 reproduction (n={n}):\n{}", table.render());
    println!("speedups: {:?}", curve.speedups());
    println!("\nFig. 5 trend:\n{}", curve.ascii_plot(48, 12));
    Ok(0)
}

fn cmd_inspect_artifacts(flags: &Flags) -> Result<i32> {
    let dir = flags
        .get("dir")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(crate::runtime::artifacts_dir);
    let manifest = dir.join("manifest.txt");
    match std::fs::read_to_string(&manifest) {
        Ok(text) => {
            let entries = crate::runtime::parse_manifest(&text)?;
            println!("{} artifacts in {}:", entries.len(), dir.display());
            for e in &entries {
                let ins: Vec<String> = e
                    .inputs
                    .iter()
                    .map(|s| format!("{}[{:?}]", s.dtype, s.dims))
                    .collect();
                println!("  {} ({}) -> {} output(s)", e.name, ins.join(", "), e.out_arity);
            }
            let rt = KernelRuntime::auto(&dir);
            println!("backend after load: {:?}", rt.backend());
        }
        Err(e) => println!("no manifest at {}: {e}", manifest.display()),
    }
    Ok(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn flags_parse_pairs_and_sets() {
        let f = Flags::parse(&s(&[
            "--n", "100", "--set", "algo.k=5", "--set", "cluster.slaves=3",
        ]))
        .unwrap();
        assert_eq!(f.get("n"), Some("100"));
        assert_eq!(f.get_parse("n", 0usize).unwrap(), 100);
        assert_eq!(f.get_parse("missing", 7usize).unwrap(), 7);
        let cfg = f.config().unwrap();
        assert_eq!(cfg.algo.k, 5);
        assert_eq!(cfg.cluster.slaves, 3);
    }

    #[test]
    fn flags_reject_malformed() {
        assert!(Flags::parse(&s(&["positional"])).is_err());
        assert!(Flags::parse(&s(&["--dangling"])).is_err(), "value required");
        assert!(Flags::parse(&s(&["--out"])).is_err(), "value required");
        assert!(Flags::parse(&s(&["--set", "noequals"])).is_err());
        assert!(Flags::parse(&s(&["--set"])).is_err(), "--set needs k=v");
        let f = Flags::parse(&s(&["--n", "banana"])).unwrap();
        assert!(f.get_parse("n", 0usize).is_err());
    }

    #[test]
    fn bare_flags_parse_as_boolean_switches() {
        // Trailing switch.
        let f = Flags::parse(&s(&["--blobs", "100", "--explain-plan"])).unwrap();
        assert_eq!(f.get("blobs"), Some("100"));
        assert!(f.get_bool("explain-plan"));
        assert!(!f.get_bool("absent"));
        // Switch followed by another flag.
        let f = Flags::parse(&s(&["--explain-plan", "--blobs", "50"])).unwrap();
        assert!(f.get_bool("explain-plan"));
        assert_eq!(f.get_parse("blobs", 0usize).unwrap(), 50);
        // Explicit value still works.
        let f = Flags::parse(&s(&["--explain-plan", "yes"])).unwrap();
        assert!(f.get_bool("explain-plan"));
        // --quiet is a switch too; --trace-out still requires a value.
        let f = Flags::parse(&s(&["--quiet", "--blobs", "50"])).unwrap();
        assert!(f.get_bool("quiet"));
        assert!(Flags::parse(&s(&["--trace-out"])).is_err());
    }

    #[test]
    fn chaos_flags_map_into_the_faults_config() {
        // Exercises the same helper cmd_run uses, so the mapping cannot
        // silently drift from what `psch run` applies.
        let f = Flags::parse(&s(&[
            "--task-fail-prob", "0.1", "--fail-node", "1@40",
        ]))
        .unwrap();
        let mut cfg = f.config().unwrap();
        apply_chaos_flags(&f, &mut cfg).unwrap();
        assert!((cfg.faults.task_fail_prob - 0.1).abs() < 1e-12);
        assert_eq!(cfg.faults.node_deaths.len(), 1);
        assert_eq!(cfg.faults.node_deaths[0].slave, 1);
        assert_eq!(cfg.faults.node_deaths[0].at_heartbeat, 40);

        // An out-of-range death is rejected by the shared validation.
        let bad = Flags::parse(&s(&["--fail-node", "9@5"])).unwrap();
        let mut cfg = bad.config().unwrap();
        assert!(apply_chaos_flags(&bad, &mut cfg).is_err());
    }

    #[test]
    fn graph_flags_map_into_the_config() {
        let f = Flags::parse(&s(&["--graph", "tnn", "--knn-t", "5"])).unwrap();
        let mut cfg = f.config().unwrap();
        apply_graph_flags(&f, &mut cfg).unwrap();
        assert_eq!(cfg.algo.graph, crate::knn::GraphMode::Tnn);
        assert_eq!(cfg.knn.t, 5);

        // Bad values are rejected by the shared config parser.
        let bad = Flags::parse(&s(&["--graph", "banana"])).unwrap();
        let mut cfg = bad.config().unwrap();
        assert!(apply_graph_flags(&bad, &mut cfg).is_err());
        let bad = Flags::parse(&s(&["--knn-t", "0"])).unwrap();
        let mut cfg = bad.config().unwrap();
        assert!(apply_graph_flags(&bad, &mut cfg).is_err());
    }

    #[test]
    fn eigensolver_flag_maps_into_the_config() {
        let f = Flags::parse(&s(&["--eigensolver", "chebdav"])).unwrap();
        let mut cfg = f.config().unwrap();
        apply_eigen_flags(&f, &mut cfg).unwrap();
        assert_eq!(
            cfg.eigen.solver,
            crate::coordinator::eigen::EigenSolverKind::ChebDav
        );
        // No flag leaves the configured backend alone.
        let none = Flags::parse(&s(&[])).unwrap();
        let mut cfg = none.config().unwrap();
        apply_eigen_flags(&none, &mut cfg).unwrap();
        assert_eq!(
            cfg.eigen.solver,
            crate::coordinator::eigen::EigenSolverKind::Lanczos
        );
        // Bad values are rejected by the shared config parser.
        let bad = Flags::parse(&s(&["--eigensolver", "banana"])).unwrap();
        let mut cfg = bad.config().unwrap();
        assert!(apply_eigen_flags(&bad, &mut cfg).is_err());
    }

    #[test]
    fn unknown_command_errors() {
        assert!(run(&s(&["frobnicate"])).is_err());
        assert_eq!(run(&[]).unwrap(), 2);
        assert_eq!(run(&s(&["help"])).unwrap(), 0);
    }

    #[test]
    fn gen_data_roundtrip() {
        let dir = std::env::temp_dir().join("psch_cli_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("g.txt");
        let code = run(&s(&[
            "gen-data",
            "--out",
            path.to_str().unwrap(),
            "--n",
            "50",
            "--edges",
            "100",
            "--k",
            "2",
        ]))
        .unwrap();
        assert_eq!(code, 0);
        let topo = Topology::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(topo.num_vertices(), 50);
        assert_eq!(topo.num_edges(), 100);
    }
}
