//! In-tree property-testing harness (no proptest in the offline vendor set).
//!
//! [`check`] runs a property over `cases` generated inputs from a seeded
//! [`Gen`]; on failure it reports the seed and case index so the exact
//! failing input can be replayed deterministically. Generators for the
//! domain's common inputs (vectors, symmetric matrices, graphs, labelings)
//! live here too.

use crate::data::{planted_graph, Topology};
use crate::linalg::DenseMatrix;
use crate::util::Xoshiro256;

/// A seeded input generator for one property-test case.
pub struct Gen {
    rng: Xoshiro256,
}

impl Gen {
    /// Generator for a given case seed.
    pub fn new(seed: u64) -> Self {
        Self { rng: Xoshiro256::new(seed) }
    }

    /// Uniform usize in [lo, hi].
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.next_index(hi - lo + 1)
    }

    /// Uniform f64 in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    /// Random bool with probability p of true.
    pub fn bool_p(&mut self, p: f64) -> bool {
        self.rng.next_f64() < p
    }

    /// Vector of f64 in [lo, hi).
    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.f64_in(lo, hi)).collect()
    }

    /// Byte vector.
    pub fn bytes(&mut self, len: usize) -> Vec<u8> {
        (0..len).map(|_| (self.rng.next_u64() & 0xff) as u8).collect()
    }

    /// Random labeling of n points over k classes.
    pub fn labeling(&mut self, n: usize, k: usize) -> Vec<usize> {
        (0..n).map(|_| self.rng.next_index(k)).collect()
    }

    /// Random symmetric matrix with entries in [-1, 1].
    pub fn symmetric_matrix(&mut self, n: usize) -> DenseMatrix {
        let mut m = DenseMatrix::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let v = self.f64_in(-1.0, 1.0);
                m[(i, j)] = v;
                m[(j, i)] = v;
            }
        }
        m
    }

    /// Random planted graph (n in [2k, 4k], ~2.5 edges/vertex).
    pub fn graph(&mut self, k: usize) -> Topology {
        let n = self.usize_in(2 * k.max(1) * 10, 4 * k.max(1) * 10);
        let edges = (n as f64 * 2.5) as usize;
        planted_graph(n, edges, k, 0.1, self.rng.next_u64())
    }

    /// Access the underlying RNG.
    pub fn rng(&mut self) -> &mut Xoshiro256 {
        &mut self.rng
    }
}

/// Run `prop` over `cases` generated inputs; panics with the replay seed on
/// the first failure. `prop` returns `Err(reason)` or panics to fail.
pub fn check<F>(name: &str, cases: usize, base_seed: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> std::result::Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(case as u64);
        let mut gen = Gen::new(seed);
        if let Err(reason) = prop(&mut gen) {
            panic!(
                "property {name:?} failed at case {case}/{cases} \
                 (replay: Gen::new({seed:#x})): {reason}"
            );
        }
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("trivial", 50, 1, |g| {
            let v = g.vec_f64(10, 0.0, 1.0);
            prop_assert!(v.iter().all(|&x| (0.0..1.0).contains(&x)), "range");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "replay")]
    fn check_reports_seed_on_failure() {
        check("fails", 10, 2, |g| {
            let x = g.usize_in(0, 100);
            prop_assert!(x > 1000, "x={x} is never > 1000");
            Ok(())
        });
    }

    #[test]
    fn generators_shapes() {
        let mut g = Gen::new(5);
        assert_eq!(g.vec_f64(4, 0.0, 1.0).len(), 4);
        assert_eq!(g.bytes(8).len(), 8);
        let m = g.symmetric_matrix(6);
        assert!(m.is_symmetric(0.0));
        let l = g.labeling(20, 3);
        assert!(l.iter().all(|&x| x < 3));
        let topo = g.graph(2);
        topo.validate().unwrap();
    }

    #[test]
    fn generator_deterministic_by_seed() {
        let a = Gen::new(9).vec_f64(16, -1.0, 1.0);
        let b = Gen::new(9).vec_f64(16, -1.0, 1.0);
        assert_eq!(a, b);
    }
}
