//! Clustering quality metrics: NMI, ARI, purity.
//!
//! Used to validate the pipeline against planted ground truth — the paper
//! itself reports no quality numbers, only times, so these metrics guard
//! *our* correctness (a fast wrong clustering would be worthless).

use std::collections::HashMap;

/// Contingency table between two labelings.
fn contingency(a: &[usize], b: &[usize]) -> HashMap<(usize, usize), usize> {
    assert_eq!(a.len(), b.len(), "labelings must align");
    let mut c = HashMap::new();
    for (&x, &y) in a.iter().zip(b) {
        *c.entry((x, y)).or_insert(0) += 1;
    }
    c
}

fn class_counts(a: &[usize]) -> HashMap<usize, usize> {
    let mut c = HashMap::new();
    for &x in a {
        *c.entry(x).or_insert(0) += 1;
    }
    c
}

/// Normalized mutual information in [0, 1] (arithmetic-mean normalization).
pub fn nmi(truth: &[usize], pred: &[usize]) -> f64 {
    let n = truth.len();
    if n == 0 {
        return 1.0;
    }
    let nt = class_counts(truth);
    let np = class_counts(pred);
    let joint = contingency(truth, pred);
    let nf = n as f64;

    let mut mi = 0.0;
    for (&(t, p), &c) in &joint {
        let pxy = c as f64 / nf;
        let px = nt[&t] as f64 / nf;
        let py = np[&p] as f64 / nf;
        mi += pxy * (pxy / (px * py)).ln();
    }
    let h = |counts: &HashMap<usize, usize>| -> f64 {
        counts
            .values()
            .map(|&c| {
                let p = c as f64 / nf;
                -p * p.ln()
            })
            .sum()
    };
    let (ht, hp) = (h(&nt), h(&np));
    if ht == 0.0 && hp == 0.0 {
        return 1.0; // both single-cluster: identical partitions
    }
    let denom = (ht + hp) / 2.0;
    if denom == 0.0 {
        0.0
    } else {
        (mi / denom).clamp(0.0, 1.0)
    }
}

/// Adjusted Rand index in [-1, 1] (1 = identical partitions, ~0 = random).
pub fn ari(truth: &[usize], pred: &[usize]) -> f64 {
    let n = truth.len();
    if n < 2 {
        return 1.0;
    }
    let choose2 = |x: usize| -> f64 { (x * x.saturating_sub(1)) as f64 / 2.0 };
    let joint = contingency(truth, pred);
    let nt = class_counts(truth);
    let np = class_counts(pred);
    let sum_ij: f64 = joint.values().map(|&c| choose2(c)).sum();
    let sum_a: f64 = nt.values().map(|&c| choose2(c)).sum();
    let sum_b: f64 = np.values().map(|&c| choose2(c)).sum();
    let total = choose2(n);
    let expected = sum_a * sum_b / total;
    let max_index = (sum_a + sum_b) / 2.0;
    if (max_index - expected).abs() < 1e-15 {
        return 1.0; // degenerate: e.g. both all-singletons or both one-cluster
    }
    (sum_ij - expected) / (max_index - expected)
}

/// Purity in (0, 1]: fraction of points in their cluster's majority class.
pub fn purity(truth: &[usize], pred: &[usize]) -> f64 {
    let n = truth.len();
    if n == 0 {
        return 1.0;
    }
    let joint = contingency(pred, truth); // (cluster, class) -> count
    let mut best: HashMap<usize, usize> = HashMap::new();
    for (&(cluster, _class), &c) in &joint {
        let e = best.entry(cluster).or_insert(0);
        if c > *e {
            *e = c;
        }
    }
    best.values().sum::<usize>() as f64 / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_agreement() {
        let t = vec![0, 0, 1, 1, 2, 2];
        assert!((nmi(&t, &t) - 1.0).abs() < 1e-12);
        assert!((ari(&t, &t) - 1.0).abs() < 1e-12);
        assert_eq!(purity(&t, &t), 1.0);
    }

    #[test]
    fn label_permutation_invariant() {
        let t = vec![0, 0, 1, 1, 2, 2];
        let p = vec![2, 2, 0, 0, 1, 1]; // same partition, renamed
        assert!((nmi(&t, &p) - 1.0).abs() < 1e-12);
        assert!((ari(&t, &p) - 1.0).abs() < 1e-12);
        assert_eq!(purity(&t, &p), 1.0);
    }

    #[test]
    fn independent_labelings_near_zero_ari() {
        // Pred splits orthogonally to truth.
        let t = vec![0, 0, 0, 0, 1, 1, 1, 1];
        let p = vec![0, 1, 0, 1, 0, 1, 0, 1];
        assert!(ari(&t, &p).abs() < 0.2, "{}", ari(&t, &p));
        assert!(nmi(&t, &p) < 0.2);
    }

    #[test]
    fn partial_agreement_ordering() {
        let t = vec![0, 0, 0, 1, 1, 1];
        let good = vec![0, 0, 1, 1, 1, 1]; // one mistake
        let bad = vec![0, 1, 0, 1, 0, 1]; // orthogonal
        assert!(nmi(&t, &good) > nmi(&t, &bad));
        assert!(ari(&t, &good) > ari(&t, &bad));
        assert!(purity(&t, &good) > purity(&t, &bad));
    }

    #[test]
    fn purity_overclustering_is_one() {
        // Every point its own cluster: purity 1 (known metric quirk).
        let t = vec![0, 0, 1, 1];
        let p = vec![0, 1, 2, 3];
        assert_eq!(purity(&t, &p), 1.0);
        // ARI penalizes it (not 1; degenerate all-singleton guard aside).
        assert!(ari(&t, &p) < 0.5);
    }

    #[test]
    fn empty_and_trivial() {
        assert_eq!(nmi(&[], &[]), 1.0);
        assert_eq!(ari(&[0], &[0]), 1.0);
        let ones = vec![0; 5];
        assert!((nmi(&ones, &ones) - 1.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn mismatched_lengths_panic() {
        nmi(&[0, 1], &[0]);
    }
}
