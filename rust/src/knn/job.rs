//! Distributed t-NN similarity phase (graph mode `tnn`).
//!
//! The phase-1 alternative to [`crate::coordinator::similarity_job`]'s
//! all-pairs job: instead of pricing every tile and post-filtering by
//! `epsilon`, each map task owns a block of rows and asks the shared
//! spatial index for each row's `t` nearest neighbors — pairs the index
//! prunes are never priced at all. As a `dataflow::Pipeline`:
//!
//! ```text
//! read_dfs(points) → map tnn-query        per-row bounded top-t heaps;
//!                                         emits the row's heap + one
//!                                         mirror record per neighbor
//!                  → combine (merge_max)  mirrors collapse map-side
//!                  → reduce tnn-symmetrize S = max(S, Sᵀ) + unit diagonal,
//!                                         writes graph-row table chunks,
//!                                         emits the degree
//! ```
//!
//! The index is shared by every map task and built lazily by whichever
//! task runs first (`OnceLock`) — planning a pipeline for `--explain-plan`
//! never pays the build. Its deterministic virtual cost is charged to the
//! block-0 task so the makespan model stays independent of thread timing.
//! The reduce writes the exact `chunk_key(row, colblock) →
//! encode_sparse_row` format phase 2 already consumes, so the eigen phase
//! runs unchanged on either graph mode. Output is byte-identical to the
//! [`super::tnn_sparse`] oracle.

use std::sync::{Arc, OnceLock};

use crate::coordinator::similarity_job::{chunk_key, SimilarityOutput, BLOCK};
use crate::coordinator::{costmodel, PhaseStats, Services};
use crate::dataflow::{Collected, Emit, Group, Pipeline};
use crate::error::{Error, Result};
use crate::mapreduce::names;
use crate::util::bytes::{decode_sparse_row, encode_sparse_row};

use super::{merge_max, IndexKind, KnnConfig, KnnIndex, QueryStats};

struct TnnMapper {
    points: Arc<Vec<f64>>,
    knn: KnnConfig,
    /// Built on first use (once per job), shared across map tasks.
    index: OnceLock<KnnIndex>,
    gamma: f64,
    /// Effective neighbor count (already clamped to n−1).
    t: usize,
    n: usize,
    d: usize,
}

impl TnnMapper {
    /// Query the index for every owned row; emit the row's heap plus one
    /// mirror record per neighbor (the symmetrization half).
    fn map_block(&self, b: u64, out: &mut Emit<'_, u64, Vec<u8>>) -> Result<()> {
        let index = self.index.get_or_init(|| {
            KnnIndex::build(self.points.clone(), self.n, self.d, &self.knn)
        });
        let b = b as usize;
        let lo = b * BLOCK;
        let hi = ((b + 1) * BLOCK).min(self.n);
        // The owned rows come off the staged DFS points file; the scheduler
        // charges the read at the attempt's locality tier.
        out.incr(names::EXTRA_INPUT_BYTES, ((hi - lo) * self.d * 8) as u64);
        if b == 0 && self.knn.index == IndexKind::KdTree {
            // kd-tree build: ~n·log₂(n) comparisons, charged to the block-0
            // task regardless of which thread happened to build — the
            // virtual makespan must not depend on wall-clock racing. (The
            // brute index has no build to charge.)
            let build_units =
                self.n as u64 * self.n.next_power_of_two().trailing_zeros().max(1) as u64;
            out.incr(
                names::COMPUTE_US,
                costmodel::units_to_us(build_units, costmodel::KNN_PRUNED_PAIRS_PER_S),
            );
        }
        let mut stats = QueryStats::default();
        let mut evictions = 0u64;
        for i in lo..hi {
            let heap = index.query(index.row(i), self.t, Some(i as u32), &mut stats);
            evictions += heap.evictions();
            let own: Vec<(u32, f64)> = heap
                .into_sorted()
                .into_iter()
                .map(|nb| (nb.idx, (-self.gamma * nb.d2).exp()))
                .collect();
            for &(j, w) in &own {
                out.emit(j as u64, encode_sparse_row(&[(i as u32, w)]));
            }
            out.emit(i as u64, encode_sparse_row(&own));
        }
        out.incr(names::KNN_PAIRS_EVALUATED, stats.pairs_evaluated);
        out.incr(names::KNN_PRUNED_PAIRS, stats.pruned_pairs);
        out.incr(names::KNN_HEAP_EVICTIONS, evictions);
        // Deterministic virtual compute: priced pairs at the reference
        // machine's per-pair rate, dismissed candidates an order cheaper.
        out.incr(
            names::COMPUTE_US,
            costmodel::units_to_us(stats.pairs_evaluated, costmodel::KNN_PAIRS_PER_S)
                + costmodel::units_to_us(
                    stats.pruned_pairs,
                    costmodel::KNN_PRUNED_PAIRS_PER_S,
                ),
        );
        Ok(())
    }
}

/// Build the tnn-mode phase-1 pipeline: stage the points in the DFS, one
/// split per row block, and wire `read_dfs → map_kv(tnn-query) →
/// group_reduce(combine + tnn-symmetrize) → collect(degrees)`.
pub(crate) fn tnn_pipeline(
    services: &Services,
    points: Arc<Vec<f64>>,
    n: usize,
    d: usize,
    sigma: f64,
    table_name: &str,
) -> Result<(Pipeline, Collected<u64, f64>)> {
    if n == 0 || points.len() < n * d {
        return Err(Error::MapReduce(format!(
            "tnn similarity: need n×d points, got n={n} d={d} len={}",
            points.len()
        )));
    }
    let knn = services.knn;
    let t = knn.t.min(n - 1);
    let table = services.tables.create(table_name, services.cluster.num_slaves())?;
    let gamma = crate::spectral::gamma_of_sigma(sigma);

    // Stage the input points in the DFS so every split can declare the
    // nodes holding its row block.
    let input_path = format!("/input/{table_name}.points");
    let mut raw = Vec::with_capacity(points.len() * 8);
    for &x in points.iter() {
        raw.extend_from_slice(&x.to_le_bytes());
    }
    services.dfs.write_file(&input_path, &raw)?;
    let row_bytes = d * 8;
    let nb = n.div_ceil(BLOCK);
    let mut splits: Vec<Vec<(u64, ())>> = Vec::with_capacity(nb);
    let mut ranges: Vec<Vec<(usize, usize)>> = Vec::with_capacity(nb);
    for b in 0..nb {
        splits.push(vec![(b as u64, ())]);
        ranges.push(vec![(b * BLOCK * row_bytes, ((b + 1) * BLOCK).min(n) * row_bytes)]);
    }

    // The shared spatial index is built lazily by the first map task to
    // run — a pipeline constructed only for `--explain-plan` never pays it.
    let mapper =
        TnnMapper { points, knn, index: OnceLock::new(), gamma, t, n, d };

    let pipeline = Pipeline::new("similarity-tnn");
    let table_c = table.clone();
    let degrees = pipeline
        .read_dfs(&input_path, splits, ranges)
        .map_kv("tnn-query", move |b: u64, _: (), out| mapper.map_block(b, out))
        .group_reduce("tnn-symmetrize")
        .reducers(services.cluster.num_slaves())
        .combine(|row: u64, values: &mut Group<'_, Vec<u8>>, out| {
            // Map-side row merge: a row's own heap and the mirrors landing
            // on it collapse to one record before crossing the shuffle.
            let mut entries: Vec<(u32, f64)> = Vec::new();
            while let Some(chunk) = values.next_value() {
                entries.extend(decode_sparse_row(&chunk));
            }
            merge_max(&mut entries);
            out.emit(row, encode_sparse_row(&entries));
            Ok(())
        })
        .reduce(move |row: u64, values: &mut Group<'_, Vec<u8>>, out| {
            // Max-symmetrization: the union of the row's heap and every
            // mirror, duplicates collapsed to the max weight, unit diagonal.
            let mut entries: Vec<(u32, f64)> = Vec::new();
            while let Some(chunk) = values.next_value() {
                entries.extend(decode_sparse_row(&chunk));
            }
            entries.push((row as u32, 1.0));
            merge_max(&mut entries);
            let degree: f64 = entries.iter().map(|&(_, v)| v).sum();
            out.incr("SIM_ENTRIES_KEPT", entries.len() as u64);
            out.incr(
                names::COMPUTE_US,
                costmodel::units_to_us(
                    entries.len() as u64,
                    costmodel::GRAPH_EDGES_PER_S,
                ),
            );
            // Write per-column-block chunks — the same table layout the
            // epsilon path produces and the eigen phase consumes.
            let mut i = 0;
            let mut out_bytes = 0u64;
            while i < entries.len() {
                let cb = entries[i].0 as usize / BLOCK;
                let mut j = i;
                while j < entries.len() && entries[j].0 as usize / BLOCK == cb {
                    j += 1;
                }
                let payload = encode_sparse_row(&entries[i..j]);
                out_bytes += payload.len() as u64;
                table_c.put(chunk_key(row, cb as u64), payload)?;
                i = j;
            }
            out.incr(names::EXTRA_OUTPUT_BYTES, out_bytes);
            out.emit(row, degree);
            Ok(())
        })
        .collect();
    Ok((pipeline, degrees))
}

/// Run the tnn-mode phase 1: build the sparse t-NN similarity table plus
/// the degree vector. `points` is n×d row-major f64; neighbor count and
/// index kind come from [`Services::knn`]. Returns the same
/// [`SimilarityOutput`] shape as the epsilon path, so the driver's phase
/// accounting is mode-agnostic.
pub fn run_tnn_phase(
    services: &Services,
    points: Arc<Vec<f64>>,
    n: usize,
    d: usize,
    sigma: f64,
    table_name: &str,
) -> Result<SimilarityOutput> {
    let mut stats = PhaseStats { name: "similarity".into(), ..Default::default() };
    let (pipeline, degree_handle) =
        tnn_pipeline(services, points, n, d, sigma, table_name)?;
    let mut run = pipeline.run(services)?;

    let mut degrees = vec![0.0f64; n];
    for (row, degree) in degree_handle.take(&mut run) {
        degrees[row as usize] = degree;
    }
    stats.absorb_run(&run.stats);
    let counters = run.stats.merged_counters();
    Ok(SimilarityOutput {
        degrees,
        stats,
        nnz: counters.get("SIM_ENTRIES_KEPT"),
        counters,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::coordinator::similarity_job::read_similarity_row;
    use crate::data::gaussian_blobs;
    use crate::knn::KnnConfig;
    use crate::runtime::KernelRuntime;

    fn services(m: usize, knn: KnnConfig) -> Services {
        let mut svc = Services::new(Cluster::new(m), Arc::new(KernelRuntime::native()));
        svc.knn = knn;
        svc
    }

    fn flat(points: &[Vec<f64>]) -> Arc<Vec<f64>> {
        Arc::new(points.iter().flatten().copied().collect())
    }

    #[test]
    fn distributed_rows_match_oracle_bitwise() {
        let (n, d) = (180, 4);
        let ps = gaussian_blobs(n, 3, d, 0.4, 8.0, 5);
        let cfg = KnnConfig { t: 6, ..Default::default() };
        let svc = services(2, cfg);
        let out = run_tnn_phase(&svc, flat(&ps.points), n, d, 1.2, "S").unwrap();
        let oracle = crate::knn::tnn_sparse(&ps.points, 1.2, &cfg);
        let table = svc.tables.open("S").unwrap();
        let nb = n.div_ceil(BLOCK);
        for i in 0..n {
            let row = read_similarity_row(&table, i as u64, nb);
            let want: Vec<(u32, f64)> = oracle.row(i).collect();
            assert_eq!(row.len(), want.len(), "row {i} nnz");
            for ((j1, v1), (j2, v2)) in row.iter().zip(&want) {
                assert_eq!(j1, j2, "row {i}");
                assert_eq!(v1.to_bits(), v2.to_bits(), "row {i} col {j1}");
            }
        }
        assert_eq!(out.nnz, oracle.nnz() as u64);
    }

    #[test]
    fn counters_and_stats_populated() {
        let (n, d) = (150, 3);
        let ps = gaussian_blobs(n, 3, d, 0.4, 8.0, 7);
        let svc = services(3, KnnConfig::default());
        let out = run_tnn_phase(&svc, flat(&ps.points), n, d, 1.0, "S").unwrap();
        assert!(out.counters.get(names::KNN_PAIRS_EVALUATED) > 0);
        assert!(
            out.counters.get(names::KNN_PRUNED_PAIRS) > 0,
            "kd-tree should prune on blob data"
        );
        assert!(out.stats.virtual_s > 0.0);
        assert_eq!(out.stats.jobs, 1, "query map + symmetrize reduce fuse");
        assert!(out.stats.shuffle_bytes > 0, "heaps cross the shuffle");
        // Degrees: unit diagonal plus at least t positive weights.
        for &deg in &out.degrees {
            assert!(deg > 1.0, "degree {deg} missing neighbors");
        }
    }

    #[test]
    fn rejects_empty_input() {
        let svc = services(2, KnnConfig::default());
        assert!(run_tnn_phase(&svc, Arc::new(Vec::new()), 0, 3, 1.0, "S").is_err());
    }
}
