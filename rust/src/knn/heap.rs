//! Bounded top-t neighbor heaps — the per-row state of every t-NN query.
//!
//! A [`TopTHeap`] keeps the `t` nearest candidates seen so far as a binary
//! max-heap ordered by `(d2, idx)`. That key is a *total* order (indices
//! are distinct), so the surviving set is exactly the `t` smallest keys of
//! the candidate stream **regardless of arrival order** — a kd-tree
//! traversal and a brute-force scan that feed the same candidates produce
//! byte-identical neighbor lists. The heap's current worst distance is the
//! pruning bound the spatial indexes test subtrees and partial distances
//! against.

use std::cmp::Ordering;

/// One candidate neighbor: squared distance to the query plus point index.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Neighbor {
    /// Squared Euclidean distance to the query point.
    pub d2: f64,
    /// Index of the candidate point.
    pub idx: u32,
}

/// Total order: nearest first, ties broken by the lower index. Distances
/// are finite by construction, so `total_cmp` equals the numeric order.
fn cmp(a: &Neighbor, b: &Neighbor) -> Ordering {
    a.d2.total_cmp(&b.d2).then(a.idx.cmp(&b.idx))
}

/// Bounded max-heap of the `t` nearest candidates.
#[derive(Debug, Clone)]
pub struct TopTHeap {
    cap: usize,
    /// Max-heap by [`cmp`]: `items[0]` is the worst kept neighbor.
    items: Vec<Neighbor>,
    evictions: u64,
}

impl TopTHeap {
    /// Empty heap keeping at most `cap` neighbors.
    pub fn new(cap: usize) -> Self {
        Self { cap, items: Vec::with_capacity(cap), evictions: 0 }
    }

    /// Number of kept neighbors.
    pub fn len(&self) -> usize {
        self.items.len()
    }

    /// True when nothing has been kept.
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Candidates a full heap displaced (the `KNN_HEAP_EVICTIONS` feed).
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// The pruning bound: the worst kept squared distance once the heap is
    /// full, `+inf` before. A candidate whose (partial) squared distance
    /// exceeds this **strictly** can never enter the heap — equality must
    /// not prune, because the index tie-break may still admit it.
    pub fn bound(&self) -> f64 {
        if self.items.len() < self.cap {
            f64::INFINITY
        } else {
            // cap 0: nothing is ever wanted, every candidate is prunable.
            self.items.first().map_or(f64::NEG_INFINITY, |n| n.d2)
        }
    }

    /// Offer a candidate; returns whether it was kept.
    pub fn push(&mut self, n: Neighbor) -> bool {
        if self.cap == 0 {
            return false;
        }
        if self.items.len() < self.cap {
            self.items.push(n);
            self.sift_up(self.items.len() - 1);
            true
        } else if cmp(&n, &self.items[0]) == Ordering::Less {
            self.items[0] = n;
            self.sift_down(0);
            self.evictions += 1;
            true
        } else {
            false
        }
    }

    /// Merge another heap's survivors into this one (bounded heap union).
    /// Backs the ROADMAP's distributed-index follow-up, where per-block
    /// subtree queries merge at query time; the current shuffle combiner
    /// instead merges *weight-encoded rows* via `knn::merge_max`, since
    /// RBF weights are not invertible back to distances losslessly.
    pub fn merge(&mut self, other: TopTHeap) {
        for n in other.items {
            self.push(n);
        }
    }

    /// Drain into a list sorted nearest-first by `(d2, idx)`.
    pub fn into_sorted(self) -> Vec<Neighbor> {
        let mut v = self.items;
        v.sort_unstable_by(cmp);
        v
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if cmp(&self.items[i], &self.items[parent]) == Ordering::Greater {
                self.items.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        let len = self.items.len();
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut largest = i;
            if l < len && cmp(&self.items[l], &self.items[largest]) == Ordering::Greater {
                largest = l;
            }
            if r < len && cmp(&self.items[r], &self.items[largest]) == Ordering::Greater {
                largest = r;
            }
            if largest == i {
                return;
            }
            self.items.swap(i, largest);
            i = largest;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nb(d2: f64, idx: u32) -> Neighbor {
        Neighbor { d2, idx }
    }

    #[test]
    fn keeps_the_t_smallest_keys() {
        let mut h = TopTHeap::new(3);
        for (d2, idx) in [(5.0, 0), (1.0, 1), (4.0, 2), (2.0, 3), (3.0, 4)] {
            h.push(nb(d2, idx));
        }
        let got: Vec<u32> = h.into_sorted().iter().map(|n| n.idx).collect();
        assert_eq!(got, vec![1, 3, 4]);
    }

    #[test]
    fn insertion_order_never_changes_the_survivors() {
        let cands = [(2.5, 7u32), (0.5, 3), (2.5, 1), (9.0, 0), (0.1, 9), (2.5, 2)];
        let mut fwd = TopTHeap::new(4);
        let mut rev = TopTHeap::new(4);
        for &(d2, idx) in &cands {
            fwd.push(nb(d2, idx));
        }
        for &(d2, idx) in cands.iter().rev() {
            rev.push(nb(d2, idx));
        }
        assert_eq!(fwd.into_sorted(), rev.into_sorted());
    }

    #[test]
    fn equal_distances_tie_break_by_index() {
        let mut h = TopTHeap::new(2);
        h.push(nb(1.0, 8));
        h.push(nb(1.0, 5));
        h.push(nb(1.0, 2)); // evicts idx 8
        assert_eq!(h.evictions(), 1);
        let got: Vec<u32> = h.into_sorted().iter().map(|n| n.idx).collect();
        assert_eq!(got, vec![2, 5]);
    }

    #[test]
    fn bound_and_evictions_track_fullness() {
        let mut h = TopTHeap::new(2);
        assert_eq!(h.bound(), f64::INFINITY);
        h.push(nb(3.0, 0));
        h.push(nb(1.0, 1));
        assert_eq!(h.bound(), 3.0);
        assert!(!h.push(nb(4.0, 2)), "worse than the bound");
        assert!(h.push(nb(2.0, 3)), "better than the bound");
        assert_eq!(h.bound(), 2.0);
        assert_eq!(h.evictions(), 1);
        assert_eq!(h.len(), 2);
    }

    #[test]
    fn merge_is_a_bounded_union() {
        let mut a = TopTHeap::new(3);
        a.push(nb(1.0, 0));
        a.push(nb(9.0, 1));
        let mut b = TopTHeap::new(3);
        b.push(nb(2.0, 2));
        b.push(nb(3.0, 3));
        a.merge(b);
        let got: Vec<u32> = a.into_sorted().iter().map(|n| n.idx).collect();
        assert_eq!(got, vec![0, 2, 3]);
    }

    #[test]
    fn zero_capacity_keeps_nothing() {
        let mut h = TopTHeap::new(0);
        assert!(!h.push(nb(1.0, 0)));
        assert!(h.is_empty());
    }
}
