//! t-nearest-neighbor similarity subsystem (DESIGN.md §2.10).
//!
//! The paper's phase 1 "calculate the similarity matrix … and then sparse
//! it" is O(n²) when sparsification is a post-filter: every pair is priced
//! before `epsilon` drops it. This subsystem makes sparsification
//! *constructive* instead — the graph is born sparse as a t-nearest-neighbor
//! similarity matrix (the formulation of 1802.04450 and 2212.04443), and
//! candidate pairs are pruned **before** their distance is fully evaluated:
//!
//! - [`heap`]: bounded top-t neighbor heaps with a total `(d2, idx)` order,
//!   so survivors are independent of candidate arrival order;
//! - [`kdtree`]: a bounding-box kd-tree whose subtree and partial-distance
//!   tests are conservative in floating point — query results are
//!   bit-identical to a brute-force scan;
//! - [`job`]: the distributed pipeline (`read_dfs → tnn-query map →
//!   row-merging combiner → max-symmetrization reduce`) writing the same
//!   graph-row table format phase 2 already consumes;
//! - [`tnn_sparse`]: the exact single-machine oracle the distributed path
//!   is byte-identical to.
//!
//! Weights follow the paper: `S_ij = exp(-‖x_i − x_j‖² / 2σ²)` for kept
//! pairs, unit diagonal, symmetrized as `S = max(S, Sᵀ)` — an edge survives
//! when *either* endpoint ranks the other among its `t` nearest.

pub mod heap;
pub mod job;
pub mod kdtree;

use std::sync::Arc;

use crate::linalg::kernels::{self, ScanSink};
use crate::linalg::CsrMatrix;

pub use heap::{Neighbor, TopTHeap};
pub use job::run_tnn_phase;
pub use kdtree::KdTree;

/// How phase 1 builds the sparse similarity graph (`algo.graph`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum GraphMode {
    /// All-pairs RBF, entries below `algo.epsilon` dropped (paper Alg. 4.2).
    #[default]
    Epsilon,
    /// t-nearest-neighbor graph via the spatial index (this subsystem).
    Tnn,
}

impl GraphMode {
    /// Parse a config/CLI value.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "epsilon" => Some(Self::Epsilon),
            "tnn" => Some(Self::Tnn),
            _ => None,
        }
    }

    /// The config spelling.
    pub fn as_str(&self) -> &'static str {
        match self {
            Self::Epsilon => "epsilon",
            Self::Tnn => "tnn",
        }
    }
}

/// Which spatial index answers t-NN queries (`knn.index`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum IndexKind {
    /// Bounding-box kd-tree (subtree + partial-distance pruning).
    #[default]
    KdTree,
    /// Linear scan with partial-distance pruning only (reference/debug).
    Brute,
}

impl IndexKind {
    /// Parse a config/CLI value.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "kdtree" => Some(Self::KdTree),
            "brute" => Some(Self::Brute),
            _ => None,
        }
    }

    /// The config spelling.
    pub fn as_str(&self) -> &'static str {
        match self {
            Self::KdTree => "kdtree",
            Self::Brute => "brute",
        }
    }
}

/// `[knn]` config section: t-NN graph construction knobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KnnConfig {
    /// Neighbors kept per row before symmetrization (clamped to n−1).
    pub t: usize,
    /// kd-tree leaf bucket size.
    pub leaf_size: usize,
    /// Spatial index answering the queries.
    pub index: IndexKind,
}

impl Default for KnnConfig {
    fn default() -> Self {
        Self { t: 10, leaf_size: 16, index: IndexKind::KdTree }
    }
}

/// [`ScanSink`] feeding a candidate scan into a [`TopTHeap`]: the heap's
/// current worst survivor is the abort bound, completed distances are
/// pushed (the heap's total `(d2, idx)` order rejects losers), aborted
/// candidates count as pruned. Both the brute scan and the kd-tree leaf
/// scan drain the blocked distance kernels through this sink.
pub(crate) struct HeapSink<'a> {
    /// Destination heap (bound source + survivor store).
    pub heap: &'a mut TopTHeap,
    /// Pruning tallies to update.
    pub stats: &'a mut QueryStats,
}

impl ScanSink for HeapSink<'_> {
    fn bound(&self) -> f64 {
        self.heap.bound()
    }

    fn emit(&mut self, id: u32, d2: Option<f64>) {
        match d2 {
            Some(d2) => {
                self.stats.pairs_evaluated += 1;
                self.heap.push(Neighbor { d2, idx: id });
            }
            None => self.stats.pruned_pairs += 1,
        }
    }
}

/// Per-query/per-task pruning tallies (the `KNN_*` counter feeds).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct QueryStats {
    /// Candidate pairs whose distance was evaluated to completion.
    pub pairs_evaluated: u64,
    /// Candidate pairs dismissed by a bounding-box subtree test or a
    /// partial-distance early exit — never fully priced.
    pub pruned_pairs: u64,
}

/// The exact t-NN oracle: either index answers the same queries, the
/// kd-tree just prices fewer pairs.
pub enum KnnIndex {
    /// Bounding-box kd-tree.
    KdTree(KdTree),
    /// Flat scan (partial-distance pruning only).
    Brute {
        /// Row-major n × d coordinates.
        points: Arc<Vec<f64>>,
        /// Point count.
        n: usize,
        /// Dimensionality.
        d: usize,
    },
}

impl KnnIndex {
    /// Build the configured index over a flat row-major point set.
    pub fn build(points: Arc<Vec<f64>>, n: usize, d: usize, cfg: &KnnConfig) -> Self {
        match cfg.index {
            IndexKind::KdTree => Self::KdTree(KdTree::build(points, n, d, cfg.leaf_size)),
            IndexKind::Brute => Self::Brute { points, n, d },
        }
    }

    /// Number of indexed points.
    pub fn len(&self) -> usize {
        match self {
            Self::KdTree(tree) => tree.len(),
            Self::Brute { n, .. } => *n,
        }
    }

    /// True when no points are indexed.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Point `i` as a coordinate slice.
    pub fn row(&self, i: usize) -> &[f64] {
        match self {
            Self::KdTree(tree) => tree.row(i),
            Self::Brute { points, d, .. } => &points[i * d..(i + 1) * d],
        }
    }

    /// Exact `t` nearest neighbors of `q` (optionally excluding one id).
    pub fn query(
        &self,
        q: &[f64],
        t: usize,
        exclude: Option<u32>,
        stats: &mut QueryStats,
    ) -> TopTHeap {
        match self {
            Self::KdTree(tree) => tree.query(q, t, exclude, stats),
            Self::Brute { points, n, d } => {
                let mut heap = TopTHeap::new(t);
                if t == 0 {
                    return heap;
                }
                let mut sink = HeapSink { heap: &mut heap, stats };
                kernels::sq_dist_scan_range(
                    q,
                    points.as_slice(),
                    *d,
                    0,
                    *n as u32,
                    exclude,
                    &mut sink,
                );
                heap
            }
        }
    }
}

/// Collapse duplicate columns keeping the max weight — the
/// max-symmetrization merge (`S = max(S, Sᵀ)`) the combiner, the reducer
/// and the oracle all share. Leaves `entries` sorted by column.
pub(crate) fn merge_max(entries: &mut Vec<(u32, f64)>) {
    entries.sort_unstable_by(|a, b| a.0.cmp(&b.0).then(b.1.total_cmp(&a.1)));
    entries.dedup_by_key(|e| e.0);
}

/// Exact single-machine t-NN similarity oracle: RBF weights on each row's
/// `min(t, n−1)` nearest neighbors, unit diagonal, `S = max(S, Sᵀ)`
/// symmetrization. The distributed [`job`] pipeline is byte-identical to
/// this function.
pub fn tnn_sparse(points: &[Vec<f64>], sigma: f64, cfg: &KnnConfig) -> CsrMatrix {
    let n = points.len();
    if n == 0 {
        return CsrMatrix::from_rows(0, Vec::new());
    }
    let d = points[0].len();
    let flat: Arc<Vec<f64>> = Arc::new(points.iter().flatten().copied().collect());
    let index = KnnIndex::build(flat.clone(), n, d, cfg);
    let gamma = crate::spectral::gamma_of_sigma(sigma);
    let mut stats = QueryStats::default();
    let mut rows: Vec<Vec<(u32, f64)>> = (0..n)
        .map(|i| {
            let mut r = Vec::with_capacity(cfg.t + 2);
            r.push((i as u32, 1.0));
            r
        })
        .collect();
    for i in 0..n {
        let heap = index.query(index.row(i), cfg.t, Some(i as u32), &mut stats);
        for nb in heap.into_sorted() {
            let w = (-gamma * nb.d2).exp();
            rows[i].push((nb.idx, w));
            rows[nb.idx as usize].push((i as u32, w));
        }
    }
    for r in rows.iter_mut() {
        merge_max(r);
    }
    CsrMatrix::from_rows(n, rows)
}

/// The σ auto-tuning heuristic (`algo.sigma = "auto"`, per 1802.04450):
/// the mean distance to each point's t-th nearest neighbor, with `t`
/// clamped to n−1. Reuses the configured spatial index, so the estimate
/// prices far fewer pairs than an all-pairs scan.
pub fn auto_sigma(
    points: Arc<Vec<f64>>,
    n: usize,
    d: usize,
    cfg: &KnnConfig,
) -> crate::error::Result<f64> {
    let bad = |msg: String| crate::error::Error::Config(format!("sigma auto: {msg}"));
    if n < 2 {
        return Err(bad(format!("needs at least 2 points, got {n}")));
    }
    let t = cfg.t.clamp(1, n - 1);
    let index = KnnIndex::build(points, n, d, cfg);
    let mut stats = QueryStats::default();
    let mut total = 0.0f64;
    for i in 0..n {
        let heap = index.query(index.row(i), t, Some(i as u32), &mut stats);
        let sorted = heap.into_sorted();
        // Ascending (d2, idx) order: the last survivor IS the t-th neighbor.
        let tth = sorted
            .last()
            .ok_or_else(|| bad(format!("point {i} has no neighbors")))?;
        total += tth.d2.sqrt();
    }
    let sigma = total / n as f64;
    if !(sigma.is_finite() && sigma > 0.0) {
        return Err(bad(format!(
            "degenerate estimate {sigma} (all points coincide?)"
        )));
    }
    Ok(sigma)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pts() -> Vec<Vec<f64>> {
        vec![
            vec![0.0, 0.0],
            vec![1.0, 0.0],
            vec![0.0, 1.2],
            vec![10.0, 10.0],
            vec![10.5, 10.0],
        ]
    }

    #[test]
    fn oracle_weights_match_the_rbf_formula() {
        let s = tnn_sparse(&pts(), 1.0, &KnnConfig { t: 1, ..Default::default() });
        // Point 0's nearest is 1 at d2 = 1.
        assert!((s.get(0, 1) - (-0.5f64).exp()).abs() < 1e-15);
        assert_eq!(s.get(0, 0), 1.0, "unit diagonal");
        assert_eq!(s.get(0, 3), 0.0, "far pair never materialized");
    }

    #[test]
    fn max_symmetrization_keeps_one_sided_edges() {
        // With t = 1: 2's nearest is 0, but 0's nearest is 1. The (2, 0)
        // edge must survive in BOTH rows via S = max(S, Sᵀ).
        let s = tnn_sparse(&pts(), 1.0, &KnnConfig { t: 1, ..Default::default() });
        assert!(s.get(2, 0) > 0.0);
        assert_eq!(s.get(2, 0), s.get(0, 2));
        assert!(s.is_symmetric(0.0), "exactly symmetric");
    }

    #[test]
    fn t_clamps_to_n_minus_one() {
        let s = tnn_sparse(&pts(), 1.0, &KnnConfig { t: 100, ..Default::default() });
        for i in 0..5 {
            assert_eq!(s.row_nnz(i), 5, "t >= n-1 degenerates to dense");
        }
    }

    #[test]
    fn mode_and_index_parse_roundtrip() {
        for m in [GraphMode::Epsilon, GraphMode::Tnn] {
            assert_eq!(GraphMode::parse(m.as_str()), Some(m));
        }
        for k in [IndexKind::KdTree, IndexKind::Brute] {
            assert_eq!(IndexKind::parse(k.as_str()), Some(k));
        }
        assert_eq!(GraphMode::parse("banana"), None);
        assert_eq!(IndexKind::parse(""), None);
    }

    #[test]
    fn merge_max_dedups_keeping_the_heavier_entry() {
        let mut e = vec![(3u32, 0.5), (1, 0.9), (3, 0.7), (2, 0.1)];
        merge_max(&mut e);
        assert_eq!(e, vec![(1, 0.9), (2, 0.1), (3, 0.7)]);
    }

    #[test]
    fn auto_sigma_is_the_mean_tth_neighbor_distance() {
        // Points on a line at 0, 1, 3: with t = 1 the nearest-neighbor
        // distances are 1, 1, 2 → mean 4/3.
        let flat = Arc::new(vec![0.0, 1.0, 3.0]);
        let cfg = KnnConfig { t: 1, ..Default::default() };
        let s = auto_sigma(flat.clone(), 3, 1, &cfg).unwrap();
        assert!((s - 4.0 / 3.0).abs() < 1e-12, "got {s}");
        // Both index kinds agree bit-for-bit (kd-tree pruning is exact).
        let brute =
            KnnConfig { t: 1, index: IndexKind::Brute, ..Default::default() };
        assert_eq!(
            s.to_bits(),
            auto_sigma(flat, 3, 1, &brute).unwrap().to_bits()
        );
    }

    #[test]
    fn auto_sigma_clamps_t_and_rejects_degenerate_input() {
        // t far above n-1 clamps: with 2 points the 1st neighbor is used.
        let flat = Arc::new(vec![0.0, 2.0]);
        let cfg = KnnConfig { t: 50, ..Default::default() };
        assert!((auto_sigma(flat, 2, 1, &cfg).unwrap() - 2.0).abs() < 1e-12);
        assert!(auto_sigma(Arc::new(vec![0.0]), 1, 1, &cfg).is_err(), "n < 2");
        let coincident = Arc::new(vec![1.0, 1.0, 1.0]);
        assert!(auto_sigma(coincident, 3, 1, &cfg).is_err(), "zero distances");
    }

    #[test]
    fn empty_input_yields_empty_matrix() {
        let s = tnn_sparse(&[], 1.0, &KnnConfig::default());
        assert_eq!(s.rows(), 0);
        assert_eq!(s.nnz(), 0);
    }
}
