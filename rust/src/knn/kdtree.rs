//! Bounding-box kd-tree: the exact t-NN spatial index.
//!
//! Built once over the flat `n × d` point set (median split on the widest
//! dimension, `leaf_size` bucket leaves), then queried per row. A query
//! descends nearer-child-first and prunes whole subtrees whose bounding box
//! cannot beat the heap's current worst distance; leaf scans run through
//! the blocked distance kernel ([`crate::linalg::kernels`]), which aborts
//! candidates early once their running sum passes the same bound. Both
//! tests are conservative in floating point (the computed box distance
//! never exceeds the computed point distance, and equality never prunes),
//! so the result is **bit-identical to a brute-force scan** — the property
//! the oracle-equivalence tests pin.

use std::sync::Arc;

use crate::linalg::kernels;

use super::heap::TopTHeap;
use super::{HeapSink, QueryStats};

/// One tree node; `start..end` is its contiguous slice of [`KdTree::order`].
struct Node {
    start: usize,
    end: usize,
    /// Per-dimension bounding box of the subtree's points.
    lo: Vec<f64>,
    hi: Vec<f64>,
    /// Child node ids, `None` for leaves.
    children: Option<(usize, usize)>,
}

/// Exact t-NN kd-tree over a flat row-major point set.
pub struct KdTree {
    points: Arc<Vec<f64>>,
    n: usize,
    d: usize,
    /// Point ids, partitioned so every node's points are contiguous.
    order: Vec<u32>,
    nodes: Vec<Node>,
    root: Option<usize>,
}

impl KdTree {
    /// Build over `n` points of dimension `d` (row-major in `points`).
    pub fn build(points: Arc<Vec<f64>>, n: usize, d: usize, leaf_size: usize) -> Self {
        assert!(points.len() >= n * d, "kdtree: {n}x{d} points short");
        let leaf_size = leaf_size.max(1);
        let mut order: Vec<u32> = (0..n as u32).collect();
        let mut nodes = Vec::new();
        let root = if n == 0 {
            None
        } else {
            Some(build_node(&points, d, leaf_size, &mut order, 0, n, &mut nodes))
        };
        Self { points, n, d, order, nodes, root }
    }

    /// Number of indexed points.
    pub fn len(&self) -> usize {
        self.n
    }

    /// True when no points are indexed.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Point `i` as a coordinate slice.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.points[i * self.d..(i + 1) * self.d]
    }

    /// Exact `t` nearest neighbors of `q` (optionally excluding one id).
    pub fn query(
        &self,
        q: &[f64],
        t: usize,
        exclude: Option<u32>,
        stats: &mut QueryStats,
    ) -> TopTHeap {
        let mut heap = TopTHeap::new(t);
        if t > 0 {
            if let Some(root) = self.root {
                self.visit(root, self.min_sq_dist(root, q), q, exclude, &mut heap, stats);
            }
        }
        heap
    }

    /// Descend into `node` unless its box distance proves it sterile.
    fn visit(
        &self,
        node: usize,
        min_d2: f64,
        q: &[f64],
        exclude: Option<u32>,
        heap: &mut TopTHeap,
        stats: &mut QueryStats,
    ) {
        let nd = &self.nodes[node];
        if min_d2 > heap.bound() {
            stats.pruned_pairs += (nd.end - nd.start) as u64;
            return;
        }
        match nd.children {
            None => {
                let mut sink = HeapSink { heap, stats };
                kernels::sq_dist_scan_ids(
                    q,
                    self.points.as_slice(),
                    self.d,
                    &self.order[nd.start..nd.end],
                    exclude,
                    &mut sink,
                );
            }
            Some((l, r)) => {
                let dl = self.min_sq_dist(l, q);
                let dr = self.min_sq_dist(r, q);
                // Nearer child first: its hits shrink the bound before the
                // farther sibling is tested against it.
                if dl <= dr {
                    self.visit(l, dl, q, exclude, heap, stats);
                    self.visit(r, dr, q, exclude, heap, stats);
                } else {
                    self.visit(r, dr, q, exclude, heap, stats);
                    self.visit(l, dl, q, exclude, heap, stats);
                }
            }
        }
    }

    /// Squared distance from `q` to the node's bounding box (0 inside).
    fn min_sq_dist(&self, node: usize, q: &[f64]) -> f64 {
        let nd = &self.nodes[node];
        let mut acc = 0.0f64;
        for (c, &v) in q.iter().enumerate() {
            let excess = if v < nd.lo[c] {
                nd.lo[c] - v
            } else if v > nd.hi[c] {
                v - nd.hi[c]
            } else {
                0.0
            };
            acc += excess * excess;
        }
        acc
    }
}

/// Recursively build the subtree over `order[start..end]`; returns its id.
fn build_node(
    points: &[f64],
    d: usize,
    leaf_size: usize,
    order: &mut [u32],
    start: usize,
    end: usize,
    nodes: &mut Vec<Node>,
) -> usize {
    let mut lo = vec![f64::INFINITY; d];
    let mut hi = vec![f64::NEG_INFINITY; d];
    for &id in &order[start..end] {
        let p = &points[id as usize * d..(id as usize + 1) * d];
        for c in 0..d {
            lo[c] = lo[c].min(p[c]);
            hi[c] = hi[c].max(p[c]);
        }
    }
    let len = end - start;
    // Widest dimension; ties resolve to the lowest dimension index so the
    // tree shape is a pure function of the point set.
    let mut dim = 0;
    let mut width = hi[0] - lo[0];
    for c in 1..d {
        let w = hi[c] - lo[c];
        if w > width {
            width = w;
            dim = c;
        }
    }
    if len <= leaf_size || width <= 0.0 {
        // Small bucket — or every point identical, which no split separates.
        nodes.push(Node { start, end, lo, hi, children: None });
        return nodes.len() - 1;
    }
    let mid = len / 2;
    order[start..end].select_nth_unstable_by(mid, |&a, &b| {
        points[a as usize * d + dim]
            .total_cmp(&points[b as usize * d + dim])
            .then(a.cmp(&b))
    });
    let left = build_node(points, d, leaf_size, order, start, start + mid, nodes);
    let right = build_node(points, d, leaf_size, order, start + mid, end, nodes);
    nodes.push(Node { start, end, lo, hi, children: Some((left, right)) });
    nodes.len() - 1
}

#[cfg(test)]
mod tests {
    use super::super::heap::Neighbor;
    use super::*;
    use crate::linalg::vector::sq_dist_bounded;
    use crate::util::rng::Xoshiro256;

    fn random_points(n: usize, d: usize, seed: u64) -> Arc<Vec<f64>> {
        let mut rng = Xoshiro256::new(seed);
        Arc::new(
            (0..n * d)
                .map(|_| (rng.next_u64() >> 11) as f64 / (1u64 << 53) as f64 * 10.0)
                .collect(),
        )
    }

    /// Brute-force reference with the same tie semantics.
    fn brute(points: &[f64], n: usize, d: usize, q: &[f64], t: usize, skip: u32) -> Vec<Neighbor> {
        let mut heap = TopTHeap::new(t);
        for j in 0..n {
            if j as u32 == skip {
                continue;
            }
            let p = &points[j * d..(j + 1) * d];
            if let Some(d2) = sq_dist_bounded(q, p, f64::INFINITY) {
                heap.push(Neighbor { d2, idx: j as u32 });
            }
        }
        heap.into_sorted()
    }

    #[test]
    fn matches_brute_force_bitwise() {
        let (n, d) = (200, 3);
        let pts = random_points(n, d, 42);
        for leaf in [1usize, 4, 16] {
            let tree = KdTree::build(pts.clone(), n, d, leaf);
            let mut stats = QueryStats::default();
            for i in (0..n).step_by(13) {
                let got = tree
                    .query(tree.row(i), 7, Some(i as u32), &mut stats)
                    .into_sorted();
                let want = brute(&pts, n, d, tree.row(i), 7, i as u32);
                assert_eq!(got.len(), want.len(), "i={i} leaf={leaf}");
                for (g, w) in got.iter().zip(&want) {
                    assert_eq!(g.idx, w.idx, "i={i} leaf={leaf}");
                    assert_eq!(g.d2.to_bits(), w.d2.to_bits(), "i={i} leaf={leaf}");
                }
            }
        }
    }

    #[test]
    fn pruning_actually_skips_work() {
        let (n, d) = (400, 2);
        let pts = random_points(n, d, 7);
        let tree = KdTree::build(pts.clone(), n, d, 8);
        let mut stats = QueryStats::default();
        for i in 0..n {
            tree.query(tree.row(i), 5, Some(i as u32), &mut stats);
        }
        assert!(stats.pruned_pairs > 0, "no pruning on 400 planar points");
        let seen = stats.pairs_evaluated + stats.pruned_pairs;
        assert_eq!(seen, (n * (n - 1)) as u64, "every candidate accounted for");
        assert!(
            stats.pairs_evaluated < seen / 2,
            "index should dodge most full distances: {stats:?}"
        );
    }

    #[test]
    fn duplicate_points_and_tiny_sets() {
        // All-identical points: unsplittable, still answers exactly.
        let pts: Arc<Vec<f64>> = Arc::new(vec![1.0; 10 * 2]);
        let tree = KdTree::build(pts, 10, 2, 4);
        let mut stats = QueryStats::default();
        let got = tree.query(tree.row(0), 3, Some(0), &mut stats).into_sorted();
        let ids: Vec<u32> = got.iter().map(|nb| nb.idx).collect();
        assert_eq!(ids, vec![1, 2, 3], "zero distances tie-break by index");
        // Empty and single-point sets.
        let empty = KdTree::build(Arc::new(Vec::new()), 0, 2, 4);
        assert!(empty.is_empty());
        assert!(empty.query(&[0.0, 0.0], 3, None, &mut stats).is_empty());
        let one = KdTree::build(Arc::new(vec![5.0, 5.0]), 1, 2, 4);
        assert_eq!(one.len(), 1);
        assert!(one.query(one.row(0), 3, Some(0), &mut stats).is_empty());
    }
}
