//! In-tree micro-benchmark harness (no criterion in the offline vendor set).
//!
//! [`bench`] runs warmup + timed iterations and reports min/median/mean —
//! enough statistics for the kernel and ablation benches. Experiment-scale
//! benches (table1, fig5) measure whole pipeline runs once per
//! configuration; the virtual clock makes those deterministic.

use std::time::{Duration, Instant};

/// Summary statistics of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchStats {
    /// Benchmark label.
    pub name: String,
    /// Timed iterations.
    pub iters: usize,
    /// Fastest iteration.
    pub min: Duration,
    /// Median iteration.
    pub median: Duration,
    /// Mean iteration.
    pub mean: Duration,
}

impl BenchStats {
    /// One-line human-readable rendering.
    pub fn render(&self) -> String {
        format!(
            "{:<40} {:>10} min {:>10} med {:>10} mean   ({} iters)",
            self.name,
            crate::util::fmt::human_duration(self.min),
            crate::util::fmt::human_duration(self.median),
            crate::util::fmt::human_duration(self.mean),
            self.iters
        )
    }

    /// JSON object rendering for `BENCH_*.json` payloads.
    pub fn json(&self) -> String {
        format!(
            "{{\"name\":\"{}\",\"iters\":{},\"min_ns\":{},\"median_ns\":{},\
             \"mean_ns\":{}}}",
            crate::trace::json::esc(&self.name),
            self.iters,
            self.min.as_nanos(),
            self.median.as_nanos(),
            self.mean.as_nanos(),
        )
    }
}

/// Render a whole bench's results as one `BENCH_*.json` document.
pub fn stats_json(bench: &str, stats: &[BenchStats]) -> String {
    let entries: Vec<String> = stats.iter().map(BenchStats::json).collect();
    format!(
        "{{\"bench\":\"{}\",\"results\":[{}]}}\n",
        crate::trace::json::esc(bench),
        entries.join(",")
    )
}

/// Like [`stats_json`] with an extra `speedup` object: one
/// `name → ratio` entry per comparison (scalar median / blocked median
/// in the kernels bench).
pub fn stats_json_with_speedups(
    bench: &str,
    stats: &[BenchStats],
    speedups: &[(&str, f64)],
) -> String {
    let entries: Vec<String> = stats.iter().map(BenchStats::json).collect();
    let ratios: Vec<String> = speedups
        .iter()
        .map(|(name, r)| format!("\"{}\":{:.4}", crate::trace::json::esc(name), r))
        .collect();
    format!(
        "{{\"bench\":\"{}\",\"results\":[{}],\"speedup\":{{{}}}}}\n",
        crate::trace::json::esc(bench),
        entries.join(","),
        ratios.join(",")
    )
}

/// One row of the cross-bench trajectory log (`BENCH_trajectory.json`):
/// which bench ran, where its payload landed, the headline makespan and
/// the seed it echoes. Deliberately timestamp-free so same-seed reruns
/// append byte-identical rows.
#[derive(Debug, Clone)]
pub struct TrajectoryRow<'a> {
    /// Bench name (matches the payload's `bench`/`experiment` key).
    pub bench: &'a str,
    /// Path of the `BENCH_*.json` payload this row points at.
    pub report: &'a str,
    /// Headline virtual makespan of the bench's last/largest run (0 for
    /// wall-clock-only micro-benches).
    pub makespan_s: f64,
    /// The data seed the bench ran with.
    pub seed: u64,
}

/// Render one trajectory row as a JSON object.
pub fn trajectory_row_json(row: &TrajectoryRow) -> String {
    format!(
        "{{\"bench\":\"{}\",\"report\":\"{}\",\"makespan_s\":{},\"seed\":{}}}",
        crate::trace::json::esc(row.bench),
        crate::trace::json::esc(row.report),
        crate::trace::json::num(row.makespan_s),
        row.seed
    )
}

/// Append a row to the JSON-array log at `path`, creating the file on
/// first use. An unparseable file is restarted rather than corrupted
/// further.
pub fn append_trajectory_at(
    path: &std::path::Path,
    row: &TrajectoryRow,
) -> std::io::Result<()> {
    let entry = trajectory_row_json(row);
    let doc = match std::fs::read_to_string(path) {
        Ok(text) => {
            let trimmed = text.trim_end();
            match trimmed.strip_suffix(']') {
                Some(body) if body.trim_end().ends_with('[') => {
                    format!("{}{entry}]\n", body.trim_end())
                }
                Some(body) => format!("{},\n{entry}]\n", body.trim_end()),
                None => format!("[{entry}]\n"),
            }
        }
        Err(_) => format!("[{entry}]\n"),
    };
    std::fs::write(path, doc)
}

/// Append a row to `BENCH_trajectory.json` beside Cargo.toml — the single
/// cross-bench log every `BENCH_*.json` writer also feeds. Warn-only like
/// `write_bench_json`: benches keep running on read-only checkouts.
pub fn append_trajectory(row: &TrajectoryRow) {
    let target = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("BENCH_trajectory.json");
    if let Err(e) = append_trajectory_at(&target, row) {
        eprintln!("warning: could not append {}: {e}", target.display());
    }
}

/// (warmup, iters) for a bench binary, overridable via the environment
/// (`PSCH_BENCH_WARMUP` / `PSCH_BENCH_ITERS`) so CI can run reduced
/// iteration counts; `iters` is clamped to at least 1.
pub fn bench_params(default_warmup: usize, default_iters: usize) -> (usize, usize) {
    let read = |key: &str, default: usize| {
        std::env::var(key)
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or(default)
    };
    (
        read("PSCH_BENCH_WARMUP", default_warmup),
        read("PSCH_BENCH_ITERS", default_iters).max(1),
    )
}

/// Run `f` for `warmup` untimed + `iters` timed iterations.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<Duration> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples.sort();
    let mean = samples.iter().sum::<Duration>() / iters as u32;
    BenchStats {
        name: name.to_string(),
        iters,
        min: samples[0],
        median: samples[iters / 2],
        mean,
    }
}

/// Time a single invocation (for expensive whole-pipeline benches).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

/// The A2 locality-ablation experiment, shared by
/// `benches/ablation_loadbalance.rs` and `rust/tests/test_scheduler.rs` so
/// the bench and the asserting test always run the identical setup: the
/// phase-1 similarity job on a 4-slave / 2-rack cluster whose read tiers
/// are clearly separated (disk 100 MB/s, rack 40 MB/s, cross-rack 10 MB/s)
/// and whose DFS blocks each hold exactly one 128-row point block (d = 4,
/// f32). Returns the locality summary and the phase's virtual seconds.
pub fn locality_ablation_run(
    policy: crate::scheduler::Policy,
) -> (crate::metrics::LocalitySummary, f64) {
    use std::sync::Arc;

    let n = 13 * 128; // 13 row blocks -> 7 paired map tasks
    let model = crate::cluster::NetworkModel {
        disk_bw: 100e6,
        rack_bw: 40e6,
        cross_rack_bw: 10e6,
        ..crate::cluster::NetworkModel::default()
    };
    let topo = crate::scheduler::RackTopology::uniform(4, 2);
    let mut cluster = crate::cluster::Cluster::with_model(4, 2, model);
    cluster.set_topology(topo.clone());
    cluster.set_tracker_config(crate::scheduler::TrackerConfig {
        policy,
        ..Default::default()
    });
    let mut svc = crate::coordinator::Services::new(
        cluster,
        Arc::new(crate::runtime::KernelRuntime::native()),
    );
    svc.dfs = crate::dfs::Dfs::with_topology(4, 2, 128 * 4 * 4, topo);
    let ps = crate::data::gaussian_blobs(n, 4, 4, 0.3, 10.0, 11);
    let flat: Vec<f32> = ps.points.iter().flatten().map(|&x| x as f32).collect();
    let out = crate::coordinator::similarity_job::run_similarity_phase(
        &svc,
        Arc::new(flat),
        n,
        4,
        1.5,
        1e-8,
        "S",
    )
    .expect("similarity phase");
    (
        crate::metrics::LocalitySummary::from_counters(&out.counters),
        out.stats.virtual_s,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_ordered_stats() {
        let mut x = 0u64;
        let stats = bench("noop", 2, 11, || {
            x = x.wrapping_add(1);
        });
        assert_eq!(stats.iters, 11);
        assert!(stats.min <= stats.median);
        assert!(stats.median <= stats.mean * 3);
        assert!(x >= 13);
        assert!(stats.render().contains("noop"));
    }

    #[test]
    fn time_once_returns_value() {
        let (v, d) = time_once(|| 21 * 2);
        assert_eq!(v, 42);
        assert!(d.as_nanos() > 0);
    }

    #[test]
    fn stats_json_is_parseable() {
        let stats = bench("k [xla]", 0, 3, || {});
        let doc = stats_json("kernels", &[stats]);
        let v = crate::trace::json::Value::parse(&doc).unwrap();
        assert_eq!(v.get("bench").unwrap().as_str(), Some("kernels"));
        let results = v.get("results").unwrap().items().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].get("name").unwrap().as_str(), Some("k [xla]"));
        assert_eq!(results[0].get("iters").unwrap().as_u64(), Some(3));
    }

    #[test]
    fn stats_json_with_speedups_carries_the_ratio_object() {
        let stats = bench("spmv [scalar]", 0, 2, || {});
        let doc = stats_json_with_speedups(
            "kernels",
            &[stats],
            &[("spmv_rows", 1.75), ("assign_tile", 2.0)],
        );
        let v = crate::trace::json::Value::parse(&doc).unwrap();
        assert_eq!(v.get("bench").unwrap().as_str(), Some("kernels"));
        assert_eq!(v.get("results").unwrap().items().unwrap().len(), 1);
        let sp = v.get("speedup").unwrap();
        assert!((sp.get("spmv_rows").unwrap().as_f64().unwrap() - 1.75).abs() < 1e-9);
        assert!((sp.get("assign_tile").unwrap().as_f64().unwrap() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn trajectory_appends_grow_one_array() {
        let dir = std::env::temp_dir().join("psch_trajectory_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_trajectory.json");
        let _ = std::fs::remove_file(&path);
        let row = |bench: &'static str, mk: f64| TrajectoryRow {
            bench,
            report: "BENCH_x.json",
            makespan_s: mk,
            seed: 42,
        };
        append_trajectory_at(&path, &row("table1", 5673.0)).unwrap();
        append_trajectory_at(&path, &row("fig5", 5753.5)).unwrap();
        let v = crate::trace::json::Value::parse(
            &std::fs::read_to_string(&path).unwrap(),
        )
        .unwrap();
        let rows = v.items().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get("bench").unwrap().as_str(), Some("table1"));
        assert_eq!(rows[1].get("bench").unwrap().as_str(), Some("fig5"));
        assert_eq!(rows[1].get("seed").unwrap().as_u64(), Some(42));
        assert!(
            (rows[1].get("makespan_s").unwrap().as_f64().unwrap() - 5753.5)
                .abs()
                < 1e-9
        );
        // A corrupt log restarts instead of growing garbage.
        std::fs::write(&path, "not json").unwrap();
        append_trajectory_at(&path, &row("kernels", 0.0)).unwrap();
        let v = crate::trace::json::Value::parse(
            &std::fs::read_to_string(&path).unwrap(),
        )
        .unwrap();
        assert_eq!(v.items().unwrap().len(), 1);
    }

    #[test]
    fn bench_params_defaults_without_env_overrides() {
        // The CI override variables are absent in the test environment, so
        // the defaults pass through (iters clamped to >= 1).
        std::env::remove_var("PSCH_BENCH_WARMUP");
        std::env::remove_var("PSCH_BENCH_ITERS");
        assert_eq!(bench_params(3, 30), (3, 30));
        assert_eq!(bench_params(0, 0), (0, 1), "iters clamps to 1");
    }
}
