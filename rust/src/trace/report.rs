//! The unified RunReport: one JSON document per run (`psch run
//! --report-json`) carrying the config echo, per-phase stats + counters,
//! every existing summary family (Locality/Shuffle/Fault/Knn), eval
//! metrics, and — when tracing was on — the critical-path/straggler
//! analysis. Benches and CI consume this one schema instead of scraping
//! CLI lines.
//!
//! Schema (`psch.run_report.v2`; field glossary in DESIGN.md §2.11 and
//! §2.15). v2 is a strict superset of v1: the `timeseries` and
//! `histograms` keys were **added**, every v1 key is unchanged, so v1
//! parsers keep working on v2 documents (and the [`crate::telemetry::diff`]
//! reader accepts both versions):
//!
//! ```text
//! { schema:     "psch.run_report.v2",
//!   config:     { cluster{..} shuffle{..} faults{..} knn{..} algo{..}
//!                 eigen{..} serving{..} },
//!   phases:     [ { name, virtual_s, wall_s, jobs, shuffle_bytes,
//!                   shuffle_fetch_s, locality{..}, shuffle{..}, faults{..},
//!                   knn{..}, eigen{..}, serving{..},
//!                   counters{NAME:value,..} } ],
//!   totals:     { virtual_s, wall_s, jobs, nnz, sigma_resolved },
//!   quality:    { nmi, ari } | null,
//!   trace:      { makespan_s, jobs, critical_path{..}, stragglers[..],
//!                 reduce_skew[..] } | null,
//!   timeseries: { samples, times_s[..], gauges[..] } | null,
//!   histograms: [ { name, unit, edges[..], counts[..], count, sum,
//!                   p50, p95, max } ] | null }
//! ```

use super::critical;
use super::json::{esc, num};
use super::TraceData;
use crate::config::{Config, SigmaSpec};
use crate::coordinator::{PhaseStats, PipelineResult};
use crate::metrics::LocalitySummary;

/// The RunReport schema identifier. v2 added the `timeseries` and
/// `histograms` telemetry sections (additively — v1 parsers keep working).
pub const RUN_REPORT_SCHEMA: &str = "psch.run_report.v2";

fn config_json(cfg: &Config) -> String {
    let c = &cfg.cluster;
    let a = &cfg.algo;
    // `algo.sigma` echoes as a number when fixed and as the string
    // "auto" when the run estimates it from the t-NN graph; the value a
    // run actually used lands in `totals.sigma_resolved` either way.
    let sigma = match a.sigma {
        SigmaSpec::Fixed(v) => num(v),
        SigmaSpec::Auto => "\"auto\"".to_string(),
    };
    format!(
        "{{\"cluster\":{{\"slaves\":{},\"slots_per_slave\":{},\"replication\":{},\
         \"racks\":{},\"scheduler\":\"{}\",\"heartbeat_s\":{},\
         \"speculation_enabled\":{}}},\
         \"shuffle\":{{\"sort_buffer_kb\":{},\"merge_factor\":{},\
         \"fetch_parallelism\":{}}},\
         \"faults\":{{\"task_fail_prob\":{},\"max_attempts\":{},\
         \"blacklist_after\":{},\"node_deaths\":{}}},\
         \"knn\":{{\"t\":{},\"leaf_size\":{}}},\
         \"algo\":{{\"k\":{},\"sigma\":{},\"epsilon\":{},\"graph\":\"{}\",\
         \"lanczos_steps\":{},\"kmeans_iters\":{},\"kmeans_tol\":{},\
         \"seed\":{}}},\
         \"eigen\":{{\"solver\":\"{}\",\"block_size\":{},\"filter_degree\":{},\
         \"max_outer\":{},\"residual_tol\":{},\"bound_steps\":{}}},\
         \"serving\":{{\"landmarks\":{},\"batch_points\":{},\
         \"refresh\":\"{}\"}}}}",
        c.slaves,
        c.slots_per_slave,
        c.replication,
        c.racks,
        esc(&format!("{:?}", c.scheduler)),
        num(c.heartbeat_s),
        c.speculation.enabled,
        cfg.shuffle.sort_buffer_kb,
        cfg.shuffle.merge_factor,
        cfg.shuffle.fetch_parallelism,
        num(cfg.faults.task_fail_prob),
        cfg.faults.max_attempts,
        cfg.faults.blacklist_after,
        cfg.faults.node_deaths.len(),
        cfg.knn.t,
        cfg.knn.leaf_size,
        a.k,
        sigma,
        num(a.epsilon),
        a.graph.as_str(),
        a.lanczos_steps,
        a.kmeans_iters,
        num(a.kmeans_tol),
        a.seed,
        cfg.eigen.solver.as_str(),
        cfg.eigen.block_size,
        cfg.eigen.filter_degree,
        cfg.eigen.max_outer,
        num(cfg.eigen.residual_tol),
        cfg.eigen.bound_steps,
        cfg.serving.landmarks,
        cfg.serving.batch_points,
        cfg.serving.refresh.as_str(),
    )
}

fn phase_json(p: &PhaseStats) -> String {
    let loc = LocalitySummary::from_counters(&p.counters);
    let sh = p.shuffle_summary();
    let fa = p.fault_summary();
    let kn = p.knn_summary();
    let ei = p.eigen_summary();
    let se = p.serving_summary();
    let counters: Vec<String> =
        p.counters.iter().map(|(k, v)| format!("\"{}\":{v}", esc(k))).collect();
    format!(
        "{{\"name\":\"{}\",\"virtual_s\":{},\"wall_s\":{},\"jobs\":{},\
         \"shuffle_bytes\":{},\"shuffle_fetch_s\":{},\
         \"locality\":{{\"data_local\":{},\"rack_local\":{},\"off_rack\":{},\
         \"speculative_attempts\":{},\"speculative_wins\":{},\
         \"virtual_read_s\":{}}},\
         \"shuffle\":{{\"spills\":{},\"spilled_records\":{},\"merge_passes\":{},\
         \"fetch_node_local\":{},\"fetch_rack_local\":{},\"fetch_off_rack\":{},\
         \"fetch_s\":{}}},\
         \"faults\":{{\"failed_map_attempts\":{},\"failed_reduce_attempts\":{},\
         \"map_reruns\":{},\"fetch_failures\":{},\"blacklisted_slaves\":{},\
         \"node_deaths\":{}}},\
         \"knn\":{{\"pairs_evaluated\":{},\"pruned_pairs\":{},\
         \"heap_evictions\":{}}},\
         \"eigen\":{{\"jobs\":{},\"matvecs_batched\":{},\
         \"filter_degree\":{}}},\
         \"serving\":{{\"points\":{},\"batches\":{},\
         \"refresh_updates\":{}}},\
         \"counters\":{{{}}}}}",
        esc(&p.name),
        num(p.virtual_s),
        num(p.wall_s),
        p.jobs,
        p.shuffle_bytes,
        num(p.shuffle_fetch_s),
        loc.data_local,
        loc.rack_local,
        loc.off_rack,
        loc.speculative_attempts,
        loc.speculative_wins,
        num(loc.virtual_read_s),
        sh.spills,
        sh.spilled_records,
        sh.merge_passes,
        sh.fetch_node_local,
        sh.fetch_rack_local,
        sh.fetch_off_rack,
        num(sh.fetch_s),
        fa.failed_map_attempts,
        fa.failed_reduce_attempts,
        fa.map_reruns,
        fa.fetch_failures,
        fa.blacklisted_slaves,
        fa.node_deaths,
        kn.pairs_evaluated,
        kn.pruned_pairs,
        kn.heap_evictions,
        ei.eigen_jobs,
        ei.matvecs_batched,
        ei.filter_degree,
        se.points,
        se.batches,
        se.refresh_updates,
        counters.join(","),
    )
}

fn trace_json(data: &TraceData) -> String {
    let cp = critical::analyze(data, 10);
    let by_phase: Vec<String> = cp
        .by_phase
        .iter()
        .map(|p| format!("{{\"name\":\"{}\",\"seconds\":{}}}", esc(&p.name), num(p.seconds)))
        .collect();
    let by_kind: Vec<String> = cp
        .by_kind
        .iter()
        .map(|k| format!("{{\"kind\":\"{}\",\"seconds\":{}}}", esc(&k.kind), num(k.seconds)))
        .collect();
    let top: Vec<String> = cp
        .top
        .iter()
        .map(|t| {
            format!(
                "{{\"phase\":\"{}\",\"job\":\"{}\",\"kind\":\"{}\",\
                 \"detail\":\"{}\",\"seconds\":{}}}",
                esc(&t.phase),
                esc(&t.job),
                esc(&t.kind),
                esc(&t.detail),
                num(t.seconds)
            )
        })
        .collect();
    let stragglers: Vec<String> = critical::stragglers(data)
        .iter()
        .map(|s| {
            format!(
                "{{\"phase\":\"{}\",\"attempts\":{},\"p50_s\":{},\"p95_s\":{},\
                 \"max_s\":{}}}",
                esc(&s.phase),
                s.attempts,
                num(s.p50_s),
                num(s.p95_s),
                num(s.max_s)
            )
        })
        .collect();
    let skew: Vec<String> = critical::reduce_skew(data)
        .iter()
        .map(|s| {
            format!(
                "{{\"job\":\"{}\",\"reducers\":{},\"mean_bytes\":{},\
                 \"max_bytes\":{},\"skew\":{}}}",
                esc(&s.job),
                s.reducers,
                num(s.mean_bytes),
                s.max_bytes,
                num(s.skew)
            )
        })
        .collect();
    format!(
        "{{\"makespan_s\":{},\"jobs\":{},\
         \"critical_path\":{{\"total_s\":{},\"by_phase\":[{}],\"by_kind\":[{}],\
         \"top\":[{}]}},\"stragglers\":[{}],\"reduce_skew\":[{}]}}",
        num(data.makespan_s),
        data.jobs.len(),
        num(cp.total_s),
        by_phase.join(","),
        by_kind.join(","),
        top.join(","),
        stragglers.join(","),
        skew.join(","),
    )
}

/// Build the RunReport document. `quality` is `(nmi, ari)` against the
/// planted truth when one exists; `trace` is the recorded trace when
/// tracing was enabled — it also feeds the v2 `timeseries`/`histograms`
/// telemetry sections (null for untraced runs).
pub fn run_report_json(
    cfg: &Config,
    result: &PipelineResult,
    quality: Option<(f64, f64)>,
    trace: Option<&TraceData>,
) -> String {
    let phases: Vec<String> = result.phases.iter().map(phase_json).collect();
    let quality = match quality {
        Some((nmi, ari)) => format!("{{\"nmi\":{},\"ari\":{}}}", num(nmi), num(ari)),
        None => "null".to_string(),
    };
    let (trace, timeseries, histograms) = match trace {
        Some(data) => {
            let tel = crate::telemetry::from_trace(data, cfg.cluster.racks);
            (
                trace_json(data),
                crate::telemetry::timeseries_json(&tel.timeseries),
                crate::telemetry::histograms_json(&tel.histograms),
            )
        }
        None => ("null".to_string(), "null".to_string(), "null".to_string()),
    };
    format!(
        "{{\"schema\":\"{RUN_REPORT_SCHEMA}\",\"config\":{},\"phases\":[{}],\
         \"totals\":{{\"virtual_s\":{},\"wall_s\":{},\"jobs\":{},\"nnz\":{},\
         \"sigma_resolved\":{}}},\
         \"quality\":{quality},\"trace\":{trace},\
         \"timeseries\":{timeseries},\"histograms\":{histograms}}}\n",
        config_json(cfg),
        phases.join(","),
        num(result.total_virtual_s),
        num(result.total_wall_s),
        result.phases.iter().map(|p| p.jobs).sum::<usize>(),
        result.nnz,
        num(result.sigma),
    )
}

#[cfg(test)]
mod tests {
    use super::super::json::Value;
    use super::*;
    use crate::mapreduce::names;

    fn result_fixture() -> PipelineResult {
        let mut phases = vec![
            PhaseStats { name: "similarity".into(), ..Default::default() },
            PhaseStats { name: "eigenvectors".into(), ..Default::default() },
            PhaseStats { name: "kmeans".into(), ..Default::default() },
        ];
        phases[0].virtual_s = 10.0;
        phases[0].jobs = 1;
        phases[0].counters.incr(names::DATA_LOCAL_MAPS, 4);
        phases[0].counters.incr(names::SPILLS, 2);
        phases[1].counters.incr(names::EIGEN_JOBS, 13);
        phases[1].counters.incr(names::MATVECS_BATCHED, 96);
        phases[1].counters.incr(names::CHEB_FILTER_DEGREE, 8);
        phases[2].counters.incr(names::ASSIGN_POINTS, 17);
        phases[2].counters.incr(names::ASSIGN_BATCHES, 2);
        PipelineResult {
            labels: vec![0, 1],
            eigenvalues: vec![0.0, 0.1],
            phases,
            nnz: 42,
            total_virtual_s: 10.0,
            total_wall_s: 0.5,
            sigma: 1.25,
            centers: vec![vec![1.0, 0.0], vec![0.0, 1.0]],
            embedding: vec![1.0, 0.0, 0.0, 1.0],
        }
    }

    #[test]
    fn report_parses_and_carries_the_schema() {
        let cfg = Config::default();
        let text =
            run_report_json(&cfg, &result_fixture(), Some((0.9, 0.8)), None);
        let v = Value::parse(&text).expect("valid JSON");
        assert_eq!(
            v.get("schema").unwrap().as_str(),
            Some(RUN_REPORT_SCHEMA)
        );
        let phases = v.get("phases").unwrap().items().unwrap();
        assert_eq!(phases.len(), 3);
        let sim = &phases[0];
        assert_eq!(sim.get("name").unwrap().as_str(), Some("similarity"));
        assert_eq!(
            sim.get("locality").unwrap().get("data_local").unwrap().as_u64(),
            Some(4)
        );
        assert_eq!(
            sim.get("counters").unwrap().get("SPILLS").unwrap().as_u64(),
            Some(2)
        );
        assert_eq!(
            v.get("quality").unwrap().get("nmi").unwrap().as_f64(),
            Some(0.9)
        );
        assert_eq!(v.get("trace"), Some(&Value::Null));
        assert_eq!(
            v.get("config")
                .unwrap()
                .get("cluster")
                .unwrap()
                .get("slaves")
                .unwrap()
                .as_u64(),
            Some(Config::default().cluster.slaves as u64)
        );
        assert_eq!(v.get("totals").unwrap().get("nnz").unwrap().as_u64(), Some(42));
        // Eigen family: per-phase summary object + config echo.
        let eig = &phases[1];
        assert_eq!(
            eig.get("eigen").unwrap().get("jobs").unwrap().as_u64(),
            Some(13)
        );
        assert_eq!(
            eig.get("eigen").unwrap().get("matvecs_batched").unwrap().as_u64(),
            Some(96)
        );
        assert_eq!(
            eig.get("eigen").unwrap().get("filter_degree").unwrap().as_u64(),
            Some(8)
        );
        let ecfg = v.get("config").unwrap().get("eigen").unwrap();
        assert_eq!(ecfg.get("solver").unwrap().as_str(), Some("lanczos"));
        assert_eq!(
            ecfg.get("block_size").unwrap().as_u64(),
            Some(Config::default().eigen.block_size as u64)
        );
        assert_eq!(
            ecfg.get("filter_degree").unwrap().as_u64(),
            Some(Config::default().eigen.filter_degree as u64)
        );
        // Serving family: per-phase summary object + config echo +
        // resolved sigma in totals.
        let km = &phases[2];
        assert_eq!(
            km.get("serving").unwrap().get("points").unwrap().as_u64(),
            Some(17)
        );
        assert_eq!(
            km.get("serving").unwrap().get("batches").unwrap().as_u64(),
            Some(2)
        );
        let scfg = v.get("config").unwrap().get("serving").unwrap();
        assert_eq!(scfg.get("refresh").unwrap().as_str(), Some("off"));
        assert_eq!(
            scfg.get("batch_points").unwrap().as_u64(),
            Some(Config::default().serving.batch_points as u64)
        );
        assert_eq!(
            v.get("totals").unwrap().get("sigma_resolved").unwrap().as_f64(),
            Some(1.25)
        );
        // A fixed sigma echoes as a number, auto as the string "auto".
        let acfg = v.get("config").unwrap().get("algo").unwrap();
        assert_eq!(acfg.get("sigma").unwrap().as_f64(), Some(1.0));
        let mut auto_cfg = Config::default();
        auto_cfg.algo.sigma = SigmaSpec::Auto;
        let text2 =
            run_report_json(&auto_cfg, &result_fixture(), None, None);
        let v2 = Value::parse(&text2).unwrap();
        assert_eq!(
            v2.get("config")
                .unwrap()
                .get("algo")
                .unwrap()
                .get("sigma")
                .unwrap()
                .as_str(),
            Some("auto")
        );
    }

    #[test]
    fn missing_quality_is_null() {
        let cfg = Config::default();
        let text = run_report_json(&cfg, &result_fixture(), None, None);
        let v = Value::parse(&text).unwrap();
        assert_eq!(v.get("quality"), Some(&Value::Null));
        // Untraced runs carry null telemetry sections too.
        assert_eq!(v.get("timeseries"), Some(&Value::Null));
        assert_eq!(v.get("histograms"), Some(&Value::Null));
    }

    #[test]
    fn traced_report_carries_v2_telemetry_sections() {
        use crate::trace::TraceSink;
        let sink = TraceSink::default();
        sink.enable(2, 2);
        sink.begin_phase("similarity");
        let plan = crate::scheduler::SchedulePlan {
            makespan_s: 4.0,
            attempts: vec![crate::scheduler::Attempt {
                task: 0,
                slave: 0,
                slot: 0,
                start_s: 0.0,
                end_s: 4.0,
                locality: crate::scheduler::Locality::NodeLocal,
                speculative: false,
                won: true,
            }],
            ..Default::default()
        };
        sink.record_job(crate::trace::JobTrace {
            name: "sim:map".into(),
            overhead_s: 1.0,
            virtual_time_s: 5.0,
            map: crate::trace::plan_trace(
                &plan,
                &[],
                &crate::cluster::NetworkModel::default(),
            ),
            reruns: Vec::new(),
            fetch: None,
            reduce: None,
            spill_bytes: Vec::new(),
        });
        sink.end_phase();
        let data = sink.snapshot().unwrap();
        let cfg = Config::default();
        let text =
            run_report_json(&cfg, &result_fixture(), None, Some(&data));
        let v = Value::parse(&text).unwrap();
        assert_eq!(v.get("schema").unwrap().as_str(), Some("psch.run_report.v2"));
        let ts = v.get("timeseries").unwrap();
        assert_eq!(
            ts.get("samples").unwrap().as_u64(),
            Some(crate::telemetry::SAMPLES as u64)
        );
        assert!(!ts.get("gauges").unwrap().items().unwrap().is_empty());
        let hists = v.get("histograms").unwrap().items().unwrap();
        assert_eq!(hists.len(), 4);
        assert_eq!(
            hists[0].get("name").unwrap().as_str(),
            Some("attempt_duration_seconds")
        );
        // The v1 keys are all still present (additive schema change).
        for key in ["config", "phases", "totals", "quality", "trace"] {
            assert!(v.get(key).is_some(), "v1 key {key} missing");
        }
    }
}
